"""Layer-1 Pallas kernel: tiled Gaussian (RBF) kernel matrix.

Computes `K[i,j] = exp(-γ‖x_i − y_j‖²)` blockwise via the Gram-matrix
identity `‖x−y‖² = ‖x‖² + ‖y‖² − 2⟨x,y⟩`: each grid step loads a
(block_r × d) row panel and a (block_c × d) column panel into VMEM, runs the
inner-product block on the MXU, and applies the exp on the VPU. The feature
dimension `d` stays whole inside the block (kernel feature dims here are
small: 1–64 padded to 8/32 lanes).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pairwise_kernel(x_ref, y_ref, gamma_ref, o_ref):
    x = x_ref[...]  # (br, d)
    y = y_ref[...]  # (bc, d)
    gamma = gamma_ref[0]
    xx = jnp.sum(x * x, axis=1, keepdims=True)  # (br, 1)
    yy = jnp.sum(y * y, axis=1, keepdims=True).T  # (1, bc)
    xy = jnp.dot(x, y.T, preferred_element_type=jnp.float32)
    sq = jnp.maximum(xx + yy - 2.0 * xy, 0.0)
    o_ref[...] = jnp.exp(-gamma * sq)


def _block(dim: int, preferred: int) -> int:
    b = min(dim, preferred)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block",))
def gaussian_matrix(
    x: jax.Array, y: jax.Array, gamma: jax.Array, *, block: int = 128
) -> jax.Array:
    """Gaussian kernel matrix between row-feature arrays (f32)."""
    r, d = x.shape
    c, d2 = y.shape
    assert d == d2, f"feature dim mismatch: {x.shape} vs {y.shape}"
    br = _block(r, block)
    bc = _block(c, block)
    grid = (r // br, c // bc)
    gamma_arr = jnp.asarray(gamma, jnp.float32).reshape((1,))
    return pl.pallas_call(
        _pairwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), y.astype(jnp.float32), gamma_arr)
