"""Layer-1 Pallas kernel: MXU-tiled matrix multiplication.

This is the compute hot-spot of the dense generalized-vec-trick path
(`P = K·V·Gᵀ`, DESIGN.md §Hardware-Adaptation). The paper's Algorithm 1 is a
CPU-oriented per-edge gather/scatter; on TPU the profitable mapping is dense
GEMMs on the MXU, so the kernel below tiles the operands into
(block_m × block_k)·(block_k × block_n) VMEM blocks and accumulates over the
K grid axis in f32.

VMEM budget (per grid step, f32, 128³ blocks): 3 · 128·128·4 B = 192 KiB —
comfortably under the ~16 MiB VMEM of a TPU core, leaving room for
double-buffering by the Mosaic pipeliner. Arithmetic intensity at 128-blocks
is 128/3 ≈ 43 flops/byte, above the MXU roofline knee, so the kernel is
compute-bound on real hardware (interpret=True on CPU is for correctness
only; see DESIGN.md §Perf).

`interpret=True` is mandatory in this image: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref, *, k_steps: int):
    """One (i, j, k) grid step: o[i,j] (+)= x[i,k] @ y[k,j]."""

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _block(dim: int, preferred: int) -> int:
    """Largest divisor of `dim` that is ≤ preferred (prefers MXU-native 128)."""
    b = min(dim, preferred)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block",))
def matmul(x: jax.Array, y: jax.Array, *, block: int = 128) -> jax.Array:
    """`x @ y` via the Pallas tiled kernel (f32).

    Shapes need not be multiples of `block`; the block size is shrunk to the
    largest divisor ≤ `block` per dimension (AOT buckets are chosen so this
    stays at 64/128 — see `aot.py`).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"matmul shape mismatch: {x.shape} @ {y.shape}"
    bm = _block(m, block)
    bk = _block(k, block)
    bn = _block(n, block)
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(x.astype(jnp.float32), y.astype(jnp.float32))


def matmul_nt(x: jax.Array, y: jax.Array, *, block: int = 128) -> jax.Array:
    """`x @ yᵀ` (convenience wrapper used by the kron_mv graph)."""
    return matmul(x, y.T, block=block)
