"""Pure-jnp oracles for the Pallas kernels and the L2 graphs.

Everything here is the "obviously correct" formulation; pytest asserts the
Pallas kernels and the AOT graphs match these within f32 tolerance.
"""

import jax.numpy as jnp


def matmul_ref(x, y):
    """Plain jnp matmul in f32."""
    return jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def gaussian_matrix_ref(x, y, gamma):
    """Gaussian kernel matrix via explicit pairwise differences."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    diff = x[:, None, :] - y[None, :, :]
    sq = jnp.sum(diff * diff, axis=-1)
    return jnp.exp(-jnp.asarray(gamma, jnp.float32) * sq)


def kron_mv_ref(k, g, start, end, v):
    """u_h = Σ_l G[e_h, e_l]·K[s_h, s_l]·v_l — the direct O(n²) formulation
    (small test sizes only)."""
    kk = k[start[:, None], start[None, :]]  # (n, n)
    gg = g[end[:, None], end[None, :]]
    return (kk * gg) @ v


def predict_ref(khat, ghat, train_start, train_end, test_start, test_end, a):
    """Zero-shot prediction oracle: p_h = Σ_l Ĝ[te_h, e_l]·K̂[ts_h, s_l]·a_l."""
    kk = khat[test_start[:, None], train_start[None, :]]  # (t, n)
    gg = ghat[test_end[:, None], train_end[None, :]]
    return (kk * gg) @ a


def ridge_train_ref(k, g, start, end, y, lam, iters):
    """Fixed-iteration CG on (R(G⊗K)Rᵀ + λI)a = y, matching model.ridge_train
    step-for-step but with the dense kron_mv oracle."""
    kk = k[start[:, None], start[None, :]]
    gg = g[end[:, None], end[None, :]]
    q = kk * gg

    def mv(x):
        return q @ x + lam * x

    a = jnp.zeros_like(y)
    r = y - mv(a)
    p = r
    rs = r @ r
    for _ in range(iters):
        qp = mv(p)
        alpha = rs / jnp.maximum(p @ qp, 1e-30)
        a = a + alpha * p
        r = r - alpha * qp
        rs_new = r @ r
        p = r + (rs_new / jnp.maximum(rs, 1e-30)) * p
        rs = rs_new
    return a
