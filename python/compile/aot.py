"""AOT pipeline: lower the Layer-2 graphs to HLO **text** artifacts + a
manifest the Rust runtime can discover.

HLO text — NOT `lowered.compile()` / serialized protos — is the interchange
format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids, which the
`xla` crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage (from python/):  python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, args) -> str:
    """Lower a jitted function to XLA HLO text via StableHLO."""
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


# Shape buckets. The Rust registry pads any smaller problem up to the next
# bucket; keep the set small so `make artifacts` stays fast. ridge_train
# buckets leave one vertex of padding headroom (bm > m callers use) — see
# runtime/artifacts.rs::ridge_train.
KRON_MV_BUCKETS = [
    (64, 64, 1024),
    (128, 128, 4096),
    (256, 256, 8192),
]
GAUSSIAN_BUCKETS = [
    (128, 128, 8),
    (256, 256, 32),
]
RIDGE_BUCKETS = [
    # (m, q, n, iters)
    (128, 128, 4096, 50),
]
PREDICT_BUCKETS = [
    # (u, v, t, m, q, n): test starts, test ends, test edges, train dims
    (64, 64, 1024, 128, 128, 4096),
]


def build_artifacts(out_dir: str) -> list[dict]:
    entries = []

    for m, q, n in KRON_MV_BUCKETS:
        name = f"kron_mv_m{m}_q{q}_n{n}"
        text = to_hlo_text(
            model.kron_mv_fn, (f32(m, m), f32(q, q), i32(n), i32(n), f32(n))
        )
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append(
            {"name": name, "kind": "kron_mv", "file": fname, "m": m, "q": q, "n": n}
        )
        print(f"  wrote {fname} ({len(text)} chars)")

    for rows, cols, dim in GAUSSIAN_BUCKETS:
        name = f"gaussian_kernel_r{rows}_c{cols}_d{dim}"
        text = to_hlo_text(
            model.gaussian_kernel_fn, (f32(rows, dim), f32(cols, dim), f32())
        )
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append(
            {
                "name": name,
                "kind": "gaussian_kernel",
                "file": fname,
                "rows": rows,
                "cols": cols,
                "dim": dim,
            }
        )
        print(f"  wrote {fname} ({len(text)} chars)")

    for m, q, n, iters in RIDGE_BUCKETS:
        name = f"ridge_train_m{m}_q{q}_n{n}_it{iters}"
        text = to_hlo_text(
            model.make_ridge_train_fn(iters),
            (f32(m, m), f32(q, q), i32(n), i32(n), f32(n), f32()),
        )
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append(
            {
                "name": name,
                "kind": "ridge_train",
                "file": fname,
                "m": m,
                "q": q,
                "n": n,
                "iters": iters,
            }
        )
        print(f"  wrote {fname} ({len(text)} chars)")

    for u, v, t, m, q, n in PREDICT_BUCKETS:
        name = f"predict_u{u}_v{v}_t{t}_m{m}_q{q}_n{n}"
        text = to_hlo_text(
            model.predict_fn,
            (f32(u, m), f32(v, q), i32(n), i32(n), i32(t), i32(t), f32(n)),
        )
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append(
            {
                "name": name,
                "kind": "predict",
                "file": fname,
                "u": u,
                "v": v,
                "t": t,
                "m": m,
                "q": q,
                "n": n,
            }
        )
        print(f"  wrote {fname} ({len(text)} chars)")

    return entries


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)
    print(f"lowering artifacts to {args.out}")
    entries = build_artifacts(args.out)
    manifest = {"version": 1, "artifacts": entries}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(entries)} artifacts")


if __name__ == "__main__":
    main()
