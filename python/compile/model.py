"""Layer-2 JAX graphs: the dense generalized-vec-trick path and a complete
fixed-iteration Kronecker ridge trainer, built on the Layer-1 Pallas kernels.

Each public function here is a *shape-static* computation that `aot.py`
lowers to HLO text for the Rust runtime. Semantics mirror the native Rust
implementations exactly (modulo f32):

* `kron_mv`    — `u = R(G⊗K)Rᵀ v` via scatter → `K·V·Gᵀ` (Pallas matmuls) →
  gather. This is the proof-of-Theorem-1 identity `R vec(N V Mᵀ)` executed
  densely (DESIGN.md §Hardware-Adaptation).
* `gaussian_kernel` — kernel-matrix computation (Pallas pairwise kernel).
* `predict`    — zero-shot prediction `R̂(Ĝ⊗K̂)Rᵀ a`.
* `ridge_train`— full CG solve of `(R(G⊗K)Rᵀ + λI)a = y` with a fixed
  iteration count (`lax.fori_loop`, rolled — constant artifact size).

Index conventions match the Rust side: each edge h carries a start-vertex
index `start[h] ∈ [m]` (rows of K) and an end-vertex index `end[h] ∈ [q]`
(rows of G).
"""

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.matmul import matmul
from .kernels.pairwise import gaussian_matrix


def kron_mv(k, g, start, end, v):
    """`u = R(G⊗K)Rᵀ v` (dense path).

    Args:
      k: (m, m) f32 start-vertex kernel matrix.
      g: (q, q) f32 end-vertex kernel matrix.
      start: (n,) i32 start-vertex index per edge.
      end: (n,) i32 end-vertex index per edge.
      v: (n,) f32 input vector.

    Returns:
      (n,) f32 output `u_h = Σ_l K[s_h,s_l]·G[e_h,e_l]·v_l`.
    """
    m = k.shape[0]
    q = g.shape[0]
    v_mat = jnp.zeros((m, q), jnp.float32).at[start, end].add(v)
    p = matmul(matmul(k, v_mat), g.T)  # K V Gᵀ, MXU-tiled
    return p[start, end]


def gaussian_kernel(x1, x2, gamma):
    """Gaussian kernel matrix (Pallas pairwise kernel)."""
    return gaussian_matrix(x1, x2, gamma)


def predict(khat, ghat, train_start, train_end, test_start, test_end, a):
    """Zero-shot prediction `p = R̂(Ĝ⊗K̂)Rᵀ a` (dense path).

    Args:
      khat: (u, m) f32 test×train start-vertex kernel block.
      ghat: (v, q) f32 test×train end-vertex kernel block.
      train_start/train_end: (n,) i32 training-edge indices.
      test_start/test_end: (t,) i32 test-edge indices (into khat/ghat rows).
      a: (n,) f32 dual coefficients.
    """
    m = khat.shape[1]
    q = ghat.shape[1]
    v_mat = jnp.zeros((m, q), jnp.float32).at[train_start, train_end].add(a)
    p = matmul(matmul(khat, v_mat), ghat.T)  # K̂ V Ĝᵀ  (u × v)
    return p[test_start, test_end]


def ridge_train(k, g, start, end, y, lam, *, iters: int):
    """Dual Kronecker ridge regression: `iters` CG steps on
    `(R(G⊗K)Rᵀ + λI) a = y`, entirely on-device.

    The CG state is carried through `lax.fori_loop`, so the lowered HLO has
    constant size regardless of `iters`.
    """

    def mv(x):
        return kron_mv(k, g, start, end, x) + lam * x

    a0 = jnp.zeros_like(y)
    r0 = y - mv(a0)
    p0 = r0
    rs0 = r0 @ r0

    def body(_, state):
        a, r, p, rs = state
        qp = mv(p)
        denom = jnp.maximum(p @ qp, 1e-30)
        alpha = rs / denom
        a = a + alpha * p
        r = r - alpha * qp
        rs_new = r @ r
        beta = rs_new / jnp.maximum(rs, 1e-30)
        p = r + beta * p
        return (a, r, p, rs_new)

    a, _, _, _ = lax.fori_loop(0, iters, body, (a0, r0, p0, rs0))
    return a


# ---------------------------------------------------------------------------
# jit wrappers with the tuple outputs the AOT pipeline expects
# ---------------------------------------------------------------------------

def kron_mv_fn(k, g, start, end, v):
    return (kron_mv(k, g, start, end, v),)


def gaussian_kernel_fn(x1, x2, gamma):
    return (gaussian_kernel(x1, x2, gamma),)


def predict_fn(khat, ghat, train_start, train_end, test_start, test_end, a):
    return (predict(khat, ghat, train_start, train_end, test_start, test_end, a),)


def make_ridge_train_fn(iters: int):
    def ridge_train_fn(k, g, start, end, y, lam):
        return (ridge_train(k, g, start, end, y, lam, iters=iters),)

    return ridge_train_fn
