"""AOT pipeline round-trip: lower → HLO text → recompile with XLA in-process
→ execute → compare against the oracle. This validates the exact artifact
bytes the Rust runtime will consume, before Rust ever sees them."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def roundtrip_execute(hlo_text: str, args):
    """Parse HLO text and execute with the in-process XLA CPU client."""
    client = xc.make_cpu_client()
    # hlo_text was produced via mlir_module_to_xla_computation; re-parse.
    comp = xc._xla.hlo_module_from_text(hlo_text)
    # Compile from the proto-serialized module.
    exe = client.compile(xc._xla.XlaComputation(comp.as_serialized_hlo_module_proto()).as_serialized_hlo_module_proto())
    outs = exe.execute_sharded([client.buffer_from_pyval(np.asarray(a)) for a in args])
    arrs = outs.disassemble_into_single_device_arrays()
    return [np.asarray(a[0]) for a in arrs]


def test_hlo_text_is_parseable():
    text = aot.to_hlo_text(
        model.kron_mv_fn,
        (aot.f32(8, 8), aot.f32(8, 8), aot.i32(16), aot.i32(16), aot.f32(16)),
    )
    assert "ENTRY" in text
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None


def test_kron_mv_artifact_numerics():
    rng = np.random.default_rng(41)
    m = q = 8
    n = 16
    text = aot.to_hlo_text(
        model.kron_mv_fn,
        (aot.f32(m, m), aot.f32(q, q), aot.i32(n), aot.i32(n), aot.f32(n)),
    )
    k = (rng.standard_normal((m, m)) * 0.1 + np.eye(m)).astype(np.float32)
    g = (rng.standard_normal((q, q)) * 0.1 + np.eye(q)).astype(np.float32)
    start = rng.integers(0, m, n).astype(np.int32)
    end = rng.integers(0, q, n).astype(np.int32)
    v = rng.standard_normal(n).astype(np.float32)
    try:
        outs = roundtrip_execute(text, [k, g, start, end, v])
    except Exception as exc:  # pragma: no cover - client API drift
        pytest.skip(f"in-process XLA execution unavailable: {exc}")
    want = np.asarray(ref.kron_mv_ref(jnp.asarray(k), jnp.asarray(g),
                                      jnp.asarray(start), jnp.asarray(end),
                                      jnp.asarray(v)))
    np.testing.assert_allclose(outs[0], want, rtol=1e-4, atol=1e-4)


def test_manifest_generation(tmp_path):
    # Shrink buckets for test speed.
    old = (aot.KRON_MV_BUCKETS, aot.GAUSSIAN_BUCKETS, aot.RIDGE_BUCKETS,
           aot.PREDICT_BUCKETS)
    aot.KRON_MV_BUCKETS = [(8, 8, 32)]
    aot.GAUSSIAN_BUCKETS = [(16, 16, 4)]
    aot.RIDGE_BUCKETS = [(8, 8, 32, 5)]
    aot.PREDICT_BUCKETS = [(8, 8, 16, 8, 8, 32)]
    try:
        entries = aot.build_artifacts(str(tmp_path))
    finally:
        (aot.KRON_MV_BUCKETS, aot.GAUSSIAN_BUCKETS, aot.RIDGE_BUCKETS,
         aot.PREDICT_BUCKETS) = old
    assert len(entries) == 4
    for e in entries:
        path = tmp_path / e["file"]
        assert path.exists()
        assert "ENTRY" in path.read_text()
    manifest = {"version": 1, "artifacts": entries}
    text = json.dumps(manifest)
    parsed = json.loads(text)
    kinds = {e["kind"] for e in parsed["artifacts"]}
    assert kinds == {"kron_mv", "gaussian_kernel", "ridge_train", "predict"}
