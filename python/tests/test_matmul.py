"""Pallas tiled matmul vs the pure-jnp oracle (hypothesis shape/dtype sweep)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul import matmul, matmul_nt, _block
from compile.kernels.ref import matmul_ref

jax.config.update("jax_platform_name", "cpu")


def rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_random_shapes(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, m, k)
    y = rand(rng, k, n)
    got = matmul(x, y, block=32)
    want = matmul_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 64, 128), (64, 256, 192)])
def test_matmul_bucket_shapes(shape):
    m, k, n = shape
    rng = np.random.default_rng(7)
    x = rand(rng, m, k)
    y = rand(rng, k, n)
    np.testing.assert_allclose(matmul(x, y), matmul_ref(x, y), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64, jnp.bfloat16])
def test_matmul_dtype_coercion(dtype):
    # inputs of any float dtype are computed in f32
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((16, 16)), dtype)
    y = jnp.asarray(rng.standard_normal((16, 16)), dtype)
    got = matmul(x, y, block=16)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(
        got, matmul_ref(x.astype(jnp.float32), y.astype(jnp.float32)),
        rtol=2e-2, atol=2e-2,  # loose for bf16 inputs
    )


def test_matmul_nt():
    rng = np.random.default_rng(5)
    x = rand(rng, 24, 8)
    y = rand(rng, 40, 8)
    np.testing.assert_allclose(
        matmul_nt(x, y), matmul_ref(x, y.T), rtol=1e-5, atol=1e-5
    )


def test_block_divisor_helper():
    assert _block(256, 128) == 128
    assert _block(100, 128) == 100
    assert _block(96, 64) == 48
    assert _block(7, 128) == 7
    assert _block(1, 128) == 1


def test_matmul_rejects_mismatched_shapes():
    with pytest.raises(AssertionError):
        matmul(jnp.zeros((4, 5)), jnp.zeros((6, 4)))
