"""Pallas Gaussian-kernel-matrix kernel vs the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.pairwise import gaussian_matrix
from compile.kernels.ref import gaussian_matrix_ref

jax.config.update("jax_platform_name", "cpu")


@settings(max_examples=12, deadline=None)
@given(
    r=st.integers(1, 64),
    c=st.integers(1, 64),
    d=st.integers(1, 16),
    gamma=st.floats(1e-3, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_gaussian_matches_ref_random_shapes(r, c, d, gamma, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((r, d)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((c, d)), jnp.float32)
    got = gaussian_matrix(x, y, gamma, block=32)
    want = gaussian_matrix_ref(x, y, gamma)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_bucket_shape_128():
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((128, 8)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((128, 8)), jnp.float32)
    got = gaussian_matrix(x, y, 0.5)
    want = gaussian_matrix_ref(x, y, 0.5)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_diagonal_is_one():
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.standard_normal((32, 4)), jnp.float32)
    k = gaussian_matrix(x, x, 2.0, block=32)
    # the Gram-matrix formulation leaves f32 round-off on the diagonal
    np.testing.assert_allclose(jnp.diag(k), jnp.ones(32), rtol=2e-5, atol=2e-5)


def test_values_in_unit_interval():
    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.standard_normal((16, 3)) * 10, jnp.float32)
    y = jnp.asarray(rng.standard_normal((24, 3)) * 10, jnp.float32)
    k = np.asarray(gaussian_matrix(x, y, 1.0, block=8))
    assert (k >= 0).all() and (k <= 1.0 + 1e-6).all()


def test_zero_padding_feature_dim_is_exact():
    # The Rust registry zero-pads feature dims up to the bucket; padding must
    # not change the kernel values.
    rng = np.random.default_rng(19)
    x = jnp.asarray(rng.standard_normal((16, 3)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((16, 3)), jnp.float32)
    xp = jnp.pad(x, ((0, 0), (0, 5)))
    yp = jnp.pad(y, ((0, 0), (0, 5)))
    np.testing.assert_allclose(
        gaussian_matrix(x, y, 0.7, block=16),
        gaussian_matrix(xp, yp, 0.7, block=16),
        rtol=1e-6,
        atol=1e-6,
    )


def test_feature_dim_mismatch_rejected():
    with pytest.raises(AssertionError):
        gaussian_matrix(jnp.zeros((4, 3)), jnp.zeros((4, 2)), 1.0)
