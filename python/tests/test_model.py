"""Layer-2 graphs vs oracles: kron_mv identity, prediction, ridge training."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def random_psd(rng, n):
    a = rng.standard_normal((n, n)).astype(np.float32)
    k = a @ a.T / n + np.eye(n, dtype=np.float32)
    return jnp.asarray(k)


def random_edges(rng, m, q, n):
    start = jnp.asarray(rng.integers(0, m, n), jnp.int32)
    end = jnp.asarray(rng.integers(0, q, n), jnp.int32)
    return start, end


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(2, 24),
    q=st.integers(2, 24),
    n=st.integers(1, 60),
    seed=st.integers(0, 2**31 - 1),
)
def test_kron_mv_matches_oracle(m, q, n, seed):
    rng = np.random.default_rng(seed)
    k = random_psd(rng, m)
    g = random_psd(rng, q)
    start, end = random_edges(rng, m, q, n)
    v = jnp.asarray(rng.standard_normal(n), jnp.float32)
    got = model.kron_mv(k, g, start, end, v)
    want = ref.kron_mv_ref(k, g, start, end, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_kron_mv_accumulates_duplicate_edges():
    rng = np.random.default_rng(23)
    k = random_psd(rng, 4)
    g = random_psd(rng, 4)
    start = jnp.asarray([0, 0, 1], jnp.int32)
    end = jnp.asarray([1, 1, 2], jnp.int32)  # duplicate edge (0, 1)
    v = jnp.asarray([1.0, 2.0, 3.0], jnp.float32)
    got = model.kron_mv(k, g, start, end, v)
    want = ref.kron_mv_ref(k, g, start, end, v)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_predict_matches_oracle():
    rng = np.random.default_rng(29)
    m, q, n = 12, 10, 30
    u, v_dim, t = 8, 6, 14
    khat = jnp.asarray(rng.standard_normal((u, m)), jnp.float32)
    ghat = jnp.asarray(rng.standard_normal((v_dim, q)), jnp.float32)
    tr_s, tr_e = random_edges(rng, m, q, n)
    te_s = jnp.asarray(rng.integers(0, u, t), jnp.int32)
    te_e = jnp.asarray(rng.integers(0, v_dim, t), jnp.int32)
    a = jnp.asarray(rng.standard_normal(n), jnp.float32)
    got = model.predict(khat, ghat, tr_s, tr_e, te_s, te_e, a)
    want = ref.predict_ref(khat, ghat, tr_s, tr_e, te_s, te_e, a)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_ridge_train_matches_oracle_cg():
    rng = np.random.default_rng(31)
    m, q, n = 10, 9, 40
    k = random_psd(rng, m)
    g = random_psd(rng, q)
    start, end = random_edges(rng, m, q, n)
    y = jnp.asarray(rng.standard_normal(n), jnp.float32)
    got = model.ridge_train(k, g, start, end, y, 0.5, iters=25)
    want = ref.ridge_train_ref(k, g, start, end, y, 0.5, 25)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_ridge_train_solves_system():
    rng = np.random.default_rng(37)
    m, q, n = 8, 8, 25
    k = random_psd(rng, m)
    g = random_psd(rng, q)
    start, end = random_edges(rng, m, q, n)
    y = jnp.asarray(rng.standard_normal(n), jnp.float32)
    lam = 1.0
    a = model.ridge_train(k, g, start, end, y, lam, iters=150)
    resid = ref.kron_mv_ref(k, g, start, end, a) + lam * a - y
    assert float(jnp.linalg.norm(resid)) < 1e-3 * float(jnp.linalg.norm(y))
