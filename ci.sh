#!/usr/bin/env bash
# CI entry point — run from the repo root. Mirrors .github/workflows/ci.yml.
#
# Checks, in order:
#   1. cargo fmt --check        formatting
#   2. cargo clippy -D warnings lints (includes missing_docs via lib.rs)
#   3. cargo build --release    the tier-1 build
#   4. cargo test -q            unit + integration tests
#   5. cargo test --doc         doc tests (keeps the lib.rs quickstart compiling)
#   6. cargo doc --no-deps      rustdoc gate (-D warnings: broken intra-doc
#                               links / code blocks fail instead of rotting)
#   7. example smoke            quickstart + model_lifecycle run end to end
#   8. model-lifecycle smoke    train --save → predict --model → serve --model
#                               exercises the kronvt-model/v1 artifact across
#                               fresh processes
#   9. ./bench.sh --smoke       quick-mode run of the JSON-writing benches so
#                               the bench targets can't bit-rot
#  10. python3 -m json.tool     every BENCH_*.json must exist and parse
set -euo pipefail
cd "$(dirname "$0")/rust"

run() {
    echo "==> $*"
    "$@"
}

run cargo fmt --check
run cargo clippy --all-targets -- -D warnings
run cargo build --release
run cargo test -q
# The eigendecomposition fast-path and tensor-chain acceptance suites by
# name, so a test-harness filter can never silently drop the
# closed-form/preconditioner checks or the D=2-bitwise / D=3-oracle chain
# pins (both also run as part of `cargo test -q` above).
run cargo test -q --test eigen_paths
run cargo test -q --test tensor_chain
# The serving fault-tolerance suite by name: deadlines, worker respawn,
# typed overload, and zero-downtime hot swap must never be filtered out.
run cargo test -q --test serving_faults
run cargo test --doc
run env RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# Example smoke: the public API surface (Learner / TrainedModel / serving)
# must run end to end, not merely compile.
run cargo run --release --example quickstart
run cargo run --release --example model_lifecycle

# Model-lifecycle smoke over the CLI: a saved artifact must score and serve
# in fresh processes without retraining.
model_artifact=$(mktemp "${TMPDIR:-/tmp}/kronvt-model-XXXXXX.json")
run cargo run --release -- train --data checker --scale 0.05 --seed 3 \
    --method kronridge --kernel gaussian:1 --lambda 0.0078125 --save "$model_artifact"
run cargo run --release -- predict --model "$model_artifact" --data checker --scale 0.05 --seed 3
run cargo run --release -- serve --model "$model_artifact" --requests 20 --threads 1
rm -f "$model_artifact"

run ../bench.sh --smoke

shopt -s nullglob
bench_files=(../BENCH_*.json)
if [ "${#bench_files[@]}" -eq 0 ]; then
    echo "ci.sh: no BENCH_*.json files found" >&2
    exit 1
fi
for f in "${bench_files[@]}"; do
    run python3 -m json.tool "$f" > /dev/null
done

# The serving bench must record the overload scenario with its full schema
# (shed / deadline-expired / latency tail), not just parse as JSON.
run python3 - <<'EOF'
import json
doc = json.load(open("../BENCH_serving.json"))
overload = doc.get("overload")
assert overload is not None, "BENCH_serving.json is missing the 'overload' section"
for key in ("offered", "accepted", "rejected_overload", "deadline_expired",
            "shed", "request_timeout_ms", "p50_secs", "p99_secs"):
    assert key in overload, f"BENCH_serving.json overload section is missing '{key}'"
print("BENCH_serving.json overload schema ok")
EOF

echo "ci.sh: all checks passed"
