#!/usr/bin/env bash
# CI entry point — run from the repo root. Mirrors .github/workflows/ci.yml.
#
# Checks, in order:
#   1. cargo fmt --check        formatting
#   2. cargo clippy -D warnings lints (includes missing_docs via lib.rs)
#   3. cargo build --release    the tier-1 build
#   4. cargo test -q            unit + integration tests
#   5. cargo test --doc         doc tests (keeps the lib.rs quickstart compiling)
#   6. cargo doc --no-deps      rustdoc gate (-D warnings: broken intra-doc
#                               links / code blocks fail instead of rotting)
#   7. example smoke            quickstart + model_lifecycle run end to end
#   8. model-lifecycle smoke    train --save → predict --model → serve --model
#                               exercises the kronvt-model/v1 artifact across
#                               fresh processes
#   9. ./bench.sh --smoke       quick-mode run of the JSON-writing benches so
#                               the bench targets can't bit-rot
#  10. python3 -m json.tool     every BENCH_*.json must exist and parse
set -euo pipefail
cd "$(dirname "$0")/rust"

run() {
    echo "==> $*"
    "$@"
}

run cargo fmt --check
run cargo clippy --all-targets -- -D warnings
run cargo build --release
run cargo test -q
# The eigendecomposition fast-path and tensor-chain acceptance suites by
# name, so a test-harness filter can never silently drop the
# closed-form/preconditioner checks or the D=2-bitwise / D=3-oracle chain
# pins (both also run as part of `cargo test -q` above).
run cargo test -q --test eigen_paths
run cargo test -q --test tensor_chain
# The serving fault-tolerance suite by name: deadlines, worker respawn,
# typed overload, and zero-downtime hot swap must never be filtered out.
run cargo test -q --test serving_faults
# The networked-serving suite by name: wire scores bitwise-identical to
# in-process, typed errors round-tripping the socket, protocol edge cases,
# and the 2-shard router (identical to unsharded, dead-shard ejection).
run cargo test -q --test net_serving
# The stochastic-trainer suite by name: the batch-restricted GVT apply
# pinned bitwise against full-apply rows at every thread count, fixed-seed
# determinism (in-memory vs on-disk source included), and convergence to
# the exact CG dual solution.
run cargo test -q --test stochastic
run cargo test --doc
run env RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# Example smoke: the public API surface (Learner / TrainedModel / serving)
# must run end to end, not merely compile.
run cargo run --release --example quickstart
run cargo run --release --example model_lifecycle

# Model-lifecycle smoke over the CLI: a saved artifact must score and serve
# in fresh processes without retraining.
model_artifact=$(mktemp "${TMPDIR:-/tmp}/kronvt-model-XXXXXX.json")
run cargo run --release -- train --data checker --scale 0.05 --seed 3 \
    --method kronridge --kernel gaussian:1 --lambda 0.0078125 --save "$model_artifact"
run cargo run --release -- predict --model "$model_artifact" --data checker --scale 0.05 --seed 3
run cargo run --release -- serve --model "$model_artifact" --requests 20 --threads 1
rm -f "$model_artifact"

run ../bench.sh --smoke

shopt -s nullglob
bench_files=(../BENCH_*.json)
if [ "${#bench_files[@]}" -eq 0 ]; then
    echo "ci.sh: no BENCH_*.json files found" >&2
    exit 1
fi
for f in "${bench_files[@]}"; do
    run python3 -m json.tool "$f" > /dev/null
done

# The serving bench must record the overload scenario with its full schema
# (shed / deadline-expired / latency tail), not just parse as JSON.
run python3 - <<'EOF'
import json
doc = json.load(open("../BENCH_serving.json"))
overload = doc.get("overload")
assert overload is not None, "BENCH_serving.json is missing the 'overload' section"
for key in ("offered", "accepted", "rejected_overload", "deadline_expired",
            "shed", "request_timeout_ms", "p50_secs", "p99_secs"):
    assert key in overload, f"BENCH_serving.json overload section is missing '{key}'"
print("BENCH_serving.json overload schema ok")
EOF

# The network bench must record the sustained mixed-traffic run (latency
# tail, error mix, wire faithfulness) and the warm-vs-cold-swap scenario.
run python3 - <<'EOF'
import json
doc = json.load(open("../BENCH_net.json"))
net = doc.get("net")
assert net is not None, "BENCH_net.json is missing the 'net' section"
for key in ("offered", "scored", "deadline_expired", "invalid", "other_errors",
            "throughput_rps", "p50_secs", "p95_secs", "p99_secs",
            "cache_hits", "cache_misses", "bitwise_identical"):
    assert key in net, f"BENCH_net.json net section is missing '{key}'"
swap = doc.get("swap")
assert swap is not None, "BENCH_net.json is missing the 'swap' section"
for key in ("swaps", "warm_p50_secs", "cold_first_mean_secs", "cold_first_max_secs"):
    assert key in swap, f"BENCH_net.json swap section is missing '{key}'"
print("BENCH_net.json net/swap schema ok")
EOF

# The stochastic bench must record the trainer-vs-CG comparison with its
# full schema (wall-clock, residuals, dual agreement), not just parse.
run python3 - <<'EOF'
import json
doc = json.load(open("../BENCH_stochastic.json"))
stoch = doc.get("stochastic")
assert stoch is not None, "BENCH_stochastic.json is missing the 'stochastic' section"
rows = stoch.get("rows")
assert rows, "BENCH_stochastic.json stochastic section has no rows"
for row in rows:
    for key in ("side", "density", "n_edges", "batch_edges", "epochs_run",
                "stoch_secs", "stoch_converged", "stoch_final_residual",
                "cg_iters", "cg_secs", "cg_converged", "max_abs_diff_stoch_cg"):
        assert key in row, f"BENCH_stochastic.json row is missing '{key}'"
print("BENCH_stochastic.json stochastic schema ok")
EOF

# Doc consistency: every CLI flag the binary accepts (the per-subcommand
# allowlists in src/main.rs) must be documented in README.md or docs/*.md,
# and every --flag named in usage() must be a flag some subcommand accepts.
run python3 - <<'EOF'
import glob, re
src = open("src/main.rs").read()
allow = set()
for arrays in re.findall(r"const [A-Z_]+_FLAGS: &\[&str\] = &\[(.*?)\];", src, re.S):
    allow.update(re.findall(r'"([a-z][a-z0-9-]*)"', arrays))
assert allow, "found no *_FLAGS allowlists in src/main.rs"

docs = "".join(open(p).read() for p in ["../README.md"] + sorted(glob.glob("../docs/*.md")))
undocumented = sorted(f for f in allow if not re.search(r"--" + re.escape(f) + r"(?![a-z0-9-])", docs))
assert not undocumented, f"CLI flags accepted by src/main.rs but absent from README.md/docs/*.md: {undocumented}"

usage = re.search(r"fn usage\(\).*?std::process::exit", src, re.S).group(0)
phantom = sorted(set(re.findall(r"--([a-z][a-z0-9-]*)", usage)) - allow)
assert not phantom, f"usage() advertises flags no subcommand accepts: {phantom}"
print(f"CLI flag docs consistent ({len(allow)} flags)")
EOF

echo "ci.sh: all checks passed"
