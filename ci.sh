#!/usr/bin/env bash
# CI entry point — run from the repo root. Mirrors .github/workflows/ci.yml.
#
# Checks, in order:
#   1. cargo fmt --check        formatting
#   2. cargo clippy -D warnings lints (includes missing_docs via lib.rs)
#   3. cargo build --release    the tier-1 build
#   4. cargo test -q            unit + integration tests
#   5. cargo test --doc         doc tests (keeps the lib.rs quickstart compiling)
#   6. ./bench.sh --smoke       quick-mode run of the JSON-writing benches so
#                               the bench targets can't bit-rot
set -euo pipefail
cd "$(dirname "$0")/rust"

run() {
    echo "==> $*"
    "$@"
}

run cargo fmt --check
run cargo clippy --all-targets -- -D warnings
run cargo build --release
run cargo test -q
run cargo test --doc
run ../bench.sh --smoke

echo "ci.sh: all checks passed"
