#!/usr/bin/env bash
# CI entry point — run from the repo root. Mirrors .github/workflows/ci.yml.
#
# Checks, in order:
#   1. cargo fmt --check        formatting
#   2. cargo clippy -D warnings lints (includes missing_docs via lib.rs)
#   3. cargo build --release    the tier-1 build
#   4. cargo test -q            unit + integration tests
#   5. cargo test --doc         doc tests (keeps the lib.rs quickstart compiling)
#   6. cargo doc --no-deps      rustdoc gate (-D warnings: broken intra-doc
#                               links / code blocks fail instead of rotting)
#   7. ./bench.sh --smoke       quick-mode run of the JSON-writing benches so
#                               the bench targets can't bit-rot
#   8. python3 -m json.tool     every BENCH_*.json must exist and parse
set -euo pipefail
cd "$(dirname "$0")/rust"

run() {
    echo "==> $*"
    "$@"
}

run cargo fmt --check
run cargo clippy --all-targets -- -D warnings
run cargo build --release
run cargo test -q
run cargo test --doc
run env RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
run ../bench.sh --smoke

shopt -s nullglob
bench_files=(../BENCH_*.json)
if [ "${#bench_files[@]}" -eq 0 ]; then
    echo "ci.sh: no BENCH_*.json files found" >&2
    exit 1
fi
for f in "${bench_files[@]}"; do
    run python3 -m json.tool "$f" > /dev/null
done

echo "ci.sh: all checks passed"
