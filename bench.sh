#!/usr/bin/env bash
# Bench runner — executes the bench_* targets and rewrites the repo-root
# BENCH_*.json result files (see docs/BENCHMARKS.md for the convention;
# sections written by a real run drop their 'placeholder' flag).
#
# bench_gvt_micro additionally covers the pairwise kernel family table
# (BENCH_pairwise.json) and the D-way tensor-chain table
# (BENCH_tensor.json), so both --quick and --smoke refresh them.
# bench_convergence writes the eigendecomposition fast-path comparison
# (BENCH_eigen.json); in smoke mode only that JSON section runs (-- --smoke).
# bench_stochastic writes the mini-batch-trainer-vs-CG comparison
# (BENCH_stochastic.json); smoke mode runs its one small row (-- --smoke).
#
# Usage:
#   ./bench.sh            # every bench target, quick mode
#   ./bench.sh --full     # every bench target, paper-scale settings
#   ./bench.sh --smoke    # only the fast JSON-writing benches, quick mode
#                         # (what ci.sh runs so bench targets can't bit-rot)
set -euo pipefail
cd "$(dirname "$0")/rust"

MODE="--quick"
SMOKE=0
for arg in "$@"; do
    case "$arg" in
        --full) MODE="--full" ;;
        --quick) MODE="--quick" ;;
        --smoke) SMOKE=1 ;;
        *) echo "unknown flag: $arg (expected --quick, --full, --smoke)" >&2; exit 2 ;;
    esac
done

if [[ "$SMOKE" == 1 ]]; then
    # bench_net is loopback-TCP only, quick mode is fast — keep the wire
    # bench (and BENCH_net.json) from bit-rotting too.
    BENCHES=(bench_gemm bench_gvt_micro bench_net)
    echo "==> cargo bench --bench bench_convergence -- --smoke"
    cargo bench --bench bench_convergence -- --smoke
    echo "==> cargo bench --bench bench_stochastic -- --smoke"
    cargo bench --bench bench_stochastic -- --smoke
else
    BENCHES=(
        bench_gemm
        bench_gvt_micro
        bench_complexity
        bench_convergence
        bench_checkerboard
        bench_drug_target
        bench_serving
        bench_net
        bench_stochastic
        bench_table6
    )
fi

for b in "${BENCHES[@]}"; do
    echo "==> cargo bench --bench $b -- $MODE"
    cargo bench --bench "$b" -- "$MODE"
done

echo "bench.sh: done — refreshed BENCH_*.json files:"
ls -1 ../BENCH_*.json
