//! The §5.5 scalability story in miniature: train KronSVM and the explicit
//! SMO baseline on growing checkerboard subsets, report train time, predict
//! time and AUC — the data behind Fig. 7. Sizes are scaled to this container
//! (pass `--max-m 800` etc. to push further).
//!
//! Run with: `cargo run --release --example checkerboard_scaling`

use kronvt::baselines::{ExplicitSvm, ExplicitSvmConfig};
use kronvt::data::checkerboard::CheckerboardConfig;
use kronvt::eval::auc::auc;
use kronvt::kernels::KernelKind;
use kronvt::train::{KronSvm, SvmConfig};
use kronvt::util::args::Args;
use kronvt::util::timer::Timer;

fn main() {
    let args = Args::parse();
    args.expect_known("checkerboard_scaling", &["max-m", "baseline-cap"]).expect("flags");
    let max_m = args.get_usize("max-m", 400).expect("--max-m");
    let baseline_cap = args.get_usize("baseline-cap", 4000).expect("--baseline-cap");
    let gaussian = KernelKind::Gaussian { gamma: 1.0 };

    println!(
        "{:>6} {:>8} | {:>12} {:>12} {:>7} | {:>12} {:>12} {:>7}",
        "m=q", "edges", "kron train", "kron pred", "AUC", "smo train", "smo pred", "AUC"
    );

    let mut m = 50;
    while m <= max_m {
        let data = CheckerboardConfig { m, q: m, density: 0.25, noise: 0.2, seed: 9, ..Default::default() }.generate();
        let (train, test) = data.zero_shot_split(0.3, 3);

        // KronSVM (10 outer × 10 inner, λ = 2⁻⁷, as §5.5)
        let timer = Timer::start();
        let kron = KronSvm::new(SvmConfig {
            lambda: 2f64.powi(-7),
            kernel_d: gaussian,
            kernel_t: gaussian,
            outer_iters: 10,
            inner_iters: 10,
            ..Default::default()
        })
        .fit(&train)
        .expect("kron train");
        let kron_train = timer.elapsed_secs();
        let timer = Timer::start();
        let kron_scores = kron.predict(&test);
        let kron_pred = timer.elapsed_secs();
        let kron_auc = auc(&test.labels, &kron_scores);

        // Explicit SMO baseline — only up to the cap (quadratic blow-up).
        let (smo_train, smo_pred, smo_auc) = if train.n_edges() <= baseline_cap {
            let timer = Timer::start();
            let smo = ExplicitSvm::fit(
                &train,
                &ExplicitSvmConfig { c: 100.0, kernel: gaussian, ..Default::default() },
            )
            .expect("smo train");
            let t_train = timer.elapsed_secs();
            let timer = Timer::start();
            let scores = smo.predict(&test);
            let t_pred = timer.elapsed_secs();
            (
                format!("{t_train:>11.2}s"),
                format!("{t_pred:>11.2}s"),
                format!("{:>7.3}", auc(&test.labels, &scores)),
            )
        } else {
            (format!("{:>12}", "(skipped)"), format!("{:>12}", "-"), format!("{:>7}", "-"))
        };

        println!(
            "{:>6} {:>8} | {:>11.2}s {:>11.3}s {:>7.3} | {} {} {}",
            m,
            train.n_edges(),
            kron_train,
            kron_pred,
            kron_auc,
            smo_train,
            smo_pred,
            smo_auc
        );
        m *= 2;
    }
    println!("checkerboard_scaling OK");
}
