//! Zero-shot prediction serving: train once, then serve batched requests
//! carrying *novel* vertices through the [`PredictServer`] coordinator —
//! merged batches are sharded across a scoring pool (`--workers`) and
//! repeated vertices reuse their kernel rows via the per-vertex LRU cache
//! (`--cache-vertices`; requests draw from a `--vertex-pool` of distinct
//! vertices to mimic repeat-vertex production traffic). Reports latency
//! percentiles, throughput, and the cache hit rate, and verifies served
//! scores against direct prediction.
//!
//! Run with: `cargo run --release --example zero_shot_server`

use kronvt::api::{Compute, Learner};
use kronvt::coordinator::{PredictServer, ServerConfig};
use kronvt::data::checkerboard::{true_label, CheckerboardConfig};
use kronvt::data::Dataset;
use kronvt::eval::auc::auc;
use kronvt::kernels::KernelKind;
use kronvt::linalg::Matrix;
use kronvt::util::args::Args;
use kronvt::util::rng::Pcg32;
use kronvt::util::timer::Timer;

fn main() {
    let args = Args::parse();
    args.expect_known(
        "zero_shot_server",
        &["requests", "edges", "threads", "workers", "cache-vertices", "vertex-pool"],
    )
    .expect("flags");
    let n_requests = args.get_usize("requests", 200).expect("--requests");
    let edges_per_request = args.get_usize("edges", 16).expect("--edges");

    // Train on checkerboard data through the unified estimator API.
    let data = CheckerboardConfig { m: 120, q: 120, density: 0.3, noise: 0.15, feature_range: 15.0, seed: 21 }
        .generate();
    let (train, _) = data.zero_shot_split(0.2, 4);
    println!("training KronSVM on {} edges...", train.n_edges());
    let compute = Compute::threads(args.get_usize("threads", 0).expect("--threads"))
        .with_cache_vertices(args.get_usize("cache-vertices", 512).expect("--cache-vertices"));
    let model = Learner::svm()
        .lambda(2f64.powi(-7))
        .kernel(KernelKind::Gaussian { gamma: 1.0 })
        .iterations(10)
        .inner_iterations(10)
        .compute(compute)
        .fit(&train)
        .expect("training");

    let model_check = model.clone(); // for the direct-prediction spot check
    let server: PredictServer = model
        .serve(ServerConfig {
            max_batch_edges: 4096,
            workers: args.get_usize("workers", 2).expect("--workers"),
            compute,
            ..Default::default()
        })
        .expect("dual model serves");

    // Fire requests whose vertices repeat across a bounded pool (the cache's
    // target traffic pattern); collect latency + correctness.
    let mut rng = Pcg32::seeded(77);
    let pool = args.get_usize("vertex-pool", 24).expect("--vertex-pool").max(4);
    let start_pool: Vec<Vec<f64>> =
        (0..pool).map(|_| vec![rng.uniform_in(0.0, 15.0)]).collect();
    let end_pool: Vec<Vec<f64>> = (0..pool).map(|_| vec![rng.uniform_in(0.0, 15.0)]).collect();
    let mut latencies = Vec::with_capacity(n_requests);
    let mut all_scores = Vec::new();
    let mut all_labels = Vec::new();
    let wall = Timer::start();
    for _ in 0..n_requests {
        let u = 4;
        let v = 4;
        let sf: Vec<Vec<f64>> = (0..u).map(|_| start_pool[rng.below(pool)].clone()).collect();
        let ef: Vec<Vec<f64>> = (0..v).map(|_| end_pool[rng.below(pool)].clone()).collect();
        let edges: Vec<(u32, u32)> = (0..edges_per_request)
            .map(|_| (rng.below(u) as u32, rng.below(v) as u32))
            .collect();
        let t = Timer::start();
        let scores = server
            .predict_blocking(sf.clone(), ef.clone(), edges.clone())
            .expect("request served");
        latencies.push(t.elapsed_secs());
        for (h, &(s, e)) in edges.iter().enumerate() {
            all_scores.push(scores[h]);
            all_labels.push(true_label(sf[s as usize][0], ef[e as usize][0]));
        }
    }
    let wall_secs = wall.elapsed_secs();

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| latencies[(p * (latencies.len() - 1) as f64) as usize];
    let st = server.stats();
    let total_edges = st.edges_scored.load(std::sync::atomic::Ordering::Relaxed);
    println!(
        "served {n_requests} requests / {total_edges} edges in {wall_secs:.2}s  ({:.0} edges/s)",
        total_edges as f64 / wall_secs
    );
    println!(
        "latency p50={:.2}ms p90={:.2}ms p99={:.2}ms  batches={}",
        pct(0.50) * 1e3,
        pct(0.90) * 1e3,
        pct(0.99) * 1e3,
        st.batches.load(std::sync::atomic::Ordering::Relaxed)
    );
    let hits = st.cache_hits.load(std::sync::atomic::Ordering::Relaxed);
    let misses = st.cache_misses.load(std::sync::atomic::Ordering::Relaxed);
    println!(
        "kernel-row cache: {hits} hits / {misses} misses ({:.0}% hit rate)",
        100.0 * hits as f64 / (hits + misses).max(1) as f64
    );
    let served_auc = auc(&all_labels, &all_scores);
    println!("AUC of served predictions vs noise-free labels: {served_auc:.3}");

    // Spot-check correctness against direct prediction for one request.
    let sf = vec![vec![12.3], vec![55.5]];
    let ef = vec![vec![71.2], vec![3.4]];
    let edges = vec![(0u32, 0u32), (1, 1), (0, 1)];
    let served = server
        .predict_blocking(sf.clone(), ef.clone(), edges.clone())
        .expect("request");
    server.shutdown();

    let data2 = Dataset {
        start_features: Matrix::from_rows(&[&[12.3], &[55.5]]),
        end_features: Matrix::from_rows(&[&[71.2], &[3.4]]),
        start_idx: edges.iter().map(|&(s, _)| s).collect(),
        end_idx: edges.iter().map(|&(_, e)| e).collect(),
        labels: vec![0.0; 3],
        name: "spot".into(),
    };
    let direct = model_check.predict(&data2);
    // Allclose rather than bitwise: the serving context prunes the SVM's
    // zero duals, which may flip the Algorithm-1 branch choice.
    for (h, (s, d)) in served.iter().zip(&direct).enumerate() {
        assert!(
            (s - d).abs() <= 1e-9 * (1.0 + d.abs()),
            "served score {h} diverged from direct prediction: {s} vs {d}"
        );
    }
    assert!(served_auc > 0.6, "served AUC should beat chance");
    println!("zero_shot_server OK (served == direct for the spot request)");
}
