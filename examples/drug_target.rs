//! End-to-end driver: the paper's drug–target interaction workload.
//!
//! Reproduces the §5 pipeline on the shape-exact synthetic GPCR and IC
//! datasets (Table 5): 9-fold vertex-disjoint cross-validation (Fig. 2) over
//! all five methods of Table 6, reporting per-method mean AUC and runtime —
//! the same rows as Tables 6 and 7. This is the full-system workload: data
//! generation → zero-shot CV splits → Kronecker training via the
//! generalized vec trick → efficient prediction → AUC.
//!
//! Run with: `cargo run --release --example drug_target [-- --data gpcr]`

use kronvt::baselines::{KnnConfig, KnnModel, SgdConfig, SgdLossKind, SgdModel};
use kronvt::coordinator::run_cv_jobs;
use kronvt::data::{dti, Dataset};
use kronvt::eval::auc::auc;
use kronvt::kernels::KernelKind;
use kronvt::train::{KronRidge, KronSvm, RidgeConfig, SvmConfig};
use kronvt::util::args::Args;
use kronvt::util::timer::Timer;

fn method_scores(method: &str, train: &Dataset, test: &Dataset) -> Vec<f64> {
    // λ from the coarse validation grid of §5.2 (normalized features);
    // iteration truncation provides most of the regularization.
    match method {
        "KronSVM" => KronSvm::new(SvmConfig {
            lambda: 1.0,
            kernel_d: KernelKind::Linear,
            kernel_t: KernelKind::Linear,
            outer_iters: 10,
            inner_iters: 10,
            ..Default::default()
        })
        .fit(train)
        .expect("train")
        .predict(test),
        "KronRidge" => KronRidge::new(RidgeConfig {
            lambda: 1e-2,
            kernel_d: KernelKind::Linear,
            kernel_t: KernelKind::Linear,
            iterations: 10,
            ..Default::default()
        })
        .fit(train)
        .expect("train")
        .predict(test),
        "SGD hinge" => SgdModel::fit(
            train,
            &SgdConfig { loss: SgdLossKind::Hinge, lambda: 1e-4, updates: 200_000, ..Default::default() },
        )
        .expect("train")
        .predict(test),
        "SGD logistic" => SgdModel::fit(
            train,
            &SgdConfig {
                loss: SgdLossKind::Logistic,
                lambda: 1e-4,
                updates: 200_000,
                ..Default::default()
            },
        )
        .expect("train")
        .predict(test),
        "KNN" => KnnModel::fit(train, &KnnConfig { k: 9, ..Default::default() })
            .expect("train")
            .predict(test),
        other => panic!("unknown method {other}"),
    }
}

fn main() {
    let args = Args::parse();
    args.expect_known("drug_target", &["data", "seed"]).expect("flags");
    let which = args.get_str("data", "gpcr,ic");
    let seed = args.get_u64("seed", 1).expect("--seed");

    for name in which.split(',') {
        let cfg = match name {
            "gpcr" => dti::gpcr(seed),
            "ic" => dti::ic(seed),
            "e" => dti::e(seed),
            "ki" => dti::ki(seed),
            other => {
                eprintln!("skipping unknown dataset {other}");
                continue;
            }
        };
        let ds = cfg.generate();
        let st = ds.stats();
        println!(
            "\n=== {name}: {} edges ({} pos / {} neg), {}×{} vertices ===",
            st.edges, st.positives, st.negatives, st.start_vertices, st.end_vertices
        );
        let folds = ds.ninefold_cv(seed);
        println!("9-fold zero-shot CV (Fig. 2): {} usable folds", folds.len());

        println!("{:<14} {:>8} {:>10}", "method", "AUC", "time");
        for method in ["KronSVM", "KronRidge", "SGD hinge", "SGD logistic", "KNN"] {
            let timer = Timer::start();
            let results = run_cv_jobs(&folds, 1, |tr, te| {
                auc(&te.labels, &method_scores(method, tr, te))
            });
            let mean = kronvt::coordinator::jobs::mean_auc(&results);
            println!("{:<14} {:>8.3} {:>9.1}s", method, mean, timer.elapsed_secs());
        }
    }
    println!("\ndrug_target OK");
}
