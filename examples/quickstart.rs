//! Quickstart: train Kronecker ridge regression and a Kronecker SVM on the
//! checkerboard problem through the unified estimator API
//! ([`Learner`] → [`TrainedModel`]), evaluate zero-shot AUC, round-trip the
//! ridge model through the portable `kronvt-model/v1` artifact, and show
//! the sparse prediction shortcut.
//!
//! Run with: `cargo run --release --example quickstart`

use kronvt::api::{Compute, Learner, TrainedModel};
use kronvt::data::checkerboard::CheckerboardConfig;
use kronvt::eval::auc::auc;
use kronvt::kernels::KernelKind;
use kronvt::util::timer::Timer;

fn main() {
    // 1. Generate a labeled bipartite graph (the §5.1 checkerboard).
    let data = CheckerboardConfig { m: 150, q: 150, density: 0.25, noise: 0.2, feature_range: 20.0, seed: 42 }
        .generate();
    println!("dataset: {} edges over {}×{} vertices", data.n_edges(), data.m(), data.q());

    // 2. Zero-shot split: test vertices are disjoint from training vertices.
    let (train, test) = data.zero_shot_split(0.25, 7);
    println!("train: {} edges ({}×{} vertices); test: {} edges", train.n_edges(), train.m(), train.q(), test.n_edges());

    let gaussian = KernelKind::Gaussian { gamma: 1.0 };
    let compute = Compute::all_cores();

    // 3. Kronecker ridge regression (§4.1): one linear system, MINRES.
    let timer = Timer::start();
    let ridge = Learner::ridge()
        .lambda(2f64.powi(-7))
        .kernel(gaussian)
        .iterations(100)
        .compute(compute)
        .fit(&train)
        .expect("ridge training");
    let ridge_auc = auc(&test.labels, &ridge.predict(&test));
    println!("KronRidge: AUC={ridge_auc:.3} in {:.2}s", timer.elapsed_secs());

    // 4. Kronecker L2-SVM (§4.2): truncated Newton, 10×10 iterations.
    let timer = Timer::start();
    let svm = Learner::svm()
        .lambda(2f64.powi(-7))
        .kernel(gaussian)
        .iterations(10)
        .inner_iterations(10)
        .compute(compute)
        .fit(&train)
        .expect("svm training");
    let svm_auc = auc(&test.labels, &svm.predict(&test));
    println!(
        "KronSVM:   AUC={svm_auc:.3} in {:.2}s ({} of {} dual coefficients non-zero)",
        timer.elapsed_secs(),
        svm.as_dual().expect("dual model").nnz(),
        train.n_edges()
    );

    // 5. The model lifecycle: save → load reproduces predictions bitwise —
    //    the artifact another process (kronvt predict / serve --model)
    //    would load.
    let path = std::env::temp_dir().join("kronvt_quickstart_model.json");
    ridge.save(&path).expect("save artifact");
    let loaded = TrainedModel::load(&path).expect("load artifact");
    assert_eq!(loaded.predict(&test), ridge.predict(&test), "loaded model must match bitwise");
    println!("artifact: saved + reloaded {} — predictions bitwise identical", path.display());
    std::fs::remove_file(&path).ok();

    // 6. The prediction shortcut (eq. 5) vs the explicit decision function
    //    (eq. 6) — same numbers, very different cost.
    let svm_dual = svm.as_dual().expect("dual model");
    let timer = Timer::start();
    let fast = svm_dual.predict(&test);
    let fast_secs = timer.elapsed_secs();
    let timer = Timer::start();
    let slow = svm_dual.predict_explicit(&test);
    let slow_secs = timer.elapsed_secs();
    let max_diff = fast
        .iter()
        .zip(&slow)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!(
        "prediction: generalized vec trick {:.4}s vs explicit {:.4}s ({:.0}× speedup, max |Δ| = {max_diff:.2e})",
        fast_secs,
        slow_secs,
        slow_secs / fast_secs.max(1e-12)
    );

    assert!(ridge_auc > 0.6 && svm_auc > 0.6, "models should beat chance comfortably");
    println!("quickstart OK");
}
