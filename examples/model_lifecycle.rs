//! The unified model lifecycle, end to end: **fit** a model with the
//! [`Learner`] builder, **save** it as a portable `kronvt-model/v1`
//! artifact, **load** it back (as `kronvt predict` / `kronvt serve --model`
//! would in a fresh process), verify the reload is bitwise identical, and
//! **serve** the loaded model through the batched prediction server without
//! retraining.
//!
//! Run with: `cargo run --release --example model_lifecycle`

use kronvt::api::{Compute, Learner, TrainedModel};
use kronvt::coordinator::ServerConfig;
use kronvt::data::checkerboard::CheckerboardConfig;
use kronvt::eval::auc::auc;
use kronvt::kernels::KernelKind;
use kronvt::util::rng::Pcg32;

fn main() {
    // --- fit ---------------------------------------------------------------
    let data = CheckerboardConfig { m: 80, q: 80, density: 0.3, noise: 0.15, feature_range: 10.0, seed: 33 }
        .generate();
    let (train, test) = data.zero_shot_split(0.25, 6);
    let compute = Compute::threads(2).with_cache_vertices(256);
    let model = Learner::ridge()
        .lambda(2f64.powi(-6))
        .kernel(KernelKind::Gaussian { gamma: 1.0 })
        .iterations(80)
        .compute(compute)
        .fit(&train)
        .expect("training");
    let scores = model.predict_batch(&test, &compute);
    println!(
        "fit: KronRidge on {} edges — zero-shot AUC {:.3}",
        train.n_edges(),
        auc(&test.labels, &scores)
    );

    // --- save --------------------------------------------------------------
    let path = std::env::temp_dir().join("kronvt_lifecycle_example.json");
    model.save(&path).expect("save artifact");
    let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    println!("save: kronvt-model/v1 artifact at {} ({bytes} bytes)", path.display());

    // --- load --------------------------------------------------------------
    let loaded = TrainedModel::load(&path).expect("load artifact");
    let reloaded_scores = loaded.predict_batch(&test, &compute);
    assert_eq!(scores, reloaded_scores, "loaded model must predict bitwise identically");
    println!("load: reloaded model predicts bitwise identically ({} edges)", scores.len());

    // --- serve (no retraining) ---------------------------------------------
    let dual = loaded.as_dual().expect("dual model");
    let (d, r) = (dual.train_start_features.cols(), dual.train_end_features.cols());
    let server = loaded
        .serve(ServerConfig { workers: 2, compute, ..Default::default() })
        .expect("serve loaded model");
    let mut rng = Pcg32::seeded(99);
    let mut served_edges = 0usize;
    for _ in 0..50 {
        let sf: Vec<Vec<f64>> = (0..3).map(|_| rng.uniform_vec(d, 0.0, 10.0)).collect();
        let ef: Vec<Vec<f64>> = (0..3).map(|_| rng.uniform_vec(r, 0.0, 10.0)).collect();
        let edges: Vec<(u32, u32)> =
            (0..6).map(|_| (rng.below(3) as u32, rng.below(3) as u32)).collect();
        let scores = server.predict_blocking(sf, ef, edges).expect("request served");
        assert!(scores.iter().all(|s| s.is_finite()));
        served_edges += scores.len();
    }
    let stats = server.stats();
    let hits = stats.cache_hits.load(std::sync::atomic::Ordering::Relaxed);
    let misses = stats.cache_misses.load(std::sync::atomic::Ordering::Relaxed);
    println!(
        "serve: {served_edges} edges scored from the loaded artifact — cache {hits} hits / {misses} misses"
    );
    server.shutdown();
    std::fs::remove_file(&path).ok();
    println!("model_lifecycle OK");
}
