//! Figures 3, 4, 5 — regularized risk and zero-shot test AUC as a function
//! of optimization iterations, over the λ grid the paper plots
//! (2⁻¹⁰, 2⁻⁵, 2⁰, 2⁵, 2¹⁰), for:
//!
//! * Fig. 3: KronRidge (dual, MINRES), up to 100 iterations
//! * Fig. 4: KronSVM with 10 inner iterations per outer Newton step
//! * Fig. 5: KronSVM with 100 inner iterations
//!
//! Expected shape (matching §5.2): risk decreases monotonically; test AUC
//! peaks within tens of iterations and then plateaus or degrades; more inner
//! iterations reduce risk faster per outer step but do not reach better AUC.
//!
//! Run: `cargo bench --bench bench_convergence [-- ridge|svm10|svm100] [--full]`

use kronvt::data::dti;
use kronvt::train::{KronRidge, KronSvm, RidgeConfig, SvmConfig};
use kronvt::util::args::Args;

const LAMBDAS: [i32; 5] = [-10, -5, 0, 5, 10];
const PRINT_ITERS: [usize; 8] = [1, 2, 5, 10, 20, 40, 70, 100];

fn datasets(full: bool, seed: u64) -> Vec<(String, kronvt::data::Dataset)> {
    let mut out = vec![
        ("GPCR".to_string(), dti::gpcr(seed).generate()),
        ("IC".to_string(), dti::ic(seed).generate()),
    ];
    if full {
        out.push(("E".to_string(), dti::e(seed).generate()));
        out.push(("Ki".to_string(), dti::ki(seed).generate()));
    } else {
        // scaled-down E/Ki shapes keep the quick run under a few minutes
        out.push((
            "E(scaled)".to_string(),
            dti::DtiConfig { m: 150, q: 220, n: 8200, positives: 90, seed, ..Default::default() }
                .generate(),
        ));
        out.push((
            "Ki(scaled)".to_string(),
            dti::DtiConfig { m: 470, q: 52, n: 10300, positives: 350, seed, ..Default::default() }
                .generate(),
        ));
    }
    out
}

fn print_trace(label: &str, lambda_exp: i32, trace: &kronvt::train::TrainTrace) {
    for rec in &trace.records {
        if PRINT_ITERS.contains(&rec.iter) || rec.iter == trace.records.len() {
            println!(
                "{label} lambda=2^{lambda_exp:<3} iter={:>3} risk={:<14.6e} test_auc={:.4}",
                rec.iter,
                rec.risk,
                rec.val_auc.unwrap_or(f64::NAN)
            );
        }
    }
}

fn main() {
    let args = Args::parse();
    args.expect_known("bench_convergence", &["bench", "full", "quick", "seed"]).expect("flags");
    let full = args.has("full");
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let seed = args.get_u64("seed", 1).expect("--seed");

    for (name, data) in datasets(full, seed) {
        // zero-shot train/test split in place of one CV fold (Fig. 2 block)
        let (train, test) = data.zero_shot_split(1.0 / 3.0, seed);
        println!(
            "\n### {name}: train n={} (m={}, q={}), test n={} — linear vertex kernels",
            train.n_edges(),
            train.m(),
            train.q(),
            test.n_edges()
        );

        if which == "all" || which == "ridge" {
            println!("--- Fig. 3: KronRidge ---");
            for exp in LAMBDAS {
                let cfg = RidgeConfig {
                    lambda: 2f64.powi(exp),
                    iterations: 100,
                    trace: true,
                    tol: 1e-14,
                    ..Default::default()
                };
                let (_, trace) = KronRidge::new(cfg).fit_traced(&train, Some(&test)).unwrap();
                print_trace("ridge", exp, &trace);
            }
        }

        for (tag, inner) in [("svm10", 10usize), ("svm100", 100usize)] {
            if which != "all" && which != tag {
                continue;
            }
            println!("--- Fig. {}: KronSVM, {} inner iterations ---",
                     if inner == 10 { 4 } else { 5 }, inner);
            for exp in LAMBDAS {
                let cfg = SvmConfig {
                    lambda: 2f64.powi(exp),
                    outer_iters: if full { 100 } else { 40 },
                    inner_iters: inner,
                    trace: true,
                    ..Default::default()
                };
                let (_, trace) = KronSvm::new(cfg).fit_traced(&train, Some(&test)).unwrap();
                print_trace(tag, exp, &trace);
            }
        }
    }
    println!("\nbench_convergence done");
}
