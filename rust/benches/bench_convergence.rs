//! Figures 3, 4, 5 — regularized risk and zero-shot test AUC as a function
//! of optimization iterations, over the λ grid the paper plots
//! (2⁻¹⁰, 2⁻⁵, 2⁰, 2⁵, 2¹⁰), for:
//!
//! * Fig. 3: KronRidge (dual, MINRES), up to 100 iterations
//! * Fig. 4: KronSVM with 10 inner iterations per outer Newton step
//! * Fig. 5: KronSVM with 100 inner iterations
//!
//! Expected shape (matching §5.2): risk decreases monotonically; test AUC
//! peaks within tens of iterations and then plateaus or degrades; more inner
//! iterations reduce risk faster per outer step but do not reach better AUC.
//!
//! The run finishes with the **eigendecomposition fast-path comparison**
//! (closed-form exact solve vs. plain CG vs. spectrally preconditioned CG on
//! complete and near-complete checkerboards), written to `BENCH_eigen.json`
//! (section `"eigen"`, see `docs/BENCHMARKS.md`). `-- --smoke` runs only
//! that JSON-writing section (what `ci.sh` exercises).
//!
//! Run: `cargo bench --bench bench_convergence [-- ridge|svm10|svm100]
//! [--full|--smoke]`

use std::sync::Arc;

use kronvt::data::checkerboard::CheckerboardConfig;
use kronvt::data::dti;
use kronvt::gvt::operator::RidgeSystemOp;
use kronvt::gvt::{KronKernelOp, KronSpectralPrecond};
use kronvt::kernels::KernelKind;
use kronvt::linalg::eigh;
use kronvt::linalg::solvers::{cg, pcg, SolverConfig};
use kronvt::linalg::vecops::max_abs_diff;
use kronvt::train::{KronRidge, KronSvm, RidgeConfig, RidgeSolver, SvmConfig};
use kronvt::util::args::Args;
use kronvt::util::json::{update_json_file, Json};
use kronvt::util::timer::timeit;

const LAMBDAS: [i32; 5] = [-10, -5, 0, 5, 10];
const PRINT_ITERS: [usize; 8] = [1, 2, 5, 10, 20, 40, 70, 100];

fn datasets(full: bool, seed: u64) -> Vec<(String, kronvt::data::Dataset)> {
    let mut out = vec![
        ("GPCR".to_string(), dti::gpcr(seed).generate()),
        ("IC".to_string(), dti::ic(seed).generate()),
    ];
    if full {
        out.push(("E".to_string(), dti::e(seed).generate()));
        out.push(("Ki".to_string(), dti::ki(seed).generate()));
    } else {
        // scaled-down E/Ki shapes keep the quick run under a few minutes
        out.push((
            "E(scaled)".to_string(),
            dti::DtiConfig { m: 150, q: 220, n: 8200, positives: 90, seed, ..Default::default() }
                .generate(),
        ));
        out.push((
            "Ki(scaled)".to_string(),
            dti::DtiConfig { m: 470, q: 52, n: 10300, positives: 350, seed, ..Default::default() }
                .generate(),
        ));
    }
    out
}

fn print_trace(label: &str, lambda_exp: i32, trace: &kronvt::train::TrainTrace) {
    for rec in &trace.records {
        if PRINT_ITERS.contains(&rec.iter) || rec.iter == trace.records.len() {
            println!(
                "{label} lambda=2^{lambda_exp:<3} iter={:>3} risk={:<14.6e} test_auc={:.4}",
                rec.iter,
                rec.risk,
                rec.val_auc.unwrap_or(f64::NAN)
            );
        }
    }
}

/// One eigen-comparison case: closed-form exact solve (complete graphs
/// only), plain CG, and spectrally preconditioned CG on a checkerboard ridge
/// system, reporting wall-clock, iteration counts, and max-abs solution
/// differences.
fn eigen_row(side: usize, density: f64, gamma: f64, lambda: f64, seed: u64) -> Json {
    let train = CheckerboardConfig {
        m: side,
        q: side,
        density,
        noise: 0.1,
        feature_range: 8.0,
        seed,
    }
    .generate();
    let kernel = KernelKind::Gaussian { gamma };
    let g = kernel.square_matrix(&train.end_features);
    let k = kernel.square_matrix(&train.start_features);
    let idx = train.kron_index();
    let n = idx.len();
    let op = KronKernelOp::new(Arc::new(g.clone()), Arc::new(k.clone()), idx.clone());
    let sys = RidgeSystemOp { op: &op, lambda };
    let precond = KronSpectralPrecond::new(&eigh(&g), &eigh(&k), idx, lambda);
    let cfg = SolverConfig { max_iters: 2000, tol: 1e-9 };

    let mut x_cg = vec![0.0; n];
    let (cg_stats, cg_secs) = timeit(|| cg(&sys, &train.labels, &mut x_cg, &cfg));
    let mut x_pcg = vec![0.0; n];
    let (pcg_stats, pcg_secs) = timeit(|| pcg(&sys, &train.labels, &mut x_pcg, &precond, &cfg));

    // Closed form applies only when the graph is complete; its timing
    // includes the kernel builds and both eigendecompositions (a whole fit).
    let complete = density >= 1.0;
    let (exact_secs, diff_exact_pcg, exact_desc) = if complete {
        let ridge_cfg =
            RidgeConfig { lambda, kernel_d: kernel, kernel_t: kernel, ..Default::default() };
        let (model, secs) = timeit(|| {
            KronRidge::new(ridge_cfg).with_solver(RidgeSolver::Exact).fit(&train).unwrap()
        });
        let diff = max_abs_diff(&model.dual_coef, &x_pcg);
        (Json::from(secs), Json::from(diff), format!("{secs:.3}s (diff {diff:.2e})"))
    } else {
        (Json::Null, Json::Null, "n/a (incomplete graph)".to_string())
    };

    println!(
        "eigen {side}x{side} density={density} n={n} lambda={lambda:.0e}: \
         cg {} iters {cg_secs:.3}s | pcg {} iters {pcg_secs:.3}s | exact {exact_desc}",
        cg_stats.iterations, pcg_stats.iterations
    );
    Json::obj(vec![
        ("side", Json::from(side)),
        ("density", Json::from(density)),
        ("n_edges", Json::from(n)),
        ("gamma", Json::from(gamma)),
        ("lambda", Json::from(lambda)),
        ("cg_iters", Json::from(cg_stats.iterations)),
        ("cg_secs", Json::from(cg_secs)),
        ("cg_converged", Json::from(cg_stats.converged)),
        ("pcg_iters", Json::from(pcg_stats.iterations)),
        ("pcg_secs", Json::from(pcg_secs)),
        ("pcg_converged", Json::from(pcg_stats.converged)),
        ("max_abs_diff_cg_pcg", Json::from(max_abs_diff(&x_cg, &x_pcg))),
        ("exact_fit_secs", exact_secs),
        ("max_abs_diff_exact_pcg", diff_exact_pcg),
    ])
}

/// The eigendecomposition fast-path comparison: complete (closed form is
/// exact, the preconditioner is the exact inverse) and near-complete
/// (surrogate preconditioning) checkerboards, written to `BENCH_eigen.json`.
fn run_eigen(smoke: bool, full: bool, seed: u64) {
    println!("\n--- eigendecomposition fast paths: exact vs cg vs precond-cg ---");
    let side = if smoke {
        16
    } else if full {
        48
    } else {
        24
    };
    let rows = vec![
        // Complete graph, moderate conditioning.
        eigen_row(side, 1.0, 0.3, 1e-3, seed),
        // Near-complete, ill-conditioned: the preconditioner's headline case.
        eigen_row(side, 0.85, 0.02, 1e-4, seed),
    ];
    let host_threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let section = Json::obj(vec![
        ("bench", Json::from("bench_convergence")),
        ("host_threads", Json::from(host_threads)),
        ("smoke", Json::from(smoke)),
        ("full", Json::from(full)),
        ("rows", Json::Arr(rows)),
    ]);
    let out =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_eigen.json");
    match update_json_file(&out, "eigen", section) {
        Ok(()) => println!("wrote eigen results to {}", out.display()),
        Err(err) => eprintln!("failed to write {}: {err}", out.display()),
    }
}

fn main() {
    let args = Args::parse();
    args.expect_known("bench_convergence", &["bench", "full", "quick", "seed", "smoke"])
        .expect("flags");
    let full = args.has("full");
    let smoke = args.has("smoke");
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let seed = args.get_u64("seed", 1).expect("--seed");

    if smoke {
        run_eigen(true, full, seed);
        println!("\nbench_convergence done");
        return;
    }

    for (name, data) in datasets(full, seed) {
        // zero-shot train/test split in place of one CV fold (Fig. 2 block)
        let (train, test) = data.zero_shot_split(1.0 / 3.0, seed);
        println!(
            "\n### {name}: train n={} (m={}, q={}), test n={} — linear vertex kernels",
            train.n_edges(),
            train.m(),
            train.q(),
            test.n_edges()
        );

        if which == "all" || which == "ridge" {
            println!("--- Fig. 3: KronRidge ---");
            for exp in LAMBDAS {
                let cfg = RidgeConfig {
                    lambda: 2f64.powi(exp),
                    iterations: 100,
                    trace: true,
                    tol: 1e-14,
                    ..Default::default()
                };
                let (_, trace) = KronRidge::new(cfg).fit_traced(&train, Some(&test)).unwrap();
                print_trace("ridge", exp, &trace);
            }
        }

        for (tag, inner) in [("svm10", 10usize), ("svm100", 100usize)] {
            if which != "all" && which != tag {
                continue;
            }
            println!("--- Fig. {}: KronSVM, {} inner iterations ---",
                     if inner == 10 { 4 } else { 5 }, inner);
            for exp in LAMBDAS {
                let cfg = SvmConfig {
                    lambda: 2f64.powi(exp),
                    outer_iters: if full { 100 } else { 40 },
                    inner_iters: inner,
                    trace: true,
                    ..Default::default()
                };
                let (_, trace) = KronSvm::new(cfg).fit_traced(&train, Some(&test)).unwrap();
                print_trace(tag, exp, &trace);
            }
        }
    }

    run_eigen(false, full, seed);
    println!("\nbench_convergence done");
}
