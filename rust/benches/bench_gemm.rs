//! GEMM core micro-benchmark: the packed, register-blocked GEMM
//! (`linalg::gemm`) against the naive loops it replaced.
//!
//! Per (m, n, k) shape:
//! * **naive-nt** — the pre-GEMM `matmul_nt`: one `dot` per output element,
//!   no blocking (what every `kernel_matrix` call used to run on);
//! * **packed-nt** — `gemm_nt_into`, serial, then sharded over 2/4/8 worker
//!   threads;
//! * **axpy-nn** — the pre-GEMM `matmul_into` (i-k-j AXPY loops with k/j
//!   cache blocks and the since-removed zero-skip branch);
//! * **packed-nn** — `gemm_nn_into` (transpose-pack + NT core), serial.
//!
//! Asserts the packed results are bitwise identical to the per-element `dot`
//! reference before timing anything, and records the speedups into
//! `BENCH_batched_gvt.json` (section `"gemm"`, see `docs/BENCHMARKS.md`).
//!
//! Run: `cargo bench --bench bench_gemm [-- --quick|--full]`

use kronvt::linalg::gemm::{gemm_nn_into, gemm_nt_into, pack_transpose};
use kronvt::linalg::vecops::dot;
use kronvt::util::args::Args;
use kronvt::util::json::{update_json_file, Json};
use kronvt::util::rng::Pcg32;
use kronvt::util::timer::{fmt_secs, BenchRunner};

const NT_THREADS: [usize; 3] = [2, 4, 8];

/// The pre-GEMM `matmul_nt`: an unblocked dot-product loop.
fn naive_nt(a: &[f64], b: &[f64], m: usize, k: usize, n: usize, c: &mut [f64]) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            c[i * n + j] = dot(arow, &b[j * k..(j + 1) * k]);
        }
    }
}

/// The pre-GEMM `matmul_into`: i-k-j AXPY loops with k/j cache blocking and
/// the (since-removed) zero-skip branch.
fn axpy_blocked_nn(a: &[f64], b: &[f64], m: usize, k_dim: usize, n: usize, c: &mut [f64]) {
    c.iter_mut().for_each(|v| *v = 0.0);
    const KB: usize = 64;
    const JB: usize = 256;
    for jb in (0..n).step_by(JB) {
        let jend = (jb + JB).min(n);
        for kb in (0..k_dim).step_by(KB) {
            let kend = (kb + KB).min(k_dim);
            for i in 0..m {
                let a_row = &a[i * k_dim..(i + 1) * k_dim];
                let c_row = &mut c[i * n..(i + 1) * n];
                for kk in kb..kend {
                    let aik = a_row[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let b_row = &b[kk * n..(kk + 1) * n];
                    for j in jb..jend {
                        c_row[j] += aik * b_row[j];
                    }
                }
            }
        }
    }
}

fn main() {
    let args = Args::parse();
    args.expect_known("bench_gemm", &["bench", "full", "quick"]).expect("flags");
    let full = args.has("full");
    let quick = args.has("quick");
    let mut rng = Pcg32::seeded(4242);

    let shapes: &[(usize, usize, usize)] = if full {
        &[(256, 256, 128), (512, 512, 256), (768, 768, 384), (1024, 1024, 256)]
    } else if quick {
        &[(128, 128, 64), (256, 256, 128)]
    } else {
        &[(256, 256, 128), (512, 512, 256)]
    };

    println!(
        "{:>5} {:>5} {:>5} | {:>10} {:>10} {:>7} | {:>10} {:>10} {:>10} | {:>10} {:>10} {:>7} | {:>8}",
        "m", "n", "k", "naive-nt", "packed-nt", "spd", "nt-2t", "nt-4t", "nt-8t", "axpy-nn",
        "packed-nn", "spd", "GFLOP/s"
    );

    let mut json_rows = Vec::new();
    let mut largest: Option<Json> = None;
    for &(m, n, k) in shapes {
        let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
        let bt: Vec<f64> = (0..n * k).map(|_| rng.normal()).collect();
        let bn = pack_transpose(&bt, n, k); // k×n row-major for the NN path
        let mut c = vec![0.0; m * n];
        let mut c_ref = vec![0.0; m * n];

        // correctness gate: packed == per-element dot reference, bitwise
        naive_nt(&a, &bt, m, k, n, &mut c_ref);
        gemm_nt_into(&a, &bt, m, k, n, &mut c, 1);
        assert_eq!(c, c_ref, "packed NT diverged from the dot reference");
        gemm_nt_into(&a, &bt, m, k, n, &mut c, 4);
        assert_eq!(c, c_ref, "threaded NT diverged from serial");
        gemm_nn_into(&a, &bn, m, k, n, &mut c, 1);
        assert_eq!(c, c_ref, "packed NN diverged from the dot reference");

        let runner = BenchRunner::quick();
        let t_naive_nt = runner.run(|| naive_nt(&a, &bt, m, k, n, &mut c)).min_secs;
        let t_packed_nt = runner.run(|| gemm_nt_into(&a, &bt, m, k, n, &mut c, 1)).min_secs;
        let mut t_nt_threads = Vec::new();
        for &t in &NT_THREADS {
            t_nt_threads.push(runner.run(|| gemm_nt_into(&a, &bt, m, k, n, &mut c, t)).min_secs);
        }
        let t_axpy_nn = runner.run(|| axpy_blocked_nn(&a, &bn, m, k, n, &mut c)).min_secs;
        let t_packed_nn = runner.run(|| gemm_nn_into(&a, &bn, m, k, n, &mut c, 1)).min_secs;

        let gflops = 2.0 * (m * n * k) as f64 / t_packed_nt / 1e9;
        println!(
            "{:>5} {:>5} {:>5} | {:>10} {:>10} {:>6.2}x | {:>10} {:>10} {:>10} | {:>10} {:>10} {:>6.2}x | {:>8.2}",
            m,
            n,
            k,
            fmt_secs(t_naive_nt),
            fmt_secs(t_packed_nt),
            t_naive_nt / t_packed_nt,
            fmt_secs(t_nt_threads[0]),
            fmt_secs(t_nt_threads[1]),
            fmt_secs(t_nt_threads[2]),
            fmt_secs(t_axpy_nn),
            fmt_secs(t_packed_nn),
            t_axpy_nn / t_packed_nn,
            gflops
        );

        let row = Json::obj(vec![
            ("m", Json::from(m)),
            ("n", Json::from(n)),
            ("k", Json::from(k)),
            ("naive_nt_secs", Json::from(t_naive_nt)),
            ("packed_nt_secs", Json::from(t_packed_nt)),
            ("speedup_nt", Json::from(t_naive_nt / t_packed_nt)),
            ("packed_nt_2t_secs", Json::from(t_nt_threads[0])),
            ("packed_nt_4t_secs", Json::from(t_nt_threads[1])),
            ("packed_nt_8t_secs", Json::from(t_nt_threads[2])),
            ("axpy_nn_secs", Json::from(t_axpy_nn)),
            ("packed_nn_secs", Json::from(t_packed_nn)),
            ("speedup_nn", Json::from(t_axpy_nn / t_packed_nn)),
            ("packed_nt_gflops", Json::from(gflops)),
        ]);
        largest = Some(row.clone());
        json_rows.push(row);
    }

    let host_threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let section = Json::obj(vec![
        ("bench", Json::from("bench_gemm")),
        ("host_threads", Json::from(host_threads)),
        ("full", Json::from(full)),
        ("quick", Json::from(quick)),
        ("rows", Json::Arr(json_rows)),
        ("largest", largest.unwrap_or(Json::Null)),
    ]);
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_batched_gvt.json");
    match update_json_file(&out, "gemm", section) {
        Ok(()) => println!("\nwrote GEMM results to {}", out.display()),
        Err(err) => eprintln!("\nfailed to write {}: {err}", out.display()),
    }
    println!("bench_gemm done");
}
