//! Tables 5, 6 and 7 — dataset statistics, AUCs, and CPU runtimes for the
//! five learning methods across the six datasets.
//!
//! Methods (§5.6): KronSVM (10×10 iterations), KronRidge, SGD hinge, SGD
//! logistic (10⁶ updates or ≥ 1 epoch), KNN. Linear vertex kernels on the
//! DTI sets, Gaussian (γ=1) on the checkerboards; λ from a coarse
//! validation grid as §5.2 prescribes. DTI sets use 3×3-fold zero-shot CV (Fig. 2);
//! checkerboards use an independently generated test set.
//!
//! Expected shape (Tables 6–7): KronSVM best or tied nearly everywhere;
//! KronRidge close behind; SGD competitive on DTI but exactly 0.5 on the
//! checkerboards (linear model, multiplicative concept); KNN solid on the
//! 2-feature checkerboards, slow on high-dimensional DTI.
//!
//! Run: `cargo bench --bench bench_table6 [-- --full]`

use kronvt::baselines::{KnnConfig, KnnModel, SgdConfig, SgdLossKind, SgdModel};
use kronvt::coordinator::run_cv_jobs;
use kronvt::data::checkerboard::CheckerboardConfig;
use kronvt::data::{dti, Dataset};
use kronvt::eval::auc::auc;
use kronvt::kernels::KernelKind;
use kronvt::train::{KronRidge, KronSvm, RidgeConfig, SvmConfig};
use kronvt::util::args::Args;
use kronvt::util::timer::Timer;

const METHODS: [&str; 5] = ["KronSVM", "KronRidge", "SGD hinge", "SGD logistic", "KNN"];

fn run_method(method: &str, train: &Dataset, test: &Dataset, gaussian: bool) -> Vec<f64> {
    let kernel = if gaussian { KernelKind::Gaussian { gamma: 1.0 } } else { KernelKind::Linear };
    // §5.2: a small iteration budget is the main regularizer; λ is set on a
    // coarse validation grid (our normalized synthetic features want larger
    // λ than the paper's raw-similarity features did).
    let lambda = if gaussian { 2f64.powi(-7) } else { 1.0 };
    match method {
        "KronSVM" => KronSvm::new(SvmConfig {
            lambda,
            kernel_d: kernel,
            kernel_t: kernel,
            outer_iters: 10,
            inner_iters: 10,
            ..Default::default()
        })
        .fit(train)
        .unwrap()
        .predict(test),
        "KronRidge" => KronRidge::new(RidgeConfig {
            lambda: if gaussian { lambda } else { 1e-2 },
            kernel_d: kernel,
            kernel_t: kernel,
            iterations: if gaussian { 100 } else { 10 },
            ..Default::default()
        })
        .fit(train)
        .unwrap()
        .predict(test),
        "SGD hinge" | "SGD logistic" => {
            let loss =
                if method == "SGD hinge" { SgdLossKind::Hinge } else { SgdLossKind::Logistic };
            SgdModel::fit(
                train,
                &SgdConfig { loss, lambda: 1e-4, updates: 1_000_000, ..Default::default() },
            )
            .unwrap()
            .predict(test)
        }
        "KNN" => KnnModel::fit(train, &KnnConfig { k: 9, ..Default::default() })
            .unwrap()
            .predict(test),
        other => panic!("unknown method {other}"),
    }
}

struct Cell {
    auc: f64,
    secs: f64,
}

fn main() {
    let args = Args::parse();
    args.expect_known("bench_table6", &["bench", "full", "quick", "seed"]).expect("flags");
    let full = args.has("full");
    let seed = args.get_u64("seed", 1).expect("--seed");

    // --- datasets (Table 5) ---
    let mut datasets: Vec<(String, Dataset, bool, bool)> = Vec::new(); // (name, data, gaussian?, cv?)
    datasets.push(("GPCR".into(), dti::gpcr(seed).generate(), false, true));
    datasets.push(("IC".into(), dti::ic(seed).generate(), false, true));
    if full {
        datasets.push(("E".into(), dti::e(seed).generate(), false, true));
        datasets.push(("Ki".into(), dti::ki(seed).generate(), false, true));
    } else {
        datasets.push((
            "E(sc)".into(),
            dti::DtiConfig { m: 180, q: 260, n: 11_800, positives: 120, seed, ..Default::default() }
                .generate(),
            false,
            true,
        ));
        datasets.push((
            "Ki(sc)".into(),
            dti::DtiConfig { m: 560, q: 62, n: 14_900, positives: 510, seed, ..Default::default() }
                .generate(),
            false,
            true,
        ));
    }
    let checker_m = if full { 1000 } else { 250 };
    // keep the paper's vertex density (1000 vertices / 100 units = 10 per
    // unit cell) when scaling the board down
    let checker_range = checker_m as f64 / 10.0;
    datasets.push((
        if full { "Checker".into() } else { "Checker(sc)".into() },
        CheckerboardConfig {
            m: checker_m,
            q: checker_m,
            density: 0.25,
            noise: 0.2,
            feature_range: checker_range,
            seed,
        }
        .generate(),
        true,
        false,
    ));
    if full {
        // Checker+ is 10.24M edges; include only on --full runs with patience.
        datasets.push((
            "Checker+(sc)".into(),
            CheckerboardConfig {
                m: 2000,
                q: 2000,
                density: 0.25,
                noise: 0.2,
                feature_range: 200.0,
                seed,
            }
            .generate(),
            true,
            false,
        ));
    }

    println!("== Table 5: dataset statistics ==\n");
    println!(
        "{:<14} {:>9} {:>8} {:>9} {:>8} {:>8}",
        "dataset", "edges", "pos.", "neg.", "starts", "ends"
    );
    for (name, ds, _, _) in &datasets {
        let st = ds.stats();
        println!(
            "{:<14} {:>9} {:>8} {:>9} {:>8} {:>8}",
            name, st.edges, st.positives, st.negatives, st.start_vertices, st.end_vertices
        );
    }

    // --- run the grid ---
    let mut table: Vec<(String, Vec<Cell>)> = Vec::new();
    for (name, ds, gaussian, use_cv) in &datasets {
        let mut cells = Vec::new();
        for method in METHODS {
            let timer = Timer::start();
            let auc_val = if *use_cv {
                let folds = ds.ninefold_cv(seed);
                let results =
                    run_cv_jobs(&folds, 1, |tr, te| auc(&te.labels, &run_method(method, tr, te, *gaussian)));
                kronvt::coordinator::jobs::mean_auc(&results)
            } else {
                let test = CheckerboardConfig {
                    m: ds.m(),
                    q: ds.q(),
                    density: 0.25,
                    noise: 0.2,
                    feature_range: ds.m() as f64 / 10.0,
                    seed: seed ^ 0xFEED,
                }
                .generate();
                auc(&test.labels, &run_method(method, ds, &test, *gaussian))
            };
            cells.push(Cell { auc: auc_val, secs: timer.elapsed_secs() });
            eprintln!("[{name}] {method}: AUC={auc_val:.3} ({:.1}s)", cells.last().unwrap().secs);
        }
        table.push((name.clone(), cells));
    }

    // --- Table 6 (AUC) ---
    println!("\n== Table 6: AUCs ==\n");
    print!("{:<14}", "");
    for (name, _) in &table {
        print!(" {name:>12}");
    }
    println!();
    for (mi, method) in METHODS.iter().enumerate() {
        print!("{method:<14}");
        for (_, cells) in &table {
            print!(" {:>12.2}", cells[mi].auc);
        }
        println!();
    }

    // --- Table 7 (runtime) ---
    println!("\n== Table 7: CPU runtime in seconds ==\n");
    print!("{:<14}", "");
    for (name, _) in &table {
        print!(" {name:>12}");
    }
    println!();
    for (mi, method) in METHODS.iter().enumerate() {
        print!("{method:<14}");
        for (_, cells) in &table {
            print!(" {:>12.1}", cells[mi].secs);
        }
        println!();
    }
    println!("\nbench_table6 done");
}
