//! Stochastic mini-batch trainer vs. exact CG: convergence against
//! wall-clock on checkerboards scaled past the exact solver's comfortable
//! size.
//!
//! Each row fits the same Kronecker ridge dual system twice — once with the
//! mini-batch sampled-GVT block coordinate descent trainer
//! ([`fit_stochastic_source`] over an in-memory streaming source) and once
//! with plain CG on the full [`KronKernelOp`] — and reports wall-clock,
//! epoch/iteration counts, final residuals, and the max-abs difference
//! between the two dual solutions. Expected shape: CG wins on small boards;
//! as the edge count grows the stochastic trainer's O(batch·m) steps and
//! streaming access pattern close the gap while tracking the CG solution to
//! within the residual tolerance.
//!
//! Results land in `BENCH_stochastic.json` (section `"stochastic"`, see
//! `docs/BENCHMARKS.md`). `-- --smoke` runs one small row (what `ci.sh`
//! exercises); `-- --full` scales the boards up.
//!
//! Run: `cargo bench --bench bench_stochastic [-- --full|--smoke] [--seed N]`

use std::sync::Arc;

use kronvt::api::Compute;
use kronvt::data::checkerboard::CheckerboardConfig;
use kronvt::data::stream::InMemorySource;
use kronvt::gvt::operator::RidgeSystemOp;
use kronvt::gvt::KronKernelOp;
use kronvt::kernels::KernelKind;
use kronvt::linalg::solvers::{cg, SolverConfig};
use kronvt::linalg::vecops::max_abs_diff;
use kronvt::train::{fit_stochastic_source, StochasticConfig};
use kronvt::util::args::Args;
use kronvt::util::json::{update_json_file, Json};
use kronvt::util::timer::timeit;

/// One comparison case: stochastic trainer vs. plain CG on the same
/// checkerboard ridge dual system.
fn row(side: usize, density: f64, batch_edges: usize, epochs: usize, seed: u64) -> Json {
    let train = CheckerboardConfig {
        m: side,
        q: side,
        density,
        noise: 0.1,
        feature_range: 8.0,
        seed,
    }
    .generate();
    let kernel = KernelKind::Gaussian { gamma: 0.3 };
    let lambda = 1e-3;

    let cfg = StochasticConfig {
        lambda,
        kernel_d: kernel,
        kernel_t: kernel,
        batch_edges,
        epochs,
        seed,
        tol: 1e-6,
        ..Default::default()
    };
    let source = InMemorySource::new(&train);
    let compute = Compute::default();
    let (stoch, stoch_secs) = timeit(|| {
        fit_stochastic_source(
            &source,
            &train.start_features,
            &train.end_features,
            &cfg,
            &compute,
            None,
        )
        .unwrap()
    });

    // Exact CG reference on the same dual system (kernel builds included in
    // the timing, mirroring what a fresh fit pays).
    let ((cg_stats, x_cg, n), cg_secs) = timeit(|| {
        let g = kernel.square_matrix(&train.end_features);
        let k = kernel.square_matrix(&train.start_features);
        let idx = train.kron_index();
        let n = idx.len();
        let op = KronKernelOp::new(Arc::new(g), Arc::new(k), idx);
        let sys = RidgeSystemOp { op: &op, lambda };
        let solver_cfg = SolverConfig { max_iters: 4000, tol: 1e-9 };
        let mut x_cg = vec![0.0; n];
        let stats = cg(&sys, &train.labels, &mut x_cg, &solver_cfg);
        (stats, x_cg, n)
    });

    let diff = max_abs_diff(&stoch.duals, &x_cg);
    println!(
        "stochastic {side}x{side} density={density} n={n} batch={batch_edges}: \
         stoch {} epochs {stoch_secs:.3}s (resid {:.2e}) | cg {} iters {cg_secs:.3}s | \
         diff {diff:.2e}",
        stoch.epochs_run, stoch.final_residual, cg_stats.iterations
    );
    Json::obj(vec![
        ("side", Json::from(side)),
        ("density", Json::from(density)),
        ("n_edges", Json::from(n)),
        ("batch_edges", Json::from(batch_edges)),
        ("epochs_run", Json::from(stoch.epochs_run)),
        ("stoch_secs", Json::from(stoch_secs)),
        ("stoch_converged", Json::from(stoch.converged)),
        ("stoch_final_residual", Json::from(stoch.final_residual)),
        ("cg_iters", Json::from(cg_stats.iterations)),
        ("cg_secs", Json::from(cg_secs)),
        ("cg_converged", Json::from(cg_stats.converged)),
        ("max_abs_diff_stoch_cg", Json::from(diff)),
    ])
}

fn main() {
    let args = Args::parse();
    args.expect_known("bench_stochastic", &["bench", "full", "quick", "seed", "smoke"])
        .expect("flags");
    let full = args.has("full");
    let smoke = args.has("smoke");
    let seed = args.get_u64("seed", 1).expect("--seed");

    println!("--- stochastic mini-batch trainer vs exact CG ---");
    let rows = if smoke {
        vec![row(16, 0.5, 128, 40, seed)]
    } else if full {
        vec![
            row(64, 0.5, 512, 60, seed),
            row(128, 0.5, 1024, 60, seed),
            row(192, 0.4, 2048, 40, seed),
        ]
    } else {
        vec![row(32, 0.5, 256, 50, seed), row(64, 0.5, 512, 40, seed)]
    };

    let host_threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let section = Json::obj(vec![
        ("bench", Json::from("bench_stochastic")),
        ("host_threads", Json::from(host_threads)),
        ("smoke", Json::from(smoke)),
        ("full", Json::from(full)),
        ("rows", Json::Arr(rows)),
    ]);
    let out =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_stochastic.json");
    match update_json_file(&out, "stochastic", section) {
        Ok(()) => println!("wrote stochastic results to {}", out.display()),
        Err(err) => eprintln!("failed to write {}: {err}", out.display()),
    }
    println!("\nbench_stochastic done");
}
