//! Network-serving benchmark: sustained mixed traffic over the TCP/JSON
//! lines protocol (`coordinator::net`), measuring the end-to-end latency a
//! remote client actually sees — parse + merge + score + serialize + two
//! socket hops — rather than the in-process numbers of `bench_serving`.
//!
//! Two sections go to `BENCH_net.json` at the repo root:
//!
//! * **net** — C concurrent loopback clients replay a request stream drawn
//!   from a bounded vertex pool, mixed the way real traffic is: mostly
//!   plain predicts, a slice with aggressive deadlines (some of which
//!   expire into typed `deadline_exceeded` lines), and a slice of invalid
//!   requests (`invalid_request` lines). Reported: p50/p95/p99 completion
//!   latency of scored requests, throughput, and the error mix. Scores are
//!   asserted bitwise-equal to in-process `predict_blocking` on a sample.
//! * **swap** — steady-state (warm kernel-row cache) p50 vs the latency of
//!   the first request after a `swap_model` (new generation, cold cache),
//!   over several swaps: the price of a zero-downtime deploy as seen from
//!   the wire.
//!
//! Run: `cargo bench --bench bench_net [-- --full --threads N --workers W --clients C]`

use std::sync::atomic::Ordering;
use std::sync::Arc;

use kronvt::api::{Compute, TrainedModel};
use kronvt::coordinator::{
    NetClient, NetServer, NetServerConfig, PredictError, PredictServer, ServerConfig,
};
use kronvt::data::dti::DtiConfig;
use kronvt::kernels::KernelKind;
use kronvt::train::{KronRidge, RidgeConfig};
use kronvt::util::args::Args;
use kronvt::util::json::{update_json_file, Json};
use kronvt::util::rng::Pcg32;
use kronvt::util::timer::{fmt_secs, Timer};

fn percentile(sorted: &[f64], p: f64) -> f64 {
    // Empty-set percentiles report 0.0: JSON cannot encode NaN.
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted.get(idx).copied().unwrap_or(0.0)
}

fn main() {
    let args = Args::parse();
    args.expect_known("bench_net", &["bench", "full", "quick", "threads", "workers", "clients"])
        .expect("flags");
    let full = args.has("full");
    let threads = args.get_usize("threads", 1).expect("--threads");
    let workers = args.get_usize("workers", 2).expect("--workers");
    let clients = args.get_usize("clients", 4).expect("--clients");
    let (dti, per_client, pool_size, swaps) = if full {
        (kronvt::data::dti::gpcr(7), 200, 48, 5)
    } else {
        (
            DtiConfig { m: 90, q: 70, n: 1800, positives: 120, seed: 7, ..Default::default() },
            40,
            24,
            3,
        )
    };

    let data = dti.generate();
    println!("training KronRidge on {} ({} edges)...", data.name, data.n_edges());
    let (train, _) = data.zero_shot_split(0.2, 5);
    let gaussian = KernelKind::Gaussian { gamma: 0.5 };
    let model = KronRidge::new(RidgeConfig {
        lambda: 2f64.powi(-4),
        kernel_d: gaussian,
        kernel_t: gaussian,
        iterations: 50,
        ..Default::default()
    })
    .with_compute(Compute::threads(threads))
    .fit(&train)
    .expect("training");
    let d = model.train_start_features.cols();
    let r = model.train_end_features.cols();

    let server = Arc::new(PredictServer::start(
        model.clone(),
        ServerConfig {
            workers,
            compute: Compute::threads(threads).with_cache_vertices(4 * pool_size),
            ..Default::default()
        },
    ));
    let net = NetServer::start(server.clone(), NetServerConfig::default()).expect("listener");
    let addr = net.local_addr().to_string();
    println!("listening on {addr}; {clients} clients x {per_client} requests");

    // Bounded vertex pool: repeat-vertex traffic keeps the kernel-row
    // cache relevant, exactly as in bench_serving.
    let mut rng = Pcg32::seeded(1234);
    let start_pool: Vec<Vec<f64>> =
        (0..pool_size).map(|_| rng.normal_vec(d).iter().map(|x| 0.3 * x).collect()).collect();
    let end_pool: Vec<Vec<f64>> =
        (0..pool_size).map(|_| rng.normal_vec(r).iter().map(|x| 0.3 * x).collect()).collect();

    // ---- sustained mixed traffic ----
    let timer = Timer::start();
    let outcomes: Vec<(Vec<f64>, usize, usize, usize)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let (addr, start_pool, end_pool) = (&addr, &start_pool, &end_pool);
                scope.spawn(move || {
                    let mut rng = Pcg32::seeded(9000 + c as u64);
                    let mut client = NetClient::connect(addr).expect("client connect");
                    let mut ok_latencies = Vec::new();
                    let (mut expired, mut invalid, mut other) = (0usize, 0usize, 0usize);
                    for i in 0..per_client {
                        let sf: Vec<Vec<f64>> =
                            (0..4).map(|_| start_pool[rng.below(pool_size)].clone()).collect();
                        let ef: Vec<Vec<f64>> =
                            (0..4).map(|_| end_pool[rng.below(pool_size)].clone()).collect();
                        let mut edges: Vec<(u32, u32)> = (0..8)
                            .map(|_| (rng.below(4) as u32, rng.below(4) as u32))
                            .collect();
                        // The mix: ~1/10 invalid (dangling edge), ~1/10 on
                        // a deadline tight enough that some expire.
                        let deadline = match i % 10 {
                            3 => {
                                edges[0].0 = 99; // references no request vertex
                                None
                            }
                            7 => Some(1u64),
                            _ => None,
                        };
                        let t = Timer::start();
                        let reply =
                            client.predict(&sf, &ef, &edges, deadline).expect("transport");
                        match reply.result {
                            Ok(scores) => {
                                assert_eq!(scores.len(), 8);
                                ok_latencies.push(t.elapsed_secs());
                            }
                            Err(PredictError::DeadlineExceeded) => expired += 1,
                            Err(PredictError::InvalidRequest(_)) => invalid += 1,
                            Err(_) => other += 1,
                        }
                    }
                    (ok_latencies, expired, invalid, other)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    let wall_secs = timer.elapsed_secs();
    let mut latencies: Vec<f64> = outcomes.iter().flat_map(|o| o.0.iter().copied()).collect();
    let expired: usize = outcomes.iter().map(|o| o.1).sum();
    let invalid: usize = outcomes.iter().map(|o| o.2).sum();
    let other: usize = outcomes.iter().map(|o| o.3).sum();
    latencies.sort_by(f64::total_cmp);
    let offered = clients * per_client;
    let scored = latencies.len();
    let (p50, p95, p99) =
        (percentile(&latencies, 0.50), percentile(&latencies, 0.95), percentile(&latencies, 0.99));
    let rps = scored as f64 / wall_secs;
    println!(
        "mixed traffic: offered {offered}, scored {scored}, expired {expired}, \
         invalid {invalid}, other {other} in {}",
        fmt_secs(wall_secs)
    );
    println!(
        "latency p50 {} p95 {} p99 {}  ({rps:.0} scored req/s)",
        fmt_secs(p50),
        fmt_secs(p95),
        fmt_secs(p99)
    );

    // Wire faithfulness spot check: one batch scored over TCP must equal
    // the in-process path bitwise.
    {
        let sf: Vec<Vec<f64>> = (0..4).map(|i| start_pool[i].clone()).collect();
        let ef: Vec<Vec<f64>> = (0..4).map(|i| end_pool[i].clone()).collect();
        let edges: Vec<(u32, u32)> = (0..4).map(|i| (i as u32, (3 - i) as u32)).collect();
        let mut client = NetClient::connect(&addr).expect("check connect");
        let wire = client
            .predict(&sf, &ef, &edges, None)
            .expect("transport")
            .result
            .expect("scored");
        let local = server
            .predict_blocking(sf, ef, edges)
            .expect("in-process scored");
        assert_eq!(wire, local, "wire scores must be bitwise-identical to in-process");
    }

    let st = server.stats();
    let host_threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let section = Json::obj(vec![
        ("bench", Json::from("bench_net")),
        ("full", Json::from(full)),
        ("host_threads", Json::from(host_threads)),
        ("threads", Json::from(threads)),
        ("workers", Json::from(workers)),
        ("clients", Json::from(clients)),
        ("offered", Json::from(offered)),
        ("scored", Json::from(scored)),
        ("deadline_expired", Json::from(expired)),
        ("invalid", Json::from(invalid)),
        ("other_errors", Json::from(other)),
        ("wall_secs", Json::from(wall_secs)),
        ("throughput_rps", Json::from(rps)),
        ("p50_secs", Json::from(p50)),
        ("p95_secs", Json::from(p95)),
        ("p99_secs", Json::from(p99)),
        ("cache_hits", Json::from(st.cache_hits.load(Ordering::Relaxed))),
        ("cache_misses", Json::from(st.cache_misses.load(Ordering::Relaxed))),
        ("bitwise_identical", Json::from(true)),
    ]);
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_net.json");
    match update_json_file(&out, "net", section) {
        Ok(()) => println!("wrote mixed-traffic results to {}", out.display()),
        Err(err) => eprintln!("failed to write {}: {err}", out.display()),
    }

    // ---- warm vs cold-after-swap latency ----
    // Steady state first: one client, fixed vertices, so every kernel row
    // is a cache hit. Then swap the model (same weights — the cost under
    // measure is the generation change: fresh context, cold cache) and
    // time the first request against the new generation.
    let mut client = NetClient::connect(&addr).expect("swap client");
    let sf: Vec<Vec<f64>> = (0..4).map(|i| start_pool[i].clone()).collect();
    let ef: Vec<Vec<f64>> = (0..4).map(|i| end_pool[i].clone()).collect();
    let edges: Vec<(u32, u32)> = (0..8).map(|i| ((i % 4) as u32, ((i + 1) % 4) as u32)).collect();
    let mut warm = Vec::new();
    for _ in 0..20 {
        let t = Timer::start();
        let reply = client.predict(&sf, &ef, &edges, None).expect("transport");
        reply.result.expect("warm request scored");
        warm.push(t.elapsed_secs());
    }
    warm.sort_by(f64::total_cmp);
    let warm_p50 = percentile(&warm, 0.50);

    let mut cold_firsts = Vec::new();
    for _ in 0..swaps {
        let generation = server
            .swap_model(TrainedModel::from_dual(model.clone(), 2f64.powi(-4)))
            .expect("hot swap");
        let t = Timer::start();
        let reply = client.predict(&sf, &ef, &edges, None).expect("transport");
        let scores = reply.result.expect("post-swap request scored");
        assert_eq!(scores.len(), 8);
        assert_eq!(reply.generation, generation, "first reply already on the new generation");
        cold_firsts.push(t.elapsed_secs());
        // Re-warm so the next swap measures from steady state again.
        for _ in 0..5 {
            client.predict(&sf, &ef, &edges, None).expect("transport").result.expect("rewarm");
        }
    }
    cold_firsts.sort_by(f64::total_cmp);
    let cold_mean = cold_firsts.iter().sum::<f64>() / cold_firsts.len().max(1) as f64;
    let cold_max = cold_firsts.last().copied().unwrap_or(0.0);
    println!(
        "hot swap x{swaps}: warm p50 {}, cold first mean {} max {}",
        fmt_secs(warm_p50),
        fmt_secs(cold_mean),
        fmt_secs(cold_max)
    );
    let swap_section = Json::obj(vec![
        ("bench", Json::from("bench_net")),
        ("full", Json::from(full)),
        ("swaps", Json::from(swaps)),
        ("warm_p50_secs", Json::from(warm_p50)),
        ("cold_first_mean_secs", Json::from(cold_mean)),
        ("cold_first_max_secs", Json::from(cold_max)),
    ]);
    match update_json_file(&out, "swap", swap_section) {
        Ok(()) => println!("wrote warm-vs-cold swap results to {}", out.display()),
        Err(err) => eprintln!("failed to write {}: {err}", out.display()),
    }

    let ns = net.stats();
    println!(
        "wire: {} connections, {} lines, {} replies ({} errors)",
        ns.connections.load(Ordering::Relaxed),
        ns.lines.load(Ordering::Relaxed),
        ns.replies.load(Ordering::Relaxed),
        ns.wire_errors.load(Ordering::Relaxed),
    );
    net.shutdown();
    if let Ok(server) = Arc::try_unwrap(server) {
        server.shutdown();
    }
    println!("bench_net done");
}
