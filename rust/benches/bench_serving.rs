//! Serving-pipeline benchmark: batched zero-shot throughput with a cold vs
//! warm per-vertex kernel-row cache.
//!
//! Trains one KronRidge model on a synthetic DTI dataset (32-D features —
//! the regime where computing a vertex's `K̂`/`Ĝ` row dominates the batch
//! matvec), then replays a stream of requests whose vertices repeat across a
//! bounded pool (the drug–target / collaborative-filtering traffic pattern
//! the cache targets):
//!
//! * **cold** — a fresh [`PredictContext`](kronvt::model::PredictContext)
//!   with the cache disabled scores the stream (every batch recomputes its
//!   kernel rows);
//! * **warm** — a context with the cache enabled scores the same stream
//!   after one prewarming pass (every vertex row is a hit).
//!
//! Both paths produce bitwise-identical scores (asserted); on repeat-vertex
//! traffic the warm path is expected ≥2× faster per batch. A third section
//! measures end-to-end [`PredictServer`] throughput (merger + scoring pool),
//! and a fourth throws the whole stream at a deliberately under-provisioned
//! server (1 worker, tiny queue, 50ms deadline) to record overload behavior:
//! typed `Overloaded` rejections, deadline expiries / shed work, and the
//! p50/p99 completion latency of accepted requests. Results go to
//! `BENCH_serving.json` at the repo root under `"serving"` and `"overload"`
//! — the perf-trajectory convention of `docs/BENCHMARKS.md`.
//!
//! Run: `cargo bench --bench bench_serving [-- --full --threads N --workers W]`

use kronvt::api::Compute;
use kronvt::coordinator::{PredictError, PredictRequest, PredictServer, ServerConfig};
use kronvt::data::dti::DtiConfig;
use kronvt::data::Dataset;
use kronvt::kernels::KernelKind;
use kronvt::linalg::Matrix;
use kronvt::train::{KronRidge, RidgeConfig};
use kronvt::util::args::Args;
use kronvt::util::json::{update_json_file, Json};
use kronvt::util::rng::Pcg32;
use kronvt::util::timer::{fmt_secs, Timer};

fn main() {
    let args = Args::parse();
    args.expect_known("bench_serving", &["bench", "full", "quick", "threads", "workers"])
        .expect("flags");
    let full = args.has("full");
    let threads = args.get_usize("threads", 1).expect("--threads");
    let workers = args.get_usize("workers", 2).expect("--workers");
    let (dti, requests, edges_per_request, pool_size) = if full {
        (kronvt::data::dti::gpcr(7), 400, 64, 48)
    } else {
        (
            DtiConfig { m: 90, q: 70, n: 1800, positives: 120, seed: 7, ..Default::default() },
            120,
            32,
            24,
        )
    };
    let cache_cap = 4 * pool_size;

    let data = dti.generate();
    println!("training KronRidge on {} ({} edges)...", data.name, data.n_edges());
    let (train, _) = data.zero_shot_split(0.2, 5);
    let gaussian = KernelKind::Gaussian { gamma: 0.5 };
    let model = KronRidge::new(RidgeConfig {
        lambda: 2f64.powi(-4),
        kernel_d: gaussian,
        kernel_t: gaussian,
        iterations: 50,
        ..Default::default()
    })
    .with_compute(Compute::threads(threads))
    .fit(&train)
    .expect("training");

    // Request stream over a bounded vertex pool (repeat-vertex traffic).
    // Pool vertices are novel O(1)-scale feature vectors, like the training
    // features the DTI generator emits.
    let d = model.train_start_features.cols();
    let r = model.train_end_features.cols();
    let mut rng = Pcg32::seeded(1234);
    let start_pool: Vec<Vec<f64>> =
        (0..pool_size).map(|_| rng.normal_vec(d).iter().map(|x| 0.3 * x).collect()).collect();
    let end_pool: Vec<Vec<f64>> =
        (0..pool_size).map(|_| rng.normal_vec(r).iter().map(|x| 0.3 * x).collect()).collect();
    let batches: Vec<Dataset> = (0..requests)
        .map(|b| {
            let (u, v) = (6, 6);
            let su: Vec<usize> = (0..u).map(|_| rng.below(pool_size)).collect();
            let ev: Vec<usize> = (0..v).map(|_| rng.below(pool_size)).collect();
            Dataset {
                start_features: Matrix::from_fn(u, d, |i, j| start_pool[su[i]][j]),
                end_features: Matrix::from_fn(v, r, |i, j| end_pool[ev[i]][j]),
                start_idx: (0..edges_per_request).map(|_| rng.below(u) as u32).collect(),
                end_idx: (0..edges_per_request).map(|_| rng.below(v) as u32).collect(),
                labels: vec![0.0; edges_per_request],
                name: format!("bench-batch-{b}"),
            }
        })
        .collect();
    let total_edges = requests * edges_per_request;

    // ---- cold vs warm PredictContext (min over a few stream replays) ----
    let stream_secs = |ctx: &kronvt::model::PredictContext| -> (f64, Vec<Vec<f64>>) {
        let t = Timer::start();
        let scores: Vec<Vec<f64>> = batches.iter().map(|b| ctx.predict_batch(b)).collect();
        (t.elapsed_secs(), scores)
    };
    let reps = if full { 5 } else { 3 };

    let mut cold_secs = f64::INFINITY;
    let mut cold_scores = Vec::new();
    for _ in 0..reps {
        // fresh: no cache at all
        let ctx = model.predict_context(&Compute::threads(threads).with_cache_vertices(0));
        let (secs, scores) = stream_secs(&ctx);
        cold_secs = cold_secs.min(secs);
        cold_scores = scores;
    }

    let warm_ctx =
        model.predict_context(&Compute::threads(threads).with_cache_vertices(cache_cap));
    let (_, prewarm_scores) = stream_secs(&warm_ctx); // populate the cache
    let mut warm_secs = f64::INFINITY;
    let mut warm_scores = Vec::new();
    for _ in 0..reps {
        let (secs, scores) = stream_secs(&warm_ctx);
        warm_secs = warm_secs.min(secs);
        warm_scores = scores;
    }
    assert_eq!(cold_scores, prewarm_scores, "cold and caching runs must agree bitwise");
    assert_eq!(cold_scores, warm_scores, "warm-cache scores must be bitwise identical");
    let hits = warm_ctx.cache_hits();
    let misses = warm_ctx.cache_misses();
    let hit_rate = hits as f64 / (hits + misses).max(1) as f64;
    let speedup = cold_secs / warm_secs;

    println!(
        "{requests} batches x {edges_per_request} edges, vertex pool {pool_size}/side, threads={threads}"
    );
    println!(
        "cold (no cache): {}/stream  {:>8.0} edges/s",
        fmt_secs(cold_secs),
        total_edges as f64 / cold_secs
    );
    println!(
        "warm (cached):   {}/stream  {:>8.0} edges/s  speedup {speedup:.2}x  hit rate {:.0}%",
        fmt_secs(warm_secs),
        total_edges as f64 / warm_secs,
        100.0 * hit_rate
    );

    // ---- end-to-end server throughput (merger + scoring pool + cache) ----
    let server = PredictServer::start(
        model.clone(),
        ServerConfig {
            workers,
            compute: Compute::threads(threads).with_cache_vertices(cache_cap),
            ..Default::default()
        },
    );
    let t = Timer::start();
    for b in &batches {
        let sf: Vec<Vec<f64>> = (0..b.m()).map(|i| b.start_features.row(i).to_vec()).collect();
        let ef: Vec<Vec<f64>> = (0..b.q()).map(|i| b.end_features.row(i).to_vec()).collect();
        let edges: Vec<(u32, u32)> =
            b.start_idx.iter().zip(&b.end_idx).map(|(&s, &e)| (s, e)).collect();
        let scores = server.predict_blocking(sf, ef, edges).expect("served");
        assert_eq!(scores.len(), edges_per_request);
    }
    let server_secs = t.elapsed_secs();
    let server_eps = total_edges as f64 / server_secs;
    println!(
        "server ({workers} workers): {} for {total_edges} edges  {server_eps:>8.0} edges/s",
        fmt_secs(server_secs)
    );
    server.shutdown();

    let host_threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let section = Json::obj(vec![
        ("bench", Json::from("bench_serving")),
        ("host_threads", Json::from(host_threads)),
        ("full", Json::from(full)),
        ("threads", Json::from(threads)),
        ("workers", Json::from(workers)),
        ("requests", Json::from(requests)),
        ("edges_per_request", Json::from(edges_per_request)),
        ("vertex_pool", Json::from(pool_size)),
        ("cold_stream_secs", Json::from(cold_secs)),
        ("warm_stream_secs", Json::from(warm_secs)),
        ("warm_speedup", Json::from(speedup)),
        ("cache_hit_rate", Json::from(hit_rate)),
        ("server_edges_per_sec", Json::from(server_eps)),
        ("bitwise_identical", Json::from(true)),
    ]);
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_serving.json");
    match update_json_file(&out, "serving", section) {
        Ok(()) => println!("\nwrote cold-vs-warm serving results to {}", out.display()),
        Err(err) => eprintln!("\nfailed to write {}: {err}", out.display()),
    }

    // ---- overload: offered load far beyond capacity ----
    // One worker, a tiny queue, one request per batch, and a 50ms default
    // deadline; the whole stream is thrown at the server at once via
    // try_submit. Measures what the robustness layer does under saturation:
    // typed Overloaded rejections at the queue, deadline expiries (some shed
    // un-computed on the worker), and the completion-latency tail of the
    // accepted requests.
    let timeout_ms = 50u64;
    let server = PredictServer::start(
        model,
        ServerConfig {
            workers: 1,
            max_queue: 8,
            max_batch_edges: edges_per_request, // one request per merged batch
            request_timeout_ms: timeout_ms,
            compute: Compute::threads(threads).with_cache_vertices(cache_cap),
        },
    );
    let mut accepted = Vec::new();
    let mut rejected = 0usize;
    for b in &batches {
        let sf: Vec<Vec<f64>> = (0..b.m()).map(|i| b.start_features.row(i).to_vec()).collect();
        let ef: Vec<Vec<f64>> = (0..b.q()).map(|i| b.end_features.row(i).to_vec()).collect();
        let edges: Vec<(u32, u32)> =
            b.start_idx.iter().zip(&b.end_idx).map(|(&s, &e)| (s, e)).collect();
        let (tx, rx) = std::sync::mpsc::channel();
        let sent_at = std::time::Instant::now();
        match server.try_submit(PredictRequest::new(sf, ef, edges, tx)) {
            Ok(()) => accepted.push((rx, sent_at)),
            Err(PredictError::Overloaded) => rejected += 1,
            Err(err) => panic!("unexpected admission error: {err}"),
        }
    }
    let offered = batches.len();
    let mut completed_latencies = Vec::new();
    let mut expired = 0usize;
    for (rx, sent_at) in accepted.iter() {
        match rx.recv().expect("every accepted request is answered").result {
            Ok(scores) => {
                assert_eq!(scores.len(), edges_per_request);
                completed_latencies.push(sent_at.elapsed().as_secs_f64());
            }
            Err(PredictError::DeadlineExceeded) => expired += 1,
            Err(err) => panic!("unexpected serving error under overload: {err}"),
        }
    }
    completed_latencies.sort_by(f64::total_cmp);
    // Empty-set percentiles report 0.0: JSON cannot encode NaN, and an
    // all-expired run is a legitimate (if extreme) overload outcome.
    let pct = |p: f64| -> f64 {
        let idx = ((completed_latencies.len() as f64 - 1.0) * p).round() as usize;
        completed_latencies.get(idx).copied().unwrap_or(0.0)
    };
    let (p50, p99) = (pct(0.50), pct(0.99));
    let st = server.stats();
    let shed = st.shed.load(std::sync::atomic::Ordering::Relaxed);
    println!(
        "overload (1 worker, queue 8, {timeout_ms}ms deadline): offered {offered}, \
         accepted {}, rejected {rejected}, expired {expired} ({shed} shed unscored), \
         p50 {} p99 {}",
        accepted.len(),
        fmt_secs(p50),
        fmt_secs(p99)
    );
    let overload = Json::obj(vec![
        ("bench", Json::from("bench_serving")),
        ("full", Json::from(full)),
        ("offered", Json::from(offered)),
        ("accepted", Json::from(accepted.len())),
        ("rejected_overload", Json::from(rejected)),
        ("deadline_expired", Json::from(expired)),
        ("shed", Json::from(shed)),
        ("request_timeout_ms", Json::from(timeout_ms as usize)),
        ("p50_secs", Json::from(p50)),
        ("p99_secs", Json::from(p99)),
    ]);
    server.shutdown();
    match update_json_file(&out, "overload", overload) {
        Ok(()) => println!("wrote overload results to {}", out.display()),
        Err(err) => eprintln!("failed to write {}: {err}", out.display()),
    }
    println!("bench_serving done");
}
