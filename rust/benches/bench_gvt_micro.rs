//! §Perf micro-benchmarks of the generalized-vec-trick hot path.
//!
//! Measures, per (m, q, n) shape: both branches of Algorithm 1, the
//! auto-selected branch, the literal pseudocode transcription (strided
//! loops), the native dense scatter→GEMM→gather path, the explicit baseline,
//! and — when artifacts are built — the PJRT dense path. Reports effective
//! GFLOP/s against the Theorem-1 flop model.
//!
//! A second table measures the [`GvtEngine`] parallel path (serial vs 2/4/8
//! worker threads, precomputed [`EdgePlan`]) and records the serial-vs-
//! parallel speedups into `BENCH_gvt_parallel.json` at the repo root under
//! the `"micro"` key — the perf-trajectory convention described in
//! `docs/BENCHMARKS.md`.
//!
//! A third table measures the **multi-RHS batched apply**
//! (`apply_planned_multi`, k = 8 right-hand sides in one sweep) against k
//! repeated single applies, serially and at 4 threads, asserting bitwise
//! per-column equality first, and records the batched speedups into
//! `BENCH_batched_gvt.json` (section `"multi_rhs"`).
//!
//! A fourth table measures the **pairwise kernel family**
//! ([`PairwiseOp`]: Kronecker / symmetric / anti-symmetric / Cartesian
//! training applies composed from planned GVT applies) against the
//! materialized dense baseline at small sizes — asserting agreement first —
//! and records per-variant apply times into `BENCH_pairwise.json`
//! (section `"pairwise"`).
//!
//! A fifth table measures the **D-way tensor-chain apply**
//! ([`TensorKernelOp`] at D = 2 / 3 / 4, matched vertex budgets) serially
//! and at 4 threads, asserting the D = 2 chain bitwise against the
//! two-factor operator first, and records per-order apply times into
//! `BENCH_tensor.json` (section `"tensor_chain"`).
//!
//! Run: `cargo bench --bench bench_gvt_micro [-- --quick|--full]`

use std::sync::Arc;

use kronvt::gvt::algorithm::gvt_reference;
use kronvt::gvt::complexity;
use kronvt::gvt::dense::dense_apply;
use kronvt::gvt::explicit::explicit_apply_streaming;
use kronvt::gvt::{
    gvt_apply_into, Branch, EdgePlan, GvtEngine, GvtWorkspace, KronIndex, PairwiseKernelKind,
    PairwiseOp, TensorIndex, TensorKernelOp,
};
use kronvt::linalg::vecops::assert_allclose;
use kronvt::linalg::Matrix;
use kronvt::runtime::ArtifactRegistry;
use kronvt::util::args::Args;
use kronvt::util::json::{update_json_file, Json};
use kronvt::util::rng::Pcg32;
use kronvt::util::timer::{fmt_secs, BenchRunner};

const PAR_THREADS: [usize; 3] = [2, 4, 8];

fn random_kernel(rng: &mut Pcg32, n: usize) -> Matrix {
    let x = Matrix::from_fn(n, 4, |_, _| rng.normal());
    kronvt::kernels::KernelKind::Gaussian { gamma: 0.3 }.square_matrix(&x)
}

fn main() {
    let args = Args::parse();
    args.expect_known("bench_gvt_micro", &["bench", "full", "quick"]).expect("flags");
    let full = args.has("full");
    let quick = args.has("quick");
    let mut rng = Pcg32::seeded(777);

    let registry = {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if ArtifactRegistry::available(&dir) {
            ArtifactRegistry::open(&dir).ok()
        } else {
            None
        }
    };

    let shapes: &[(usize, usize, usize)] = if full {
        &[(100, 100, 2_500), (200, 200, 10_000), (400, 400, 40_000), (800, 800, 160_000), (1000, 1000, 250_000)]
    } else if quick {
        &[(100, 100, 2_500), (200, 200, 10_000)]
    } else {
        &[(100, 100, 2_500), (200, 200, 10_000), (400, 400, 40_000)]
    };

    println!(
        "{:>5} {:>5} {:>8} | {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} | {:>8}",
        "m", "q", "n", "branch-T", "branch-S", "auto", "pseudo", "dense", "explicit", "pjrt", "GFLOP/s"
    );

    // kept alive across the serial table for reuse in the parallel table
    let mut problems: Vec<(usize, usize, usize, Matrix, Matrix, KronIndex, Vec<f64>, f64)> =
        Vec::new();

    for &(m, q, n) in shapes {
        let k = random_kernel(&mut rng, m);
        let g = random_kernel(&mut rng, q);
        let idx = KronIndex::new(
            (0..n).map(|_| rng.below(q) as u32).collect(),
            (0..n).map(|_| rng.below(m) as u32).collect(),
        );
        let v = rng.normal_vec(n);
        let mut u = vec![0.0; n];
        let mut ws = GvtWorkspace::new();
        let runner = BenchRunner::quick();

        let t_branch_t = runner
            .run(|| gvt_apply_into(&g, &k, &g, &k, &idx, &idx, &v, &mut u, &mut ws, Some(Branch::T)))
            .min_secs;
        let t_branch_s = runner
            .run(|| gvt_apply_into(&g, &k, &g, &k, &idx, &idx, &v, &mut u, &mut ws, Some(Branch::S)))
            .min_secs;
        let t_auto = runner
            .run(|| gvt_apply_into(&g, &k, &g, &k, &idx, &idx, &v, &mut u, &mut ws, None))
            .min_secs;
        let t_pseudo = if n <= 40_000 {
            fmt_secs(runner.run(|| gvt_reference(&g, &k, &idx, &idx, &v)).min_secs)
        } else {
            "-".into()
        };
        let t_dense = if m * q <= 1_000_000 {
            fmt_secs(runner.run(|| dense_apply(&g, &k, &idx, &idx, &v)).min_secs)
        } else {
            "-".into()
        };
        let t_explicit = if n <= 40_000 {
            fmt_secs(runner.run(|| explicit_apply_streaming(&g, &k, &idx, &idx, &v)).min_secs)
        } else {
            "-".into()
        };
        let t_pjrt = registry
            .as_ref()
            .and_then(|reg| {
                reg.find_bucket("kron_mv", &[("m", m), ("q", q), ("n", n)])?;
                Some(fmt_secs(runner.run(|| reg.kron_mv(&k, &g, &idx, &v).unwrap()).min_secs))
            })
            .unwrap_or_else(|| "-".into());

        // Theorem-1 flop model: 2 flops per multiply-add in both stages.
        let flops = 2.0 * complexity::gvt_cost(q, q, m, m, n, n) as f64;
        let gflops = flops / t_auto / 1e9;

        println!(
            "{:>5} {:>5} {:>8} | {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} | {:>8.2}",
            m,
            q,
            n,
            fmt_secs(t_branch_t),
            fmt_secs(t_branch_s),
            fmt_secs(t_auto),
            t_pseudo,
            t_dense,
            t_explicit,
            t_pjrt,
            gflops
        );
        problems.push((m, q, n, k, g, idx, v, t_auto));
    }

    // ---- Parallel engine scaling (serial vs GvtEngine at 2/4/8 threads) ----
    println!();
    println!(
        "{:>5} {:>5} {:>8} | {:>10} {:>10} {:>10} {:>10} | {:>7} {:>7} {:>7}",
        "m", "q", "n", "serial", "2t", "4t", "8t", "spd-2t", "spd-4t", "spd-8t"
    );
    let mut json_rows = Vec::new();
    let mut largest: Option<Json> = None;
    for (m, q, n, k, g, idx, v, t_serial) in &problems {
        let plan = EdgePlan::build(idx, g.cols(), k.cols());
        let mut u = vec![0.0; *n];
        let mut ws = GvtWorkspace::new();
        let runner = BenchRunner::quick();
        let mut par_secs = Vec::new();
        for &threads in &PAR_THREADS {
            let engine = GvtEngine::new(threads);
            let secs = runner
                .run(|| {
                    engine.apply_planned(g, k, g, k, idx, idx, &plan, v, &mut u, &mut ws, None)
                })
                .min_secs;
            par_secs.push(secs);
        }
        println!(
            "{:>5} {:>5} {:>8} | {:>10} {:>10} {:>10} {:>10} | {:>6.2}x {:>6.2}x {:>6.2}x",
            m,
            q,
            n,
            fmt_secs(*t_serial),
            fmt_secs(par_secs[0]),
            fmt_secs(par_secs[1]),
            fmt_secs(par_secs[2]),
            t_serial / par_secs[0],
            t_serial / par_secs[1],
            t_serial / par_secs[2],
        );
        let row = Json::obj(vec![
            ("m", Json::from(*m)),
            ("q", Json::from(*q)),
            ("n", Json::from(*n)),
            ("serial_secs", Json::from(*t_serial)),
            ("threads_2_secs", Json::from(par_secs[0])),
            ("threads_4_secs", Json::from(par_secs[1])),
            ("threads_8_secs", Json::from(par_secs[2])),
            ("speedup_2t", Json::from(t_serial / par_secs[0])),
            ("speedup_4t", Json::from(t_serial / par_secs[1])),
            ("speedup_8t", Json::from(t_serial / par_secs[2])),
        ]);
        largest = Some(row.clone());
        json_rows.push(row);
    }

    let host_threads =
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let section = Json::obj(vec![
        ("bench", Json::from("bench_gvt_micro")),
        ("host_threads", Json::from(host_threads)),
        ("full", Json::from(full)),
        ("rows", Json::Arr(json_rows)),
        ("largest", largest.unwrap_or(Json::Null)),
    ]);
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_gvt_parallel.json");
    match update_json_file(&out, "micro", section) {
        Ok(()) => println!("\nwrote serial-vs-parallel results to {}", out.display()),
        Err(err) => eprintln!("\nfailed to write {}: {err}", out.display()),
    }

    // ---- Multi-RHS: k=8 batched apply vs 8 repeated single applies ----
    const K_RHS: usize = 8;
    println!();
    println!(
        "{:>5} {:>5} {:>8} | {:>10} {:>10} {:>7} | {:>10} {:>10} {:>7}",
        "m", "q", "n", "8xsingle", "multi-1t", "spd", "8xsing-4t", "multi-4t", "spd"
    );
    let mut multi_rows = Vec::new();
    let mut multi_largest: Option<Json> = None;
    for (m, q, n, k, g, idx, _, _) in &problems {
        let plan = EdgePlan::build_full(idx, idx, g.rows(), g.cols(), k.rows(), k.cols());
        let mut vrng = Pcg32::seeded(0xBA7C + *n as u64);
        let v = vrng.normal_vec(n * K_RHS);
        let mut u_single = vec![0.0; n * K_RHS];
        let mut u_multi = vec![0.0; n * K_RHS];
        let mut ws = GvtWorkspace::new();
        let runner = BenchRunner::quick();

        // correctness gate: every column bitwise equal to its single apply
        for threads in [1usize, 4] {
            let engine = GvtEngine::new(threads);
            for j in 0..K_RHS {
                let (vj, uj) =
                    (&v[j * n..(j + 1) * n], &mut u_single[j * n..(j + 1) * n]);
                engine.apply_planned(g, k, g, k, idx, idx, &plan, vj, uj, &mut ws, None);
            }
            engine.apply_planned_multi(
                g, k, g, k, idx, idx, &plan, &v, &mut u_multi, K_RHS, &mut ws, None,
            );
            assert_eq!(u_single, u_multi, "multi-RHS diverged at {threads} threads");
        }

        let mut secs = [[0.0f64; 2]; 2]; // [threads 1|4][single|multi]
        for (ti, &threads) in [1usize, 4].iter().enumerate() {
            let engine = GvtEngine::new(threads);
            secs[ti][0] = runner
                .run(|| {
                    for j in 0..K_RHS {
                        let (vj, uj) =
                            (&v[j * n..(j + 1) * n], &mut u_single[j * n..(j + 1) * n]);
                        engine.apply_planned(g, k, g, k, idx, idx, &plan, vj, uj, &mut ws, None);
                    }
                })
                .min_secs;
            secs[ti][1] = runner
                .run(|| {
                    engine.apply_planned_multi(
                        g, k, g, k, idx, idx, &plan, &v, &mut u_multi, K_RHS, &mut ws, None,
                    )
                })
                .min_secs;
        }
        println!(
            "{:>5} {:>5} {:>8} | {:>10} {:>10} {:>6.2}x | {:>10} {:>10} {:>6.2}x",
            m,
            q,
            n,
            fmt_secs(secs[0][0]),
            fmt_secs(secs[0][1]),
            secs[0][0] / secs[0][1],
            fmt_secs(secs[1][0]),
            fmt_secs(secs[1][1]),
            secs[1][0] / secs[1][1],
        );
        let row = Json::obj(vec![
            ("m", Json::from(*m)),
            ("q", Json::from(*q)),
            ("n", Json::from(*n)),
            ("k_rhs", Json::from(K_RHS)),
            ("single_1t_secs", Json::from(secs[0][0])),
            ("multi_1t_secs", Json::from(secs[0][1])),
            ("speedup_1t", Json::from(secs[0][0] / secs[0][1])),
            ("single_4t_secs", Json::from(secs[1][0])),
            ("multi_4t_secs", Json::from(secs[1][1])),
            ("speedup_4t", Json::from(secs[1][0] / secs[1][1])),
        ]);
        multi_largest = Some(row.clone());
        multi_rows.push(row);
    }
    let multi_section = Json::obj(vec![
        ("bench", Json::from("bench_gvt_micro")),
        ("host_threads", Json::from(host_threads)),
        ("full", Json::from(full)),
        ("k_rhs", Json::from(K_RHS)),
        ("rows", Json::Arr(multi_rows)),
        ("largest", multi_largest.unwrap_or(Json::Null)),
    ]);
    let out_multi = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_batched_gvt.json");
    match update_json_file(&out_multi, "multi_rhs", multi_section) {
        Ok(()) => println!("\nwrote multi-RHS results to {}", out_multi.display()),
        Err(err) => eprintln!("\nfailed to write {}: {err}", out_multi.display()),
    }

    // ---- Pairwise kernel family: composed GVT applies vs dense baseline ----
    // Square homogeneous problems (one vertex set, one kernel matrix); the
    // dense baseline materializes the pairwise kernel matrix (n×n) and is
    // only built at small n.
    const DENSE_CAP: usize = 3_000;
    let pair_shapes: &[(usize, usize)] = if full {
        &[(100, 2_500), (200, 10_000), (400, 40_000)]
    } else if quick {
        &[(60, 900), (100, 2_500)]
    } else {
        &[(100, 2_500), (200, 10_000)]
    };
    println!();
    println!(
        "{:>5} {:>8} {:>14} | {:>10} {:>10} {:>10} | {:>8}",
        "verts", "n", "variant", "gvt-1t", "gvt-4t", "dense-mv", "vs-dense"
    );
    let variants = [
        PairwiseKernelKind::Kronecker,
        PairwiseKernelKind::SymmetricKron,
        PairwiseKernelKind::AntiSymmetricKron,
        PairwiseKernelKind::Cartesian,
    ];
    let mut pair_rows = Vec::new();
    for &(nv, n) in pair_shapes {
        let kmat = Arc::new(random_kernel(&mut rng, nv));
        let idx = KronIndex::new(
            (0..n).map(|_| rng.below(nv) as u32).collect(),
            (0..n).map(|_| rng.below(nv) as u32).collect(),
        );
        let v = rng.normal_vec(n);
        for kind in variants {
            let cross = kind.needs_cross().then(|| kmat.clone());
            let op =
                PairwiseOp::training(kind, kmat.clone(), kmat.clone(), cross.clone(), None, idx.clone())
                    .expect("valid pairwise training op");
            let op_4t = PairwiseOp::training(kind, kmat.clone(), kmat.clone(), cross, None, idx.clone())
                .expect("valid pairwise training op")
                .with_threads(4);
            let mut u = vec![0.0; n];
            let runner = BenchRunner::quick();

            // dense oracle: materialize once, gate correctness, time its matvec
            let dense_mv_secs = if n <= DENSE_CAP {
                let dense = op.explicit_dense();
                op.apply_into(&v, &mut u);
                assert_allclose(&u, &dense.matvec(&v), 1e-9, 1e-9);
                Some(runner.run(|| dense.matvec(&v)).min_secs)
            } else {
                None
            };

            let t_1t = runner.run(|| op.apply_into(&v, &mut u)).min_secs;
            let t_4t = runner.run(|| op_4t.apply_into(&v, &mut u)).min_secs;
            println!(
                "{:>5} {:>8} {:>14} | {:>10} {:>10} {:>10} | {:>8}",
                nv,
                n,
                kind.name(),
                fmt_secs(t_1t),
                fmt_secs(t_4t),
                dense_mv_secs.map(fmt_secs).unwrap_or_else(|| "-".into()),
                dense_mv_secs
                    .map(|d| format!("{:.2}x", d / t_1t))
                    .unwrap_or_else(|| "-".into()),
            );
            pair_rows.push(Json::obj(vec![
                ("vertices", Json::from(nv)),
                ("n", Json::from(n)),
                ("variant", Json::from(kind.name())),
                ("terms", Json::from(op.n_terms())),
                ("gvt_1t_secs", Json::from(t_1t)),
                ("gvt_4t_secs", Json::from(t_4t)),
                (
                    "dense_matvec_secs",
                    dense_mv_secs.map(Json::from).unwrap_or(Json::Null),
                ),
                (
                    "speedup_vs_dense_1t",
                    dense_mv_secs.map(|d| Json::from(d / t_1t)).unwrap_or(Json::Null),
                ),
            ]));
        }
    }
    let pair_section = Json::obj(vec![
        ("bench", Json::from("bench_gvt_micro")),
        ("host_threads", Json::from(host_threads)),
        ("full", Json::from(full)),
        ("rows", Json::Arr(pair_rows)),
    ]);
    let out_pair = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_pairwise.json");
    match update_json_file(&out_pair, "pairwise", pair_section) {
        Ok(()) => println!("\nwrote pairwise-family results to {}", out_pair.display()),
        Err(err) => eprintln!("\nfailed to write {}: {err}", out_pair.display()),
    }

    // ---- D-way tensor chains: TensorKernelOp applies at D = 2 / 3 / 4 ----
    // Vertex budgets are matched across orders (Π_d m_d ≈ constant) so the
    // rows compare chain-pipeline overhead, not problem size. The D = 2 row
    // is gated bitwise against the two-factor KronKernelOp (it must be the
    // same pipeline), and every row gates 4-thread against serial bitwise.
    let chain_n: usize = if full {
        80_000
    } else if quick {
        5_000
    } else {
        20_000
    };
    let chain_shapes: &[&[usize]] = &[&[200, 200], &[60, 60, 60][..], &[25, 25, 25, 25][..]];
    println!();
    println!(
        "{:>14} {:>8} | {:>10} {:>10} | {:>7}",
        "dims", "n", "chain-1t", "chain-4t", "spd-4t"
    );
    let mut chain_rows = Vec::new();
    for &dims in chain_shapes {
        let factors: Vec<Arc<Matrix>> =
            dims.iter().map(|&d| Arc::new(random_kernel(&mut rng, d))).collect();
        let idx = TensorIndex::new(
            dims.iter().map(|&d| (0..chain_n).map(|_| rng.below(d) as u32).collect()).collect(),
        );
        let v = rng.normal_vec(chain_n);
        let op = TensorKernelOp::new(factors.clone(), idx.clone());
        let op_4t = TensorKernelOp::new(factors.clone(), idx.clone()).with_threads(4);
        let mut u = vec![0.0; chain_n];
        let mut u_4t = vec![0.0; chain_n];
        op.apply_into(&v, &mut u);
        op_4t.apply_into(&v, &mut u_4t);
        assert_eq!(u, u_4t, "chain apply diverged across thread counts at D={}", dims.len());
        if dims.len() == 2 {
            let kron = kronvt::gvt::KronKernelOp::new(
                factors[0].clone(),
                factors[1].clone(),
                idx.to_kron().expect("two-mode index"),
            );
            let mut u_kron = vec![0.0; chain_n];
            kron.apply_into(&v, &mut u_kron);
            assert_eq!(u, u_kron, "D=2 chain diverged from the two-factor operator");
        }
        let runner = BenchRunner::quick();
        let t_1t = runner.run(|| op.apply_into(&v, &mut u)).min_secs;
        let t_4t = runner.run(|| op_4t.apply_into(&v, &mut u_4t)).min_secs;
        let dims_str =
            dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x");
        println!(
            "{:>14} {:>8} | {:>10} {:>10} | {:>6.2}x",
            dims_str,
            chain_n,
            fmt_secs(t_1t),
            fmt_secs(t_4t),
            t_1t / t_4t,
        );
        chain_rows.push(Json::obj(vec![
            ("order", Json::from(dims.len())),
            ("dims", Json::Arr(dims.iter().map(|&d| Json::from(d)).collect())),
            ("n", Json::from(chain_n)),
            ("chain_1t_secs", Json::from(t_1t)),
            ("chain_4t_secs", Json::from(t_4t)),
            ("speedup_4t", Json::from(t_1t / t_4t)),
        ]));
    }
    let chain_section = Json::obj(vec![
        ("bench", Json::from("bench_gvt_micro")),
        ("host_threads", Json::from(host_threads)),
        ("full", Json::from(full)),
        ("rows", Json::Arr(chain_rows)),
    ]);
    let out_chain = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_tensor.json");
    match update_json_file(&out_chain, "tensor_chain", chain_section) {
        Ok(()) => println!("\nwrote tensor-chain results to {}", out_chain.display()),
        Err(err) => eprintln!("\nfailed to write {}: {err}", out_chain.display()),
    }
    println!("bench_gvt_micro done");
}
