//! §Perf micro-benchmarks of the generalized-vec-trick hot path.
//!
//! Measures, per (m, q, n) shape: both branches of Algorithm 1, the
//! auto-selected branch, the literal pseudocode transcription (strided
//! loops), the native dense scatter→GEMM→gather path, the explicit baseline,
//! and — when artifacts are built — the PJRT dense path. Reports effective
//! GFLOP/s against the Theorem-1 flop model. This is the harness used for
//! the EXPERIMENTS.md §Perf before/after numbers.
//!
//! Run: `cargo bench --bench bench_gvt_micro [-- --full]`

use kronvt::gvt::algorithm::gvt_reference;
use kronvt::gvt::complexity;
use kronvt::gvt::dense::dense_apply;
use kronvt::gvt::explicit::explicit_apply_streaming;
use kronvt::gvt::{gvt_apply_into, Branch, GvtWorkspace, KronIndex};
use kronvt::linalg::Matrix;
use kronvt::runtime::ArtifactRegistry;
use kronvt::util::args::Args;
use kronvt::util::rng::Pcg32;
use kronvt::util::timer::{fmt_secs, BenchRunner};

fn random_kernel(rng: &mut Pcg32, n: usize) -> Matrix {
    let x = Matrix::from_fn(n, 4, |_, _| rng.normal());
    kronvt::kernels::KernelKind::Gaussian { gamma: 0.3 }.square_matrix(&x)
}

fn main() {
    let args = Args::parse();
    let full = args.has("full");
    let mut rng = Pcg32::seeded(777);

    let registry = {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if ArtifactRegistry::available(&dir) {
            ArtifactRegistry::open(&dir).ok()
        } else {
            None
        }
    };

    let shapes: &[(usize, usize, usize)] = if full {
        &[(100, 100, 2_500), (200, 200, 10_000), (400, 400, 40_000), (800, 800, 160_000), (1000, 1000, 250_000)]
    } else {
        &[(100, 100, 2_500), (200, 200, 10_000), (400, 400, 40_000)]
    };

    println!(
        "{:>5} {:>5} {:>8} | {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} | {:>8}",
        "m", "q", "n", "branch-T", "branch-S", "auto", "pseudo", "dense", "explicit", "pjrt", "GFLOP/s"
    );

    for &(m, q, n) in shapes {
        let k = random_kernel(&mut rng, m);
        let g = random_kernel(&mut rng, q);
        let idx = KronIndex::new(
            (0..n).map(|_| rng.below(q) as u32).collect(),
            (0..n).map(|_| rng.below(m) as u32).collect(),
        );
        let v = rng.normal_vec(n);
        let mut u = vec![0.0; n];
        let mut ws = GvtWorkspace::new();
        let runner = BenchRunner::quick();

        let t_branch_t = runner
            .run(|| gvt_apply_into(&g, &k, &g, &k, &idx, &idx, &v, &mut u, &mut ws, Some(Branch::T)))
            .min_secs;
        let t_branch_s = runner
            .run(|| gvt_apply_into(&g, &k, &g, &k, &idx, &idx, &v, &mut u, &mut ws, Some(Branch::S)))
            .min_secs;
        let t_auto = runner
            .run(|| gvt_apply_into(&g, &k, &g, &k, &idx, &idx, &v, &mut u, &mut ws, None))
            .min_secs;
        let t_pseudo = if n <= 40_000 {
            fmt_secs(runner.run(|| gvt_reference(&g, &k, &idx, &idx, &v)).min_secs)
        } else {
            "-".into()
        };
        let t_dense = if m * q <= 1_000_000 {
            fmt_secs(runner.run(|| dense_apply(&g, &k, &idx, &idx, &v)).min_secs)
        } else {
            "-".into()
        };
        let t_explicit = if n <= 40_000 {
            fmt_secs(runner.run(|| explicit_apply_streaming(&g, &k, &idx, &idx, &v)).min_secs)
        } else {
            "-".into()
        };
        let t_pjrt = registry
            .as_ref()
            .and_then(|reg| {
                reg.find_bucket("kron_mv", &[("m", m), ("q", q), ("n", n)])?;
                Some(fmt_secs(runner.run(|| reg.kron_mv(&k, &g, &idx, &v).unwrap()).min_secs))
            })
            .unwrap_or_else(|| "-".into());

        // Theorem-1 flop model: 2 flops per multiply-add in both stages.
        let flops = 2.0 * complexity::gvt_cost(q, q, m, m, n, n) as f64;
        let gflops = flops / t_auto / 1e9;

        println!(
            "{:>5} {:>5} {:>8} | {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} | {:>8.2}",
            m,
            q,
            n,
            fmt_secs(t_branch_t),
            fmt_secs(t_branch_s),
            fmt_secs(t_auto),
            t_pseudo,
            t_dense,
            t_explicit,
            t_pjrt,
            gflops
        );
    }
    println!("\nbench_gvt_micro done");
}
