//! Figure 7 — large-scale checkerboard simulation: training time, prediction
//! time, and test AUC as the problem grows (m = q, n = 0.25·m²), for KronSVM
//! and the explicit SMO baseline ("LibSVM").
//!
//! Paper settings: Gaussian kernel γ = 1, λ = 2⁻⁷, 10 outer × 10 inner
//! iterations, test set the same size as the training set, AUC ceiling 0.8
//! (20% label noise). Expected shape: KronSVM time grows ~linearly in n and
//! reaches millions of edges; the baseline grows ~quadratically and is
//! dropped early; KronSVM AUC climbs toward ≈0.73–0.80 as m grows.
//!
//! Sizes default to this container's budget; `--full` pushes to the paper's
//! 1000-vertex Checker scale and beyond (`--max-m 6400` for Checker+ if you
//! have hours). `--threads N` shards the GVT matvecs inside KronSVM training
//! across N worker threads (0 = all cores); at the largest size the bench
//! additionally times serial-vs-parallel training and records the speedup
//! into `BENCH_gvt_parallel.json` under the `"checkerboard"` key.
//!
//! Run: `cargo bench --bench bench_checkerboard [-- --full] [--max-m M] [--threads N]`

use kronvt::baselines::{ExplicitSvm, ExplicitSvmConfig};
use kronvt::data::checkerboard::CheckerboardConfig;
use kronvt::data::Dataset;
use kronvt::eval::auc::auc;
use kronvt::kernels::KernelKind;
use kronvt::train::{KronSvm, SvmConfig};
use kronvt::util::args::Args;
use kronvt::util::json::{update_json_file, Json};
use kronvt::util::timer::{fmt_secs, Timer};

/// Train KronSVM with the paper's Fig. 7 settings; returns (model, secs).
fn train_kron(
    train: &Dataset,
    gaussian: KernelKind,
    threads: usize,
) -> (kronvt::model::DualModel, f64) {
    let t = Timer::start();
    let model = KronSvm::new(SvmConfig {
        lambda: 2f64.powi(-7),
        kernel_d: gaussian,
        kernel_t: gaussian,
        outer_iters: 10,
        inner_iters: 10,
        ..Default::default()
    })
    .with_compute(kronvt::api::Compute::threads(threads))
    .fit(train)
    .expect("kron train");
    (model, t.elapsed_secs())
}

fn main() {
    let args = Args::parse();
    args.expect_known(
        "bench_checkerboard",
        &["bench", "full", "quick", "max-m", "baseline-cap", "seed", "threads"],
    )
    .expect("flags");
    let full = args.has("full");
    let max_m = args.get_usize("max-m", if full { 1000 } else { 400 }).expect("--max-m");
    let baseline_cap_edges = args
        .get_usize("baseline-cap", if full { 16_000 } else { 4_000 })
        .expect("--baseline-cap");
    let seed = args.get_u64("seed", 1).expect("--seed");
    let threads = args.get_usize("threads", 4).expect("--threads");
    let gaussian = KernelKind::Gaussian { gamma: 1.0 };

    println!(
        "{:>6} {:>9} | {:>11} {:>11} {:>7} | {:>11} {:>11} {:>7}",
        "m=q", "n", "kron train", "kron pred", "AUC", "smo train", "smo pred", "AUC"
    );

    let mut m = 100;
    while m <= max_m {
        // train and test graphs of the same size, vertex-disjoint (§5.5)
        let train = CheckerboardConfig {
            m,
            q: m,
            density: 0.25,
            noise: 0.2,
            feature_range: 100.0,
            seed,
        }
        .generate();
        let test = CheckerboardConfig {
            m,
            q: m,
            density: 0.25,
            noise: 0.2,
            feature_range: 100.0,
            seed: seed ^ 0xABCD,
        }
        .generate();
        let n = train.n_edges();

        let (kron, kron_train) = train_kron(&train, gaussian, threads);
        let t = Timer::start();
        let scores = kron.predict_threaded(&test, threads);
        let kron_pred = t.elapsed_secs();
        let kron_auc = auc(&test.labels, &scores);

        // At the largest size, also time a fully serial training run and
        // record the serial-vs-parallel speedup (the models are bitwise
        // identical, so this is a pure walltime comparison).
        if m * 2 > max_m && threads != 1 {
            let (serial_model, serial_secs) = train_kron(&train, gaussian, 1);
            assert_eq!(serial_model.dual_coef, kron.dual_coef, "parallel must match serial");
            let speedup = serial_secs / kron_train;
            println!(
                "   parallel check @ m={m}: serial train {} vs {} threads {} — {:.2}x speedup",
                fmt_secs(serial_secs),
                threads,
                fmt_secs(kron_train),
                speedup
            );
            let section = Json::obj(vec![
                ("bench", Json::from("bench_checkerboard")),
                ("m", Json::from(m)),
                ("n", Json::from(n)),
                ("threads", Json::from(threads)),
                ("serial_train_secs", Json::from(serial_secs)),
                ("parallel_train_secs", Json::from(kron_train)),
                ("speedup", Json::from(speedup)),
            ]);
            let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
                .join("..")
                .join("BENCH_gvt_parallel.json");
            if let Err(err) = update_json_file(&out, "checkerboard", section) {
                eprintln!("failed to write {}: {err}", out.display());
            }
        }

        let (smo_train, smo_pred, smo_auc) = if n <= baseline_cap_edges {
            let t = Timer::start();
            let smo = ExplicitSvm::fit(
                &train,
                &ExplicitSvmConfig { c: 2f64.powi(7), kernel: gaussian, ..Default::default() },
            )
            .expect("smo train");
            let t_train = t.elapsed_secs();
            let t = Timer::start();
            let s = smo.predict(&test);
            let t_pred = t.elapsed_secs();
            (fmt_secs(t_train), fmt_secs(t_pred), format!("{:.3}", auc(&test.labels, &s)))
        } else {
            ("(skipped)".into(), "-".into(), "-".into())
        };

        println!(
            "{:>6} {:>9} | {:>11} {:>11} {:>7.3} | {:>11} {:>11} {:>7}",
            m,
            n,
            fmt_secs(kron_train),
            fmt_secs(kron_pred),
            kron_auc,
            smo_train,
            smo_pred,
            smo_auc
        );
        m *= 2;
    }
    println!("\nnote: AUC ceiling is 0.8 (20% label flips); it climbs with m because");
    println!("vertex density per checkerboard cell grows — the paper's Fig. 7 shape.");
    println!("bench_checkerboard done");
}
