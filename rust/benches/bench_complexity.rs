//! Tables 3 & 4 — complexity of the proposed generalized vec trick vs the
//! explicit ("Baseline") approach, in the paper's three regimes:
//!
//! * Independent: n = m = q (no shared vertices)
//! * Dependent:   max(m,q) << n << m·q      (the paper's main setting)
//! * Complete:    n = m·q                   (R = I; plain vec trick)
//!
//! Prints measured matvec times and fitted scaling exponents in n for both
//! the dual (kernel) and primal (feature) operators. Expected shape: equal
//! asymptotics in the Independent regime; the proposed method wins by
//! ~n/(m+q) in the Dependent regime; baseline exponent ≈ 2, proposed ≈ 1.
//!
//! Run: `cargo bench --bench bench_complexity [-- --full]`

use kronvt::gvt::explicit::explicit_apply_streaming;
use kronvt::gvt::{gvt_apply_into, GvtWorkspace, KronIndex};
use kronvt::linalg::Matrix;
use kronvt::model::primal::PrimalKronOp;
use kronvt::util::args::Args;
use kronvt::util::rng::Pcg32;
use kronvt::util::timer::{fmt_secs, BenchRunner};

fn random_kernel(rng: &mut Pcg32, n: usize) -> Matrix {
    let x = Matrix::from_fn(n, 4, |_, _| rng.normal());
    kronvt::kernels::KernelKind::Gaussian { gamma: 0.3 }.square_matrix(&x)
}

fn random_idx(rng: &mut Pcg32, q: usize, m: usize, n: usize) -> KronIndex {
    KronIndex::new(
        (0..n).map(|_| rng.below(q) as u32).collect(),
        (0..n).map(|_| rng.below(m) as u32).collect(),
    )
}

struct Row {
    regime: &'static str,
    m: usize,
    q: usize,
    n: usize,
    proposed: f64,
    baseline: f64,
}

fn bench_dual(regime: &'static str, m: usize, q: usize, n: usize, rng: &mut Pcg32) -> Row {
    let k = random_kernel(rng, m);
    let g = random_kernel(rng, q);
    let idx = random_idx(rng, q, m, n);
    let v = rng.normal_vec(n);
    let mut u = vec![0.0; n];
    let mut ws = GvtWorkspace::new();
    let runner = BenchRunner::quick();

    let proposed = runner
        .run(|| gvt_apply_into(&g, &k, &g, &k, &idx, &idx, &v, &mut u, &mut ws, None))
        .min_secs;
    // Baseline cost is O(n²); cap the actual measurement and extrapolate for
    // very large n so the bench stays tractable.
    let baseline = if n <= 40_000 {
        runner.run(|| explicit_apply_streaming(&g, &k, &idx, &idx, &v)).min_secs
    } else {
        let n_small = 20_000;
        let idx_s = random_idx(rng, q, m, n_small);
        let v_s = rng.normal_vec(n_small);
        let t = runner.run(|| explicit_apply_streaming(&g, &k, &idx_s, &idx_s, &v_s)).min_secs;
        t * (n as f64 / n_small as f64).powi(2)
    };
    Row { regime, m, q, n, proposed, baseline }
}

fn fit_exponent(points: &[(f64, f64)]) -> f64 {
    // least-squares slope of log t vs log n
    let n = points.len() as f64;
    let (mut sx, mut sy, mut sxx, mut sxy) = (0.0, 0.0, 0.0, 0.0);
    for &(x, y) in points {
        let (lx, ly) = (x.ln(), y.ln());
        sx += lx;
        sy += ly;
        sxx += lx * lx;
        sxy += lx * ly;
    }
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

fn main() {
    let args = Args::parse();
    args.expect_known("bench_complexity", &["bench", "full", "quick"]).expect("flags");
    let full = args.has("full");
    let mut rng = Pcg32::seeded(404);

    println!("== Table 3 (dual): R(G⊗K)Rᵀv — proposed (Algorithm 1) vs explicit baseline ==\n");
    println!(
        "{:<12} {:>6} {:>6} {:>9} {:>12} {:>12} {:>9}",
        "regime", "m", "q", "n", "proposed", "baseline", "speedup"
    );

    let mut rows = Vec::new();
    // Independent: n = m = q
    for &n in if full { &[500usize, 1000, 2000, 4000][..] } else { &[500usize, 1000, 2000][..] } {
        rows.push(bench_dual("independent", n, n, n, &mut rng));
    }
    // Dependent: fixed m, q; growing n
    let (m, q) = (300, 200);
    let dep_sizes: &[usize] =
        if full { &[2_000, 8_000, 32_000, 128_000] } else { &[2_000, 8_000, 32_000] };
    let mut dep_points_prop = Vec::new();
    let mut dep_points_base = Vec::new();
    for &n in dep_sizes {
        let row = bench_dual("dependent", m, q, n, &mut rng);
        dep_points_prop.push((n as f64, row.proposed));
        dep_points_base.push((n as f64, row.baseline));
        rows.push(row);
    }
    // Complete: n = m·q
    for &side in if full { &[60usize, 120, 240][..] } else { &[60usize, 120][..] } {
        rows.push(bench_dual("complete", side, side, side * side, &mut rng));
    }

    for r in &rows {
        println!(
            "{:<12} {:>6} {:>6} {:>9} {:>12} {:>12} {:>8.1}×",
            r.regime,
            r.m,
            r.q,
            r.n,
            fmt_secs(r.proposed),
            fmt_secs(r.baseline),
            r.baseline / r.proposed
        );
    }
    println!(
        "\ndependent-regime scaling exponents (t ~ n^e): proposed e={:.2} (expect ≈1), baseline e={:.2} (expect ≈2)",
        fit_exponent(&dep_points_prop),
        fit_exponent(&dep_points_base)
    );

    // ---- Table 4: primal ----
    println!("\n== Table 4 (primal): R(T⊗D)w — matrix-free vs explicit row-by-row design ==\n");
    println!(
        "{:<12} {:>6} {:>6} {:>9} {:>7} {:>12} {:>12} {:>9}",
        "regime", "m", "q", "n", "d·r", "proposed", "baseline", "speedup"
    );
    let (d_feat, r_feat) = (32usize, 16usize);
    let primal_sizes: &[usize] = if full { &[2_000, 8_000, 32_000] } else { &[2_000, 8_000] };
    for &n in primal_sizes {
        let ds = kronvt::data::Dataset {
            start_features: Matrix::from_fn(m, d_feat, |_, _| rng.normal()),
            end_features: Matrix::from_fn(q, r_feat, |_, _| rng.normal()),
            start_idx: (0..n).map(|_| rng.below(m) as u32).collect(),
            end_idx: (0..n).map(|_| rng.below(q) as u32).collect(),
            labels: vec![0.0; n],
            name: "bench".into(),
        };
        let op = PrimalKronOp::new(&ds);
        let w = rng.normal_vec(op.w_dim());
        let runner = BenchRunner::quick();
        let proposed = runner.run(|| op.forward(&w)).min_secs;
        // Baseline: form each row of X = R(T⊗D) on the fly — O(n·d·r) flops
        // per matvec with no vertex sharing exploited.
        let baseline = runner
            .run(|| {
                let mut out = vec![0.0; n];
                for h in 0..n {
                    let drow = ds.start_features.row(ds.start_idx[h] as usize);
                    let trow = ds.end_features.row(ds.end_idx[h] as usize);
                    let mut acc = 0.0;
                    for (jt, tv) in trow.iter().enumerate() {
                        let wrow = &w[jt * d_feat..(jt + 1) * d_feat];
                        acc += tv * kronvt::linalg::vecops::dot(wrow, drow);
                    }
                    out[h] = acc;
                }
                out
            })
            .min_secs;
        println!(
            "{:<12} {:>6} {:>6} {:>9} {:>7} {:>12} {:>12} {:>8.1}×",
            "dependent",
            m,
            q,
            n,
            d_feat * r_feat,
            fmt_secs(proposed),
            fmt_secs(baseline),
            baseline / proposed
        );
    }
    println!("\nbench_complexity done");
}
