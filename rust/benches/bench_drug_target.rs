//! Figure 6 — the drug–target (Ki) experiment:
//!
//! * left:   training time, KronSVM vs the explicit SMO baseline ("LibSVM"),
//!           as a function of the number of training edges
//! * middle: prediction time for 10 000 test pairs — Kronecker shortcut vs
//!           the baseline decision function (same coefficients, eq. 5 vs 6)
//! * right:  the corresponding zero-shot AUCs
//!
//! Gaussian kernel on both vertex kernels (kron ≡ concatenated, §5.1),
//! λ = 2⁻⁵ / C = 2⁵, 10 outer × 10 inner iterations — the paper's settings
//! (γ adapted to the normalized synthetic features, see below). Expected shape: KronSVM scales ~linearly and the baseline
//! ~quadratically in n (orders of magnitude apart well before 10⁵ edges);
//! the Kronecker predictor is 100–1000× faster at equal outputs; AUCs are
//! comparable.
//!
//! Run: `cargo bench --bench bench_drug_target [-- --full]`

use kronvt::baselines::{ExplicitSvm, ExplicitSvmConfig};
use kronvt::data::dti;
use kronvt::eval::auc::auc;
use kronvt::kernels::KernelKind;
use kronvt::train::{KronSvm, SvmConfig};
use kronvt::util::args::Args;
use kronvt::util::timer::{fmt_secs, Timer};

fn main() {
    let args = Args::parse();
    args.expect_known("bench_drug_target", &["bench", "full", "quick", "seed"]).expect("flags");
    let full = args.has("full");
    let seed = args.get_u64("seed", 1).expect("--seed");
    // The paper uses γ = 10⁻⁵ on its raw fingerprint features; our synthetic
    // features are normalized to O(1) scale, so the equivalent "informative
    // kernel" criterion of §5.3 (not ≈identity, not ≈all-ones) gives γ ≈ 1.
    let gamma = 1.0;
    let gaussian = KernelKind::Gaussian { gamma };

    // Ki-shaped synthetic data (full Table-5 size: 1421×156, 93 356 edges).
    let ki = dti::ki(seed).generate();
    let (train_pool, test_pool) = ki.zero_shot_split(1.0 / 3.0, seed);
    let test = test_pool.subsample_edges(10_000, seed ^ 0x7);
    println!(
        "Ki-shaped data: train pool n={} (m={}, q={}), test n={}",
        train_pool.n_edges(),
        train_pool.m(),
        train_pool.q(),
        test.n_edges()
    );

    let train_sizes: &[usize] = if full {
        &[1_000, 2_000, 4_000, 8_000, 16_000, 32_000, 60_000]
    } else {
        &[1_000, 2_000, 4_000, 8_000]
    };
    let baseline_cap = if full { 16_000 } else { 4_000 };

    println!(
        "\n{:>8} | {:>11} {:>11} | {:>11} {:>11} | {:>7} {:>7}",
        "edges", "kron train", "smo train", "kron pred", "base pred", "kronAUC", "smoAUC"
    );

    for &n in train_sizes {
        let train = train_pool.subsample_edges(n, seed ^ (n as u64));

        // --- KronSVM ---
        let t = Timer::start();
        let kron = KronSvm::new(SvmConfig {
            lambda: 2f64.powi(-5),
            kernel_d: gaussian,
            kernel_t: gaussian,
            outer_iters: 10,
            inner_iters: 10,
            ..Default::default()
        })
        .fit(&train)
        .expect("kron train");
        let kron_train = t.elapsed_secs();
        let t = Timer::start();
        let kron_scores = kron.predict(&test);
        let kron_pred = t.elapsed_secs();
        let kron_auc = auc(&test.labels, &kron_scores);

        // --- explicit SMO baseline + both prediction paths ---
        let (smo_train_s, base_pred_s, smo_auc_s) = if n <= baseline_cap {
            let t = Timer::start();
            let smo = ExplicitSvm::fit(
                &train,
                &ExplicitSvmConfig { c: 2f64.powi(5), kernel: gaussian, ..Default::default() },
            )
            .expect("smo train");
            let smo_train = t.elapsed_secs();

            // Fig. 6 middle: SAME coefficients, two decision functions.
            let t = Timer::start();
            let base_scores = smo.predict(&test);
            let base_pred = t.elapsed_secs();
            let kron_model = smo.to_dual_model(&train).expect("gaussian factorizes");
            let t = Timer::start();
            let kron_scores2: Vec<f64> =
                kron_model.pruned().predict(&test).iter().map(|p| p + smo.bias).collect();
            let shortcut_pred = t.elapsed_secs();
            let max_diff = base_scores
                .iter()
                .zip(&kron_scores2)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            let smo_auc = auc(&test.labels, &base_scores);
            println!(
                "        (same-coefficients check: shortcut {} vs explicit {} — {:.0}× faster, max|Δ|={max_diff:.1e})",
                fmt_secs(shortcut_pred),
                fmt_secs(base_pred),
                base_pred / shortcut_pred.max(1e-12),
            );
            (fmt_secs(smo_train), fmt_secs(base_pred), format!("{smo_auc:.3}"))
        } else {
            ("(skipped)".into(), "-".into(), "-".into())
        };

        println!(
            "{:>8} | {:>11} {:>11} | {:>11} {:>11} | {:>7.3} {:>7}",
            n,
            fmt_secs(kron_train),
            smo_train_s,
            fmt_secs(kron_pred),
            base_pred_s,
            kron_auc,
            smo_auc_s
        );
    }
    println!("\nbench_drug_target done");
}
