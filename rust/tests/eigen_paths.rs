//! Oracle suite for the eigendecomposition fast paths: the closed-form
//! complete-graph ridge solve, the Kronecker spectral preconditioner, and
//! the leave-one-out shortcut, each pinned against an independent
//! ground-truth computation:
//!
//! 1. closed form vs. the dense Cholesky oracle (`ridge_exact_dual`) and
//!    vs. iterative CG, bitwise identical across thread counts;
//! 2. preconditioned CG vs. the oracle, and **strictly fewer iterations**
//!    than plain CG on an ill-conditioned near-complete checkerboard;
//! 3. the LOO shortcut vs. `n` literal leave-one-out refits;
//! 4. a whole λ grid (the `cv --lambdas` workload) costing exactly one
//!    eigendecomposition pair, asserted via the `eigh` call counter.

use std::sync::Arc;

use kronvt::api::{Compute, Learner};
use kronvt::data::checkerboard::CheckerboardConfig;
use kronvt::data::Dataset;
use kronvt::gvt::operator::RidgeSystemOp;
use kronvt::gvt::{KronKernelOp, KronSpectralPrecond, PairwiseKernelKind};
use kronvt::kernels::KernelKind;
use kronvt::linalg::solvers::{cg, pcg, SolverConfig};
use kronvt::linalg::vecops::assert_allclose;
use kronvt::linalg::{eigh, eigh_count, Matrix};
use kronvt::train::ridge::ridge_exact_dual;
use kronvt::train::{KronRidge, RidgeConfig, RidgeSolver};
use kronvt::util::proptest::{complete_dataset, incomplete_dataset};
use kronvt::util::rng::Pcg32;

const GAUSS: KernelKind = KernelKind::Gaussian { gamma: 0.3 };

fn gauss_cfg(lambda: f64) -> RidgeConfig {
    RidgeConfig { lambda, kernel_d: GAUSS, kernel_t: GAUSS, ..Default::default() }
}

/// Materialize the Kronecker training kernel `Q[h][h'] = G[e_h,e_h'] ·
/// K[s_h,s_h']` — an independent dense reference, no GVT code involved.
fn dense_q(train: &Dataset) -> Matrix {
    let g = GAUSS.square_matrix(&train.end_features);
    let k = GAUSS.square_matrix(&train.start_features);
    let n = train.n_edges();
    Matrix::from_fn(n, n, |h1, h2| {
        g.get(train.end_idx[h1] as usize, train.end_idx[h2] as usize)
            * k.get(train.start_idx[h1] as usize, train.start_idx[h2] as usize)
    })
}

#[test]
fn closed_form_matches_dense_cholesky_oracle_across_threads() {
    let mut rng = Pcg32::seeded(0xE161);
    let train = complete_dataset(&mut rng, 7, 5);
    let cfg = gauss_cfg(0.5);
    let oracle = ridge_exact_dual(&train, &cfg, PairwiseKernelKind::Kronecker);
    let serial = KronRidge::new(cfg).fit(&train).unwrap();
    assert_allclose(&serial.dual_coef, &oracle, 1e-8, 1e-8);
    // Bitwise deterministic across thread counts.
    for threads in [2, 4] {
        let par = KronRidge::new(cfg)
            .with_compute(Compute::threads(threads))
            .fit(&train)
            .unwrap();
        assert_eq!(serial.dual_coef, par.dual_coef, "threads={threads}");
    }
    // The explicit 'exact' solver takes the identical path.
    let exact = KronRidge::new(cfg).with_solver(RidgeSolver::Exact).fit(&train).unwrap();
    assert_eq!(serial.dual_coef, exact.dual_coef);
}

#[test]
fn closed_form_agrees_with_iterative_cg() {
    let mut rng = Pcg32::seeded(0xE162);
    let train = complete_dataset(&mut rng, 6, 6);
    let cfg = RidgeConfig { iterations: 800, tol: 1e-13, ..gauss_cfg(0.5) };
    let closed = KronRidge::new(cfg).with_solver(RidgeSolver::Exact).fit(&train).unwrap();
    let iterative = KronRidge::new(cfg).with_solver(RidgeSolver::Cg).fit(&train).unwrap();
    assert_allclose(&closed.dual_coef, &iterative.dual_coef, 1e-8, 1e-8);
}

#[test]
fn precond_cg_matches_dense_cholesky_oracle_on_incomplete_graph() {
    let mut rng = Pcg32::seeded(0xE163);
    let train = incomplete_dataset(&mut rng, 8, 7, 40);
    let cfg = RidgeConfig { iterations: 800, tol: 1e-13, ..gauss_cfg(0.5) };
    let oracle = ridge_exact_dual(&train, &cfg, PairwiseKernelKind::Kronecker);
    let model = KronRidge::new(cfg).with_solver(RidgeSolver::PrecondCg).fit(&train).unwrap();
    assert_allclose(&model.dual_coef, &oracle, 1e-8, 1e-8);
}

#[test]
fn precond_cg_strictly_beats_plain_cg_when_ill_conditioned() {
    // Near-complete checkerboard with a wide-spectrum kernel and tiny λ:
    // plain CG grinds; the complete-graph surrogate inverse clusters the
    // spectrum near 1.
    let train = CheckerboardConfig {
        m: 16,
        q: 16,
        density: 0.85,
        noise: 0.1,
        feature_range: 8.0,
        seed: 11,
    }
    .generate();
    let kernel = KernelKind::Gaussian { gamma: 0.02 };
    let lambda = 1e-4;
    let g = kernel.square_matrix(&train.end_features);
    let k = kernel.square_matrix(&train.start_features);
    let idx = train.kron_index();
    let n = idx.len();
    let op = KronKernelOp::new(Arc::new(g.clone()), Arc::new(k.clone()), idx.clone());
    let sys = RidgeSystemOp { op: &op, lambda };
    let precond = KronSpectralPrecond::new(&eigh(&g), &eigh(&k), idx, lambda);
    let cfg = SolverConfig { max_iters: 1000, tol: 1e-9 };

    let mut x_cg = vec![0.0; n];
    let cg_stats = cg(&sys, &train.labels, &mut x_cg, &cfg);
    let mut x_pcg = vec![0.0; n];
    let pcg_stats = pcg(&sys, &train.labels, &mut x_pcg, &precond, &cfg);

    assert!(pcg_stats.converged, "residual={}", pcg_stats.residual_norm);
    assert!(
        pcg_stats.iterations < cg_stats.iterations,
        "preconditioned CG must take strictly fewer iterations ({} vs {})",
        pcg_stats.iterations,
        cg_stats.iterations
    );
    // Both agree with the dense Cholesky oracle (loosely: the residual
    // tolerance divided by λ bounds the solution error).
    let mut q_dense = Matrix::from_fn(n, n, |h1, h2| {
        g.get(train.end_idx[h1] as usize, train.end_idx[h2] as usize)
            * k.get(train.start_idx[h1] as usize, train.start_idx[h2] as usize)
    });
    q_dense.add_diag(lambda);
    let oracle = q_dense.solve_spd(&train.labels).unwrap();
    assert_allclose(&x_pcg, &oracle, 1e-3, 1e-3);
}

#[test]
fn precond_cg_is_exact_inverse_on_complete_graph() {
    // Density 1.0 ⇒ every vertex pair labeled ⇒ R is a permutation and the
    // preconditioner is the exact inverse: PCG converges almost immediately.
    let train = CheckerboardConfig {
        m: 9,
        q: 8,
        density: 1.0,
        noise: 0.1,
        feature_range: 8.0,
        seed: 12,
    }
    .generate();
    let lambda = 0.3;
    let g = GAUSS.square_matrix(&train.end_features);
    let k = GAUSS.square_matrix(&train.start_features);
    let idx = train.kron_index();
    assert!(idx.complete_layout(8, 9).is_some(), "density 1.0 must give a complete graph");
    let n = idx.len();
    let op = KronKernelOp::new(Arc::new(g.clone()), Arc::new(k.clone()), idx.clone());
    let sys = RidgeSystemOp { op: &op, lambda };
    let precond = KronSpectralPrecond::new(&eigh(&g), &eigh(&k), idx, lambda);
    let cfg = SolverConfig { max_iters: 100, tol: 1e-10 };
    let mut x = vec![0.0; n];
    let stats = pcg(&sys, &train.labels, &mut x, &precond, &cfg);
    assert!(stats.converged);
    assert!(stats.iterations <= 3, "exact-inverse preconditioning took {}", stats.iterations);
    let mut x_cg = vec![0.0; n];
    cg(&sys, &train.labels, &mut x_cg, &cfg);
    assert_allclose(&x, &x_cg, 1e-6, 1e-6);
}

#[test]
fn loo_path_matches_literal_refits() {
    let mut rng = Pcg32::seeded(0xE164);
    let train = complete_dataset(&mut rng, 4, 3);
    let n = train.n_edges();
    let lambdas = [0.5, 2.0];
    let loo = KronRidge::new(gauss_cfg(1.0)).loo_path(&train, &lambdas).unwrap();
    assert_eq!(loo.len(), lambdas.len());
    let q_dense = dense_q(&train);
    for (grid, &lambda) in loo.iter().zip(&lambdas) {
        assert_eq!(grid.len(), n);
        for h in 0..n {
            // Literal refit: drop edge h, solve the (n-1)-edge ridge system
            // on the materialized kernel, predict edge h.
            let keep: Vec<usize> = (0..n).filter(|&j| j != h).collect();
            let mut q_sub =
                Matrix::from_fn(n - 1, n - 1, |i, j| q_dense.get(keep[i], keep[j]));
            q_sub.add_diag(lambda);
            let y_sub: Vec<f64> = keep.iter().map(|&j| train.labels[j]).collect();
            let a_sub = q_sub.solve_spd(&y_sub).unwrap();
            let pred: f64 =
                keep.iter().zip(&a_sub).map(|(&j, aj)| q_dense.get(h, j) * aj).sum();
            assert!(
                (grid[h] - pred).abs() <= 1e-8 * (1.0 + pred.abs()),
                "λ={lambda} edge {h}: shortcut {} vs literal {pred}",
                grid[h]
            );
        }
    }
}

#[test]
fn lambda_grid_costs_one_decomposition_pair() {
    // The `cv --lambdas` workload: on a complete training graph the whole λ
    // grid — any length — must cost exactly two eigh calls (one per kernel
    // factor), both through the raw trainer and the Learner builder.
    let mut rng = Pcg32::seeded(0xE165);
    let train = complete_dataset(&mut rng, 6, 5);
    let lambdas = [0.01, 0.1, 1.0, 10.0, 100.0];

    let before = eigh_count();
    let models = KronRidge::new(gauss_cfg(1.0)).fit_path(&train, &lambdas).unwrap();
    assert_eq!(eigh_count() - before, 2, "fit_path must share one decomposition pair");
    assert_eq!(models.len(), lambdas.len());
    for (model, &lambda) in models.iter().zip(&lambdas) {
        let oracle =
            ridge_exact_dual(&train, &gauss_cfg(lambda), PairwiseKernelKind::Kronecker);
        assert_allclose(&model.dual_coef, &oracle, 1e-8, 1e-8);
    }

    let before = eigh_count();
    let trained = Learner::ridge().kernel(GAUSS).fit_path(&train, &lambdas).unwrap();
    assert_eq!(eigh_count() - before, 2, "Learner::fit_path must share one pair");
    assert_eq!(trained.len(), lambdas.len());

    let before = eigh_count();
    let loo = KronRidge::new(gauss_cfg(1.0)).loo_path(&train, &lambdas).unwrap();
    assert_eq!(eigh_count() - before, 2, "loo_path must share one pair");
    assert_eq!(loo.len(), lambdas.len());
}
