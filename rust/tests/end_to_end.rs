//! End-to-end integration: full train → zero-shot predict → AUC pipelines
//! across methods and datasets, plus cross-method consistency checks.

use kronvt::baselines::{ExplicitSvm, ExplicitSvmConfig, KnnConfig, KnnModel, SgdConfig, SgdModel};
use kronvt::coordinator::run_cv_jobs;
use kronvt::data::checkerboard::CheckerboardConfig;
use kronvt::data::dti;
use kronvt::eval::auc::auc;
use kronvt::kernels::KernelKind;
use kronvt::train::{KronRidge, KronSvm, RidgeConfig, SvmConfig};

#[test]
fn all_methods_beat_chance_on_dti() {
    // The Table-5 GPCR shape.
    let ds = dti::gpcr(5).generate();
    let (train, test) = ds.zero_shot_split(0.3, 11);

    // λ tuned on the validation grid as the paper does per-dataset (§5.2);
    // early-terminated iterations provide most of the regularization.
    let kron_svm = KronSvm::new(SvmConfig {
        lambda: 1.0,
        outer_iters: 10,
        inner_iters: 10,
        ..Default::default()
    })
    .fit(&train)
    .unwrap();
    let a_svm = auc(&test.labels, &kron_svm.predict(&test));

    let kron_ridge = KronRidge::new(RidgeConfig { lambda: 1e-2, iterations: 10, ..Default::default() })
        .fit(&train)
        .unwrap();
    let a_ridge = auc(&test.labels, &kron_ridge.predict(&test));

    let sgd = SgdModel::fit(&train, &SgdConfig { updates: 100_000, ..Default::default() }).unwrap();
    let a_sgd = auc(&test.labels, &sgd.predict(&test));

    let knn = KnnModel::fit(&train, &KnnConfig::default()).unwrap();
    let a_knn = auc(&test.labels, &knn.predict(&test));

    assert!(a_svm > 0.55, "KronSVM AUC={a_svm}");
    assert!(a_ridge > 0.55, "KronRidge AUC={a_ridge}");
    // the single zero-shot test block has only ~15 positives, so baseline
    // AUCs carry ±0.1 noise — sanity bounds only (Table 6 shape is asserted
    // on the full CV in bench_table6)
    assert!(a_sgd > 0.4, "SGD AUC={a_sgd}");
    assert!(a_knn > 0.4, "KNN AUC={a_knn}");
    // Kronecker methods should dominate the linear baseline on bilinear data
    assert!(a_svm.max(a_ridge) >= a_sgd - 0.02, "kron {a_svm}/{a_ridge} vs sgd {a_sgd}");
}

#[test]
fn kron_svm_and_explicit_smo_agree_on_gaussian_kernel() {
    // Both optimize (slightly different) SVM objectives over the *same*
    // Kronecker kernel; their rankings should agree strongly.
    let data = CheckerboardConfig {
        m: 40,
        q: 40,
        density: 0.4,
        noise: 0.05,
        feature_range: 6.0,
        seed: 13,
        ..Default::default()
    }
    .generate();
    let (train, test) = data.zero_shot_split(0.3, 17);
    let gaussian = KernelKind::Gaussian { gamma: 1.0 };

    let kron = KronSvm::new(SvmConfig {
        lambda: 2f64.powi(-7),
        kernel_d: gaussian,
        kernel_t: gaussian,
        outer_iters: 10,
        inner_iters: 10,
        ..Default::default()
    })
    .fit(&train)
    .unwrap();
    let smo = ExplicitSvm::fit(
        &train,
        &ExplicitSvmConfig { c: 100.0, kernel: gaussian, ..Default::default() },
    )
    .unwrap();

    let a_kron = auc(&test.labels, &kron.predict(&test));
    let a_smo = auc(&test.labels, &smo.predict(&test));
    assert!(a_kron > 0.75, "kron AUC={a_kron}");
    assert!(a_smo > 0.75, "smo AUC={a_smo}");
    assert!((a_kron - a_smo).abs() < 0.12, "kron {a_kron} vs smo {a_smo}");
}

#[test]
fn ninefold_cv_pipeline_runs_all_folds() {
    let ds = dti::gpcr(3).generate();
    let folds = ds.ninefold_cv(7);
    assert_eq!(folds.len(), 9);
    let results = run_cv_jobs(&folds, 1, |tr, te| {
        let model = KronRidge::new(RidgeConfig { lambda: 1e-2, iterations: 10, ..Default::default() })
            .fit(tr)
            .unwrap();
        auc(&te.labels, &model.predict(te))
    });
    assert_eq!(results.len(), 9);
    let mean = kronvt::coordinator::jobs::mean_auc(&results);
    assert!(mean > 0.55, "mean CV AUC={mean}");
}

#[test]
fn early_stopping_model_is_competitive() {
    // §5.2's claim: a handful of iterations with early stopping reaches the
    // accuracy of (nearly) converged optimization.
    let ds = dti::gpcr(9).generate();
    let (train_all, test) = ds.zero_shot_split(0.25, 3);
    let (train, val) = train_all.zero_shot_split(0.25, 5);

    let stopped = KronRidge::new(RidgeConfig {
        lambda: 1e-6,
        iterations: 200,
        trace: true,
        patience: 5,
        ..Default::default()
    })
    .fit_traced(&train, Some(&val))
    .unwrap();
    let converged = KronRidge::new(RidgeConfig { lambda: 1e-6, iterations: 200, ..Default::default() })
        .fit(&train)
        .unwrap();

    let a_stop = auc(&test.labels, &stopped.0.predict(&test));
    let a_conv = auc(&test.labels, &converged.predict(&test));
    assert!(
        stopped.1.records.len() < 200,
        "early stopping never triggered ({} iters)",
        stopped.1.records.len()
    );
    assert!(a_stop > a_conv - 0.05, "stopped {a_stop} vs converged {a_conv}");
}

#[test]
fn svm_sparse_prediction_shortcut_is_exact() {
    let data = CheckerboardConfig {
        m: 30,
        q: 30,
        density: 0.4,
        noise: 0.1,
        feature_range: 5.0,
        seed: 23,
        ..Default::default()
    }
    .generate();
    let (train, test) = data.zero_shot_split(0.3, 29);
    let gaussian = KernelKind::Gaussian { gamma: 1.0 };
    let model = KronSvm::new(SvmConfig {
        lambda: 0.01,
        kernel_d: gaussian,
        kernel_t: gaussian,
        outer_iters: 20,
        inner_iters: 20,
        sparsity_threshold: 1e-9,
        ..Default::default()
    })
    .fit(&train)
    .unwrap();
    let full = model.predict(&test);
    let pruned = model.pruned().predict(&test);
    let explicit = model.predict_explicit(&test);
    kronvt::linalg::vecops::assert_allclose(&full, &pruned, 1e-10, 1e-10);
    kronvt::linalg::vecops::assert_allclose(&full, &explicit, 1e-8, 1e-8);
}
