//! Model-persistence integration tests: the **fit → save → load → serve**
//! lifecycle must reproduce the in-memory model's predictions **bit for
//! bit** across every pairwise family, ridge and SVM, serial and threaded
//! execution — plus rejection of corrupted and over-versioned artifacts,
//! and a genuine fresh-process round trip through the CLI binary
//! (`train --save` → `predict --model`).

use std::path::PathBuf;
use std::process::Command;

use kronvt::api::{Compute, Estimator, Learner, NewtonLoss, TrainedModel};
use kronvt::data::checkerboard::{CheckerboardConfig, HomogeneousConfig};
use kronvt::data::Dataset;
use kronvt::gvt::PairwiseKernelKind;
use kronvt::kernels::KernelKind;

fn hetero_data() -> Dataset {
    CheckerboardConfig {
        m: 30,
        q: 30,
        density: 0.35,
        noise: 0.15,
        feature_range: 8.0,
        seed: 71,
    }
    .generate()
}

fn homo_data() -> Dataset {
    HomogeneousConfig { vertices: 26, density: 0.4, noise: 0.15, feature_range: 6.0, seed: 72 }
        .generate()
}

/// Unique temp path per test (tests run concurrently in one process).
fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("kronvt_lifecycle_{tag}_{}.json", std::process::id()))
}

fn save_load(model: &TrainedModel, tag: &str) -> TrainedModel {
    let path = temp_path(tag);
    model.save(&path).expect("save");
    let loaded = TrainedModel::load(&path).expect("load");
    std::fs::remove_file(&path).ok();
    loaded
}

/// The core acceptance matrix: all four pairwise families × {ridge, svm} ×
/// threads {1, 4}, each asserting the loaded model scores a batch bitwise
/// identically to the in-memory model.
#[test]
fn save_load_predict_is_bitwise_across_families_methods_threads() {
    let kernel = KernelKind::Gaussian { gamma: 0.8 };
    for pairwise in [
        PairwiseKernelKind::Kronecker,
        PairwiseKernelKind::SymmetricKron,
        PairwiseKernelKind::AntiSymmetricKron,
        PairwiseKernelKind::Cartesian,
    ] {
        // symmetric / anti-symmetric / Cartesian need one shared vertex
        // domain; Kronecker exercises the heterogeneous shape.
        let data = if pairwise == PairwiseKernelKind::Kronecker {
            hetero_data()
        } else {
            homo_data()
        };
        let (train, zero_shot) = data.zero_shot_split(0.3, 5);
        // The Cartesian δ does not extend to novel vertices (zero-shot
        // scores are identically 0), so score the training edges themselves
        // — a non-trivial in-sample batch — for that family.
        let test = if pairwise == PairwiseKernelKind::Cartesian {
            Dataset { labels: vec![0.0; train.n_edges()], ..train.clone() }
        } else {
            zero_shot
        };
        for threads in [1usize, 4] {
            let compute = Compute::threads(threads);
            for method in ["ridge", "svm"] {
                let learner = match method {
                    "ridge" => Learner::ridge().iterations(40),
                    _ => Learner::svm().iterations(8).inner_iterations(8),
                }
                .lambda(2f64.powi(-5))
                .kernel(kernel)
                .pairwise(pairwise)
                .compute(compute);
                let model = learner.fit(&train).unwrap_or_else(|e| {
                    panic!("{method}/{pairwise:?}/t{threads}: {e}")
                });
                let scores = model.predict_batch(&test, &compute);
                let loaded =
                    save_load(&model, &format!("{method}_{}_{threads}", pairwise.name()));
                // parameters round-trip bitwise...
                assert_eq!(
                    model.as_dual().unwrap().dual_coef,
                    loaded.as_dual().unwrap().dual_coef,
                    "{method}/{pairwise:?}/t{threads}: duals"
                );
                assert_eq!(model.lambda().to_bits(), loaded.lambda().to_bits());
                // ...and so do the scores, threaded or serial
                assert_eq!(
                    scores,
                    loaded.predict_batch(&test, &compute),
                    "{method}/{pairwise:?}/t{threads}: scores"
                );
            }
        }
    }
}

/// The Estimator trait is the generic entry point; the Newton learner and
/// the primal path flow through the same TrainedModel + artifact.
#[test]
fn newton_and_primal_models_round_trip() {
    let (train, test) = hetero_data().zero_shot_split(0.3, 9);
    // generic truncated Newton (logistic), dual
    let newton: &dyn Estimator =
        &Learner::newton(NewtonLoss::Logistic).lambda(0.1).iterations(6).inner_iterations(10);
    let model = newton.fit(&train).unwrap();
    let loaded = save_load(&model, "newton_logistic");
    assert_eq!(model.predict(&test), loaded.predict(&test));
    // primal ridge (linear kernels)
    let primal = Learner::ridge().lambda(1.0).iterations(60).primal(true).fit(&train).unwrap();
    assert_eq!(primal.kind_name(), "primal");
    let loaded = save_load(&primal, "primal_ridge");
    assert_eq!(primal.as_primal().unwrap().w, loaded.as_primal().unwrap().w);
    assert_eq!(primal.predict(&test), loaded.predict(&test));
}

/// The multi-λ path produces one artifact-capable model per λ, each
/// round-tripping bitwise.
#[test]
fn fit_path_models_round_trip() {
    let (train, test) = hetero_data().zero_shot_split(0.3, 11);
    let lambdas = [0.25, 4.0];
    let models = Learner::ridge()
        .iterations(60)
        .kernel(KernelKind::Gaussian { gamma: 0.5 })
        .fit_path(&train, &lambdas)
        .unwrap();
    assert_eq!(models.len(), 2);
    for (j, model) in models.iter().enumerate() {
        assert_eq!(model.lambda(), lambdas[j]);
        let loaded = save_load(model, &format!("path_{j}"));
        assert_eq!(model.predict(&test), loaded.predict(&test), "λ={}", lambdas[j]);
    }
}

/// A loaded model serves through the full context/server pipeline with the
/// same scores the in-memory model produces.
#[test]
fn loaded_model_serves_through_context() {
    let (train, test) = hetero_data().zero_shot_split(0.3, 13);
    let model = Learner::ridge()
        .lambda(2f64.powi(-5))
        .kernel(KernelKind::Gaussian { gamma: 0.8 })
        .iterations(40)
        .fit(&train)
        .unwrap();
    let direct = model.predict(&test);
    let loaded = save_load(&model, "serve_ctx");
    let ctx = loaded
        .into_context(&Compute::threads(2).with_cache_vertices(64))
        .expect("dual context");
    // ridge leaves no explicit zero duals → pruning is a no-op → bitwise
    assert_eq!(ctx.predict_batch(&test), direct, "cold");
    assert_eq!(ctx.predict_batch(&test), direct, "warm (cache hits change no bits)");
}

#[test]
fn corrupted_and_over_versioned_artifacts_are_rejected() {
    let (train, _) = hetero_data().zero_shot_split(0.3, 17);
    let model = Learner::ridge().iterations(10).fit(&train).unwrap();
    let path = temp_path("reject");
    model.save(&path).expect("save");
    let good = std::fs::read_to_string(&path).unwrap();

    // truncated / garbage JSON
    std::fs::write(&path, &good[..good.len() / 2]).unwrap();
    assert!(TrainedModel::load(&path).is_err(), "truncated artifact must fail");
    std::fs::write(&path, "not json at all").unwrap();
    assert!(TrainedModel::load(&path).is_err(), "garbage must fail");

    // over-versioned format tag → explicit version error
    std::fs::write(&path, good.replace("kronvt-model/v1", "kronvt-model/v9")).unwrap();
    let err = TrainedModel::load(&path).unwrap_err();
    assert!(
        err.contains("kronvt-model/v9") && err.contains("kronvt-model/v1"),
        "version mismatch must name both versions: {err}"
    );

    // schema violation: duals shorter than the edge index
    std::fs::write(
        &path,
        {
            let json = kronvt::util::json::Json::parse(&good).unwrap();
            let mut obj = json.as_obj().unwrap().clone();
            obj.insert("dual_coef".into(), kronvt::util::json::Json::num_arr(&[1.0]));
            kronvt::util::json::Json::Obj(obj).to_string()
        },
    )
    .unwrap();
    assert!(TrainedModel::load(&path).is_err(), "coefficient/index mismatch must fail");

    // missing file
    std::fs::remove_file(&path).ok();
    assert!(TrainedModel::load(&path).is_err());
}

#[test]
fn non_finite_models_refuse_to_save() {
    let (train, _) = hetero_data().zero_shot_split(0.3, 19);
    let model = Learner::ridge().iterations(10).fit(&train).unwrap();
    let mut dual = model.as_dual().unwrap().clone();
    dual.dual_coef[0] = f64::NAN;
    let broken = TrainedModel::from_dual(dual, model.lambda());
    let path = temp_path("nonfinite");
    let err = broken.save(&path).unwrap_err();
    assert!(err.contains("dual_coef"), "{err}");
    assert!(!path.exists(), "nothing may be written for a non-finite model");
}

/// Crash-safety of the artifact lifecycle: saves stage through a fsynced
/// `.tmp` sibling and rename into place, so a torn write (a crash mid-save)
/// can never corrupt a previously good artifact; the loader refuses `.tmp`
/// paths outright and sweeps stale staging files.
#[test]
fn atomic_save_survives_torn_writes_and_cleans_stale_tmp() {
    let (train, test) = hetero_data().zero_shot_split(0.3, 23);
    let model = Learner::ridge().iterations(10).fit(&train).unwrap();
    let expected = model.predict(&test);
    let path = temp_path("atomic");
    let tmp = PathBuf::from(format!("{}.tmp", path.display()));

    model.save(&path).expect("save");
    assert!(!tmp.exists(), "a completed save leaves no staging file behind");
    let good = std::fs::read_to_string(&path).unwrap();

    // Simulated crash mid-save: a torn (truncated) staging file next to the
    // good artifact. Loading the artifact still works, and the stale .tmp
    // is swept.
    std::fs::write(&tmp, &good[..good.len() / 2]).unwrap();
    let loaded = TrainedModel::load(&path).expect("good artifact loads past a torn .tmp sibling");
    assert_eq!(loaded.predict(&test), expected, "bitwise despite the torn sibling");
    assert!(!tmp.exists(), "a successful load sweeps the stale staging file");

    // The staging file itself is never a valid load target, even when its
    // content is a complete document.
    std::fs::write(&tmp, &good).unwrap();
    let err = TrainedModel::load(&tmp).unwrap_err();
    assert!(err.contains(".tmp"), "refusal must name the staging suffix: {err}");
    std::fs::remove_file(&tmp).ok();

    // A save that fails (non-finite parameters) is all-or-nothing: the
    // previous artifact is untouched and no staging file is left behind.
    let mut dual = model.as_dual().unwrap().clone();
    dual.dual_coef[0] = f64::INFINITY;
    let broken = TrainedModel::from_dual(dual, model.lambda());
    assert!(broken.save(&path).is_err());
    assert!(!tmp.exists(), "failed save leaves no staging file");
    assert_eq!(
        std::fs::read_to_string(&path).unwrap(),
        good,
        "failed save leaves the previous artifact byte-identical"
    );

    // Re-saving over an existing artifact is also all-or-nothing: after the
    // save, the file is exactly the new document (rename, not append/trunc).
    model.save(&path).expect("re-save");
    assert_eq!(std::fs::read_to_string(&path).unwrap(), good, "same model → same document");
    let reloaded = TrainedModel::load(&path).expect("reload");
    assert_eq!(reloaded.predict(&test), expected);
    std::fs::remove_file(&path).ok();
}

/// The real acceptance path: a **fresh process** (the CLI binary) loads what
/// another process saved and reproduces the training process's test scores
/// bitwise — asserted by comparing the shortest-round-trip `score_sum`
/// lines, which match iff the floats match bit for bit.
#[test]
fn cli_train_save_predict_round_trip_is_bitwise_across_processes() {
    let exe = env!("CARGO_BIN_EXE_kronvt");
    let model_path = temp_path("cli");
    let common = [
        "--data",
        "checker",
        "--scale",
        "0.04",
        "--seed",
        "3",
        "--test-frac",
        "0.25",
    ];

    let train_out = Command::new(exe)
        .arg("train")
        .args(common)
        .args(["--method", "kronridge", "--kernel", "gaussian:1", "--lambda", "0.0078125"])
        .args(["--save", model_path.to_str().unwrap()])
        .output()
        .expect("run kronvt train");
    assert!(
        train_out.status.success(),
        "train failed: {}",
        String::from_utf8_lossy(&train_out.stderr)
    );
    let train_stdout = String::from_utf8_lossy(&train_out.stdout).to_string();
    let train_sum = extract_score_sum(&train_stdout);

    let predict_out = Command::new(exe)
        .arg("predict")
        .args(common)
        .args(["--model", model_path.to_str().unwrap()])
        .output()
        .expect("run kronvt predict");
    assert!(
        predict_out.status.success(),
        "predict failed: {}",
        String::from_utf8_lossy(&predict_out.stderr)
    );
    let predict_stdout = String::from_utf8_lossy(&predict_out.stdout).to_string();
    let predict_sum = extract_score_sum(&predict_stdout);

    assert_eq!(
        train_sum, predict_sum,
        "fresh-process scores diverged:\n--- train ---\n{train_stdout}\n--- predict ---\n{predict_stdout}"
    );

    // and the artifact serves without retraining
    let serve_out = Command::new(exe)
        .arg("serve")
        .args(["--model", model_path.to_str().unwrap(), "--requests", "5", "--threads", "1"])
        .output()
        .expect("run kronvt serve");
    assert!(
        serve_out.status.success(),
        "serve --model failed: {}",
        String::from_utf8_lossy(&serve_out.stderr)
    );
    assert!(
        String::from_utf8_lossy(&serve_out.stdout).contains("served 5 requests"),
        "serve must answer without retraining"
    );

    // A dataset whose feature dimensions don't match the artifact is a clean
    // CLI error (`error: ...`, exit 1), never an internal dimension panic.
    let out = Command::new(exe)
        .args(["predict", "--model", model_path.to_str().unwrap(), "--data", "gpcr"])
        .output()
        .expect("run kronvt predict");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("error:") && stderr.contains("features"),
        "dim mismatch must be a clean error: {stderr}"
    );

    // Training-only flags are rejected with --model rather than silently
    // losing to the artifact's own settings.
    let out = Command::new(exe)
        .args(["serve", "--model", model_path.to_str().unwrap(), "--lambda", "0.5"])
        .output()
        .expect("run kronvt serve");
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("--lambda"),
        "dead flag must be named"
    );

    std::fs::remove_file(&model_path).ok();
}

/// Typos in CLI flags fail loudly (the util::args satellite, end to end).
#[test]
fn cli_rejects_unknown_flags_and_bad_values() {
    let exe = env!("CARGO_BIN_EXE_kronvt");
    let out = Command::new(exe)
        .args(["train", "--lamda", "0.1"]) // typo
        .output()
        .expect("run kronvt");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--lamda"), "must name the unknown flag: {stderr}");

    let out = Command::new(exe)
        .args(["train", "--threads", "foo"])
        .output()
        .expect("run kronvt");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--threads"), "must name the bad flag: {stderr}");
}

fn extract_score_sum(stdout: &str) -> String {
    stdout
        .lines()
        .find_map(|l| l.split("score_sum=").nth(1))
        .unwrap_or_else(|| panic!("no score_sum line in output:\n{stdout}"))
        .trim()
        .to_string()
}
