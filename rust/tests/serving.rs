//! Integration tests for the vertex-cached, sharded prediction pipeline:
//! bitwise equivalence of cold / warm / uncached / sharded serving, mixed
//! valid-and-invalid traffic under the scoring pool (invalid requests get
//! typed `InvalidRequest` errors), and LRU behavior under eviction
//! pressure. Fault-path guarantees (deadlines, panics, overload, hot swap)
//! live in `serving_faults.rs`.

use std::sync::atomic::Ordering;
use std::sync::mpsc::channel;

use kronvt::api::Compute;
use kronvt::coordinator::{PredictError, PredictRequest, PredictServer, ServerConfig};
use kronvt::data::checkerboard::CheckerboardConfig;
use kronvt::data::Dataset;
use kronvt::kernels::KernelKind;
use kronvt::linalg::Matrix;
use kronvt::model::DualModel;
use kronvt::train::{KronRidge, RidgeConfig};
use kronvt::util::rng::Pcg32;

/// A ridge model (no explicit zero duals → pruning is a no-op → serving must
/// be bitwise identical to `DualModel::predict`).
fn trained_model() -> DualModel {
    let data = CheckerboardConfig {
        m: 40,
        q: 40,
        density: 0.3,
        noise: 0.15,
        feature_range: 12.0,
        seed: 9,
    }
    .generate();
    let (train, _) = data.zero_shot_split(0.25, 3);
    KronRidge::new(RidgeConfig {
        lambda: 2f64.powi(-5),
        kernel_d: KernelKind::Gaussian { gamma: 1.0 },
        kernel_t: KernelKind::Gaussian { gamma: 1.0 },
        iterations: 40,
        ..Default::default()
    })
    .fit(&train)
    .expect("training")
}

fn request_data(
    rng: &mut Pcg32,
    u: usize,
    v: usize,
    t: usize,
) -> (Vec<Vec<f64>>, Vec<Vec<f64>>, Vec<(u32, u32)>) {
    let sf: Vec<Vec<f64>> = (0..u).map(|_| vec![rng.uniform_in(0.0, 12.0)]).collect();
    let ef: Vec<Vec<f64>> = (0..v).map(|_| vec![rng.uniform_in(0.0, 12.0)]).collect();
    let edges: Vec<(u32, u32)> =
        (0..t).map(|_| (rng.below(u) as u32, rng.below(v) as u32)).collect();
    (sf, ef, edges)
}

fn direct_predict(
    model: &DualModel,
    sf: &[Vec<f64>],
    ef: &[Vec<f64>],
    edges: &[(u32, u32)],
) -> Vec<f64> {
    let ds = Dataset {
        start_features: Matrix::from_fn(sf.len(), sf[0].len(), |i, j| sf[i][j]),
        end_features: Matrix::from_fn(ef.len(), ef[0].len(), |i, j| ef[i][j]),
        start_idx: edges.iter().map(|&(s, _)| s).collect(),
        end_idx: edges.iter().map(|&(_, e)| e).collect(),
        labels: vec![0.0; edges.len()],
        name: "direct".into(),
    };
    model.predict(&ds)
}

/// Every serving configuration — cache off/on, cold/warm, serial/sharded
/// matvec, one/many scoring workers — must return bitwise-identical scores
/// for the same requests.
#[test]
fn all_serving_configurations_are_bitwise_identical() {
    let model = trained_model();
    let mut rng = Pcg32::seeded(100);
    let requests: Vec<_> = (0..6).map(|_| request_data(&mut rng, 5, 4, 12)).collect();
    let expected: Vec<Vec<f64>> =
        requests.iter().map(|(sf, ef, e)| direct_predict(&model, sf, ef, e)).collect();

    for (threads, workers, cache_vertices) in [
        (1, 1, 0),   // the uncached serial reference path
        (1, 1, 256), // cached
        (2, 1, 0),   // sharded matvec
        (4, 3, 256), // cached + sharded + pooled
        (0, 2, 1),   // all cores, eviction on every vertex
    ] {
        let server = PredictServer::start(
            model.clone(),
            ServerConfig {
                workers,
                compute: Compute::threads(threads).with_cache_vertices(cache_vertices),
                ..Default::default()
            },
        );
        // submit one at a time → deterministic batch composition
        for round in 0..2 {
            for (i, (sf, ef, edges)) in requests.iter().enumerate() {
                let got = server
                    .predict_blocking(sf.clone(), ef.clone(), edges.clone())
                    .expect("served");
                assert_eq!(
                    got, expected[i],
                    "request {i} round {round} (threads={threads} workers={workers} cache={cache_vertices})"
                );
            }
        }
        server.shutdown();
    }
}

/// Repeat-vertex traffic must actually hit the cache, and the hits must not
/// change a single bit of the replies.
#[test]
fn cache_hits_leave_scores_bitwise_unchanged() {
    let model = trained_model();
    let mut rng = Pcg32::seeded(101);
    let (sf, ef, edges) = request_data(&mut rng, 6, 6, 20);
    let direct = direct_predict(&model, &sf, &ef, &edges);

    let server = PredictServer::start(
        model,
        ServerConfig {
            compute: Compute::threads(2).with_cache_vertices(64),
            ..Default::default()
        },
    );
    for round in 0..5 {
        let got = server.predict_blocking(sf.clone(), ef.clone(), edges.clone()).unwrap();
        assert_eq!(got, direct, "round {round}");
    }
    let st = server.stats();
    let hits = st.cache_hits.load(Ordering::Relaxed);
    let misses = st.cache_misses.load(Ordering::Relaxed);
    assert_eq!(hits + misses, 60, "5 rounds × 12 vertex lookups");
    assert!(misses <= 12, "only the first round may compute the 6+6 vertex rows, got {misses}");
    assert!(hits >= 48, "warm rounds must hit, got {hits}");
    server.shutdown();
}

/// A tiny cache under constant eviction (capacity 1 per side, alternating
/// vertex sets) must stay correct — eviction may cost hits, never bits.
#[test]
fn eviction_pressure_never_corrupts_scores() {
    let model = trained_model();
    let mut rng = Pcg32::seeded(102);
    let reqs: Vec<_> = (0..3).map(|_| request_data(&mut rng, 3, 3, 8)).collect();
    let expected: Vec<Vec<f64>> =
        reqs.iter().map(|(sf, ef, e)| direct_predict(&model, sf, ef, e)).collect();
    let server = PredictServer::start(
        model,
        ServerConfig { compute: Compute::serial().with_cache_vertices(1), ..Default::default() },
    );
    for round in 0..4 {
        for (i, (sf, ef, edges)) in reqs.iter().enumerate() {
            let got = server.predict_blocking(sf.clone(), ef.clone(), edges.clone()).unwrap();
            assert_eq!(got, expected[i], "request {i} round {round}");
        }
    }
    server.shutdown();
}

/// Mixed valid/invalid requests under the sharded worker pool: invalid ones
/// get typed `InvalidRequest` errors, valid ones exact scores, nothing is
/// lost or misrouted.
#[test]
fn mixed_traffic_under_sharded_pool() {
    let model = trained_model();
    let mut rng = Pcg32::seeded(103);
    let server = PredictServer::start(
        model.clone(),
        ServerConfig {
            workers: 4,
            max_batch_edges: 64,
            compute: Compute::threads(2).with_cache_vertices(32),
            ..Default::default()
        },
    );
    let sender = server.sender();

    let mut expected = Vec::new(); // None = invalid request
    let mut replies = Vec::new();
    for i in 0..30 {
        let (tx, rx) = channel();
        if i % 5 == 2 {
            // invalid: edge references a vertex the request doesn't carry
            sender
                .send(PredictRequest::new(vec![vec![0.5]], vec![vec![0.5]], vec![(0, 9)], tx))
                .unwrap();
            expected.push(None);
        } else if i % 7 == 3 {
            // invalid: wrong feature dimensionality
            sender
                .send(PredictRequest::new(
                    vec![vec![0.5, 0.5, 0.5]],
                    vec![vec![0.5]],
                    vec![(0, 0), (0, 0)],
                    tx,
                ))
                .unwrap();
            expected.push(None);
        } else {
            let (sf, ef, edges) = request_data(&mut rng, 3, 3, 7);
            expected.push(Some(direct_predict(&model, &sf, &ef, &edges)));
            sender.send(PredictRequest::new(sf, ef, edges, tx)).unwrap();
        }
        replies.push(rx);
    }
    drop(sender);

    for (i, (rx, want)) in replies.into_iter().zip(&expected).enumerate() {
        let got = rx.recv().expect("every request answered").result;
        match want {
            None => match got {
                Err(PredictError::InvalidRequest(_)) => {}
                other => panic!("request {i} must get InvalidRequest, got {other:?}"),
            },
            Some(want) => assert_eq!(got.as_ref().expect("scored"), want, "request {i}"),
        }
    }
    let st = server.stats();
    assert_eq!(st.requests.load(Ordering::Relaxed), 30);
    server.shutdown();
}

/// The bounded queue plus scoring pool must survive a burst far larger than
/// `max_queue` (senders block, nothing is dropped) and shut down gracefully.
#[test]
fn backpressure_burst_is_lossless() {
    let model = trained_model();
    let server = PredictServer::start(
        model,
        ServerConfig {
            workers: 2,
            max_queue: 4,
            max_batch_edges: 32,
            compute: Compute::serial().with_cache_vertices(16),
            ..Default::default()
        },
    );
    let mut rng = Pcg32::seeded(104);
    std::thread::scope(|scope| {
        for _ in 0..4 {
            let sender = server.sender();
            let reqs: Vec<_> = (0..25).map(|_| request_data(&mut rng, 2, 2, 5)).collect();
            scope.spawn(move || {
                let mut rxs = Vec::new();
                for (sf, ef, edges) in reqs {
                    let (tx, rx) = channel();
                    sender.send(PredictRequest::new(sf, ef, edges, tx)).unwrap();
                    rxs.push(rx);
                }
                rxs.into_iter()
                    .map(|rx| rx.recv().unwrap().result.expect("scored").len())
                    .sum::<usize>()
            });
        }
    });
    // scope joined: all submitter threads done, every reply received
    let st = server.stats();
    assert_eq!(st.requests.load(Ordering::Relaxed), 100);
    assert_eq!(st.edges_scored.load(Ordering::Relaxed), 500);
    server.shutdown();
}
