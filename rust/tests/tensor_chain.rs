//! Acceptance suite for the D-way tensor-product chain generalization:
//!
//! 1. the D = 2 chain apply is **bitwise identical** to the two-factor
//!    [`KronKernelOp`] at every thread count, single- and multi-RHS — the
//!    pre-refactor operator is literally the `D = 2` special case;
//! 2. the D = 3 chain apply matches a dense triple-Kronecker oracle to
//!    1e-10;
//! 3. the grid generator's complete/incomplete split is detected by
//!    [`TensorDataset::is_complete_grid`];
//! 4. a D = 3 ridge model trains end to end through
//!    [`Learner::fit_tensor`] on the spatio-temporal checkerboard, with
//!    predictions matching a dense Kronecker oracle (SPD solve + explicit
//!    cross-kernel products) to 1e-10 and a finite test AUC.

use std::sync::Arc;

use kronvt::api::{Compute, Learner};
use kronvt::data::{GridCheckerboardConfig, TensorDataset};
use kronvt::eval::auc::auc;
use kronvt::gvt::{KronKernelOp, TensorIndex, TensorKernelOp};
use kronvt::kernels::{kernel_matrix, KernelKind};
use kronvt::linalg::vecops::assert_allclose;
use kronvt::linalg::Matrix;
use kronvt::util::rng::Pcg32;

const GAUSS: KernelKind = KernelKind::Gaussian { gamma: 0.5 };

/// A random Gaussian kernel matrix over `n` vertices with 3-dim features.
fn random_kernel(rng: &mut Pcg32, n: usize) -> Matrix {
    let x = Matrix::from_fn(n, 3, |_, _| rng.normal());
    GAUSS.square_matrix(&x)
}

/// A random edge index over the given per-mode vertex counts.
fn random_index(rng: &mut Pcg32, dims: &[usize], n: usize) -> TensorIndex {
    TensorIndex::new(
        dims.iter().map(|&d| (0..n).map(|_| rng.below(d) as u32).collect()).collect(),
    )
}

#[test]
fn two_mode_chain_is_bitwise_identical_to_kron_op() {
    let mut rng = Pcg32::seeded(0x7C2);
    let (m, q, n) = (17, 13, 300);
    let k = Arc::new(random_kernel(&mut rng, m));
    let g = Arc::new(random_kernel(&mut rng, q));
    let idx = random_index(&mut rng, &[m, q], n);
    let kron_idx = idx.to_kron().expect("two-mode index converts");
    let v = rng.normal_vec(n);
    const K_RHS: usize = 5;
    let vs = rng.normal_vec(n * K_RHS);

    for threads in [1, 2, 4] {
        let chain = TensorKernelOp::new(vec![k.clone(), g.clone()], idx.clone())
            .with_threads(threads);
        let kron = KronKernelOp::new(k.clone(), g.clone(), kron_idx.clone())
            .with_threads(threads);

        let mut u_chain = vec![0.0; n];
        let mut u_kron = vec![0.0; n];
        chain.apply_into(&v, &mut u_chain);
        kron.apply_into(&v, &mut u_kron);
        assert_eq!(u_chain, u_kron, "single-RHS diverged at {threads} threads");

        let mut us_chain = vec![0.0; n * K_RHS];
        let mut us_kron = vec![0.0; n * K_RHS];
        chain.apply_multi_into(&vs, K_RHS, &mut us_chain);
        kron.apply_multi_into(&vs, K_RHS, &mut us_kron);
        assert_eq!(us_chain, us_kron, "multi-RHS diverged at {threads} threads");
    }
}

#[test]
fn three_mode_chain_matches_dense_oracle() {
    let mut rng = Pcg32::seeded(0x7C3);
    let dims = [7, 6, 5];
    let factors: Vec<Arc<Matrix>> =
        dims.iter().map(|&d| Arc::new(random_kernel(&mut rng, d))).collect();
    let n = 120;
    let idx = random_index(&mut rng, &dims, n);
    let v = rng.normal_vec(n);

    // Dense oracle: Q[h][h'] = Π_d K_d[i_d(h), i_d(h')], no chain code.
    let q = Matrix::from_fn(n, n, |h1, h2| {
        (0..dims.len())
            .map(|d| {
                factors[d].get(idx.modes[d][h1] as usize, idx.modes[d][h2] as usize)
            })
            .product()
    });
    let want = q.matvec(&v);

    for threads in [1, 4] {
        let op = TensorKernelOp::new(factors.clone(), idx.clone()).with_threads(threads);
        let mut got = vec![0.0; n];
        op.apply_into(&v, &mut got);
        assert_allclose(&got, &want, 1e-10, 1e-10);
    }
    // The diagonal shortcut agrees with the oracle's diagonal too.
    let op = TensorKernelOp::new(factors.clone(), idx.clone());
    let diag: Vec<f64> = (0..n).map(|h| q.get(h, h)).collect();
    assert_allclose(&op.diagonal(), &diag, 1e-12, 1e-12);
}

#[test]
fn grid_generator_complete_and_incomplete_are_detected() {
    let cfg = GridCheckerboardConfig {
        dims: vec![5, 4, 3],
        density: 0.4,
        noise: 0.1,
        feature_range: 8.0,
        seed: 11,
    };
    let complete = cfg.generate_complete();
    assert!(complete.is_complete_grid(), "generate_complete must cover every cell");
    assert_eq!(complete.n_edges(), 5 * 4 * 3);
    complete.validate().expect("complete grid validates");

    let sparse = cfg.generate();
    sparse.validate().expect("sampled grid validates");
    assert!(sparse.n_edges() < complete.n_edges());
    assert!(!sparse.is_complete_grid(), "a 40% sample must not be a complete grid");
}

/// Dense end-to-end oracle: materialize the D-way training kernel, solve
/// `(Q + λI) a = y` with the dense SPD factorization, and score test cells
/// with explicit per-mode cross-kernel products.
fn dense_ridge_oracle(train: &TensorDataset, test: &TensorDataset, lambda: f64) -> Vec<f64> {
    let order = train.order();
    let kernels: Vec<Matrix> =
        train.features.iter().map(|f| GAUSS.square_matrix(f)).collect();
    let n = train.n_edges();
    let mut sys = Matrix::from_fn(n, n, |h1, h2| {
        (0..order)
            .map(|d| {
                kernels[d]
                    .get(train.index.modes[d][h1] as usize, train.index.modes[d][h2] as usize)
            })
            .product()
    });
    for h in 0..n {
        let q_hh = sys.get(h, h);
        sys.set(h, h, q_hh + lambda);
    }
    let a = sys.solve_spd(&train.labels).expect("ridge system is SPD");

    let cross: Vec<Matrix> = (0..order)
        .map(|d| kernel_matrix(GAUSS, &test.features[d], &train.features[d]))
        .collect();
    (0..test.n_edges())
        .map(|t| {
            (0..n)
                .map(|h| {
                    a[h] * (0..order)
                        .map(|d| {
                            cross[d].get(
                                test.index.modes[d][t] as usize,
                                train.index.modes[d][h] as usize,
                            )
                        })
                        .product::<f64>()
                })
                .sum()
        })
        .collect()
}

#[test]
fn three_mode_ridge_trains_end_to_end_and_matches_dense_oracle() {
    let data = GridCheckerboardConfig {
        dims: vec![8, 6, 5],
        density: 0.5,
        noise: 0.1,
        feature_range: 8.0,
        seed: 23,
    }
    .generate();
    let (train, test) = data.holdout_split(0.3, 23);
    assert_eq!(train.order(), 3);
    assert!(test.n_edges() > 0);

    let lambda = 0.1;
    let model = Learner::ridge()
        .lambda(lambda)
        .kernel(GAUSS)
        .iterations(800)
        .tol(1e-14)
        .fit_tensor(&train)
        .expect("D=3 ridge trains through the Learner");
    assert_eq!(model.kind_name(), "tensor");
    assert_eq!(model.as_tensor().expect("tensor model").order(), 3);

    let scores = model.predict_tensor(&test, &Compute::default()).expect("predicts");
    let oracle = dense_ridge_oracle(&train, &test, lambda);
    assert_allclose(&scores, &oracle, 1e-10, 1e-10);

    let test_auc = auc(&test.labels, &scores);
    assert!(test_auc.is_finite(), "AUC must be finite, got {test_auc}");
    assert!(
        test_auc > 0.5,
        "the Gaussian tensor ridge should beat chance on the grid checkerboard \
         (AUC = {test_auc})"
    );

    // Thread count is transparent to both training and prediction.
    for threads in [2, 4] {
        let par = Learner::ridge()
            .lambda(lambda)
            .kernel(GAUSS)
            .iterations(800)
            .tol(1e-14)
            .compute(Compute::threads(threads))
            .fit_tensor(&train)
            .expect("parallel fit");
        let par_scores =
            par.predict_tensor(&test, &Compute::threads(threads)).expect("predicts");
        assert_eq!(scores, par_scores, "predictions diverged at {threads} threads");
    }
}
