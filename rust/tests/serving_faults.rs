//! Fault-tolerance guarantees of the prediction server, proven under
//! deterministic fault injection ([`FaultPlan`]) instead of timing luck:
//!
//! * expired deadlines answer `DeadlineExceeded` and their work is shed,
//!   never computed (checked at merge time and again on the scoring worker);
//! * a panicking scoring worker costs exactly its batch, is respawned by the
//!   pool supervisor, and subsequent traffic scores bitwise-correctly;
//! * overload (`try_submit` against a full queue, or an injected rejection)
//!   answers `Overloaded` immediately — never a hang;
//! * `swap_model` under concurrent traffic loses zero requests, stamps every
//!   reply with the generation that scored it (old or new, never torn), and
//!   post-swap scores are bitwise identical to a fresh server on the new
//!   model.
//!
//! Everything runs under scoring-pool sizes {1, 4}: supervision and swap
//! correctness must not depend on spare workers.

use std::sync::atomic::Ordering;
use std::sync::mpsc::channel;

use kronvt::api::{Compute, TrainedModel};
use kronvt::coordinator::{
    FaultPlan, PredictError, PredictRequest, PredictServer, ServerConfig,
};
use kronvt::data::Dataset;
use kronvt::gvt::{KronIndex, PairwiseKernelKind};
use kronvt::kernels::KernelKind;
use kronvt::linalg::Matrix;
use kronvt::model::DualModel;
use kronvt::util::rng::Pcg32;

const WORKER_COUNTS: [usize; 2] = [1, 4];

/// A tiny dual model built directly (no training) — different seeds give
/// different models with identical feature dims, which is exactly what the
/// hot-swap tests need.
fn toy_model(seed: u64) -> DualModel {
    let mut rng = Pcg32::seeded(seed);
    let (m, q, n) = (6, 5, 15);
    DualModel {
        dual_coef: rng.normal_vec(n),
        train_start_features: Matrix::from_fn(m, 3, |_, _| rng.normal()),
        train_end_features: Matrix::from_fn(q, 2, |_, _| rng.normal()),
        train_idx: KronIndex::new(
            (0..n).map(|_| rng.below(q) as u32).collect(),
            (0..n).map(|_| rng.below(m) as u32).collect(),
        ),
        kernel_d: KernelKind::Gaussian { gamma: 0.3 },
        kernel_t: KernelKind::Gaussian { gamma: 0.3 },
        pairwise: PairwiseKernelKind::Kronecker,
    }
}

fn request_data(
    rng: &mut Pcg32,
    u: usize,
    v: usize,
    t: usize,
) -> (Vec<Vec<f64>>, Vec<Vec<f64>>, Vec<(u32, u32)>) {
    let sf: Vec<Vec<f64>> = (0..u).map(|_| rng.normal_vec(3)).collect();
    let ef: Vec<Vec<f64>> = (0..v).map(|_| rng.normal_vec(2)).collect();
    let edges: Vec<(u32, u32)> =
        (0..t).map(|_| (rng.below(u) as u32, rng.below(v) as u32)).collect();
    (sf, ef, edges)
}

fn direct_predict(
    model: &DualModel,
    sf: &[Vec<f64>],
    ef: &[Vec<f64>],
    edges: &[(u32, u32)],
) -> Vec<f64> {
    let ds = Dataset {
        start_features: Matrix::from_fn(sf.len(), sf[0].len(), |i, j| sf[i][j]),
        end_features: Matrix::from_fn(ef.len(), ef[0].len(), |i, j| ef[i][j]),
        start_idx: edges.iter().map(|&(s, _)| s).collect(),
        end_idx: edges.iter().map(|&(_, e)| e).collect(),
        labels: vec![0.0; edges.len()],
        name: "direct".into(),
    };
    model.predict(&ds)
}

fn config(workers: usize) -> ServerConfig {
    ServerConfig { workers, compute: Compute::serial(), ..Default::default() }
}

/// An already-expired deadline (0 ms) gets `DeadlineExceeded` without a
/// single edge being scored, and the server keeps serving afterwards.
#[test]
fn expired_deadline_is_shed_not_scored() {
    for workers in WORKER_COUNTS {
        let model = toy_model(41);
        let mut rng = Pcg32::seeded(42);
        let (sf, ef, edges) = request_data(&mut rng, 3, 3, 6);
        let expected = direct_predict(&model, &sf, &ef, &edges);
        let server = PredictServer::start(model, config(workers));

        let (tx, rx) = channel();
        let req =
            PredictRequest::new(sf.clone(), ef.clone(), edges.clone(), tx).with_deadline_ms(0);
        server.submit(req).unwrap();
        let reply = rx.recv().expect("expired requests are still answered");
        assert_eq!(reply.result, Err(PredictError::DeadlineExceeded), "workers={workers}");

        let st = server.stats();
        assert_eq!(st.deadline_expired.load(Ordering::Relaxed), 1);
        assert_eq!(st.edges_scored.load(Ordering::Relaxed), 0, "expired work is never computed");

        // same data without a deadline: scored, bitwise-correct
        let ok = server.predict_blocking(sf, ef, edges).unwrap();
        assert_eq!(ok, expected, "workers={workers}");
        server.shutdown();
    }
}

/// An injected straggler (the scoring worker stalls past the request's
/// deadline) triggers the *score-time* expiry pass: the batch was merged
/// while still live, and the stall sheds it on the worker.
#[test]
fn sleep_fault_expires_queued_requests() {
    let model = toy_model(43);
    let mut rng = Pcg32::seeded(44);
    let (sf, ef, edges) = request_data(&mut rng, 3, 3, 6);
    let server = PredictServer::start_with_faults(
        model,
        config(1),
        FaultPlan::seeded(5).sleep_on_batch(1, 400),
    );
    let (tx, rx) = channel();
    let req = PredictRequest::new(sf, ef, edges, tx).with_deadline_ms(100);
    server.submit(req).unwrap();
    let reply = rx.recv().expect("stalled requests are still answered");
    assert_eq!(reply.result, Err(PredictError::DeadlineExceeded));

    let st = server.stats();
    assert_eq!(st.deadline_expired.load(Ordering::Relaxed), 1);
    assert_eq!(st.shed.load(Ordering::Relaxed), 1, "expired after merging → shed on the worker");
    assert_eq!(st.edges_scored.load(Ordering::Relaxed), 0);
    server.shutdown();
}

/// A panicking scoring worker costs exactly its batch — whose requests
/// observe `ShuttingDown` through the dropped reply channel instead of a
/// hang — and is respawned: the very next request scores bitwise-correctly,
/// with the panic and the respawn counted.
#[test]
fn panicking_worker_is_respawned_and_traffic_continues() {
    for workers in WORKER_COUNTS {
        let model = toy_model(45);
        let mut rng = Pcg32::seeded(46);
        let (sf, ef, edges) = request_data(&mut rng, 4, 3, 8);
        let expected = direct_predict(&model, &sf, &ef, &edges);
        let server = PredictServer::start_with_faults(
            model,
            config(workers),
            FaultPlan::seeded(6).panic_on_batch(1),
        );

        // batch 1: the worker panics before touching it; predict_blocking
        // maps the dropped reply to a typed error instead of hanging.
        let crashed = server.predict_blocking(sf.clone(), ef.clone(), edges.clone());
        assert_eq!(crashed, Err(PredictError::ShuttingDown), "workers={workers}");

        // batch 2: the respawned worker scores it, bit for bit.
        let scores = server.predict_blocking(sf, ef, edges).expect("respawned worker serves");
        assert_eq!(scores, expected, "workers={workers}");

        let st = server.stats();
        assert_eq!(st.panics.load(Ordering::Relaxed), 1, "workers={workers}");
        assert_eq!(st.respawns.load(Ordering::Relaxed), 1, "workers={workers}");
        server.shutdown();
    }
}

/// Offered load far beyond capacity (one stalled worker, tiny queues):
/// `try_submit` answers `Overloaded` on the spot — reply already waiting,
/// no hang — while every accepted request still completes.
#[test]
fn overload_returns_typed_error_never_hangs() {
    let model = toy_model(47);
    let mut rng = Pcg32::seeded(48);
    let (sf, ef, edges) = request_data(&mut rng, 2, 2, 4);
    let server = PredictServer::start_with_faults(
        model,
        ServerConfig {
            workers: 1,
            max_queue: 2,
            max_batch_edges: edges.len(), // one request per batch
            compute: Compute::serial(),
            ..Default::default()
        },
        // The only worker stalls on its first batch, so the pool queue, the
        // merger, and then the bounded request queue all back up.
        FaultPlan::seeded(7).sleep_on_batch(1, 300),
    );

    let mut accepted = Vec::new();
    let mut rejected = 0usize;
    for _ in 0..40 {
        let (tx, rx) = channel();
        match server.try_submit(PredictRequest::new(sf.clone(), ef.clone(), edges.clone(), tx)) {
            Ok(()) => accepted.push(rx),
            Err(PredictError::Overloaded) => {
                // the refusal is answered before try_submit returns
                let reply = rx.try_recv().expect("Overloaded reply is immediate");
                assert_eq!(reply.result, Err(PredictError::Overloaded));
                rejected += 1;
            }
            Err(other) => panic!("unexpected admission error: {other:?}"),
        }
    }
    assert!(rejected > 0, "40 instant requests against a stalled 1-worker server must overflow");
    assert!(!accepted.is_empty(), "the queue admits up to its bound");

    // every accepted request completes with real scores — nothing hangs
    for rx in accepted {
        let reply = rx.recv().expect("accepted requests are answered");
        assert_eq!(reply.result.expect("scored").len(), edges.len());
    }
    let st = server.stats();
    assert_eq!(st.rejected_overload.load(Ordering::Relaxed), rejected);
    server.shutdown();
}

/// The queue-rejection injection is deterministic: exactly the planned
/// request ordinal is refused `Overloaded`, everything else scores.
#[test]
fn injected_queue_rejection_refuses_exactly_the_planned_request() {
    let model = toy_model(49);
    let mut rng = Pcg32::seeded(50);
    let server =
        PredictServer::start_with_faults(model, config(1), FaultPlan::seeded(8).reject_request(2));
    for i in 1..=4u64 {
        let (sf, ef, edges) = request_data(&mut rng, 2, 2, 3);
        let got = server.predict_blocking(sf, ef, edges);
        if i == 2 {
            assert_eq!(got, Err(PredictError::Overloaded), "request {i} is the planned rejection");
        } else {
            assert_eq!(got.expect("scored").len(), 3, "request {i}");
        }
    }
    assert_eq!(server.stats().rejected_overload.load(Ordering::Relaxed), 1);
    server.shutdown();
}

/// Zero-downtime hot swap under concurrent traffic: no request is lost, every
/// reply's generation is the old or the new one (never torn — generation-0
/// replies are bitwise model A, generation-1 replies bitwise model B), and
/// the swapped server matches a fresh server on the new model bit for bit.
#[test]
fn hot_swap_under_traffic_loses_nothing_and_generations_are_never_torn() {
    for workers in WORKER_COUNTS {
        let model_a = toy_model(51);
        let model_b = toy_model(52); // same dims, different parameters
        let mut rng = Pcg32::seeded(53);
        let (sf, ef, edges) = request_data(&mut rng, 4, 3, 8);
        let expect_a = direct_predict(&model_a, &sf, &ef, &edges);
        let expect_b = direct_predict(&model_b, &sf, &ef, &edges);
        assert_ne!(expect_a, expect_b, "the two models must be distinguishable");

        let server = PredictServer::start(model_a, config(workers));
        let (senders, per_sender) = (3, 60);
        std::thread::scope(|scope| {
            for _ in 0..senders {
                let server = &server;
                let (sf, ef, edges) = (sf.clone(), ef.clone(), edges.clone());
                let (expect_a, expect_b) = (&expect_a, &expect_b);
                scope.spawn(move || {
                    for _ in 0..per_sender {
                        let reply = server
                            .predict_reply(sf.clone(), ef.clone(), edges.clone())
                            .expect("submitted");
                        let scores = reply.result.expect("no request may be lost in a swap");
                        match reply.generation {
                            0 => assert_eq!(&scores, expect_a, "generation 0 is model A"),
                            1 => assert_eq!(&scores, expect_b, "generation 1 is model B"),
                            g => panic!("impossible generation {g}"),
                        }
                    }
                });
            }
            // swap mid-traffic
            std::thread::sleep(std::time::Duration::from_millis(20));
            let generation = server
                .swap_model(TrainedModel::from_dual(model_b.clone(), 0.1))
                .expect("same-dims swap succeeds");
            assert_eq!(generation, 1);
        });

        let st = server.stats();
        assert_eq!(st.requests.load(Ordering::Relaxed), senders * per_sender);
        assert_eq!(st.generation.load(Ordering::Relaxed), 1);

        // post-swap, the live server is bitwise a fresh server on model B
        let swapped = server.predict_blocking(sf.clone(), ef.clone(), edges.clone()).unwrap();
        let fresh = PredictServer::start(model_b, config(workers));
        let fresh_scores = fresh.predict_blocking(sf, ef, edges).unwrap();
        assert_eq!(swapped, fresh_scores, "workers={workers}");
        assert_eq!(swapped, expect_b);
        fresh.shutdown();
        server.shutdown();
    }
}

/// A model with different feature dimensions can never be swapped in — the
/// merger validates requests against the dims fixed at startup.
#[test]
fn hot_swap_rejects_mismatched_feature_dims() {
    let server = PredictServer::start(toy_model(54), config(1));
    let mut rng = Pcg32::seeded(55);
    let (m, q, n) = (6, 5, 15);
    let wrong_dims = DualModel {
        dual_coef: rng.normal_vec(n),
        train_start_features: Matrix::from_fn(m, 4, |_, _| rng.normal()), // 4 ≠ 3
        train_end_features: Matrix::from_fn(q, 2, |_, _| rng.normal()),
        train_idx: KronIndex::new(
            (0..n).map(|_| rng.below(q) as u32).collect(),
            (0..n).map(|_| rng.below(m) as u32).collect(),
        ),
        kernel_d: KernelKind::Gaussian { gamma: 0.3 },
        kernel_t: KernelKind::Gaussian { gamma: 0.3 },
        pairwise: PairwiseKernelKind::Kronecker,
    };
    let err = server.swap_model(TrainedModel::from_dual(wrong_dims, 0.1)).unwrap_err();
    assert!(err.contains("hot-swap"), "{err}");
    assert_eq!(server.stats().generation.load(Ordering::Relaxed), 0, "generation unchanged");

    // the original model still serves
    let (sf, ef, edges) = request_data(&mut rng, 2, 2, 3);
    assert_eq!(server.predict_blocking(sf, ef, edges).unwrap().len(), 3);
    server.shutdown();
}
