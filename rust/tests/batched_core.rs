//! Integration tests for the batched compute core: the packed GEMM, the
//! multi-RHS GVT apply, block CG, and the batched training/prediction paths
//! built on them. The two properties everything rests on:
//!
//! 1. the packed GEMM equals the per-element `dot` reference **bitwise** at
//!    awkward shapes (1×1, primes, micro-kernel tails) for every thread
//!    count, and
//! 2. every column of a batched apply/solve/prediction equals the
//!    corresponding single-RHS computation **bitwise** across thread counts
//!    and both Algorithm-1 branches — so batching can never change a solver
//!    trajectory or a served score.

use std::sync::Arc;

use kronvt::gvt::{
    gvt_apply_into, gvt_apply_multi_into, Branch, EdgePlan, GvtEngine, GvtWorkspace, KronIndex,
    KronKernelOp,
};
use kronvt::linalg::gemm::{gemm_nn_into, gemm_nt_into, pack_transpose};
use kronvt::linalg::solvers::{block_cg, cg, SolverConfig};
use kronvt::linalg::vecops::dot;
use kronvt::linalg::Matrix;
use kronvt::train::{KronRidge, RidgeConfig};
use kronvt::util::rng::Pcg32;

/// Awkward GEMM shapes: degenerate, prime, and micro-kernel tail sizes
/// (m % 4, n % 4, k % 4 covering every remainder).
const GEMM_SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 7, 1),
    (3, 2, 5),
    (4, 4, 4),
    (5, 3, 9),
    (7, 13, 11),
    (8, 8, 6),
    (16, 64, 4),
    (31, 29, 37),
    (65, 70, 33),
];

fn dot_reference_nt(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut c = vec![0.0; m * n];
    for i in 0..m {
        for j in 0..n {
            c[i * n + j] = dot(&a[i * k..(i + 1) * k], &b[j * k..(j + 1) * k]);
        }
    }
    c
}

#[test]
fn packed_gemm_equals_reference_at_awkward_shapes() {
    let mut rng = Pcg32::seeded(0x6E44);
    for &(m, k, n) in GEMM_SHAPES {
        let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
        let b_nt: Vec<f64> = (0..n * k).map(|_| rng.normal()).collect();
        let b_nn = pack_transpose(&b_nt, n, k); // k×n row-major
        let reference = dot_reference_nt(&a, &b_nt, m, k, n);
        for threads in [1, 2, 4, 8] {
            let mut c_nt = vec![f64::NAN; m * n];
            gemm_nt_into(&a, &b_nt, m, k, n, &mut c_nt, threads);
            assert_eq!(c_nt, reference, "NT m={m} k={k} n={n} threads={threads}");
            let mut c_nn = vec![f64::NAN; m * n];
            gemm_nn_into(&a, &b_nn, m, k, n, &mut c_nn, threads);
            assert_eq!(c_nn, reference, "NN m={m} k={k} n={n} threads={threads}");
        }
    }
}

#[test]
fn packed_gemm_close_to_plain_triple_loop() {
    // Different association than the dot reduction → approximate, but tight.
    let mut rng = Pcg32::seeded(0x6E45);
    for &(m, k, n) in GEMM_SHAPES {
        let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
        let mut c = vec![0.0; m * n];
        gemm_nn_into(&a, &b, m, k, n, &mut c, 1);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a[i * k + kk] * b[kk * n + j];
                }
                assert!((c[i * n + j] - s).abs() < 1e-9, "({i},{j}): {} vs {s}", c[i * n + j]);
            }
        }
    }
}

#[allow(clippy::type_complexity)]
fn random_gvt_problem(
    seed: u64,
    a: usize,
    b: usize,
    c: usize,
    d: usize,
    e: usize,
    f: usize,
) -> (Matrix, Matrix, KronIndex, KronIndex) {
    let mut rng = Pcg32::seeded(seed);
    let m = Matrix::from_fn(a, b, |_, _| rng.normal());
    let n = Matrix::from_fn(c, d, |_, _| rng.normal());
    let rows = KronIndex::new(
        (0..f).map(|_| rng.below(a) as u32).collect(),
        (0..f).map(|_| rng.below(c) as u32).collect(),
    );
    let cols = KronIndex::new(
        (0..e).map(|_| rng.below(b) as u32).collect(),
        (0..e).map(|_| rng.below(d) as u32).collect(),
    );
    (m, n, rows, cols)
}

#[test]
fn multi_rhs_apply_bitwise_matches_single_per_column() {
    // Large enough to engage the parallel engine (e + f ≥ 2048), awkward
    // enough (k_rhs 1, 3, 8; zeros in v; both branches) to hit every path.
    let (a, b, c, d, e, f) = (7, 9, 6, 8, 2600, 2200);
    let (m, n, rows, cols) = random_gvt_problem(0xF00D, a, b, c, d, e, f);
    let m_t = m.transpose();
    let n_t = n.transpose();
    let plan_full = EdgePlan::build_full(&rows, &cols, a, b, c, d);
    let plan_plain = EdgePlan::build(&cols, b, d);
    let mut rng = Pcg32::seeded(0xF00E);
    for k_rhs in [1usize, 3, 8] {
        let mut v = rng.normal_vec(e * k_rhs);
        for (i, vi) in v.iter_mut().enumerate() {
            if i % 7 == 0 {
                *vi = 0.0;
            }
        }
        for branch in [None, Some(Branch::T), Some(Branch::S)] {
            // serial single-RHS reference, column by column
            let mut ws = GvtWorkspace::new();
            let mut reference = vec![0.0; f * k_rhs];
            for j in 0..k_rhs {
                let mut uj = vec![0.0; f];
                gvt_apply_into(
                    &m, &n, &m_t, &n_t, &rows, &cols, &v[j * e..(j + 1) * e], &mut uj, &mut ws,
                    branch,
                );
                reference[j * f..(j + 1) * f].copy_from_slice(&uj);
            }
            // serial multi
            let mut u = vec![f64::NAN; f * k_rhs];
            gvt_apply_multi_into(
                &m, &n, &m_t, &n_t, &rows, &cols, &v, &mut u, k_rhs, &mut ws, branch,
            );
            assert_eq!(u, reference, "serial multi k={k_rhs} branch={branch:?}");
            // engine, all thread counts, with and without output buckets
            for threads in [1, 2, 4, 8] {
                for plan in [&plan_full, &plan_plain] {
                    let mut u = vec![f64::NAN; f * k_rhs];
                    let mut ws2 = GvtWorkspace::new();
                    GvtEngine::new(threads).apply_planned_multi(
                        &m, &n, &m_t, &n_t, &rows, &cols, plan, &v, &mut u, k_rhs, &mut ws2,
                        branch,
                    );
                    assert_eq!(
                        u, reference,
                        "k={k_rhs} branch={branch:?} threads={threads} buckets={}",
                        plan.has_output_buckets()
                    );
                }
            }
        }
    }
}

fn random_kernel(rng: &mut Pcg32, n: usize) -> Matrix {
    let x = Matrix::from_fn(n, 3, |_, _| rng.normal());
    kronvt::kernels::KernelKind::Gaussian { gamma: 0.4 }.square_matrix(&x)
}

#[test]
fn block_cg_through_kernel_operator_bitwise_matches_cg() {
    // The multi-λ ridge workload: (Q + λ_j I) a_j = y through one batched
    // operator must reproduce each standalone CG solve bit for bit.
    let mut rng = Pcg32::seeded(0xAB1E);
    let (q, m_verts, n_edges) = (12, 11, 2600);
    let g = Arc::new(random_kernel(&mut rng, q));
    let k = Arc::new(random_kernel(&mut rng, m_verts));
    let idx = KronIndex::new(
        (0..n_edges).map(|_| rng.below(q) as u32).collect(),
        (0..n_edges).map(|_| rng.below(m_verts) as u32).collect(),
    );
    let y = rng.normal_vec(n_edges);
    let shifts = [0.25, 1.0, 4.0];
    let cfg = SolverConfig { max_iters: 30, tol: 1e-10 };
    for threads in [1, 4] {
        let op = KronKernelOp::new(g.clone(), k.clone(), idx.clone()).with_threads(threads);
        let mut b = vec![0.0; n_edges * shifts.len()];
        for bj in b.chunks_mut(n_edges) {
            bj.copy_from_slice(&y);
        }
        let mut x = vec![0.0; n_edges * shifts.len()];
        let stats = block_cg(&op, &shifts, &b, &mut x, &cfg);
        for (j, &lambda) in shifts.iter().enumerate() {
            let sys = kronvt::gvt::operator::RidgeSystemOp { op: &op, lambda };
            let mut x_single = vec![0.0; n_edges];
            let s = cg(&sys, &y, &mut x_single, &cfg);
            assert_eq!(
                &x[j * n_edges..(j + 1) * n_edges],
                x_single.as_slice(),
                "λ={lambda} threads={threads}"
            );
            assert_eq!(stats[j].iterations, s.iterations, "λ={lambda} threads={threads}");
        }
    }
}

#[test]
fn lambda_path_training_and_batched_prediction_match_singles() {
    // End to end: fit_path + predict_path over a λ grid give, per λ, the
    // same scores as training/predicting that λ through the same solver.
    let mut rng = Pcg32::seeded(0xCAB5);
    let (m_verts, q_verts, n_edges) = (15, 14, 120);
    let train = kronvt::data::Dataset {
        start_features: Matrix::from_fn(m_verts, 3, |_, _| rng.normal()),
        end_features: Matrix::from_fn(q_verts, 2, |_, _| rng.normal()),
        start_idx: (0..n_edges).map(|_| rng.below(m_verts) as u32).collect(),
        end_idx: (0..n_edges).map(|_| rng.below(q_verts) as u32).collect(),
        labels: (0..n_edges).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect(),
        name: "train".into(),
    };
    let test = kronvt::data::Dataset {
        start_features: Matrix::from_fn(6, 3, |_, _| rng.normal()),
        end_features: Matrix::from_fn(5, 2, |_, _| rng.normal()),
        start_idx: (0..20).map(|_| rng.below(6) as u32).collect(),
        end_idx: (0..20).map(|_| rng.below(5) as u32).collect(),
        labels: vec![0.0; 20],
        name: "test".into(),
    };
    let lambdas = [0.5, 2.0, 8.0];
    let cfg = RidgeConfig { iterations: 200, tol: 1e-12, ..Default::default() };
    let models = KronRidge::new(cfg).fit_path(&train, &lambdas).unwrap();
    let batched = kronvt::model::predict_path(&models, &test).unwrap();
    assert_eq!(batched.len(), lambdas.len());
    for (j, model) in models.iter().enumerate() {
        // batched prediction column == that model's own prediction, bitwise
        assert_eq!(batched[j], model.predict(&test), "λ={} prediction", lambdas[j]);
        // and the trained coefficients agree with the exact solve
        let exact = kronvt::train::ridge::ridge_exact_dual(
            &train,
            &RidgeConfig { lambda: lambdas[j], ..cfg },
            kronvt::gvt::PairwiseKernelKind::Kronecker,
        );
        kronvt::linalg::vecops::assert_allclose(&model.dual_coef, &exact, 1e-6, 1e-6);
    }
}
