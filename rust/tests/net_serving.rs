//! Networked-serving acceptance suite, all over real loopback TCP:
//!
//! * scores read back from the wire are **bitwise identical** to in-process
//!   [`PredictServer::predict_blocking`] — the JSON layer round-trips every
//!   `f64` exactly;
//! * the full typed-error taxonomy survives serialization: invalid
//!   requests, expired deadlines (including mid-flight expiry while a
//!   request is queued behind an injected straggler), overload, and a
//!   worker crash all come back as their wire error codes and map to the
//!   same [`PredictError`] a local caller would see;
//! * protocol edge cases answer errors without desynchronizing or killing
//!   the connection: oversized lines, invalid UTF-8, malformed JSON,
//!   non-object requests, truncated lines at disconnect; unknown request
//!   fields are ignored (forward compatibility);
//! * a 2-shard [`ShardRouter`] over two TCP listeners returns results
//!   bitwise identical to one unsharded server, and keeps serving (with an
//!   ejection) when one shard dies.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use kronvt::api::Compute;
use kronvt::coordinator::{
    FaultPlan, NetClient, NetServer, NetServerConfig, NetShard, PredictError, PredictServer,
    RouterStats, ServerConfig, ShardBackend, ShardRouter, ShardRouterConfig,
};
use kronvt::data::Dataset;
use kronvt::gvt::{KronIndex, PairwiseKernelKind};
use kronvt::kernels::KernelKind;
use kronvt::linalg::Matrix;
use kronvt::model::DualModel;
use kronvt::util::json::Json;
use kronvt::util::rng::Pcg32;

/// A tiny dual model built directly (no training) — deterministic scores,
/// instant setup.
fn toy_model(seed: u64) -> DualModel {
    let mut rng = Pcg32::seeded(seed);
    let (m, q, n) = (6, 5, 15);
    DualModel {
        dual_coef: rng.normal_vec(n),
        train_start_features: Matrix::from_fn(m, 3, |_, _| rng.normal()),
        train_end_features: Matrix::from_fn(q, 2, |_, _| rng.normal()),
        train_idx: KronIndex::new(
            (0..n).map(|_| rng.below(q) as u32).collect(),
            (0..n).map(|_| rng.below(m) as u32).collect(),
        ),
        kernel_d: KernelKind::Gaussian { gamma: 0.3 },
        kernel_t: KernelKind::Gaussian { gamma: 0.3 },
        pairwise: PairwiseKernelKind::Kronecker,
    }
}

fn request_data(
    rng: &mut Pcg32,
    u: usize,
    v: usize,
    t: usize,
) -> (Vec<Vec<f64>>, Vec<Vec<f64>>, Vec<(u32, u32)>) {
    let sf: Vec<Vec<f64>> = (0..u).map(|_| rng.normal_vec(3)).collect();
    let ef: Vec<Vec<f64>> = (0..v).map(|_| rng.normal_vec(2)).collect();
    let edges: Vec<(u32, u32)> =
        (0..t).map(|_| (rng.below(u) as u32, rng.below(v) as u32)).collect();
    (sf, ef, edges)
}

fn direct_predict(
    model: &DualModel,
    sf: &[Vec<f64>],
    ef: &[Vec<f64>],
    edges: &[(u32, u32)],
) -> Vec<f64> {
    let ds = Dataset {
        start_features: Matrix::from_fn(sf.len(), sf[0].len(), |i, j| sf[i][j]),
        end_features: Matrix::from_fn(ef.len(), ef[0].len(), |i, j| ef[i][j]),
        start_idx: edges.iter().map(|&(s, _)| s).collect(),
        end_idx: edges.iter().map(|&(_, e)| e).collect(),
        labels: vec![0.0; edges.len()],
        name: "direct".into(),
    };
    model.predict(&ds)
}

fn config(workers: usize) -> ServerConfig {
    ServerConfig { workers, compute: Compute::serial(), ..Default::default() }
}

/// Start a listener over a fresh server for `model`, on an OS-chosen port.
fn listen(model: DualModel, workers: usize) -> (Arc<PredictServer>, NetServer, String) {
    listen_with(model, config(workers), NetServerConfig::default(), FaultPlan::none())
}

fn listen_with(
    model: DualModel,
    cfg: ServerConfig,
    net_cfg: NetServerConfig,
    faults: FaultPlan,
) -> (Arc<PredictServer>, NetServer, String) {
    let server = Arc::new(PredictServer::start_with_faults(model, cfg, faults));
    let net = NetServer::start(server.clone(), net_cfg).expect("bind loopback");
    let addr = net.local_addr().to_string();
    (server, net, addr)
}

fn shutdown(server: Arc<PredictServer>, net: NetServer) {
    net.shutdown();
    if let Ok(server) = Arc::try_unwrap(server) {
        server.shutdown();
    }
}

// ---------------------------------------------------------------- scores

/// Concurrent clients over real TCP read back exactly the bytes the model
/// produces: every score bitwise-equal to the in-process path, every reply
/// id-matched under pipelining.
#[test]
fn wire_scores_bitwise_identical_to_in_process() {
    let model = toy_model(11);
    let (server, net, addr) = listen(model.clone(), 2);
    std::thread::scope(|scope| {
        for c in 0..4u64 {
            let (addr, model, server) = (&addr, &model, &server);
            scope.spawn(move || {
                let mut rng = Pcg32::seeded(100 + c);
                let mut client = NetClient::connect(addr).expect("connect");
                for _ in 0..10 {
                    let (sf, ef, edges) = request_data(&mut rng, 4, 4, 9);
                    let expected = direct_predict(model, &sf, &ef, &edges);
                    let wire = client
                        .predict(&sf, &ef, &edges, None)
                        .expect("transport")
                        .result
                        .expect("scored");
                    assert_eq!(wire, expected, "wire scores must be bitwise identical");
                    let local = server
                        .predict_blocking(sf, ef, edges)
                        .expect("in-process path");
                    assert_eq!(wire, local);
                }
            });
        }
    });
    assert_eq!(net.stats().bad_lines.load(Ordering::SeqCst), 0);
    assert_eq!(net.stats().connections.load(Ordering::SeqCst), 4);
    shutdown(server, net);
}

// ----------------------------------------------------------- typed errors

/// Invalid request, expired deadline, and injected overload all round-trip
/// the wire as their error codes and map back to the exact
/// [`PredictError`] variants, on one connection, without desynchronizing
/// the reply stream.
#[test]
fn typed_errors_round_trip_the_wire() {
    let model = toy_model(21);
    // The 3rd admitted request trips the injected queue rejection.
    let (server, net, addr) = listen_with(
        model.clone(),
        config(1),
        NetServerConfig::default(),
        FaultPlan::seeded(7).reject_request(3),
    );
    let mut rng = Pcg32::seeded(22);
    let (sf, ef, edges) = request_data(&mut rng, 3, 3, 6);
    let mut client = NetClient::connect(&addr).expect("connect");

    // 1: an edge referencing a vertex the request does not carry.
    let mut bad_edges = edges.clone();
    bad_edges[0].0 = 99;
    let reply = client.predict(&sf, &ef, &bad_edges, None).expect("transport");
    assert!(matches!(reply.result, Err(PredictError::InvalidRequest(_))), "{:?}", reply.result);

    // 2: an already-expired deadline.
    let reply = client.predict(&sf, &ef, &edges, Some(0)).expect("transport");
    assert_eq!(reply.result, Err(PredictError::DeadlineExceeded));

    // 3: the injected queue rejection — overload.
    let reply = client.predict(&sf, &ef, &edges, None).expect("transport");
    assert_eq!(reply.result, Err(PredictError::Overloaded));

    // 4: same connection, same data — scored and bitwise-correct.
    let reply = client.predict(&sf, &ef, &edges, None).expect("transport");
    assert_eq!(reply.result.expect("scored"), direct_predict(&model, &sf, &ef, &edges));

    // Retryability is visible on the wire itself.
    let raw = kronvt::coordinator::net::encode_request(77, &sf, &ef, &edges, Some(0))
        .dump()
        .unwrap();
    client.send_raw(&raw).expect("send");
    let v = client.recv_json(5_000).expect("response");
    let err = v.get("error").expect("error object");
    assert_eq!(err.get("code").and_then(Json::as_str), Some("deadline_exceeded"));
    assert_eq!(err.get("retryable"), Some(&Json::Bool(true)));
    assert_eq!(v.get("id").and_then(Json::as_u64), Some(77));

    shutdown(server, net);
}

/// A scoring-worker crash mid-batch surfaces as `shutting_down` on the
/// wire (retryable), and the connection + respawned worker keep serving.
#[test]
fn worker_crash_round_trips_as_shutting_down() {
    let model = toy_model(31);
    let (server, net, addr) = listen_with(
        model.clone(),
        config(1),
        NetServerConfig::default(),
        FaultPlan::seeded(9).panic_on_batch(1),
    );
    let mut rng = Pcg32::seeded(32);
    let (sf, ef, edges) = request_data(&mut rng, 3, 3, 6);
    let mut client = NetClient::connect(&addr).expect("connect");

    let reply = client.predict(&sf, &ef, &edges, Some(10_000)).expect("transport");
    assert_eq!(reply.result, Err(PredictError::ShuttingDown), "crashed batch's casualty");

    let reply = client.predict(&sf, &ef, &edges, Some(10_000)).expect("transport");
    assert_eq!(
        reply.result.expect("respawned worker scores"),
        direct_predict(&model, &sf, &ef, &edges)
    );
    assert_eq!(server.stats().respawns.load(Ordering::Relaxed), 1);
    shutdown(server, net);
}

/// A request that is valid at admission but expires while queued behind an
/// injected straggler answers `deadline_exceeded` over the socket — the
/// mid-flight expiry path, not the admission-time one.
#[test]
fn deadline_expires_mid_flight_over_the_socket() {
    let model = toy_model(41);
    let (server, net, addr) = listen_with(
        model,
        config(1),
        NetServerConfig::default(),
        FaultPlan::seeded(3).sleep_on_batch(1, 400),
    );
    let mut rng = Pcg32::seeded(42);
    let (sf, ef, edges) = request_data(&mut rng, 3, 3, 6);
    let mut client = NetClient::connect(&addr).expect("connect");
    let reply = client.predict(&sf, &ef, &edges, Some(50)).expect("transport");
    assert_eq!(reply.result, Err(PredictError::DeadlineExceeded));
    assert!(server.stats().shed.load(Ordering::Relaxed) >= 1, "expired work shed unscored");
    shutdown(server, net);
}

// -------------------------------------------------------- protocol edges

/// Malformed lines answer `bad_request` without desynchronizing the
/// stream; unknown fields are ignored; `op: info` reports feature dims.
#[test]
fn malformed_lines_answer_bad_request_and_connection_survives() {
    let model = toy_model(51);
    let (server, net, addr) = listen(model.clone(), 1);
    let mut client = NetClient::connect(&addr).expect("connect");
    let expect_code = |client: &mut NetClient, code: &str| {
        let v = client.recv_json(5_000).expect("response");
        assert_eq!(
            v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some(code),
            "full response: {v}"
        );
        assert_eq!(v.get("id"), Some(&Json::Null), "unattributable lines echo a null id");
    };

    client.send_raw("this is not json").expect("send");
    expect_code(&mut client, "bad_request");

    client.send_raw("[1, 2, 3]").expect("send");
    expect_code(&mut client, "bad_request");

    client.send_bytes(b"{\"id\": 1, \"rows\": \xff\xfe}\n").expect("send");
    expect_code(&mut client, "bad_request");

    // Structurally wrong but attributable: typed invalid_request, id echoed.
    client.send_raw(r#"{"id": 8, "rows": 3, "cols": [], "edges": []}"#).expect("send");
    let v = client.recv_json(5_000).expect("response");
    assert_eq!(
        v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
        Some("invalid_request")
    );
    assert_eq!(v.get("id").and_then(Json::as_u64), Some(8));

    client.send_raw(r#"{"id": 9, "op": "frobnicate"}"#).expect("send");
    let v = client.recv_json(5_000).expect("response");
    assert_eq!(
        v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
        Some("invalid_request")
    );

    // Unknown fields are ignored (forward compatibility), request scores.
    let mut rng = Pcg32::seeded(52);
    let (sf, ef, edges) = request_data(&mut rng, 3, 3, 5);
    let mut v = kronvt::coordinator::net::encode_request(10, &sf, &ef, &edges, None);
    if let Json::Obj(map) = &mut v {
        map.insert("future_knob".into(), Json::from("ignored"));
        map.insert("priority".into(), Json::from(3usize));
    }
    client.send_raw(&v.dump().unwrap()).expect("send");
    let v = client.recv_json(5_000).expect("response");
    let scores: Vec<f64> =
        v.get("scores").and_then(Json::as_arr).expect("scored").iter().filter_map(Json::as_f64).collect();
    assert_eq!(scores, direct_predict(&model, &sf, &ef, &edges));

    // op info: dims over the wire.
    let (dims, generation) = client.info().expect("info");
    assert_eq!(dims, (3, 2));
    assert_eq!(generation, 0);

    assert!(net.stats().bad_lines.load(Ordering::SeqCst) >= 3);
    shutdown(server, net);
}

/// An oversized line is rejected and discarded through its newline; the
/// same connection then serves a normal request.
#[test]
fn oversized_line_is_rejected_and_stream_resyncs() {
    let model = toy_model(61);
    let (server, net, addr) = listen_with(
        model.clone(),
        config(1),
        NetServerConfig { max_line_bytes: 1024, ..Default::default() },
        FaultPlan::none(),
    );
    let mut client = NetClient::connect(&addr).expect("connect");
    let huge = format!("{{\"id\": 1, \"rows\": \"{}\"}}", "x".repeat(8 * 1024));
    client.send_raw(&huge).expect("send");
    let v = client.recv_json(5_000).expect("response");
    assert_eq!(
        v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
        Some("bad_request")
    );

    let mut rng = Pcg32::seeded(62);
    let (sf, ef, edges) = request_data(&mut rng, 3, 3, 5);
    let reply = client.predict(&sf, &ef, &edges, None).expect("transport");
    assert_eq!(reply.result.expect("resynced"), direct_predict(&model, &sf, &ef, &edges));
    shutdown(server, net);
}

/// A connection dropped mid-line is counted as a truncated bad line and
/// does not disturb other connections.
#[test]
fn truncated_line_at_disconnect_is_counted_not_fatal() {
    let model = toy_model(71);
    let (server, net, addr) = listen(model.clone(), 1);
    {
        let mut client = NetClient::connect(&addr).expect("connect");
        client.send_bytes(b"{\"id\": 1, \"rows\": [[0.1, 0.2").expect("send partial");
        // dropped here: no newline ever arrives
    }
    // The reader notices EOF within a poll tick or two.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while net.stats().bad_lines.load(Ordering::SeqCst) == 0 {
        assert!(std::time::Instant::now() < deadline, "truncated line never counted");
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    // A fresh connection is unaffected.
    let mut rng = Pcg32::seeded(72);
    let (sf, ef, edges) = request_data(&mut rng, 3, 3, 5);
    let mut client = NetClient::connect(&addr).expect("connect");
    let reply = client.predict(&sf, &ef, &edges, None).expect("transport");
    assert_eq!(reply.result.expect("scored"), direct_predict(&model, &sf, &ef, &edges));
    shutdown(server, net);
}

// -------------------------------------------------------------- sharding

fn router_over(addrs: &[String], cfg: ShardRouterConfig) -> ShardRouter {
    let backends: Vec<Box<dyn ShardBackend>> =
        addrs.iter().map(|a| Box::new(NetShard::new(a)) as Box<dyn ShardBackend>).collect();
    ShardRouter::new(backends, cfg).expect("router")
}

/// A 2-shard router over two TCP listeners returns bitwise-identical
/// results to a single unsharded server — scatter/merge preserves request
/// order and per-edge scores exactly.
#[test]
fn two_shard_router_matches_unsharded_server() {
    let model = toy_model(81);
    let (server_a, net_a, addr_a) = listen(model.clone(), 1);
    let (server_b, net_b, addr_b) = listen(model.clone(), 1);
    let reference = PredictServer::start(model, config(2));
    let router = router_over(&[addr_a, addr_b], ShardRouterConfig::default());

    let mut rng = Pcg32::seeded(82);
    for _ in 0..6 {
        // 16 distinct start vertices: both shards essentially certainly
        // receive traffic (fixed deterministic hash).
        let (sf, ef, edges) = request_data(&mut rng, 16, 6, 40);
        let routed = router.predict(&sf, &ef, &edges, None).expect("routable");
        let unsharded = reference
            .predict_blocking(sf, ef, edges)
            .expect("reference path");
        assert_eq!(routed.result.expect("scored"), unsharded, "sharded == unsharded, bitwise");
    }
    let st: &RouterStats = router.stats();
    assert!(st.scattered.load(Ordering::SeqCst) >= 1, "batches spanned both shards");
    assert_eq!(st.shard_failures.load(Ordering::SeqCst), 0);
    reference.shutdown();
    shutdown(server_a, net_a);
    shutdown(server_b, net_b);
}

/// Shard loss: when one of two shards dies, the router charges its health,
/// ejects it, and every batch still returns complete, correct scores via
/// the survivor.
#[test]
fn router_ejects_dead_shard_and_traffic_continues() {
    let model = toy_model(91);
    let (server_a, net_a, addr_a) = listen(model.clone(), 1);
    let (server_b, net_b, addr_b) = listen(model.clone(), 1);
    let reference = PredictServer::start(model, config(2));
    let router = router_over(
        &[addr_a, addr_b],
        ShardRouterConfig { eject_after: 1, probe_cooldown_ms: 60_000 },
    );

    let mut rng = Pcg32::seeded(92);
    let (sf, ef, edges) = request_data(&mut rng, 16, 6, 40);
    let expected = reference
        .predict_blocking(sf.clone(), ef.clone(), edges.clone())
        .expect("reference path");

    // Healthy warm-up: both shards serving.
    let routed = router.predict(&sf, &ef, &edges, None).expect("routable");
    assert_eq!(routed.result.expect("scored"), expected);
    assert_eq!(router.healthy_count(), 2);

    // Kill shard B entirely (listener and server).
    shutdown(server_b, net_b);

    for _ in 0..3 {
        let routed = router.predict(&sf, &ef, &edges, None).expect("survivor carries traffic");
        assert_eq!(routed.result.expect("scored"), expected, "still complete and bitwise-equal");
    }
    assert_eq!(router.stats().ejections.load(Ordering::SeqCst), 1, "dead shard ejected");
    assert_eq!(router.healthy_count(), 1);
    assert!(router.stats().shard_failures.load(Ordering::SeqCst) >= 1);

    reference.shutdown();
    shutdown(server_a, net_a);
}
