//! Integration tests for the parallel GVT execution engine: serial/parallel
//! equivalence across branches, thread counts, sparsity patterns and
//! degenerate shapes, determinism of repeated applies, and cross-thread
//! sharing of the `Sync` operators.

use std::sync::Arc;

use kronvt::gvt::{
    gvt_apply_into, gvt_apply_into_parallel, Branch, EdgePlan, GvtEngine, GvtWorkspace,
    KronIndex, KronKernelOp, KronPredictOp,
};
use kronvt::kernels::KernelKind;
use kronvt::linalg::solvers::LinOp;
use kronvt::linalg::vecops::assert_allclose;
use kronvt::linalg::Matrix;
use kronvt::util::rng::Pcg32;

const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 8];

struct Problem {
    m: Matrix,
    n: Matrix,
    m_t: Matrix,
    n_t: Matrix,
    rows: KronIndex,
    cols: KronIndex,
    v: Vec<f64>,
}

impl Problem {
    fn random(seed: u64, a: usize, b: usize, c: usize, d: usize, e: usize, f: usize) -> Problem {
        let mut rng = Pcg32::seeded(seed);
        let m = Matrix::from_fn(a, b, |_, _| rng.normal());
        let n = Matrix::from_fn(c, d, |_, _| rng.normal());
        let rows = KronIndex::new(
            (0..f).map(|_| rng.below(a) as u32).collect(),
            (0..f).map(|_| rng.below(c) as u32).collect(),
        );
        let cols = KronIndex::new(
            (0..e).map(|_| rng.below(b) as u32).collect(),
            (0..e).map(|_| rng.below(d) as u32).collect(),
        );
        let v = rng.normal_vec(e);
        Problem { m_t: m.transpose(), n_t: n.transpose(), m, n, rows, cols, v }
    }

    fn serial(&self, branch: Option<Branch>) -> Vec<f64> {
        let mut u = vec![0.0; self.rows.len()];
        let mut ws = GvtWorkspace::new();
        gvt_apply_into(
            &self.m, &self.n, &self.m_t, &self.n_t, &self.rows, &self.cols, &self.v, &mut u,
            &mut ws, branch,
        );
        u
    }

    fn parallel(&self, branch: Option<Branch>, threads: usize) -> Vec<f64> {
        let mut u = vec![0.0; self.rows.len()];
        let mut ws = GvtWorkspace::new();
        gvt_apply_into_parallel(
            &self.m, &self.n, &self.m_t, &self.n_t, &self.rows, &self.cols, &self.v, &mut u,
            &mut ws, branch, threads,
        );
        u
    }
}

#[test]
fn parallel_matches_serial_both_branches_all_thread_counts() {
    // Large enough (e + f ≥ 2048) that the engine actually shards.
    let p = Problem::random(9000, 15, 11, 9, 13, 4096, 3000);
    for branch in [Branch::T, Branch::S] {
        let serial = p.serial(Some(branch));
        for threads in THREAD_COUNTS {
            let par = p.parallel(Some(branch), threads);
            // acceptance bound 1e-10; in fact bitwise identical
            assert_allclose(&par, &serial, 1e-10, 1e-10);
            assert_eq!(par, serial, "branch {branch:?} threads {threads}");
        }
    }
    // auto branch selection too
    let serial = p.serial(None);
    for threads in THREAD_COUNTS {
        assert_eq!(p.parallel(None, threads), serial);
    }
}

#[test]
fn parallel_matches_serial_with_sparse_v() {
    let mut p = Problem::random(9001, 10, 10, 10, 10, 5000, 5000);
    for (l, vl) in p.v.iter_mut().enumerate() {
        if l % 5 != 0 {
            *vl = 0.0; // 80% zeros — the eq. (5) sparse shortcut path
        }
    }
    for branch in [Some(Branch::T), Some(Branch::S), None] {
        let serial = p.serial(branch);
        for threads in THREAD_COUNTS {
            assert_eq!(p.parallel(branch, threads), serial, "branch {branch:?}");
        }
    }
}

#[test]
fn degenerate_shapes_e1_f1_and_unit_dims() {
    // e = 1 (a single column edge), f = 1 (a single output edge), and
    // 1×1 factor matrices. All far below the parallel threshold, so the
    // engine must fall back to serial without panicking; the convenience
    // wrapper still goes through plan construction.
    for &(a, b, c, d, e, f) in
        &[(3usize, 4usize, 5usize, 2usize, 1usize, 7usize), (3, 4, 5, 2, 7, 1), (1, 1, 1, 1, 1, 1)]
    {
        let p = Problem::random(9002 + (a + e + f) as u64, a, b, c, d, e, f);
        for branch in [Some(Branch::T), Some(Branch::S), None] {
            let serial = p.serial(branch);
            for threads in THREAD_COUNTS {
                assert_eq!(p.parallel(branch, threads), serial);
            }
        }
    }
}

#[test]
fn empty_bucket_rows_are_handled() {
    // Concentrate all column indices on a handful of rows so most stage-1
    // buckets are empty; workers owning empty rows must still zero them.
    let mut rng = Pcg32::seeded(9003);
    let (a, b, c, d, e, f) = (8, 40, 8, 40, 3000, 3000);
    let m = Matrix::from_fn(a, b, |_, _| rng.normal());
    let n = Matrix::from_fn(c, d, |_, _| rng.normal());
    let rows = KronIndex::new(
        (0..f).map(|_| rng.below(a) as u32).collect(),
        (0..f).map(|_| rng.below(c) as u32).collect(),
    );
    // only 2 of 40 possible left values / 3 of 40 right values occur
    let cols = KronIndex::new(
        (0..e).map(|_| [0u32, 39][rng.below(2)]).collect(),
        (0..e).map(|_| [5u32, 6, 38][rng.below(3)]).collect(),
    );
    let v = rng.normal_vec(e);
    let p = Problem { m_t: m.transpose(), n_t: n.transpose(), m, n, rows, cols, v };
    for branch in [Some(Branch::T), Some(Branch::S)] {
        let serial = p.serial(branch);
        for threads in THREAD_COUNTS {
            assert_eq!(p.parallel(branch, threads), serial);
        }
    }
}

#[test]
fn repeated_parallel_applies_are_deterministic() {
    // Same plan + workspace reused across applies: results must be
    // identical run over run (solver convergence depends on this).
    let p = Problem::random(9004, 12, 14, 13, 11, 6000, 5500);
    let plan = EdgePlan::build(&p.cols, p.m.cols(), p.n.cols());
    let engine = GvtEngine::new(4);
    let mut ws = GvtWorkspace::new();
    let mut first = vec![0.0; p.rows.len()];
    engine.apply_planned(
        &p.m, &p.n, &p.m_t, &p.n_t, &p.rows, &p.cols, &plan, &p.v, &mut first, &mut ws, None,
    );
    for _ in 0..5 {
        let mut again = vec![0.0; p.rows.len()];
        engine.apply_planned(
            &p.m, &p.n, &p.m_t, &p.n_t, &p.rows, &p.cols, &plan, &p.v, &mut again, &mut ws, None,
        );
        assert_eq!(again, first);
    }
}

fn toy_kernel(rng: &mut Pcg32, n: usize) -> Matrix {
    let x = Matrix::from_fn(n, 3, |_, _| rng.normal());
    KernelKind::Gaussian { gamma: 0.4 }.square_matrix(&x)
}

#[test]
fn kernel_operator_threads_knob_is_transparent() {
    let mut rng = Pcg32::seeded(9005);
    let (q, m, n) = (30, 25, 4000);
    let g = Arc::new(toy_kernel(&mut rng, q));
    let k = Arc::new(toy_kernel(&mut rng, m));
    let idx = KronIndex::new(
        (0..n).map(|_| rng.below(q) as u32).collect(),
        (0..n).map(|_| rng.below(m) as u32).collect(),
    );
    let v = rng.normal_vec(n);
    let baseline = KronKernelOp::new(g.clone(), k.clone(), idx.clone()).apply_vec(&v);
    for threads in THREAD_COUNTS {
        let op = KronKernelOp::new(g.clone(), k.clone(), idx.clone()).with_threads(threads);
        assert_eq!(op.apply_vec(&v), baseline, "threads={threads}");
        // forced branches through the operator too
        for branch in [Branch::T, Branch::S] {
            let forced = KronKernelOp::new(g.clone(), k.clone(), idx.clone())
                .with_branch(branch)
                .with_threads(threads);
            let serial_forced =
                KronKernelOp::new(g.clone(), k.clone(), idx.clone()).with_branch(branch);
            assert_eq!(forced.apply_vec(&v), serial_forced.apply_vec(&v));
        }
    }
}

#[test]
fn predict_operator_threads_knob_is_transparent() {
    let mut rng = Pcg32::seeded(9006);
    let (q, m, n) = (20, 20, 2500);
    let (v_test, u_test, t_test) = (15, 15, 2500);
    let train_idx = KronIndex::new(
        (0..n).map(|_| rng.below(q) as u32).collect(),
        (0..n).map(|_| rng.below(m) as u32).collect(),
    );
    let test_idx = KronIndex::new(
        (0..t_test).map(|_| rng.below(v_test) as u32).collect(),
        (0..t_test).map(|_| rng.below(u_test) as u32).collect(),
    );
    let ghat = Matrix::from_fn(v_test, q, |_, _| rng.normal());
    let khat = Matrix::from_fn(u_test, m, |_, _| rng.normal());
    let mut a = rng.normal_vec(n);
    for (i, ai) in a.iter_mut().enumerate() {
        if i % 3 == 0 {
            *ai = 0.0; // sparse dual coefficients
        }
    }
    let baseline =
        KronPredictOp::new(ghat.clone(), khat.clone(), test_idx.clone(), train_idx.clone())
            .predict(&a);
    for threads in THREAD_COUNTS {
        let op = KronPredictOp::new(ghat.clone(), khat.clone(), test_idx.clone(), train_idx.clone())
            .with_threads(threads);
        assert_eq!(op.predict(&a), baseline, "threads={threads}");
    }
}

#[test]
fn one_shared_operator_across_many_threads() {
    // The refactored operators are Sync: a single trained operator can be
    // applied concurrently from many threads (each apply may itself be
    // multi-threaded) without locks around the caller.
    let mut rng = Pcg32::seeded(9007);
    let (q, m, n) = (18, 18, 3000);
    let g = Arc::new(toy_kernel(&mut rng, q));
    let k = Arc::new(toy_kernel(&mut rng, m));
    let idx = KronIndex::new(
        (0..n).map(|_| rng.below(q) as u32).collect(),
        (0..n).map(|_| rng.below(m) as u32).collect(),
    );
    let op = Arc::new(KronKernelOp::new(g, k, idx).with_threads(2));
    let inputs: Vec<Vec<f64>> = (0..8).map(|_| rng.normal_vec(n)).collect();
    let expected: Vec<Vec<f64>> = inputs.iter().map(|v| op.apply_vec(v)).collect();
    let got: Vec<Vec<f64>> = std::thread::scope(|scope| {
        let handles: Vec<_> = inputs
            .iter()
            .map(|v| {
                let op = Arc::clone(&op);
                scope.spawn(move || op.apply_vec(v))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (g_out, e_out) in got.iter().zip(&expected) {
        assert_eq!(g_out, e_out);
    }
}
