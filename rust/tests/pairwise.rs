//! Integration tests for the pairwise kernel operator family: bitwise
//! equivalence of `PairwiseOp::Kronecker` with the legacy single-kernel
//! operators (single- and multi-RHS, all thread counts), symmetry /
//! anti-symmetry invariants under edge-orientation swaps, Cartesian δ
//! semantics, and the end-to-end train → predict → serve path on the
//! homogeneous-graph generator.

use std::sync::Arc;

use kronvt::api::Compute;
use kronvt::coordinator::{PredictServer, ServerConfig};
use kronvt::data::checkerboard::HomogeneousConfig;
use kronvt::data::Dataset;
use kronvt::eval::auc::auc;
use kronvt::gvt::{KronIndex, KronKernelOp, KronPredictOp, PairwiseKernelKind, PairwiseOp};
use kronvt::kernels::KernelKind;
use kronvt::linalg::vecops::assert_allclose;
use kronvt::linalg::Matrix;
use kronvt::train::{KronRidge, KronSvm, RidgeConfig, SvmConfig};
use kronvt::util::rng::Pcg32;

fn random_kernel(rng: &mut Pcg32, n: usize) -> Matrix {
    let x = Matrix::from_fn(n, 3, |_, _| rng.normal());
    KernelKind::Gaussian { gamma: 0.4 }.square_matrix(&x)
}

fn random_edges(rng: &mut Pcg32, q: usize, m: usize, n_edges: usize) -> KronIndex {
    KronIndex::new(
        (0..n_edges).map(|_| rng.below(q) as u32).collect(),
        (0..n_edges).map(|_| rng.below(m) as u32).collect(),
    )
}

/// Property: `PairwiseOp::Kronecker` is **bitwise identical** to the
/// pre-family `KronKernelOp` apply — single- and multi-RHS, every thread
/// count, problem large enough to engage the parallel engine path.
#[test]
fn kronecker_training_is_bitwise_identical_to_legacy_operator() {
    let mut rng = Pcg32::seeded(900);
    let (q, m, n) = (24, 20, 3000);
    let g = Arc::new(random_kernel(&mut rng, q));
    let k = Arc::new(random_kernel(&mut rng, m));
    let idx = random_edges(&mut rng, q, m, n);
    let k_rhs = 3;
    let mut v = rng.normal_vec(n * k_rhs);
    for (i, vi) in v.iter_mut().enumerate() {
        if i % 7 == 0 {
            *vi = 0.0; // exercise the zero-skip in both operators
        }
    }
    for threads in [1, 2, 4] {
        let legacy =
            KronKernelOp::new(g.clone(), k.clone(), idx.clone()).with_threads(threads);
        let pairwise = PairwiseOp::training(
            PairwiseKernelKind::Kronecker,
            g.clone(),
            k.clone(),
            None,
            None,
            idx.clone(),
        )
        .unwrap()
        .with_threads(threads);
        // single-RHS
        let mut a = vec![0.0; n];
        let mut b = vec![0.0; n];
        legacy.apply_into(&v[..n], &mut a);
        pairwise.apply_into(&v[..n], &mut b);
        assert_eq!(a, b, "single-RHS, threads={threads}");
        // multi-RHS
        let mut am = vec![0.0; n * k_rhs];
        let mut bm = vec![0.0; n * k_rhs];
        legacy.apply_multi_into(&v, k_rhs, &mut am);
        pairwise.apply_multi_into(&v, k_rhs, &mut bm);
        assert_eq!(am, bm, "multi-RHS, threads={threads}");
    }
}

/// Property: the Kronecker prediction path is bitwise identical to
/// `KronPredictOp` — single and multi-RHS, serial and threaded.
#[test]
fn kronecker_prediction_is_bitwise_identical_to_legacy_operator() {
    let mut rng = Pcg32::seeded(901);
    let (q, m, n) = (12, 10, 2600);
    let (v_test, u_test, t_test) = (8, 9, 2400);
    let train_idx = random_edges(&mut rng, q, m, n);
    let test_idx = random_edges(&mut rng, v_test, u_test, t_test);
    let ghat = Matrix::from_fn(v_test, q, |_, _| rng.normal());
    let khat = Matrix::from_fn(u_test, m, |_, _| rng.normal());
    let k_rhs = 3;
    let duals = rng.normal_vec(n * k_rhs);
    for threads in [1, 2, 4] {
        let legacy =
            KronPredictOp::new(ghat.clone(), khat.clone(), test_idx.clone(), train_idx.clone())
                .with_threads(threads);
        let pairwise = PairwiseOp::prediction(
            PairwiseKernelKind::Kronecker,
            ghat.clone(),
            khat.clone(),
            None,
            None,
            test_idx.clone(),
            train_idx.clone(),
        )
        .unwrap()
        .with_threads(threads);
        assert_eq!(
            legacy.predict(&duals[..n]),
            pairwise.predict(&duals[..n]),
            "predict, threads={threads}"
        );
        assert_eq!(
            legacy.predict_multi(&duals, k_rhs),
            pairwise.predict_multi(&duals, k_rhs),
            "predict_multi, threads={threads}"
        );
    }
}

/// Property: the symmetric kernel operator is invariant under swapping any
/// edge's vertex order — as a materialized matrix (bitwise: products and the
/// two-term sum commute) and through the fast GVT path (tightly allclose:
/// stage accumulation orders differ).
#[test]
fn symmetric_training_is_invariant_under_edge_orientation_swap() {
    let mut rng = Pcg32::seeded(902);
    let (nv, n) = (10, 40);
    let kmat = Arc::new(random_kernel(&mut rng, nv));
    let idx = random_edges(&mut rng, nv, nv, n);
    // swap the orientation of every third edge
    let mut left = idx.left.clone();
    let mut right = idx.right.clone();
    for h in (0..n).step_by(3) {
        std::mem::swap(&mut left[h], &mut right[h]);
    }
    let swapped_idx = KronIndex::new(left, right);

    let op = PairwiseOp::training(
        PairwiseKernelKind::SymmetricKron,
        kmat.clone(),
        kmat.clone(),
        Some(kmat.clone()),
        None,
        idx,
    )
    .unwrap();
    let op_swapped = PairwiseOp::training(
        PairwiseKernelKind::SymmetricKron,
        kmat.clone(),
        kmat.clone(),
        Some(kmat.clone()),
        None,
        swapped_idx,
    )
    .unwrap();

    // the materialized matrices agree bit for bit
    let (dense, dense_swapped) = (op.explicit_dense(), op_swapped.explicit_dense());
    assert_eq!(dense.data(), dense_swapped.data());

    // and the matrix-free applies agree to accumulation-order noise
    let v = rng.normal_vec(n);
    let mut a = vec![0.0; n];
    let mut b = vec![0.0; n];
    op.apply_into(&v, &mut a);
    op_swapped.apply_into(&v, &mut b);
    assert_allclose(&a, &b, 1e-12, 1e-12);
}

/// Property: swapping one *output* edge's orientation negates the
/// anti-symmetric kernel's row (ranking semantics: reversing a directed
/// pair flips its score).
#[test]
fn antisymmetric_prediction_negates_under_orientation_swap() {
    let mut rng = Pcg32::seeded(903);
    let (nv, n) = (9, 30);
    let (tv, t) = (5, 12);
    let train_features = Matrix::from_fn(nv, 3, |_, _| rng.normal());
    let test_features = Matrix::from_fn(tv, 3, |_, _| rng.normal());
    let train_idx = random_edges(&mut rng, nv, nv, n);
    let test_idx = random_edges(&mut rng, tv, tv, t);
    let swapped_test = KronIndex::new(test_idx.right.clone(), test_idx.left.clone());
    let kernel = KernelKind::Gaussian { gamma: 0.3 };
    let a = rng.normal_vec(n);

    let build = |tidx: KronIndex| {
        PairwiseOp::prediction_from_features(
            PairwiseKernelKind::AntiSymmetricKron,
            kernel,
            kernel,
            &test_features,
            &test_features,
            &train_features,
            &train_features,
            tidx,
            train_idx.clone(),
            1,
        )
        .unwrap()
    };
    let straight = build(test_idx).predict(&a);
    let reversed = build(swapped_test).predict(&a);
    let negated: Vec<f64> = reversed.iter().map(|s| -s).collect();
    assert_allclose(&straight, &negated, 1e-12, 1e-12);
}

/// The Cartesian kernel's δ factors do not extend to novel vertices: fully
/// zero-shot scores are identically zero, while scoring the training edges
/// themselves (shared vertices) is non-trivial and matches the explicit
/// decision function.
#[test]
fn cartesian_delta_semantics_in_and_out_of_sample() {
    let mut rng = Pcg32::seeded(904);
    let (nv, n) = (8, 24);
    let features = Matrix::from_fn(nv, 2, |_, _| rng.normal());
    let train_idx = random_edges(&mut rng, nv, nv, n);
    let model = kronvt::model::DualModel {
        dual_coef: rng.normal_vec(n),
        train_start_features: features.clone(),
        train_end_features: features.clone(),
        train_idx: train_idx.clone(),
        kernel_d: KernelKind::Gaussian { gamma: 0.5 },
        kernel_t: KernelKind::Gaussian { gamma: 0.5 },
        pairwise: PairwiseKernelKind::Cartesian,
    };
    // in-sample: score the training edges themselves
    let in_sample = Dataset {
        start_features: features.clone(),
        end_features: features,
        start_idx: train_idx.right.clone(),
        end_idx: train_idx.left.clone(),
        labels: vec![0.0; n],
        name: "in-sample".into(),
    };
    let scores = model.predict(&in_sample);
    assert!(scores.iter().any(|&s| s != 0.0), "in-sample Cartesian scores must be non-trivial");
    assert_allclose(&scores, &model.predict_explicit(&in_sample), 1e-10, 1e-10);
    // zero-shot: novel vertices share no identity with training vertices
    let novel = Dataset {
        start_features: Matrix::from_fn(3, 2, |_, _| rng.normal()),
        end_features: Matrix::from_fn(3, 2, |_, _| rng.normal()),
        start_idx: vec![0, 1, 2],
        end_idx: vec![1, 2, 0],
        labels: vec![0.0; 3],
        name: "novel".into(),
    };
    assert!(model.predict(&novel).iter().all(|&s| s == 0.0));
}

/// End to end (the acceptance path): ridge with the symmetric kernel on the
/// homogeneous-graph generator learns a finite, better-than-chance AUC, and
/// its predictions are invariant to test-edge orientation.
#[test]
fn symmetric_ridge_end_to_end_on_homogeneous_graph() {
    let data = HomogeneousConfig {
        vertices: 70,
        density: 0.35,
        noise: 0.1,
        feature_range: 8.0,
        seed: 11,
    }
    .generate();
    let (train, test) = data.zero_shot_split(0.3, 13);
    let cfg = RidgeConfig {
        lambda: 2f64.powi(-7),
        kernel_d: KernelKind::Gaussian { gamma: 1.0 },
        kernel_t: KernelKind::Gaussian { gamma: 1.0 },
        iterations: 100,
        ..Default::default()
    };
    let model = KronRidge::new(cfg)
        .with_pairwise(PairwiseKernelKind::SymmetricKron)
        .fit(&train)
        .unwrap();
    let scores = model.predict(&test);
    let test_auc = auc(&test.labels, &scores);
    assert!(test_auc.is_finite(), "AUC must be finite");
    assert!(test_auc > 0.6, "AUC={test_auc}");
    // orientation invariance: swap every test edge's role assignment
    let swapped = Dataset {
        start_features: test.end_features.clone(),
        end_features: test.start_features.clone(),
        start_idx: test.end_idx.clone(),
        end_idx: test.start_idx.clone(),
        labels: test.labels.clone(),
        name: "swapped".into(),
    };
    assert_allclose(&scores, &model.predict(&swapped), 1e-10, 1e-10);
}

/// End to end: the SVM trainer accepts the symmetric family and the trained
/// model serves through the batched prediction server with finite scores.
#[test]
fn symmetric_svm_trains_and_serves() {
    let data = HomogeneousConfig {
        vertices: 50,
        density: 0.35,
        noise: 0.1,
        feature_range: 8.0,
        seed: 21,
    }
    .generate();
    let (train, test) = data.zero_shot_split(0.3, 23);
    let cfg = SvmConfig {
        lambda: 2f64.powi(-7),
        kernel_d: KernelKind::Gaussian { gamma: 1.0 },
        kernel_t: KernelKind::Gaussian { gamma: 1.0 },
        outer_iters: 10,
        inner_iters: 10,
        ..Default::default()
    };
    let model = KronSvm::new(cfg)
        .with_pairwise(PairwiseKernelKind::SymmetricKron)
        .fit(&train)
        .unwrap();
    let test_auc = auc(&test.labels, &model.predict(&test));
    assert!(test_auc.is_finite() && test_auc > 0.55, "AUC={test_auc}");

    // serve the symmetric model through the full pipeline
    let direct_model = model.clone();
    let server = PredictServer::start(
        model,
        ServerConfig {
            workers: 2,
            compute: Compute::threads(2).with_cache_vertices(64),
            ..Default::default()
        },
    );
    let mut rng = Pcg32::seeded(24);
    for round in 0..4 {
        let sf: Vec<Vec<f64>> = (0..3).map(|_| vec![rng.uniform_in(0.0, 8.0)]).collect();
        let ef: Vec<Vec<f64>> = (0..3).map(|_| vec![rng.uniform_in(0.0, 8.0)]).collect();
        let edges: Vec<(u32, u32)> =
            (0..6).map(|_| (rng.below(3) as u32, rng.below(3) as u32)).collect();
        let served =
            server.predict_blocking(sf.clone(), ef.clone(), edges.clone()).unwrap();
        assert!(served.iter().all(|s| s.is_finite()), "round {round}");
        // cross-check against the direct model on the same batch
        let ds = Dataset {
            start_features: Matrix::from_fn(3, 1, |i, _| sf[i][0]),
            end_features: Matrix::from_fn(3, 1, |i, _| ef[i][0]),
            start_idx: edges.iter().map(|&(s, _)| s).collect(),
            end_idx: edges.iter().map(|&(_, e)| e).collect(),
            labels: vec![0.0; 6],
            name: "req".into(),
        };
        assert_allclose(&served, &direct_model.predict(&ds), 1e-10, 1e-10);
    }
    server.shutdown();
}

/// The batched multi-λ path (`fit_path` + `predict_path`) works through the
/// pairwise operators: each symmetric-kernel path model matches the exact
/// Cholesky solve for its λ.
#[test]
fn symmetric_fit_path_matches_exact_solutions() {
    let data = HomogeneousConfig {
        vertices: 24,
        density: 0.3,
        noise: 0.2,
        feature_range: 6.0,
        seed: 31,
    }
    .generate();
    let lambdas = [0.5, 2.0];
    let cfg = RidgeConfig {
        kernel_d: KernelKind::Gaussian { gamma: 0.8 },
        kernel_t: KernelKind::Gaussian { gamma: 0.8 },
        iterations: 900,
        tol: 1e-13,
        ..Default::default()
    };
    let models = KronRidge::new(cfg)
        .with_pairwise(PairwiseKernelKind::SymmetricKron)
        .fit_path(&data, &lambdas)
        .unwrap();
    assert_eq!(models.len(), lambdas.len());
    for (model, &lambda) in models.iter().zip(&lambdas) {
        let exact = kronvt::train::ridge::ridge_exact_dual(
            &data,
            &RidgeConfig { lambda, ..cfg },
            PairwiseKernelKind::SymmetricKron,
        );
        assert_allclose(&model.dual_coef, &exact, 1e-5, 1e-5);
    }
    // batched prediction over the path agrees with per-model prediction
    let (_, test) = data.zero_shot_split(0.25, 32);
    if test.n_edges() > 0 {
        let batched = kronvt::model::predict_path(&models, &test).unwrap();
        for (j, scores) in batched.iter().enumerate() {
            assert_eq!(scores, &models[j].predict(&test), "model {j}");
        }
    }
}

/// The threads knob stays transparent for the pairwise families: threaded
/// training is bitwise identical to serial training.
#[test]
fn symmetric_threaded_training_matches_serial_bitwise() {
    let data = HomogeneousConfig {
        vertices: 40,
        density: 0.5,
        noise: 0.15,
        feature_range: 8.0,
        seed: 41,
    }
    .generate();
    let base = RidgeConfig {
        lambda: 0.3,
        kernel_d: KernelKind::Gaussian { gamma: 1.0 },
        kernel_t: KernelKind::Gaussian { gamma: 1.0 },
        iterations: 30,
        tol: 1e-12,
        ..Default::default()
    };
    let serial = KronRidge::new(base)
        .with_pairwise(PairwiseKernelKind::SymmetricKron)
        .fit(&data)
        .unwrap();
    for threads in [2, 4] {
        let par = KronRidge::new(base)
            .with_pairwise(PairwiseKernelKind::SymmetricKron)
            .with_compute(Compute::threads(threads))
            .fit(&data)
            .unwrap();
        assert_eq!(serial.dual_coef, par.dual_coef, "threads={threads}");
    }
}
