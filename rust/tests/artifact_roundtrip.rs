//! Integration: the AOT artifacts produced by `make artifacts` load through
//! PJRT and agree numerically with the native Rust implementations.
//!
//! These tests are skipped (with a notice) when `artifacts/manifest.json` is
//! missing so that `cargo test` works in a pure-Rust checkout; run
//! `make artifacts` first for full coverage.

use kronvt::coordinator::{Route, Router, RouterConfig};
use kronvt::gvt::{gvt_apply, KronIndex};
use kronvt::kernels::{kernel_matrix, KernelKind};
use kronvt::linalg::vecops::assert_allclose;
use kronvt::linalg::Matrix;
use kronvt::runtime::ArtifactRegistry;
use kronvt::util::rng::Pcg32;

fn registry() -> Option<ArtifactRegistry> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !ArtifactRegistry::available(&dir) {
        eprintln!("NOTE: artifacts/ missing — run `make artifacts`; skipping PJRT round-trip");
        return None;
    }
    Some(ArtifactRegistry::open(&dir).expect("open registry"))
}

fn random_kernel(rng: &mut Pcg32, n: usize, feat: usize) -> Matrix {
    let x = Matrix::from_fn(n, feat, |_, _| rng.normal());
    KernelKind::Gaussian { gamma: 0.3 }.square_matrix(&x)
}

fn random_idx(rng: &mut Pcg32, q: usize, m: usize, n: usize) -> KronIndex {
    KronIndex::new(
        (0..n).map(|_| rng.below(q) as u32).collect(),
        (0..n).map(|_| rng.below(m) as u32).collect(),
    )
}

#[test]
fn kron_mv_artifact_matches_native() {
    let Some(reg) = registry() else { return };
    let mut rng = Pcg32::seeded(2000);
    // deliberately not a bucket size: exercises padding
    let (m, q, n) = (50, 37, 700);
    let k = random_kernel(&mut rng, m, 4);
    let g = random_kernel(&mut rng, q, 4);
    let idx = random_idx(&mut rng, q, m, n);
    let v = rng.normal_vec(n);

    let pjrt = reg.kron_mv(&k, &g, &idx, &v).expect("pjrt kron_mv");
    let native = gvt_apply(&g, &k, &idx, &idx, &v);
    // f32 on the PJRT side
    assert_allclose(&pjrt, &native, 1e-3, 1e-3);
}

#[test]
fn kron_mv_artifact_exact_bucket_size() {
    let Some(reg) = registry() else { return };
    let mut rng = Pcg32::seeded(2001);
    let (m, q, n) = (64, 64, 1024);
    let k = random_kernel(&mut rng, m, 4);
    let g = random_kernel(&mut rng, q, 4);
    let idx = random_idx(&mut rng, q, m, n);
    let v = rng.normal_vec(n);
    let pjrt = reg.kron_mv(&k, &g, &idx, &v).expect("pjrt kron_mv");
    let native = gvt_apply(&g, &k, &idx, &idx, &v);
    assert_allclose(&pjrt, &native, 1e-3, 1e-3);
}

#[test]
fn gaussian_kernel_artifact_matches_native() {
    let Some(reg) = registry() else { return };
    let mut rng = Pcg32::seeded(2002);
    let x1 = Matrix::from_fn(33, 5, |_, _| rng.normal());
    let x2 = Matrix::from_fn(21, 5, |_, _| rng.normal());
    let gamma = 0.7;
    let pjrt = reg.gaussian_kernel(&x1, &x2, gamma).expect("pjrt gaussian");
    let native = kernel_matrix(KernelKind::Gaussian { gamma }, &x1, &x2);
    assert_allclose(pjrt.data(), native.data(), 2e-3, 2e-3);
}

#[test]
fn ridge_train_artifact_matches_native_solution() {
    let Some(reg) = registry() else { return };
    let mut rng = Pcg32::seeded(2003);
    let (m, q, n) = (40, 30, 500);
    let k = random_kernel(&mut rng, m, 4);
    let g = random_kernel(&mut rng, q, 4);
    let idx = random_idx(&mut rng, q, m, n);
    let y: Vec<f64> = (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
    let lambda = 1.0;

    let a_pjrt = reg.ridge_train(&k, &g, &idx, &y, lambda).expect("pjrt ridge_train");

    // native: solve the same system well past 50 CG iterations
    use kronvt::gvt::operator::RidgeSystemOp;
    use kronvt::gvt::KronKernelOp;
    use kronvt::linalg::solvers::{minres, LinOp, SolverConfig};
    use std::sync::Arc;
    let op = KronKernelOp::new(Arc::new(g.clone()), Arc::new(k.clone()), idx.clone());
    let sys = RidgeSystemOp { op: &op, lambda };
    let mut a_native = vec![0.0; n];
    minres(&sys, &y, &mut a_native, &SolverConfig { max_iters: 400, tol: 1e-12 });

    // The artifact runs exactly 50 f32 CG iterations; compare loosely and on
    // predictions rather than coefficients.
    let p_pjrt = op.apply_vec(&a_pjrt);
    let p_native = op.apply_vec(&a_native);
    assert_allclose(&p_pjrt, &p_native, 5e-2, 5e-2);
}

#[test]
fn router_dispatches_and_falls_back() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let router = Router::auto(&dir, RouterConfig::default());
    let mut rng = Pcg32::seeded(2004);
    let (m, q) = (48, 48);
    let k = random_kernel(&mut rng, m, 4);
    let g = random_kernel(&mut rng, q, 4);

    // dense graph → dense route is at least *considered*; sparse → native
    let sparse_idx = random_idx(&mut rng, q, m, 200);
    assert_eq!(router.decide(m, q, 200), Route::NativeGvt);

    let dense_n = m * q; // complete graph
    let dense_idx = random_idx(&mut rng, q, m, dense_n);
    let v_sparse = rng.normal_vec(200);
    let v_dense = rng.normal_vec(dense_n);

    // whatever the route, results must match native
    let u1 = router.kron_mv(&k, &g, &sparse_idx, &v_sparse);
    let u1_ref = gvt_apply(&g, &k, &sparse_idx, &sparse_idx, &v_sparse);
    assert_allclose(&u1, &u1_ref, 1e-3, 1e-3);

    let u2 = router.kron_mv(&k, &g, &dense_idx, &v_dense);
    let u2_ref = gvt_apply(&g, &k, &dense_idx, &dense_idx, &v_dense);
    assert_allclose(&u2, &u2_ref, 1e-3, 1e-2);

    if router.has_pjrt() {
        // the complete-graph case should actually prefer the GEMM path
        assert_eq!(router.decide(m, q, dense_n), Route::PjrtDense);
        assert!(router.stats().pjrt_calls >= 1);
    }
}
