//! Integration tests for the stochastic mini-batch trainer and its
//! streaming edge sources: the batch-restricted GVT apply pinned bitwise
//! against row-slicing the full apply at every thread count, fixed-seed
//! determinism (including in-memory vs on-disk source equivalence),
//! convergence to the exact CG dual solution, and end-to-end zero-shot
//! accuracy plus the `kronvt-model/v1` artifact round trip.

use kronvt::api::{Compute, Learner, TrainedModel};
use kronvt::data::checkerboard::CheckerboardConfig;
use kronvt::data::stream::{write_dataset_edges, BinaryEdgeReader, InMemorySource};
use kronvt::eval::auc::auc;
use kronvt::gvt::{BatchPlan, Branch, EdgePlan, GvtEngine, GvtWorkspace, KronIndex};
use kronvt::kernels::KernelKind;
use kronvt::linalg::vecops::assert_allclose;
use kronvt::linalg::Matrix;
use kronvt::train::{
    fit_stochastic, fit_stochastic_source, KronRidge, RidgeConfig, RidgeSolver, SamplingMode,
    StochasticConfig,
};
use kronvt::util::proptest::complete_dataset;
use kronvt::util::rng::Pcg32;

#[test]
fn restricted_apply_matches_full_apply_rows_bitwise_at_every_thread_count() {
    let mut rng = Pcg32::seeded(900);
    let (a, b, c, d) = (6usize, 8usize, 5usize, 7usize);
    let (e, f) = (3000usize, 2600usize);
    let m = Matrix::from_fn(a, b, |_, _| rng.normal());
    let n = Matrix::from_fn(c, d, |_, _| rng.normal());
    let (m_t, n_t) = (m.transpose(), n.transpose());
    let rows = KronIndex::new(
        (0..f).map(|_| rng.below(a) as u32).collect(),
        (0..f).map(|_| rng.below(c) as u32).collect(),
    );
    let cols = KronIndex::new(
        (0..e).map(|_| rng.below(b) as u32).collect(),
        (0..e).map(|_| rng.below(d) as u32).collect(),
    );
    let v: Vec<f64> = (0..e).map(|_| rng.normal()).collect();
    let plan = EdgePlan::build_full(&rows, &cols, a, b, c, d);

    // Batch positions with deliberate duplicates, as with-replacement
    // sampling produces.
    let picks: Vec<u32> = (0..400).map(|_| rng.below(f) as u32).collect();
    let batch = BatchPlan::build(&rows, &picks, a, c);

    for threads in [1usize, 2, 4] {
        let engine = GvtEngine::new(threads);
        let mut full = vec![0.0; f];
        let mut ws = GvtWorkspace::new();
        for branch in [None, Some(Branch::T), Some(Branch::S)] {
            engine.apply_planned(
                &m, &n, &m_t, &n_t, &rows, &cols, &plan, &v, &mut full, &mut ws, branch,
            );
            let want: Vec<f64> = picks.iter().map(|&h| full[h as usize]).collect();
            let mut got = vec![0.0; picks.len()];
            engine.apply_restricted(
                &m, &n, &m_t, &n_t, &rows, &cols, &plan, &batch, &v, &mut got, &mut ws, branch,
            );
            assert_eq!(got, want, "threads={threads} branch={branch:?}");
        }
    }
}

fn small_board(seed: u64) -> kronvt::data::Dataset {
    CheckerboardConfig {
        m: 24,
        q: 24,
        density: 0.5,
        noise: 0.15,
        feature_range: 8.0,
        seed,
    }
    .generate()
}

#[test]
fn fixed_seed_epochs_are_deterministic_across_runs_and_threads() {
    let ds = small_board(11);
    let cfg = StochasticConfig { batch_edges: 64, epochs: 8, ..Default::default() };
    let (one, trace_one) = fit_stochastic(&ds, None, &cfg, &Compute::serial()).unwrap();
    let (two, trace_two) = fit_stochastic(&ds, None, &cfg, &Compute::serial()).unwrap();
    assert_eq!(one.dual_coef, two.dual_coef);
    assert_eq!(trace_one.records.len(), trace_two.records.len());
    for threads in [2usize, 4] {
        let (par, _) = fit_stochastic(&ds, None, &cfg, &Compute::threads(threads)).unwrap();
        assert_eq!(one.dual_coef, par.dual_coef, "threads={threads}");
    }
    // and both sampling modes react to the seed
    for sampling in [SamplingMode::EpochShuffle, SamplingMode::WithReplacement] {
        let base = StochasticConfig { sampling, ..cfg };
        let reseeded = StochasticConfig { seed: 77, ..base };
        let (x, _) = fit_stochastic(&ds, None, &base, &Compute::serial()).unwrap();
        let (y, _) = fit_stochastic(&ds, None, &reseeded, &Compute::serial()).unwrap();
        assert_ne!(x.dual_coef, y.dual_coef, "{sampling:?}");
    }
}

#[test]
fn on_disk_source_trains_bitwise_identically_to_in_memory() {
    let ds = small_board(12);
    let cfg = StochasticConfig { batch_edges: 48, epochs: 6, ..Default::default() };
    let compute = Compute::threads(2);
    // Small chunks so the schedule spans several chunks per epoch.
    let mem = InMemorySource::with_chunk_edges(&ds, 128).unwrap();
    let from_mem = fit_stochastic_source(
        &mem,
        &ds.start_features,
        &ds.end_features,
        &cfg,
        &compute,
        None,
    )
    .unwrap();
    let mut path = std::env::temp_dir();
    path.push(format!("kronvt-stochastic-{}.edges", std::process::id()));
    write_dataset_edges(&path, &ds, 128).unwrap();
    let disk = BinaryEdgeReader::open(&path).unwrap();
    let from_disk = fit_stochastic_source(
        &disk,
        &ds.start_features,
        &ds.end_features,
        &cfg,
        &compute,
        None,
    )
    .unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(from_mem.duals, from_disk.duals);
    assert_eq!(from_mem.epochs_run, from_disk.epochs_run);
    let mem_risks: Vec<u64> = from_mem.trace.records.iter().map(|r| r.risk.to_bits()).collect();
    let disk_risks: Vec<u64> = from_disk.trace.records.iter().map(|r| r.risk.to_bits()).collect();
    assert_eq!(mem_risks, disk_risks);
}

#[test]
fn converges_to_the_exact_cg_dual_solution_on_a_complete_graph() {
    let mut rng = Pcg32::seeded(910);
    let train = complete_dataset(&mut rng, 6, 5);
    let lambda = 2.0;
    // Exact CG reference.
    let ridge_cfg =
        RidgeConfig { lambda, iterations: 800, tol: 1e-13, ..Default::default() };
    let exact = KronRidge::new(ridge_cfg).with_solver(RidgeSolver::Cg).fit(&train).unwrap();
    // Stochastic: generous epoch budget, residual tolerance 1e-8; the
    // documented acceptance tolerance against the exact duals is 1e-5.
    let cfg = StochasticConfig {
        lambda,
        batch_edges: 5,
        epochs: 5000,
        tol: 1e-8,
        ..Default::default()
    };
    let source = InMemorySource::new(&train);
    let result = fit_stochastic_source(
        &source,
        &train.start_features,
        &train.end_features,
        &cfg,
        &Compute::serial(),
        None,
    )
    .unwrap();
    assert!(
        result.converged,
        "no convergence in {} epochs (residual {})",
        result.epochs_run, result.final_residual
    );
    assert!(result.epochs_run < cfg.epochs, "tolerance should stop the run early");
    assert_allclose(&result.duals, &exact.dual_coef, 1e-5, 1e-5);
}

#[test]
fn zero_shot_split_gets_finite_above_chance_auc_and_a_v1_artifact_round_trip() {
    let data = CheckerboardConfig {
        m: 40,
        q: 40,
        density: 0.4,
        noise: 0.1,
        feature_range: 8.0,
        seed: 13,
    }
    .generate();
    let (train, test) = data.zero_shot_split(0.3, 9);
    let compute = Compute::threads(2);
    let model = Learner::stochastic()
        .lambda(2f64.powi(-5))
        .kernel(KernelKind::Gaussian { gamma: 1.0 })
        .iterations(25)
        .batch_edges(64)
        .seed(4)
        .compute(compute)
        .fit(&train)
        .unwrap();
    let scores = model.predict_batch(&test, &compute);
    let auc_val = auc(&test.labels, &scores);
    assert!(auc_val.is_finite() && auc_val > 0.55, "AUC={auc_val}");
    // The stochastic trainer produces a plain dual model, so the
    // kronvt-model/v1 artifact path applies unchanged.
    let mut path = std::env::temp_dir();
    path.push(format!("kronvt-stochastic-model-{}.json", std::process::id()));
    model.save(&path).unwrap();
    let loaded = TrainedModel::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(scores, loaded.predict_batch(&test, &compute));
}

#[test]
fn validation_monitoring_records_auc_and_patience_stops_early() {
    let data = small_board(14);
    let (train, val) = data.zero_shot_split(0.3, 2);
    let cfg = StochasticConfig {
        lambda: 1e-6,
        batch_edges: 32,
        epochs: 60,
        tol: 0.0,
        patience: 1,
        ..Default::default()
    };
    let (_, trace) = fit_stochastic(&train, Some(&val), &cfg, &Compute::serial()).unwrap();
    assert!(!trace.records.is_empty());
    assert!(trace.records.iter().all(|r| r.val_auc.is_some()));
    assert!(
        trace.records.len() < 60,
        "expected validation-AUC early stop, ran {} epochs",
        trace.records.len()
    );
}
