//! `kronvt` CLI — train, evaluate, and serve Kronecker product kernel
//! methods.
//!
//! ```text
//! kronvt datasets                          # Table-5 style dataset stats
//! kronvt train --data checker --method kronsvm --kernel gaussian:1 \
//!              --lambda 0.0078125 --outer 10 --inner 10
//! kronvt cv --data gpcr --method kronridge --lambda 1e-4
//! kronvt serve --data checker --requests 100
//! kronvt artifacts                         # artifact registry status
//! ```

use kronvt::baselines::{ExplicitSvm, ExplicitSvmConfig, KnnConfig, KnnModel, SgdConfig, SgdLossKind, SgdModel};
use kronvt::coordinator::{run_cv_jobs, run_cv_path_jobs, PredictServer, ServerConfig};
use kronvt::data::{checkerboard, dti, Dataset};
use kronvt::eval::auc::auc;
use kronvt::gvt::PairwiseKernelKind;
use kronvt::kernels::KernelKind;
use kronvt::train::{KronRidge, KronSvm, RidgeConfig, SvmConfig};
use kronvt::util::args::Args;
use kronvt::util::rng::Pcg32;
use kronvt::util::timer::Timer;

fn load_dataset(name: &str, seed: u64, scale: f64) -> Result<Dataset, String> {
    let ds = match name {
        "checker" => {
            let mut cfg = checkerboard::checker(seed);
            cfg.m = ((cfg.m as f64 * scale) as usize).max(10);
            cfg.q = cfg.m;
            cfg.generate()
        }
        "checker+" => {
            let mut cfg = checkerboard::checker_plus(seed);
            cfg.m = ((cfg.m as f64 * scale) as usize).max(10);
            cfg.q = cfg.m;
            cfg.generate()
        }
        "homo" => {
            let mut cfg = checkerboard::homogeneous(seed);
            cfg.vertices = ((cfg.vertices as f64 * scale) as usize).max(10);
            cfg.generate()
        }
        "ki" => dti::ki(seed).generate(),
        "gpcr" => dti::gpcr(seed).generate(),
        "ic" => dti::ic(seed).generate(),
        "e" => dti::e(seed).generate(),
        other => {
            return Err(format!(
                "unknown dataset '{other}' (checker, checker+, homo, ki, gpcr, ic, e)"
            ))
        }
    };
    Ok(ds)
}

fn train_and_eval(
    method: &str,
    train: &Dataset,
    test: &Dataset,
    args: &Args,
) -> Result<f64, String> {
    let lambda = args.get_f64("lambda", 1e-4);
    let kernel = KernelKind::parse(&args.get_str("kernel", "linear"))?;
    let pairwise = PairwiseKernelKind::parse(&args.get_str("pairwise", "kron"))?;
    // GVT matvec parallelism (0 = all cores); results are identical for
    // every thread count, only faster.
    let threads = args.get_usize("threads", 1);
    if pairwise != PairwiseKernelKind::Kronecker
        && !matches!(method, "kronsvm" | "kronridge")
    {
        return Err(format!(
            "--pairwise {} is only supported by kronsvm/kronridge (got '{method}')",
            pairwise.name()
        ));
    }
    let scores = match method {
        "kronsvm" => {
            let cfg = SvmConfig {
                lambda,
                kernel_d: kernel,
                kernel_t: kernel,
                outer_iters: args.get_usize("outer", 10),
                inner_iters: args.get_usize("inner", 10),
                threads,
                pairwise,
                ..Default::default()
            };
            KronSvm::new(cfg).fit(train)?.predict_threaded(test, threads)
        }
        "kronridge" => {
            let cfg = RidgeConfig {
                lambda,
                kernel_d: kernel,
                kernel_t: kernel,
                iterations: args.get_usize("iterations", 100),
                threads,
                pairwise,
                ..Default::default()
            };
            KronRidge::new(cfg).fit(train)?.predict_threaded(test, threads)
        }
        "libsvm" => {
            let cfg = ExplicitSvmConfig {
                c: args.get_f64("c", 1.0),
                kernel,
                ..Default::default()
            };
            ExplicitSvm::fit(train, &cfg)?.predict(test)
        }
        "sgd-hinge" | "sgd-logistic" => {
            let cfg = SgdConfig {
                loss: if method == "sgd-hinge" { SgdLossKind::Hinge } else { SgdLossKind::Logistic },
                lambda,
                updates: args.get_usize("updates", 1_000_000),
                ..Default::default()
            };
            SgdModel::fit(train, &cfg)?.predict(test)
        }
        "knn" => {
            let cfg = KnnConfig { k: args.get_usize("k", 5), ..Default::default() };
            KnnModel::fit(train, &cfg)?.predict(test)
        }
        other => return Err(format!("unknown method '{other}'")),
    };
    Ok(auc(&test.labels, &scores))
}

fn cmd_datasets(args: &Args) -> Result<(), String> {
    let seed = args.get_u64("seed", 1);
    println!("{:<10} {:>9} {:>8} {:>9} {:>8} {:>8}", "dataset", "edges", "pos.", "neg.", "starts", "ends");
    for name in ["gpcr", "ic", "e", "ki", "checker", "homo"] {
        let ds = load_dataset(name, seed, args.get_f64("scale", 1.0))?;
        let st = ds.stats();
        println!(
            "{:<10} {:>9} {:>8} {:>9} {:>8} {:>8}",
            name, st.edges, st.positives, st.negatives, st.start_vertices, st.end_vertices
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<(), String> {
    let data = args.get_str("data", "checker");
    let method = args.get_str("method", "kronsvm");
    let seed = args.get_u64("seed", 1);
    let ds = load_dataset(&data, seed, args.get_f64("scale", 0.1))?;
    let (train, test) = ds.zero_shot_split(args.get_f64("test-frac", 0.25), seed);
    println!(
        "dataset={} train: n={} m={} q={}; test: n={}",
        data,
        train.n_edges(),
        train.m(),
        train.q(),
        test.n_edges()
    );
    let timer = Timer::start();
    let auc_val = train_and_eval(&method, &train, &test, args)?;
    println!("method={method} AUC={auc_val:.4} time={:.2}s", timer.elapsed_secs());
    Ok(())
}

fn cmd_cv(args: &Args) -> Result<(), String> {
    let data = args.get_str("data", "gpcr");
    let method = args.get_str("method", "kronridge");
    let seed = args.get_u64("seed", 1);
    let ds = load_dataset(&data, seed, args.get_f64("scale", 1.0))?;
    let folds = ds.ninefold_cv(seed);
    // Fold-level parallelism; combine with --threads (per-matvec sharding)
    // carefully — the product of the two should not exceed the core count.
    let fold_workers = args.get_usize("fold-workers", 1);
    if args.has("threads") && !args.has("fold-workers") {
        eprintln!(
            "note: `cv --threads` now shards each GVT matvec; use --fold-workers N \
             to train folds concurrently (the pre-engine meaning of --threads)"
        );
    }
    // `--lambdas a,b,c` routes each fold through the batched compute core:
    // one block-CG solve trains the whole λ grid, one multi-RHS prediction
    // scores every model (kronridge only).
    if let Some(spec) = args.get("lambdas") {
        let lambdas: Vec<f64> = spec
            .split(',')
            .filter(|t| !t.is_empty())
            .map(|t| t.parse::<f64>().map_err(|_| format!("bad lambda '{t}'")))
            .collect::<Result<_, _>>()?;
        if lambdas.is_empty() {
            return Err("--lambdas needs at least one value".into());
        }
        if method != "kronridge" {
            return Err(
                "--lambdas (batched λ-grid CV) currently supports --method kronridge".into()
            );
        }
        let kernel = KernelKind::parse(&args.get_str("kernel", "linear"))?;
        let cfg = RidgeConfig {
            kernel_d: kernel,
            kernel_t: kernel,
            iterations: args.get_usize("iterations", 100),
            threads: args.get_usize("threads", 1),
            pairwise: PairwiseKernelKind::parse(&args.get_str("pairwise", "kron"))?,
            ..Default::default()
        };
        let results = run_cv_path_jobs(&folds, fold_workers, |tr, te| {
            KronRidge::new(cfg)
                .fit_path(tr, &lambdas)
                .and_then(|models| kronvt::model::predict_path(&models, te))
                .map(|score_sets| {
                    score_sets.iter().map(|s| auc(&te.labels, s)).collect::<Vec<f64>>()
                })
                .unwrap_or_else(|_| vec![f64::NAN; lambdas.len()])
        });
        for r in &results {
            let row: Vec<String> = r.aucs.iter().map(|a| format!("{a:.4}")).collect();
            println!(
                "fold {} AUCs=[{}] ({} train, {} test edges, {:.2}s)",
                r.fold,
                row.join(", "),
                r.train_edges,
                r.test_edges,
                r.train_secs
            );
        }
        let means = kronvt::coordinator::jobs::mean_auc_path(&results);
        let mut best = 0;
        for (j, &m) in means.iter().enumerate() {
            println!("lambda={:<12} mean AUC={m:.4}", lambdas[j]);
            // NaN means (diverged folds) must never win — or block a later
            // finite mean from displacing them.
            if !m.is_nan() && (means[best].is_nan() || m > means[best]) {
                best = j;
            }
        }
        println!(
            "best lambda={} (mean AUC {:.4} over {} folds)",
            lambdas[best],
            means[best],
            results.len()
        );
        return Ok(());
    }
    let results = run_cv_jobs(&folds, fold_workers, |tr, te| {
        train_and_eval(&method, tr, te, args).unwrap_or(f64::NAN)
    });
    for r in &results {
        println!(
            "fold {} AUC={:.4} ({} train, {} test edges, {:.2}s)",
            r.fold, r.auc, r.train_edges, r.test_edges, r.train_secs
        );
    }
    let mean = kronvt::coordinator::jobs::mean_auc(&results);
    println!("mean AUC over {} folds: {mean:.4}", results.len());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let seed = args.get_u64("seed", 1);
    let ds = load_dataset(&args.get_str("data", "checker"), seed, args.get_f64("scale", 0.06))?;
    let (train, _) = ds.zero_shot_split(0.25, seed);
    let threads = args.get_usize("threads", 0);
    let pairwise = PairwiseKernelKind::parse(&args.get_str("pairwise", "kron"))?;
    let cfg = SvmConfig {
        lambda: args.get_f64("lambda", 2f64.powi(-7)),
        kernel_d: KernelKind::Gaussian { gamma: 1.0 },
        kernel_t: KernelKind::Gaussian { gamma: 1.0 },
        threads,
        pairwise,
        ..Default::default()
    };
    println!("training model on {} edges...", train.n_edges());
    let model = KronSvm::new(cfg).fit(&train)?;
    let d = model.train_start_features.cols();
    let r = model.train_end_features.cols();
    let server = PredictServer::start(
        model,
        ServerConfig {
            threads,
            workers: args.get_usize("serve-workers", 2),
            cache_vertices: args.get_usize("cache-vertices", 1024),
            max_queue: args.get_usize("max-queue", 1024),
            ..Default::default()
        },
    );

    // Real serving traffic repeats vertices across requests (the same drug
    // against new targets, the same user against new items); draw request
    // vertices from a bounded pool so the kernel-row cache sees that pattern.
    let n_requests = args.get_usize("requests", 100);
    let pool_size = args.get_usize("vertex-pool", 16).max(4);
    let mut rng = Pcg32::seeded(seed ^ 0x5E7);
    let start_pool: Vec<Vec<f64>> =
        (0..pool_size).map(|_| rng.uniform_vec(d, 0.0, 100.0)).collect();
    let end_pool: Vec<Vec<f64>> = (0..pool_size).map(|_| rng.uniform_vec(r, 0.0, 100.0)).collect();
    let timer = Timer::start();
    for _ in 0..n_requests {
        let sf: Vec<Vec<f64>> =
            (0..4).map(|_| start_pool[rng.below(pool_size)].clone()).collect();
        let ef: Vec<Vec<f64>> = (0..4).map(|_| end_pool[rng.below(pool_size)].clone()).collect();
        let edges: Vec<(u32, u32)> =
            (0..8).map(|_| (rng.below(4) as u32, rng.below(4) as u32)).collect();
        let scores = server.predict_blocking(sf, ef, edges)?;
        assert_eq!(scores.len(), 8);
    }
    let secs = timer.elapsed_secs();
    let st = server.stats();
    let hits = st.cache_hits.load(std::sync::atomic::Ordering::Relaxed);
    let misses = st.cache_misses.load(std::sync::atomic::Ordering::Relaxed);
    println!(
        "served {} requests ({} edges) in {:.3}s — {:.0} edges/s, {} batches",
        st.requests.load(std::sync::atomic::Ordering::Relaxed),
        st.edges_scored.load(std::sync::atomic::Ordering::Relaxed),
        secs,
        st.edges_scored.load(std::sync::atomic::Ordering::Relaxed) as f64 / secs,
        st.batches.load(std::sync::atomic::Ordering::Relaxed),
    );
    println!(
        "kernel-row cache: {hits} hits / {misses} misses ({:.0}% hit rate)",
        100.0 * hits as f64 / (hits + misses).max(1) as f64
    );
    server.shutdown();
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<(), String> {
    let dir = args.get_str("dir", "artifacts");
    if !kronvt::runtime::ArtifactRegistry::available(&dir) {
        println!("no artifact manifest at {dir}/ — run `make artifacts` (native paths still work)");
        return Ok(());
    }
    // List the manifest without opening a PJRT client, so this works even in
    // builds without the `pjrt` feature.
    let manifest = kronvt::runtime::ArtifactManifest::load(std::path::Path::new(&dir))
        .map_err(|e| e.to_string())?;
    println!("{} artifacts in {dir}/:", manifest.artifacts.len());
    for a in &manifest.artifacts {
        println!("  {:<40} kind={:<16} file={}", a.name, a.kind, a.file);
    }
    match kronvt::runtime::ArtifactRegistry::open(&dir) {
        Ok(_) => println!("PJRT client: available"),
        Err(err) => println!("PJRT client: unavailable ({err}); native GVT paths still work"),
    }
    Ok(())
}

fn usage() -> ! {
    eprintln!(
        "usage: kronvt <command> [--flags]\n\
         commands:\n\
           datasets   print Table-5 style dataset statistics\n\
           train      train one method on a zero-shot split and report AUC\n\
           cv         9-fold zero-shot cross-validation (Fig. 2)\n\
           serve      run the batched zero-shot prediction server demo\n\
           artifacts  show the PJRT artifact registry status\n\
         common flags: --data checker|checker+|homo|ki|gpcr|ic|e --method kronsvm|kronridge|libsvm|sgd-hinge|sgd-logistic|knn\n\
                       --kernel linear|gaussian:G --lambda L --seed S --scale F\n\
                       --pairwise kron|symmetric|antisymmetric|cartesian\n\
                                     pairwise kernel family (kronsvm/kronridge; symmetric and\n\
                                     antisymmetric need one shared vertex domain, e.g. --data homo)\n\
                       --threads N   GVT matvec worker threads (0 = all cores; identical results, just faster)\n\
                       --fold-workers N   (cv only) train folds concurrently\n\
                       --lambdas a,b,c    (cv + kronridge) batched λ-grid CV: one block-CG solve\n\
                                          and one multi-RHS prediction per fold covers every λ\n\
         serve flags:  --serve-workers N   scoring-pool threads (batches scored concurrently)\n\
                       --cache-vertices N  per-side kernel-row LRU capacity (0 = off)\n\
                       --max-queue N       request-queue bound (backpressure)\n\
                       --vertex-pool P     distinct request vertices per side (repeat-vertex traffic)"
    );
    std::process::exit(2)
}

fn main() {
    let args = Args::parse();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    let result = match cmd {
        "datasets" => cmd_datasets(&args),
        "train" => cmd_train(&args),
        "cv" => cmd_cv(&args),
        "serve" => cmd_serve(&args),
        "artifacts" => cmd_artifacts(&args),
        _ => usage(),
    };
    if let Err(err) = result {
        eprintln!("error: {err}");
        std::process::exit(1);
    }
}
