//! `kronvt` CLI — train, persist, evaluate, and serve Kronecker product
//! kernel methods through one model lifecycle: **fit → save → load →
//! serve**.
//!
//! ```text
//! kronvt datasets                          # Table-5 style dataset stats
//! kronvt train --data checker --method kronsvm --kernel gaussian:1 \
//!              --lambda 0.0078125 --outer 10 --inner 10 --save model.json
//! kronvt predict --model model.json --data checker     # fresh-process scoring
//! kronvt cv --data gpcr --method kronridge --lambda 1e-4
//! kronvt train --data grid --factors 20x15x12 --kernel gaussian:1   # D-way chain
//! kronvt serve --model model.json --requests 100       # serve without retraining
//! kronvt serve --model model.json --listen 127.0.0.1:7878 --serve-secs 60   # TCP protocol
//! kronvt serve --shards 127.0.0.1:7878,127.0.0.1:7879  # route across shard processes
//! kronvt artifacts                         # artifact registry status
//! ```
//!
//! Unknown flags are rejected per subcommand, and unparsable flag values
//! are errors — typos fail loudly.

use std::path::Path;

use kronvt::api::{Compute, Learner, TrainedModel};
use kronvt::baselines::{ExplicitSvm, ExplicitSvmConfig, KnnConfig, KnnModel, SgdConfig, SgdLossKind, SgdModel};
use kronvt::coordinator::{
    run_cv_jobs, run_cv_path_jobs, NetClient, NetServer, NetServerConfig, NetShard,
    PredictServer, ServerConfig, ShardBackend, ShardRouter, ShardRouterConfig,
};
use kronvt::data::{checkerboard, dti, Dataset, GridCheckerboardConfig};
use kronvt::eval::auc::auc;
use kronvt::gvt::PairwiseKernelKind;
use kronvt::kernels::KernelKind;
use kronvt::train::{KronRidge, RidgeConfig, RidgeSolver};
use kronvt::util::args::Args;
use kronvt::util::rng::Pcg32;
use kronvt::util::timer::Timer;

fn load_dataset(name: &str, seed: u64, scale: f64) -> Result<Dataset, String> {
    let ds = match name {
        "checker" => {
            let mut cfg = checkerboard::checker(seed);
            cfg.m = ((cfg.m as f64 * scale) as usize).max(10);
            cfg.q = cfg.m;
            cfg.generate()
        }
        "checker+" => {
            let mut cfg = checkerboard::checker_plus(seed);
            cfg.m = ((cfg.m as f64 * scale) as usize).max(10);
            cfg.q = cfg.m;
            cfg.generate()
        }
        "homo" => {
            let mut cfg = checkerboard::homogeneous(seed);
            cfg.vertices = ((cfg.vertices as f64 * scale) as usize).max(10);
            cfg.generate()
        }
        "ki" => dti::ki(seed).generate(),
        "gpcr" => dti::gpcr(seed).generate(),
        "ic" => dti::ic(seed).generate(),
        "e" => dti::e(seed).generate(),
        other => {
            return Err(format!(
                "unknown dataset '{other}' (checker, checker+, homo, ki, gpcr, ic, e; \
                 --data grid takes the tensor-chain path)"
            ))
        }
    };
    Ok(ds)
}

/// Parse a `--factors AxBxC` grid spec into per-mode vertex counts.
fn parse_factors(spec: &str) -> Result<Vec<usize>, String> {
    let dims: Vec<usize> = spec
        .split('x')
        .map(|t| {
            t.parse::<usize>()
                .ok()
                .filter(|&d| d > 0)
                .ok_or_else(|| format!("bad --factors '{spec}': '{t}' is not a positive integer"))
        })
        .collect::<Result<_, _>>()?;
    if dims.len() < 2 {
        return Err(format!("--factors '{spec}' needs at least two 'x'-separated modes"));
    }
    Ok(dims)
}

/// Build the spatio-temporal checkerboard grid the `--data grid` path
/// trains and scores on (deterministic given the flags).
fn grid_config(args: &Args, seed: u64) -> Result<GridCheckerboardConfig, String> {
    Ok(GridCheckerboardConfig {
        dims: parse_factors(&args.get_str("factors", "20x15x12"))?,
        density: args.get_f64("density", 0.25)?,
        noise: args.get_f64("noise", 0.2)?,
        feature_range: 8.0,
        seed,
    })
}

/// A fully parsed training method: every flag is validated up front, so a
/// typo fails before any dataset is trained (in particular, `cv` maps
/// per-fold *training* failures to NaN — a bad flag must never hide there).
enum MethodPlan {
    /// Kronecker methods through the unified estimator API.
    Kron(Learner),
    /// Explicit SMO baseline.
    Libsvm(ExplicitSvmConfig),
    /// Linear SGD baselines.
    Sgd(SgdConfig),
    /// K-nearest-neighbours baseline.
    Knn(KnnConfig),
}

fn parse_method(method: &str, args: &Args, compute: Compute) -> Result<MethodPlan, String> {
    let lambda = args.get_f64("lambda", 1e-4)?;
    let kernel = KernelKind::parse(&args.get_str("kernel", "linear"))?;
    let pairwise = PairwiseKernelKind::parse(&args.get_str("pairwise", "kron"))?;
    if args.has("solver") && method != "kronridge" {
        return Err(format!("--solver applies to --method kronridge only (got '{method}')"));
    }
    let solver = args.get_str("solver", "auto");
    let stochastic = method == "kronridge" && solver == "stochastic";
    for flag in ["batch-edges", "epochs"] {
        if args.has(flag) && !stochastic {
            return Err(format!(
                "--{flag} applies to --method kronridge with --solver stochastic only"
            ));
        }
    }
    match method {
        "kronsvm" => Ok(MethodPlan::Kron(
            Learner::svm()
                .iterations(args.get_usize("outer", 10)?)
                .inner_iterations(args.get_usize("inner", 10)?)
                .lambda(lambda)
                .kernel(kernel)
                .pairwise(pairwise)
                .compute(compute),
        )),
        "kronridge" if stochastic => {
            // The stochastic trainer's budget is epochs (full data passes),
            // not solver iterations — reject the wrong knob loudly.
            if args.has("iterations") {
                return Err(
                    "--solver stochastic trains in epochs; use --epochs (default 30), \
                     not --iterations"
                        .into(),
                );
            }
            Ok(MethodPlan::Kron(
                Learner::stochastic()
                    .iterations(args.get_usize("epochs", 30)?)
                    .batch_edges(args.get_usize("batch-edges", 512)?)
                    .seed(args.get_u64("seed", 1)?)
                    .lambda(lambda)
                    .kernel(kernel)
                    .pairwise(pairwise)
                    .compute(compute),
            ))
        }
        "kronridge" => Ok(MethodPlan::Kron(
            Learner::ridge()
                .iterations(args.get_usize("iterations", 100)?)
                .lambda(lambda)
                .kernel(kernel)
                .pairwise(pairwise)
                .solver(RidgeSolver::parse(&solver)?)
                .compute(compute),
        )),
        _ if pairwise != PairwiseKernelKind::Kronecker => Err(format!(
            "--pairwise {} is only supported by kronsvm/kronridge (got '{method}')",
            pairwise.name()
        )),
        "libsvm" => Ok(MethodPlan::Libsvm(ExplicitSvmConfig {
            c: args.get_f64("c", 1.0)?,
            kernel,
            ..Default::default()
        })),
        "sgd-hinge" | "sgd-logistic" => Ok(MethodPlan::Sgd(SgdConfig {
            loss: if method == "sgd-hinge" { SgdLossKind::Hinge } else { SgdLossKind::Logistic },
            lambda,
            updates: args.get_usize("updates", 1_000_000)?,
            ..Default::default()
        })),
        "knn" => Ok(MethodPlan::Knn(KnnConfig {
            k: args.get_usize("k", 5)?,
            ..Default::default()
        })),
        other => Err(format!("unknown method '{other}'")),
    }
}

/// Train one parsed method and score the test edges. Errors here are
/// genuine training failures, never flag typos (those fail in
/// [`parse_method`]).
fn run_plan(
    plan: &MethodPlan,
    train: &Dataset,
    test: &Dataset,
    compute: &Compute,
) -> Result<Vec<f64>, String> {
    match plan {
        MethodPlan::Kron(learner) => Ok(learner.fit(train)?.predict_batch(test, compute)),
        MethodPlan::Libsvm(cfg) => Ok(ExplicitSvm::fit(train, cfg)?.predict(test)),
        MethodPlan::Sgd(cfg) => Ok(SgdModel::fit(train, cfg)?.predict(test)),
        MethodPlan::Knn(cfg) => Ok(KnnModel::fit(train, cfg)?.predict(test)),
    }
}

const DATASETS_FLAGS: &[&str] = &["seed", "scale"];

fn cmd_datasets(args: &Args) -> Result<(), String> {
    args.expect_known("datasets", DATASETS_FLAGS)?;
    let seed = args.get_u64("seed", 1)?;
    println!("{:<10} {:>9} {:>8} {:>9} {:>8} {:>8}", "dataset", "edges", "pos.", "neg.", "starts", "ends");
    for name in ["gpcr", "ic", "e", "ki", "checker", "homo"] {
        let ds = load_dataset(name, seed, args.get_f64("scale", 1.0)?)?;
        let st = ds.stats();
        println!(
            "{:<10} {:>9} {:>8} {:>9} {:>8} {:>8}",
            name, st.edges, st.positives, st.negatives, st.start_vertices, st.end_vertices
        );
    }
    Ok(())
}

const TRAIN_FLAGS: &[&str] = &[
    "data", "method", "seed", "scale", "test-frac", "lambda", "kernel", "pairwise", "solver",
    "threads", "outer", "inner", "iterations", "c", "updates", "k", "save", "factors", "density",
    "noise", "batch-edges", "epochs",
];

/// `train --data grid`: D-way tensor-chain ridge on the spatio-temporal
/// checkerboard — the factor-list analogue of the two-factor train path,
/// with the same AUC / score_sum / `--save` reporting (v2 artifact).
fn train_grid(args: &Args) -> Result<(), String> {
    let method = args.get_str("method", "kronridge");
    if method != "kronridge" {
        return Err(format!("--data grid trains with --method kronridge only (got '{method}')"));
    }
    let seed = args.get_u64("seed", 1)?;
    let compute = Compute::threads(args.get_usize("threads", 1)?);
    let ds = grid_config(args, seed)?.generate();
    let (train, test) = ds.holdout_split(args.get_f64("test-frac", 0.25)?, seed);
    println!(
        "dataset={} dims={:?} train: n={}; test: n={}",
        ds.name,
        train.dims(),
        train.n_edges(),
        test.n_edges()
    );
    let learner = Learner::ridge()
        .iterations(args.get_usize("iterations", 100)?)
        .lambda(args.get_f64("lambda", 1e-4)?)
        .kernel(KernelKind::parse(&args.get_str("kernel", "gaussian:1"))?)
        .compute(compute);
    let timer = Timer::start();
    let model = learner.fit_tensor(&train)?;
    let scores = model.predict_tensor(&test, &compute)?;
    let auc_val = auc(&test.labels, &scores);
    println!(
        "method=kronridge(tensor) D={} AUC={auc_val:.4} time={:.2}s",
        train.order(),
        timer.elapsed_secs()
    );
    let score_sum: f64 = scores.iter().sum();
    println!("test n={} score_sum={score_sum}", test.n_edges());
    if let Some(path) = args.get("save") {
        model.save(Path::new(path))?;
        println!("saved kronvt-model/v2 artifact to {path}");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<(), String> {
    args.expect_known("train", TRAIN_FLAGS)?;
    let data = args.get_str("data", "checker");
    if data == "grid" {
        return train_grid(args);
    }
    for flag in ["factors", "density", "noise"] {
        if args.has(flag) {
            return Err(format!("--{flag} applies to --data grid only (got --data {data})"));
        }
    }
    let method = args.get_str("method", "kronsvm");
    let seed = args.get_u64("seed", 1)?;
    // GVT matvec parallelism (0 = all cores); results are identical for
    // every thread count, only faster.
    let compute = Compute::threads(args.get_usize("threads", 1)?);
    let plan = parse_method(&method, args, compute)?;
    if args.has("save") && !matches!(plan, MethodPlan::Kron(_)) {
        return Err(format!("--save persists kronsvm/kronridge models only (got '{method}')"));
    }
    let ds = load_dataset(&data, seed, args.get_f64("scale", 0.1)?)?;
    let (train, test) = ds.zero_shot_split(args.get_f64("test-frac", 0.25)?, seed);
    println!(
        "dataset={} train: n={} m={} q={}; test: n={}",
        data,
        train.n_edges(),
        train.m(),
        train.q(),
        test.n_edges()
    );
    let timer = Timer::start();
    let (scores, model) = match &plan {
        MethodPlan::Kron(learner) => {
            let model = learner.fit(&train)?;
            (model.predict_batch(&test, &compute), Some(model))
        }
        _ => (run_plan(&plan, &train, &test, &compute)?, None),
    };
    let auc_val = auc(&test.labels, &scores);
    println!("method={method} AUC={auc_val:.4} time={:.2}s", timer.elapsed_secs());
    // Shortest-round-trip sum: a fresh `kronvt predict` on the same split
    // prints the identical string iff scoring is bitwise reproducible.
    let score_sum: f64 = scores.iter().sum();
    println!("test n={} score_sum={score_sum}", test.n_edges());
    if let Some(path) = args.get("save") {
        let model = model.expect("checked above: --save implies a Kron plan");
        model.save(Path::new(path))?;
        println!("saved kronvt-model/v1 artifact to {path}");
    }
    Ok(())
}

const PREDICT_FLAGS: &[&str] = &[
    "model", "data", "seed", "scale", "test-frac", "threads", "factors", "density", "noise",
];

/// `predict --data grid`: score a saved tensor-chain (v2) artifact on the
/// regenerated grid test split — same determinism contract as the
/// two-factor path (matching score_sum lines prove the bitwise round trip).
fn predict_grid(args: &Args, path: &str, model: TrainedModel) -> Result<(), String> {
    if model.as_tensor().is_none() {
        return Err(format!(
            "--data grid scores tensor-chain models, but {path} holds a {} model",
            model.kind_name()
        ));
    }
    let seed = args.get_u64("seed", 1)?;
    let ds = grid_config(args, seed)?.generate();
    let (_, test) = ds.holdout_split(args.get_f64("test-frac", 0.25)?, seed);
    let compute = Compute::threads(args.get_usize("threads", 1)?);
    let timer = Timer::start();
    let scores = model.predict_tensor(&test, &compute)?;
    let auc_val = auc(&test.labels, &scores);
    println!(
        "model={path} kind={} lambda={} AUC={auc_val:.4} time={:.2}s",
        model.kind_name(),
        model.lambda(),
        timer.elapsed_secs()
    );
    let score_sum: f64 = scores.iter().sum();
    println!("test n={} score_sum={score_sum}", test.n_edges());
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<(), String> {
    args.expect_known("predict", PREDICT_FLAGS)?;
    let path = args.get("model").ok_or("predict requires --model PATH")?;
    let model = TrainedModel::load(Path::new(path))?;
    let data = args.get_str("data", "checker");
    if model.as_tensor().is_some() && args.has("data") && data != "grid" {
        return Err(format!("{path} holds a tensor-chain model; score it with --data grid"));
    }
    if data == "grid" || model.as_tensor().is_some() {
        return predict_grid(args, path, model);
    }
    for flag in ["factors", "density", "noise"] {
        if args.has(flag) {
            return Err(format!("--{flag} applies to --data grid only (got --data {data})"));
        }
    }
    let seed = args.get_u64("seed", 1)?;
    // Defaults mirror `train`, so the same seed reproduces the same split —
    // matching score_sum lines prove the save → load round trip is bitwise.
    let ds = load_dataset(&data, seed, args.get_f64("scale", 0.1)?)?;
    let (_, test) = ds.zero_shot_split(args.get_f64("test-frac", 0.25)?, seed);
    // A clean CLI error (not an internal dimension assert) when the chosen
    // dataset doesn't match the features the artifact was trained on.
    let (d, r) = model.feature_dims();
    if test.start_features.cols() != d || test.end_features.cols() != r {
        return Err(format!(
            "--data {data} carries {}-d start / {}-d end vertex features but the model \
             expects {d}-d / {r}-d — score the dataset family the model was trained on",
            test.start_features.cols(),
            test.end_features.cols()
        ));
    }
    let compute = Compute::threads(args.get_usize("threads", 1)?);
    let timer = Timer::start();
    let scores = model.predict_batch(&test, &compute);
    let auc_val = auc(&test.labels, &scores);
    println!(
        "model={path} kind={} lambda={} AUC={auc_val:.4} time={:.2}s",
        model.kind_name(),
        model.lambda(),
        timer.elapsed_secs()
    );
    let score_sum: f64 = scores.iter().sum();
    println!("test n={} score_sum={score_sum}", test.n_edges());
    Ok(())
}

const CV_FLAGS: &[&str] = &[
    "data", "method", "seed", "scale", "lambda", "lambdas", "kernel", "pairwise", "solver",
    "threads", "fold-workers", "outer", "inner", "iterations", "c", "updates", "k",
];

fn cmd_cv(args: &Args) -> Result<(), String> {
    args.expect_known("cv", CV_FLAGS)?;
    let data = args.get_str("data", "gpcr");
    let method = args.get_str("method", "kronridge");
    let seed = args.get_u64("seed", 1)?;
    let ds = load_dataset(&data, seed, args.get_f64("scale", 1.0)?)?;
    let folds = ds.ninefold_cv(seed);
    // Fold-level parallelism; combine with --threads (per-matvec sharding)
    // carefully — the product of the two should not exceed the core count.
    let fold_workers = args.get_usize("fold-workers", 1)?;
    if args.has("threads") && !args.has("fold-workers") {
        eprintln!(
            "note: `cv --threads` now shards each GVT matvec; use --fold-workers N \
             to train folds concurrently (the pre-engine meaning of --threads)"
        );
    }
    // `--lambdas a,b,c` routes each fold through the batched compute core:
    // one block-CG solve trains the whole λ grid, one multi-RHS prediction
    // scores every model (kronridge only).
    if let Some(spec) = args.get("lambdas") {
        let lambdas: Vec<f64> = spec
            .split(',')
            .filter(|t| !t.is_empty())
            .map(|t| t.parse::<f64>().map_err(|_| format!("bad lambda '{t}'")))
            .collect::<Result<_, _>>()?;
        if lambdas.is_empty() {
            return Err("--lambdas needs at least one value".into());
        }
        if method != "kronridge" {
            return Err(
                "--lambdas (batched λ-grid CV) currently supports --method kronridge".into()
            );
        }
        let kernel = KernelKind::parse(&args.get_str("kernel", "linear"))?;
        let cfg = RidgeConfig {
            kernel_d: kernel,
            kernel_t: kernel,
            iterations: args.get_usize("iterations", 100)?,
            ..Default::default()
        };
        let pairwise = PairwiseKernelKind::parse(&args.get_str("pairwise", "kron"))?;
        // On complete training graphs `auto` solves the whole λ grid in
        // closed form from one eigendecomposition pair per fold.
        let solver = RidgeSolver::parse(&args.get_str("solver", "auto"))?;
        let compute = Compute::threads(args.get_usize("threads", 1)?);
        let results = run_cv_path_jobs(&folds, fold_workers, |tr, te| {
            KronRidge::new(cfg)
                .with_pairwise(pairwise)
                .with_solver(solver)
                .with_compute(compute)
                .fit_path(tr, &lambdas)
                .and_then(|models| kronvt::model::predict_path(&models, te))
                .map(|score_sets| {
                    score_sets.iter().map(|s| auc(&te.labels, s)).collect::<Vec<f64>>()
                })
                .unwrap_or_else(|_| vec![f64::NAN; lambdas.len()])
        });
        for r in &results {
            let row: Vec<String> = r.aucs.iter().map(|a| format!("{a:.4}")).collect();
            println!(
                "fold {} AUCs=[{}] ({} train, {} test edges, {:.2}s)",
                r.fold,
                row.join(", "),
                r.train_edges,
                r.test_edges,
                r.train_secs
            );
        }
        let means = kronvt::coordinator::jobs::mean_auc_path(&results, lambdas.len())?;
        let mut best = 0;
        for (j, &m) in means.iter().enumerate() {
            println!("lambda={:<12} mean AUC={m:.4}", lambdas[j]);
            // NaN means (diverged folds) must never win — or block a later
            // finite mean from displacing them.
            if !m.is_nan() && (means[best].is_nan() || m > means[best]) {
                best = j;
            }
        }
        println!(
            "best lambda={} (mean AUC {:.4} over {} folds)",
            lambdas[best],
            means[best],
            results.len()
        );
        return Ok(());
    }
    // Parse every flag once, up front: a typo fails the command here instead
    // of being folded into a NaN AUC by the per-fold error handling below.
    let compute = Compute::threads(args.get_usize("threads", 1)?);
    let plan = parse_method(&method, args, compute)?;
    let results = run_cv_jobs(&folds, fold_workers, |tr, te| {
        run_plan(&plan, tr, te, &compute)
            .map(|scores| auc(&te.labels, &scores))
            .unwrap_or(f64::NAN)
    });
    for r in &results {
        println!(
            "fold {} AUC={:.4} ({} train, {} test edges, {:.2}s)",
            r.fold, r.auc, r.train_edges, r.test_edges, r.train_secs
        );
    }
    let mean = kronvt::coordinator::jobs::mean_auc(&results);
    println!("mean AUC over {} folds: {mean:.4}", results.len());
    Ok(())
}

const SERVE_FLAGS: &[&str] = &[
    "data", "seed", "scale", "lambda", "threads", "pairwise", "model", "requests",
    "serve-workers", "cache-vertices", "max-queue", "vertex-pool", "request-timeout-ms",
    "swap-watch", "swap-poll-ms", "listen", "shards", "serve-secs",
];

/// `serve --shards A,B,...`: route demo traffic across running listeners
/// (started with `serve --listen`) through the vertex-affine
/// [`ShardRouter`] — no model is loaded; feature dims come from the
/// protocol's `info` operation.
fn cmd_serve_shards(args: &Args, shards_csv: &str) -> Result<(), String> {
    for flag in [
        "data", "scale", "lambda", "pairwise", "model", "serve-workers", "cache-vertices",
        "max-queue", "request-timeout-ms", "swap-watch", "swap-poll-ms", "listen",
        "serve-secs", "threads",
    ] {
        if args.has(flag) {
            return Err(format!(
                "--{flag} has no effect with --shards (the shard processes own their \
                 models and serving config); drop it"
            ));
        }
    }
    let seed = args.get_u64("seed", 1)?;
    let addrs: Vec<&str> = shards_csv.split(',').filter(|a| !a.is_empty()).collect();
    if addrs.is_empty() {
        return Err("--shards needs a comma-separated list of host:port addresses".into());
    }
    // Probe feature dims over the wire so traffic is shaped correctly.
    let mut dims = None;
    for addr in &addrs {
        if let Ok(((d, r), generation)) = NetClient::connect(addr).and_then(|mut c| c.info()) {
            println!("shard {addr}: dims ({d}, {r}), generation {generation}");
            dims = Some((d, r));
            break;
        }
    }
    let (d, r) = dims.ok_or("no shard answered the dims probe (op \"info\")")?;
    let backends: Vec<Box<dyn ShardBackend>> =
        addrs.iter().map(|a| Box::new(NetShard::new(a)) as Box<dyn ShardBackend>).collect();
    let router = ShardRouter::new(backends, ShardRouterConfig::default())?;

    let n_requests = args.get_usize("requests", 100)?;
    let pool_size = args.get_usize("vertex-pool", 16)?.max(4);
    let mut rng = Pcg32::seeded(seed ^ 0x5E7);
    let start_pool: Vec<Vec<f64>> =
        (0..pool_size).map(|_| rng.uniform_vec(d, 0.0, 100.0)).collect();
    let end_pool: Vec<Vec<f64>> = (0..pool_size).map(|_| rng.uniform_vec(r, 0.0, 100.0)).collect();
    let timer = Timer::start();
    let mut scored = 0usize;
    for _ in 0..n_requests {
        let sf: Vec<Vec<f64>> = (0..4).map(|_| start_pool[rng.below(pool_size)].clone()).collect();
        let ef: Vec<Vec<f64>> = (0..4).map(|_| end_pool[rng.below(pool_size)].clone()).collect();
        let edges: Vec<(u32, u32)> =
            (0..8).map(|_| (rng.below(4) as u32, rng.below(4) as u32)).collect();
        let reply = router.predict(&sf, &ef, &edges, None)?;
        let scores = reply.result.map_err(|e| e.to_string())?;
        assert_eq!(scores.len(), 8);
        scored += scores.len();
    }
    let st = router.stats();
    use std::sync::atomic::Ordering::Relaxed;
    println!(
        "routed {n_requests} requests ({scored} edges) over {} shard(s) in {:.3}s — \
         {} scattered, {} shard failures, {} ejections, {} re-probes, {} healthy",
        router.shard_count(),
        timer.elapsed_secs(),
        st.scattered.load(Relaxed),
        st.shard_failures.load(Relaxed),
        st.ejections.load(Relaxed),
        st.reprobes.load(Relaxed),
        router.healthy_count(),
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    args.expect_known("serve", SERVE_FLAGS)?;
    if let Some(shards) = args.get("shards") {
        let shards = shards.to_string();
        return cmd_serve_shards(args, &shards);
    }
    let seed = args.get_u64("seed", 1)?;
    let compute = Compute::threads(args.get_usize("threads", 0)?)
        .with_cache_vertices(args.get_usize("cache-vertices", 1024)?);

    // `--model` serves a saved artifact without retraining — the portable
    // train-once / serve-anywhere path; otherwise train a demo model.
    let model: TrainedModel = match args.get("model") {
        Some(path) => {
            // These flags configure the demo-training branch only; with
            // --model the artifact's own settings apply, so accepting them
            // silently would contradict the fail-loudly flag policy.
            for flag in ["data", "scale", "lambda", "pairwise"] {
                if args.has(flag) {
                    return Err(format!(
                        "--{flag} has no effect with --model (the saved artifact's own \
                         training settings apply); drop it or serve without --model"
                    ));
                }
            }
            let model = TrainedModel::load(Path::new(path))?;
            println!("loaded {} model from {path} (lambda={})", model.kind_name(), model.lambda());
            model
        }
        None => {
            let ds =
                load_dataset(&args.get_str("data", "checker"), seed, args.get_f64("scale", 0.06)?)?;
            let (train, _) = ds.zero_shot_split(0.25, seed);
            let pairwise = PairwiseKernelKind::parse(&args.get_str("pairwise", "kron"))?;
            println!(
                "training model on {} edges... (pass --model PATH to serve a saved artifact)",
                train.n_edges()
            );
            Learner::svm()
                .lambda(args.get_f64("lambda", 2f64.powi(-7))?)
                .kernel(KernelKind::Gaussian { gamma: 1.0 })
                .pairwise(pairwise)
                .compute(compute)
                .fit(&train)?
        }
    };
    let (d, r) = model.feature_dims();
    let server: PredictServer = model.serve(ServerConfig {
        workers: args.get_usize("serve-workers", 2)?,
        max_queue: args.get_usize("max-queue", 1024)?,
        request_timeout_ms: args.get_u64("request-timeout-ms", 0)?,
        compute,
        ..Default::default()
    })?;
    // Shared so the TCP front-end's connection threads can score against
    // the same server the watcher hot-swaps.
    let server = std::sync::Arc::new(server);

    // `--listen ADDR` opens the TCP/JSON-lines front-end (protocol spec in
    // docs/SERVING.md); the demo traffic below then exercises the full
    // wire path through a loopback NetClient instead of in-process calls.
    let net = match args.get("listen") {
        Some(addr) => {
            let net = NetServer::start(
                server.clone(),
                NetServerConfig { addr: addr.to_string(), ..Default::default() },
            )?;
            println!("listening on {} (newline-delimited JSON; see docs/SERVING.md)", net.local_addr());
            Some(net)
        }
        None => {
            if args.has("serve-secs") {
                return Err("--serve-secs needs --listen (nothing to keep open otherwise)".into());
            }
            None
        }
    };

    // Real serving traffic repeats vertices across requests (the same drug
    // against new targets, the same user against new items); draw request
    // vertices from a bounded pool so the kernel-row cache sees that pattern.
    let n_requests = args.get_usize("requests", 100)?;
    let pool_size = args.get_usize("vertex-pool", 16)?.max(4);
    let mut rng = Pcg32::seeded(seed ^ 0x5E7);
    let start_pool: Vec<Vec<f64>> =
        (0..pool_size).map(|_| rng.uniform_vec(d, 0.0, 100.0)).collect();
    let end_pool: Vec<Vec<f64>> = (0..pool_size).map(|_| rng.uniform_vec(r, 0.0, 100.0)).collect();
    let timer = Timer::start();
    // `--swap-watch PATH` hot-swaps the serving model whenever the artifact
    // at PATH changes (mtime poll every --swap-poll-ms, default 200) —
    // zero downtime, in-flight batches finish on the generation they
    // started with. Scoped so the watcher borrows the server and always
    // joins before shutdown.
    let swap_poll_ms = args.get_u64("swap-poll-ms", 200)?.max(10);
    if args.has("swap-poll-ms") && !args.has("swap-watch") {
        return Err("--swap-poll-ms needs --swap-watch (it is the watcher's poll interval)".into());
    }
    let stop = std::sync::atomic::AtomicBool::new(false);
    std::thread::scope(|scope| -> Result<(), String> {
        if let Some(watch) = args.get("swap-watch") {
            let (server, stop) = (&server, &stop);
            scope.spawn(move || {
                let path = Path::new(watch);
                let mtime = |p: &Path| std::fs::metadata(p).and_then(|m| m.modified()).ok();
                let mut last = mtime(path);
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    std::thread::sleep(std::time::Duration::from_millis(swap_poll_ms));
                    let now = mtime(path);
                    if now.is_some() && now != last {
                        last = now;
                        // A failed load/swap must not kill serving: report
                        // it and keep the current generation live.
                        let before = server
                            .stats()
                            .generation
                            .load(std::sync::atomic::Ordering::Relaxed);
                        match TrainedModel::load(path).and_then(|m| server.swap_model(m)) {
                            Ok(generation) => println!(
                                "hot-swap {watch}: generation {before} -> {generation}"
                            ),
                            Err(e) => eprintln!("swap-watch {watch}: {e}"),
                        }
                    }
                }
            });
        }
        let run = (|| -> Result<(), String> {
            // With --listen, demo traffic goes over real TCP through the
            // listener — a self-contained smoke test of the wire path.
            let mut client = match &net {
                Some(net) => Some(NetClient::connect(&net.local_addr().to_string())?),
                None => None,
            };
            for _ in 0..n_requests {
                let sf: Vec<Vec<f64>> =
                    (0..4).map(|_| start_pool[rng.below(pool_size)].clone()).collect();
                let ef: Vec<Vec<f64>> =
                    (0..4).map(|_| end_pool[rng.below(pool_size)].clone()).collect();
                let edges: Vec<(u32, u32)> =
                    (0..8).map(|_| (rng.below(4) as u32, rng.below(4) as u32)).collect();
                let scores = match client.as_mut() {
                    Some(c) => {
                        c.predict(&sf, &ef, &edges, None)?.result.map_err(String::from)?
                    }
                    None => server.predict_blocking(sf, ef, edges)?,
                };
                assert_eq!(scores.len(), 8);
            }
            // `--serve-secs S` keeps the listener open for external
            // clients (nc, curl, another `serve --shards` process) after
            // the demo traffic.
            let serve_secs = args.get_u64("serve-secs", 0)?;
            if serve_secs > 0 {
                println!("serving external traffic for {serve_secs}s...");
                std::thread::sleep(std::time::Duration::from_secs(serve_secs));
            }
            Ok(())
        })();
        // Set on every exit path, or a `?` above would leave the watcher
        // spinning and the scope joining forever.
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        run
    })?;
    let secs = timer.elapsed_secs();
    let st = server.stats();
    let hits = st.cache_hits.load(std::sync::atomic::Ordering::Relaxed);
    let misses = st.cache_misses.load(std::sync::atomic::Ordering::Relaxed);
    println!(
        "served {} requests ({} edges) in {:.3}s — {:.0} edges/s, {} batches",
        st.requests.load(std::sync::atomic::Ordering::Relaxed),
        st.edges_scored.load(std::sync::atomic::Ordering::Relaxed),
        secs,
        st.edges_scored.load(std::sync::atomic::Ordering::Relaxed) as f64 / secs,
        st.batches.load(std::sync::atomic::Ordering::Relaxed),
    );
    println!(
        "kernel-row cache: {hits} hits / {misses} misses ({:.0}% hit rate)",
        100.0 * hits as f64 / (hits + misses).max(1) as f64
    );
    println!(
        "robustness: generation {} — {} deadline-expired ({} shed unscored), {} overload-rejected, \
         {} worker panics / {} respawns",
        st.generation.load(std::sync::atomic::Ordering::Relaxed),
        st.deadline_expired.load(std::sync::atomic::Ordering::Relaxed),
        st.shed.load(std::sync::atomic::Ordering::Relaxed),
        st.rejected_overload.load(std::sync::atomic::Ordering::Relaxed),
        st.panics.load(std::sync::atomic::Ordering::Relaxed),
        st.respawns.load(std::sync::atomic::Ordering::Relaxed),
    );
    // Drain the network layer first (connection threads hold Arc clones of
    // the server), then the server itself.
    if let Some(net) = net {
        let ns = net.stats();
        println!(
            "wire: {} connection(s), {} line(s), {} bad line(s), {} replies ({} errors)",
            ns.connections.load(std::sync::atomic::Ordering::Relaxed),
            ns.lines.load(std::sync::atomic::Ordering::Relaxed),
            ns.bad_lines.load(std::sync::atomic::Ordering::Relaxed),
            ns.replies.load(std::sync::atomic::Ordering::Relaxed),
            ns.wire_errors.load(std::sync::atomic::Ordering::Relaxed),
        );
        net.shutdown();
    }
    if let Ok(server) = std::sync::Arc::try_unwrap(server) {
        server.shutdown();
    }
    Ok(())
}

const ARTIFACTS_FLAGS: &[&str] = &["dir"];

fn cmd_artifacts(args: &Args) -> Result<(), String> {
    args.expect_known("artifacts", ARTIFACTS_FLAGS)?;
    let dir = args.get_str("dir", "artifacts");
    if !kronvt::runtime::ArtifactRegistry::available(&dir) {
        println!("no artifact manifest at {dir}/ — run `make artifacts` (native paths still work)");
        return Ok(());
    }
    // List the manifest without opening a PJRT client, so this works even in
    // builds without the `pjrt` feature.
    let manifest = kronvt::runtime::ArtifactManifest::load(std::path::Path::new(&dir))
        .map_err(|e| e.to_string())?;
    println!("{} artifacts in {dir}/:", manifest.artifacts.len());
    for a in &manifest.artifacts {
        println!("  {:<40} kind={:<16} file={}", a.name, a.kind, a.file);
    }
    match kronvt::runtime::ArtifactRegistry::open(&dir) {
        Ok(_) => println!("PJRT client: available"),
        Err(err) => println!("PJRT client: unavailable ({err}); native GVT paths still work"),
    }
    Ok(())
}

fn usage() -> ! {
    eprintln!(
        "usage: kronvt <command> [options]\n\
         commands:\n\
           datasets   print Table-5 style dataset statistics\n\
           train      train one method on a zero-shot split, report AUC; --save PATH\n\
                      writes the portable kronvt-model/v1 artifact\n\
           predict    load --model PATH in a fresh process and score the test split\n\
                      (bitwise identical to the model that was saved)\n\
           cv         9-fold zero-shot cross-validation (Fig. 2)\n\
           serve      batched zero-shot prediction server; --model PATH serves a\n\
                      saved artifact without retraining\n\
           artifacts  show the PJRT artifact registry status\n\
         common flags: --data checker|checker+|homo|ki|gpcr|ic|e|grid --method kronsvm|kronridge|libsvm|sgd-hinge|sgd-logistic|knn\n\
                       --kernel linear|gaussian:G --lambda L --seed S --scale F\n\
                       --pairwise kron|symmetric|antisymmetric|cartesian\n\
                                     pairwise kernel family (kronsvm/kronridge; symmetric and\n\
                                     antisymmetric need one shared vertex domain, e.g. --data homo)\n\
                       --solver auto|exact|minres|cg|precond-cg|stochastic\n\
                                     kronridge dual solver; auto takes the closed-form\n\
                                     eigendecomposition path on complete training graphs;\n\
                                     stochastic is the mini-batch sampled-GVT trainer\n\
                       --batch-edges N    (--solver stochastic) edges per mini-batch (default 512)\n\
                       --epochs N         (--solver stochastic) full data passes (default 30;\n\
                                          --seed, default 1, fixes the sampling schedule)\n\
                       --threads N   GVT matvec worker threads (0 = all cores; identical results, just faster)\n\
                       --fold-workers N   (cv only) train folds concurrently\n\
                       --lambdas a,b,c    (cv + kronridge) batched λ-grid CV: one block-CG solve\n\
                                          and one multi-RHS prediction per fold covers every λ\n\
         grid flags:   --data grid takes the D-way tensor-chain path (train/predict, kronridge):\n\
                       --factors AxBxC    per-mode vertex counts (default 20x15x12; any D >= 2)\n\
                       --density F        labeled fraction of the grid cells (default 0.25)\n\
                       --noise F          label-flip probability (default 0.2)\n\
         model flags:  --save PATH   (train) persist the trained model artifact\n\
                       --model PATH  (predict/serve) load a saved artifact\n\
         serve flags:  --serve-workers N   scoring-pool threads (batches scored concurrently)\n\
                       --cache-vertices N  per-side kernel-row LRU capacity (0 = off)\n\
                       --max-queue N       request-queue bound (backpressure)\n\
                       --vertex-pool P     distinct request vertices per side (repeat-vertex traffic)\n\
                       --request-timeout-ms MS  default per-request deadline (0 = none); expired\n\
                                           requests answer DeadlineExceeded and are shed unscored\n\
                       --swap-watch PATH   hot-swap the serving model when the artifact at PATH\n\
                                           changes (zero downtime, generation counter in stats)\n\
                       --swap-poll-ms MS   swap-watch mtime poll interval (default 200, min 10)\n\
                       --requests N        demo requests to drive through the server (default 100)\n\
         network flags (docs/SERVING.md):\n\
                       --listen ADDR       serve the newline-delimited JSON protocol on ADDR\n\
                                           (host:port; port 0 picks a free port and prints it);\n\
                                           demo traffic then runs over loopback TCP\n\
                       --serve-secs S      with --listen: stay up S seconds for external clients\n\
                                           after the demo traffic\n\
                       --shards A,B,...    route demo traffic across running --listen processes\n\
                                           by start-vertex hash (scatter/merge, failure ejection);\n\
                                           no model is loaded — dims come from the wire"
    );
    std::process::exit(2)
}

fn main() {
    let args = Args::parse();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    let result = match cmd {
        "datasets" => cmd_datasets(&args),
        "train" => cmd_train(&args),
        "predict" => cmd_predict(&args),
        "cv" => cmd_cv(&args),
        "serve" => cmd_serve(&args),
        "artifacts" => cmd_artifacts(&args),
        _ => usage(),
    };
    if let Err(err) = result {
        eprintln!("error: {err}");
        std::process::exit(1);
    }
}
