//! Wall-clock timing helpers and a tiny benchmark runner (criterion is not
//! available offline; `cargo bench` targets use [`BenchRunner`] instead).

use std::time::{Duration, Instant};

/// Simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds since construction.
    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed time since construction.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
}

/// Time a closure, returning (result, seconds).
pub fn timeit<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_secs())
}

/// Statistics over repeated measurements.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    /// Number of samples taken.
    pub iters: usize,
    /// Mean seconds per sample.
    pub mean_secs: f64,
    /// Fastest sample (the number benches report).
    pub min_secs: f64,
    /// Slowest sample.
    pub max_secs: f64,
    /// Population standard deviation of the samples.
    pub stddev_secs: f64,
}

impl BenchStats {
    /// Aggregate raw per-sample timings (panics on empty input).
    pub fn from_samples(samples: &[f64]) -> Self {
        assert!(!samples.is_empty());
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
        BenchStats {
            iters: samples.len(),
            mean_secs: mean,
            min_secs: samples.iter().cloned().fold(f64::INFINITY, f64::min),
            max_secs: samples.iter().cloned().fold(0.0, f64::max),
            stddev_secs: var.sqrt(),
        }
    }
}

/// Minimal benchmark runner: warms up, then samples until `target_time` is
/// spent or `max_iters` reached, whichever comes first (min 3 samples).
pub struct BenchRunner {
    /// Untimed warm-up runs before sampling.
    pub warmup: usize,
    /// Sampling stops once this much time is spent (min 3 samples).
    pub target_time: Duration,
    /// Hard cap on samples.
    pub max_iters: usize,
}

impl Default for BenchRunner {
    fn default() -> Self {
        BenchRunner { warmup: 1, target_time: Duration::from_secs(2), max_iters: 50 }
    }
}

impl BenchRunner {
    /// Faster settings for CI / container runs (0.5 s budget, 20 samples).
    pub fn quick() -> Self {
        BenchRunner { warmup: 1, target_time: Duration::from_millis(500), max_iters: 20 }
    }

    /// Run `f` repeatedly and report stats. `f` should perform one complete
    /// unit of the benchmarked work.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> BenchStats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let budget = Timer::start();
        while samples.len() < 3
            || (budget.elapsed() < self.target_time && samples.len() < self.max_iters)
        {
            let t = Timer::start();
            std::hint::black_box(f());
            samples.push(t.elapsed_secs());
        }
        BenchStats::from_samples(&samples)
    }
}

/// Format seconds human-readably (ns/µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timeit_measures() {
        let ((), secs) = timeit(|| std::thread::sleep(Duration::from_millis(10)));
        assert!(secs >= 0.009, "secs={secs}");
    }

    #[test]
    fn bench_stats() {
        let s = BenchStats::from_samples(&[1.0, 2.0, 3.0]);
        assert_eq!(s.iters, 3);
        assert!((s.mean_secs - 2.0).abs() < 1e-12);
        assert!((s.min_secs - 1.0).abs() < 1e-12);
        assert!((s.max_secs - 3.0).abs() < 1e-12);
    }

    #[test]
    fn runner_runs_at_least_three() {
        let r = BenchRunner { warmup: 0, target_time: Duration::from_millis(1), max_iters: 5 };
        let stats = r.run(|| 1 + 1);
        assert!(stats.iters >= 3);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(2.5).ends_with('s'));
        assert!(fmt_secs(0.002).ends_with("ms"));
        assert!(fmt_secs(2e-6).ends_with("µs"));
        assert!(fmt_secs(5e-9).ends_with("ns"));
    }
}
