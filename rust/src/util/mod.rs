//! Small self-contained utilities (the build is fully offline, so the crate
//! hand-rolls what would normally come from `rand`, `serde_json`, `clap`,
//! `criterion`, …).

pub mod rng;
pub mod timer;
pub mod json;
pub mod args;
pub mod logging;
pub mod proptest;
