//! Minimal command-line argument parsing (clap is not available offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments, which covers every binary in this crate. Typed getters return
//! a clear error on unparsable input (`--threads foo` fails loudly instead
//! of silently falling back to the default), and [`Args::expect_known`]
//! rejects flags a subcommand does not understand, so typos like `--lamda`
//! cannot be ignored.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional arguments, in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` / boolean `--flag` pairs.
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an explicit iterator (excluding argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(item) = iter.next() {
            if let Some(stripped) = item.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(item);
            }
        }
        out
    }

    /// Parse from the process environment (skips argv[0]).
    pub fn parse() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    /// Whether `--key` was passed (with or without a value).
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Raw value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// String value of `--key`, or `default`.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// `usize` value of `--key`, or `default` when absent. Unparsable input
    /// is an **error**, never a silent fallback.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key}: expected a non-negative integer, got '{v}'")),
        }
    }

    /// `u64` value of `--key`, or `default` when absent. Unparsable input is
    /// an **error**, never a silent fallback.
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key}: expected a non-negative integer, got '{v}'")),
        }
    }

    /// `f64` value of `--key`, or `default` when absent. Unparsable input is
    /// an **error**, never a silent fallback.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| format!("--{key}: expected a number, got '{v}'"))
            }
        }
    }

    /// Boolean value of `--key` (`true|1|yes` / `false|0|no`), or `default`
    /// when absent. Anything else is an **error**.
    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool, String> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(format!("--{key}: expected true/false, got '{v}'")),
        }
    }

    /// Reject any flag not in `allowed` — per-subcommand strictness, so a
    /// typo like `--lamda 0.1` fails loudly instead of being ignored.
    /// `context` names the subcommand for the error message.
    pub fn expect_known(&self, context: &str, allowed: &[&str]) -> Result<(), String> {
        for key in self.flags.keys() {
            if !allowed.contains(&key.as_str()) {
                let mut known: Vec<String> =
                    allowed.iter().map(|a| format!("--{a}")).collect();
                known.sort();
                return Err(format!(
                    "unknown flag --{key} for `{context}` (known flags: {})",
                    known.join(", ")
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(items: &[&str]) -> Args {
        Args::parse_from(items.iter().map(|s| s.to_string()))
    }

    #[test]
    fn key_value_pairs() {
        let a = parse(&["--m", "100", "--lambda=0.5", "train"]);
        assert_eq!(a.get_usize("m", 0).unwrap(), 100);
        assert_eq!(a.get_f64("lambda", 0.0).unwrap(), 0.5);
        assert_eq!(a.positional, vec!["train"]);
    }

    #[test]
    fn bool_flags() {
        let a = parse(&["--verbose", "--quiet", "--x", "1"]);
        assert!(a.has("verbose"));
        assert!(a.get_bool("verbose", false).unwrap());
        assert!(a.has("quiet"));
        assert_eq!(a.get_usize("x", 0).unwrap(), 1);
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse(&["--first", "--last"]);
        assert_eq!(a.get("first"), Some("true"));
        assert_eq!(a.get("last"), Some("true"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert_eq!(a.get_str("name", "dflt"), "dflt");
        assert!(!a.get_bool("flag", false).unwrap());
        assert_eq!(a.get_u64("seed", 42).unwrap(), 42);
        assert_eq!(a.get_f64("lambda", 0.25).unwrap(), 0.25);
    }

    #[test]
    fn negative_number_values() {
        let a = parse(&["--lambda=-0.5"]);
        assert_eq!(a.get_f64("lambda", 0.0).unwrap(), -0.5);
    }

    #[test]
    fn unparsable_values_error_instead_of_defaulting() {
        // regression: `--threads foo` used to silently fall back to the
        // default, hiding the typo from the user
        let a = parse(&["--threads", "foo", "--lambda", "abc", "--seed=1.5", "--v", "maybe"]);
        let err = a.get_usize("threads", 1).unwrap_err();
        assert!(err.contains("--threads") && err.contains("foo"), "{err}");
        let err = a.get_f64("lambda", 1.0).unwrap_err();
        assert!(err.contains("--lambda") && err.contains("abc"), "{err}");
        assert!(a.get_u64("seed", 1).is_err(), "1.5 is not a u64");
        assert!(a.get_bool("v", false).is_err());
        // negative values are invalid for the unsigned getters
        assert!(parse(&["--n=-3"]).get_usize("n", 0).is_err());
    }

    #[test]
    fn unknown_flags_are_rejected_per_subcommand() {
        let a = parse(&["--lamda", "0.1", "--seed", "3"]);
        let err = a.expect_known("train", &["lambda", "seed"]).unwrap_err();
        assert!(err.contains("--lamda") && err.contains("train"), "{err}");
        assert!(err.contains("--lambda"), "error lists the known flags: {err}");
        assert!(parse(&["--seed", "3"]).expect_known("train", &["lambda", "seed"]).is_ok());
        assert!(parse(&[]).expect_known("train", &[]).is_ok());
    }
}
