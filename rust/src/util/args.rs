//! Minimal command-line argument parsing (clap is not available offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments, which covers every binary in this crate.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional arguments, in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` / boolean `--flag` pairs.
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an explicit iterator (excluding argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(item) = iter.next() {
            if let Some(stripped) = item.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(item);
            }
        }
        out
    }

    /// Parse from the process environment (skips argv[0]).
    pub fn parse() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    /// Whether `--key` was passed (with or without a value).
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Raw value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// String value of `--key`, or `default`.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// `usize` value of `--key`, or `default` (also on parse failure).
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// `u64` value of `--key`, or `default` (also on parse failure).
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// `f64` value of `--key`, or `default` (also on parse failure).
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Boolean value of `--key` (`true|1|yes` / `false|0|no`), or `default`.
    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) => default,
            None => default,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(items: &[&str]) -> Args {
        Args::parse_from(items.iter().map(|s| s.to_string()))
    }

    #[test]
    fn key_value_pairs() {
        let a = parse(&["--m", "100", "--lambda=0.5", "train"]);
        assert_eq!(a.get_usize("m", 0), 100);
        assert_eq!(a.get_f64("lambda", 0.0), 0.5);
        assert_eq!(a.positional, vec!["train"]);
    }

    #[test]
    fn bool_flags() {
        let a = parse(&["--verbose", "--quiet", "--x", "1"]);
        assert!(a.has("verbose"));
        assert!(a.get_bool("verbose", false));
        assert!(a.has("quiet"));
        assert_eq!(a.get_usize("x", 0), 1);
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse(&["--first", "--last"]);
        assert_eq!(a.get("first"), Some("true"));
        assert_eq!(a.get("last"), Some("true"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_str("name", "dflt"), "dflt");
        assert!(!a.get_bool("flag", false));
    }

    #[test]
    fn negative_number_values() {
        let a = parse(&["--lambda=-0.5"]);
        assert_eq!(a.get_f64("lambda", 0.0), -0.5);
    }
}
