//! Deterministic PCG32 random number generator.
//!
//! All stochastic components of the library (data generators, splits, SGD,
//! property tests) are seeded through this generator so every experiment is
//! exactly reproducible. The implementation is PCG-XSH-RR 64/32 (O'Neill,
//! 2014) — tiny, fast, and statistically solid for simulation workloads.

/// PCG32 generator (PCG-XSH-RR 64/32).
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Create a generator from a seed with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Split off an independent child generator (new stream derived from the
    /// current state). Useful for giving each CV fold / thread its own RNG.
    pub fn split(&mut self) -> Pcg32 {
        let seed = self.next_u64();
        let stream = self.next_u64();
        Pcg32::new(seed, stream)
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit output (two 32-bit draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in `[0, 1)` (53-bit resolution).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Unbiased uniform integer in `[0, bound)` (Lemire rejection method).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "below(0) is meaningless");
        let bound = bound as u64;
        // 64-bit Lemire: multiply-shift with rejection.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= lo.wrapping_sub(bound) % bound {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal draw (Box–Muller; one value per call, simple > fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Vector of uniforms in `[lo, hi)`.
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.uniform_in(lo, hi)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut rng = Pcg32::seeded(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Pcg32::seeded(3);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[rng.below(10)] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg32::seeded(5);
        let s = rng.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(9);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Pcg32::seeded(1234);
        let mut a = root.split();
        let mut b = root.split();
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
