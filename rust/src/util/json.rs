//! Minimal JSON reader/writer (no serde offline).
//!
//! Supports the full JSON grammar needed by the artifact manifest and the
//! experiment result files: objects, arrays, strings (with escapes), numbers,
//! booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys for deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value as `usize`, if this is a number that is an exact
    /// non-negative integer in range. Fractional, negative, non-finite, or
    /// too-large numbers return `None` — `{"threads": -1}` must be rejected
    /// by the caller, not silently truncated to a garbage value.
    pub fn as_usize(&self) -> Option<usize> {
        let n = self.as_f64()?;
        // NaN fails the fract test; `usize::MAX as f64` rounds to 2^64, so
        // the strict upper bound also rejects the saturating-cast edge case.
        if n >= 0.0 && n.fract() == 0.0 && n < usize::MAX as f64 {
            Some(n as usize)
        } else {
            None
        }
    }

    /// Numeric value as `u64`, under the same strictness as
    /// [`Json::as_usize`]: exact non-negative integers only, and the
    /// strict `< 2^64` bound rejects the saturating-cast edge case
    /// (`u64::MAX as f64` rounds up to 2^64). Wire-protocol fields such as
    /// request ids and `deadline_ms` go through this.
    pub fn as_u64(&self) -> Option<u64> {
        let n = self.as_f64()?;
        if n >= 0.0 && n.fract() == 0.0 && n < u64::MAX as f64 {
            Some(n as u64)
        } else {
            None
        }
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array items, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Key–value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object member by key (None for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object from `(key, value)` pairs (convenience constructor).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Numeric array from a slice (convenience constructor).
    pub fn num_arr(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Allocation-free scan for any non-finite number — the fast path of
    /// [`Json::dump`]; the path-building pass below runs only on failure.
    fn has_non_finite(&self) -> bool {
        match self {
            Json::Num(n) => !n.is_finite(),
            Json::Arr(items) => items.iter().any(Json::has_non_finite),
            Json::Obj(map) => map.values().any(Json::has_non_finite),
            _ => false,
        }
    }

    /// Path of the first non-finite number in the tree (`"a.b[3]"`), if any.
    fn first_non_finite(&self, path: &str) -> Option<String> {
        match self {
            Json::Num(n) if !n.is_finite() => Some(if path.is_empty() {
                "<root>".to_string()
            } else {
                path.to_string()
            }),
            Json::Arr(items) => items
                .iter()
                .enumerate()
                .find_map(|(i, v)| v.first_non_finite(&format!("{path}[{i}]"))),
            Json::Obj(map) => map.iter().find_map(|(k, v)| {
                let sub = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
                v.first_non_finite(&sub)
            }),
            _ => None,
        }
    }

    /// Serialize to a JSON string, **rejecting non-finite numbers**: JSON has
    /// no `NaN`/`Infinity` tokens, so a tree containing one cannot be written
    /// faithfully — this returns an error naming the offending path instead
    /// of silently emitting a lossy placeholder.
    ///
    /// Finite numbers use shortest-round-trip decimal formatting (Rust's
    /// `Display` for `f64`, plus an exact-integer fast path and a `-0`
    /// special case), so `Json::parse(&v.dump()?)` reproduces every `f64`
    /// **bit for bit** — the property the `kronvt-model/v1` artifacts rely
    /// on.
    pub fn dump(&self) -> Result<String, String> {
        if self.has_non_finite() {
            let path = self
                .first_non_finite("")
                .expect("non-finite number located by the fast scan");
            return Err(format!(
                "cannot serialize non-finite number at '{path}' (JSON has no NaN/inf)"
            ));
        }
        Ok(self.to_string())
    }
}

/// Read–modify–write one section of a `BENCH_*.json` results file (the
/// repo's convention for tracking the perf trajectory, see
/// `docs/BENCHMARKS.md`): parse `path` if it exists (an unreadable or
/// non-object file is replaced by an empty object), set the top-level `key`
/// to `value`, and write the result back. Each bench owns one top-level key,
/// so different benches can share a file without clobbering each other.
pub fn update_json_file(path: &std::path::Path, key: &str, value: Json) -> std::io::Result<()> {
    let mut root = std::fs::read_to_string(path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .and_then(|json| json.as_obj().cloned())
        .unwrap_or_default();
    root.insert(key.to_string(), value);
    let text = Json::Obj(root)
        .dump()
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
    std::fs::write(path, format!("{text}\n"))
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest).map_err(|e| e.to_string())?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).map_err(|e| e.to_string())?;
        text.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{text}': {e}"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if !n.is_finite() {
                    // NaN/±inf are not valid JSON; `Display` cannot fail, so
                    // degrade to `null` here — [`Json::dump`] rejects these
                    // trees up front with a proper error.
                    write!(f, "null")
                } else if *n == 0.0 && n.is_sign_negative() {
                    // the exact-integer fast path would lose the sign of -0.0
                    // (`-0.0 as i64 == 0`), breaking bit-exact round-trips
                    write!(f, "-0")
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    // Rust's float Display is shortest-round-trip: the parser
                    // recovers the identical bit pattern
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\nthere", "d": null}, "e": true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 42, "s": "x", "a": [1,2]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(42));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn as_usize_rejects_non_integers() {
        // regression: `"threads": -1` used to truncate to a garbage value
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-0.25").unwrap().as_usize(), None);
        assert_eq!(Json::parse("1e30").unwrap().as_usize(), None, "beyond usize range");
        assert_eq!(Json::Num(f64::NAN).as_usize(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_usize(), None);
        assert_eq!(Json::Num(18_446_744_073_709_551_616.0).as_usize(), None, "2^64 saturates");
        // exact integers still pass, including 0 and -0
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
        assert_eq!(Json::Num(-0.0).as_usize(), Some(0));
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
        assert_eq!(Json::parse("1e3").unwrap().as_usize(), Some(1000));
        assert_eq!(Json::Str("3".into()).as_usize(), None, "strings are not numbers");
    }

    #[test]
    fn as_u64_strictness_matches_as_usize() {
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
        assert_eq!(Json::Num(f64::NAN).as_u64(), None);
        assert_eq!(Json::Num(18_446_744_073_709_551_616.0).as_u64(), None, "2^64 saturates");
        assert_eq!(Json::parse("0").unwrap().as_u64(), Some(0));
        assert_eq!(Json::parse("9007199254740992").unwrap().as_u64(), Some(1 << 53));
        assert_eq!(Json::from(7_u64), Json::Num(7.0));
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""tab\there A""#).unwrap();
        assert_eq!(v.as_str(), Some("tab\there A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn update_json_file_merges_sections() {
        let path = std::env::temp_dir().join("kronvt_bench_json_test.json");
        let _ = std::fs::remove_file(&path);
        update_json_file(&path, "micro", Json::obj(vec![("speedup", Json::Num(2.5))])).unwrap();
        update_json_file(&path, "checker", Json::obj(vec![("speedup", Json::Num(1.9))])).unwrap();
        // overwrite one section, keep the other
        update_json_file(&path, "micro", Json::obj(vec![("speedup", Json::Num(3.0))])).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("micro").unwrap().get("speedup").unwrap().as_f64(), Some(3.0));
        assert_eq!(parsed.get("checker").unwrap().get("speedup").unwrap().as_f64(), Some(1.9));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn dump_rejects_non_finite_numbers_with_path() {
        let err = Json::Num(f64::NAN).dump().unwrap_err();
        assert!(err.contains("<root>"), "{err}");
        let nested = Json::obj(vec![(
            "coef",
            Json::Arr(vec![Json::Num(1.0), Json::Num(f64::INFINITY)]),
        )]);
        let err = nested.dump().unwrap_err();
        assert!(err.contains("coef[1]"), "{err}");
        assert!(Json::Num(f64::NEG_INFINITY).dump().is_err());
        // Display never emits an invalid bare token either
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        // finite trees dump exactly like Display
        let fine = Json::obj(vec![("x", Json::Num(0.1))]);
        assert_eq!(fine.dump().unwrap(), fine.to_string());
    }

    #[test]
    fn float_formatting_round_trips_bitwise() {
        // shortest-round-trip property on awkward values, including -0.0,
        // subnormals, and values near the integer fast-path boundary
        for &x in &[
            0.1,
            -0.0,
            1.0 / 3.0,
            2f64.powi(-1074), // smallest subnormal
            f64::MAX,
            f64::MIN_POSITIVE,
            1e15 - 1.0,
            1e15,
            -123456.789e-300,
            std::f64::consts::PI,
        ] {
            let text = Json::Num(x).to_string();
            let back = Json::parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x:?} -> {text} -> {back:?}");
        }
        assert_eq!(Json::Num(-0.0).to_string(), "-0");
    }

    #[test]
    fn update_json_file_refuses_non_finite() {
        let path = std::env::temp_dir().join("kronvt_json_nonfinite_test.json");
        let _ = std::fs::remove_file(&path);
        let err = update_json_file(&path, "bad", Json::Num(f64::NAN)).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(!path.exists(), "nothing may be written on error");
    }
}
