//! Tiny leveled logger writing to stderr.
//!
//! Controlled by the `KRONVT_LOG` env var (`error|warn|info|debug|trace`) or
//! programmatically via [`set_level`]. Default level: `info`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

/// Log severity, ordered from most to least severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Unrecoverable problems.
    Error = 0,
    /// Degraded-but-continuing conditions (e.g. PJRT fallback).
    Warn = 1,
    /// High-level progress (default level).
    Info = 2,
    /// Per-operation details.
    Debug = 3,
    /// Everything.
    Trace = 4,
}

impl Level {
    /// Parse a level name (unknown names fall back to `Info`).
    pub fn from_str(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }

    /// Canonical upper-case name.
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn current_level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == 255 {
        let lvl = std::env::var("KRONVT_LOG").map(|v| Level::from_str(&v)).unwrap_or(Level::Info);
        LEVEL.store(lvl as u8, Ordering::Relaxed);
        lvl
    } else {
        // Safety: only valid discriminants are ever stored.
        match raw {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            3 => Level::Debug,
            _ => Level::Trace,
        }
    }
}

/// Set the global log level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Whether `level` is enabled.
pub fn enabled(level: Level) -> bool {
    level <= current_level()
}

/// Core log function; prefer the macros.
pub fn log(level: Level, module: &str, msg: std::fmt::Arguments) {
    if enabled(level) {
        let now = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
        eprintln!("[{:>10.3} {:5} {}] {}", now.as_secs_f64() % 100_000.0, level.name(), module, msg);
    }
}

/// Log at [`Level::Info`](crate::util::logging::Level::Info) with `format!` syntax.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

/// Log at [`Level::Warn`](crate::util::logging::Level::Warn) with `format!` syntax.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

/// Log at [`Level::Debug`](crate::util::logging::Level::Debug) with `format!` syntax.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

/// Log at [`Level::Error`](crate::util::logging::Level::Error) with `format!` syntax.
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Info);
        assert!(Level::Info < Level::Trace);
    }

    #[test]
    fn set_and_check() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }

    #[test]
    fn parse_names() {
        assert_eq!(Level::from_str("debug"), Level::Debug);
        assert_eq!(Level::from_str("bogus"), Level::Info);
    }
}
