//! Hand-rolled property-testing harness (the `proptest` crate is unavailable
//! offline).
//!
//! A property is a closure over a seeded [`Pcg32`]; the harness runs it for
//! `cases` independent seeds and reports the first failing seed so failures
//! are reproducible with `check_seeded`.

use super::rng::Pcg32;

/// Number of cases to run per property (overridable via `KRONVT_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("KRONVT_PROP_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(32)
}

/// Run `prop` for `cases` random seeds derived from `base_seed`. The property
/// should panic (e.g. via `assert!`) on failure; the harness re-panics with
/// the failing seed in the message.
pub fn check_n(base_seed: u64, cases: usize, prop: impl Fn(&mut Pcg32)) {
    for case in 0..cases {
        let seed = base_seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Pcg32::seeded(seed);
            prop(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Run with the default number of cases.
pub fn check(base_seed: u64, prop: impl Fn(&mut Pcg32)) {
    check_n(base_seed, default_cases(), prop);
}

/// Re-run a single failing case.
pub fn check_seeded(seed: u64, prop: impl Fn(&mut Pcg32)) {
    let mut rng = Pcg32::seeded(seed);
    prop(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_n(1, 16, |rng| {
            let x = rng.uniform();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check_n(2, 16, |rng| {
                let x = rng.below(10);
                assert!(x < 5, "x={x}");
            });
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("seed"), "msg={msg}");
    }
}
