//! Hand-rolled property-testing harness (the `proptest` crate is unavailable
//! offline) plus the shared generators the unit, integration, and property
//! tests draw their random-but-reproducible inputs from: SPD matrices,
//! complete/incomplete edge indices, and whole pairwise datasets.
//!
//! A property is a closure over a seeded [`Pcg32`]; the harness runs it for
//! `cases` independent seeds and reports the first failing seed so failures
//! are reproducible with `check_seeded`.

use super::rng::Pcg32;
use crate::data::Dataset;
use crate::gvt::KronIndex;
use crate::linalg::Matrix;

/// Number of cases to run per property (overridable via `KRONVT_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("KRONVT_PROP_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(32)
}

/// Run `prop` for `cases` random seeds derived from `base_seed`. The property
/// should panic (e.g. via `assert!`) on failure; the harness re-panics with
/// the failing seed in the message.
pub fn check_n(base_seed: u64, cases: usize, prop: impl Fn(&mut Pcg32)) {
    for case in 0..cases {
        let seed = base_seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Pcg32::seeded(seed);
            prop(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Run with the default number of cases.
pub fn check(base_seed: u64, prop: impl Fn(&mut Pcg32)) {
    check_n(base_seed, default_cases(), prop);
}

/// Re-run a single failing case.
pub fn check_seeded(seed: u64, prop: impl Fn(&mut Pcg32)) {
    let mut rng = Pcg32::seeded(seed);
    prop(&mut rng);
}

/// Random symmetric positive-definite `n × n` matrix: `G·Gᵀ` plus a random
/// positive diagonal shift, so eigenvalues are strictly positive but the
/// conditioning varies from case to case.
pub fn spd_matrix(rng: &mut Pcg32, n: usize) -> Matrix {
    let g = Matrix::from_fn(n, n, |_, _| rng.normal());
    let mut a = g.matmul_nt(&g);
    a.add_diag(0.1 + rng.uniform() * n as f64);
    a
}

/// Edge index enumerating the **complete** `q × m` graph — every
/// (end-vertex, start-vertex) pair exactly once — in a shuffled order, so
/// completeness detection can't rely on enumeration order.
pub fn complete_edge_index(rng: &mut Pcg32, q: usize, m: usize) -> KronIndex {
    let mut pairs: Vec<(u32, u32)> = (0..q as u32)
        .flat_map(|g| (0..m as u32).map(move |k| (g, k)))
        .collect();
    rng.shuffle(&mut pairs);
    KronIndex::new(pairs.iter().map(|p| p.0).collect(), pairs.iter().map(|p| p.1).collect())
}

/// Edge index over `n_edges` **distinct** cells of the `q × m` grid (no
/// duplicate edges; incomplete whenever `n_edges < q·m`).
pub fn incomplete_edge_index(rng: &mut Pcg32, q: usize, m: usize, n_edges: usize) -> KronIndex {
    assert!(n_edges <= q * m, "cannot draw {n_edges} distinct edges from a {q}x{m} grid");
    let cells = rng.sample_indices(q * m, n_edges);
    KronIndex::new(
        cells.iter().map(|&c| (c / m) as u32).collect(),
        cells.iter().map(|&c| (c % m) as u32).collect(),
    )
}

fn dataset_from_index(rng: &mut Pcg32, q: usize, m: usize, idx: KronIndex, name: &str) -> Dataset {
    let d = 3;
    let r = 2;
    let labels = (0..idx.len()).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
    Dataset {
        start_features: Matrix::from_fn(m, d, |_, _| rng.normal()),
        end_features: Matrix::from_fn(q, r, |_, _| rng.normal()),
        start_idx: idx.right,
        end_idx: idx.left,
        labels,
        name: name.to_string(),
    }
}

/// Random dataset whose edge index enumerates the complete `q × m` graph in
/// shuffled order: Gaussian vertex features, ±1 labels.
pub fn complete_dataset(rng: &mut Pcg32, q: usize, m: usize) -> Dataset {
    let idx = complete_edge_index(rng, q, m);
    dataset_from_index(rng, q, m, idx, "proptest-complete")
}

/// Random dataset over `n_edges` distinct cells of the `q × m` grid:
/// Gaussian vertex features, ±1 labels.
pub fn incomplete_dataset(rng: &mut Pcg32, q: usize, m: usize, n_edges: usize) -> Dataset {
    let idx = incomplete_edge_index(rng, q, m, n_edges);
    dataset_from_index(rng, q, m, idx, "proptest-incomplete")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_n(1, 16, |rng| {
            let x = rng.uniform();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check_n(2, 16, |rng| {
                let x = rng.below(10);
                assert!(x < 5, "x={x}");
            });
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("seed"), "msg={msg}");
    }

    #[test]
    fn spd_matrix_is_symmetric_with_positive_diagonal() {
        check_n(3, 16, |rng| {
            let n = 1 + rng.below(12);
            let a = spd_matrix(rng, n);
            for i in 0..n {
                assert!(a.get(i, i) > 0.0);
                for j in 0..n {
                    assert_eq!(a.get(i, j), a.get(j, i));
                }
            }
        });
    }

    #[test]
    fn complete_edge_index_is_complete() {
        check_n(4, 16, |rng| {
            let q = 1 + rng.below(6);
            let m = 1 + rng.below(6);
            let idx = complete_edge_index(rng, q, m);
            assert_eq!(idx.len(), q * m);
            assert!(idx.complete_layout(q, m).is_some());
        });
    }

    #[test]
    fn incomplete_edge_index_has_distinct_cells() {
        check_n(5, 16, |rng| {
            let (q, m) = (2 + rng.below(5), 2 + rng.below(5));
            let n_edges = 1 + rng.below(q * m - 1); // strictly fewer than q·m
            let idx = incomplete_edge_index(rng, q, m, n_edges);
            assert_eq!(idx.len(), n_edges);
            assert!(idx.validate(q, m).is_ok());
            let flats = idx.flat(m);
            let mut seen = std::collections::HashSet::new();
            assert!(flats.iter().all(|&f| seen.insert(f)), "duplicate edge");
            assert!(idx.complete_layout(q, m).is_none());
        });
    }

    #[test]
    fn generated_datasets_validate() {
        check_n(6, 8, |rng| {
            let complete = complete_dataset(rng, 3, 4);
            complete.validate().expect("complete dataset must validate");
            assert!(complete.kron_index().complete_layout(3, 4).is_some());
            let sparse = incomplete_dataset(rng, 3, 4, 7);
            sparse.validate().expect("incomplete dataset must validate");
            assert!(sparse.kron_index().complete_layout(3, 4).is_none());
        });
    }
}
