//! Explicit-materialization baseline: builds the `f × e` submatrix
//! `B = R (M ⊗ N) Cᵀ` entry by entry (`B[h,l] = M[p_h,r_l]·N[q_h,t_l]`) and
//! multiplies. This is the "Baseline" of Tables 3–4 — `O(f·e)` time and
//! memory — used for correctness tests and for the complexity benches that
//! regenerate those tables.

use super::KronIndex;
use crate::linalg::Matrix;

/// Materialize `B = R (M ⊗ N) Cᵀ ∈ R^{f×e}`.
pub fn explicit_submatrix(m: &Matrix, n: &Matrix, rows: &KronIndex, cols: &KronIndex) -> Matrix {
    let f = rows.len();
    let e = cols.len();
    let mut out = Matrix::zeros(f, e);
    for h in 0..f {
        let p = rows.left[h] as usize;
        let q = rows.right[h] as usize;
        let row = out.row_mut(h);
        for l in 0..e {
            let r = cols.left[l] as usize;
            let t = cols.right[l] as usize;
            row[l] = m.get(p, r) * n.get(q, t);
        }
    }
    out
}

/// Baseline matvec: materialize then multiply (`O(f·e)`).
pub fn explicit_apply(
    m: &Matrix,
    n: &Matrix,
    rows: &KronIndex,
    cols: &KronIndex,
    v: &[f64],
) -> Vec<f64> {
    explicit_submatrix(m, n, rows, cols).matvec(v)
}

/// Baseline matvec without materializing the submatrix (recomputes entries
/// on the fly; same `O(f·e)` flops, `O(1)` extra memory). This is what a
/// memory-constrained explicit solver would do.
pub fn explicit_apply_streaming(
    m: &Matrix,
    n: &Matrix,
    rows: &KronIndex,
    cols: &KronIndex,
    v: &[f64],
) -> Vec<f64> {
    let f = rows.len();
    let e = cols.len();
    assert_eq!(v.len(), e);
    let mut u = vec![0.0; f];
    for h in 0..f {
        let p = rows.left[h] as usize;
        let q = rows.right[h] as usize;
        let m_row = m.row(p);
        let n_row = n.row(q);
        let mut acc = 0.0;
        for l in 0..e {
            acc += m_row[cols.left[l] as usize] * n_row[cols.right[l] as usize] * v[l];
        }
        u[h] = acc;
    }
    u
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::assert_allclose;
    use crate::util::rng::Pcg32;

    #[test]
    fn submatrix_agrees_with_full_kron() {
        let mut rng = Pcg32::seeded(60);
        let m = Matrix::from_fn(3, 4, |_, _| rng.normal());
        let n = Matrix::from_fn(2, 5, |_, _| rng.normal());
        let rows = KronIndex::from_usize(&[0, 2, 1], &[1, 0, 1]);
        let cols = KronIndex::from_usize(&[3, 0, 2, 1], &[4, 2, 0, 1]);
        let sub = explicit_submatrix(&m, &n, &rows, &cols);
        let full = m.kron(&n);
        for (h, &fr) in rows.flat(2).iter().enumerate() {
            for (l, &fc) in cols.flat(5).iter().enumerate() {
                assert!((sub.get(h, l) - full.get(fr, fc)).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn streaming_matches_materialized() {
        let mut rng = Pcg32::seeded(61);
        let m = Matrix::from_fn(4, 4, |_, _| rng.normal());
        let n = Matrix::from_fn(3, 3, |_, _| rng.normal());
        let rows = KronIndex::from_usize(&[0, 1, 2, 3, 2], &[0, 1, 2, 0, 1]);
        let cols = KronIndex::from_usize(&[1, 2, 0, 3], &[2, 1, 0, 2]);
        let v = rng.normal_vec(4);
        let a = explicit_apply(&m, &n, &rows, &cols, &v);
        let b = explicit_apply_streaming(&m, &n, &rows, &cols, &v);
        assert_allclose(&a, &b, 1e-12, 1e-12);
    }
}
