//! D-way tensor-product index sequences — the generalization of
//! [`KronIndex`](super::KronIndex) from two-factor Kronecker products
//! `M ⊗ N` to chains `K₁ ⊗ K₂ ⊗ … ⊗ K_D`.
//!
//! A [`TensorIndex`] holds one index column per mode: entry `h` of mode `d`
//! selects a row (or column) of factor `K_d`, so the whole tuple
//! `(i¹_h, …, i^D_h)` names one row (or column) of the chain product under
//! row-major tuple ordering — exactly Lemma 2 of the paper applied
//! recursively. The two-factor `KronIndex` is the `D = 2` special case
//! ([`TensorIndex::from_kron`] / [`TensorIndex::to_kron`]).
//!
//! All dimension products use **checked arithmetic**: a chain over modes of
//! sizes `d₁·d₂·…·d_D` overflows `usize` long before memory runs out, and a
//! silently wrapped product would alias unrelated grid cells. Every helper
//! that multiplies dimensions either returns an `Option`/`Result` or panics
//! with an explicit overflow message.

use super::KronIndex;

/// Index sequences selecting rows (or columns) of a D-way tensor-product
/// chain `K₁ ⊗ … ⊗ K_D` by per-factor indices. 0-based; mode `d` indexes
/// factor `K_d`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorIndex {
    /// One column per mode; `modes[d][h]` indexes factor `d` for edge `h`.
    /// All columns have equal length (the number of edges).
    pub modes: Vec<Vec<u32>>,
}

/// Product of `dims` with overflow checking.
pub(crate) fn checked_product(dims: &[usize]) -> Option<usize> {
    dims.iter().try_fold(1usize, |acc, &d| acc.checked_mul(d))
}

impl TensorIndex {
    /// Construct from per-mode index columns, validating that at least one
    /// mode is present and all columns have equal length.
    pub fn new(modes: Vec<Vec<u32>>) -> TensorIndex {
        assert!(!modes.is_empty(), "tensor index needs at least one mode");
        let len = modes[0].len();
        for (d, col) in modes.iter().enumerate() {
            assert_eq!(
                col.len(),
                len,
                "mode {d} has {} entries but mode 0 has {len}",
                col.len()
            );
        }
        TensorIndex { modes }
    }

    /// Construct from usize slices (convenience).
    pub fn from_usize(modes: &[&[usize]]) -> TensorIndex {
        TensorIndex::new(
            modes.iter().map(|col| col.iter().map(|&i| i as u32).collect()).collect(),
        )
    }

    /// The `D = 2` embedding: `left` becomes mode 0, `right` mode 1.
    pub fn from_kron(idx: &KronIndex) -> TensorIndex {
        TensorIndex { modes: vec![idx.left.clone(), idx.right.clone()] }
    }

    /// Back to a two-factor [`KronIndex`] — `Some` only when `order() == 2`.
    pub fn to_kron(&self) -> Option<KronIndex> {
        if self.modes.len() != 2 {
            return None;
        }
        Some(KronIndex::new(self.modes[0].clone(), self.modes[1].clone()))
    }

    /// Number of modes `D` in the chain.
    pub fn order(&self) -> usize {
        self.modes.len()
    }

    /// Number of indexed rows/columns (edges).
    pub fn len(&self) -> usize {
        self.modes[0].len()
    }

    /// Whether the index selects zero rows/columns.
    pub fn is_empty(&self) -> bool {
        self.modes[0].is_empty()
    }

    /// Check the mode count matches `dims` and every index is in-bounds for
    /// its mode's dimension.
    pub fn validate(&self, dims: &[usize]) -> Result<(), String> {
        if dims.len() != self.order() {
            return Err(format!(
                "tensor index has {} modes but {} dimensions were given",
                self.order(),
                dims.len()
            ));
        }
        for (d, (col, &dim)) in self.modes.iter().zip(dims).enumerate() {
            for (h, &i) in col.iter().enumerate() {
                if i as usize >= dim {
                    return Err(format!("edge {h}: mode {d} index {i} out of bounds ({dim})"));
                }
            }
        }
        Ok(())
    }

    /// Whether every mode's column is surjective onto `[0, dims[d])`
    /// separately (the D-way analogue of the Theorem 1 assumption).
    pub fn is_surjective(&self, dims: &[usize]) -> bool {
        if dims.len() != self.order() {
            return false;
        }
        self.modes.iter().zip(dims).all(|(col, &dim)| {
            let mut seen = vec![false; dim];
            for &i in col {
                if (i as usize) < dim {
                    seen[i as usize] = true;
                } else {
                    return false;
                }
            }
            seen.iter().all(|&s| s)
        })
    }

    /// The flat row-major index of each edge's tuple in the chain product:
    /// `((i¹·d₂ + i²)·d₃ + i³)·…`. Panics with an explicit message if the
    /// grid size overflows `usize` (checked arithmetic throughout).
    pub fn flat(&self, dims: &[usize]) -> Vec<usize> {
        assert_eq!(dims.len(), self.order(), "one dimension per mode required");
        checked_product(dims).unwrap_or_else(|| {
            panic!("tensor grid size {dims:?} overflows usize")
        });
        (0..self.len())
            .map(|h| {
                let mut acc = 0usize;
                for (col, &dim) in self.modes.iter().zip(dims) {
                    acc = acc
                        .checked_mul(dim)
                        .and_then(|a| a.checked_add(col[h] as usize))
                        .unwrap_or_else(|| {
                            panic!("flat index overflow at edge {h} for grid {dims:?}")
                        });
                }
                acc
            })
            .collect()
    }

    /// Flat row-major keys over a contiguous *subrange* of modes
    /// (`mode_lo..mode_hi`), as `u32` — the form the engine's stage-1
    /// bucketing and final gather consume. Errors if the subgrid size
    /// exceeds `u32::MAX` (bucket keys are 32-bit) or overflows.
    pub(crate) fn flat_range_u32(
        &self,
        dims: &[usize],
        mode_lo: usize,
        mode_hi: usize,
    ) -> Result<Vec<u32>, String> {
        let sub = &dims[mode_lo..mode_hi];
        let total = checked_product(sub)
            .ok_or_else(|| format!("tensor subgrid {sub:?} overflows usize"))?;
        if total > u32::MAX as usize {
            return Err(format!(
                "tensor subgrid {sub:?} has {total} cells, exceeding the 32-bit bucket-key limit"
            ));
        }
        Ok((0..self.len())
            .map(|h| {
                let mut acc = 0usize;
                for d in mode_lo..mode_hi {
                    acc = acc * dims[d] + self.modes[d][h] as usize;
                }
                acc as u32
            })
            .collect())
    }

    /// If this index enumerates the **complete grid**
    /// `[0,d₁) × … × [0,d_D)` — every cell exactly once, in any order —
    /// return the layout mapping each flat row-major cell to the edge
    /// position `h` covering it; otherwise `None`. The D-way analogue of
    /// [`KronIndex::complete_layout`], and the condition under which the
    /// index matrix `R` is a permutation of the full grid.
    pub fn complete_layout(&self, dims: &[usize]) -> Option<Vec<u32>> {
        if dims.len() != self.order() {
            return None;
        }
        let total = checked_product(dims)?;
        if total == 0 || self.len() != total || total > u32::MAX as usize {
            return None;
        }
        let mut layout = vec![u32::MAX; total];
        for h in 0..self.len() {
            let mut pos = 0usize;
            for (col, &dim) in self.modes.iter().zip(dims) {
                let i = col[h] as usize;
                if i >= dim {
                    return None;
                }
                pos = pos * dim + i;
            }
            if layout[pos] != u32::MAX {
                return None; // duplicate cell
            }
            layout[pos] = h as u32;
        }
        // len == total and no duplicates ⇒ every cell covered (pigeonhole).
        Some(layout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_index_basics() {
        let idx = TensorIndex::from_usize(&[&[0, 1, 2], &[1, 0, 1], &[0, 1, 1]]);
        assert_eq!(idx.order(), 3);
        assert_eq!(idx.len(), 3);
        assert!(!idx.is_empty());
        assert!(idx.validate(&[3, 2, 2]).is_ok());
        assert!(idx.validate(&[2, 2, 2]).is_err());
        assert!(idx.validate(&[3, 2]).is_err());
        assert!(idx.is_surjective(&[3, 2, 2]));
        assert!(!idx.is_surjective(&[4, 2, 2]));
        // flat = (i1*2 + i2)*2 + i3
        assert_eq!(idx.flat(&[3, 2, 2]), vec![2, 5, 11]);
    }

    #[test]
    fn round_trips_with_kron_index() {
        let kron = KronIndex::from_usize(&[0, 1, 2], &[1, 0, 1]);
        let tensor = TensorIndex::from_kron(&kron);
        assert_eq!(tensor.order(), 2);
        assert_eq!(tensor.to_kron(), Some(kron.clone()));
        // flat agrees with the two-factor definition
        assert_eq!(tensor.flat(&[3, 2]), kron.flat(2));
        let d3 = TensorIndex::from_usize(&[&[0], &[0], &[0]]);
        assert_eq!(d3.to_kron(), None);
    }

    #[test]
    #[should_panic(expected = "mode 1 has")]
    fn mismatched_mode_lengths_panic() {
        TensorIndex::new(vec![vec![0, 1], vec![0]]);
    }

    #[test]
    #[should_panic(expected = "overflows usize")]
    fn flat_overflow_panics_with_message() {
        let idx = TensorIndex::from_usize(&[&[0], &[0], &[0]]);
        idx.flat(&[usize::MAX, usize::MAX, 2]);
    }

    #[test]
    fn flat_range_u32_is_the_row_major_subkey() {
        let idx = TensorIndex::from_usize(&[&[1, 0], &[2, 1], &[0, 3]]);
        let dims = [2, 3, 4];
        // full range matches flat()
        let full = idx.flat_range_u32(&dims, 0, 3).unwrap();
        assert_eq!(
            full.iter().map(|&k| k as usize).collect::<Vec<_>>(),
            idx.flat(&dims)
        );
        // trailing range (modes 1..3): key = i2*4 + i3
        let rest = idx.flat_range_u32(&dims, 1, 3).unwrap();
        assert_eq!(rest, vec![8, 7]);
        // leading range (modes 0..2): key = i1*3 + i2
        let prefix = idx.flat_range_u32(&dims, 0, 2).unwrap();
        assert_eq!(prefix, vec![5, 1]);
        // over-u32 subgrid is rejected with a clear error
        let big = [usize::MAX / 2, 3, 4];
        assert!(idx.flat_range_u32(&big, 0, 2).unwrap_err().contains("32-bit"));
    }

    #[test]
    fn complete_layout_detects_full_grids() {
        // 2×2×2 grid enumerated in scrambled order.
        let idx = TensorIndex::from_usize(&[
            &[1, 0, 0, 1, 0, 1, 0, 1],
            &[0, 0, 1, 1, 0, 0, 1, 1],
            &[1, 0, 0, 1, 1, 0, 1, 0],
        ]);
        let layout = idx.complete_layout(&[2, 2, 2]).expect("complete");
        for (pos, &h) in layout.iter().enumerate() {
            assert_eq!(idx.flat(&[2, 2, 2])[h as usize], pos);
        }
        // missing + duplicate cell
        let dup = TensorIndex::from_usize(&[&[0, 0], &[0, 0], &[0, 0]]);
        assert!(dup.complete_layout(&[1, 1, 2]).is_none());
        // wrong edge count
        let short = TensorIndex::from_usize(&[&[0], &[0], &[0]]);
        assert!(short.complete_layout(&[2, 1, 1]).is_none());
        // wrong mode count
        assert!(short.complete_layout(&[1, 1]).is_none());
        // empty grid is never complete
        let empty = TensorIndex::new(vec![vec![], vec![]]);
        assert!(empty.complete_layout(&[0, 0]).is_none());
    }
}
