//! Algorithm 1 — the generalized vec trick.
//!
//! Computes `u = R (M ⊗ N) Cᵀ v`, i.e. `u_h = Σ_l M[p_h,r_l]·N[q_h,t_l]·v_l`,
//! in `O(min(ae + df, ce + bf))`.
//!
//! ### Layout notes (differs from the paper's pseudocode, same math)
//!
//! The pseudocode's inner loops stride down matrix *columns*; on modern CPUs
//! that wastes most of the memory bandwidth. Both branches here are
//! restructured so every inner loop is a contiguous-slice AXPY or dot:
//!
//! * branch T: stage 1 accumulates rows of `T ∈ R^{d×a}` via rows of `Mᵀ`,
//!   one `O(ad)` transpose puts `T` in gather-friendly layout for stage 2.
//! * branch S: stage 1 accumulates rows of `Sᵀ ∈ R^{b×c}` via rows of `Nᵀ`,
//!   then transposes to `S ∈ R^{c×b}` for contiguous stage-2 dots.
//!
//! The extra transpose costs `O(ad)` / `O(bc)`, dominated by the stage costs
//! (`e ≥ max(b,d)`, `f ≥ max(a,c)` under Theorem 1's surjectivity).
//!
//! Stage 1 skips zero entries of `v`, which implements the paper's sparse
//! speedup (eq. 5): cost scales with `‖v‖₀` instead of `e`.

use super::complexity::{self, Branch as CBranch};
use super::KronIndex;
use crate::linalg::vecops::{axpy, dot};
use crate::linalg::Matrix;

pub use super::complexity::Branch;

/// Reusable scratch buffers so training loops do no per-matvec allocation.
#[derive(Debug, Default)]
pub struct GvtWorkspace {
    stage: Vec<f64>,
    stage_t: Vec<f64>,
}

impl GvtWorkspace {
    /// Empty workspace; buffers grow on first use and are then reused.
    pub fn new() -> Self {
        Self::default()
    }

    /// Like `grab`, but without clearing: callers (the parallel engine's
    /// stage-1 workers) are responsible for zeroing every region they
    /// accumulate into.
    pub(crate) fn grab_uncleared(&mut self, n1: usize, n2: usize) -> (&mut [f64], &mut [f64]) {
        if self.stage.len() < n1 {
            self.stage.resize(n1, 0.0);
        }
        if self.stage_t.len() < n2 {
            self.stage_t.resize(n2, 0.0);
        }
        (&mut self.stage[..n1], &mut self.stage_t[..n2])
    }

    fn grab(&mut self, n1: usize, n2: usize) -> (&mut [f64], &mut [f64]) {
        if self.stage.len() < n1 {
            self.stage.resize(n1, 0.0);
        }
        if self.stage_t.len() < n2 {
            self.stage_t.resize(n2, 0.0);
        }
        self.stage[..n1].fill(0.0);
        // stage_t is fully overwritten by the transpose; no clearing needed.
        (&mut self.stage[..n1], &mut self.stage_t[..n2])
    }
}

/// Blocked out-of-place transpose of a `rows×cols` row-major buffer.
/// Shared with [`super::engine`]'s parallel transpose as its serial fallback.
pub(crate) fn transpose_into(src: &[f64], rows: usize, cols: usize, dst: &mut [f64]) {
    debug_assert_eq!(src.len(), rows * cols);
    debug_assert!(dst.len() >= rows * cols);
    const B: usize = 32;
    for ib in (0..rows).step_by(B) {
        for jb in (0..cols).step_by(B) {
            for i in ib..(ib + B).min(rows) {
                for j in jb..(jb + B).min(cols) {
                    dst[j * rows + i] = src[i * cols + j];
                }
            }
        }
    }
}

/// Full-featured entry point: computes `u = R(M⊗N)Cᵀv` into `u`.
///
/// * `m`, `n` — factor matrices (`a×b`, `c×d`).
/// * `m_t`, `n_t` — their transposes. Pass the same reference for symmetric
///   matrices; only the branch actually executed reads its transpose.
/// * `rows` — `(p, q)` over `[a]×[c]`, length `f`;
///   `cols` — `(r, t)` over `[b]×[d]`, length `e`.
/// * `branch` — `None` selects by the Theorem-1 flop model.
#[allow(clippy::too_many_arguments)]
pub fn gvt_apply_into(
    m: &Matrix,
    n: &Matrix,
    m_t: &Matrix,
    n_t: &Matrix,
    rows: &KronIndex,
    cols: &KronIndex,
    v: &[f64],
    u: &mut [f64],
    ws: &mut GvtWorkspace,
    branch: Option<Branch>,
) {
    let (a, b) = (m.rows(), m.cols());
    let (c, d) = (n.rows(), n.cols());
    debug_assert_eq!(m_t.rows(), b);
    debug_assert_eq!(m_t.cols(), a);
    debug_assert_eq!(n_t.rows(), d);
    debug_assert_eq!(n_t.cols(), c);
    let e = cols.len();
    let f = rows.len();
    assert_eq!(v.len(), e, "v must have length e = |cols|");
    assert_eq!(u.len(), f, "u must have length f = |rows|");
    debug_assert!(rows.validate(a, c).is_ok(), "row indices out of bounds");
    debug_assert!(cols.validate(b, d).is_ok(), "col indices out of bounds");

    let branch = branch.unwrap_or_else(|| complexity::choose_branch(a, b, c, d, e, f));
    match branch {
        CBranch::T => {
            // Stage 1: T[t_l, :] += v_l · Mᵀ[r_l, :]   (T is d×a)
            let (t_buf, tt_buf) = ws.grab(d * a, a * d);
            for l in 0..e {
                let vl = v[l];
                if vl == 0.0 {
                    continue;
                }
                let r = cols.left[l] as usize;
                let t = cols.right[l] as usize;
                axpy(vl, m_t.row(r), &mut t_buf[t * a..(t + 1) * a]);
            }
            // Tᵀ is a×d: row p_h is column p_h of T.
            transpose_into(t_buf, d, a, tt_buf);
            // Stage 2: u_h = N[q_h, :] · Tᵀ[p_h, :]
            for h in 0..f {
                let p = rows.left[h] as usize;
                let q = rows.right[h] as usize;
                u[h] = dot(n.row(q), &tt_buf[p * d..(p + 1) * d]);
            }
        }
        CBranch::S => {
            // Stage 1: Sᵀ[r_l, :] += v_l · Nᵀ[t_l, :]   (Sᵀ is b×c)
            let (st_buf, s_buf) = ws.grab(b * c, c * b);
            for l in 0..e {
                let vl = v[l];
                if vl == 0.0 {
                    continue;
                }
                let r = cols.left[l] as usize;
                let t = cols.right[l] as usize;
                axpy(vl, n_t.row(t), &mut st_buf[r * c..(r + 1) * c]);
            }
            // S is c×b.
            transpose_into(st_buf, b, c, s_buf);
            // Stage 2: u_h = S[q_h, :] · M[p_h, :]
            for h in 0..f {
                let p = rows.left[h] as usize;
                let q = rows.right[h] as usize;
                u[h] = dot(&s_buf[q * b..(q + 1) * b], m.row(p));
            }
        }
    }
}

/// Multi-RHS [`gvt_apply_into`]: computes `u_j = R(M⊗N)Cᵀ v_j` for `k_rhs`
/// right-hand sides in **one sweep** over the edge index.
///
/// `v` holds `k_rhs` column *planes* of length `e` (`v[j·e + l]` is entry
/// `l` of RHS `j`) and `u` receives `k_rhs` planes of length `f` — the
/// layout block solvers want (each RHS a contiguous vector).
///
/// Compared to `k_rhs` separate applies, stage 1 traverses the edge index
/// once, loading each edge's `Mᵀ`/`Nᵀ` row a single time and scale-adding it
/// into all `k_rhs` accumulator planes (a k-wide panel update); the blocked
/// transpose moves all planes; and stage 2 loads each output edge's `N`/`M`
/// row once for all `k_rhs` dots.
///
/// **Column `j` of the result is bitwise identical to a single-RHS
/// [`gvt_apply_into`] on plane `j`** (tested): per plane, the accumulation
/// order, the eq.-5 zero-skip, and every dot's reduction are exactly the
/// single-RHS ones — so solvers batched through this path retrace their
/// single-RHS trajectories bit for bit.
#[allow(clippy::too_many_arguments)]
pub fn gvt_apply_multi_into(
    m: &Matrix,
    n: &Matrix,
    m_t: &Matrix,
    n_t: &Matrix,
    rows: &KronIndex,
    cols: &KronIndex,
    v: &[f64],
    u: &mut [f64],
    k_rhs: usize,
    ws: &mut GvtWorkspace,
    branch: Option<Branch>,
) {
    let (a, b) = (m.rows(), m.cols());
    let (c, d) = (n.rows(), n.cols());
    debug_assert_eq!(m_t.rows(), b);
    debug_assert_eq!(m_t.cols(), a);
    debug_assert_eq!(n_t.rows(), d);
    debug_assert_eq!(n_t.cols(), c);
    let e = cols.len();
    let f = rows.len();
    assert_eq!(v.len(), e * k_rhs, "v must hold k_rhs planes of length e");
    assert_eq!(u.len(), f * k_rhs, "u must hold k_rhs planes of length f");
    if k_rhs == 0 {
        return;
    }
    debug_assert!(rows.validate(a, c).is_ok(), "row indices out of bounds");
    debug_assert!(cols.validate(b, d).is_ok(), "col indices out of bounds");

    let branch = branch.unwrap_or_else(|| complexity::choose_branch(a, b, c, d, e, f));
    match branch {
        CBranch::T => {
            let plane = d * a;
            let (t_buf, tt_buf) = ws.grab(plane * k_rhs, plane * k_rhs);
            // Stage 1 (one edge traversal, k-wide panel update):
            //   T_j[t_l, :] += v_j[l] · Mᵀ[r_l, :]
            for l in 0..e {
                let r = cols.left[l] as usize;
                let t = cols.right[l] as usize;
                let src = m_t.row(r);
                for j in 0..k_rhs {
                    let vl = v[j * e + l];
                    if vl == 0.0 {
                        continue;
                    }
                    axpy(vl, src, &mut t_buf[j * plane + t * a..j * plane + (t + 1) * a]);
                }
            }
            for j in 0..k_rhs {
                transpose_into(&t_buf[j * plane..(j + 1) * plane], d, a, &mut tt_buf[j * plane..]);
            }
            // Stage 2: u_j[h] = N[q_h, :] · Tᵀ_j[p_h, :], the N row loaded
            // once per edge for all planes.
            for h in 0..f {
                let p = rows.left[h] as usize;
                let q = rows.right[h] as usize;
                let nrow = n.row(q);
                for j in 0..k_rhs {
                    u[j * f + h] = dot(nrow, &tt_buf[j * plane + p * d..j * plane + (p + 1) * d]);
                }
            }
        }
        CBranch::S => {
            let plane = b * c;
            let (st_buf, s_buf) = ws.grab(plane * k_rhs, plane * k_rhs);
            // Stage 1: Sᵀ_j[r_l, :] += v_j[l] · Nᵀ[t_l, :]
            for l in 0..e {
                let r = cols.left[l] as usize;
                let t = cols.right[l] as usize;
                let src = n_t.row(t);
                for j in 0..k_rhs {
                    let vl = v[j * e + l];
                    if vl == 0.0 {
                        continue;
                    }
                    axpy(vl, src, &mut st_buf[j * plane + r * c..j * plane + (r + 1) * c]);
                }
            }
            for j in 0..k_rhs {
                transpose_into(&st_buf[j * plane..(j + 1) * plane], b, c, &mut s_buf[j * plane..]);
            }
            // Stage 2: u_j[h] = S_j[q_h, :] · M[p_h, :]
            for h in 0..f {
                let p = rows.left[h] as usize;
                let q = rows.right[h] as usize;
                let mrow = m.row(p);
                for j in 0..k_rhs {
                    u[j * f + h] = dot(&s_buf[j * plane + q * b..j * plane + (q + 1) * b], mrow);
                }
            }
        }
    }
}

/// Multi-threaded [`gvt_apply_into`]: shards stage 1 by accumulation row,
/// the blocked transpose by column blocks, and stage 2 by output chunks
/// across `threads` scoped worker threads (see [`super::engine`]).
///
/// This convenience entry point builds the [`super::engine::EdgePlan`] on
/// every call; loops should build the plan once and go through
/// [`super::engine::GvtEngine::apply_planned`] (as [`super::operator`]'s
/// operators do). The result is bitwise identical to the serial
/// [`gvt_apply_into`] for every thread count.
#[allow(clippy::too_many_arguments)]
pub fn gvt_apply_into_parallel(
    m: &Matrix,
    n: &Matrix,
    m_t: &Matrix,
    n_t: &Matrix,
    rows: &KronIndex,
    cols: &KronIndex,
    v: &[f64],
    u: &mut [f64],
    ws: &mut GvtWorkspace,
    branch: Option<Branch>,
    threads: usize,
) {
    let plan = super::engine::EdgePlan::build(cols, m.cols(), n.cols());
    super::engine::GvtEngine::new(threads)
        .apply_planned(m, n, m_t, n_t, rows, cols, &plan, v, u, ws, branch);
}

/// Allocating convenience wrapper around [`gvt_apply_into`]; computes the
/// transposes internally. Prefer [`super::operator::KronKernelOp`] /
/// [`gvt_apply_into`] in loops.
pub fn gvt_apply(
    m: &Matrix,
    n: &Matrix,
    rows: &KronIndex,
    cols: &KronIndex,
    v: &[f64],
) -> Vec<f64> {
    let m_t = m.transpose();
    let n_t = n.transpose();
    let mut u = vec![0.0; rows.len()];
    let mut ws = GvtWorkspace::new();
    gvt_apply_into(m, n, &m_t, &n_t, rows, cols, v, &mut u, &mut ws, None);
    u
}

/// Literal transcription of Algorithm 1's pseudocode (column-strided loops,
/// no layout tricks). Reference implementation for tests.
pub fn gvt_reference(
    m: &Matrix,
    n: &Matrix,
    rows: &KronIndex,
    cols: &KronIndex,
    v: &[f64],
) -> Vec<f64> {
    let (a, b) = (m.rows(), m.cols());
    let (c, d) = (n.rows(), n.cols());
    let e = cols.len();
    let f = rows.len();
    assert_eq!(v.len(), e);
    let mut u = vec![0.0; f];
    if a * e + d * f < c * e + b * f {
        // T ← 0 ∈ R^{d×a}; T[j,k] += v_h · M[k,i] for (i,j) = (r_h, t_h)
        let mut t_mat = Matrix::zeros(d, a);
        for h in 0..e {
            let (i, j) = (cols.left[h] as usize, cols.right[h] as usize);
            for k in 0..a {
                t_mat.add_at(j, k, v[h] * m.get(k, i));
            }
        }
        // u_h = Σ_k N[i,k]·T[k,j] for (i,j) = (q_h, p_h)
        for h in 0..f {
            let (i, j) = (rows.right[h] as usize, rows.left[h] as usize);
            let mut acc = 0.0;
            for k in 0..d {
                acc += n.get(i, k) * t_mat.get(k, j);
            }
            u[h] = acc;
        }
    } else {
        // S ← 0 ∈ R^{c×b}; S[k,i] += v_h · N[k,j] for (i,j) = (r_h, t_h)
        let mut s_mat = Matrix::zeros(c, b);
        for h in 0..e {
            let (i, j) = (cols.left[h] as usize, cols.right[h] as usize);
            for k in 0..c {
                s_mat.add_at(k, i, v[h] * n.get(k, j));
            }
        }
        // u_h = Σ_k S[i,k]·M[j,k] for (i,j) = (q_h, p_h)
        for h in 0..f {
            let (i, j) = (rows.right[h] as usize, rows.left[h] as usize);
            let mut acc = 0.0;
            for k in 0..b {
                acc += s_mat.get(i, k) * m.get(j, k);
            }
            u[h] = acc;
        }
    }
    u
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gvt::explicit::explicit_apply;
    use crate::linalg::vecops::assert_allclose;
    use crate::util::proptest;
    use crate::util::rng::Pcg32;

    fn random_setup(
        rng: &mut Pcg32,
        a: usize,
        b: usize,
        c: usize,
        d: usize,
        e: usize,
        f: usize,
    ) -> (Matrix, Matrix, KronIndex, KronIndex, Vec<f64>) {
        let m = Matrix::from_fn(a, b, |_, _| rng.normal());
        let n = Matrix::from_fn(c, d, |_, _| rng.normal());
        let rows = KronIndex::new(
            (0..f).map(|_| rng.below(a) as u32).collect(),
            (0..f).map(|_| rng.below(c) as u32).collect(),
        );
        let cols = KronIndex::new(
            (0..e).map(|_| rng.below(b) as u32).collect(),
            (0..e).map(|_| rng.below(d) as u32).collect(),
        );
        let v = rng.normal_vec(e);
        (m, n, rows, cols, v)
    }

    #[test]
    fn matches_explicit_small() {
        let mut rng = Pcg32::seeded(50);
        let (m, n, rows, cols, v) = random_setup(&mut rng, 3, 4, 5, 2, 7, 6);
        let fast = gvt_apply(&m, &n, &rows, &cols, &v);
        let slow = explicit_apply(&m, &n, &rows, &cols, &v);
        assert_allclose(&fast, &slow, 1e-10, 1e-10);
    }

    #[test]
    fn both_branches_agree_with_explicit() {
        let mut rng = Pcg32::seeded(51);
        let (m, n, rows, cols, v) = random_setup(&mut rng, 6, 3, 4, 5, 20, 15);
        let m_t = m.transpose();
        let n_t = n.transpose();
        let mut ws = GvtWorkspace::new();
        let slow = explicit_apply(&m, &n, &rows, &cols, &v);
        for branch in [Branch::T, Branch::S] {
            let mut u = vec![0.0; rows.len()];
            gvt_apply_into(&m, &n, &m_t, &n_t, &rows, &cols, &v, &mut u, &mut ws, Some(branch));
            assert_allclose(&u, &slow, 1e-10, 1e-10);
        }
    }

    #[test]
    fn reference_pseudocode_agrees() {
        let mut rng = Pcg32::seeded(52);
        let (m, n, rows, cols, v) = random_setup(&mut rng, 4, 6, 3, 5, 12, 9);
        let fast = gvt_apply(&m, &n, &rows, &cols, &v);
        let pseudo = gvt_reference(&m, &n, &rows, &cols, &v);
        assert_allclose(&fast, &pseudo, 1e-10, 1e-10);
    }

    #[test]
    fn property_matches_explicit_random_shapes() {
        proptest::check(0xBEEF, |rng| {
            let a = 1 + rng.below(8);
            let b = 1 + rng.below(8);
            let c = 1 + rng.below(8);
            let d = 1 + rng.below(8);
            let e = 1 + rng.below(24);
            let f = 1 + rng.below(24);
            let (m, n, rows, cols, v) = random_setup(rng, a, b, c, d, e, f);
            let fast = gvt_apply(&m, &n, &rows, &cols, &v);
            let slow = explicit_apply(&m, &n, &rows, &cols, &v);
            assert_allclose(&fast, &slow, 1e-9, 1e-9);
        });
    }

    #[test]
    fn vec_trick_special_case() {
        // R = C = I: the generalized trick must reduce to Roth's lemma,
        // (M ⊗ N)·v with pairs enumerated row-major.
        let mut rng = Pcg32::seeded(53);
        let (a, b, c, d) = (3, 4, 2, 5);
        let m = Matrix::from_fn(a, b, |_, _| rng.normal());
        let n = Matrix::from_fn(c, d, |_, _| rng.normal());
        let rows = KronIndex::new(
            (0..a * c).map(|i| (i / c) as u32).collect(),
            (0..a * c).map(|i| (i % c) as u32).collect(),
        );
        let cols = KronIndex::new(
            (0..b * d).map(|i| (i / d) as u32).collect(),
            (0..b * d).map(|i| (i % d) as u32).collect(),
        );
        let v = rng.normal_vec(b * d);
        let fast = gvt_apply(&m, &n, &rows, &cols, &v);
        let full = m.kron(&n).matvec(&v);
        assert_allclose(&fast, &full, 1e-10, 1e-10);
    }

    #[test]
    fn zero_skipping_equals_dense() {
        let mut rng = Pcg32::seeded(54);
        let (m, n, rows, cols, mut v) = random_setup(&mut rng, 5, 5, 5, 5, 30, 30);
        for l in 0..v.len() {
            if l % 3 != 0 {
                v[l] = 0.0;
            }
        }
        let fast = gvt_apply(&m, &n, &rows, &cols, &v);
        let slow = explicit_apply(&m, &n, &rows, &cols, &v);
        assert_allclose(&fast, &slow, 1e-10, 1e-10);
    }

    #[test]
    fn workspace_reuse_is_clean() {
        // Two different applications through the same workspace must not
        // contaminate each other.
        let mut rng = Pcg32::seeded(55);
        let (m, n, rows, cols, v1) = random_setup(&mut rng, 4, 4, 4, 4, 10, 10);
        let v2 = rng.normal_vec(10);
        let m_t = m.transpose();
        let n_t = n.transpose();
        let mut ws = GvtWorkspace::new();
        let mut u1 = vec![0.0; 10];
        let mut u2 = vec![0.0; 10];
        gvt_apply_into(&m, &n, &m_t, &n_t, &rows, &cols, &v1, &mut u1, &mut ws, None);
        gvt_apply_into(&m, &n, &m_t, &n_t, &rows, &cols, &v2, &mut u2, &mut ws, None);
        let fresh = gvt_apply(&m, &n, &rows, &cols, &v2);
        assert_allclose(&u2, &fresh, 1e-12, 1e-12);
    }

    #[test]
    fn linearity_property() {
        proptest::check_n(0xCAFE, 16, |rng| {
            let (m, n, rows, cols, v1) = random_setup(rng, 3, 4, 4, 3, 15, 12);
            let v2 = rng.normal_vec(15);
            let alpha = rng.normal();
            let u1 = gvt_apply(&m, &n, &rows, &cols, &v1);
            let u2 = gvt_apply(&m, &n, &rows, &cols, &v2);
            let vsum: Vec<f64> = v1.iter().zip(&v2).map(|(x, y)| x + alpha * y).collect();
            let usum = gvt_apply(&m, &n, &rows, &cols, &vsum);
            let expect: Vec<f64> = u1.iter().zip(&u2).map(|(x, y)| x + alpha * y).collect();
            assert_allclose(&usum, &expect, 1e-8, 1e-8);
        });
    }
}
