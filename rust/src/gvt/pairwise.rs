//! The **pairwise kernel operator family** over the generalized vec trick.
//!
//! The source paper's Algorithm 1 computes one specific pairwise kernel —
//! the plain Kronecker product `k⊗((d,t),(d',t')) = k(d,d')·g(t,t')` — but
//! the follow-up work (Viljanen, Airola & Pahikkala 2020, *Generalized vec
//! trick for fast learning of pairwise kernel models*) shows the same
//! `R(M⊗N)Cᵀ` apply composes into a whole family of pairwise kernels, and
//! the comparative study of Stock et al. (2018) shows those families are
//! what homogeneous-graph problems (protein–protein, drug–drug interaction,
//! ranking) actually need. This module builds each family member as a
//! composition of one or two *planned* GVT applies — the pairwise kernel matrix is
//! **never materialized**:
//!
//! | [`PairwiseKernelKind`] | edge-kernel formula | GVT composition |
//! |---|---|---|
//! | `Kronecker` | `k(d,d')·g(t,t')` | 1 apply (bitwise identical to [`KronKernelOp`](super::operator::KronKernelOp)) |
//! | `SymmetricKron` | `½[k(d,d')g(t,t') + c(d,t')c(t,d')]` | 2 applies, second with swapped column index |
//! | `AntiSymmetricKron` | `½[k(d,d')g(t,t') − c(d,t')c(t,d')]` | 2 applies, second negated |
//! | `Cartesian` | `k(d,d')·δ(t,t') + δ(d,d')·g(t,t')` | 2 applies against identity / δ factors |
//!
//! where `c(·,·)` is the shared vertex kernel evaluated *across* the two
//! vertex roles (requires both roles to live in one feature space with one
//! kernel — the homogeneous setting) and `δ` is vertex identity. The
//! symmetric (anti-symmetric) kernels are the projections of the Kronecker
//! kernel onto the symmetric (anti-symmetric) subspace, so they remain PSD;
//! the Cartesian kernel is the direct-sum kernel of Kashima et al.
//!
//! The swapped-column-index trick: the cross term
//! `u_h = Σ_l c(d_{p_h}, t'_{t_l})·c(t_{q_h}, d'_{r_l})·v_l` is itself one
//! generalized vec trick apply `R(C ⊗ Cᵀ)C̃ᵀv` whose *column* index swaps
//! each edge's vertex pair — so every family member reuses the
//! [`GvtEngine`]/[`EdgePlan`] machinery, the multi-RHS batched path, and the
//! bitwise-deterministic threading unchanged.

use std::sync::Arc;

use super::engine::{EdgePlan, GvtEngine, WorkspacePool};
use super::explicit::explicit_submatrix;
use super::KronIndex;
use crate::kernels::{kernel_matrix_threaded, KernelKind};
use crate::linalg::solvers::{LinOp, MultiLinOp};
use crate::linalg::Matrix;

/// Selector for the pairwise kernel family computed by a [`PairwiseOp`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PairwiseKernelKind {
    /// The paper's Kronecker product kernel `k(d,d')·g(t,t')` — exactly the
    /// pre-existing behavior, bit for bit.
    #[default]
    Kronecker,
    /// Symmetrized Kronecker kernel
    /// `½[k(d,d')g(t,t') + c(d,t')c(t,d')]` for homogeneous edges where both
    /// vertices share one feature space (protein–protein, drug–drug);
    /// invariant under swapping either edge's vertex order.
    SymmetricKron,
    /// Anti-symmetrized Kronecker kernel
    /// `½[k(d,d')g(t,t') − c(d,t')c(t,d')]` for directed / ranking labels;
    /// flips sign when one edge's vertex order is swapped.
    AntiSymmetricKron,
    /// Cartesian (direct-sum) kernel `k(d,d')·δ(t,t') + δ(d,d')·g(t,t')`
    /// (Kashima et al.): two edges interact only when they share a vertex.
    /// Note δ does not extend to novel vertices, so fully zero-shot
    /// predictions are identically 0 — this kernel is for in-sample /
    /// shared-vertex completion settings.
    Cartesian,
}

impl PairwiseKernelKind {
    /// Parse a CLI name: `kron`/`kronecker`, `symmetric`/`sym`,
    /// `antisymmetric`/`anti`, `cartesian`.
    pub fn parse(s: &str) -> Result<PairwiseKernelKind, String> {
        match s {
            "kron" | "kronecker" => Ok(PairwiseKernelKind::Kronecker),
            "symmetric" | "sym" => Ok(PairwiseKernelKind::SymmetricKron),
            "antisymmetric" | "anti" => Ok(PairwiseKernelKind::AntiSymmetricKron),
            "cartesian" => Ok(PairwiseKernelKind::Cartesian),
            other => Err(format!(
                "unknown pairwise kernel '{other}' (kron, symmetric, antisymmetric, cartesian)"
            )),
        }
    }

    /// Canonical CLI / manifest name.
    pub fn name(&self) -> &'static str {
        match self {
            PairwiseKernelKind::Kronecker => "kron",
            PairwiseKernelKind::SymmetricKron => "symmetric",
            PairwiseKernelKind::AntiSymmetricKron => "antisymmetric",
            PairwiseKernelKind::Cartesian => "cartesian",
        }
    }

    /// Whether this family needs the cross-role kernel block `c(·,·)`
    /// (start-vertex vs end-vertex evaluations).
    pub fn needs_cross(&self) -> bool {
        matches!(
            self,
            PairwiseKernelKind::SymmetricKron | PairwiseKernelKind::AntiSymmetricKron
        )
    }

    /// Validate that the vertex domains support this family: the symmetric
    /// and anti-symmetric kernels evaluate the vertex kernel *across* the
    /// two roles, so both roles must share one kernel function and one
    /// feature dimensionality.
    pub fn validate_vertex_domains(
        &self,
        kernel_d: KernelKind,
        kernel_t: KernelKind,
        d_dim: usize,
        r_dim: usize,
    ) -> Result<(), String> {
        if !self.needs_cross() {
            return Ok(());
        }
        if kernel_d != kernel_t {
            return Err(format!(
                "pairwise kernel '{}' requires identical start/end vertex kernels \
                 (got {} vs {})",
                self.name(),
                kernel_d.name(),
                kernel_t.name()
            ));
        }
        if d_dim != r_dim {
            return Err(format!(
                "pairwise kernel '{}' requires start and end vertices in one feature \
                 space (got {d_dim}-d vs {r_dim}-d features)",
                self.name()
            ));
        }
        Ok(())
    }
}

/// Exact-match vertex-identity block `δ[i,j] = 1` iff row `i` of `x` equals
/// row `j` of `y` bit for bit — the `δ(·,·)` factor of the Cartesian kernel.
/// Between a vertex set and itself this is the identity matrix (plus any
/// duplicate-feature collisions, which by definition *are* the same vertex).
pub fn delta_matrix(x: &Matrix, y: &Matrix) -> Matrix {
    assert_eq!(x.cols(), y.cols(), "delta_matrix: feature dim mismatch");
    Matrix::from_fn(x.rows(), y.rows(), |i, j| if x.row(i) == y.row(j) { 1.0 } else { 0.0 })
}

/// One planned `w · R(M⊗N)Cᵀ` summand of a [`PairwiseOp`].
struct PairwiseTerm {
    weight: f64,
    m: Arc<Matrix>,
    n: Arc<Matrix>,
    m_t: Arc<Matrix>,
    n_t: Arc<Matrix>,
    rows: Arc<KronIndex>,
    cols: Arc<KronIndex>,
    plan: Arc<EdgePlan>,
}

impl PairwiseTerm {
    /// Build a term, creating a full (output-bucketed) [`EdgePlan`] unless a
    /// shared plan is supplied.
    #[allow(clippy::too_many_arguments)]
    fn new(
        weight: f64,
        m: Arc<Matrix>,
        n: Arc<Matrix>,
        m_t: Arc<Matrix>,
        n_t: Arc<Matrix>,
        rows: Arc<KronIndex>,
        cols: Arc<KronIndex>,
        plan: Option<Arc<EdgePlan>>,
    ) -> PairwiseTerm {
        let plan = plan.unwrap_or_else(|| {
            Arc::new(EdgePlan::build_full(&rows, &cols, m.rows(), m.cols(), n.rows(), n.cols()))
        });
        PairwiseTerm { weight, m, n, m_t, n_t, rows, cols, plan }
    }
}

/// Long-lived trained-side state shared by every per-batch prediction
/// operator of one serving context (mirrors what
/// [`KronPredictOp::with_shared`](super::operator::KronPredictOp::with_shared)
/// shares, extended with the swapped-column plan the symmetric family
/// needs): the train edge index, its stage-1 [`EdgePlan`] bucketing, the
/// swapped index + plan when the kind uses the cross term, and a
/// [`WorkspacePool`]. Build once per trained model, reuse across batches.
pub struct PairwiseShared {
    kind: PairwiseKernelKind,
    train_idx: Arc<KronIndex>,
    swapped_idx: Option<Arc<KronIndex>>,
    plan: Arc<EdgePlan>,
    swapped_plan: Option<Arc<EdgePlan>>,
    pool: Arc<WorkspacePool>,
}

impl PairwiseShared {
    /// Prebuild shared prediction state for `train_idx` over `q` end
    /// vertices and `m` start vertices (the column counts of the `Ĝ`/`K̂`
    /// blocks every batch supplies).
    pub fn new(
        kind: PairwiseKernelKind,
        train_idx: Arc<KronIndex>,
        q: usize,
        m: usize,
    ) -> PairwiseShared {
        Self::with_pool_retention(
            kind,
            train_idx,
            q,
            m,
            super::engine::DEFAULT_POOL_RETENTION,
        )
    }

    /// [`PairwiseShared::new`] with an explicit bound on idle pooled
    /// workspaces (the [`Compute`](crate::api::Compute) policy's
    /// `workspace_retention` knob).
    pub fn with_pool_retention(
        kind: PairwiseKernelKind,
        train_idx: Arc<KronIndex>,
        q: usize,
        m: usize,
        retention: usize,
    ) -> PairwiseShared {
        let plan = Arc::new(EdgePlan::build(&train_idx, q, m));
        let (swapped_idx, swapped_plan) = if kind.needs_cross() {
            let swapped =
                Arc::new(KronIndex::new(train_idx.right.clone(), train_idx.left.clone()));
            let swapped_plan = Arc::new(EdgePlan::build(&swapped, m, q));
            (Some(swapped), Some(swapped_plan))
        } else {
            (None, None)
        };
        PairwiseShared {
            kind,
            train_idx,
            swapped_idx,
            plan,
            swapped_plan,
            pool: Arc::new(WorkspacePool::with_retention(retention)),
        }
    }

    /// The pairwise family this shared state was built for.
    pub fn kind(&self) -> PairwiseKernelKind {
        self.kind
    }

    /// The shared training edge index.
    pub fn train_idx(&self) -> &Arc<KronIndex> {
        &self.train_idx
    }
}

/// A pairwise kernel operator: a weighted sum of one or two planned GVT applies
/// implementing one [`PairwiseKernelKind`], either as the square training
/// operator `Q = Σ w·R(M⊗N)Rᵀ` (via [`PairwiseOp::training`]) or as the
/// rectangular test-vs-train prediction operator (via
/// [`PairwiseOp::prediction`] and friends).
///
/// Like the single-kernel operators it generalizes, a `PairwiseOp` is
/// `Sync` (scratch comes from a [`WorkspacePool`]), carries a `threads` knob
/// ([`PairwiseOp::with_threads`]) with bitwise-deterministic sharding, and
/// implements [`LinOp`]/[`MultiLinOp`] so CG/MINRES/QMR/block-CG drive it
/// unchanged. The `Kronecker` variant executes the *identical* call sequence
/// as [`KronKernelOp`](super::operator::KronKernelOp) /
/// [`KronPredictOp`](super::operator::KronPredictOp), so its results are
/// bitwise unchanged from the pre-family code (pinned by tests).
pub struct PairwiseOp {
    kind: PairwiseKernelKind,
    terms: Vec<PairwiseTerm>,
    n_out: usize,
    n_in: usize,
    engine: GvtEngine,
    pool: Arc<WorkspacePool>,
}

impl PairwiseOp {
    /// Build the square training-kernel operator over the training edges
    /// `idx` (`left` = end vertex into `g`, `right` = start vertex into `k`,
    /// as everywhere in the crate).
    ///
    /// `g` (`q×q`) and `k` (`m×m`) are the symmetric per-role kernel
    /// matrices; the auxiliary blocks depend on the kind:
    ///
    /// * `SymmetricKron`/`AntiSymmetricKron` — `aux_g` (`q×m`) is the
    ///   **required** end-vs-start cross-role kernel block; its transpose is
    ///   derived internally (so the two cross factors can never disagree)
    ///   and `aux_k` is ignored;
    /// * `Cartesian` — `aux_g` (`q×q`) / `aux_k` (`m×m`) are the end / start
    ///   vertex-identity δ blocks. Pass
    ///   [`delta_matrix`]`(features, features)` so duplicate feature rows
    ///   count as the same vertex — **matching what the prediction path
    ///   does** — or `None` to fall back to the index identity
    ///   ([`Matrix::eye`]);
    /// * `Kronecker` — both ignored, pass `None`.
    pub fn training(
        kind: PairwiseKernelKind,
        g: Arc<Matrix>,
        k: Arc<Matrix>,
        aux_g: Option<Arc<Matrix>>,
        aux_k: Option<Arc<Matrix>>,
        idx: KronIndex,
    ) -> Result<PairwiseOp, String> {
        if g.rows() != g.cols() {
            return Err(format!("G must be square, got {}x{}", g.rows(), g.cols()));
        }
        if k.rows() != k.cols() {
            return Err(format!("K must be square, got {}x{}", k.rows(), k.cols()));
        }
        idx.validate(g.rows(), k.rows()).map_err(|e| format!("edge index: {e}"))?;
        let n = idx.len();
        let idx = Arc::new(idx);
        let terms = match kind {
            PairwiseKernelKind::Kronecker => vec![PairwiseTerm::new(
                1.0,
                g.clone(),
                k.clone(),
                g,
                k,
                idx.clone(),
                idx,
                None,
            )],
            PairwiseKernelKind::SymmetricKron | PairwiseKernelKind::AntiSymmetricKron => {
                let cross = aux_g.ok_or_else(|| {
                    format!(
                        "pairwise kernel '{}' needs the q×m end-vs-start cross-kernel block",
                        kind.name()
                    )
                })?;
                if cross.rows() != g.rows() || cross.cols() != k.rows() {
                    return Err(format!(
                        "cross-kernel block must be {}x{}, got {}x{}",
                        g.rows(),
                        k.rows(),
                        cross.rows(),
                        cross.cols()
                    ));
                }
                let cross_t = Arc::new(cross.transpose());
                let swapped = Arc::new(KronIndex::new(idx.right.clone(), idx.left.clone()));
                let w = if kind == PairwiseKernelKind::AntiSymmetricKron { -0.5 } else { 0.5 };
                vec![
                    PairwiseTerm::new(
                        0.5,
                        g.clone(),
                        k.clone(),
                        g,
                        k,
                        idx.clone(),
                        idx.clone(),
                        None,
                    ),
                    // Cross term R(C ⊗ Cᵀ)C̃ᵀ: the column index swaps each
                    // edge's (end, start) pair, turning `c(d,t')c(t,d')`
                    // into one standard GVT apply.
                    PairwiseTerm::new(
                        w,
                        cross.clone(),
                        cross_t.clone(),
                        cross_t,
                        cross,
                        idx.clone(),
                        swapped,
                        None,
                    ),
                ]
            }
            PairwiseKernelKind::Cartesian => {
                let delta_q = match aux_g {
                    Some(d) if d.rows() == g.rows() && d.cols() == g.rows() => d,
                    Some(d) => {
                        return Err(format!(
                            "end-side delta block must be {0}x{0}, got {1}x{2}",
                            g.rows(),
                            d.rows(),
                            d.cols()
                        ))
                    }
                    None => Arc::new(Matrix::eye(g.rows())),
                };
                let delta_m = match aux_k {
                    Some(d) if d.rows() == k.rows() && d.cols() == k.rows() => d,
                    Some(d) => {
                        return Err(format!(
                            "start-side delta block must be {0}x{0}, got {1}x{2}",
                            k.rows(),
                            d.rows(),
                            d.cols()
                        ))
                    }
                    None => Arc::new(Matrix::eye(k.rows())),
                };
                // Both terms share the same rows/cols index and the same
                // factor dimensions, so one plan serves both.
                let plan = Arc::new(EdgePlan::build_full(
                    &idx,
                    &idx,
                    g.rows(),
                    g.rows(),
                    k.rows(),
                    k.rows(),
                ));
                vec![
                    PairwiseTerm::new(
                        1.0,
                        g.clone(),
                        delta_m.clone(),
                        g,
                        delta_m,
                        idx.clone(),
                        idx.clone(),
                        Some(plan.clone()),
                    ),
                    PairwiseTerm::new(
                        1.0,
                        delta_q.clone(),
                        k.clone(),
                        delta_q,
                        k,
                        idx.clone(),
                        idx,
                        Some(plan),
                    ),
                ]
            }
        };
        Ok(PairwiseOp {
            kind,
            terms,
            n_out: n,
            n_in: n,
            engine: GvtEngine::serial(),
            pool: Arc::new(WorkspacePool::new()),
        })
    }

    /// Convenience training constructor that computes every kernel /
    /// identity block from raw vertex features — the **single checked seam**
    /// all trainers (ridge, SVM, Newton) build their dual operators through.
    /// Validates the vertex domains once
    /// ([`PairwiseKernelKind::validate_vertex_domains`]) and assembles the
    /// per-family auxiliary blocks exactly as the prediction-side
    /// [`PairwiseOp::prediction_from_features`] does, so the trained and
    /// scored kernels can never drift apart. Blocks are built with the
    /// threaded GEMM and the returned operator shards its applies over the
    /// same `threads`.
    pub fn training_from_features(
        kind: PairwiseKernelKind,
        kernel_d: KernelKind,
        kernel_t: KernelKind,
        start_features: &Matrix,
        end_features: &Matrix,
        idx: KronIndex,
        threads: usize,
    ) -> Result<PairwiseOp, String> {
        kind.validate_vertex_domains(
            kernel_d,
            kernel_t,
            start_features.cols(),
            end_features.cols(),
        )?;
        let k = Arc::new(kernel_d.square_matrix_threaded(start_features, threads));
        let g = Arc::new(kernel_t.square_matrix_threaded(end_features, threads));
        let (aux_g, aux_k) = match kind {
            PairwiseKernelKind::Kronecker => (None, None),
            PairwiseKernelKind::SymmetricKron | PairwiseKernelKind::AntiSymmetricKron => (
                Some(Arc::new(kernel_matrix_threaded(
                    kernel_t,
                    end_features,
                    start_features,
                    threads,
                ))),
                None,
            ),
            // Feature-equality δ blocks (not the index identity), so the
            // trained kernel agrees with what the prediction path scores when
            // distinct vertex indices carry identical feature rows.
            PairwiseKernelKind::Cartesian => (
                Some(Arc::new(delta_matrix(end_features, end_features))),
                Some(Arc::new(delta_matrix(start_features, start_features))),
            ),
        };
        Self::training(kind, g, k, aux_g, aux_k, idx).map(|op| op.with_threads(threads))
    }

    /// Build the rectangular prediction operator from precomputed kernel
    /// blocks. `ghat` (`v×q`) and `khat` (`u×m`) are the test-vs-train
    /// blocks every family uses; the auxiliary blocks depend on the kind:
    ///
    /// * `SymmetricKron`/`AntiSymmetricKron` — `aux_g` (`v×m`) holds
    ///   `c(test-end, train-start)` and `aux_k` (`u×q`) holds
    ///   `c(test-start, train-end)`;
    /// * `Cartesian` — `aux_g` (`v×q`) and `aux_k` (`u×m`) are the
    ///   [`delta_matrix`] identity blocks of the end / start side;
    /// * `Kronecker` — both ignored, pass `None`.
    pub fn prediction(
        kind: PairwiseKernelKind,
        ghat: Matrix,
        khat: Matrix,
        aux_g: Option<Matrix>,
        aux_k: Option<Matrix>,
        test_idx: KronIndex,
        train_idx: KronIndex,
    ) -> Result<PairwiseOp, String> {
        let train_idx = Arc::new(train_idx);
        Self::prediction_impl(
            kind,
            ghat,
            khat,
            aux_g,
            aux_k,
            test_idx,
            train_idx,
            None,
            None,
            None,
            Arc::new(WorkspacePool::new()),
        )
    }

    /// [`PairwiseOp::prediction`] reusing the trained-side state of a
    /// serving context — the serving fast path: only the per-batch test-side
    /// blocks and transposes are built here; the train index, its plans, and
    /// the workspace pool come from `shared` (built once per model).
    pub fn prediction_shared(
        ghat: Matrix,
        khat: Matrix,
        aux_g: Option<Matrix>,
        aux_k: Option<Matrix>,
        test_idx: KronIndex,
        shared: &PairwiseShared,
    ) -> Result<PairwiseOp, String> {
        Self::prediction_impl(
            shared.kind,
            ghat,
            khat,
            aux_g,
            aux_k,
            test_idx,
            shared.train_idx.clone(),
            shared.swapped_idx.clone(),
            Some(shared.plan.clone()),
            shared.swapped_plan.clone(),
            shared.pool.clone(),
        )
    }

    /// Convenience prediction constructor that computes every kernel /
    /// identity block from raw vertex features (what [`crate::model`] and
    /// the trainers' validation scoring use). The blocks are built with the
    /// threaded GEMM and the returned operator shards its applies over the
    /// same `threads`.
    #[allow(clippy::too_many_arguments)]
    pub fn prediction_from_features(
        kind: PairwiseKernelKind,
        kernel_d: KernelKind,
        kernel_t: KernelKind,
        test_start: &Matrix,
        test_end: &Matrix,
        train_start: &Matrix,
        train_end: &Matrix,
        test_idx: KronIndex,
        train_idx: KronIndex,
        threads: usize,
    ) -> Result<PairwiseOp, String> {
        kind.validate_vertex_domains(
            kernel_d,
            kernel_t,
            train_start.cols(),
            train_end.cols(),
        )?;
        let khat = kernel_matrix_threaded(kernel_d, test_start, train_start, threads);
        let ghat = kernel_matrix_threaded(kernel_t, test_end, train_end, threads);
        let (aux_g, aux_k) = match kind {
            PairwiseKernelKind::Kronecker => (None, None),
            // Fully homogeneous trained side: the cross blocks equal
            // ghat/khat bit for bit (one shared kernel and feature matrix),
            // so reuse them instead of two more kernel GEMMs.
            PairwiseKernelKind::SymmetricKron | PairwiseKernelKind::AntiSymmetricKron
                if train_start == train_end =>
            {
                (Some(ghat.clone()), Some(khat.clone()))
            }
            PairwiseKernelKind::SymmetricKron | PairwiseKernelKind::AntiSymmetricKron => (
                Some(kernel_matrix_threaded(kernel_t, test_end, train_start, threads)),
                Some(kernel_matrix_threaded(kernel_d, test_start, train_end, threads)),
            ),
            PairwiseKernelKind::Cartesian => (
                Some(delta_matrix(test_end, train_end)),
                Some(delta_matrix(test_start, train_start)),
            ),
        };
        Self::prediction(kind, ghat, khat, aux_g, aux_k, test_idx, train_idx)
            .map(|op| op.with_threads(threads))
    }

    #[allow(clippy::too_many_arguments)]
    fn prediction_impl(
        kind: PairwiseKernelKind,
        ghat: Matrix,
        khat: Matrix,
        aux_g: Option<Matrix>,
        aux_k: Option<Matrix>,
        test_idx: KronIndex,
        train_idx: Arc<KronIndex>,
        swapped_idx: Option<Arc<KronIndex>>,
        plan: Option<Arc<EdgePlan>>,
        swapped_plan: Option<Arc<EdgePlan>>,
        pool: Arc<WorkspacePool>,
    ) -> Result<PairwiseOp, String> {
        test_idx
            .validate(ghat.rows(), khat.rows())
            .map_err(|e| format!("test index: {e}"))?;
        train_idx
            .validate(ghat.cols(), khat.cols())
            .map_err(|e| format!("train index: {e}"))?;
        let (v, q) = (ghat.rows(), ghat.cols());
        let (u, m) = (khat.rows(), khat.cols());
        let n_out = test_idx.len();
        let n_in = train_idx.len();
        let test_idx = Arc::new(test_idx);
        let ghat_t = Arc::new(ghat.transpose());
        let khat_t = Arc::new(khat.transpose());
        let ghat = Arc::new(ghat);
        let khat = Arc::new(khat);

        let require_aux = |block: Option<Matrix>,
                           name: &str,
                           rows: usize,
                           cols: usize|
         -> Result<Arc<Matrix>, String> {
            let block = block.ok_or_else(|| {
                format!("pairwise kernel '{}' needs the {name} block", kind.name())
            })?;
            if block.rows() != rows || block.cols() != cols {
                return Err(format!(
                    "{name} block must be {rows}x{cols}, got {}x{}",
                    block.rows(),
                    block.cols()
                ));
            }
            Ok(Arc::new(block))
        };

        let terms = match kind {
            PairwiseKernelKind::Kronecker => vec![PairwiseTerm::new(
                1.0,
                ghat,
                khat,
                ghat_t,
                khat_t,
                test_idx,
                train_idx,
                plan,
            )],
            PairwiseKernelKind::SymmetricKron | PairwiseKernelKind::AntiSymmetricKron => {
                let aux_g = require_aux(aux_g, "test-end × train-start cross", v, m)?;
                let aux_k = require_aux(aux_k, "test-start × train-end cross", u, q)?;
                let aux_g_t = Arc::new(aux_g.transpose());
                let aux_k_t = Arc::new(aux_k.transpose());
                let swapped = swapped_idx.unwrap_or_else(|| {
                    Arc::new(KronIndex::new(train_idx.right.clone(), train_idx.left.clone()))
                });
                let w = if kind == PairwiseKernelKind::AntiSymmetricKron { -0.5 } else { 0.5 };
                vec![
                    PairwiseTerm::new(
                        0.5,
                        ghat,
                        khat,
                        ghat_t,
                        khat_t,
                        test_idx.clone(),
                        train_idx,
                        plan,
                    ),
                    PairwiseTerm::new(
                        w,
                        aux_g,
                        aux_k,
                        aux_g_t,
                        aux_k_t,
                        test_idx,
                        swapped,
                        swapped_plan,
                    ),
                ]
            }
            PairwiseKernelKind::Cartesian => {
                let aux_g = require_aux(aux_g, "test-end × train-end delta", v, q)?;
                let aux_k = require_aux(aux_k, "test-start × train-start delta", u, m)?;
                let aux_g_t = Arc::new(aux_g.transpose());
                let aux_k_t = Arc::new(aux_k.transpose());
                // Both terms share the train-side column index, so they can
                // share one plan.
                let shared_plan = plan.unwrap_or_else(|| {
                    Arc::new(EdgePlan::build_full(&test_idx, &train_idx, v, q, u, m))
                });
                vec![
                    PairwiseTerm::new(
                        1.0,
                        ghat,
                        aux_k,
                        ghat_t,
                        aux_k_t,
                        test_idx.clone(),
                        train_idx.clone(),
                        Some(shared_plan.clone()),
                    ),
                    PairwiseTerm::new(
                        1.0,
                        aux_g,
                        khat,
                        aux_g_t,
                        khat_t,
                        test_idx,
                        train_idx,
                        Some(shared_plan),
                    ),
                ]
            }
        };
        Ok(PairwiseOp { kind, terms, n_out, n_in, engine: GvtEngine::serial(), pool })
    }

    /// Shard every apply over `threads` worker threads (`0` = all cores,
    /// `1` = serial). Results are bitwise identical for every thread count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.engine = GvtEngine::new(threads);
        self
    }

    /// Replace the operator's scratch pool with one retaining at most
    /// `retention` idle workspaces (see
    /// [`WorkspacePool::with_retention`]) — the
    /// [`Compute`](crate::api::Compute) policy's workspace knob. Purely a
    /// memory/recycling policy: results are unaffected.
    pub fn with_pool_retention(mut self, retention: usize) -> Self {
        self.pool = Arc::new(WorkspacePool::with_retention(retention));
        self
    }

    /// The pairwise family this operator computes.
    pub fn kind(&self) -> PairwiseKernelKind {
        self.kind
    }

    /// Number of planned GVT applies per matvec (1 for Kronecker, 2 for the
    /// other families).
    pub fn n_terms(&self) -> usize {
        self.terms.len()
    }

    /// Worker threads used per apply.
    pub fn threads(&self) -> usize {
        self.engine.threads()
    }

    /// Output dimension: training edges `n` (training op) or test edges `t`
    /// (prediction op).
    pub fn n_out(&self) -> usize {
        self.n_out
    }

    /// Input dimension: training edges `n` for both operator shapes.
    pub fn n_in(&self) -> usize {
        self.n_in
    }

    /// Number of test edges scored per prediction (alias of
    /// [`PairwiseOp::n_out`], mirroring `KronPredictOp::n_test`).
    pub fn n_test(&self) -> usize {
        self.n_out
    }

    /// Number of training edges / dual coefficients expected (alias of
    /// [`PairwiseOp::n_in`], mirroring `KronPredictOp::n_train`).
    pub fn n_train(&self) -> usize {
        self.n_in
    }

    /// `u ← P v` — one apply of the pairwise operator. Zero entries of `v`
    /// are skipped inside every term (eq. 5 of the paper).
    pub fn apply_into(&self, v: &[f64], u: &mut [f64]) {
        assert_eq!(v.len(), self.n_in, "input must have length {}", self.n_in);
        assert_eq!(u.len(), self.n_out, "output must have length {}", self.n_out);
        self.pool.with(|ws| {
            let first = &self.terms[0];
            self.engine.apply_planned(
                &first.m, &first.n, &first.m_t, &first.n_t, &first.rows, &first.cols,
                &first.plan, v, u, ws, None,
            );
            if self.terms.len() == 1 && first.weight == 1.0 {
                return; // the Kronecker fast path: bitwise the legacy apply
            }
            if first.weight != 1.0 {
                for ui in u.iter_mut() {
                    *ui *= first.weight;
                }
            }
            // Scratch for the remaining terms comes from a second pooled
            // workspace (stage 2 fully overwrites it), not a fresh
            // allocation — this sits inside every solver iteration.
            self.pool.with(|ws_tmp| {
                let (tmp, _) = ws_tmp.grab_uncleared(u.len(), 0);
                for term in &self.terms[1..] {
                    self.engine.apply_planned(
                        &term.m, &term.n, &term.m_t, &term.n_t, &term.rows, &term.cols,
                        &term.plan, v, tmp, ws, None,
                    );
                    for (ui, &ti) in u.iter_mut().zip(tmp.iter()) {
                        *ui += term.weight * ti;
                    }
                }
            });
        });
    }

    /// `u_j ← P v_j` for `k_rhs` stacked column planes in one batched sweep
    /// per term (the multi-RHS GVT path). Plane `j` is bitwise identical to
    /// [`PairwiseOp::apply_into`] on plane `j`.
    pub fn apply_multi_into(&self, v: &[f64], k_rhs: usize, u: &mut [f64]) {
        assert_eq!(
            v.len(),
            self.n_in * k_rhs,
            "input must hold {k_rhs} planes of length {}",
            self.n_in
        );
        assert_eq!(
            u.len(),
            self.n_out * k_rhs,
            "output must hold {k_rhs} planes of length {}",
            self.n_out
        );
        if k_rhs == 0 {
            return;
        }
        self.pool.with(|ws| {
            let first = &self.terms[0];
            self.engine.apply_planned_multi(
                &first.m, &first.n, &first.m_t, &first.n_t, &first.rows, &first.cols,
                &first.plan, v, u, k_rhs, ws, None,
            );
            if self.terms.len() == 1 && first.weight == 1.0 {
                return;
            }
            if first.weight != 1.0 {
                for ui in u.iter_mut() {
                    *ui *= first.weight;
                }
            }
            // Pooled scratch, as in `apply_into` (stage 2 overwrites every
            // plane slot, so no clearing is needed).
            self.pool.with(|ws_tmp| {
                let (tmp, _) = ws_tmp.grab_uncleared(u.len(), 0);
                for term in &self.terms[1..] {
                    self.engine.apply_planned_multi(
                        &term.m, &term.n, &term.m_t, &term.n_t, &term.rows, &term.cols,
                        &term.plan, v, tmp, k_rhs, ws, None,
                    );
                    for (ui, &ti) in u.iter_mut().zip(tmp.iter()) {
                        *ui += term.weight * ti;
                    }
                }
            });
        });
    }

    /// Predict scores for all test edges from dual coefficients `a`
    /// (prediction-shaped operators; mirrors `KronPredictOp::predict`).
    pub fn predict(&self, a: &[f64]) -> Vec<f64> {
        let mut p = vec![0.0; self.n_out];
        self.predict_into(a, &mut p);
        p
    }

    /// [`PairwiseOp::predict`] into a preallocated buffer. Panics on length
    /// mismatches (a wrong-length dual vector must not silently truncate).
    pub fn predict_into(&self, a: &[f64], out: &mut [f64]) {
        assert_eq!(
            a.len(),
            self.n_in,
            "dual coefficient vector has length {} but the model was trained on {} edges",
            a.len(),
            self.n_in
        );
        assert_eq!(
            out.len(),
            self.n_out,
            "output buffer has length {} but {} test edges were requested",
            out.len(),
            self.n_out
        );
        self.apply_into(a, out);
    }

    /// Predict `k_rhs` coefficient planes in one batched sweep per term;
    /// plane `j` is bitwise identical to [`PairwiseOp::predict`] on
    /// coefficient set `j` (mirrors `KronPredictOp::predict_multi`).
    pub fn predict_multi(&self, duals: &[f64], k_rhs: usize) -> Vec<f64> {
        let mut out = vec![0.0; self.n_out * k_rhs];
        self.predict_multi_into(duals, k_rhs, &mut out);
        out
    }

    /// [`PairwiseOp::predict_multi`] into a preallocated buffer.
    pub fn predict_multi_into(&self, duals: &[f64], k_rhs: usize, out: &mut [f64]) {
        self.apply_multi_into(duals, k_rhs, out);
    }

    /// Materialize the operator as a dense matrix by summing each term's
    /// explicit submatrix — the `O(f·e)` "Baseline" oracle for tests and the
    /// pairwise bench table. Never used on a hot path.
    pub fn explicit_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.n_out, self.n_in);
        for term in &self.terms {
            let sub = explicit_submatrix(&term.m, &term.n, &term.rows, &term.cols);
            for (o, &s) in out.data_mut().iter_mut().zip(sub.data()) {
                *o += term.weight * s;
            }
        }
        out
    }
}

impl LinOp for PairwiseOp {
    fn dim(&self) -> usize {
        debug_assert_eq!(
            self.n_in, self.n_out,
            "LinOp is only meaningful for square (training) pairwise operators"
        );
        self.n_in
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.apply_into(x, y);
    }
    // apply_transpose: default (every training-family matrix is symmetric).
}

impl MultiLinOp for PairwiseOp {
    fn apply_multi(&self, v: &[f64], k_rhs: usize, u: &mut [f64]) {
        self.apply_multi_into(v, k_rhs, u);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gvt::operator::{KronKernelOp, KronPredictOp};
    use crate::linalg::vecops::assert_allclose;
    use crate::util::rng::Pcg32;

    fn random_kernel(rng: &mut Pcg32, n: usize) -> Matrix {
        let g = Matrix::from_fn(n, n, |_, _| rng.normal());
        let mut k = g.matmul_nt(&g);
        for i in 0..n {
            k.add_at(i, i, 1.0);
        }
        let scale = 1.0 / (n as f64);
        k.data_mut().iter_mut().for_each(|v| *v *= scale);
        k
    }

    fn random_edges(rng: &mut Pcg32, q: usize, m: usize, n_edges: usize) -> KronIndex {
        KronIndex::new(
            (0..n_edges).map(|_| rng.below(q) as u32).collect(),
            (0..n_edges).map(|_| rng.below(m) as u32).collect(),
        )
    }

    fn assert_sync<T: Sync>() {}

    #[test]
    fn pairwise_op_is_sync() {
        assert_sync::<PairwiseOp>();
        assert_sync::<PairwiseShared>();
    }

    #[test]
    fn kind_parse_roundtrip() {
        for kind in [
            PairwiseKernelKind::Kronecker,
            PairwiseKernelKind::SymmetricKron,
            PairwiseKernelKind::AntiSymmetricKron,
            PairwiseKernelKind::Cartesian,
        ] {
            assert_eq!(PairwiseKernelKind::parse(kind.name()).unwrap(), kind);
        }
        assert_eq!(
            PairwiseKernelKind::parse("sym").unwrap(),
            PairwiseKernelKind::SymmetricKron
        );
        assert!(PairwiseKernelKind::parse("nope").is_err());
    }

    #[test]
    fn domain_validation_rejects_mismatches() {
        let sym = PairwiseKernelKind::SymmetricKron;
        let gauss = KernelKind::Gaussian { gamma: 1.0 };
        assert!(sym.validate_vertex_domains(gauss, gauss, 3, 3).is_ok());
        assert!(sym.validate_vertex_domains(gauss, KernelKind::Linear, 3, 3).is_err());
        assert!(sym.validate_vertex_domains(gauss, gauss, 3, 2).is_err());
        // the kron and cartesian families stay heterogeneous-friendly
        assert!(PairwiseKernelKind::Kronecker
            .validate_vertex_domains(gauss, KernelKind::Linear, 3, 2)
            .is_ok());
        assert!(PairwiseKernelKind::Cartesian
            .validate_vertex_domains(gauss, KernelKind::Linear, 3, 2)
            .is_ok());
    }

    #[test]
    fn delta_matrix_marks_exact_row_matches() {
        let x = Matrix::from_vec(3, 2, vec![1.0, 2.0, 3.0, 4.0, 1.0, 2.0]);
        let d = delta_matrix(&x, &x);
        // rows 0 and 2 are identical → a 2x2 block of ones
        for i in 0..3 {
            for j in 0..3 {
                let same = x.row(i) == x.row(j);
                assert_eq!(d.get(i, j), if same { 1.0 } else { 0.0 });
            }
        }
    }

    #[test]
    fn kronecker_training_matches_kron_kernel_op_bitwise() {
        let mut rng = Pcg32::seeded(700);
        let (q, m, n) = (7, 6, 40);
        let g = Arc::new(random_kernel(&mut rng, q));
        let k = Arc::new(random_kernel(&mut rng, m));
        let idx = random_edges(&mut rng, q, m, n);
        let legacy = KronKernelOp::new(g.clone(), k.clone(), idx.clone());
        let pairwise =
            PairwiseOp::training(PairwiseKernelKind::Kronecker, g, k, None, None, idx).unwrap();
        assert_eq!(pairwise.n_terms(), 1);
        let v = rng.normal_vec(n);
        let mut a = vec![0.0; n];
        let mut b = vec![0.0; n];
        legacy.apply_into(&v, &mut a);
        pairwise.apply_into(&v, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn symmetric_training_matches_explicit_dense() {
        let mut rng = Pcg32::seeded(701);
        let (nv, n) = (8, 30);
        let kmat = Arc::new(random_kernel(&mut rng, nv));
        let idx = random_edges(&mut rng, nv, nv, n);
        for kind in [
            PairwiseKernelKind::SymmetricKron,
            PairwiseKernelKind::AntiSymmetricKron,
            PairwiseKernelKind::Cartesian,
        ] {
            let cross = kind.needs_cross().then(|| kmat.clone());
            let op =
                PairwiseOp::training(kind, kmat.clone(), kmat.clone(), cross, None, idx.clone())
                    .unwrap();
            assert_eq!(op.n_terms(), 2);
            let dense = op.explicit_dense();
            let v = rng.normal_vec(n);
            let mut fast = vec![0.0; n];
            op.apply_into(&v, &mut fast);
            assert_allclose(&fast, &dense.matvec(&v), 1e-10, 1e-10);
        }
    }

    #[test]
    fn symmetric_training_entries_are_projections_of_kron() {
        // Q_sym[h,l] + Q_anti[h,l] must equal the plain Kronecker entry.
        let mut rng = Pcg32::seeded(702);
        let (nv, n) = (6, 18);
        let kmat = Arc::new(random_kernel(&mut rng, nv));
        let idx = random_edges(&mut rng, nv, nv, n);
        let kron = PairwiseOp::training(
            PairwiseKernelKind::Kronecker,
            kmat.clone(),
            kmat.clone(),
            None,
            None,
            idx.clone(),
        )
        .unwrap()
        .explicit_dense();
        let sym = PairwiseOp::training(
            PairwiseKernelKind::SymmetricKron,
            kmat.clone(),
            kmat.clone(),
            Some(kmat.clone()),
            None,
            idx.clone(),
        )
        .unwrap()
        .explicit_dense();
        let anti = PairwiseOp::training(
            PairwiseKernelKind::AntiSymmetricKron,
            kmat.clone(),
            kmat.clone(),
            Some(kmat.clone()),
            None,
            idx,
        )
        .unwrap()
        .explicit_dense();
        for h in 0..n {
            for l in 0..n {
                let sum = sym.get(h, l) + anti.get(h, l);
                assert!((sum - kron.get(h, l)).abs() < 1e-12, "entry ({h},{l})");
            }
        }
    }

    #[test]
    fn cartesian_entries_require_a_shared_vertex() {
        let mut rng = Pcg32::seeded(703);
        let (q, m, n) = (5, 5, 12);
        let g = Arc::new(random_kernel(&mut rng, q));
        let k = Arc::new(random_kernel(&mut rng, m));
        let idx = random_edges(&mut rng, q, m, n);
        let dense = PairwiseOp::training(
            PairwiseKernelKind::Cartesian,
            g.clone(),
            k.clone(),
            None,
            None,
            idx.clone(),
        )
        .unwrap()
        .explicit_dense();
        for h in 0..n {
            for l in 0..n {
                let (sh, rh) = (idx.left[h] as usize, idx.right[h] as usize);
                let (sl, rl) = (idx.left[l] as usize, idx.right[l] as usize);
                let mut expect = 0.0;
                if rh == rl {
                    expect += g.get(sh, sl);
                }
                if sh == sl {
                    expect += k.get(rh, rl);
                }
                assert!((dense.get(h, l) - expect).abs() < 1e-12, "entry ({h},{l})");
            }
        }
    }

    #[test]
    fn kronecker_prediction_matches_kron_predict_op_bitwise() {
        let mut rng = Pcg32::seeded(704);
        let (q, m, n) = (5, 6, 20);
        let (v_test, u_test, t_test) = (4, 3, 11);
        let train_idx = random_edges(&mut rng, q, m, n);
        let test_idx = random_edges(&mut rng, v_test, u_test, t_test);
        let ghat = Matrix::from_fn(v_test, q, |_, _| rng.normal());
        let khat = Matrix::from_fn(u_test, m, |_, _| rng.normal());
        let a = rng.normal_vec(n);
        let legacy =
            KronPredictOp::new(ghat.clone(), khat.clone(), test_idx.clone(), train_idx.clone());
        let pairwise = PairwiseOp::prediction(
            PairwiseKernelKind::Kronecker,
            ghat,
            khat,
            None,
            None,
            test_idx,
            train_idx,
        )
        .unwrap();
        assert_eq!(pairwise.n_test(), t_test);
        assert_eq!(pairwise.n_train(), n);
        assert_eq!(legacy.predict(&a), pairwise.predict(&a));
    }

    #[test]
    fn prediction_shared_matches_fresh_operator() {
        let mut rng = Pcg32::seeded(705);
        let nv = 7;
        let n = 26;
        let kmat = random_kernel(&mut rng, nv);
        let train_idx = random_edges(&mut rng, nv, nv, n);
        let a = rng.normal_vec(n);
        for kind in [
            PairwiseKernelKind::Kronecker,
            PairwiseKernelKind::SymmetricKron,
            PairwiseKernelKind::AntiSymmetricKron,
        ] {
            let shared =
                PairwiseShared::new(kind, Arc::new(train_idx.clone()), nv, nv);
            let test_idx = random_edges(&mut rng, 3, 4, 9);
            let ghat = Matrix::from_fn(3, nv, |_, _| rng.normal());
            let khat = Matrix::from_fn(4, nv, |_, _| rng.normal());
            let aux = kind.needs_cross();
            let aux_g = aux.then(|| Matrix::from_fn(3, nv, |i, j| ghat.get(i, j) * 0.5));
            let aux_k = aux.then(|| Matrix::from_fn(4, nv, |i, j| khat.get(i, j) * 0.5));
            let fresh = PairwiseOp::prediction(
                kind,
                ghat.clone(),
                khat.clone(),
                aux_g.clone(),
                aux_k.clone(),
                test_idx.clone(),
                train_idx.clone(),
            )
            .unwrap()
            .predict(&a);
            let via_shared =
                PairwiseOp::prediction_shared(ghat, khat, aux_g, aux_k, test_idx, &shared)
                    .unwrap()
                    .predict(&a);
            assert_eq!(fresh, via_shared, "{kind:?}");
            let _ = (shared.kind(), shared.train_idx().len(), kmat.rows());
        }
    }

    #[test]
    fn training_rejects_bad_shapes() {
        let mut rng = Pcg32::seeded(706);
        let g = Arc::new(random_kernel(&mut rng, 4));
        let k = Arc::new(random_kernel(&mut rng, 3));
        let idx = random_edges(&mut rng, 4, 3, 8);
        // missing cross block
        assert!(PairwiseOp::training(
            PairwiseKernelKind::SymmetricKron,
            g.clone(),
            k.clone(),
            None,
            None,
            idx.clone()
        )
        .is_err());
        // wrong-shape cross block
        let bad_cross = Arc::new(Matrix::zeros(3, 4));
        assert!(PairwiseOp::training(
            PairwiseKernelKind::SymmetricKron,
            g.clone(),
            k.clone(),
            Some(bad_cross),
            None,
            idx.clone()
        )
        .is_err());
        // out-of-bounds edges
        let bad_idx = KronIndex::from_usize(&[9], &[0]);
        assert!(
            PairwiseOp::training(PairwiseKernelKind::Kronecker, g, k, None, None, bad_idx).is_err()
        );
    }

    #[test]
    fn multi_rhs_planes_match_single_applies() {
        let mut rng = Pcg32::seeded(707);
        let (nv, n) = (6, 24);
        let kmat = Arc::new(random_kernel(&mut rng, nv));
        let idx = random_edges(&mut rng, nv, nv, n);
        for kind in [
            PairwiseKernelKind::Kronecker,
            PairwiseKernelKind::SymmetricKron,
            PairwiseKernelKind::Cartesian,
        ] {
            let cross = kind.needs_cross().then(|| kmat.clone());
            let op =
                PairwiseOp::training(kind, kmat.clone(), kmat.clone(), cross, None, idx.clone())
                    .unwrap();
            let k_rhs = 3;
            let v = rng.normal_vec(n * k_rhs);
            let mut multi = vec![0.0; n * k_rhs];
            op.apply_multi_into(&v, k_rhs, &mut multi);
            for j in 0..k_rhs {
                let mut single = vec![0.0; n];
                op.apply_into(&v[j * n..(j + 1) * n], &mut single);
                assert_eq!(&multi[j * n..(j + 1) * n], single.as_slice(), "{kind:?} plane {j}");
            }
        }
    }
}
