//! Linear operators built from the generalized vec trick.
//!
//! * [`TensorKernelOp`] — the training kernel matrix of a **D-way chain**
//!   `Q = R(K₁⊗…⊗K_D)Rᵀ` as a matrix-free symmetric operator; the
//!   generalization of eq. 7 of the paper to tensor-product grids.
//! * [`KronKernelOp`] — the two-factor `Q = R(G⊗K)Rᵀ` (eq. 7), now a thin
//!   `D = 2` wrapper over [`TensorKernelOp`] pinned bitwise to the
//!   pre-chain two-factor pipeline.
//! * [`RidgeSystemOp`] — `Q + λI` (the ridge linear system, §4.1).
//! * [`SvmNewtonOp`] — `H·Q + λI` with `H = diag(h)`, `h ∈ {0,1}ⁿ` the
//!   support mask (the L2-SVM Newton system, §4.2) — nonsymmetric, provides
//!   the transpose `Q·H + λI` for QMR.
//! * [`TensorPredictOp`] / [`KronPredictOp`] — zero-shot prediction
//!   `R̂(K̂₁⊗…⊗K̂_D)Rᵀ a` (§3.1, D-way and two-factor) with the
//!   sparse-coefficient shortcut of eq. (5).
//!
//! Every operator executes through the [`GvtEngine`](super::engine::GvtEngine)
//! with a precomputed plan ([`ChainPlan`](super::engine::ChainPlan), which
//! wraps the two-factor [`EdgePlan`](super::engine::EdgePlan) at `D = 2`);
//! the `threads` knob (via `with_threads`) shards each matvec across cores
//! with bitwise-deterministic results. Scratch buffers come from a
//! [`WorkspacePool`], so the operators are `Sync` — `LinOp` consumers and
//! the coordinator's batch worker can share one trained operator across
//! threads.

use std::sync::Arc;

use super::engine::{ChainPlan, EdgePlan, GvtEngine, WorkspacePool};
use super::tensor::TensorIndex;
use super::{Branch, KronIndex};
use crate::linalg::eig::EigH;
use crate::linalg::solvers::{LinOp, MultiLinOp};
use crate::linalg::Matrix;

/// The training-kernel operator of a D-way tensor-product chain,
/// `Q = R(K₁⊗…⊗K_D)Rᵀ` (n×n, symmetric PSD).
///
/// Each `K_d` is the (symmetric) kernel matrix of one grid mode and `idx`
/// maps each training edge to its per-mode vertex tuple. This is what lets
/// ridge / SVM / Newton training run unchanged on grid and tensor workloads
/// (spatio-temporal, multi-relational): the solvers only see a `LinOp`.
///
/// Like the two-factor operator it generalizes, the operator is `Sync`
/// (per-apply scratch from an internal pool) and every apply is bitwise
/// identical for every thread count.
pub struct TensorKernelOp {
    factors: Vec<Arc<Matrix>>,
    idx: TensorIndex,
    plan: ChainPlan,
    engine: GvtEngine,
    pool: WorkspacePool,
    branch: Option<Branch>,
}

impl TensorKernelOp {
    /// Build the operator from one symmetric kernel matrix per mode and the
    /// training edge index (one index column per mode). Runs single-threaded
    /// until [`TensorKernelOp::with_threads`] is applied.
    pub fn new(factors: Vec<Arc<Matrix>>, idx: TensorIndex) -> Self {
        assert!(factors.len() >= 2, "tensor chain needs at least two factors");
        for (d, k) in factors.iter().enumerate() {
            assert_eq!(k.rows(), k.cols(), "factor {d} must be square");
        }
        let dims: Vec<usize> = factors.iter().map(|k| k.rows()).collect();
        let plan =
            ChainPlan::build(&idx, &idx, &dims, &dims).expect("invalid tensor kernel operator");
        TensorKernelOp {
            factors,
            idx,
            plan,
            engine: GvtEngine::serial(),
            pool: WorkspacePool::new(),
            branch: None,
        }
    }

    /// Force a specific branch of Algorithm 1. Honored at `D = 2` (where the
    /// chain delegates to the two-factor pipeline); ignored for `D ≥ 3`.
    pub fn with_branch(mut self, branch: Branch) -> Self {
        self.branch = Some(branch);
        self
    }

    /// Shard every matvec over `threads` worker threads (`0` = all cores,
    /// `1` = serial). Results are bitwise identical for every thread count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.engine = GvtEngine::new(threads);
        self
    }

    /// Worker threads used per matvec.
    pub fn threads(&self) -> usize {
        self.engine.threads()
    }

    /// Number of factors `D` in the chain.
    pub fn order(&self) -> usize {
        self.factors.len()
    }

    /// Number of training edges `n`.
    pub fn n_edges(&self) -> usize {
        self.idx.len()
    }

    /// The training edge index.
    pub fn index(&self) -> &TensorIndex {
        &self.idx
    }

    /// The per-mode kernel matrices.
    pub fn factors(&self) -> &[Arc<Matrix>] {
        &self.factors
    }

    fn factor_refs(&self) -> Vec<&Matrix> {
        self.factors.iter().map(|f| f.as_ref()).collect()
    }

    /// `u ← Q v`. Zero entries of `v` are skipped (sparse shortcut).
    pub fn apply_into(&self, v: &[f64], u: &mut [f64]) {
        let refs = self.factor_refs();
        self.pool.with(|ws| {
            // symmetric factors are their own transposes
            self.engine.apply_chain(&refs, &refs, &self.plan, v, u, ws, self.branch);
        });
    }

    /// `u_j ← Q v_j` for `k_rhs` column planes in one batched sweep. Column
    /// `j` is bitwise identical to [`TensorKernelOp::apply_into`] on plane
    /// `j`, so block solvers retrace single-RHS trajectories exactly.
    pub fn apply_multi_into(&self, v: &[f64], k_rhs: usize, u: &mut [f64]) {
        let refs = self.factor_refs();
        self.pool.with(|ws| {
            self.engine.apply_chain_multi(&refs, &refs, &self.plan, v, u, k_rhs, ws, self.branch);
        });
    }

    /// Diagonal of `Q`: `Q[h,h] = Π_d K_d[i^d_h, i^d_h]`.
    pub fn diagonal(&self) -> Vec<f64> {
        (0..self.idx.len())
            .map(|h| {
                self.factors
                    .iter()
                    .zip(&self.idx.modes)
                    .map(|(k, col)| k.get(col[h] as usize, col[h] as usize))
                    .product()
            })
            .collect()
    }
}

impl LinOp for TensorKernelOp {
    fn dim(&self) -> usize {
        self.idx.len()
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.apply_into(x, y);
    }
    // apply_transpose: default (symmetric).
}

impl MultiLinOp for TensorKernelOp {
    fn apply_multi(&self, v: &[f64], k_rhs: usize, u: &mut [f64]) {
        self.apply_multi_into(v, k_rhs, u);
    }
}

/// The training-kernel operator `Q = R(G⊗K)Rᵀ` (n×n, symmetric PSD).
///
/// `G` is the `q×q` end-vertex kernel matrix, `K` the `m×m` start-vertex
/// kernel matrix, and `idx` maps each training edge to its
/// (end-vertex, start-vertex) pair — `idx.left ∈ [q]`, `idx.right ∈ [m]`
/// (matching `G ⊗ K` row ordering). Kernel matrices must be symmetric, so no
/// transposes are stored and `Aᵀ = A`.
///
/// A thin `D = 2` wrapper over [`TensorKernelOp`]: the chain plan delegates
/// two-factor applies to the unmodified
/// [`GvtEngine::apply_planned`](super::engine::GvtEngine::apply_planned)
/// pipeline (automatic branch selection, branch forcing, output-side
/// stage-2 buckets), so results are **bitwise identical to the pre-chain
/// operator** at every thread count.
///
/// The operator is `Sync`: one trained operator may be applied from many
/// threads at once (each apply draws its own scratch workspace from an
/// internal pool), and each apply can itself be sharded across threads via
/// [`KronKernelOp::with_threads`].
pub struct KronKernelOp {
    inner: TensorKernelOp,
    idx: KronIndex,
}

impl KronKernelOp {
    /// Build the operator from symmetric kernel matrices and the training
    /// edge index. Runs single-threaded until [`KronKernelOp::with_threads`]
    /// is applied.
    pub fn new(g: Arc<Matrix>, k: Arc<Matrix>, idx: KronIndex) -> Self {
        assert_eq!(g.rows(), g.cols(), "G must be square");
        assert_eq!(k.rows(), k.cols(), "K must be square");
        idx.validate(g.rows(), k.rows()).expect("edge indices out of bounds");
        // The D=2 chain plan carries the same full EdgePlan (output-side
        // buckets included) the pre-chain operator built.
        let inner = TensorKernelOp::new(vec![g, k], TensorIndex::from_kron(&idx));
        KronKernelOp { inner, idx }
    }

    /// Force a specific branch of Algorithm 1 (benchmarks / tests).
    pub fn with_branch(mut self, branch: Branch) -> Self {
        self.inner = self.inner.with_branch(branch);
        self
    }

    /// Shard every matvec over `threads` worker threads (`0` = all cores,
    /// `1` = serial). Results are bitwise identical for every thread count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.inner = self.inner.with_threads(threads);
        self
    }

    /// Worker threads used per matvec.
    pub fn threads(&self) -> usize {
        self.inner.threads()
    }

    /// Number of training edges `n`.
    pub fn n_edges(&self) -> usize {
        self.idx.len()
    }

    /// Number of distinct end vertices `q` (rows of G).
    pub fn q_vertices(&self) -> usize {
        self.inner.factors()[0].rows()
    }

    /// Number of distinct start vertices `m` (rows of K).
    pub fn m_vertices(&self) -> usize {
        self.inner.factors()[1].rows()
    }

    /// The training edge index.
    pub fn index(&self) -> &KronIndex {
        &self.idx
    }

    /// The end-vertex kernel matrix `G`.
    pub fn g(&self) -> &Arc<Matrix> {
        &self.inner.factors()[0]
    }

    /// The start-vertex kernel matrix `K`.
    pub fn k(&self) -> &Arc<Matrix> {
        &self.inner.factors()[1]
    }

    /// `u ← Q v`. Zero entries of `v` are skipped (sparse shortcut).
    pub fn apply_into(&self, v: &[f64], u: &mut [f64]) {
        self.inner.apply_into(v, u);
    }

    /// `u_j ← Q v_j` for `k_rhs` column planes in one batched sweep (one
    /// edge-index traversal for all right-hand sides). Column `j` is bitwise
    /// identical to [`KronKernelOp::apply_into`] on plane `j`, so the block
    /// solvers driving this path retrace single-RHS trajectories exactly.
    pub fn apply_multi_into(&self, v: &[f64], k_rhs: usize, u: &mut [f64]) {
        self.inner.apply_multi_into(v, k_rhs, u);
    }

    /// Diagonal of `Q`: `Q[h,h] = G[s_h,s_h]·K[r_h,r_h]` (used by SMO-style
    /// baselines and for preconditioning).
    pub fn diagonal(&self) -> Vec<f64> {
        self.inner.diagonal()
    }
}

impl LinOp for KronKernelOp {
    fn dim(&self) -> usize {
        self.idx.len()
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.apply_into(x, y);
    }
    // apply_transpose: default (symmetric).
}

impl MultiLinOp for KronKernelOp {
    fn apply_multi(&self, v: &[f64], k_rhs: usize, u: &mut [f64]) {
        self.apply_multi_into(v, k_rhs, u);
    }
}

/// `Q + λI` — the Kronecker ridge regression system (§4.1), symmetric PD.
///
/// Generic over the wrapped kernel operator so both the plain
/// [`KronKernelOp`] and the pairwise family
/// ([`PairwiseOp`](super::pairwise::PairwiseOp)) can drive the same solvers;
/// `Op` must be a *symmetric* operator.
pub struct RidgeSystemOp<'a, Op: LinOp = KronKernelOp> {
    /// The kernel operator `Q`.
    pub op: &'a Op,
    /// Regularization parameter λ.
    pub lambda: f64,
}

impl<Op: LinOp> LinOp for RidgeSystemOp<'_, Op> {
    fn dim(&self) -> usize {
        self.op.dim()
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.op.apply(x, y);
        for i in 0..x.len() {
            y[i] += self.lambda * x[i];
        }
    }
}

impl<Op: MultiLinOp> MultiLinOp for RidgeSystemOp<'_, Op> {
    fn apply_multi(&self, v: &[f64], k_rhs: usize, u: &mut [f64]) {
        self.op.apply_multi(v, k_rhs, u);
        for (uj, vj) in u.chunks_mut(self.op.dim().max(1)).zip(v.chunks(self.op.dim().max(1))) {
            for (ui, vi) in uj.iter_mut().zip(vj) {
                *ui += self.lambda * vi;
            }
        }
    }
}

/// Kronecker spectral preconditioner for the ridge system `Q + λI` with
/// `Q = R(G⊗K)Rᵀ`, built from per-factor eigendecompositions
/// `G = Q_g Λ_g Q_gᵀ`, `K = Q_k Λ_k Q_kᵀ`.
///
/// The preconditioner treats the training graph as if it were complete:
/// `M = R·(G⊗K + λI)⁻¹·Rᵀ` applied as three small GEMMs on the `q × m`
/// vertex-pair grid,
///
/// ```text
/// z = R · vec( Q_g ( (Q_gᵀ Y Q_k) ∘ D⁻¹ ) Q_kᵀ ) ,   D[i][j] = λg_i·λk_j + λ ,
/// ```
///
/// where `Y` is the residual scattered onto the grid (cells without an edge
/// stay zero). When the graph **is** complete, `R` is a permutation and `M`
/// is the *exact* inverse — PCG converges in one iteration. When the graph is
/// incomplete, `M` is the complete-graph surrogate inverse, which is the
/// spectral preconditioner of the two-step / comparative-KRR literature
/// (arXiv 1606.04275, 1803.01575): still symmetric positive-definite and an
/// increasingly good approximation the denser the graph.
///
/// Cost per apply: `O(q·m·(q + m))` — grid GEMMs only, never `n × n`.
pub struct KronSpectralPrecond {
    qg: Matrix,
    qg_t: Matrix,
    qk: Matrix,
    inv_d: Matrix,
    idx: KronIndex,
    threads: usize,
}

impl KronSpectralPrecond {
    /// Build from per-factor eigendecompositions of `G` (q×q) and `K` (m×m),
    /// the training edge index, and the ridge shift `λ > 0`. Eigenvalue
    /// products are floored at `f64::MIN_POSITIVE` before inversion so a PSD
    /// factor with (numerically) zero eigenvalues cannot produce infinities.
    pub fn new(g_eig: &EigH, k_eig: &EigH, idx: KronIndex, lambda: f64) -> Self {
        let q = g_eig.values.len();
        let m = k_eig.values.len();
        idx.validate(q, m).expect("edge indices out of bounds for eigendecompositions");
        assert!(lambda > 0.0, "spectral preconditioner requires lambda > 0");
        let inv_d = Matrix::from_fn(q, m, |i, j| {
            1.0 / (g_eig.values[i] * k_eig.values[j] + lambda).max(f64::MIN_POSITIVE)
        });
        KronSpectralPrecond {
            qg: g_eig.vectors.clone(),
            qg_t: g_eig.vectors.transpose(),
            qk: k_eig.vectors.clone(),
            inv_d,
            idx,
            threads: 1,
        }
    }

    /// Run the grid GEMMs on `threads` workers (`0` = all cores, `1` =
    /// serial). Bitwise identical results for every thread count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }
}

impl crate::linalg::solvers::Preconditioner for KronSpectralPrecond {
    fn dim(&self) -> usize {
        self.idx.len()
    }

    fn apply(&self, r: &[f64], z: &mut [f64]) {
        let q = self.qg.rows();
        let m = self.qk.rows();
        assert_eq!(r.len(), self.idx.len());
        assert_eq!(z.len(), self.idx.len());
        // Scatter the residual onto the q×m vertex-pair grid (accumulating:
        // duplicate edges add, exactly like Rᵀ).
        let mut y = Matrix::zeros(q, m);
        {
            let data = y.data_mut();
            for (h, (&gi, &ki)) in self.idx.left.iter().zip(&self.idx.right).enumerate() {
                data[gi as usize * m + ki as usize] += r[h];
            }
        }
        // U = Qgᵀ Y Qk ; W = U ∘ D⁻¹ ; Z = Qg W Qkᵀ.
        let u = self.qg_t.matmul_threaded(&y, self.threads).matmul_threaded(&self.qk, self.threads);
        let mut w = u;
        for (wi, di) in w.data_mut().iter_mut().zip(self.inv_d.data()) {
            *wi *= di;
        }
        let zg =
            self.qg.matmul_threaded(&w, self.threads).matmul_nt_threaded(&self.qk, self.threads);
        // Gather back to edge order (R).
        for (h, (&gi, &ki)) in self.idx.left.iter().zip(&self.idx.right).enumerate() {
            z[h] = zg.data()[gi as usize * m + ki as usize];
        }
    }
}

/// `H·Q + λI` with `H = diag(mask)` — the L2-SVM Newton system (§4.2).
///
/// Nonsymmetric; `Aᵀ = Q·H + λI` is provided so QMR can run. The mask is the
/// indicator of the current active set `S = {i : y_i·p_i < 1}`. Generic over
/// the wrapped kernel operator (which must be *symmetric* — true of
/// [`KronKernelOp`] and every training-shaped
/// [`PairwiseOp`](super::pairwise::PairwiseOp) family member).
pub struct SvmNewtonOp<'a, Op: LinOp = KronKernelOp> {
    op: &'a Op,
    mask: Vec<f64>,
    lambda: f64,
}

impl<'a, Op: LinOp> SvmNewtonOp<'a, Op> {
    /// Wrap the kernel operator with an active-set mask (0/1 entries) and λ.
    pub fn new(op: &'a Op, mask: Vec<f64>, lambda: f64) -> Self {
        assert_eq!(mask.len(), op.dim());
        assert!(mask.iter().all(|&m| m == 0.0 || m == 1.0), "mask must be 0/1");
        SvmNewtonOp { op, mask, lambda }
    }

    /// Active-set size `|S|`.
    pub fn active(&self) -> usize {
        self.mask.iter().filter(|&&m| m != 0.0).count()
    }
}

impl<Op: LinOp> LinOp for SvmNewtonOp<'_, Op> {
    fn dim(&self) -> usize {
        self.op.dim()
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.op.apply(x, y);
        for i in 0..x.len() {
            y[i] = self.mask[i] * y[i] + self.lambda * x[i];
        }
    }

    fn apply_transpose(&self, x: &[f64], y: &mut [f64]) {
        // (HQ + λI)ᵀ = Q H + λI  (Q symmetric, H diagonal)
        let masked: Vec<f64> = x.iter().zip(&self.mask).map(|(xi, mi)| xi * mi).collect();
        self.op.apply(&masked, y);
        for i in 0..x.len() {
            y[i] += self.lambda * x[i];
        }
    }
}

/// Zero-shot prediction operator for a D-way chain,
/// `p = R̂(K̂₁⊗…⊗K̂_D)Rᵀ a` (the §3.1 prediction generalized to tensor
/// grids).
///
/// `K̂_d ∈ R^{û_d×m_d}` holds kernel evaluations between the test and
/// training vertices of mode `d`; `test_idx` maps each requested edge to
/// its per-mode test-vertex tuple and `train_idx` maps training edges to
/// their per-mode training-vertex tuples (the same index used at training
/// time). With a sparse dual vector the per-edge stage-1 work shrinks to
/// `‖a‖₀` terms (eq. 5) because the gather skips zeros.
///
/// Like [`TensorKernelOp`], the operator is `Sync` and shards each
/// prediction across threads via [`TensorPredictOp::with_threads`].
pub struct TensorPredictOp {
    factors: Vec<Matrix>,
    factors_t: Vec<Matrix>,
    plan: Arc<ChainPlan>,
    engine: GvtEngine,
    pool: Arc<WorkspacePool>,
}

impl TensorPredictOp {
    /// Build the prediction operator from one test×train kernel block per
    /// mode and the two edge indices. Runs single-threaded until
    /// [`TensorPredictOp::with_threads`] is applied.
    pub fn new(factors: Vec<Matrix>, test_idx: TensorIndex, train_idx: TensorIndex) -> Self {
        assert!(factors.len() >= 2, "tensor chain needs at least two factors");
        let dims_a: Vec<usize> = factors.iter().map(|k| k.rows()).collect();
        let dims_b: Vec<usize> = factors.iter().map(|k| k.cols()).collect();
        let plan = ChainPlan::build(&test_idx, &train_idx, &dims_a, &dims_b)
            .expect("invalid tensor prediction operator");
        let factors_t = factors.iter().map(|k| k.transpose()).collect();
        let pool = Arc::new(WorkspacePool::new());
        TensorPredictOp::from_parts(factors, factors_t, Arc::new(plan), pool)
    }

    /// Assemble from prebuilt parts (the shared-state constructor behind
    /// [`KronPredictOp::with_shared`]).
    pub(crate) fn from_parts(
        factors: Vec<Matrix>,
        factors_t: Vec<Matrix>,
        plan: Arc<ChainPlan>,
        pool: Arc<WorkspacePool>,
    ) -> Self {
        TensorPredictOp { factors, factors_t, plan, engine: GvtEngine::serial(), pool }
    }

    /// Shard every prediction over `threads` worker threads (`0` = all
    /// cores, `1` = serial).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.engine = GvtEngine::new(threads);
        self
    }

    /// Number of factors `D` in the chain.
    pub fn order(&self) -> usize {
        self.factors.len()
    }

    /// Number of test edges `t`.
    pub fn n_test(&self) -> usize {
        self.plan.out_len()
    }

    /// Number of training edges `n` (the required dual-coefficient length).
    pub fn n_train(&self) -> usize {
        self.plan.len()
    }

    fn factor_refs(&self) -> (Vec<&Matrix>, Vec<&Matrix>) {
        (self.factors.iter().collect(), self.factors_t.iter().collect())
    }

    /// Predict scores for all test edges from dual coefficients `a` (length
    /// n). Zero coefficients are skipped.
    pub fn predict(&self, a: &[f64]) -> Vec<f64> {
        let mut p = vec![0.0; self.n_test()];
        self.predict_into(a, &mut p);
        p
    }

    /// [`TensorPredictOp::predict`] into a preallocated output buffer.
    ///
    /// Panics unless `a.len()` equals the number of training edges and
    /// `out.len()` the number of test edges — a mismatched dual vector would
    /// otherwise index out of bounds inside stage 1 or silently truncate the
    /// scores.
    pub fn predict_into(&self, a: &[f64], out: &mut [f64]) {
        assert_eq!(
            a.len(),
            self.n_train(),
            "dual coefficient vector has length {} but the model was trained on {} edges",
            a.len(),
            self.n_train()
        );
        assert_eq!(
            out.len(),
            self.n_test(),
            "output buffer has length {} but {} test edges were requested",
            out.len(),
            self.n_test()
        );
        let (refs, trefs) = self.factor_refs();
        self.pool.with(|ws| {
            self.engine.apply_chain(&refs, &trefs, &self.plan, a, out, ws, None);
        });
    }

    /// Predict scores for `k_rhs` dual-coefficient vectors (stacked as
    /// column planes of length `n_train`) in **one batched sweep**. Returns
    /// `k_rhs` planes of `n_test` scores; plane `j` is bitwise identical to
    /// [`TensorPredictOp::predict`] on coefficient set `j`.
    pub fn predict_multi(&self, duals: &[f64], k_rhs: usize) -> Vec<f64> {
        let mut out = vec![0.0; self.n_test() * k_rhs];
        self.predict_multi_into(duals, k_rhs, &mut out);
        out
    }

    /// [`TensorPredictOp::predict_multi`] into a preallocated output buffer
    /// (`k_rhs` planes of `n_test` scores).
    pub fn predict_multi_into(&self, duals: &[f64], k_rhs: usize, out: &mut [f64]) {
        assert_eq!(
            duals.len(),
            self.n_train() * k_rhs,
            "expected {} coefficient planes of length {}, got {} values",
            k_rhs,
            self.n_train(),
            duals.len()
        );
        assert_eq!(
            out.len(),
            self.n_test() * k_rhs,
            "expected {} output planes of length {}, got {} slots",
            k_rhs,
            self.n_test(),
            out.len()
        );
        let (refs, trefs) = self.factor_refs();
        self.pool.with(|ws| {
            self.engine.apply_chain_multi(&refs, &trefs, &self.plan, duals, out, k_rhs, ws, None);
        });
    }
}

/// Zero-shot prediction operator `p = R̂(Ĝ⊗K̂)Rᵀ a` (§3.1).
///
/// `K̂ ∈ R^{u×m}` holds kernel evaluations between the `u` *test* start
/// vertices and the `m` training start vertices; `Ĝ ∈ R^{v×q}` likewise for
/// end vertices. `test_idx` maps each requested edge to its
/// (test-end, test-start) pair; `train_idx` maps training edges to
/// (train-end, train-start) — the same index used at training time.
///
/// Cost `O(min(v·n + m·t, u·n + q·t))`, and with a sparse dual vector the
/// `n` terms become `‖a‖₀` (eq. 5) because stage 1 skips zeros.
///
/// A thin `D = 2` wrapper over [`TensorPredictOp`]: the chain plan
/// delegates to the unmodified two-factor pipeline, so predictions are
/// **bitwise identical to the pre-chain operator** at every thread count.
/// Like [`KronKernelOp`], the operator is `Sync` and shards each prediction
/// across threads via [`KronPredictOp::with_threads`] — this is what lets
/// the serving coordinator score batches with one shared trained model.
pub struct KronPredictOp {
    inner: TensorPredictOp,
}

impl KronPredictOp {
    /// Build the prediction operator from test–train kernel blocks and the
    /// two edge indices. Runs single-threaded until
    /// [`KronPredictOp::with_threads`] is applied.
    pub fn new(ghat: Matrix, khat: Matrix, test_idx: KronIndex, train_idx: KronIndex) -> Self {
        train_idx.validate(ghat.cols(), khat.cols()).expect("train indices out of bounds");
        test_idx.validate(ghat.rows(), khat.rows()).expect("test indices out of bounds");
        // The operator owns its test index, so the plan can carry the
        // output-side stage-2 buckets for batched prediction too. (The
        // serving fast path shares one `build` plan across per-batch test
        // indices instead — see `with_shared`.)
        let plan = Arc::new(EdgePlan::build_full(
            &test_idx,
            &train_idx,
            ghat.rows(),
            ghat.cols(),
            khat.rows(),
            khat.cols(),
        ));
        KronPredictOp::with_shared(
            ghat,
            khat,
            test_idx,
            Arc::new(train_idx),
            plan,
            Arc::new(WorkspacePool::new()),
        )
    }

    /// Like [`KronPredictOp::new`], but reusing the trained-side state — the
    /// edge index, its prebuilt [`EdgePlan`], and a shared [`WorkspacePool`].
    /// This is the serving fast path: that state never changes between
    /// batches, so a long-lived prediction context builds it once and stamps
    /// out one cheap operator per incoming test batch (only the test-side
    /// transposes and validations remain per-batch; the train index is
    /// validated in debug builds only — it is trusted context state, unlike
    /// the per-request test index).
    ///
    /// Panics if `plan` was built for a different train index (length
    /// mismatch; [`GvtEngine::apply_planned`] asserts the same invariant).
    pub fn with_shared(
        ghat: Matrix,
        khat: Matrix,
        test_idx: KronIndex,
        train_idx: Arc<KronIndex>,
        plan: Arc<EdgePlan>,
        pool: Arc<WorkspacePool>,
    ) -> Self {
        test_idx.validate(ghat.rows(), khat.rows()).expect("test indices out of bounds");
        debug_assert!(
            train_idx.validate(ghat.cols(), khat.cols()).is_ok(),
            "train indices out of bounds"
        );
        assert_eq!(
            plan.len(),
            train_idx.len(),
            "edge plan was built for a different train index"
        );
        let chain = ChainPlan::from_shared_kron(
            Arc::new(test_idx),
            train_idx,
            plan,
            [ghat.rows(), khat.rows()],
            [ghat.cols(), khat.cols()],
        );
        let ghat_t = ghat.transpose();
        let khat_t = khat.transpose();
        KronPredictOp {
            inner: TensorPredictOp::from_parts(
                vec![ghat, khat],
                vec![ghat_t, khat_t],
                Arc::new(chain),
                pool,
            ),
        }
    }

    /// Shard every prediction over `threads` worker threads (`0` = all
    /// cores, `1` = serial).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.inner = self.inner.with_threads(threads);
        self
    }

    /// Number of test edges `t`.
    pub fn n_test(&self) -> usize {
        self.inner.n_test()
    }

    /// Number of training edges `n` (the required dual-coefficient length).
    pub fn n_train(&self) -> usize {
        self.inner.n_train()
    }

    /// Predict scores for all test edges from dual coefficients `a` (length
    /// n). Zero coefficients are skipped.
    pub fn predict(&self, a: &[f64]) -> Vec<f64> {
        self.inner.predict(a)
    }

    /// [`KronPredictOp::predict`] into a preallocated output buffer.
    ///
    /// Panics unless `a.len()` equals the number of training edges and
    /// `out.len()` the number of test edges — a mismatched dual vector would
    /// otherwise index out of bounds inside stage 1 or silently truncate the
    /// scores.
    pub fn predict_into(&self, a: &[f64], out: &mut [f64]) {
        self.inner.predict_into(a, out);
    }

    /// Predict scores for `k_rhs` dual-coefficient vectors (stacked as
    /// column planes of length `n_train`) in **one batched sweep**: the test
    /// edges are scored against all coefficient sets with a single stage-1
    /// edge traversal. Returns `k_rhs` planes of `n_test` scores; plane `j`
    /// is bitwise identical to [`KronPredictOp::predict`] on coefficient set
    /// `j`. This is the multi-model / multi-λ serving path (Viljanen et
    /// al.'s multi-output setting).
    pub fn predict_multi(&self, duals: &[f64], k_rhs: usize) -> Vec<f64> {
        self.inner.predict_multi(duals, k_rhs)
    }

    /// [`KronPredictOp::predict_multi`] into a preallocated output buffer
    /// (`k_rhs` planes of `n_test` scores).
    pub fn predict_multi_into(&self, duals: &[f64], k_rhs: usize, out: &mut [f64]) {
        self.inner.predict_multi_into(duals, k_rhs, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gvt::explicit::explicit_apply;
    use crate::linalg::solvers::{cg, minres, qmr, LinOp, SolverConfig};
    use crate::linalg::vecops::assert_allclose;
    use crate::util::rng::Pcg32;

    /// Random symmetric PSD kernel matrix.
    fn random_kernel(rng: &mut Pcg32, n: usize) -> Matrix {
        let g = Matrix::from_fn(n, n, |_, _| rng.normal());
        let mut k = g.matmul_nt(&g);
        for i in 0..n {
            k.add_at(i, i, 1.0);
        }
        let scale = 1.0 / (n as f64);
        k.data_mut().iter_mut().for_each(|v| *v *= scale);
        k
    }

    fn random_edges(rng: &mut Pcg32, q: usize, m: usize, n_edges: usize) -> KronIndex {
        KronIndex::new(
            (0..n_edges).map(|_| rng.below(q) as u32).collect(),
            (0..n_edges).map(|_| rng.below(m) as u32).collect(),
        )
    }

    fn assert_sync<T: Sync>() {}

    #[test]
    fn operators_are_sync() {
        assert_sync::<TensorKernelOp>();
        assert_sync::<TensorPredictOp>();
        assert_sync::<KronKernelOp>();
        assert_sync::<KronPredictOp>();
        assert_sync::<RidgeSystemOp<'static>>();
        assert_sync::<SvmNewtonOp<'static>>();
        assert_sync::<KronSpectralPrecond>();
    }

    #[test]
    fn spectral_precond_is_symmetric() {
        use crate::linalg::eig::eigh;
        use crate::linalg::solvers::Preconditioner;
        let mut rng = Pcg32::seeded(96);
        let (q, m) = (5, 4);
        let g = random_kernel(&mut rng, q);
        let k = random_kernel(&mut rng, m);
        let idx = random_edges(&mut rng, q, m, 14);
        let n = idx.len();
        let pc = KronSpectralPrecond::new(&eigh(&g), &eigh(&k), idx, 0.3);
        let r1 = rng.normal_vec(n);
        let r2 = rng.normal_vec(n);
        let mut m1 = vec![0.0; n];
        let mut m2 = vec![0.0; n];
        pc.apply(&r1, &mut m1);
        pc.apply(&r2, &mut m2);
        let lhs = crate::linalg::vecops::dot(&m1, &r2);
        let rhs = crate::linalg::vecops::dot(&r1, &m2);
        assert!((lhs - rhs).abs() <= 1e-10 * lhs.abs().max(rhs.abs()).max(1.0));
    }

    /// On a complete graph `R` is a permutation, so the preconditioner is the
    /// exact inverse of `Q + λI` and PCG lands in ~one iteration.
    #[test]
    fn spectral_precond_is_exact_inverse_on_complete_graph() {
        use crate::linalg::eig::eigh;
        use crate::linalg::solvers::pcg;
        use crate::util::proptest::complete_edge_index;
        let mut rng = Pcg32::seeded(97);
        let (q, m) = (6, 5);
        let n = q * m;
        let g = Arc::new(random_kernel(&mut rng, q));
        let k = Arc::new(random_kernel(&mut rng, m));
        let idx = complete_edge_index(&mut rng, q, m);
        let lambda = 0.4;
        let pc = KronSpectralPrecond::new(&eigh(&g), &eigh(&k), idx.clone(), lambda);
        let op = KronKernelOp::new(g, k, idx);
        let sys = RidgeSystemOp { op: &op, lambda };
        let b = rng.normal_vec(n);
        let cfg = SolverConfig { max_iters: 50, tol: 1e-8 };
        let mut x_pcg = vec![0.0; n];
        let stats = pcg(&sys, &b, &mut x_pcg, &pc, &cfg);
        assert!(stats.converged);
        assert!(stats.iterations <= 3, "exact-inverse PCG took {} iterations", stats.iterations);
        let mut x_cg = vec![0.0; n];
        let s_cg = cg(&sys, &b, &mut x_cg, &SolverConfig { max_iters: 500, tol: 1e-12 });
        assert!(s_cg.converged);
        assert_allclose(&x_pcg, &x_cg, 1e-6, 1e-6);
    }

    /// On an incomplete graph the surrogate still solves the system and
    /// accelerates CG (strict iteration superiority is pinned on an
    /// ill-conditioned case in `tests/eigen_paths.rs`).
    #[test]
    fn spectral_precond_solves_incomplete_graph() {
        use crate::linalg::eig::eigh;
        use crate::linalg::solvers::pcg;
        use crate::util::proptest::incomplete_edge_index;
        let mut rng = Pcg32::seeded(98);
        let (q, m) = (7, 6);
        let n = 30; // < q·m = 42
        let g = Arc::new(random_kernel(&mut rng, q));
        let k = Arc::new(random_kernel(&mut rng, m));
        let idx = incomplete_edge_index(&mut rng, q, m, n);
        let lambda = 0.05;
        let pc = KronSpectralPrecond::new(&eigh(&g), &eigh(&k), idx.clone(), lambda);
        let op = KronKernelOp::new(g, k, idx);
        let sys = RidgeSystemOp { op: &op, lambda };
        let b = rng.normal_vec(n);
        let cfg = SolverConfig { max_iters: 300, tol: 1e-10 };
        let mut x_pcg = vec![0.0; n];
        let stats = pcg(&sys, &b, &mut x_pcg, &pc, &cfg);
        assert!(stats.converged, "residual={}", stats.residual_norm);
        let mut x_cg = vec![0.0; n];
        let s_cg = cg(&sys, &b, &mut x_cg, &cfg);
        assert!(s_cg.converged);
        assert_allclose(&x_pcg, &x_cg, 1e-7, 1e-7);
    }

    #[test]
    fn spectral_precond_threaded_matches_serial_bitwise() {
        use crate::linalg::eig::eigh;
        use crate::linalg::solvers::Preconditioner;
        let mut rng = Pcg32::seeded(99);
        let (q, m) = (8, 7);
        let g = random_kernel(&mut rng, q);
        let k = random_kernel(&mut rng, m);
        let idx = random_edges(&mut rng, q, m, 40);
        let n = idx.len();
        let g_eig = eigh(&g);
        let k_eig = eigh(&k);
        let r = rng.normal_vec(n);
        let serial = KronSpectralPrecond::new(&g_eig, &k_eig, idx.clone(), 0.2);
        let mut want = vec![0.0; n];
        serial.apply(&r, &mut want);
        for threads in [2, 4] {
            let pc =
                KronSpectralPrecond::new(&g_eig, &k_eig, idx.clone(), 0.2).with_threads(threads);
            let mut got = vec![0.0; n];
            pc.apply(&r, &mut got);
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn kernel_op_matches_explicit() {
        let mut rng = Pcg32::seeded(80);
        let (q, m, n) = (6, 5, 18);
        let g = Arc::new(random_kernel(&mut rng, q));
        let k = Arc::new(random_kernel(&mut rng, m));
        let idx = random_edges(&mut rng, q, m, n);
        let op = KronKernelOp::new(g.clone(), k.clone(), idx.clone());
        let v = rng.normal_vec(n);
        let fast = op.apply_vec(&v);
        let slow = explicit_apply(&g, &k, &idx, &idx, &v);
        assert_allclose(&fast, &slow, 1e-10, 1e-10);
    }

    #[test]
    fn threaded_kernel_op_matches_serial() {
        let mut rng = Pcg32::seeded(87);
        let (q, m, n) = (12, 11, 3000);
        let g = Arc::new(random_kernel(&mut rng, q));
        let k = Arc::new(random_kernel(&mut rng, m));
        let idx = random_edges(&mut rng, q, m, n);
        let v = rng.normal_vec(n);
        let serial = KronKernelOp::new(g.clone(), k.clone(), idx.clone());
        let expect = serial.apply_vec(&v);
        for threads in [2, 4] {
            let op = KronKernelOp::new(g.clone(), k.clone(), idx.clone()).with_threads(threads);
            assert_eq!(op.threads(), threads);
            assert_eq!(op.apply_vec(&v), expect, "threads={threads}");
        }
    }

    #[test]
    fn shared_operator_serves_concurrent_callers() {
        let mut rng = Pcg32::seeded(88);
        let (q, m, n) = (10, 10, 2500);
        let g = Arc::new(random_kernel(&mut rng, q));
        let k = Arc::new(random_kernel(&mut rng, m));
        let idx = random_edges(&mut rng, q, m, n);
        let op = Arc::new(KronKernelOp::new(g, k, idx).with_threads(2));
        let vs: Vec<Vec<f64>> = (0..4).map(|_| rng.normal_vec(n)).collect();
        let expect: Vec<Vec<f64>> = vs.iter().map(|v| op.apply_vec(v)).collect();
        let results: Vec<Vec<f64>> = std::thread::scope(|scope| {
            let handles: Vec<_> = vs
                .iter()
                .map(|v| {
                    let op = Arc::clone(&op);
                    scope.spawn(move || op.apply_vec(v))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (got, want) in results.iter().zip(&expect) {
            assert_eq!(got, want);
        }
    }

    #[test]
    fn apply_multi_columns_match_single_applies() {
        let mut rng = Pcg32::seeded(95);
        let (q, m, n) = (9, 8, 2800);
        let g = Arc::new(random_kernel(&mut rng, q));
        let k = Arc::new(random_kernel(&mut rng, m));
        let idx = random_edges(&mut rng, q, m, n);
        let k_rhs = 4;
        let v = rng.normal_vec(n * k_rhs);
        for threads in [1, 2, 4] {
            let op = KronKernelOp::new(g.clone(), k.clone(), idx.clone()).with_threads(threads);
            let mut singles = vec![0.0; n * k_rhs];
            for j in 0..k_rhs {
                op.apply_into(&v[j * n..(j + 1) * n], &mut singles[j * n..(j + 1) * n]);
            }
            let mut multi = vec![0.0; n * k_rhs];
            op.apply_multi_into(&v, k_rhs, &mut multi);
            assert_eq!(multi, singles, "threads={threads}");
        }
    }

    #[test]
    fn predict_multi_columns_match_single_predicts() {
        let mut rng = Pcg32::seeded(96);
        let (q, m, n) = (5, 6, 18);
        let (v_test, u_test, t_test) = (4, 5, 11);
        let train_idx = random_edges(&mut rng, q, m, n);
        let test_idx = random_edges(&mut rng, v_test, u_test, t_test);
        let ghat = Matrix::from_fn(v_test, q, |_, _| rng.normal());
        let khat = Matrix::from_fn(u_test, m, |_, _| rng.normal());
        let op = KronPredictOp::new(ghat, khat, test_idx, train_idx);
        let k_rhs = 3;
        let duals = rng.normal_vec(n * k_rhs);
        let multi = op.predict_multi(&duals, k_rhs);
        for j in 0..k_rhs {
            let single = op.predict(&duals[j * n..(j + 1) * n]);
            assert_eq!(&multi[j * t_test..(j + 1) * t_test], single.as_slice(), "plane {j}");
        }
    }

    #[test]
    fn ridge_multi_op_matches_per_column_apply() {
        let mut rng = Pcg32::seeded(97);
        let (q, m, n) = (6, 6, 24);
        let g = Arc::new(random_kernel(&mut rng, q));
        let k = Arc::new(random_kernel(&mut rng, m));
        let idx = random_edges(&mut rng, q, m, n);
        let op = KronKernelOp::new(g, k, idx);
        let sys = RidgeSystemOp { op: &op, lambda: 0.7 };
        let k_rhs = 3;
        let v = rng.normal_vec(n * k_rhs);
        let mut multi = vec![0.0; n * k_rhs];
        MultiLinOp::apply_multi(&sys, &v, k_rhs, &mut multi);
        for j in 0..k_rhs {
            let mut single = vec![0.0; n];
            sys.apply(&v[j * n..(j + 1) * n], &mut single);
            assert_eq!(&multi[j * n..(j + 1) * n], single.as_slice(), "plane {j}");
        }
    }

    #[test]
    fn kernel_op_diagonal() {
        let mut rng = Pcg32::seeded(81);
        let (q, m, n) = (4, 4, 10);
        let g = Arc::new(random_kernel(&mut rng, q));
        let k = Arc::new(random_kernel(&mut rng, m));
        let idx = random_edges(&mut rng, q, m, n);
        let op = KronKernelOp::new(g.clone(), k.clone(), idx.clone());
        let diag = op.diagonal();
        let full = crate::gvt::explicit::explicit_submatrix(&g, &k, &idx, &idx);
        for h in 0..n {
            assert!((diag[h] - full.get(h, h)).abs() < 1e-12);
        }
    }

    #[test]
    fn ridge_system_solvable_by_cg_and_minres() {
        let mut rng = Pcg32::seeded(82);
        let (q, m, n) = (8, 7, 30);
        let g = Arc::new(random_kernel(&mut rng, q));
        let k = Arc::new(random_kernel(&mut rng, m));
        let idx = random_edges(&mut rng, q, m, n);
        let op = KronKernelOp::new(g, k, idx);
        let sys = RidgeSystemOp { op: &op, lambda: 1.0 };
        let y = rng.normal_vec(n);
        let cfg = SolverConfig { max_iters: 500, tol: 1e-12 };
        let mut a1 = vec![0.0; n];
        let mut a2 = vec![0.0; n];
        assert!(cg(&sys, &y, &mut a1, &cfg).converged);
        assert!(minres(&sys, &y, &mut a2, &cfg).converged);
        assert_allclose(&a1, &a2, 1e-6, 1e-6);
        // residual check: (Q+λI)a = y
        let mut resid = sys.apply_vec(&a1);
        for i in 0..n {
            resid[i] -= y[i];
        }
        assert!(crate::linalg::vecops::norm2(&resid) < 1e-8);
    }

    #[test]
    fn svm_newton_op_transpose_is_consistent() {
        // <Ax, y> == <x, Aᵀy> for random vectors.
        let mut rng = Pcg32::seeded(83);
        let (q, m, n) = (5, 6, 20);
        let g = Arc::new(random_kernel(&mut rng, q));
        let k = Arc::new(random_kernel(&mut rng, m));
        let idx = random_edges(&mut rng, q, m, n);
        let op = KronKernelOp::new(g, k, idx);
        let mask: Vec<f64> = (0..n).map(|i| if i % 4 == 0 { 0.0 } else { 1.0 }).collect();
        let newton = SvmNewtonOp::new(&op, mask, 0.3);
        let x = rng.normal_vec(n);
        let y = rng.normal_vec(n);
        let ax = newton.apply_vec(&x);
        let mut aty = vec![0.0; n];
        newton.apply_transpose(&y, &mut aty);
        let lhs = crate::linalg::vecops::dot(&ax, &y);
        let rhs = crate::linalg::vecops::dot(&x, &aty);
        assert!((lhs - rhs).abs() < 1e-8, "{lhs} vs {rhs}");
    }

    #[test]
    fn svm_newton_solvable_by_qmr() {
        let mut rng = Pcg32::seeded(84);
        let (q, m, n) = (6, 6, 24);
        let g = Arc::new(random_kernel(&mut rng, q));
        let k = Arc::new(random_kernel(&mut rng, m));
        let idx = random_edges(&mut rng, q, m, n);
        let op = KronKernelOp::new(g, k, idx);
        let mask: Vec<f64> = (0..n).map(|i| if i % 3 == 0 { 0.0 } else { 1.0 }).collect();
        let newton = SvmNewtonOp::new(&op, mask, 0.7);
        let x_true = rng.normal_vec(n);
        let b = newton.apply_vec(&x_true);
        let mut x = vec![0.0; n];
        let stats = qmr(&newton, &b, &mut x, &SolverConfig { max_iters: 800, tol: 1e-12 });
        assert!(stats.converged, "residual={}", stats.residual_norm);
        assert_allclose(&x, &x_true, 1e-5, 1e-5);
    }

    #[test]
    fn predict_op_matches_explicit() {
        let mut rng = Pcg32::seeded(85);
        // train: q=4, m=5, n=12; test: v=3, u=6, t=8
        let (q, m, n) = (4, 5, 12);
        let (v_test, u_test, t_test) = (3, 6, 8);
        let train_idx = random_edges(&mut rng, q, m, n);
        let test_idx = random_edges(&mut rng, v_test, u_test, t_test);
        let ghat = Matrix::from_fn(v_test, q, |_, _| rng.normal());
        let khat = Matrix::from_fn(u_test, m, |_, _| rng.normal());
        let a = rng.normal_vec(n);
        let op =
            KronPredictOp::new(ghat.clone(), khat.clone(), test_idx.clone(), train_idx.clone());
        let fast = op.predict(&a);
        let slow = explicit_apply(&ghat, &khat, &test_idx, &train_idx, &a);
        assert_allclose(&fast, &slow, 1e-10, 1e-10);
    }

    #[test]
    #[should_panic(expected = "dual coefficient vector has length")]
    fn predict_rejects_wrong_dual_length() {
        let mut rng = Pcg32::seeded(89);
        let train_idx = random_edges(&mut rng, 4, 5, 12);
        let test_idx = random_edges(&mut rng, 3, 6, 8);
        let ghat = Matrix::from_fn(3, 4, |_, _| rng.normal());
        let khat = Matrix::from_fn(6, 5, |_, _| rng.normal());
        let op = KronPredictOp::new(ghat, khat, test_idx, train_idx);
        // 11 coefficients for 12 training edges: must panic, not truncate
        let _ = op.predict(&rng.normal_vec(11));
    }

    #[test]
    #[should_panic(expected = "output buffer has length")]
    fn predict_into_rejects_wrong_output_length() {
        let mut rng = Pcg32::seeded(90);
        let train_idx = random_edges(&mut rng, 4, 5, 12);
        let test_idx = random_edges(&mut rng, 3, 6, 8);
        let ghat = Matrix::from_fn(3, 4, |_, _| rng.normal());
        let khat = Matrix::from_fn(6, 5, |_, _| rng.normal());
        let op = KronPredictOp::new(ghat, khat, test_idx, train_idx);
        let a = rng.normal_vec(12);
        let mut out = vec![0.0; 7];
        op.predict_into(&a, &mut out);
    }

    #[test]
    fn shared_plan_operator_matches_fresh_operator() {
        let mut rng = Pcg32::seeded(91);
        let (q, m, n) = (5, 6, 20);
        let train_idx = random_edges(&mut rng, q, m, n);
        let shared_idx = Arc::new(train_idx.clone());
        let plan = Arc::new(EdgePlan::build(&train_idx, q, m));
        let pool = Arc::new(WorkspacePool::new());
        let a = rng.normal_vec(n);
        // two different "batches" sharing one index + plan + pool
        for seed in [0u64, 1] {
            let mut brng = Pcg32::seeded(92 + seed);
            let test_idx = random_edges(&mut brng, 3, 4, 7);
            let ghat = Matrix::from_fn(3, q, |_, _| brng.normal());
            let khat = Matrix::from_fn(4, m, |_, _| brng.normal());
            let fresh =
                KronPredictOp::new(ghat.clone(), khat.clone(), test_idx.clone(), train_idx.clone())
                    .predict(&a);
            let shared = KronPredictOp::with_shared(
                ghat,
                khat,
                test_idx,
                shared_idx.clone(),
                plan.clone(),
                pool.clone(),
            )
            .predict(&a);
            assert_eq!(fresh, shared, "batch {seed}");
        }
    }

    #[test]
    fn predict_sparse_equals_dense_coefficients() {
        let mut rng = Pcg32::seeded(86);
        let (q, m, n) = (4, 4, 15);
        let train_idx = random_edges(&mut rng, q, m, n);
        let test_idx = random_edges(&mut rng, 3, 3, 5);
        let ghat = Matrix::from_fn(3, q, |_, _| rng.normal());
        let khat = Matrix::from_fn(3, m, |_, _| rng.normal());
        let mut a = rng.normal_vec(n);
        for (i, ai) in a.iter_mut().enumerate() {
            if i % 2 == 0 {
                *ai = 0.0;
            }
        }
        let op =
            KronPredictOp::new(ghat.clone(), khat.clone(), test_idx.clone(), train_idx.clone());
        let fast = op.predict(&a);
        let slow = explicit_apply(&ghat, &khat, &test_idx, &train_idx, &a);
        assert_allclose(&fast, &slow, 1e-10, 1e-10);
    }

    /// Elementwise oracle: `u_h = Σ_l Π_d K_d[rows_d[h], cols_d[l]] · v_l`.
    fn chain_oracle(
        factors: &[&Matrix],
        rows: &TensorIndex,
        cols: &TensorIndex,
        v: &[f64],
    ) -> Vec<f64> {
        (0..rows.len())
            .map(|h| {
                (0..cols.len())
                    .map(|l| {
                        let w: f64 = factors
                            .iter()
                            .enumerate()
                            .map(|(d, k)| {
                                k.get(rows.modes[d][h] as usize, cols.modes[d][l] as usize)
                            })
                            .product();
                        w * v[l]
                    })
                    .sum()
            })
            .collect()
    }

    fn random_tensor_edges(rng: &mut Pcg32, dims: &[usize], n_edges: usize) -> TensorIndex {
        TensorIndex::new(
            dims.iter()
                .map(|&d| (0..n_edges).map(|_| rng.below(d) as u32).collect())
                .collect(),
        )
    }

    #[test]
    fn tensor_kernel_op_matches_oracle_and_diagonal() {
        let mut rng = Pcg32::seeded(93);
        let dims = [4usize, 3, 5];
        let n = 22;
        let factors: Vec<Arc<Matrix>> =
            dims.iter().map(|&d| Arc::new(random_kernel(&mut rng, d))).collect();
        let idx = random_tensor_edges(&mut rng, &dims, n);
        let v = rng.normal_vec(n);
        let refs: Vec<&Matrix> = factors.iter().map(|f| f.as_ref()).collect();
        let want = chain_oracle(&refs, &idx, &idx, &v);
        for threads in [1, 2, 4] {
            let op =
                TensorKernelOp::new(factors.clone(), idx.clone()).with_threads(threads);
            assert_eq!(op.order(), 3);
            assert_eq!(op.n_edges(), n);
            assert_allclose(&op.apply_vec(&v), &want, 1e-10, 1e-10);
        }
        let op = TensorKernelOp::new(factors.clone(), idx.clone());
        for (h, &d) in op.diagonal().iter().enumerate() {
            let explicit: f64 = factors
                .iter()
                .zip(&idx.modes)
                .map(|(k, col)| k.get(col[h] as usize, col[h] as usize))
                .product();
            assert!((d - explicit).abs() < 1e-12);
        }
    }

    #[test]
    fn tensor_predict_op_matches_oracle() {
        let mut rng = Pcg32::seeded(94);
        let train_dims = [4usize, 3, 4];
        let test_dims = [3usize, 2, 5];
        let (n, t) = (17, 9);
        let train_idx = random_tensor_edges(&mut rng, &train_dims, n);
        let test_idx = random_tensor_edges(&mut rng, &test_dims, t);
        let factors: Vec<Matrix> = test_dims
            .iter()
            .zip(&train_dims)
            .map(|(&u, &m)| Matrix::from_fn(u, m, |_, _| rng.normal()))
            .collect();
        let a = rng.normal_vec(n);
        let refs: Vec<&Matrix> = factors.iter().collect();
        let want = chain_oracle(&refs, &test_idx, &train_idx, &a);
        let op = TensorPredictOp::new(factors, test_idx, train_idx);
        assert_eq!(op.order(), 3);
        assert_eq!((op.n_test(), op.n_train()), (t, n));
        assert_allclose(&op.predict(&a), &want, 1e-10, 1e-10);
        // batched planes are bitwise equal to single predictions
        let k_rhs = 3;
        let duals = rng.normal_vec(n * k_rhs);
        let multi = op.predict_multi(&duals, k_rhs);
        for j in 0..k_rhs {
            let single = op.predict(&duals[j * n..(j + 1) * n]);
            assert_eq!(&multi[j * t..(j + 1) * t], single.as_slice(), "plane {j}");
        }
    }
}
