//! Dense scatter→GEMM→gather formulation of the generalized vec trick.
//!
//! From the proof of Theorem 1: with `V ∈ R^{d×b}` such that
//! `vec(V) = Cᵀ v` (i.e. `V[t_l, r_l] += v_l`),
//!
//! ```text
//! R (M ⊗ N) Cᵀ v = R vec(N V Mᵀ)      so      u_h = (N V Mᵀ)[q_h, p_h].
//! ```
//!
//! Instead of exploiting sparsity of `V` edge-by-edge (as [`super::algorithm`]
//! does), this path runs the two products as *dense* GEMMs — `O(cdb + cba)`
//! flops regardless of how many edges exist. On CPU this only wins near the
//! complete-graph limit; on TPU it is the right mapping because the GEMMs run
//! on the MXU (DESIGN.md §Hardware-Adaptation) — this module is the native
//! mirror of the L1/L2 artifact path, used by the router and for validation.

use super::KronIndex;
use crate::linalg::Matrix;

/// Scatter edge values into a dense `rows×cols` matrix:
/// `out[ri[l], ci[l]] += v[l]`.
pub fn scatter_edges(v: &[f64], ri: &[u32], ci: &[u32], rows: usize, cols: usize) -> Matrix {
    assert_eq!(v.len(), ri.len());
    assert_eq!(v.len(), ci.len());
    let mut out = Matrix::zeros(rows, cols);
    for l in 0..v.len() {
        out.add_at(ri[l] as usize, ci[l] as usize, v[l]);
    }
    out
}

/// Gather entries of a dense matrix at edge positions: `u[h] = p[ri[h], ci[h]]`.
pub fn gather_edges(p: &Matrix, ri: &[u32], ci: &[u32]) -> Vec<f64> {
    assert_eq!(ri.len(), ci.len());
    ri.iter().zip(ci).map(|(&r, &c)| p.get(r as usize, c as usize)).collect()
}

/// `u = R (M ⊗ N) Cᵀ v` via the dense path. Semantics identical to
/// [`super::algorithm::gvt_apply`].
pub fn dense_apply(
    m: &Matrix,
    n: &Matrix,
    rows: &KronIndex,
    cols: &KronIndex,
    v: &[f64],
) -> Vec<f64> {
    let (_a, b) = (m.rows(), m.cols());
    let (_c, d) = (n.rows(), n.cols());
    // V ∈ R^{d×b}: V[t_l, r_l] += v_l
    let v_mat = scatter_edges(v, &cols.right, &cols.left, d, b);
    // P = N V Mᵀ ∈ R^{c×a}
    let p = n.matmul(&v_mat).matmul_nt(m);
    // u_h = P[q_h, p_h]
    gather_edges(&p, &rows.right, &rows.left)
}

/// The complete-graph special case (`R = C = I`, Remark 1): the standard vec
/// trick `(M ⊗ N) vec_rowpair(Q)` as two GEMMs. Input and output vectors use
/// the row-major pair enumeration `(left·dim_right + right)` consistent with
/// [`KronIndex::flat`].
pub fn vec_trick_full(m: &Matrix, n: &Matrix, v: &[f64]) -> Vec<f64> {
    let (a, b) = (m.rows(), m.cols());
    let (c, d) = (n.rows(), n.cols());
    assert_eq!(v.len(), b * d, "v must have length b·d");
    // v enumerated as (r·d + t) → V[t, r]: V = reshape(v, b×d)ᵀ
    let v_mat = Matrix::from_fn(d, b, |t, r| v[r * d + t]);
    let p = n.matmul(&v_mat).matmul_nt(m); // c×a
    // output enumerated as (p·c + q) → P[q, p]
    let mut u = vec![0.0; a * c];
    for pi in 0..a {
        for qi in 0..c {
            u[pi * c + qi] = p.get(qi, pi);
        }
    }
    u
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gvt::algorithm::gvt_apply;
    use crate::gvt::explicit::explicit_apply;
    use crate::linalg::vecops::assert_allclose;
    use crate::util::proptest;
    use crate::util::rng::Pcg32;

    #[test]
    fn dense_matches_gvt_and_explicit() {
        let mut rng = Pcg32::seeded(70);
        let m = Matrix::from_fn(4, 5, |_, _| rng.normal());
        let n = Matrix::from_fn(3, 6, |_, _| rng.normal());
        let rows = KronIndex::from_usize(&[0, 3, 2, 1], &[2, 0, 1, 2]);
        let cols = KronIndex::from_usize(&[4, 1, 0, 2, 3], &[5, 0, 3, 1, 4]);
        let v = rng.normal_vec(5);
        let dense = dense_apply(&m, &n, &rows, &cols, &v);
        let fast = gvt_apply(&m, &n, &rows, &cols, &v);
        let slow = explicit_apply(&m, &n, &rows, &cols, &v);
        assert_allclose(&dense, &fast, 1e-10, 1e-10);
        assert_allclose(&dense, &slow, 1e-10, 1e-10);
    }

    #[test]
    fn dense_handles_duplicate_edges() {
        // Scatter must *accumulate* on repeated (r,t) pairs.
        let mut rng = Pcg32::seeded(71);
        let m = Matrix::from_fn(3, 3, |_, _| rng.normal());
        let n = Matrix::from_fn(3, 3, |_, _| rng.normal());
        let rows = KronIndex::from_usize(&[0, 1], &[1, 2]);
        let cols = KronIndex::from_usize(&[1, 1, 2], &[0, 0, 2]); // duplicate (1,0)
        let v = vec![1.0, 2.0, 3.0];
        let dense = dense_apply(&m, &n, &rows, &cols, &v);
        let slow = explicit_apply(&m, &n, &rows, &cols, &v);
        assert_allclose(&dense, &slow, 1e-12, 1e-12);
    }

    #[test]
    fn vec_trick_matches_full_kron() {
        proptest::check_n(0xD1CE, 12, |rng| {
            let a = 1 + rng.below(5);
            let b = 1 + rng.below(5);
            let c = 1 + rng.below(5);
            let d = 1 + rng.below(5);
            let m = Matrix::from_fn(a, b, |_, _| rng.normal());
            let n = Matrix::from_fn(c, d, |_, _| rng.normal());
            let v = rng.normal_vec(b * d);
            let fast = vec_trick_full(&m, &n, &v);
            let full = m.kron(&n).matvec(&v);
            assert_allclose(&fast, &full, 1e-9, 1e-9);
        });
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let v = vec![1.0, 2.0, 3.0];
        let ri = vec![0u32, 2, 1];
        let ci = vec![1u32, 0, 1];
        let m = scatter_edges(&v, &ri, &ci, 3, 2);
        let back = gather_edges(&m, &ri, &ci);
        assert_eq!(back, v);
    }
}
