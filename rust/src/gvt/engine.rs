//! Multi-threaded execution engine for Algorithm 1 — the [`GvtEngine`].
//!
//! The serial kernels in [`super::algorithm`] already restructure both
//! branches of the generalized vec trick so every inner loop is a contiguous
//! AXPY or dot. This module scales those same loops across cores with
//! std-only scoped threads (mirroring the style of
//! [`crate::coordinator::jobs`]):
//!
//! * **Stage 1** is a scatter-accumulate: edge `l` adds `v_l ·` (a row of
//!   `Mᵀ` or `Nᵀ`) into row `t_l` of `T` (branch T) or row `r_l` of `Sᵀ`
//!   (branch S). Rows are the unit of conflict, so a precomputed
//!   [`EdgePlan`] buckets edges by destination row and each worker owns a
//!   *contiguous, disjoint* range of rows — no locks, no atomics, no
//!   write contention.
//! * The **blocked transpose** between the stages parallelizes by column
//!   blocks: each worker writes a contiguous slab of the destination.
//! * **Stage 2** is embarrassingly parallel over the `f` output edges;
//!   workers take contiguous chunks of `u`.
//! * **Sampled batches** ([`BatchPlan`]) reuse the same stable bucketing for
//!   the stochastic trainer: row-restricted applies bitwise-pinned to the
//!   full apply, plus incremental scatter/gather against a persistent
//!   stage-1 accumulator.
//!
//! Within a destination row, bucketed edges keep their original order, so
//! every floating-point accumulation happens in exactly the same order as in
//! the serial code — the parallel result is **bitwise identical** to the
//! serial result for every thread count. This is what makes the solvers
//! (CG/MINRES/QMR are famously sensitive to rounding) deterministic under
//! the `threads` knob.

use std::sync::{Arc, Mutex};

use super::algorithm::{gvt_apply_into, gvt_apply_multi_into, GvtWorkspace};
use super::complexity::{self, Branch};
use super::tensor::{checked_product, TensorIndex};
use super::KronIndex;
use crate::linalg::gemm::gemm_nt_into;
use crate::linalg::vecops::{axpy, dot};
use crate::linalg::Matrix;

/// Below this many edges (`e + f`) the engine runs the serial kernels even
/// when more threads are available: spawning scoped workers costs a few
/// microseconds, which dominates tiny matvecs inside inner solver loops.
const MIN_PARALLEL_EDGES: usize = 2048;

/// Precomputed stage-1 bucketing of a column [`KronIndex`] for conflict-free
/// parallel accumulation.
///
/// For branch T, edge `l` accumulates into row `t_l = cols.right[l]` of the
/// `d×a` buffer `T`; for branch S into row `r_l = cols.left[l]` of the `b×c`
/// buffer `Sᵀ`. The plan stores, per branch, a counting-sort of edge ids by
/// destination row (CSR-style `offsets` + `order`), preserving edge order
/// within each bucket so parallel accumulation is bitwise identical to
/// serial. Build once per operator and reuse across matvecs.
#[derive(Debug, Clone)]
pub struct EdgePlan {
    e: usize,
    /// Edge ids grouped by `cols.right` (branch T destination rows, `d` buckets).
    t_order: Vec<u32>,
    /// Bucket boundaries into [`EdgePlan::t_order`], length `d + 1`.
    t_offsets: Vec<usize>,
    /// Edge ids grouped by `cols.left` (branch S destination rows, `b` buckets).
    s_order: Vec<u32>,
    /// Bucket boundaries into [`EdgePlan::s_order`], length `b + 1`.
    s_offsets: Vec<usize>,
    /// Number of output edges the output-side buckets were built for
    /// (`0` when the plan carries no output buckets).
    f_out: usize,
    /// Output edge ids grouped by `rows.left` (`p_h`; branch T stage-2
    /// gather vertices, `a` buckets). Empty unless built by
    /// [`EdgePlan::build_full`].
    t_out_order: Vec<u32>,
    /// Bucket boundaries into [`EdgePlan::t_out_order`], length `a + 1`.
    t_out_offsets: Vec<usize>,
    /// Output edge ids grouped by `rows.right` (`q_h`; branch S stage-2
    /// gather vertices, `c` buckets).
    s_out_order: Vec<u32>,
    /// Bucket boundaries into [`EdgePlan::s_out_order`], length `c + 1`.
    s_out_offsets: Vec<usize>,
}

impl EdgePlan {
    /// Bucket `cols` for both branches. `b` and `d` are the column counts of
    /// the factor matrices `M ∈ R^{a×b}` and `N ∈ R^{c×d}` (so
    /// `cols.left < b`, `cols.right < d`). The plan carries no output-side
    /// buckets — use [`EdgePlan::build_full`] when the row index is also
    /// fixed per operator (it is for training; it is not for the serving
    /// fast path, where one plan is shared across per-batch test indices).
    pub fn build(cols: &KronIndex, b: usize, d: usize) -> EdgePlan {
        let (t_order, t_offsets) = bucket_stable(&cols.right, d);
        let (s_order, s_offsets) = bucket_stable(&cols.left, b);
        EdgePlan {
            e: cols.len(),
            t_order,
            t_offsets,
            s_order,
            s_offsets,
            f_out: 0,
            t_out_order: Vec::new(),
            t_out_offsets: Vec::new(),
            s_out_order: Vec::new(),
            s_out_offsets: Vec::new(),
        }
    }

    /// [`EdgePlan::build`] plus **output-side bucketing**: output edges are
    /// additionally grouped by their stage-2 gather vertex (`p_h` for branch
    /// T, `q_h` for branch S), so the multi-RHS stage 2 loads each stage-1
    /// result row once per *vertex* instead of once per *edge*. `a` and `c`
    /// are the row counts of `M` and `N` (so `rows.left < a`,
    /// `rows.right < c`). The output buckets are tied to this `rows` index;
    /// [`GvtEngine::apply_planned_multi`] falls back to unbucketed gathers
    /// when the row index length differs.
    pub fn build_full(
        rows: &KronIndex,
        cols: &KronIndex,
        a: usize,
        b: usize,
        c: usize,
        d: usize,
    ) -> EdgePlan {
        let mut plan = EdgePlan::build(cols, b, d);
        let (t_out_order, t_out_offsets) = bucket_stable(&rows.left, a);
        let (s_out_order, s_out_offsets) = bucket_stable(&rows.right, c);
        plan.f_out = rows.len();
        plan.t_out_order = t_out_order;
        plan.t_out_offsets = t_out_offsets;
        plan.s_out_order = s_out_order;
        plan.s_out_offsets = s_out_offsets;
        plan
    }

    /// Number of edges the plan covers (`e`).
    pub fn len(&self) -> usize {
        self.e
    }

    /// Whether the plan covers zero edges.
    pub fn is_empty(&self) -> bool {
        self.e == 0
    }

    /// Whether the plan carries output-side stage-2 buckets
    /// ([`EdgePlan::build_full`]).
    pub fn has_output_buckets(&self) -> bool {
        !self.t_out_offsets.is_empty()
    }

    /// `(order, offsets)` for the requested branch's stage-1 buckets.
    fn buckets(&self, branch: Branch) -> (&[u32], &[usize]) {
        match branch {
            Branch::T => (&self.t_order, &self.t_offsets),
            Branch::S => (&self.s_order, &self.s_offsets),
        }
    }

    /// `(order, offsets)` for the requested branch's stage-2 output buckets,
    /// if present and built for a row index of length `f`.
    fn out_buckets(&self, branch: Branch, f: usize) -> Option<(&[u32], &[usize])> {
        if !self.has_output_buckets() || self.f_out != f {
            return None;
        }
        match branch {
            Branch::T => Some((&self.t_out_order, &self.t_out_offsets)),
            Branch::S => Some((&self.s_out_order, &self.s_out_offsets)),
        }
    }
}

/// Stage-1/stage-2 bucketing of a **sampled edge batch** against a fixed
/// full [`KronIndex`] — the stochastic-training analogue of [`EdgePlan`].
///
/// A batch is a list of *positions into a full index* (duplicates allowed,
/// order significant — samplers with replacement produce both). The plan
/// buckets those positions by their stage-1 destination row with the same
/// stable counting sort [`EdgePlan`] uses for full edge sets, so the batched
/// primitives on [`GvtEngine`] parallelize with conflict-free row ownership
/// and stay bitwise identical to their serial batch-order replay:
///
/// * [`GvtEngine::apply_restricted`] — the planned apply with stage 2 cut
///   down to the batch's output rows, **bitwise-pinned** to slicing the full
///   apply (build the plan against the `rows` index);
/// * [`GvtEngine::scatter_batch`] — add a batch coefficient update into a
///   persistent stage-1 accumulator, touching only the batch's edges (build
///   against the `cols` index);
/// * [`GvtEngine::gather_batch`] — read the batch's output values back out
///   of such an accumulator with strided dots (build against `rows`).
///
/// For the symmetric training operator `R(G⊗K)Rᵀ` the row and column
/// indices coincide, so one plan per batch serves all three.
#[derive(Debug, Clone)]
pub struct BatchPlan {
    /// Batch edge positions into the full index (may repeat).
    edges: Vec<u32>,
    /// Length of the full index the plan was built against.
    full: usize,
    /// Batch slots grouped by `index.right[edges[i]]` (branch T destination
    /// rows, `right_bound` buckets).
    t_order: Vec<u32>,
    /// Bucket boundaries into [`BatchPlan::t_order`], length
    /// `right_bound + 1`.
    t_offsets: Vec<usize>,
    /// Batch slots grouped by `index.left[edges[i]]` (branch S destination
    /// rows, `left_bound` buckets).
    s_order: Vec<u32>,
    /// Bucket boundaries into [`BatchPlan::s_order`], length
    /// `left_bound + 1`.
    s_offsets: Vec<usize>,
}

impl BatchPlan {
    /// Bucket the batch `positions` against `index` for both branches.
    /// `left_bound` / `right_bound` bound the index's left / right entries —
    /// pass `(b, d)` when `index` is a column index and `(a, c)` when it is
    /// a row index (matching [`EdgePlan::build`]'s convention). Panics on an
    /// out-of-range position.
    pub fn build(
        index: &KronIndex,
        positions: &[u32],
        left_bound: usize,
        right_bound: usize,
    ) -> BatchPlan {
        let full = index.len();
        let mut t_keys = Vec::with_capacity(positions.len());
        let mut s_keys = Vec::with_capacity(positions.len());
        for &pos in positions {
            let l = pos as usize;
            assert!(l < full, "batch position {l} out of range for a {full}-edge index");
            t_keys.push(index.right[l]);
            s_keys.push(index.left[l]);
        }
        let (t_order, t_offsets) = bucket_stable(&t_keys, right_bound);
        let (s_order, s_offsets) = bucket_stable(&s_keys, left_bound);
        BatchPlan {
            edges: positions.to_vec(),
            full,
            t_order,
            t_offsets,
            s_order,
            s_offsets,
        }
    }

    /// Number of batch slots (with-replacement batches count duplicates).
    pub fn len(&self) -> usize {
        self.edges.len()
    }

    /// Whether the batch is empty.
    pub fn is_empty(&self) -> bool {
        self.edges.is_empty()
    }

    /// The batch's edge positions into the full index, in sampling order.
    pub fn positions(&self) -> &[u32] {
        &self.edges
    }

    /// Length of the full index the plan was built against.
    pub fn full_len(&self) -> usize {
        self.full
    }

    /// `(order, offsets)` of the requested branch's stage-1 buckets: slots
    /// grouped by destination row; `order` entries index the batch, not the
    /// full edge set.
    fn buckets(&self, branch: Branch) -> (&[u32], &[usize]) {
        match branch {
            Branch::T => (&self.t_order, &self.t_offsets),
            Branch::S => (&self.s_order, &self.s_offsets),
        }
    }
}

/// Precomputed execution plan for a **D-way tensor-product chain apply**
/// `u = R (K₁ ⊗ K₂ ⊗ … ⊗ K_D) Cᵀ v` — the generalization of [`EdgePlan`]
/// from two factors to arbitrary chains, consumed by
/// [`GvtEngine::apply_chain`] / [`GvtEngine::apply_chain_multi`].
///
/// The pipeline threads an **edge-indexed gather**, `D−1` **mode-product
/// GEMM stages**, and an **edge-indexed scatter**, keeping the running
/// buffer in the row-major layout `(j_{d+1}, …, j_D, k₁, …, k_d)` after
/// contracting mode `d`:
///
/// 1. **Stage 1 (scatter):** `T[flat(j₂…j_D), :] += v_l · K₁ᵀ[j₁_l, :]` —
///    the same conflict-free row bucketing as the two-factor stage 1, with
///    the "rest" modes `2…D` flattened into the bucket key.
/// 2. **Modes `d = 2 … D−1`:** blocked transpose (moving mode `d` to the
///    minor axis) followed by one [`gemm_nt_into`] with `K_d` — a
///    mode-product GEMM per middle factor.
/// 3. **Mode `D` (fused gather):** after the last transpose the buffer `Z`
///    is `(a₁·…·a_{D−1}) × b_D`; each output edge takes one dot product
///    `u_h = ⟨K_D[p^D_h, :], Z[flat(p¹…p^{D−1})_h, :]⟩`.
///
/// Stage-1 bucketing preserves original edge order within each destination
/// row, every transpose is an exact move, and [`gemm_nt_into`] is bitwise
/// identical to a per-element dot for every thread count — so chain applies
/// are **bitwise identical across thread counts**, exactly like the
/// two-factor path.
///
/// **`D = 2` delegates** to the unmodified two-factor pipeline
/// ([`GvtEngine::apply_planned`], including automatic branch selection and
/// branch S), so two-factor chain applies are bitwise pinned to the
/// pre-chain behavior. For `D ≥ 3` the pipeline is the branch-T shape with
/// the middle modes contracted by GEMMs; no output-side vertex bucketing is
/// kept for the final gather (the gather is embarrassingly parallel and
/// deterministic without it).
///
/// All dimension products are overflow-checked at build time; bucket keys
/// and gather prefixes must fit in 32 bits (the same limit as
/// [`KronIndex::complete_layout`]).
#[derive(Debug, Clone)]
pub struct ChainPlan {
    /// Output edge count `f = |rows|`.
    f: usize,
    /// Input edge count `e = |cols|`.
    e: usize,
    /// Per-factor row counts `a_d` (`K_d ∈ R^{a_d × b_d}`).
    dims_a: Vec<usize>,
    /// Per-factor column counts `b_d`.
    dims_b: Vec<usize>,
    /// `D = 2` delegate state: the row/column [`KronIndex`] pair and the
    /// prebuilt two-factor [`EdgePlan`] the apply hands to
    /// [`GvtEngine::apply_planned`].
    kron_rows: Option<Arc<KronIndex>>,
    kron_cols: Option<Arc<KronIndex>>,
    kron_plan: Option<Arc<EdgePlan>>,
    /// `D ≥ 3`: number of stage-1 accumulator rows `b₂·…·b_D`.
    rest_dim: usize,
    /// `D ≥ 3`: per-input-edge stage-1 destination row (flat cols modes
    /// `2…D`), for the serial original-order replay.
    rest_keys: Vec<u32>,
    /// `D ≥ 3`: stable bucketing of input edges by [`ChainPlan::rest_keys`].
    rest_order: Vec<u32>,
    rest_offsets: Vec<usize>,
    /// `D ≥ 3`: per-input-edge mode-1 gather column `j¹_l`.
    col_first: Vec<u32>,
    /// `D ≥ 3`: per-output-edge fused-gather row (flat rows modes `1…D−1`).
    prefix_keys: Vec<u32>,
    /// `D ≥ 3`: per-output-edge mode-D factor row `p^D_h`.
    row_last: Vec<u32>,
    /// `D ≥ 3`: doubles per ping-pong workspace buffer (max stage length).
    max_stage: usize,
}

impl ChainPlan {
    /// Build a chain plan from row/column [`TensorIndex`]es and the
    /// per-factor dimensions (`dims_a[d]` rows × `dims_b[d]` columns of
    /// `K_d`). Validates mode counts, index bounds, and — with checked
    /// arithmetic — every dimension product the pipeline will form.
    pub fn build(
        rows: &TensorIndex,
        cols: &TensorIndex,
        dims_a: &[usize],
        dims_b: &[usize],
    ) -> Result<ChainPlan, String> {
        let order = dims_a.len();
        if order < 2 {
            return Err(format!("tensor chain needs at least 2 factors, got {order}"));
        }
        if dims_b.len() != order {
            return Err(format!(
                "factor dimension lists disagree: {} row counts vs {} column counts",
                order,
                dims_b.len()
            ));
        }
        if let Some(d) = dims_a.iter().chain(dims_b).position(|&x| x == 0) {
            return Err(format!("factor dimension {d} is zero; chain factors must be non-empty"));
        }
        if rows.order() != order || cols.order() != order {
            return Err(format!(
                "index order mismatch: rows has {} modes, cols {}, factors {}",
                rows.order(),
                cols.order(),
                order
            ));
        }
        rows.validate(dims_a).map_err(|e| format!("row index invalid: {e}"))?;
        cols.validate(dims_b).map_err(|e| format!("column index invalid: {e}"))?;
        let (f, e) = (rows.len(), cols.len());
        if order == 2 {
            let kr = Arc::new(rows.to_kron().expect("order 2"));
            let kc = Arc::new(cols.to_kron().expect("order 2"));
            let plan = Arc::new(EdgePlan::build_full(
                &kr, &kc, dims_a[0], dims_b[0], dims_a[1], dims_b[1],
            ));
            return Ok(ChainPlan {
                f,
                e,
                dims_a: dims_a.to_vec(),
                dims_b: dims_b.to_vec(),
                kron_rows: Some(kr),
                kron_cols: Some(kc),
                kron_plan: Some(plan),
                rest_dim: 0,
                rest_keys: Vec::new(),
                rest_order: Vec::new(),
                rest_offsets: Vec::new(),
                col_first: Vec::new(),
                prefix_keys: Vec::new(),
                row_last: Vec::new(),
                max_stage: 0,
            });
        }
        let rest_dim = checked_product(&dims_b[1..])
            .ok_or_else(|| format!("stage-1 grid {:?} overflows usize", &dims_b[1..]))?;
        let rest_keys = cols.flat_range_u32(dims_b, 1, order)?;
        let (rest_order, rest_offsets) = bucket_stable(&rest_keys, rest_dim);
        let prefix_keys = rows.flat_range_u32(dims_a, 0, order - 1)?;
        let max_stage = ChainPlan::max_stage_len(dims_a, dims_b)?;
        Ok(ChainPlan {
            f,
            e,
            dims_a: dims_a.to_vec(),
            dims_b: dims_b.to_vec(),
            kron_rows: None,
            kron_cols: None,
            kron_plan: None,
            rest_dim,
            rest_keys,
            rest_order,
            rest_offsets,
            col_first: cols.modes[0].clone(),
            prefix_keys,
            row_last: rows.modes[order - 1].clone(),
            max_stage,
        })
    }

    /// Like [`ChainPlan::build`] for `D = 2`, but wrapping already-shared
    /// trained-side state — the serving fast path analogue of
    /// [`EdgePlan::build`]-based operators: `plan` must have been built for
    /// `cols` (length-checked), and may omit output-side buckets.
    pub fn from_shared_kron(
        rows: Arc<KronIndex>,
        cols: Arc<KronIndex>,
        plan: Arc<EdgePlan>,
        dims_a: [usize; 2],
        dims_b: [usize; 2],
    ) -> ChainPlan {
        assert_eq!(plan.len(), cols.len(), "edge plan was built for a different column index");
        ChainPlan {
            f: rows.len(),
            e: cols.len(),
            dims_a: dims_a.to_vec(),
            dims_b: dims_b.to_vec(),
            kron_rows: Some(rows),
            kron_cols: Some(cols),
            kron_plan: Some(plan),
            rest_dim: 0,
            rest_keys: Vec::new(),
            rest_order: Vec::new(),
            rest_offsets: Vec::new(),
            col_first: Vec::new(),
            prefix_keys: Vec::new(),
            row_last: Vec::new(),
            max_stage: 0,
        }
    }

    /// Largest intermediate-buffer length across the pipeline's stages:
    /// after contracting modes `1…d` the buffer holds
    /// `(b_{d+1}·…·b_D) · (a₁·…·a_d)` doubles (the full output grid
    /// `a₁·…·a_D` is never materialized). Checked arithmetic throughout.
    fn max_stage_len(dims_a: &[usize], dims_b: &[usize]) -> Result<usize, String> {
        let order = dims_a.len();
        let mut max = 0usize;
        for d in 0..order - 1 {
            let b_suffix = checked_product(&dims_b[d + 1..])
                .ok_or_else(|| {
                    format!("chain suffix grid {:?} overflows usize", &dims_b[d + 1..])
                })?;
            let a_prefix = checked_product(&dims_a[..=d])
                .ok_or_else(|| format!("chain prefix grid {:?} overflows usize", &dims_a[..=d]))?;
            let len = b_suffix.checked_mul(a_prefix).ok_or_else(|| {
                format!(
                    "chain stage {d} buffer ({b_suffix} × {a_prefix} doubles) overflows usize"
                )
            })?;
            max = max.max(len);
        }
        Ok(max)
    }

    /// Number of factors `D` in the chain.
    pub fn order(&self) -> usize {
        self.dims_a.len()
    }

    /// Number of input edges `e` the plan covers.
    pub fn len(&self) -> usize {
        self.e
    }

    /// Whether the plan covers zero input edges.
    pub fn is_empty(&self) -> bool {
        self.e == 0
    }

    /// Number of output edges `f` the plan was built for.
    pub fn out_len(&self) -> usize {
        self.f
    }

    /// Per-factor row counts `a_d`.
    pub fn dims_a(&self) -> &[usize] {
        &self.dims_a
    }

    /// Per-factor column counts `b_d`.
    pub fn dims_b(&self) -> &[usize] {
        &self.dims_b
    }

    /// Whether this plan delegates to the two-factor pipeline (`D = 2`).
    pub fn is_kron_delegate(&self) -> bool {
        self.kron_plan.is_some()
    }
}

/// Stable counting sort of edge ids by `keys[l]` into `buckets` buckets.
/// Returns `(order, offsets)` with `offsets.len() == buckets + 1`.
fn bucket_stable(keys: &[u32], buckets: usize) -> (Vec<u32>, Vec<usize>) {
    let mut counts = vec![0usize; buckets + 1];
    for &k in keys {
        counts[k as usize + 1] += 1;
    }
    for i in 0..buckets {
        counts[i + 1] += counts[i];
    }
    let offsets = counts.clone();
    let mut cursor = counts;
    let mut order = vec![0u32; keys.len()];
    for (l, &k) in keys.iter().enumerate() {
        order[cursor[k as usize]] = l as u32;
        cursor[k as usize] += 1;
    }
    (order, offsets)
}

/// Partition bucket rows `0..rows` (where `offsets.len() == rows + 1`) into
/// at most `parts` contiguous, non-empty ranges with approximately equal
/// edge counts. The ranges cover every row exactly once.
fn edge_balanced_chunks(offsets: &[usize], parts: usize) -> Vec<(usize, usize)> {
    let rows = offsets.len() - 1;
    if rows == 0 {
        return Vec::new();
    }
    let total = offsets[rows];
    let parts = parts.clamp(1, rows);
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    for p in 1..=parts {
        let end = if p == parts {
            rows
        } else {
            // smallest row boundary reaching p/parts of the edges
            let target = total * p / parts;
            offsets.partition_point(|&o| o < target).clamp(start, rows)
        };
        if end > start {
            out.push((start, end));
            start = end;
        }
    }
    out
}

/// Split `0..len` into at most `parts` contiguous, non-empty, equal-ish
/// ranges (for stage-2 output chunking and the transpose).
fn even_chunks(len: usize, parts: usize) -> Vec<(usize, usize)> {
    if len == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, len);
    let base = len / parts;
    let rem = len % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let size = base + usize::from(p < rem);
        out.push((start, start + size));
        start += size;
    }
    out
}

/// Parallel blocked out-of-place transpose of a `rows×cols` row-major buffer
/// into a `cols×rows` destination; workers own contiguous column blocks of
/// the source (= row slabs of the destination).
fn transpose_into_parallel(src: &[f64], rows: usize, cols: usize, dst: &mut [f64], threads: usize) {
    debug_assert_eq!(src.len(), rows * cols);
    debug_assert!(dst.len() >= rows * cols);
    const B: usize = 32;
    let ranges = even_chunks(cols, threads);
    if ranges.len() <= 1 {
        super::algorithm::transpose_into(src, rows, cols, dst);
        return;
    }
    std::thread::scope(|scope| {
        let mut rest = &mut dst[..cols * rows];
        for &(j0, j1) in &ranges {
            let (slab, tail) = rest.split_at_mut((j1 - j0) * rows);
            rest = tail;
            scope.spawn(move || {
                for ib in (0..rows).step_by(B) {
                    for jb in (j0..j1).step_by(B) {
                        for i in ib..(ib + B).min(rows) {
                            for j in jb..(jb + B).min(j1) {
                                slab[(j - j0) * rows + i] = src[i * cols + j];
                            }
                        }
                    }
                }
            });
        }
    });
}

/// Multi-threaded executor for the generalized vec trick.
///
/// The engine is a lightweight value (it holds only the worker count);
/// workers are std scoped threads spawned per apply, in the style of
/// [`crate::coordinator::jobs::run_cv_jobs`]. What *is* reused across
/// matvecs are the [`EdgePlan`] (built once per index) and the
/// [`GvtWorkspace`] scratch buffers — the per-apply setup is thread spawn
/// only, a few µs, negligible against the `O(ae + df)` stage work it
/// parallelizes.
#[derive(Debug, Clone, Copy)]
pub struct GvtEngine {
    threads: usize,
}

impl Default for GvtEngine {
    fn default() -> Self {
        GvtEngine::serial()
    }
}

impl GvtEngine {
    /// Engine with an explicit worker count. `0` selects the machine's
    /// available parallelism; `1` always runs the serial kernels.
    pub fn new(threads: usize) -> GvtEngine {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        GvtEngine { threads }
    }

    /// Single-threaded engine (identical to calling the serial kernels).
    pub fn serial() -> GvtEngine {
        GvtEngine { threads: 1 }
    }

    /// Number of worker threads this engine uses.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Computes `u = R(M⊗N)Cᵀv` like
    /// [`gvt_apply_into`](super::algorithm::gvt_apply_into), sharding the
    /// work over the engine's threads using `plan` (which must have been
    /// built from this `cols` index). Falls back to the serial kernels when
    /// one thread is configured or the problem is too small to shard.
    ///
    /// The result is bitwise identical to the serial result for every thread
    /// count (see the module docs).
    #[allow(clippy::too_many_arguments)]
    pub fn apply_planned(
        &self,
        m: &Matrix,
        n: &Matrix,
        m_t: &Matrix,
        n_t: &Matrix,
        rows: &KronIndex,
        cols: &KronIndex,
        plan: &EdgePlan,
        v: &[f64],
        u: &mut [f64],
        ws: &mut GvtWorkspace,
        branch: Option<Branch>,
    ) {
        let (a, b) = (m.rows(), m.cols());
        let (c, d) = (n.rows(), n.cols());
        let e = cols.len();
        let f = rows.len();
        assert_eq!(plan.len(), e, "plan was built for a different column index");
        if self.threads <= 1 || e + f < MIN_PARALLEL_EDGES {
            gvt_apply_into(m, n, m_t, n_t, rows, cols, v, u, ws, branch);
            return;
        }
        assert_eq!(v.len(), e, "v must have length e = |cols|");
        assert_eq!(u.len(), f, "u must have length f = |rows|");
        debug_assert_eq!(m_t.rows(), b);
        debug_assert_eq!(m_t.cols(), a);
        debug_assert_eq!(n_t.rows(), d);
        debug_assert_eq!(n_t.cols(), c);

        let branch = branch.unwrap_or_else(|| complexity::choose_branch(a, b, c, d, e, f));
        let (order, offsets) = plan.buckets(branch);
        let threads = self.threads;
        match branch {
            Branch::T => {
                // Stage 1 (parallel over disjoint rows of T ∈ R^{d×a}):
                //   T[t_l, :] += v_l · Mᵀ[r_l, :]
                let (t_buf, tt_buf) = ws.grab_uncleared(d * a, a * d);
                stage1_parallel(t_buf, a, order, offsets, &cols.left, m_t, v, threads);
                // Tᵀ is a×d: row p_h is column p_h of T.
                transpose_into_parallel(t_buf, d, a, tt_buf, threads);
                // Stage 2 (parallel over chunks of u): u_h = N[q_h,:]·Tᵀ[p_h,:]
                let tt = &tt_buf[..a * d];
                stage2_parallel(u, &rows.left, &rows.right, threads, |p, q| {
                    dot(n.row(q), &tt[p * d..(p + 1) * d])
                });
            }
            Branch::S => {
                // Stage 1 (parallel over disjoint rows of Sᵀ ∈ R^{b×c}):
                //   Sᵀ[r_l, :] += v_l · Nᵀ[t_l, :]
                let (st_buf, s_buf) = ws.grab_uncleared(b * c, c * b);
                stage1_parallel(st_buf, c, order, offsets, &cols.right, n_t, v, threads);
                // S is c×b.
                transpose_into_parallel(st_buf, b, c, s_buf, threads);
                // Stage 2: u_h = S[q_h, :] · M[p_h, :]
                let s = &s_buf[..c * b];
                stage2_parallel(u, &rows.left, &rows.right, threads, |p, q| {
                    dot(&s[q * b..(q + 1) * b], m.row(p))
                });
            }
        }
    }

    /// [`GvtEngine::apply_planned`] restricted to a sampled subset of output
    /// rows: stage 1 runs over the **full** column index exactly as the full
    /// apply would, and stage 2 evaluates only the output edges named by
    /// `batch` (built against this `rows` index), writing
    /// `u[i] = (R(M⊗N)Cᵀv)[batch.positions()[i]]`.
    ///
    /// **Bitwise pin:** `u[i]` is bit-for-bit the value the full apply
    /// writes at position `batch.positions()[i]`, for every thread count and
    /// both branches — automatic branch selection uses the *full* output
    /// length `f` (not the batch length) so restriction can never flip the
    /// branch, stage 1 is shared verbatim, and each stage-2 output is an
    /// independent dot against the shared stage-1 result. This is the
    /// per-iteration operator contract the stochastic trainer's tests pin.
    #[allow(clippy::too_many_arguments)]
    pub fn apply_restricted(
        &self,
        m: &Matrix,
        n: &Matrix,
        m_t: &Matrix,
        n_t: &Matrix,
        rows: &KronIndex,
        cols: &KronIndex,
        plan: &EdgePlan,
        batch: &BatchPlan,
        v: &[f64],
        u: &mut [f64],
        ws: &mut GvtWorkspace,
        branch: Option<Branch>,
    ) {
        let (a, b) = (m.rows(), m.cols());
        let (c, d) = (n.rows(), n.cols());
        let e = cols.len();
        let f = rows.len();
        assert_eq!(plan.len(), e, "plan was built for a different column index");
        assert_eq!(batch.full_len(), f, "batch was built for a different row index");
        assert_eq!(v.len(), e, "v must have length e = |cols|");
        assert_eq!(u.len(), batch.len(), "u must have one slot per batch position");
        debug_assert_eq!(m_t.rows(), b);
        debug_assert_eq!(m_t.cols(), a);
        debug_assert_eq!(n_t.rows(), d);
        debug_assert_eq!(n_t.cols(), c);
        // Mirror the full apply's branch choice (which sees the full f) so
        // the restricted result is a pure row-slice of the full result.
        let branch = branch.unwrap_or_else(|| complexity::choose_branch(a, b, c, d, e, f));
        let serial = self.threads <= 1 || e + batch.len() < MIN_PARALLEL_EDGES;
        let threads = if serial { 1 } else { self.threads };
        match branch {
            Branch::T => {
                let (t_buf, tt_buf) = ws.grab_uncleared(d * a, a * d);
                if serial {
                    // Original-order stage-1 replay: bitwise-equal to the
                    // bucketed replay (per destination row both visit edges
                    // in original order) and to the serial full apply.
                    let t = &mut t_buf[..d * a];
                    t.fill(0.0);
                    for (l, &vl) in v.iter().enumerate() {
                        if vl == 0.0 {
                            continue; // sparse shortcut, eq. (5)
                        }
                        let row = cols.right[l] as usize;
                        axpy(vl, m_t.row(cols.left[l] as usize), &mut t[row * a..(row + 1) * a]);
                    }
                } else {
                    let (order, offsets) = plan.buckets(branch);
                    stage1_parallel(t_buf, a, order, offsets, &cols.left, m_t, v, threads);
                }
                transpose_into_parallel(t_buf, d, a, tt_buf, threads);
                let tt = &tt_buf[..a * d];
                stage2_restricted(u, &batch.edges, &rows.left, &rows.right, threads, |p, q| {
                    dot(n.row(q), &tt[p * d..(p + 1) * d])
                });
            }
            Branch::S => {
                let (st_buf, s_buf) = ws.grab_uncleared(b * c, c * b);
                if serial {
                    let st = &mut st_buf[..b * c];
                    st.fill(0.0);
                    for (l, &vl) in v.iter().enumerate() {
                        if vl == 0.0 {
                            continue; // sparse shortcut, eq. (5)
                        }
                        let row = cols.left[l] as usize;
                        axpy(vl, n_t.row(cols.right[l] as usize), &mut st[row * c..(row + 1) * c]);
                    }
                } else {
                    let (order, offsets) = plan.buckets(branch);
                    stage1_parallel(st_buf, c, order, offsets, &cols.right, n_t, v, threads);
                }
                transpose_into_parallel(st_buf, b, c, s_buf, threads);
                let s = &s_buf[..c * b];
                stage2_restricted(u, &batch.edges, &rows.left, &rows.right, threads, |p, q| {
                    dot(&s[q * b..(q + 1) * b], m.row(p))
                });
            }
        }
    }

    /// Adds a batched stage-1 update into a **persistent accumulator**: for
    /// each batch slot `i` naming edge `l = batch.positions()[i]`,
    ///
    /// * branch T: `acc[cols.right[l], :] += delta[i] · Mᵀ[cols.left[l], :]`
    ///   with `acc ∈ R^{d×a}` (pass `factor_t = Mᵀ`),
    /// * branch S: `acc[cols.left[l], :] += delta[i] · Nᵀ[cols.right[l], :]`
    ///   with `acc ∈ R^{b×c}` (pass `factor_t = Nᵀ`).
    ///
    /// The accumulator is **not cleared** — this is the incremental update
    /// the stochastic trainer uses to keep its stage-1 state current in
    /// `O(|batch|)` work per step instead of `O(e)`. Workers own disjoint
    /// destination-row ranges from the batch's stable buckets and replay
    /// slots in batch order within each row, so the result is bitwise
    /// identical to the serial batch-order replay at every thread count.
    /// Zero deltas are skipped (eq. 5). `batch` must have been built against
    /// this `cols` index.
    pub fn scatter_batch(
        &self,
        factor_t: &Matrix,
        cols: &KronIndex,
        batch: &BatchPlan,
        delta: &[f64],
        acc: &mut [f64],
        branch: Branch,
    ) {
        assert_eq!(batch.full_len(), cols.len(), "batch was built for a different column index");
        assert_eq!(delta.len(), batch.len(), "delta must have one entry per batch position");
        let (order, offsets) = batch.buckets(branch);
        let (keys, gather): (&[u32], &[u32]) = match branch {
            Branch::T => (&cols.right, &cols.left),
            Branch::S => (&cols.left, &cols.right),
        };
        let rows_n = offsets.len() - 1;
        let width = factor_t.cols();
        assert!(acc.len() >= rows_n * width, "accumulator too small for this branch");
        if self.threads <= 1 || batch.len() < MIN_PARALLEL_EDGES {
            for (i, &di) in delta.iter().enumerate() {
                if di == 0.0 {
                    continue; // sparse shortcut, eq. (5)
                }
                let l = batch.edges[i] as usize;
                let row = keys[l] as usize;
                let dst = &mut acc[row * width..(row + 1) * width];
                axpy(di, factor_t.row(gather[l] as usize), dst);
            }
            return;
        }
        let ranges = edge_balanced_chunks(offsets, self.threads);
        std::thread::scope(|scope| {
            let mut rest = &mut acc[..rows_n * width];
            for &(r0, r1) in &ranges {
                let (slab, tail) = rest.split_at_mut((r1 - r0) * width);
                rest = tail;
                scope.spawn(move || {
                    for row in r0..r1 {
                        let dst = &mut slab[(row - r0) * width..(row - r0 + 1) * width];
                        for &i in &order[offsets[row]..offsets[row + 1]] {
                            let di = delta[i as usize];
                            if di == 0.0 {
                                continue;
                            }
                            let l = batch.edges[i as usize] as usize;
                            axpy(di, factor_t.row(gather[l] as usize), dst);
                        }
                    }
                });
            }
        });
    }

    /// Reads the batch's output values out of a stage-1 accumulator
    /// maintained by [`GvtEngine::scatter_batch`]: for each batch slot `i`
    /// naming output edge `h = batch.positions()[i]` with
    /// `p = rows.left[h]`, `q = rows.right[h]`,
    ///
    /// * branch T: `u[i] = Σ_t N[q, t] · acc[t·a + p]` — the strided
    ///   column-`p` dot of the un-transposed `d×a` accumulator;
    /// * branch S: `u[i] = Σ_r M[p, r] · acc[r·c + q]`.
    ///
    /// Each slot is an independent sequential-order sum, so the result is
    /// deterministic for every thread count. It is numerically equal — not
    /// bitwise — to the transposed, [`dot`]-reduced stage 2 of the full
    /// apply (which reduces 4-way-unrolled); the bitwise-pinned restricted
    /// operator is [`GvtEngine::apply_restricted`]. `batch` must have been
    /// built against this `rows` index.
    pub fn gather_batch(
        &self,
        m: &Matrix,
        n: &Matrix,
        rows: &KronIndex,
        batch: &BatchPlan,
        acc: &[f64],
        u: &mut [f64],
        branch: Branch,
    ) {
        assert_eq!(batch.full_len(), rows.len(), "batch was built for a different row index");
        assert_eq!(u.len(), batch.len(), "u must have one slot per batch position");
        let threads = if self.threads <= 1 || batch.len() < MIN_PARALLEL_EDGES {
            1
        } else {
            self.threads
        };
        match branch {
            Branch::T => {
                let (a, d) = (m.rows(), n.cols());
                assert!(acc.len() >= d * a, "accumulator too small for branch T");
                stage2_restricted(u, &batch.edges, &rows.left, &rows.right, threads, |p, q| {
                    let mut s = 0.0;
                    for (t, &nqt) in n.row(q).iter().enumerate() {
                        s += nqt * acc[t * a + p];
                    }
                    s
                });
            }
            Branch::S => {
                let (b, c) = (m.cols(), n.rows());
                assert!(acc.len() >= b * c, "accumulator too small for branch S");
                stage2_restricted(u, &batch.edges, &rows.left, &rows.right, threads, |p, q| {
                    let mut s = 0.0;
                    for (r, &mpr) in m.row(p).iter().enumerate() {
                        s += mpr * acc[r * c + q];
                    }
                    s
                });
            }
        }
    }

    /// Multi-RHS [`GvtEngine::apply_planned`]: computes `u_j = R(M⊗N)Cᵀ v_j`
    /// for `k_rhs` column planes (see
    /// [`gvt_apply_multi_into`](super::algorithm::gvt_apply_multi_into) for
    /// the plane layout) in one sharded sweep.
    ///
    /// * **Stage 1** fans out over disjoint accumulation-row ranges exactly
    ///   like the single-RHS path, but each worker replays its edges once,
    ///   scale-adding every edge's factor row into all `k_rhs` planes — a
    ///   k-wide panel update amortizing the edge-index traversal.
    /// * The **blocked transpose** moves each plane with the parallel
    ///   column-block kernel.
    /// * **Stage 2** gathers per plane; when `plan` was built by
    ///   [`EdgePlan::build_full`] the output edges are visited grouped by
    ///   their gather vertex, so each stage-1 result row (`Tᵀ[p,:]` /
    ///   `S[q,:]`) is loaded once per vertex rather than once per edge.
    ///   Workers shard by plane groups when `k_rhs ≥ threads`, else by
    ///   output ranges.
    ///
    /// **Column `j` of `u` is bitwise identical to
    /// [`GvtEngine::apply_planned`] on plane `j`, for every thread count and
    /// both branches** (tested) — batching can never perturb a solver
    /// trajectory.
    #[allow(clippy::too_many_arguments)]
    pub fn apply_planned_multi(
        &self,
        m: &Matrix,
        n: &Matrix,
        m_t: &Matrix,
        n_t: &Matrix,
        rows: &KronIndex,
        cols: &KronIndex,
        plan: &EdgePlan,
        v: &[f64],
        u: &mut [f64],
        k_rhs: usize,
        ws: &mut GvtWorkspace,
        branch: Option<Branch>,
    ) {
        let (a, b) = (m.rows(), m.cols());
        let (c, d) = (n.rows(), n.cols());
        let e = cols.len();
        let f = rows.len();
        assert_eq!(plan.len(), e, "plan was built for a different column index");
        if k_rhs == 0 {
            return;
        }
        // The batch multiplies the work: a problem just under the single-RHS
        // cutoff is still worth sharding when it carries k_rhs planes.
        if self.threads <= 1 || (e + f).saturating_mul(k_rhs) < MIN_PARALLEL_EDGES {
            gvt_apply_multi_into(m, n, m_t, n_t, rows, cols, v, u, k_rhs, ws, branch);
            return;
        }
        assert_eq!(v.len(), e * k_rhs, "v must hold k_rhs planes of length e");
        assert_eq!(u.len(), f * k_rhs, "u must hold k_rhs planes of length f");
        debug_assert_eq!(m_t.rows(), b);
        debug_assert_eq!(m_t.cols(), a);
        debug_assert_eq!(n_t.rows(), d);
        debug_assert_eq!(n_t.cols(), c);

        let branch = branch.unwrap_or_else(|| complexity::choose_branch(a, b, c, d, e, f));
        let (order, offsets) = plan.buckets(branch);
        let out = plan.out_buckets(branch, f);
        let threads = self.threads;
        match branch {
            Branch::T => {
                let plane = d * a;
                let (t_buf, tt_buf) = ws.grab_uncleared(plane * k_rhs, plane * k_rhs);
                stage1_parallel_multi(
                    t_buf, a, order, offsets, &cols.left, m_t, v, e, k_rhs, threads,
                );
                for j in 0..k_rhs {
                    transpose_into_parallel(
                        &t_buf[j * plane..(j + 1) * plane],
                        d,
                        a,
                        &mut tt_buf[j * plane..(j + 1) * plane],
                        threads,
                    );
                }
                let tt = &tt_buf[..plane * k_rhs];
                let (hl, hr) = (&rows.left, &rows.right);
                stage2_parallel_multi(u, f, k_rhs, hl, hr, out, threads, |j, p, q| {
                    dot(n.row(q), &tt[j * plane + p * d..j * plane + (p + 1) * d])
                });
            }
            Branch::S => {
                let plane = b * c;
                let (st_buf, s_buf) = ws.grab_uncleared(plane * k_rhs, plane * k_rhs);
                stage1_parallel_multi(
                    st_buf, c, order, offsets, &cols.right, n_t, v, e, k_rhs, threads,
                );
                for j in 0..k_rhs {
                    transpose_into_parallel(
                        &st_buf[j * plane..(j + 1) * plane],
                        b,
                        c,
                        &mut s_buf[j * plane..(j + 1) * plane],
                        threads,
                    );
                }
                let s = &s_buf[..plane * k_rhs];
                let (hl, hr) = (&rows.left, &rows.right);
                stage2_parallel_multi(u, f, k_rhs, hl, hr, out, threads, |j, p, q| {
                    dot(&s[j * plane + q * b..j * plane + (q + 1) * b], m.row(p))
                });
            }
        }
    }

    /// Computes the **D-way chain apply** `u = R (K₁⊗…⊗K_D) Cᵀ v` using a
    /// prebuilt [`ChainPlan`]: edge-indexed gather, `D−1` mode-product GEMM
    /// stages, edge-indexed scatter (see the [`ChainPlan`] docs for the
    /// pipeline and its layout invariant).
    ///
    /// `factors[d]` is `K_d` (`dims_a[d] × dims_b[d]`) and `factors_t[d]`
    /// its transpose (for symmetric kernels pass the factor itself). The
    /// result is **bitwise identical for every thread count**, and at
    /// `D = 2` it is the unmodified two-factor
    /// [`GvtEngine::apply_planned`] path — `branch` forwards to it there
    /// and is ignored for `D ≥ 3`.
    #[allow(clippy::too_many_arguments)]
    pub fn apply_chain(
        &self,
        factors: &[&Matrix],
        factors_t: &[&Matrix],
        plan: &ChainPlan,
        v: &[f64],
        u: &mut [f64],
        ws: &mut GvtWorkspace,
        branch: Option<Branch>,
    ) {
        self.check_chain_args(factors, factors_t, plan);
        assert_eq!(v.len(), plan.e, "v must have length e = |cols|");
        assert_eq!(u.len(), plan.f, "u must have length f = |rows|");
        if let (Some(kr), Some(kc), Some(kp)) =
            (&plan.kron_rows, &plan.kron_cols, &plan.kron_plan)
        {
            self.apply_planned(
                factors[0], factors[1], factors_t[0], factors_t[1], kr, kc, kp, v, u, ws, branch,
            );
            return;
        }
        if plan.f == 0 {
            return;
        }
        // Serial fallback mirrors the two-factor cutoff: below it, the
        // stage-1 replay runs in original edge order (bitwise-equal to the
        // bucketed replay — per destination row both visit edges in
        // original order) and every stage runs on one thread.
        let serial = self.threads <= 1 || plan.e + plan.f < MIN_PARALLEL_EDGES;
        let threads = if serial { 1 } else { self.threads };
        let (abuf, bbuf) = ws.grab_uncleared(plan.max_stage, plan.max_stage);
        let a1 = plan.dims_a[0];
        if serial {
            let s1 = plan.rest_dim * a1;
            abuf[..s1].fill(0.0);
            let k1_t = factors_t[0];
            for (l, &vl) in v.iter().enumerate() {
                if vl == 0.0 {
                    continue; // sparse shortcut, eq. (5)
                }
                let row = plan.rest_keys[l] as usize;
                axpy(vl, k1_t.row(plan.col_first[l] as usize), &mut abuf[row * a1..(row + 1) * a1]);
            }
        } else {
            stage1_parallel(
                abuf,
                a1,
                &plan.rest_order,
                &plan.rest_offsets,
                &plan.col_first,
                factors_t[0],
                v,
                threads,
            );
        }
        let mut cur = plan.rest_dim * a1;
        chain_tail(factors, plan, abuf, bbuf, &mut cur, 0, threads);
        // Fused mode-D gather: u_h = ⟨K_D[p^D_h, :], Z[prefix_h, :]⟩.
        let b_last = plan.dims_b[plan.order() - 1];
        let z = &bbuf[..cur];
        let k_last = factors[plan.order() - 1];
        if serial {
            for h in 0..plan.f {
                let p = plan.prefix_keys[h] as usize;
                u[h] = dot(k_last.row(plan.row_last[h] as usize), &z[p * b_last..(p + 1) * b_last]);
            }
        } else {
            stage2_parallel(u, &plan.prefix_keys, &plan.row_last, threads, |p, q| {
                dot(k_last.row(q), &z[p * b_last..(p + 1) * b_last])
            });
        }
    }

    /// Multi-RHS [`GvtEngine::apply_chain`]: `k_rhs` column planes in one
    /// batched sweep (one stage-1 edge traversal and one stacked GEMM per
    /// middle mode for all right-hand sides). **Plane `j` is bitwise
    /// identical to [`GvtEngine::apply_chain`] on plane `j`** for every
    /// thread count — at `D = 2` via the two-factor multi path, at `D ≥ 3`
    /// because every stage is element-wise identical per plane.
    #[allow(clippy::too_many_arguments)]
    pub fn apply_chain_multi(
        &self,
        factors: &[&Matrix],
        factors_t: &[&Matrix],
        plan: &ChainPlan,
        v: &[f64],
        u: &mut [f64],
        k_rhs: usize,
        ws: &mut GvtWorkspace,
        branch: Option<Branch>,
    ) {
        self.check_chain_args(factors, factors_t, plan);
        if k_rhs == 0 {
            return;
        }
        assert_eq!(v.len(), plan.e * k_rhs, "v must hold k_rhs planes of length e");
        assert_eq!(u.len(), plan.f * k_rhs, "u must hold k_rhs planes of length f");
        if let (Some(kr), Some(kc), Some(kp)) =
            (&plan.kron_rows, &plan.kron_cols, &plan.kron_plan)
        {
            self.apply_planned_multi(
                factors[0], factors[1], factors_t[0], factors_t[1], kr, kc, kp, v, u, k_rhs, ws,
                branch,
            );
            return;
        }
        if plan.f == 0 {
            return;
        }
        if self.threads <= 1 || (plan.e + plan.f).saturating_mul(k_rhs) < MIN_PARALLEL_EDGES {
            // Small problems: per-plane serial applies (bitwise-identical to
            // the batched path by the per-plane guarantee above).
            for j in 0..k_rhs {
                self.apply_chain(
                    factors,
                    factors_t,
                    plan,
                    &v[j * plan.e..(j + 1) * plan.e],
                    &mut u[j * plan.f..(j + 1) * plan.f],
                    ws,
                    branch,
                );
            }
            return;
        }
        let threads = self.threads;
        let buf_len = plan
            .max_stage
            .checked_mul(k_rhs)
            .expect("chain multi-RHS workspace size overflows usize");
        let (abuf, bbuf) = ws.grab_uncleared(buf_len, buf_len);
        let a1 = plan.dims_a[0];
        stage1_parallel_multi(
            abuf,
            a1,
            &plan.rest_order,
            &plan.rest_offsets,
            &plan.col_first,
            factors_t[0],
            v,
            plan.e,
            k_rhs,
            threads,
        );
        let mut cur = plan.rest_dim * a1;
        chain_tail(factors, plan, abuf, bbuf, &mut cur, k_rhs, threads);
        let b_last = plan.dims_b[plan.order() - 1];
        let plane = cur;
        let z = &bbuf[..plane * k_rhs];
        let k_last = factors[plan.order() - 1];
        stage2_parallel_multi(
            u,
            plan.f,
            k_rhs,
            &plan.prefix_keys,
            &plan.row_last,
            None,
            threads,
            |j, p, q| dot(k_last.row(q), &z[j * plane + p * b_last..j * plane + (p + 1) * b_last]),
        );
    }

    /// Shared argument validation for the chain applies.
    fn check_chain_args(&self, factors: &[&Matrix], factors_t: &[&Matrix], plan: &ChainPlan) {
        let order = plan.order();
        assert_eq!(factors.len(), order, "one factor matrix per mode required");
        assert_eq!(factors_t.len(), order, "one transposed factor per mode required");
        for d in 0..order {
            assert_eq!(factors[d].rows(), plan.dims_a[d], "factor {d} row count mismatch");
            assert_eq!(factors[d].cols(), plan.dims_b[d], "factor {d} column count mismatch");
            debug_assert_eq!(factors_t[d].rows(), plan.dims_b[d]);
            debug_assert_eq!(factors_t[d].cols(), plan.dims_a[d]);
        }
    }
}

/// The middle of the chain pipeline (modes `2 … D−1` contractions plus the
/// final mode-D transpose), shared by the single- and multi-RHS applies.
///
/// On entry `abuf` holds the stage-1 result — `k_rhs.max(1)` tightly packed
/// planes of `*cur` doubles in layout `(j₂…j_D, k₁)`. Each middle mode `d`
/// transposes every plane (moving mode `d`'s column axis to the minor
/// position) into `bbuf`, then contracts it with one stacked
/// [`gemm_nt_into`] over all planes (`Y = X·K_dᵀ`, loading `K_d` rows
/// directly — middle factors need no transposes). On exit `bbuf` holds the
/// final transposed planes `Z` of `*cur` doubles each in layout
/// `(k₁…k_{D−1}, j_D)`, ready for the fused gather.
fn chain_tail(
    factors: &[&Matrix],
    plan: &ChainPlan,
    abuf: &mut [f64],
    bbuf: &mut [f64],
    cur: &mut usize,
    k_rhs: usize,
    threads: usize,
) {
    let order = plan.order();
    let planes = k_rhs.max(1);
    for d in 1..order - 1 {
        let (bd, ad) = (plan.dims_b[d], plan.dims_a[d]);
        debug_assert_eq!(*cur % bd, 0);
        let r = *cur / bd;
        for j in 0..planes {
            transpose_into_parallel(
                &abuf[j * *cur..(j + 1) * *cur],
                bd,
                r,
                &mut bbuf[j * *cur..(j + 1) * *cur],
                threads,
            );
        }
        // One stacked GEMM over all planes: they are tightly packed, so the
        // stack is a (planes·r) × bd row-major matrix; every output element
        // is dot(x_row, K_d_row) regardless of the stacking, keeping planes
        // bitwise identical to their single-RHS applies.
        gemm_nt_into(
            &bbuf[..planes * r * bd],
            factors[d].data(),
            planes * r,
            bd,
            ad,
            &mut abuf[..planes * r * ad],
            threads,
        );
        *cur = r * ad;
    }
    let b_last = plan.dims_b[order - 1];
    debug_assert_eq!(*cur % b_last, 0);
    let a_prefix = *cur / b_last;
    for j in 0..planes {
        transpose_into_parallel(
            &abuf[j * *cur..(j + 1) * *cur],
            b_last,
            a_prefix,
            &mut bbuf[j * *cur..(j + 1) * *cur],
            threads,
        );
    }
}

/// Stage 1 worker fan-out: each scoped thread owns a contiguous range of
/// destination rows of the `rows×width` accumulator `buf` (zeroing it before
/// accumulating, so callers must *not* pre-clear), and replays its buckets'
/// edges in original order. `gather` maps an edge id to the source row of
/// `factor_t` to scale-add.
#[allow(clippy::too_many_arguments)]
fn stage1_parallel(
    buf: &mut [f64],
    width: usize,
    order: &[u32],
    offsets: &[usize],
    gather: &[u32],
    factor_t: &Matrix,
    v: &[f64],
    threads: usize,
) {
    let rows = offsets.len() - 1;
    debug_assert!(buf.len() >= rows * width);
    let ranges = edge_balanced_chunks(offsets, threads);
    std::thread::scope(|scope| {
        let mut rest = &mut buf[..rows * width];
        for &(r0, r1) in &ranges {
            let (slab, tail) = rest.split_at_mut((r1 - r0) * width);
            rest = tail;
            scope.spawn(move || {
                slab.fill(0.0);
                for row in r0..r1 {
                    let dst = &mut slab[(row - r0) * width..(row - r0 + 1) * width];
                    for &l in &order[offsets[row]..offsets[row + 1]] {
                        let vl = v[l as usize];
                        if vl == 0.0 {
                            continue;
                        }
                        axpy(vl, factor_t.row(gather[l as usize] as usize), dst);
                    }
                }
            });
        }
    });
}

/// Split `buf` (holding `k_rhs` planes of `plane_len` doubles) at the given
/// contiguous, ascending `ranges` (in units of `width` doubles), returning
/// one `Vec` of per-plane slabs per range. Lets scoped workers own the same
/// row/edge range across *every* plane without locks.
fn split_planes_at<'a>(
    buf: &'a mut [f64],
    plane_len: usize,
    k_rhs: usize,
    ranges: &[(usize, usize)],
    width: usize,
) -> Vec<Vec<&'a mut [f64]>> {
    if plane_len == 0 || ranges.is_empty() {
        return Vec::new();
    }
    let mut rests: Vec<&'a mut [f64]> =
        buf[..plane_len * k_rhs].chunks_mut(plane_len).collect();
    let mut out = Vec::with_capacity(ranges.len());
    for &(r0, r1) in ranges {
        let take = (r1 - r0) * width;
        let mut slabs = Vec::with_capacity(k_rhs);
        for rest in rests.iter_mut() {
            let taken = std::mem::take(rest);
            let (slab, tail) = taken.split_at_mut(take);
            *rest = tail;
            slabs.push(slab);
        }
        out.push(slabs);
    }
    out
}

/// Multi-RHS stage-1 fan-out: workers own the same contiguous destination-row
/// range in every plane of the `rows×width×k_rhs` accumulator (zeroing their
/// slabs first), and replay their buckets' edges **once**, scale-adding each
/// edge's `factor_t` row into all planes (zero entries skipped per plane,
/// eq. 5). Bucketed edge order makes every plane bitwise identical to its
/// serial single-RHS accumulation.
#[allow(clippy::too_many_arguments)]
fn stage1_parallel_multi(
    buf: &mut [f64],
    width: usize,
    order: &[u32],
    offsets: &[usize],
    gather: &[u32],
    factor_t: &Matrix,
    v: &[f64],
    e: usize,
    k_rhs: usize,
    threads: usize,
) {
    let rows = offsets.len() - 1;
    debug_assert!(buf.len() >= rows * width * k_rhs);
    let ranges = edge_balanced_chunks(offsets, threads);
    let worker_slabs = split_planes_at(buf, rows * width, k_rhs, &ranges, width);
    std::thread::scope(|scope| {
        for (&(r0, r1), slabs) in ranges.iter().zip(worker_slabs) {
            scope.spawn(move || {
                let mut slabs = slabs;
                for slab in slabs.iter_mut() {
                    slab.fill(0.0);
                }
                for row in r0..r1 {
                    let base = (row - r0) * width;
                    for &l in &order[offsets[row]..offsets[row + 1]] {
                        let l = l as usize;
                        let src = factor_t.row(gather[l] as usize);
                        for (j, slab) in slabs.iter_mut().enumerate() {
                            let vl = v[j * e + l];
                            if vl == 0.0 {
                                continue;
                            }
                            axpy(vl, src, &mut slab[base..base + width]);
                        }
                    }
                }
            });
        }
    });
}

/// Multi-RHS stage-2 fan-out. `score(j, p, q)` evaluates output plane `j`
/// against the shared stage-1 result. With `k_rhs ≥ threads`, workers own
/// contiguous plane groups and walk the output-side vertex buckets (when
/// present), loading each gather row once per vertex; otherwise workers own
/// output-edge ranges across all planes, loading each edge's factor row once
/// for all `k_rhs` dots.
#[allow(clippy::too_many_arguments)]
fn stage2_parallel_multi(
    u: &mut [f64],
    f: usize,
    k_rhs: usize,
    left: &[u32],
    right: &[u32],
    out: Option<(&[u32], &[usize])>,
    threads: usize,
    score: impl Fn(usize, usize, usize) -> f64 + Sync,
) {
    if f == 0 {
        return;
    }
    let score = &score;
    if k_rhs >= threads {
        let groups = even_chunks(k_rhs, threads);
        std::thread::scope(|scope| {
            let mut rest = &mut u[..f * k_rhs];
            for &(j0, j1) in &groups {
                let (chunk, tail) = rest.split_at_mut((j1 - j0) * f);
                rest = tail;
                scope.spawn(move || {
                    for (jj, uplane) in chunk.chunks_mut(f).enumerate() {
                        stage2_plane(uplane, j0 + jj, left, right, out, score);
                    }
                });
            }
        });
    } else {
        let ranges = even_chunks(f, threads);
        let worker_slabs = split_planes_at(u, f, k_rhs, &ranges, 1);
        std::thread::scope(|scope| {
            for (&(h0, h1), slabs) in ranges.iter().zip(worker_slabs) {
                scope.spawn(move || {
                    let mut slabs = slabs;
                    for h in h0..h1 {
                        let (p, q) = (left[h] as usize, right[h] as usize);
                        for (j, slab) in slabs.iter_mut().enumerate() {
                            slab[h - h0] = score(j, p, q);
                        }
                    }
                });
            }
        });
    }
}

/// One output plane of multi-RHS stage 2: vertex-bucketed gather order when
/// output buckets are available (each stage-1 row stays hot across its
/// bucket), plain edge order otherwise. The per-edge value is identical
/// either way — bucketing only reorders independent writes.
fn stage2_plane(
    uplane: &mut [f64],
    j: usize,
    left: &[u32],
    right: &[u32],
    out: Option<(&[u32], &[usize])>,
    score: &(impl Fn(usize, usize, usize) -> f64 + Sync),
) {
    match out {
        Some((order, offsets)) => {
            for vertex in 0..offsets.len() - 1 {
                for &h in &order[offsets[vertex]..offsets[vertex + 1]] {
                    let h = h as usize;
                    uplane[h] = score(j, left[h] as usize, right[h] as usize);
                }
            }
        }
        None => {
            for (h, uh) in uplane.iter_mut().enumerate() {
                *uh = score(j, left[h] as usize, right[h] as usize);
            }
        }
    }
}

/// Stage 2 fan-out: contiguous chunks of `u`, each worker evaluating
/// `score(p_h, q_h)` for its edges against the shared stage-1 result.
fn stage2_parallel(
    u: &mut [f64],
    left: &[u32],
    right: &[u32],
    threads: usize,
    score: impl Fn(usize, usize) -> f64 + Sync,
) {
    let f = u.len();
    let ranges = even_chunks(f, threads);
    let score = &score;
    std::thread::scope(|scope| {
        let mut rest = u;
        for &(h0, h1) in &ranges {
            let (chunk, tail) = rest.split_at_mut(h1 - h0);
            rest = tail;
            scope.spawn(move || {
                for (i, uh) in chunk.iter_mut().enumerate() {
                    let h = h0 + i;
                    *uh = score(left[h] as usize, right[h] as usize);
                }
            });
        }
    });
}

/// Restricted stage-2 fan-out: like [`stage2_parallel`], but evaluating only
/// the output rows named by `picks` (positions into `left`/`right`), writing
/// `u[i] = score(left[picks[i]], right[picks[i]])`. Each output is an
/// independent evaluation against the shared stage-1 result, so every value
/// is bitwise the one the full stage 2 writes at the same position, for any
/// thread count.
fn stage2_restricted(
    u: &mut [f64],
    picks: &[u32],
    left: &[u32],
    right: &[u32],
    threads: usize,
    score: impl Fn(usize, usize) -> f64 + Sync,
) {
    debug_assert_eq!(u.len(), picks.len());
    let ranges = even_chunks(u.len(), threads);
    if ranges.len() <= 1 {
        for (uh, &h) in u.iter_mut().zip(picks) {
            let h = h as usize;
            *uh = score(left[h] as usize, right[h] as usize);
        }
        return;
    }
    let score = &score;
    std::thread::scope(|scope| {
        let mut rest = u;
        for &(i0, i1) in &ranges {
            let (chunk, tail) = rest.split_at_mut(i1 - i0);
            rest = tail;
            scope.spawn(move || {
                for (i, uh) in chunk.iter_mut().enumerate() {
                    let h = picks[i0 + i] as usize;
                    *uh = score(left[h] as usize, right[h] as usize);
                }
            });
        }
    });
}

/// Default retention bound for [`WorkspacePool`] — enough for a healthy
/// scoring pool's steady state without letting a one-off concurrency burst
/// pin its high-watermark of scratch memory forever.
pub const DEFAULT_POOL_RETENTION: usize = 8;

/// Lock-protected stack of [`GvtWorkspace`] scratch buffers.
///
/// The GVT operators hand one workspace to each in-flight apply, so a single
/// trained operator can serve concurrent callers (`Sync`) without sharing
/// accumulation buffers. The lock is held only to pop/push a workspace, never
/// during the matvec itself.
///
/// The free list is **bounded**: at most `retention` idle workspaces are
/// kept (default [`DEFAULT_POOL_RETENTION`]); workspaces returned beyond
/// that are dropped. Without the bound the pool grows to the high-watermark
/// of *concurrent* applies ever seen and never shrinks — a burst of traffic
/// would pin its peak scratch memory for the life of the operator.
#[derive(Debug)]
pub struct WorkspacePool {
    free: Mutex<Vec<GvtWorkspace>>,
    retention: usize,
}

impl Default for WorkspacePool {
    fn default() -> Self {
        WorkspacePool::with_retention(DEFAULT_POOL_RETENTION)
    }
}

impl WorkspacePool {
    /// Empty pool; workspaces are created on demand and recycled, keeping at
    /// most [`DEFAULT_POOL_RETENTION`] idle.
    pub fn new() -> WorkspacePool {
        WorkspacePool::default()
    }

    /// Empty pool keeping at most `retention` idle workspaces (`0` disables
    /// recycling entirely).
    pub fn with_retention(retention: usize) -> WorkspacePool {
        WorkspacePool { free: Mutex::new(Vec::new()), retention }
    }

    /// Maximum number of idle workspaces this pool retains.
    pub fn retention(&self) -> usize {
        self.retention
    }

    /// Number of idle workspaces currently pooled (≤ retention).
    pub fn pooled(&self) -> usize {
        self.free.lock().unwrap_or_else(|poisoned| poisoned.into_inner()).len()
    }

    /// Run `f` with a pooled workspace, returning the workspace to the pool
    /// afterwards (or dropping it if the free list is at its retention
    /// bound).
    pub fn with<R>(&self, f: impl FnOnce(&mut GvtWorkspace) -> R) -> R {
        let mut ws = self
            .free
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
            .pop()
            .unwrap_or_default();
        let out = f(&mut ws);
        let mut free = self.free.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        if free.len() < self.retention {
            free.push(ws);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::assert_allclose;
    use crate::util::rng::Pcg32;

    #[test]
    fn bucket_stable_preserves_order() {
        let keys = vec![2u32, 0, 2, 1, 0, 2];
        let (order, offsets) = bucket_stable(&keys, 3);
        assert_eq!(offsets, vec![0, 2, 3, 6]);
        // bucket 0 holds edges 1, 4 in original order; bucket 2 holds 0, 2, 5
        assert_eq!(&order[0..2], &[1, 4]);
        assert_eq!(&order[2..3], &[3]);
        assert_eq!(&order[3..6], &[0, 2, 5]);
    }

    #[test]
    fn edge_balanced_chunks_cover_all_rows() {
        // offsets for 6 rows with very skewed bucket sizes
        let offsets = vec![0usize, 100, 100, 100, 101, 150, 200];
        for parts in 1..=8 {
            let chunks = edge_balanced_chunks(&offsets, parts);
            assert!(!chunks.is_empty());
            assert_eq!(chunks[0].0, 0);
            assert_eq!(chunks.last().unwrap().1, 6);
            for w in chunks.windows(2) {
                assert_eq!(w[0].1, w[1].0, "ranges must be contiguous");
                assert!(w[0].0 < w[0].1, "ranges must be non-empty");
            }
        }
    }

    #[test]
    fn even_chunks_partition() {
        assert_eq!(even_chunks(0, 4), vec![]);
        assert_eq!(even_chunks(3, 8), vec![(0, 1), (1, 2), (2, 3)]);
        let c = even_chunks(10, 3);
        assert_eq!(c, vec![(0, 4), (4, 7), (7, 10)]);
    }

    #[test]
    fn parallel_transpose_matches_serial() {
        let mut rng = Pcg32::seeded(42);
        for &(rows, cols) in &[(1usize, 1usize), (5, 97), (64, 64), (33, 7)] {
            let src: Vec<f64> = (0..rows * cols).map(|_| rng.normal()).collect();
            let mut serial = vec![0.0; rows * cols];
            transpose_into_parallel(&src, rows, cols, &mut serial, 1);
            for threads in [2, 3, 8] {
                let mut par = vec![0.0; rows * cols];
                transpose_into_parallel(&src, rows, cols, &mut par, threads);
                assert_eq!(serial, par, "{rows}x{cols} @ {threads} threads");
            }
            // spot-check correctness against the definition
            for i in 0..rows {
                for j in 0..cols {
                    assert_eq!(serial[j * rows + i], src[i * cols + j]);
                }
            }
        }
    }

    #[test]
    fn engine_matches_serial_apply() {
        let mut rng = Pcg32::seeded(43);
        let (a, b, c, d, e, f) = (7, 9, 6, 8, 4000, 3500);
        let m = Matrix::from_fn(a, b, |_, _| rng.normal());
        let n = Matrix::from_fn(c, d, |_, _| rng.normal());
        let m_t = m.transpose();
        let n_t = n.transpose();
        let rows = KronIndex::new(
            (0..f).map(|_| rng.below(a) as u32).collect(),
            (0..f).map(|_| rng.below(c) as u32).collect(),
        );
        let cols = KronIndex::new(
            (0..e).map(|_| rng.below(b) as u32).collect(),
            (0..e).map(|_| rng.below(d) as u32).collect(),
        );
        let v = rng.normal_vec(e);
        let plan = EdgePlan::build(&cols, b, d);

        let mut ws = GvtWorkspace::new();
        let mut serial = vec![0.0; f];
        gvt_apply_into(&m, &n, &m_t, &n_t, &rows, &cols, &v, &mut serial, &mut ws, None);
        for threads in [2, 4, 8] {
            let engine = GvtEngine::new(threads);
            let mut par = vec![0.0; f];
            let mut ws2 = GvtWorkspace::new();
            engine.apply_planned(
                &m, &n, &m_t, &n_t, &rows, &cols, &plan, &v, &mut par, &mut ws2, None,
            );
            // bitwise identical, not just close
            assert_eq!(serial, par, "threads={threads}");
        }
        // and both branches individually
        for branch in [Branch::T, Branch::S] {
            let mut sref = vec![0.0; f];
            gvt_apply_into(&m, &n, &m_t, &n_t, &rows, &cols, &v, &mut sref, &mut ws, Some(branch));
            let mut par = vec![0.0; f];
            GvtEngine::new(4).apply_planned(
                &m, &n, &m_t, &n_t, &rows, &cols, &plan, &v, &mut par, &mut ws, Some(branch),
            );
            assert_allclose(&par, &sref, 0.0, 0.0);
        }
    }

    #[test]
    fn engine_zero_threads_autodetects() {
        assert!(GvtEngine::new(0).threads() >= 1);
        assert_eq!(GvtEngine::serial().threads(), 1);
    }

    #[test]
    fn multi_rhs_columns_match_single_rhs_bitwise() {
        // Every column of apply_planned_multi must be bit-for-bit the
        // single-RHS apply_planned result — for every thread count, both
        // branches, with and without output buckets, zeros included.
        let mut rng = Pcg32::seeded(44);
        let (a, b, c, d, e, f) = (6, 8, 7, 5, 3200, 2800);
        let m = Matrix::from_fn(a, b, |_, _| rng.normal());
        let n = Matrix::from_fn(c, d, |_, _| rng.normal());
        let m_t = m.transpose();
        let n_t = n.transpose();
        let rows = KronIndex::new(
            (0..f).map(|_| rng.below(a) as u32).collect(),
            (0..f).map(|_| rng.below(c) as u32).collect(),
        );
        let cols = KronIndex::new(
            (0..e).map(|_| rng.below(b) as u32).collect(),
            (0..e).map(|_| rng.below(d) as u32).collect(),
        );
        let k_rhs = 3;
        let mut v = rng.normal_vec(e * k_rhs);
        for (i, vi) in v.iter_mut().enumerate() {
            if i % 5 == 0 {
                *vi = 0.0; // exercise the per-plane zero-skip
            }
        }
        let plain = EdgePlan::build(&cols, b, d);
        let full = EdgePlan::build_full(&rows, &cols, a, b, c, d);
        assert!(full.has_output_buckets());
        assert!(!plain.has_output_buckets());

        let mut ws = GvtWorkspace::new();
        for branch in [None, Some(Branch::T), Some(Branch::S)] {
            // per-column single-RHS reference
            let mut singles = vec![0.0; f * k_rhs];
            for j in 0..k_rhs {
                let mut uj = vec![0.0; f];
                gvt_apply_into(
                    &m, &n, &m_t, &n_t, &rows, &cols, &v[j * e..(j + 1) * e], &mut uj, &mut ws,
                    branch,
                );
                singles[j * f..(j + 1) * f].copy_from_slice(&uj);
            }
            for threads in [1, 2, 4, 8] {
                let engine = GvtEngine::new(threads);
                for plan in [&plain, &full] {
                    let mut multi = vec![f64::NAN; f * k_rhs];
                    let mut ws2 = GvtWorkspace::new();
                    engine.apply_planned_multi(
                        &m, &n, &m_t, &n_t, &rows, &cols, plan, &v, &mut multi, k_rhs, &mut ws2,
                        branch,
                    );
                    assert_eq!(
                        multi, singles,
                        "branch={branch:?} threads={threads} buckets={}",
                        plan.has_output_buckets()
                    );
                }
            }
        }
    }

    #[test]
    fn mismatched_output_buckets_are_ignored_safely() {
        // A full plan reused with a different-length row index must fall back
        // to unbucketed gathers, not index out of bounds.
        let mut rng = Pcg32::seeded(45);
        let (a, b, c, d, e) = (5, 6, 4, 7, 2600);
        let m = Matrix::from_fn(a, b, |_, _| rng.normal());
        let n = Matrix::from_fn(c, d, |_, _| rng.normal());
        let m_t = m.transpose();
        let n_t = n.transpose();
        let cols = KronIndex::new(
            (0..e).map(|_| rng.below(b) as u32).collect(),
            (0..e).map(|_| rng.below(d) as u32).collect(),
        );
        let rows_build = KronIndex::new(vec![0; 10], vec![0; 10]);
        let plan = EdgePlan::build_full(&rows_build, &cols, a, b, c, d);
        let f = 2400;
        let rows = KronIndex::new(
            (0..f).map(|_| rng.below(a) as u32).collect(),
            (0..f).map(|_| rng.below(c) as u32).collect(),
        );
        let v = rng.normal_vec(e);
        let mut ws = GvtWorkspace::new();
        let mut expect = vec![0.0; f];
        gvt_apply_into(&m, &n, &m_t, &n_t, &rows, &cols, &v, &mut expect, &mut ws, None);
        let mut got = vec![0.0; f];
        GvtEngine::new(4).apply_planned_multi(
            &m, &n, &m_t, &n_t, &rows, &cols, &plan, &v, &mut got, 1, &mut ws, None,
        );
        assert_eq!(got, expect);
    }

    #[test]
    fn workspace_pool_recycles() {
        let pool = WorkspacePool::new();
        pool.with(|ws| {
            let (s, _) = ws.grab_uncleared(16, 16);
            s.fill(1.0);
        });
        // same workspace comes back; buffers are reused (and re-zeroed by
        // grab in the serial path, or by workers in the parallel path)
        pool.with(|ws| {
            let (s, _) = ws.grab_uncleared(16, 16);
            assert_eq!(s.len(), 16);
        });
        assert_eq!(pool.pooled(), 1);
    }

    #[test]
    fn workspace_pool_bounds_its_free_list() {
        // Regression: a burst of concurrent applies must not pin its
        // high-watermark of workspaces — the free list stays ≤ retention.
        let pool = WorkspacePool::with_retention(3);
        assert_eq!(pool.retention(), 3);
        let concurrency = 16;
        let barrier = std::sync::Barrier::new(concurrency);
        std::thread::scope(|scope| {
            for _ in 0..concurrency {
                scope.spawn(|| {
                    pool.with(|ws| {
                        let (s, _) = ws.grab_uncleared(8, 8);
                        s.fill(2.0);
                        // hold the workspace until all 16 are live, forcing
                        // 16 distinct workspaces into existence
                        barrier.wait();
                    });
                });
            }
        });
        assert!(
            pool.pooled() <= 3,
            "free list grew past retention: {}",
            pool.pooled()
        );
        // zero retention disables recycling entirely
        let none = WorkspacePool::with_retention(0);
        none.with(|_| {});
        assert_eq!(none.pooled(), 0);
    }

    /// Dense chain oracle: `u_h = Σ_l Π_d K_d[rowsᵈ_h, colsᵈ_l] · v_l`.
    fn chain_oracle(
        factors: &[&Matrix],
        rows: &TensorIndex,
        cols: &TensorIndex,
        v: &[f64],
    ) -> Vec<f64> {
        (0..rows.len())
            .map(|h| {
                (0..cols.len())
                    .map(|l| {
                        let mut w = v[l];
                        for (d, k) in factors.iter().enumerate() {
                            w *= k.get(rows.modes[d][h] as usize, cols.modes[d][l] as usize);
                        }
                        w
                    })
                    .sum()
            })
            .collect()
    }

    fn random_tensor_index(rng: &mut Pcg32, dims: &[usize], n: usize) -> TensorIndex {
        TensorIndex::new(
            dims.iter().map(|&d| (0..n).map(|_| rng.below(d) as u32).collect()).collect(),
        )
    }

    #[test]
    fn d3_chain_matches_dense_oracle() {
        let mut rng = Pcg32::seeded(50);
        let dims_a = [3usize, 4, 2];
        let dims_b = [4usize, 3, 3];
        let factors: Vec<Matrix> = dims_a
            .iter()
            .zip(&dims_b)
            .map(|(&a, &b)| Matrix::from_fn(a, b, |_, _| rng.normal()))
            .collect();
        let factors_t: Vec<Matrix> = factors.iter().map(|f| f.transpose()).collect();
        let frefs: Vec<&Matrix> = factors.iter().collect();
        let trefs: Vec<&Matrix> = factors_t.iter().collect();
        let (e, f) = (25, 18);
        let rows = random_tensor_index(&mut rng, &dims_a, f);
        let cols = random_tensor_index(&mut rng, &dims_b, e);
        let mut v = rng.normal_vec(e);
        v[3] = 0.0; // exercise the sparse shortcut
        let plan = ChainPlan::build(&rows, &cols, &dims_a, &dims_b).unwrap();
        assert!(!plan.is_kron_delegate());
        assert_eq!(plan.order(), 3);
        let mut ws = GvtWorkspace::new();
        let mut u = vec![f64::NAN; f];
        GvtEngine::serial().apply_chain(&frefs, &trefs, &plan, &v, &mut u, &mut ws, None);
        let want = chain_oracle(&frefs, &rows, &cols, &v);
        assert_allclose(&u, &want, 1e-10, 1e-10);
    }

    #[test]
    fn d4_chain_matches_dense_oracle() {
        let mut rng = Pcg32::seeded(51);
        let dims_a = [2usize, 3, 2, 3];
        let dims_b = [3usize, 2, 4, 2];
        let factors: Vec<Matrix> = dims_a
            .iter()
            .zip(&dims_b)
            .map(|(&a, &b)| Matrix::from_fn(a, b, |_, _| rng.normal()))
            .collect();
        let factors_t: Vec<Matrix> = factors.iter().map(|f| f.transpose()).collect();
        let frefs: Vec<&Matrix> = factors.iter().collect();
        let trefs: Vec<&Matrix> = factors_t.iter().collect();
        let (e, f) = (30, 22);
        let rows = random_tensor_index(&mut rng, &dims_a, f);
        let cols = random_tensor_index(&mut rng, &dims_b, e);
        let v = rng.normal_vec(e);
        let plan = ChainPlan::build(&rows, &cols, &dims_a, &dims_b).unwrap();
        let mut ws = GvtWorkspace::new();
        let mut u = vec![0.0; f];
        GvtEngine::serial().apply_chain(&frefs, &trefs, &plan, &v, &mut u, &mut ws, None);
        assert_allclose(&u, &chain_oracle(&frefs, &rows, &cols, &v), 1e-10, 1e-10);
    }

    #[test]
    fn d2_chain_is_bitwise_the_two_factor_path() {
        let mut rng = Pcg32::seeded(52);
        let (a, b, c, d, e, f) = (7, 9, 6, 8, 4000, 3500);
        let m = Matrix::from_fn(a, b, |_, _| rng.normal());
        let n = Matrix::from_fn(c, d, |_, _| rng.normal());
        let m_t = m.transpose();
        let n_t = n.transpose();
        let rows = KronIndex::new(
            (0..f).map(|_| rng.below(a) as u32).collect(),
            (0..f).map(|_| rng.below(c) as u32).collect(),
        );
        let cols = KronIndex::new(
            (0..e).map(|_| rng.below(b) as u32).collect(),
            (0..e).map(|_| rng.below(d) as u32).collect(),
        );
        let v = rng.normal_vec(e);
        let trows = TensorIndex::from_kron(&rows);
        let tcols = TensorIndex::from_kron(&cols);
        let chain = ChainPlan::build(&trows, &tcols, &[a, c], &[b, d]).unwrap();
        assert!(chain.is_kron_delegate());
        let edge_plan = EdgePlan::build_full(&rows, &cols, a, b, c, d);
        let mut ws = GvtWorkspace::new();
        for threads in [1usize, 2, 4] {
            let engine = GvtEngine::new(threads);
            for branch in [None, Some(Branch::T), Some(Branch::S)] {
                let mut want = vec![0.0; f];
                engine.apply_planned(
                    &m, &n, &m_t, &n_t, &rows, &cols, &edge_plan, &v, &mut want, &mut ws, branch,
                );
                let mut got = vec![f64::NAN; f];
                engine.apply_chain(
                    &[&m, &n],
                    &[&m_t, &n_t],
                    &chain,
                    &v,
                    &mut got,
                    &mut ws,
                    branch,
                );
                assert_eq!(got, want, "threads={threads} branch={branch:?}");
            }
        }
    }

    #[test]
    fn chain_parallel_matches_serial_bitwise() {
        let mut rng = Pcg32::seeded(53);
        let dims_a = [5usize, 4, 3];
        let dims_b = [6usize, 5, 4];
        let factors: Vec<Matrix> = dims_a
            .iter()
            .zip(&dims_b)
            .map(|(&a, &b)| Matrix::from_fn(a, b, |_, _| rng.normal()))
            .collect();
        let factors_t: Vec<Matrix> = factors.iter().map(|f| f.transpose()).collect();
        let frefs: Vec<&Matrix> = factors.iter().collect();
        let trefs: Vec<&Matrix> = factors_t.iter().collect();
        let (e, f) = (4000, 3500);
        let rows = random_tensor_index(&mut rng, &dims_a, f);
        let cols = random_tensor_index(&mut rng, &dims_b, e);
        let mut v = rng.normal_vec(e);
        for (i, vi) in v.iter_mut().enumerate() {
            if i % 7 == 0 {
                *vi = 0.0;
            }
        }
        let plan = ChainPlan::build(&rows, &cols, &dims_a, &dims_b).unwrap();
        let mut ws = GvtWorkspace::new();
        let mut serial = vec![0.0; f];
        GvtEngine::serial().apply_chain(&frefs, &trefs, &plan, &v, &mut serial, &mut ws, None);
        assert_allclose(
            &serial,
            &chain_oracle(&frefs, &rows, &cols, &v),
            1e-10,
            1e-10,
        );
        for threads in [2, 4, 8] {
            let mut par = vec![f64::NAN; f];
            let mut ws2 = GvtWorkspace::new();
            GvtEngine::new(threads)
                .apply_chain(&frefs, &trefs, &plan, &v, &mut par, &mut ws2, None);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn chain_multi_planes_match_single_rhs_bitwise() {
        let mut rng = Pcg32::seeded(54);
        let dims_a = [4usize, 3, 4];
        let dims_b = [5usize, 4, 3];
        let factors: Vec<Matrix> = dims_a
            .iter()
            .zip(&dims_b)
            .map(|(&a, &b)| Matrix::from_fn(a, b, |_, _| rng.normal()))
            .collect();
        let factors_t: Vec<Matrix> = factors.iter().map(|f| f.transpose()).collect();
        let frefs: Vec<&Matrix> = factors.iter().collect();
        let trefs: Vec<&Matrix> = factors_t.iter().collect();
        let (e, f) = (3200, 2600);
        let rows = random_tensor_index(&mut rng, &dims_a, f);
        let cols = random_tensor_index(&mut rng, &dims_b, e);
        let k_rhs = 3;
        let mut v = rng.normal_vec(e * k_rhs);
        for (i, vi) in v.iter_mut().enumerate() {
            if i % 5 == 0 {
                *vi = 0.0; // per-plane zero-skip
            }
        }
        let plan = ChainPlan::build(&rows, &cols, &dims_a, &dims_b).unwrap();
        let mut ws = GvtWorkspace::new();
        // per-plane single-RHS reference (serial)
        let mut singles = vec![0.0; f * k_rhs];
        for j in 0..k_rhs {
            let mut uj = vec![0.0; f];
            GvtEngine::serial().apply_chain(
                &frefs,
                &trefs,
                &plan,
                &v[j * e..(j + 1) * e],
                &mut uj,
                &mut ws,
                None,
            );
            singles[j * f..(j + 1) * f].copy_from_slice(&uj);
        }
        for threads in [1, 2, 4, 8] {
            let mut multi = vec![f64::NAN; f * k_rhs];
            let mut ws2 = GvtWorkspace::new();
            GvtEngine::new(threads).apply_chain_multi(
                &frefs, &trefs, &plan, &v, &mut multi, k_rhs, &mut ws2, None,
            );
            assert_eq!(multi, singles, "threads={threads}");
        }
    }

    #[test]
    fn chain_plan_rejects_bad_inputs() {
        let idx2 = TensorIndex::from_usize(&[&[0], &[0]]);
        let idx3 = TensorIndex::from_usize(&[&[0], &[0], &[0]]);
        // fewer than two factors
        let one = TensorIndex::from_usize(&[&[0]]);
        assert!(ChainPlan::build(&one, &one, &[2], &[2]).is_err());
        // dimension-list length mismatch
        assert!(ChainPlan::build(&idx2, &idx2, &[2, 2], &[2]).is_err());
        // index order mismatch
        assert!(ChainPlan::build(&idx3, &idx2, &[2, 2], &[2, 2]).is_err());
        // zero factor dimension
        assert!(ChainPlan::build(&idx2, &idx2, &[2, 0], &[2, 2]).is_err());
        // out-of-bounds index
        let oob = TensorIndex::from_usize(&[&[5], &[0], &[0]]);
        assert!(ChainPlan::build(&oob, &idx3, &[2, 2, 2], &[2, 2, 2]).is_err());
        // valid D=3 build carries no kron delegate
        let ok = ChainPlan::build(&idx3, &idx3, &[2, 2, 2], &[2, 2, 2]).unwrap();
        assert_eq!((ok.len(), ok.out_len(), ok.order()), (1, 1, 3));
        assert_eq!(ok.dims_a(), &[2, 2, 2]);
        assert_eq!(ok.dims_b(), &[2, 2, 2]);
        assert!(!ok.is_empty());
    }

    #[test]
    fn batch_plan_buckets_are_stable_over_batch_slots() {
        // index: right keys per edge position 0..5 are [2, 0, 2, 1, 0]
        let idx = KronIndex::new(vec![0, 1, 0, 1, 0], vec![2, 0, 2, 1, 0]);
        // batch picks positions [4, 0, 4, 2] — slot keys [0, 2, 0, 2]
        let batch = BatchPlan::build(&idx, &[4, 0, 4, 2], 2, 3);
        assert_eq!(batch.len(), 4);
        assert!(!batch.is_empty());
        assert_eq!(batch.positions(), &[4, 0, 4, 2]);
        assert_eq!(batch.full_len(), 5);
        let (order, offsets) = batch.buckets(Branch::T);
        assert_eq!(offsets, &[0, 2, 2, 4]);
        // bucket 0 holds slots 0, 2 in batch order; bucket 2 holds 1, 3
        assert_eq!(order, &[0, 2, 1, 3]);
    }

    #[test]
    fn restricted_apply_is_a_row_slice_of_the_full_apply() {
        let mut rng = Pcg32::seeded(46);
        let (a, b, c, d, e, f) = (7, 9, 6, 8, 4000, 3500);
        let m = Matrix::from_fn(a, b, |_, _| rng.normal());
        let n = Matrix::from_fn(c, d, |_, _| rng.normal());
        let m_t = m.transpose();
        let n_t = n.transpose();
        let rows = KronIndex::new(
            (0..f).map(|_| rng.below(a) as u32).collect(),
            (0..f).map(|_| rng.below(c) as u32).collect(),
        );
        let cols = KronIndex::new(
            (0..e).map(|_| rng.below(b) as u32).collect(),
            (0..e).map(|_| rng.below(d) as u32).collect(),
        );
        let v = rng.normal_vec(e);
        let plan = EdgePlan::build(&cols, b, d);
        // duplicates and scrambled order on purpose
        let picks: Vec<u32> = (0..600).map(|_| rng.below(f) as u32).collect();
        let batch = BatchPlan::build(&rows, &picks, a, c);
        let mut ws = GvtWorkspace::new();
        for branch in [None, Some(Branch::T), Some(Branch::S)] {
            for threads in [1usize, 2, 4] {
                let engine = GvtEngine::new(threads);
                let mut full = vec![0.0; f];
                engine.apply_planned(
                    &m, &n, &m_t, &n_t, &rows, &cols, &plan, &v, &mut full, &mut ws, branch,
                );
                let mut got = vec![f64::NAN; picks.len()];
                engine.apply_restricted(
                    &m, &n, &m_t, &n_t, &rows, &cols, &plan, &batch, &v, &mut got, &mut ws,
                    branch,
                );
                let want: Vec<f64> = picks.iter().map(|&h| full[h as usize]).collect();
                // bitwise identical, not just close
                assert_eq!(got, want, "threads={threads} branch={branch:?}");
            }
        }
    }

    #[test]
    fn scatter_gather_batches_track_the_full_apply() {
        // Build the dual coefficients incrementally through batched scatters
        // and read values back through batched gathers; the accumulator must
        // track the full planned apply, bitwise-identically across thread
        // counts and numerically against the full pipeline.
        let mut rng = Pcg32::seeded(47);
        let (q, mm) = (9, 7); // G is q×q, K is mm×mm (square training case)
        let g = Matrix::from_fn(q, q, |_, _| rng.normal());
        let k = Matrix::from_fn(mm, mm, |_, _| rng.normal());
        let g_t = g.transpose();
        let k_t = k.transpose();
        let e = 6000; // chunks of 3000 keep the parallel scatter path in play
        let idx = KronIndex::new(
            (0..e).map(|_| rng.below(q) as u32).collect(),
            (0..e).map(|_| rng.below(mm) as u32).collect(),
        );
        let coef = rng.normal_vec(e);
        let plan = EdgePlan::build(&idx, q, mm);
        let all: Vec<u32> = (0..e as u32).collect();
        let mut ws = GvtWorkspace::new();
        for branch in [Branch::T, Branch::S] {
            // branch T scatters Mᵀ = Gᵀ rows into a d×a = mm×q accumulator;
            // branch S scatters Nᵀ = Kᵀ rows into a b×c = q×mm one
            let factor_t = match branch {
                Branch::T => &g_t,
                Branch::S => &k_t,
            };
            let acc_len = mm * q;
            // batched scatters must be bitwise identical serial vs parallel
            let mut accs: Vec<Vec<f64>> = Vec::new();
            for threads in [1usize, 4] {
                let engine = GvtEngine::new(threads);
                let mut acc = vec![0.0; acc_len];
                for chunk in all.chunks(3000) {
                    let batch = BatchPlan::build(&idx, chunk, q, mm);
                    let delta: Vec<f64> = chunk.iter().map(|&l| coef[l as usize]).collect();
                    engine.scatter_batch(factor_t, &idx, &batch, &delta, &mut acc, branch);
                }
                accs.push(acc);
            }
            assert_eq!(accs[0], accs[1], "scatter branch={branch:?} serial vs parallel");
            // gathers over every edge must match the full planned apply
            let mut full = vec![0.0; e];
            GvtEngine::new(4).apply_planned(
                &g, &k, &g_t, &k_t, &idx, &idx, &plan, &coef, &mut full, &mut ws, Some(branch),
            );
            let batch_all = BatchPlan::build(&idx, &all, q, mm);
            for threads in [1usize, 4] {
                let mut got = vec![f64::NAN; e];
                GvtEngine::new(threads)
                    .gather_batch(&g, &k, &idx, &batch_all, &accs[0], &mut got, branch);
                assert_allclose(&got, &full, 1e-10, 1e-10);
            }
        }
    }
}
