//! Multi-threaded execution engine for Algorithm 1 — the [`GvtEngine`].
//!
//! The serial kernels in [`super::algorithm`] already restructure both
//! branches of the generalized vec trick so every inner loop is a contiguous
//! AXPY or dot. This module scales those same loops across cores with
//! std-only scoped threads (mirroring the style of
//! [`crate::coordinator::jobs`]):
//!
//! * **Stage 1** is a scatter-accumulate: edge `l` adds `v_l ·` (a row of
//!   `Mᵀ` or `Nᵀ`) into row `t_l` of `T` (branch T) or row `r_l` of `Sᵀ`
//!   (branch S). Rows are the unit of conflict, so a precomputed
//!   [`EdgePlan`] buckets edges by destination row and each worker owns a
//!   *contiguous, disjoint* range of rows — no locks, no atomics, no
//!   write contention.
//! * The **blocked transpose** between the stages parallelizes by column
//!   blocks: each worker writes a contiguous slab of the destination.
//! * **Stage 2** is embarrassingly parallel over the `f` output edges;
//!   workers take contiguous chunks of `u`.
//!
//! Within a destination row, bucketed edges keep their original order, so
//! every floating-point accumulation happens in exactly the same order as in
//! the serial code — the parallel result is **bitwise identical** to the
//! serial result for every thread count. This is what makes the solvers
//! (CG/MINRES/QMR are famously sensitive to rounding) deterministic under
//! the `threads` knob.

use std::sync::Mutex;

use super::algorithm::{gvt_apply_into, GvtWorkspace};
use super::complexity::{self, Branch};
use super::KronIndex;
use crate::linalg::vecops::{axpy, dot};
use crate::linalg::Matrix;

/// Below this many edges (`e + f`) the engine runs the serial kernels even
/// when more threads are available: spawning scoped workers costs a few
/// microseconds, which dominates tiny matvecs inside inner solver loops.
const MIN_PARALLEL_EDGES: usize = 2048;

/// Precomputed stage-1 bucketing of a column [`KronIndex`] for conflict-free
/// parallel accumulation.
///
/// For branch T, edge `l` accumulates into row `t_l = cols.right[l]` of the
/// `d×a` buffer `T`; for branch S into row `r_l = cols.left[l]` of the `b×c`
/// buffer `Sᵀ`. The plan stores, per branch, a counting-sort of edge ids by
/// destination row (CSR-style `offsets` + `order`), preserving edge order
/// within each bucket so parallel accumulation is bitwise identical to
/// serial. Build once per operator and reuse across matvecs.
#[derive(Debug, Clone)]
pub struct EdgePlan {
    e: usize,
    /// Edge ids grouped by `cols.right` (branch T destination rows, `d` buckets).
    t_order: Vec<u32>,
    /// Bucket boundaries into [`EdgePlan::t_order`], length `d + 1`.
    t_offsets: Vec<usize>,
    /// Edge ids grouped by `cols.left` (branch S destination rows, `b` buckets).
    s_order: Vec<u32>,
    /// Bucket boundaries into [`EdgePlan::s_order`], length `b + 1`.
    s_offsets: Vec<usize>,
}

impl EdgePlan {
    /// Bucket `cols` for both branches. `b` and `d` are the column counts of
    /// the factor matrices `M ∈ R^{a×b}` and `N ∈ R^{c×d}` (so
    /// `cols.left < b`, `cols.right < d`).
    pub fn build(cols: &KronIndex, b: usize, d: usize) -> EdgePlan {
        let (t_order, t_offsets) = bucket_stable(&cols.right, d);
        let (s_order, s_offsets) = bucket_stable(&cols.left, b);
        EdgePlan { e: cols.len(), t_order, t_offsets, s_order, s_offsets }
    }

    /// Number of edges the plan covers (`e`).
    pub fn len(&self) -> usize {
        self.e
    }

    /// Whether the plan covers zero edges.
    pub fn is_empty(&self) -> bool {
        self.e == 0
    }

    /// `(order, offsets)` for the requested branch's stage-1 buckets.
    fn buckets(&self, branch: Branch) -> (&[u32], &[usize]) {
        match branch {
            Branch::T => (&self.t_order, &self.t_offsets),
            Branch::S => (&self.s_order, &self.s_offsets),
        }
    }
}

/// Stable counting sort of edge ids by `keys[l]` into `buckets` buckets.
/// Returns `(order, offsets)` with `offsets.len() == buckets + 1`.
fn bucket_stable(keys: &[u32], buckets: usize) -> (Vec<u32>, Vec<usize>) {
    let mut counts = vec![0usize; buckets + 1];
    for &k in keys {
        counts[k as usize + 1] += 1;
    }
    for i in 0..buckets {
        counts[i + 1] += counts[i];
    }
    let offsets = counts.clone();
    let mut cursor = counts;
    let mut order = vec![0u32; keys.len()];
    for (l, &k) in keys.iter().enumerate() {
        order[cursor[k as usize]] = l as u32;
        cursor[k as usize] += 1;
    }
    (order, offsets)
}

/// Partition bucket rows `0..rows` (where `offsets.len() == rows + 1`) into
/// at most `parts` contiguous, non-empty ranges with approximately equal
/// edge counts. The ranges cover every row exactly once.
fn edge_balanced_chunks(offsets: &[usize], parts: usize) -> Vec<(usize, usize)> {
    let rows = offsets.len() - 1;
    if rows == 0 {
        return Vec::new();
    }
    let total = offsets[rows];
    let parts = parts.clamp(1, rows);
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    for p in 1..=parts {
        let end = if p == parts {
            rows
        } else {
            // smallest row boundary reaching p/parts of the edges
            let target = total * p / parts;
            offsets.partition_point(|&o| o < target).clamp(start, rows)
        };
        if end > start {
            out.push((start, end));
            start = end;
        }
    }
    out
}

/// Split `0..len` into at most `parts` contiguous, non-empty, equal-ish
/// ranges (for stage-2 output chunking and the transpose).
fn even_chunks(len: usize, parts: usize) -> Vec<(usize, usize)> {
    if len == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, len);
    let base = len / parts;
    let rem = len % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let size = base + usize::from(p < rem);
        out.push((start, start + size));
        start += size;
    }
    out
}

/// Parallel blocked out-of-place transpose of a `rows×cols` row-major buffer
/// into a `cols×rows` destination; workers own contiguous column blocks of
/// the source (= row slabs of the destination).
fn transpose_into_parallel(src: &[f64], rows: usize, cols: usize, dst: &mut [f64], threads: usize) {
    debug_assert_eq!(src.len(), rows * cols);
    debug_assert!(dst.len() >= rows * cols);
    const B: usize = 32;
    let ranges = even_chunks(cols, threads);
    if ranges.len() <= 1 {
        super::algorithm::transpose_into(src, rows, cols, dst);
        return;
    }
    std::thread::scope(|scope| {
        let mut rest = &mut dst[..cols * rows];
        for &(j0, j1) in &ranges {
            let (slab, tail) = rest.split_at_mut((j1 - j0) * rows);
            rest = tail;
            scope.spawn(move || {
                for ib in (0..rows).step_by(B) {
                    for jb in (j0..j1).step_by(B) {
                        for i in ib..(ib + B).min(rows) {
                            for j in jb..(jb + B).min(j1) {
                                slab[(j - j0) * rows + i] = src[i * cols + j];
                            }
                        }
                    }
                }
            });
        }
    });
}

/// Multi-threaded executor for the generalized vec trick.
///
/// The engine is a lightweight value (it holds only the worker count);
/// workers are std scoped threads spawned per apply, in the style of
/// [`crate::coordinator::jobs::run_cv_jobs`]. What *is* reused across
/// matvecs are the [`EdgePlan`] (built once per index) and the
/// [`GvtWorkspace`] scratch buffers — the per-apply setup is thread spawn
/// only, a few µs, negligible against the `O(ae + df)` stage work it
/// parallelizes.
#[derive(Debug, Clone, Copy)]
pub struct GvtEngine {
    threads: usize,
}

impl Default for GvtEngine {
    fn default() -> Self {
        GvtEngine::serial()
    }
}

impl GvtEngine {
    /// Engine with an explicit worker count. `0` selects the machine's
    /// available parallelism; `1` always runs the serial kernels.
    pub fn new(threads: usize) -> GvtEngine {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        GvtEngine { threads }
    }

    /// Single-threaded engine (identical to calling the serial kernels).
    pub fn serial() -> GvtEngine {
        GvtEngine { threads: 1 }
    }

    /// Number of worker threads this engine uses.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Computes `u = R(M⊗N)Cᵀv` like
    /// [`gvt_apply_into`](super::algorithm::gvt_apply_into), sharding the
    /// work over the engine's threads using `plan` (which must have been
    /// built from this `cols` index). Falls back to the serial kernels when
    /// one thread is configured or the problem is too small to shard.
    ///
    /// The result is bitwise identical to the serial result for every thread
    /// count (see the module docs).
    #[allow(clippy::too_many_arguments)]
    pub fn apply_planned(
        &self,
        m: &Matrix,
        n: &Matrix,
        m_t: &Matrix,
        n_t: &Matrix,
        rows: &KronIndex,
        cols: &KronIndex,
        plan: &EdgePlan,
        v: &[f64],
        u: &mut [f64],
        ws: &mut GvtWorkspace,
        branch: Option<Branch>,
    ) {
        let (a, b) = (m.rows(), m.cols());
        let (c, d) = (n.rows(), n.cols());
        let e = cols.len();
        let f = rows.len();
        assert_eq!(plan.len(), e, "plan was built for a different column index");
        if self.threads <= 1 || e + f < MIN_PARALLEL_EDGES {
            gvt_apply_into(m, n, m_t, n_t, rows, cols, v, u, ws, branch);
            return;
        }
        assert_eq!(v.len(), e, "v must have length e = |cols|");
        assert_eq!(u.len(), f, "u must have length f = |rows|");
        debug_assert_eq!(m_t.rows(), b);
        debug_assert_eq!(m_t.cols(), a);
        debug_assert_eq!(n_t.rows(), d);
        debug_assert_eq!(n_t.cols(), c);

        let branch = branch.unwrap_or_else(|| complexity::choose_branch(a, b, c, d, e, f));
        let (order, offsets) = plan.buckets(branch);
        let threads = self.threads;
        match branch {
            Branch::T => {
                // Stage 1 (parallel over disjoint rows of T ∈ R^{d×a}):
                //   T[t_l, :] += v_l · Mᵀ[r_l, :]
                let (t_buf, tt_buf) = ws.grab_uncleared(d * a, a * d);
                stage1_parallel(t_buf, a, order, offsets, &cols.left, m_t, v, threads);
                // Tᵀ is a×d: row p_h is column p_h of T.
                transpose_into_parallel(t_buf, d, a, tt_buf, threads);
                // Stage 2 (parallel over chunks of u): u_h = N[q_h,:]·Tᵀ[p_h,:]
                let tt = &tt_buf[..a * d];
                stage2_parallel(u, &rows.left, &rows.right, threads, |p, q| {
                    dot(n.row(q), &tt[p * d..(p + 1) * d])
                });
            }
            Branch::S => {
                // Stage 1 (parallel over disjoint rows of Sᵀ ∈ R^{b×c}):
                //   Sᵀ[r_l, :] += v_l · Nᵀ[t_l, :]
                let (st_buf, s_buf) = ws.grab_uncleared(b * c, c * b);
                stage1_parallel(st_buf, c, order, offsets, &cols.right, n_t, v, threads);
                // S is c×b.
                transpose_into_parallel(st_buf, b, c, s_buf, threads);
                // Stage 2: u_h = S[q_h, :] · M[p_h, :]
                let s = &s_buf[..c * b];
                stage2_parallel(u, &rows.left, &rows.right, threads, |p, q| {
                    dot(&s[q * b..(q + 1) * b], m.row(p))
                });
            }
        }
    }
}

/// Stage 1 worker fan-out: each scoped thread owns a contiguous range of
/// destination rows of the `rows×width` accumulator `buf` (zeroing it before
/// accumulating, so callers must *not* pre-clear), and replays its buckets'
/// edges in original order. `gather` maps an edge id to the source row of
/// `factor_t` to scale-add.
#[allow(clippy::too_many_arguments)]
fn stage1_parallel(
    buf: &mut [f64],
    width: usize,
    order: &[u32],
    offsets: &[usize],
    gather: &[u32],
    factor_t: &Matrix,
    v: &[f64],
    threads: usize,
) {
    let rows = offsets.len() - 1;
    debug_assert!(buf.len() >= rows * width);
    let ranges = edge_balanced_chunks(offsets, threads);
    std::thread::scope(|scope| {
        let mut rest = &mut buf[..rows * width];
        for &(r0, r1) in &ranges {
            let (slab, tail) = rest.split_at_mut((r1 - r0) * width);
            rest = tail;
            scope.spawn(move || {
                slab.fill(0.0);
                for row in r0..r1 {
                    let dst = &mut slab[(row - r0) * width..(row - r0 + 1) * width];
                    for &l in &order[offsets[row]..offsets[row + 1]] {
                        let vl = v[l as usize];
                        if vl == 0.0 {
                            continue;
                        }
                        axpy(vl, factor_t.row(gather[l as usize] as usize), dst);
                    }
                }
            });
        }
    });
}

/// Stage 2 fan-out: contiguous chunks of `u`, each worker evaluating
/// `score(p_h, q_h)` for its edges against the shared stage-1 result.
fn stage2_parallel(
    u: &mut [f64],
    left: &[u32],
    right: &[u32],
    threads: usize,
    score: impl Fn(usize, usize) -> f64 + Sync,
) {
    let f = u.len();
    let ranges = even_chunks(f, threads);
    let score = &score;
    std::thread::scope(|scope| {
        let mut rest = u;
        for &(h0, h1) in &ranges {
            let (chunk, tail) = rest.split_at_mut(h1 - h0);
            rest = tail;
            scope.spawn(move || {
                for (i, uh) in chunk.iter_mut().enumerate() {
                    let h = h0 + i;
                    *uh = score(left[h] as usize, right[h] as usize);
                }
            });
        }
    });
}

/// Lock-protected stack of [`GvtWorkspace`] scratch buffers.
///
/// The GVT operators hand one workspace to each in-flight apply, so a single
/// trained operator can serve concurrent callers (`Sync`) without sharing
/// accumulation buffers. The lock is held only to pop/push a workspace, never
/// during the matvec itself.
#[derive(Debug, Default)]
pub struct WorkspacePool {
    free: Mutex<Vec<GvtWorkspace>>,
}

impl WorkspacePool {
    /// Empty pool; workspaces are created on demand and recycled.
    pub fn new() -> WorkspacePool {
        WorkspacePool::default()
    }

    /// Run `f` with a pooled workspace, returning the workspace to the pool
    /// afterwards.
    pub fn with<R>(&self, f: impl FnOnce(&mut GvtWorkspace) -> R) -> R {
        let mut ws = self
            .free
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
            .pop()
            .unwrap_or_default();
        let out = f(&mut ws);
        self.free.lock().unwrap_or_else(|poisoned| poisoned.into_inner()).push(ws);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::assert_allclose;
    use crate::util::rng::Pcg32;

    #[test]
    fn bucket_stable_preserves_order() {
        let keys = vec![2u32, 0, 2, 1, 0, 2];
        let (order, offsets) = bucket_stable(&keys, 3);
        assert_eq!(offsets, vec![0, 2, 3, 6]);
        // bucket 0 holds edges 1, 4 in original order; bucket 2 holds 0, 2, 5
        assert_eq!(&order[0..2], &[1, 4]);
        assert_eq!(&order[2..3], &[3]);
        assert_eq!(&order[3..6], &[0, 2, 5]);
    }

    #[test]
    fn edge_balanced_chunks_cover_all_rows() {
        // offsets for 6 rows with very skewed bucket sizes
        let offsets = vec![0usize, 100, 100, 100, 101, 150, 200];
        for parts in 1..=8 {
            let chunks = edge_balanced_chunks(&offsets, parts);
            assert!(!chunks.is_empty());
            assert_eq!(chunks[0].0, 0);
            assert_eq!(chunks.last().unwrap().1, 6);
            for w in chunks.windows(2) {
                assert_eq!(w[0].1, w[1].0, "ranges must be contiguous");
                assert!(w[0].0 < w[0].1, "ranges must be non-empty");
            }
        }
    }

    #[test]
    fn even_chunks_partition() {
        assert_eq!(even_chunks(0, 4), vec![]);
        assert_eq!(even_chunks(3, 8), vec![(0, 1), (1, 2), (2, 3)]);
        let c = even_chunks(10, 3);
        assert_eq!(c, vec![(0, 4), (4, 7), (7, 10)]);
    }

    #[test]
    fn parallel_transpose_matches_serial() {
        let mut rng = Pcg32::seeded(42);
        for &(rows, cols) in &[(1usize, 1usize), (5, 97), (64, 64), (33, 7)] {
            let src: Vec<f64> = (0..rows * cols).map(|_| rng.normal()).collect();
            let mut serial = vec![0.0; rows * cols];
            transpose_into_parallel(&src, rows, cols, &mut serial, 1);
            for threads in [2, 3, 8] {
                let mut par = vec![0.0; rows * cols];
                transpose_into_parallel(&src, rows, cols, &mut par, threads);
                assert_eq!(serial, par, "{rows}x{cols} @ {threads} threads");
            }
            // spot-check correctness against the definition
            for i in 0..rows {
                for j in 0..cols {
                    assert_eq!(serial[j * rows + i], src[i * cols + j]);
                }
            }
        }
    }

    #[test]
    fn engine_matches_serial_apply() {
        let mut rng = Pcg32::seeded(43);
        let (a, b, c, d, e, f) = (7, 9, 6, 8, 4000, 3500);
        let m = Matrix::from_fn(a, b, |_, _| rng.normal());
        let n = Matrix::from_fn(c, d, |_, _| rng.normal());
        let m_t = m.transpose();
        let n_t = n.transpose();
        let rows = KronIndex::new(
            (0..f).map(|_| rng.below(a) as u32).collect(),
            (0..f).map(|_| rng.below(c) as u32).collect(),
        );
        let cols = KronIndex::new(
            (0..e).map(|_| rng.below(b) as u32).collect(),
            (0..e).map(|_| rng.below(d) as u32).collect(),
        );
        let v = rng.normal_vec(e);
        let plan = EdgePlan::build(&cols, b, d);

        let mut ws = GvtWorkspace::new();
        let mut serial = vec![0.0; f];
        gvt_apply_into(&m, &n, &m_t, &n_t, &rows, &cols, &v, &mut serial, &mut ws, None);
        for threads in [2, 4, 8] {
            let engine = GvtEngine::new(threads);
            let mut par = vec![0.0; f];
            let mut ws2 = GvtWorkspace::new();
            engine.apply_planned(
                &m, &n, &m_t, &n_t, &rows, &cols, &plan, &v, &mut par, &mut ws2, None,
            );
            // bitwise identical, not just close
            assert_eq!(serial, par, "threads={threads}");
        }
        // and both branches individually
        for branch in [Branch::T, Branch::S] {
            let mut sref = vec![0.0; f];
            gvt_apply_into(&m, &n, &m_t, &n_t, &rows, &cols, &v, &mut sref, &mut ws, Some(branch));
            let mut par = vec![0.0; f];
            GvtEngine::new(4).apply_planned(
                &m, &n, &m_t, &n_t, &rows, &cols, &plan, &v, &mut par, &mut ws, Some(branch),
            );
            assert_allclose(&par, &sref, 0.0, 0.0);
        }
    }

    #[test]
    fn engine_zero_threads_autodetects() {
        assert!(GvtEngine::new(0).threads() >= 1);
        assert_eq!(GvtEngine::serial().threads(), 1);
    }

    #[test]
    fn workspace_pool_recycles() {
        let pool = WorkspacePool::new();
        pool.with(|ws| {
            let (s, _) = ws.grab_uncleared(16, 16);
            s.fill(1.0);
        });
        // same workspace comes back; buffers are reused (and re-zeroed by
        // grab in the serial path, or by workers in the parallel path)
        pool.with(|ws| {
            let (s, _) = ws.grab_uncleared(16, 16);
            assert_eq!(s.len(), 16);
        });
    }
}
