//! The **generalized vec trick** (Algorithm 1 of the paper): compute
//!
//! ```text
//! u = R (M ⊗ N) Cᵀ v
//! ```
//!
//! in `O(min(ae + df, ce + bf))` time, where `M ∈ R^{a×b}`, `N ∈ R^{c×d}`,
//! `R ∈ {0,1}^{f×ac}` is a row index matrix encoded by sequences
//! `p ∈ [a]^f`, `q ∈ [c]^f`, and `C ∈ {0,1}^{e×bd}` a column index matrix
//! encoded by `r ∈ [b]^e`, `t ∈ [d]^e` (Lemma 2). Elementwise,
//!
//! ```text
//! u_h = Σ_l  M[p_h, r_l] · N[q_h, t_l] · v_l .
//! ```
//!
//! Submodules:
//! * [`algorithm`] — the two branches of Algorithm 1 (cache-transposed
//!   layouts), automatic branch selection, zero-skipping for sparse `v`.
//! * [`engine`] — the multi-threaded execution engine ([`GvtEngine`]) with
//!   conflict-free stage-1 sharding via a precomputed [`EdgePlan`];
//!   bitwise-deterministic for every thread count.
//! * [`operator`] — [`LinOp`](crate::linalg::LinOp) wrappers: the training
//!   kernel operator `R(G⊗K)Rᵀ`, Newton-system operators, prediction.
//!   All operators are `Sync` and carry a `threads` knob.
//! * [`pairwise`] — the **pairwise kernel operator family**
//!   ([`PairwiseOp`]): Kronecker, symmetric, anti-symmetric, and Cartesian
//!   pairwise kernels, each composed from one or two planned GVT applies without
//!   ever materializing the pairwise kernel matrix.
//! * [`dense`] — the scatter→GEMM→gather formulation used by the TPU/PJRT
//!   path (see DESIGN.md §Hardware-Adaptation) as a native reference.
//! * [`explicit`] — materialized baseline (`R(M⊗N)Cᵀ` built explicitly);
//!   what the paper calls "Baseline" in Tables 3–4. Tests and benches only.
//! * [`complexity`] — the flop model that drives branch choice and the
//!   coordinator's native-vs-PJRT routing.

pub mod algorithm;
pub mod engine;
pub mod operator;
pub mod pairwise;
pub mod tensor;
pub mod dense;
pub mod explicit;
pub mod complexity;

pub use algorithm::{
    gvt_apply, gvt_apply_into, gvt_apply_into_parallel, gvt_apply_multi_into, Branch, GvtWorkspace,
};
pub use engine::{BatchPlan, ChainPlan, EdgePlan, GvtEngine, WorkspacePool};
pub use operator::{
    KronKernelOp, KronPredictOp, KronSpectralPrecond, SvmNewtonOp, TensorKernelOp, TensorPredictOp,
};
pub use pairwise::{delta_matrix, PairwiseKernelKind, PairwiseOp, PairwiseShared};
pub use tensor::TensorIndex;
pub use complexity::{branch_costs, choose_branch};

/// Index sequences `(p, q)` (or `(r, t)`) selecting rows (or columns) of a
/// Kronecker product `M ⊗ N` by factor-matrix indices (Lemma 2). 0-based.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KronIndex {
    /// Index into the *left* factor (`M`): `p` (rows) or `r` (columns).
    pub left: Vec<u32>,
    /// Index into the *right* factor (`N`): `q` (rows) or `t` (columns).
    pub right: Vec<u32>,
}

impl KronIndex {
    /// Construct, validating lengths match.
    pub fn new(left: Vec<u32>, right: Vec<u32>) -> KronIndex {
        assert_eq!(left.len(), right.len(), "index sequences must have equal length");
        KronIndex { left, right }
    }

    /// Construct from usize slices (convenience).
    pub fn from_usize(left: &[usize], right: &[usize]) -> KronIndex {
        KronIndex::new(
            left.iter().map(|&i| i as u32).collect(),
            right.iter().map(|&i| i as u32).collect(),
        )
    }

    /// Number of indexed rows/columns (`f` or `e` in the paper).
    pub fn len(&self) -> usize {
        self.left.len()
    }

    /// Whether the index selects zero rows/columns.
    pub fn is_empty(&self) -> bool {
        self.left.is_empty()
    }

    /// Check all indices are in-bounds for factor dimensions
    /// (`left < dim_left`, `right < dim_right`).
    pub fn validate(&self, dim_left: usize, dim_right: usize) -> Result<(), String> {
        for (h, (&l, &r)) in self.left.iter().zip(&self.right).enumerate() {
            if l as usize >= dim_left {
                return Err(format!("index {h}: left {l} out of bounds ({dim_left})"));
            }
            if r as usize >= dim_right {
                return Err(format!("index {h}: right {r} out of bounds ({dim_right})"));
            }
        }
        Ok(())
    }

    /// Whether the sequences are surjective onto `[0, dim_left) × [0, dim_right)`
    /// *separately* (the assumption of Theorem 1; the algorithm works without
    /// it but the complexity statement needs it).
    pub fn is_surjective(&self, dim_left: usize, dim_right: usize) -> bool {
        let mut seen_l = vec![false; dim_left];
        let mut seen_r = vec![false; dim_right];
        for (&l, &r) in self.left.iter().zip(&self.right) {
            seen_l[l as usize] = true;
            seen_r[r as usize] = true;
        }
        seen_l.iter().all(|&s| s) && seen_r.iter().all(|&s| s)
    }

    /// The flat row index `(left·dim_right + right)` of each pair in the
    /// Kronecker product (row-major pair ordering, Lemma 2 with 0-base).
    ///
    /// Uses checked arithmetic: a grid large enough that `left·dim_right +
    /// right` wraps `usize` would silently alias unrelated cells, so
    /// overflow panics with an explicit message instead (mirroring the
    /// artifact-load dimension guard).
    pub fn flat(&self, dim_right: usize) -> Vec<usize> {
        self.left
            .iter()
            .zip(&self.right)
            .enumerate()
            .map(|(h, (&l, &r))| {
                (l as usize)
                    .checked_mul(dim_right)
                    .and_then(|base| base.checked_add(r as usize))
                    .unwrap_or_else(|| {
                        panic!(
                            "flat index overflow at edge {h}: left {l} × dim_right {dim_right} \
                             + right {r} exceeds usize"
                        )
                    })
            })
            .collect()
    }

    /// If this index enumerates the **complete graph** `[0, dim_left) ×
    /// [0, dim_right)` — every pair exactly once, in any order — return the
    /// layout mapping each flat grid cell `left·dim_right + right` to the
    /// edge position `h` that covers it. Otherwise (duplicates, missing
    /// cells, out-of-bounds indices, or the wrong edge count) return `None`.
    ///
    /// A `Some` layout is exactly the condition under which `R` in
    /// `Q = R(G⊗K)Rᵀ` is a permutation, which is what unlocks the
    /// eigendecomposition fast paths in [`crate::train::ridge`].
    pub fn complete_layout(&self, dim_left: usize, dim_right: usize) -> Option<Vec<u32>> {
        let total = dim_left.checked_mul(dim_right)?;
        if total == 0 || self.len() != total || total > u32::MAX as usize {
            return None;
        }
        let mut layout = vec![u32::MAX; total];
        for (h, (&l, &r)) in self.left.iter().zip(&self.right).enumerate() {
            if l as usize >= dim_left || r as usize >= dim_right {
                return None;
            }
            let pos = l as usize * dim_right + r as usize;
            if layout[pos] != u32::MAX {
                return None; // duplicate edge
            }
            layout[pos] = h as u32;
        }
        // len == total and no duplicates ⇒ every cell is covered (pigeonhole).
        Some(layout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kron_index_basics() {
        let idx = KronIndex::from_usize(&[0, 1, 2], &[1, 0, 1]);
        assert_eq!(idx.len(), 3);
        assert!(idx.validate(3, 2).is_ok());
        assert!(idx.validate(2, 2).is_err());
        assert!(idx.is_surjective(3, 2));
        assert!(!idx.is_surjective(4, 2));
        assert_eq!(idx.flat(2), vec![1, 2, 5]);
    }

    #[test]
    #[should_panic]
    fn mismatched_lengths_panic() {
        KronIndex::new(vec![0, 1], vec![0]);
    }

    #[test]
    #[should_panic(expected = "flat index overflow")]
    fn flat_overflow_panics_with_message() {
        let idx = KronIndex::from_usize(&[2], &[0]);
        let _ = idx.flat(usize::MAX);
    }

    #[test]
    fn complete_layout_accepts_any_enumeration_order() {
        // 2×3 grid enumerated in a scrambled order.
        let idx = KronIndex::from_usize(&[1, 0, 0, 1, 0, 1], &[2, 0, 2, 1, 1, 0]);
        let layout = idx.complete_layout(2, 3).expect("complete");
        // layout[l*3 + r] = h such that (left[h], right[h]) = (l, r)
        assert_eq!(layout, vec![1, 4, 2, 5, 3, 0]);
        for (h, (&l, &r)) in idx.left.iter().zip(&idx.right).enumerate() {
            assert_eq!(layout[l as usize * 3 + r as usize] as usize, h);
        }
    }

    #[test]
    fn complete_layout_rejects_incomplete_or_invalid_indices() {
        // Duplicate edge (0,0) + missing (1,1).
        let dup = KronIndex::from_usize(&[0, 0, 1, 0], &[0, 0, 0, 1]);
        assert!(dup.complete_layout(2, 2).is_none());
        // Wrong edge count.
        let short = KronIndex::from_usize(&[0, 1], &[0, 1]);
        assert!(short.complete_layout(2, 2).is_none());
        // Out-of-bounds index.
        let oob = KronIndex::from_usize(&[0, 0, 1, 5], &[0, 1, 0, 1]);
        assert!(oob.complete_layout(2, 2).is_none());
        // Empty grid is never "complete".
        let empty = KronIndex::from_usize(&[], &[]);
        assert!(empty.complete_layout(0, 0).is_none());
        // Complete 2×2 sanity check.
        let full = KronIndex::from_usize(&[0, 0, 1, 1], &[0, 1, 0, 1]);
        assert_eq!(full.complete_layout(2, 2), Some(vec![0, 1, 2, 3]));
    }
}
