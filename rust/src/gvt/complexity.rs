//! Flop-count model for Algorithm 1 (Theorem 1) and branch selection.
//!
//! Branch **T** (lines 2–11): build `T = V·Mᵀ ∈ R^{d×a}` (cost `a·e`), then
//! `u_h = N[q_h,:] · T[:,p_h]` (cost `d·f`)  →  total `a·e + d·f`.
//!
//! Branch **S** (lines 13–22): build `S = N·V ∈ R^{c×b}` (cost `c·e`), then
//! `u_h = S[q_h,:] · M[p_h,:]` (cost `b·f`)  →  total `c·e + b·f`.
//!
//! The same model (extended with a GEMM term) is what the coordinator's
//! router uses to choose between the native loops and the PJRT dense path.

/// Which branch of Algorithm 1 to execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Branch {
    /// `T = V Mᵀ` first (condition `ae + df < ce + bf` true; lines 2–11).
    T,
    /// `S = N V` first (lines 13–22).
    S,
}

/// `(cost_T, cost_S) = (a·e + d·f, c·e + b·f)` from Theorem 1.
pub fn branch_costs(a: usize, b: usize, c: usize, d: usize, e: usize, f: usize) -> (u128, u128) {
    let _ = b; // b enters only cost_S
    let cost_t = a as u128 * e as u128 + d as u128 * f as u128;
    let cost_s = c as u128 * e as u128 + b as u128 * f as u128;
    (cost_t, cost_s)
}

/// Pick the cheaper branch (the `if` on line 1 of Algorithm 1).
pub fn choose_branch(a: usize, b: usize, c: usize, d: usize, e: usize, f: usize) -> Branch {
    let (t, s) = branch_costs(a, b, c, d, e, f);
    if t < s {
        Branch::T
    } else {
        Branch::S
    }
}

/// Cost of the explicit baseline: materializing the `f×e` submatrix costs
/// `f·e` kernel evaluations (each O(1) given M, N) and the matvec `f·e`.
pub fn explicit_cost(e: usize, f: usize) -> u128 {
    2 * (e as u128) * (f as u128)
}

/// Cost of the dense scatter→GEMM→gather path (DESIGN.md
/// §Hardware-Adaptation): scatter `e`, GEMM `a·d·(b+?)`… for the square
/// training case (`M: q×q`, `N: m×m`) this is `e + m·q·(m+q) + f`.
pub fn dense_path_cost(a: usize, b: usize, c: usize, d: usize, e: usize, f: usize) -> u128 {
    // V is d×b; K V costs c·d·b; (N V) Mᵀ costs c·b·a.
    e as u128 + (c as u128 * d as u128 * b as u128) + (c as u128 * b as u128 * a as u128) + f as u128
}

/// Theorem 1 cost of the chosen branch.
pub fn gvt_cost(a: usize, b: usize, c: usize, d: usize, e: usize, f: usize) -> u128 {
    let (t, s) = branch_costs(a, b, c, d, e, f);
    t.min(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn branch_choice_follows_costs() {
        // cost_T = a·e + d·f, cost_S = c·e + b·f.
        // (a=1000, d=1000) → T expensive; (b=10, c=10) → S cheap.
        assert_eq!(choose_branch(1000, 10, 10, 1000, 500, 500), Branch::S);
        // (a=10, d=10) → T cheap; (b=1000, c=1000) → S expensive.
        assert_eq!(choose_branch(10, 1000, 1000, 10, 500, 500), Branch::T);
    }

    #[test]
    fn square_case_is_symmetric() {
        // In the training case M: q×q, N: m×m, e=f=n → costs are (qn+mn, mn+qn): equal.
        let (t, s) = branch_costs(50, 50, 80, 80, 1000, 1000);
        assert_eq!(t, 50 * 1000 + 80 * 1000);
        assert_eq!(s, 80 * 1000 + 50 * 1000);
        assert_eq!(t, s);
    }

    #[test]
    fn gvt_beats_explicit_in_dependent_regime() {
        // Dependent regime: n=10_000 edges over m=q=200 vertices.
        let (m, q, n) = (200usize, 200usize, 10_000usize);
        assert!(gvt_cost(q, q, m, m, n, n) < explicit_cost(n, n));
    }

    #[test]
    fn independent_regime_matches_baseline_asymptotics() {
        // n=m=q: gvt cost = 2n², explicit = 2n² — same order (Table 3 row 1).
        let n = 500usize;
        let g = gvt_cost(n, n, n, n, n, n);
        let e = explicit_cost(n, n);
        assert_eq!(g, e);
    }

    #[test]
    fn dense_path_wins_only_when_dense() {
        let (m, q) = (128usize, 128usize);
        let sparse_n = 500;
        let dense_n = m * q;
        assert!(
            gvt_cost(q, q, m, m, sparse_n, sparse_n)
                < dense_path_cost(q, q, m, m, sparse_n, sparse_n)
        );
        // At complete-graph density the two are the same order.
        let gvt = gvt_cost(q, q, m, m, dense_n, dense_n) as f64;
        let dense = dense_path_cost(q, q, m, m, dense_n, dense_n) as f64;
        assert!(dense / gvt < 2.0, "dense={dense}, gvt={gvt}");
    }
}
