//! Conjugate gradient method for SPD systems (Hestenes–Stiefel).

use super::{LinOp, SolveStats, SolverConfig};
use crate::linalg::vecops::{axpby, axpy, dot, norm2};

/// Solve `A x = b` for SPD `A`, starting from `x` (commonly zeros).
/// `x` is updated in place; returns solve statistics.
pub fn cg(a: &dyn LinOp, b: &[f64], x: &mut [f64], cfg: &SolverConfig) -> SolveStats {
    cg_cb(a, b, x, cfg, None)
}

/// [`cg`] with an optional per-iteration monitor (used by the convergence
/// experiments of Figs. 3–5 to trace risk/AUC against iteration count).
pub fn cg_cb(
    a: &dyn LinOp,
    b: &[f64],
    x: &mut [f64],
    cfg: &SolverConfig,
    mut monitor: Option<super::IterMonitor<'_>>,
) -> SolveStats {
    let n = a.dim();
    assert_eq!(b.len(), n);
    assert_eq!(x.len(), n);

    let b_norm = norm2(b);
    if b_norm == 0.0 {
        x.iter_mut().for_each(|v| *v = 0.0);
        return SolveStats { iterations: 0, residual_norm: 0.0, converged: true };
    }
    let tol_abs = cfg.tol * b_norm;

    // r = b - A x
    let mut r = vec![0.0; n];
    a.apply(x, &mut r);
    for i in 0..n {
        r[i] = b[i] - r[i];
    }
    let mut p = r.clone();
    let mut ap = vec![0.0; n];
    let mut rs_old = dot(&r, &r);

    let mut iters = 0;
    while iters < cfg.max_iters {
        if rs_old.sqrt() <= tol_abs {
            return SolveStats { iterations: iters, residual_norm: rs_old.sqrt(), converged: true };
        }
        a.apply(&p, &mut ap);
        let pap = dot(&p, &ap);
        if pap <= 0.0 {
            // not SPD (or numerical breakdown) — stop with current iterate
            break;
        }
        let alpha = rs_old / pap;
        axpy(alpha, &p, x);
        axpy(-alpha, &ap, &mut r);
        let rs_new = dot(&r, &r);
        axpby(1.0, &r, rs_new / rs_old, &mut p);
        rs_old = rs_new;
        iters += 1;
        if let Some(mon) = monitor.as_mut() {
            if !mon(iters, x) {
                break;
            }
        }
    }
    SolveStats {
        iterations: iters,
        residual_norm: rs_old.sqrt(),
        converged: rs_old.sqrt() <= tol_abs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::solvers::testutil::spd_system;
    use crate::linalg::vecops::assert_allclose;
    use crate::util::rng::Pcg32;

    #[test]
    fn solves_spd() {
        let mut rng = Pcg32::seeded(10);
        let (a, b, x_true) = spd_system(&mut rng, 40);
        let mut x = vec![0.0; 40];
        let stats = cg(&a, &b, &mut x, &SolverConfig::default());
        assert!(stats.converged, "residual={}", stats.residual_norm);
        assert_allclose(&x, &x_true, 1e-6, 1e-6);
    }

    #[test]
    fn zero_rhs() {
        let mut rng = Pcg32::seeded(11);
        let (a, _, _) = spd_system(&mut rng, 8);
        let mut x = vec![1.0; 8];
        let stats = cg(&a, &vec![0.0; 8], &mut x, &SolverConfig::default());
        assert!(stats.converged);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn respects_iteration_cap() {
        let mut rng = Pcg32::seeded(12);
        let (a, b, _) = spd_system(&mut rng, 60);
        let mut x = vec![0.0; 60];
        let stats = cg(&a, &b, &mut x, &SolverConfig { max_iters: 3, tol: 1e-14 });
        assert!(stats.iterations <= 3);
    }

    #[test]
    fn warm_start_improves() {
        let mut rng = Pcg32::seeded(13);
        let (a, b, x_true) = spd_system(&mut rng, 30);
        let mut x_cold = vec![0.0; 30];
        let cold = cg(&a, &b, &mut x_cold, &SolverConfig { max_iters: 2, tol: 1e-16 });
        let mut x_warm = x_true.iter().map(|v| v * 0.999).collect::<Vec<_>>();
        let warm = cg(&a, &b, &mut x_warm, &SolverConfig { max_iters: 2, tol: 1e-16 });
        assert!(warm.residual_norm < cold.residual_norm);
    }
}
