//! Conjugate gradient method for SPD systems (Hestenes–Stiefel), plain and
//! preconditioned.

use super::{LinOp, Preconditioner, SolveStats, SolverConfig, Stopping};
use crate::linalg::vecops::{axpby, axpy, dot, norm2};

/// Solve `A x = b` for SPD `A`, starting from `x` (commonly zeros).
/// `x` is updated in place; returns solve statistics.
pub fn cg(a: &dyn LinOp, b: &[f64], x: &mut [f64], cfg: &SolverConfig) -> SolveStats {
    cg_cb(a, b, x, cfg, None)
}

/// [`cg`] with an optional per-iteration monitor (used by the convergence
/// experiments of Figs. 3–5 to trace risk/AUC against iteration count).
pub fn cg_cb(
    a: &dyn LinOp,
    b: &[f64],
    x: &mut [f64],
    cfg: &SolverConfig,
    mut monitor: Option<super::IterMonitor<'_>>,
) -> SolveStats {
    let n = a.dim();
    assert_eq!(b.len(), n);
    assert_eq!(x.len(), n);

    let stop = Stopping::new(cfg, b);
    if stop.zero_rhs() {
        return Stopping::zero_solution(x);
    }

    // r = b - A x
    let mut r = vec![0.0; n];
    a.apply(x, &mut r);
    for i in 0..n {
        r[i] = b[i] - r[i];
    }
    let mut p = r.clone();
    let mut ap = vec![0.0; n];
    let mut rs_old = dot(&r, &r);

    let mut iters = 0;
    while iters < cfg.max_iters {
        if stop.converged(rs_old.sqrt()) {
            return SolveStats { iterations: iters, residual_norm: rs_old.sqrt(), converged: true };
        }
        a.apply(&p, &mut ap);
        let pap = dot(&p, &ap);
        if pap <= 0.0 {
            // not SPD (or numerical breakdown) — stop with current iterate
            break;
        }
        let alpha = rs_old / pap;
        axpy(alpha, &p, x);
        axpy(-alpha, &ap, &mut r);
        let rs_new = dot(&r, &r);
        axpby(1.0, &r, rs_new / rs_old, &mut p);
        rs_old = rs_new;
        iters += 1;
        if let Some(mon) = monitor.as_mut() {
            if !mon(iters, x) {
                break;
            }
        }
    }
    SolveStats {
        iterations: iters,
        residual_norm: rs_old.sqrt(),
        converged: stop.converged(rs_old.sqrt()),
    }
}

/// Preconditioned conjugate gradient: solve `A x = b` for SPD `A` with an
/// SPD preconditioner `M ≈ A⁻¹` applied as `z ← M r` each iteration.
///
/// With [`super::IdentityPrecond`] this retraces plain [`cg`] **bitwise**
/// (`z = r` makes every dot product and update identical, since
/// `‖r‖ = √(r·r)` uses the same reduction), so the preconditioned path can
/// never silently diverge from the plain one. With the exact inverse
/// (`M = A⁻¹`) it converges in one iteration.
pub fn pcg(
    a: &dyn LinOp,
    b: &[f64],
    x: &mut [f64],
    m: &dyn Preconditioner,
    cfg: &SolverConfig,
) -> SolveStats {
    pcg_cb(a, b, x, m, cfg, None)
}

/// [`pcg`] with an optional per-iteration monitor.
pub fn pcg_cb(
    a: &dyn LinOp,
    b: &[f64],
    x: &mut [f64],
    m: &dyn Preconditioner,
    cfg: &SolverConfig,
    mut monitor: Option<super::IterMonitor<'_>>,
) -> SolveStats {
    let n = a.dim();
    assert_eq!(b.len(), n);
    assert_eq!(x.len(), n);
    assert_eq!(m.dim(), n, "preconditioner dimension mismatch");

    let stop = Stopping::new(cfg, b);
    if stop.zero_rhs() {
        return Stopping::zero_solution(x);
    }

    // r = b - A x
    let mut r = vec![0.0; n];
    a.apply(x, &mut r);
    for i in 0..n {
        r[i] = b[i] - r[i];
    }
    let mut z = vec![0.0; n];
    m.apply(&r, &mut z);
    let mut p = z.clone();
    let mut ap = vec![0.0; n];
    let mut rz_old = dot(&r, &z);
    let mut r_norm = norm2(&r);

    let mut iters = 0;
    while iters < cfg.max_iters {
        if stop.converged(r_norm) {
            return SolveStats { iterations: iters, residual_norm: r_norm, converged: true };
        }
        a.apply(&p, &mut ap);
        let pap = dot(&p, &ap);
        if pap <= 0.0 {
            // not SPD (or numerical breakdown) — stop with current iterate
            break;
        }
        let alpha = rz_old / pap;
        axpy(alpha, &p, x);
        axpy(-alpha, &ap, &mut r);
        r_norm = norm2(&r);
        m.apply(&r, &mut z);
        let rz_new = dot(&r, &z);
        if rz_new <= 0.0 && !stop.converged(r_norm) {
            // M lost positive-definiteness numerically — stop with current
            // iterate rather than dividing by a nonpositive rz.
            iters += 1;
            break;
        }
        axpby(1.0, &z, rz_new / rz_old, &mut p);
        rz_old = rz_new;
        iters += 1;
        if let Some(mon) = monitor.as_mut() {
            if !mon(iters, x) {
                break;
            }
        }
    }
    SolveStats { iterations: iters, residual_norm: r_norm, converged: stop.converged(r_norm) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::solvers::testutil::spd_system;
    use crate::linalg::solvers::{IdentityPrecond, JacobiPrecond};
    use crate::linalg::vecops::assert_allclose;
    use crate::linalg::Matrix;
    use crate::util::rng::Pcg32;

    #[test]
    fn solves_spd() {
        let mut rng = Pcg32::seeded(10);
        let (a, b, x_true) = spd_system(&mut rng, 40);
        let mut x = vec![0.0; 40];
        let stats = cg(&a, &b, &mut x, &SolverConfig::default());
        assert!(stats.converged, "residual={}", stats.residual_norm);
        assert_allclose(&x, &x_true, 1e-6, 1e-6);
    }

    #[test]
    fn zero_rhs() {
        let mut rng = Pcg32::seeded(11);
        let (a, _, _) = spd_system(&mut rng, 8);
        let mut x = vec![1.0; 8];
        let stats = cg(&a, &vec![0.0; 8], &mut x, &SolverConfig::default());
        assert!(stats.converged);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn respects_iteration_cap() {
        let mut rng = Pcg32::seeded(12);
        let (a, b, _) = spd_system(&mut rng, 60);
        let mut x = vec![0.0; 60];
        let stats = cg(&a, &b, &mut x, &SolverConfig { max_iters: 3, tol: 1e-14 });
        assert!(stats.iterations <= 3);
    }

    #[test]
    fn warm_start_improves() {
        let mut rng = Pcg32::seeded(13);
        let (a, b, x_true) = spd_system(&mut rng, 30);
        let mut x_cold = vec![0.0; 30];
        let cold = cg(&a, &b, &mut x_cold, &SolverConfig { max_iters: 2, tol: 1e-16 });
        let mut x_warm = x_true.iter().map(|v| v * 0.999).collect::<Vec<_>>();
        let warm = cg(&a, &b, &mut x_warm, &SolverConfig { max_iters: 2, tol: 1e-16 });
        assert!(warm.residual_norm < cold.residual_norm);
    }

    #[test]
    fn pcg_with_identity_matches_cg_bitwise() {
        let mut rng = Pcg32::seeded(14);
        let (a, b, _) = spd_system(&mut rng, 25);
        for cfg in [
            SolverConfig::default(),
            SolverConfig { max_iters: 3, tol: 1e-16 },
            SolverConfig { max_iters: 200, tol: 1e-13 },
        ] {
            let mut x_cg = vec![0.0; 25];
            let s_cg = cg(&a, &b, &mut x_cg, &cfg);
            let mut x_pcg = vec![0.0; 25];
            let s_pcg = pcg(&a, &b, &mut x_pcg, &IdentityPrecond { n: 25 }, &cfg);
            assert_eq!(x_cg, x_pcg, "identity-preconditioned CG diverged from CG");
            assert_eq!(s_cg.iterations, s_pcg.iterations);
            assert_eq!(s_cg.converged, s_pcg.converged);
        }
    }

    #[test]
    fn pcg_with_jacobi_solves_spd() {
        let mut rng = Pcg32::seeded(15);
        let (a, b, x_true) = spd_system(&mut rng, 40);
        let diag: Vec<f64> = (0..40).map(|i| a.get(i, i)).collect();
        let m = JacobiPrecond::new(&diag);
        let mut x = vec![0.0; 40];
        let stats = pcg(&a, &b, &mut x, &m, &SolverConfig::default());
        assert!(stats.converged, "residual={}", stats.residual_norm);
        assert_allclose(&x, &x_true, 1e-6, 1e-6);
    }

    /// With `M = A⁻¹`, PCG lands on the solution after a single iteration.
    #[test]
    fn pcg_with_exact_inverse_converges_in_one_iteration() {
        struct DenseInverse(Matrix);
        impl crate::linalg::solvers::Preconditioner for DenseInverse {
            fn dim(&self) -> usize {
                self.0.rows()
            }
            fn apply(&self, r: &[f64], z: &mut [f64]) {
                z.copy_from_slice(&self.0.matvec(r));
            }
        }
        let mut rng = Pcg32::seeded(16);
        let (a, b, x_true) = spd_system(&mut rng, 12);
        // Dense inverse via n solves against the identity columns.
        let mut inv = Matrix::zeros(12, 12);
        for j in 0..12 {
            let mut e = vec![0.0; 12];
            e[j] = 1.0;
            let col = a.solve_spd(&e).expect("SPD");
            for i in 0..12 {
                inv.set(i, j, col[i]);
            }
        }
        let m = DenseInverse(inv);
        let mut x = vec![0.0; 12];
        let stats = pcg(&a, &b, &mut x, &m, &SolverConfig { max_iters: 50, tol: 1e-9 });
        assert!(stats.converged);
        assert!(stats.iterations <= 2, "exact preconditioner took {} iterations", stats.iterations);
        assert_allclose(&x, &x_true, 1e-7, 1e-7);
    }
}
