//! MINRES (Paige & Saunders 1975, [62] in the paper) for symmetric — possibly
//! indefinite — systems. The paper trains Kronecker ridge regression with
//! `scipy.sparse.linalg.minres`; this is the same algorithm without
//! preconditioning.

use super::{LinOp, SolveStats, SolverConfig, Stopping};
use crate::linalg::vecops::{axpy, dot, norm2, scale};

/// Solve `A x = b` for symmetric `A`, starting from `x` (updated in place).
pub fn minres(a: &dyn LinOp, b: &[f64], x: &mut [f64], cfg: &SolverConfig) -> SolveStats {
    minres_cb(a, b, x, cfg, None)
}

/// [`minres`] with an optional per-iteration monitor (used by the Fig. 3
/// ridge convergence experiment).
pub fn minres_cb(
    a: &dyn LinOp,
    b: &[f64],
    x: &mut [f64],
    cfg: &SolverConfig,
    mut monitor: Option<super::IterMonitor<'_>>,
) -> SolveStats {
    let n = a.dim();
    assert_eq!(b.len(), n);
    assert_eq!(x.len(), n);

    let stop = Stopping::new(cfg, b);
    if stop.zero_rhs() {
        // Unified zero-RHS rule (see [`Stopping`]): x = 0, no iterations.
        // Previously minres fell through with tol_abs floored at
        // f64::MIN_POSITIVE and burned max_iters from a nonzero warm start.
        return Stopping::zero_solution(x);
    }

    // r1 = b - A x0
    let mut r1 = vec![0.0; n];
    a.apply(x, &mut r1);
    for i in 0..n {
        r1[i] = b[i] - r1[i];
    }
    let beta1 = norm2(&r1);
    if beta1 == 0.0 {
        // Warm start already exact.
        return SolveStats { iterations: 0, residual_norm: 0.0, converged: true };
    }

    let mut y = r1.clone();
    let mut r2 = r1.clone();

    let (mut oldb, mut beta) = (0.0f64, beta1);
    let (mut dbar, mut epsln) = (0.0f64, 0.0f64);
    let mut phibar = beta1;
    let (mut cs, mut sn) = (-1.0f64, 0.0f64);
    let mut w = vec![0.0; n];
    let mut w2 = vec![0.0; n];

    let mut iters = 0;
    let mut converged = stop.converged(phibar);

    while iters < cfg.max_iters && !converged {
        iters += 1;
        // Lanczos step
        let s = 1.0 / beta;
        let mut v = y.clone();
        scale(s, &mut v);
        let mut y_new = vec![0.0; n];
        a.apply(&v, &mut y_new);
        if iters >= 2 {
            axpy(-(beta / oldb), &r1, &mut y_new);
        }
        let alfa = dot(&v, &y_new);
        axpy(-(alfa / beta), &r2, &mut y_new);
        r1 = std::mem::replace(&mut r2, y_new.clone());
        let _ = &r1; // r1 now holds the previous r2
        y = y_new;
        oldb = beta;
        beta = norm2(&y);

        // Apply previous rotation
        let oldeps = epsln;
        let delta = cs * dbar + sn * alfa;
        let gbar = sn * dbar - cs * alfa;
        epsln = sn * beta;
        dbar = -cs * beta;

        // Compute next rotation
        let gamma = (gbar * gbar + beta * beta).sqrt().max(f64::EPSILON);
        cs = gbar / gamma;
        sn = beta / gamma;
        let phi = cs * phibar;
        phibar *= sn;

        // Update solution: w = (v - oldeps*w1 - delta*w2) / gamma
        let denom = 1.0 / gamma;
        let w1 = std::mem::replace(&mut w2, w.clone());
        let mut w_new = v;
        axpy(-oldeps, &w1, &mut w_new);
        axpy(-delta, &w2, &mut w_new);
        scale(denom, &mut w_new);
        w = w_new;
        axpy(phi, &w, x);
        if let Some(mon) = monitor.as_mut() {
            if !mon(iters, x) {
                break;
            }
        }

        converged = stop.converged(phibar);
    }

    SolveStats { iterations: iters, residual_norm: phibar, converged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::solvers::testutil::spd_system;
    use crate::linalg::vecops::assert_allclose;
    use crate::linalg::Matrix;
    use crate::util::rng::Pcg32;

    #[test]
    fn solves_spd() {
        let mut rng = Pcg32::seeded(20);
        let (a, b, x_true) = spd_system(&mut rng, 35);
        let mut x = vec![0.0; 35];
        let stats = minres(&a, &b, &mut x, &SolverConfig { max_iters: 200, tol: 1e-12 });
        assert!(stats.converged, "residual={}", stats.residual_norm);
        assert_allclose(&x, &x_true, 1e-6, 1e-6);
    }

    #[test]
    fn solves_symmetric_indefinite() {
        // Diagonal with mixed signs — CG would break down, MINRES must not.
        let n = 20;
        let a = Matrix::from_fn(n, n, |i, j| {
            if i == j {
                if i % 2 == 0 {
                    2.0 + i as f64
                } else {
                    -(2.0 + i as f64)
                }
            } else {
                0.0
            }
        });
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64 * 0.9).sin()).collect();
        let b = a.matvec(&x_true);
        let mut x = vec![0.0; n];
        let stats = minres(&a, &b, &mut x, &SolverConfig { max_iters: 100, tol: 1e-12 });
        assert!(stats.converged);
        assert_allclose(&x, &x_true, 1e-7, 1e-7);
    }

    #[test]
    fn zero_rhs_is_immediate() {
        let mut rng = Pcg32::seeded(21);
        let (a, _, _) = spd_system(&mut rng, 6);
        let mut x = vec![0.0; 6];
        let stats = minres(&a, &vec![0.0; 6], &mut x, &SolverConfig::default());
        assert_eq!(stats.iterations, 0);
        assert!(stats.converged);
    }

    #[test]
    fn residual_decreases_with_more_iterations() {
        let mut rng = Pcg32::seeded(22);
        let (a, b, _) = spd_system(&mut rng, 50);
        let mut r_prev = f64::INFINITY;
        for iters in [1usize, 3, 10, 30] {
            let mut x = vec![0.0; 50];
            let stats = minres(&a, &b, &mut x, &SolverConfig { max_iters: iters, tol: 1e-16 });
            assert!(stats.residual_norm <= r_prev + 1e-12);
            r_prev = stats.residual_norm;
        }
    }
}
