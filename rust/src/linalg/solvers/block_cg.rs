//! Block conjugate gradient: `k` shifted SPD systems, one batched operator.
//!
//! Solves `(A + shift_j·I) x_j = b_j` for `j = 0..k` by running `k`
//! independent CG recurrences in lockstep, batching the expensive part —
//! the operator application — through [`MultiLinOp::apply_multi`]. For the
//! Kronecker kernel operator that means **one edge-index traversal per
//! iteration serves every system**, which is the multi-λ / multi-output
//! ridge workload (a whole regularization path, or one model per output,
//! trained for little more than the cost of one).
//!
//! Per column, every floating-point operation happens in exactly the order
//! of the single-RHS [`cg`](super::cg::cg) on the corresponding shifted
//! system (`RidgeSystemOp`-style `y ← A x; y += shift·x`): column `j` of the
//! block solve is **bitwise identical** to the standalone solve (tested).
//! Columns that converge (or break down) are frozen — their iterates stop
//! changing — while the remaining systems keep iterating.

use super::{MultiLinOp, Preconditioner, SolveStats, SolverConfig, Stopping};
use crate::linalg::vecops::{axpby, axpy, dot, norm2};

/// Solve `(A + shifts[j]·I) x_j = b_j` for all `j` in lockstep.
///
/// `b` and `x` hold `shifts.len()` column planes of length `a.dim()`; `x` is
/// updated in place (commonly zeros). Returns one [`SolveStats`] per system,
/// each matching what the single-RHS CG on that system would report.
pub fn block_cg(
    a: &dyn MultiLinOp,
    shifts: &[f64],
    b: &[f64],
    x: &mut [f64],
    cfg: &SolverConfig,
) -> Vec<SolveStats> {
    let n = a.dim();
    let k = shifts.len();
    assert_eq!(b.len(), n * k, "b must hold one plane of length n per shift");
    assert_eq!(x.len(), n * k, "x must hold one plane of length n per shift");
    if k == 0 {
        return Vec::new();
    }

    let mut stats =
        vec![SolveStats { iterations: 0, residual_norm: 0.0, converged: false }; k];
    let mut active = vec![true; k];
    let mut stops = Vec::with_capacity(k);
    for j in 0..k {
        let stop = Stopping::new(cfg, &b[j * n..(j + 1) * n]);
        if stop.zero_rhs() {
            stats[j] = Stopping::zero_solution(&mut x[j * n..(j + 1) * n]);
            active[j] = false;
        }
        stops.push(stop);
    }
    if active.iter().all(|&a| !a) {
        return stats;
    }

    // r_j = b_j - (A + shift_j I) x_j — batched apply, then the same
    // `y += shift·x` the shifted single-RHS operator performs.
    let mut r = vec![0.0; n * k];
    a.apply_multi(x, k, &mut r);
    for (j, rj) in r.chunks_mut(n).enumerate() {
        let xj = &x[j * n..(j + 1) * n];
        let bj = &b[j * n..(j + 1) * n];
        for i in 0..n {
            rj[i] = bj[i] - (rj[i] + shifts[j] * xj[i]);
        }
    }
    let mut p = r.clone();
    let mut ap = vec![0.0; n * k];
    let mut rs_old: Vec<f64> = r.chunks(n).map(|rj| dot(rj, rj)).collect();

    let mut iters = 0;
    loop {
        // top-of-loop convergence sweep (mirrors cg's check)
        for j in 0..k {
            if active[j] && stops[j].converged(rs_old[j].sqrt()) {
                stats[j] = SolveStats {
                    iterations: iters,
                    residual_norm: rs_old[j].sqrt(),
                    converged: true,
                };
                active[j] = false;
                // Zero the frozen column's search direction so the batched
                // apply's per-plane zero-skip drops its stage-1 work for the
                // remaining iterations (its output is discarded anyway, and
                // active columns are untouched — bitwise equality holds).
                p[j * n..(j + 1) * n].fill(0.0);
            }
        }
        if iters >= cfg.max_iters || active.iter().all(|&a| !a) {
            break;
        }
        a.apply_multi(&p, k, &mut ap);
        for j in 0..k {
            if !active[j] {
                continue;
            }
            let apj = &mut ap[j * n..(j + 1) * n];
            let pj = &p[j * n..(j + 1) * n];
            for (api, pi) in apj.iter_mut().zip(pj) {
                *api += shifts[j] * pi;
            }
            let pap = dot(pj, apj);
            if pap <= 0.0 {
                // not SPD (or numerical breakdown) — freeze this column at
                // its current iterate, exactly as cg stops.
                stats[j] = SolveStats {
                    iterations: iters,
                    residual_norm: rs_old[j].sqrt(),
                    converged: false,
                };
                active[j] = false;
                p[j * n..(j + 1) * n].fill(0.0);
                continue;
            }
            let alpha = rs_old[j] / pap;
            axpy(alpha, pj, &mut x[j * n..(j + 1) * n]);
            axpy(-alpha, apj, &mut r[j * n..(j + 1) * n]);
            let rs_new = dot(&r[j * n..(j + 1) * n], &r[j * n..(j + 1) * n]);
            axpby(1.0, &r[j * n..(j + 1) * n], rs_new / rs_old[j], &mut p[j * n..(j + 1) * n]);
            rs_old[j] = rs_new;
        }
        iters += 1;
    }
    for j in 0..k {
        if active[j] {
            stats[j] = SolveStats {
                iterations: iters,
                residual_norm: rs_old[j].sqrt(),
                converged: stops[j].converged(rs_old[j].sqrt()),
            };
        }
    }
    stats
}

/// Preconditioned block CG: like [`block_cg`] but with one
/// [`Preconditioner`] per shifted system, applied per column plane.
///
/// Column `j` retraces the standalone [`pcg`](super::cg::pcg) on
/// `(A + shifts[j]·I) x = b_j` with `preconds[j]` bit for bit (tested), with
/// the same freeze semantics as [`block_cg`]. This is the whole-λ-grid
/// workload when the training graph is *incomplete* and the Kronecker
/// spectral surrogate preconditioner is in play.
pub fn block_pcg(
    a: &dyn MultiLinOp,
    shifts: &[f64],
    preconds: &[&dyn Preconditioner],
    b: &[f64],
    x: &mut [f64],
    cfg: &SolverConfig,
) -> Vec<SolveStats> {
    let n = a.dim();
    let k = shifts.len();
    assert_eq!(preconds.len(), k, "one preconditioner per shift");
    assert_eq!(b.len(), n * k, "b must hold one plane of length n per shift");
    assert_eq!(x.len(), n * k, "x must hold one plane of length n per shift");
    if k == 0 {
        return Vec::new();
    }
    for (j, m) in preconds.iter().enumerate() {
        assert_eq!(m.dim(), n, "preconditioner {j} dimension mismatch");
    }

    let mut stats =
        vec![SolveStats { iterations: 0, residual_norm: 0.0, converged: false }; k];
    let mut active = vec![true; k];
    let mut stops = Vec::with_capacity(k);
    for j in 0..k {
        let stop = Stopping::new(cfg, &b[j * n..(j + 1) * n]);
        if stop.zero_rhs() {
            stats[j] = Stopping::zero_solution(&mut x[j * n..(j + 1) * n]);
            active[j] = false;
        }
        stops.push(stop);
    }
    if active.iter().all(|&a| !a) {
        return stats;
    }

    // r_j = b_j - (A + shift_j I) x_j, then z_j = M_j r_j (pcg's setup).
    let mut r = vec![0.0; n * k];
    a.apply_multi(x, k, &mut r);
    for (j, rj) in r.chunks_mut(n).enumerate() {
        let xj = &x[j * n..(j + 1) * n];
        let bj = &b[j * n..(j + 1) * n];
        for i in 0..n {
            rj[i] = bj[i] - (rj[i] + shifts[j] * xj[i]);
        }
    }
    let mut z = vec![0.0; n * k];
    for (j, zj) in z.chunks_mut(n).enumerate() {
        preconds[j].apply(&r[j * n..(j + 1) * n], zj);
    }
    let mut p = z.clone();
    let mut ap = vec![0.0; n * k];
    let mut rz_old: Vec<f64> =
        (0..k).map(|j| dot(&r[j * n..(j + 1) * n], &z[j * n..(j + 1) * n])).collect();
    let mut r_norm: Vec<f64> = r.chunks(n).map(norm2).collect();

    let mut iters = 0;
    loop {
        // top-of-loop convergence sweep (mirrors pcg's check)
        for j in 0..k {
            if active[j] && stops[j].converged(r_norm[j]) {
                stats[j] =
                    SolveStats { iterations: iters, residual_norm: r_norm[j], converged: true };
                active[j] = false;
                p[j * n..(j + 1) * n].fill(0.0);
            }
        }
        if iters >= cfg.max_iters || active.iter().all(|&a| !a) {
            break;
        }
        a.apply_multi(&p, k, &mut ap);
        for j in 0..k {
            if !active[j] {
                continue;
            }
            let apj = &mut ap[j * n..(j + 1) * n];
            let pj = &p[j * n..(j + 1) * n];
            for (api, pi) in apj.iter_mut().zip(pj) {
                *api += shifts[j] * pi;
            }
            let pap = dot(pj, apj);
            if pap <= 0.0 {
                // breakdown — freeze at the current iterate, exactly as pcg.
                stats[j] =
                    SolveStats { iterations: iters, residual_norm: r_norm[j], converged: false };
                active[j] = false;
                p[j * n..(j + 1) * n].fill(0.0);
                continue;
            }
            let alpha = rz_old[j] / pap;
            axpy(alpha, pj, &mut x[j * n..(j + 1) * n]);
            axpy(-alpha, apj, &mut r[j * n..(j + 1) * n]);
            r_norm[j] = norm2(&r[j * n..(j + 1) * n]);
            preconds[j].apply(&r[j * n..(j + 1) * n], &mut z[j * n..(j + 1) * n]);
            let rz_new = dot(&r[j * n..(j + 1) * n], &z[j * n..(j + 1) * n]);
            if rz_new <= 0.0 && !stops[j].converged(r_norm[j]) {
                // preconditioner lost positive-definiteness — pcg counts the
                // update it just made, then stops.
                stats[j] = SolveStats {
                    iterations: iters + 1,
                    residual_norm: r_norm[j],
                    converged: false,
                };
                active[j] = false;
                p[j * n..(j + 1) * n].fill(0.0);
                continue;
            }
            axpby(1.0, &z[j * n..(j + 1) * n], rz_new / rz_old[j], &mut p[j * n..(j + 1) * n]);
            rz_old[j] = rz_new;
        }
        iters += 1;
    }
    for j in 0..k {
        if active[j] {
            stats[j] = SolveStats {
                iterations: iters,
                residual_norm: r_norm[j],
                converged: stops[j].converged(r_norm[j]),
            };
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::super::cg::{cg, pcg};
    use super::super::testutil::spd_system;
    use super::super::{FnOp, JacobiPrecond, LinOp, MultiLinOp};
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn block_columns_bitwise_match_single_cg() {
        // Column j of the block solve must equal the standalone CG on
        // (A + shift_j I) x = b_j bit for bit — same iterates, same stats.
        let mut rng = Pcg32::seeded(30);
        let n = 35;
        let (a, b_base, _) = spd_system(&mut rng, n);
        let shifts = [0.0, 0.5, 3.0, 17.0];
        let k = shifts.len();
        let mut b = vec![0.0; n * k];
        for (j, bj) in b.chunks_mut(n).enumerate() {
            for (i, bi) in bj.iter_mut().enumerate() {
                *bi = b_base[i] + j as f64 * 0.1; // distinct RHS per system
            }
        }
        let cfg = SolverConfig { max_iters: 60, tol: 1e-11 };
        let mut x_block = vec![0.0; n * k];
        let stats = block_cg(&a, &shifts, &b, &mut x_block, &cfg);
        for (j, &shift) in shifts.iter().enumerate() {
            let a_ref = &a;
            let shifted = FnOp {
                n,
                fwd: move |x: &[f64], y: &mut [f64]| {
                    a_ref.apply(x, y);
                    for i in 0..n {
                        y[i] += shift * x[i];
                    }
                },
                tr: move |x: &[f64], y: &mut [f64]| {
                    a_ref.apply(x, y);
                    for i in 0..n {
                        y[i] += shift * x[i];
                    }
                },
            };
            let mut x_single = vec![0.0; n];
            let s = cg(&shifted, &b[j * n..(j + 1) * n], &mut x_single, &cfg);
            assert_eq!(&x_block[j * n..(j + 1) * n], x_single.as_slice(), "column {j}");
            assert_eq!(stats[j].iterations, s.iterations, "column {j} iterations");
            assert_eq!(stats[j].converged, s.converged, "column {j} converged");
            assert_eq!(stats[j].residual_norm, s.residual_norm, "column {j} residual");
        }
    }

    #[test]
    fn block_solves_spd_accurately() {
        let mut rng = Pcg32::seeded(31);
        let n = 30;
        let (a, b_base, _) = spd_system(&mut rng, n);
        let shifts = [0.1, 1.0];
        let mut b = vec![0.0; n * 2];
        b[..n].copy_from_slice(&b_base);
        b[n..].copy_from_slice(&b_base);
        let mut x = vec![0.0; n * 2];
        let stats = block_cg(&a, &shifts, &b, &mut x, &SolverConfig::default());
        for (j, &shift) in shifts.iter().enumerate() {
            assert!(stats[j].converged, "column {j}");
            // residual check: (A + shift I) x_j ≈ b_j
            let mut resid = a.apply_vec(&x[j * n..(j + 1) * n]);
            for i in 0..n {
                resid[i] += shift * x[j * n + i] - b[j * n + i];
            }
            assert!(crate::linalg::vecops::norm2(&resid) < 1e-6, "column {j}");
        }
    }

    #[test]
    fn zero_rhs_column_freezes_immediately() {
        let mut rng = Pcg32::seeded(32);
        let n = 12;
        let (a, b_base, _) = spd_system(&mut rng, n);
        let shifts = [0.5, 0.5];
        let mut b = vec![0.0; n * 2];
        b[n..].copy_from_slice(&b_base); // column 0 has a zero RHS
        let mut x = vec![1.0; n * 2];
        let stats = block_cg(&a, &shifts, &b, &mut x, &SolverConfig::default());
        assert!(stats[0].converged);
        assert_eq!(stats[0].iterations, 0);
        assert!(x[..n].iter().all(|&v| v == 0.0));
        assert!(stats[1].converged);
        assert!(stats[1].iterations > 0);
    }

    #[test]
    fn empty_shift_list_is_a_noop() {
        let mut rng = Pcg32::seeded(33);
        let (a, _, _) = spd_system(&mut rng, 5);
        let mut x: Vec<f64> = Vec::new();
        assert!(block_cg(&a, &[], &[], &mut x, &SolverConfig::default()).is_empty());
    }

    #[test]
    fn matrix_apply_multi_matches_matvec_bitwise() {
        let mut rng = Pcg32::seeded(34);
        let (a, _, _) = spd_system(&mut rng, 22);
        let k = 3;
        let v: Vec<f64> = (0..22 * k).map(|_| rng.normal()).collect();
        let mut multi = vec![0.0; 22 * k];
        a.apply_multi(&v, k, &mut multi);
        for j in 0..k {
            let single = a.matvec(&v[j * 22..(j + 1) * 22]);
            assert_eq!(&multi[j * 22..(j + 1) * 22], single.as_slice(), "plane {j}");
        }
    }

    #[test]
    fn block_pcg_columns_bitwise_match_single_pcg() {
        // Column j of the preconditioned block solve must equal the
        // standalone PCG on (A + shift_j I) x = b_j with the same
        // per-shift Jacobi preconditioner, bit for bit.
        let mut rng = Pcg32::seeded(36);
        let n = 28;
        let (a, b_base, _) = spd_system(&mut rng, n);
        let shifts = [0.0, 0.7, 5.0];
        let k = shifts.len();
        let preconds: Vec<JacobiPrecond> = shifts
            .iter()
            .map(|&s| JacobiPrecond::new(&(0..n).map(|i| a.get(i, i) + s).collect::<Vec<_>>()))
            .collect();
        let precond_refs: Vec<&dyn crate::linalg::solvers::Preconditioner> =
            preconds.iter().map(|m| m as &dyn crate::linalg::solvers::Preconditioner).collect();
        let mut b = vec![0.0; n * k];
        for (j, bj) in b.chunks_mut(n).enumerate() {
            for (i, bi) in bj.iter_mut().enumerate() {
                *bi = b_base[i] - j as f64 * 0.2;
            }
        }
        let cfg = SolverConfig { max_iters: 60, tol: 1e-11 };
        let mut x_block = vec![0.0; n * k];
        let stats = block_pcg(&a, &shifts, &precond_refs, &b, &mut x_block, &cfg);
        for (j, &shift) in shifts.iter().enumerate() {
            let a_ref = &a;
            let shifted = FnOp {
                n,
                fwd: move |x: &[f64], y: &mut [f64]| {
                    a_ref.apply(x, y);
                    for i in 0..n {
                        y[i] += shift * x[i];
                    }
                },
                tr: move |x: &[f64], y: &mut [f64]| {
                    a_ref.apply(x, y);
                    for i in 0..n {
                        y[i] += shift * x[i];
                    }
                },
            };
            let mut x_single = vec![0.0; n];
            let s = pcg(&shifted, &b[j * n..(j + 1) * n], &mut x_single, &preconds[j], &cfg);
            assert_eq!(&x_block[j * n..(j + 1) * n], x_single.as_slice(), "column {j}");
            assert_eq!(stats[j].iterations, s.iterations, "column {j} iterations");
            assert_eq!(stats[j].converged, s.converged, "column {j} converged");
            assert_eq!(stats[j].residual_norm, s.residual_norm, "column {j} residual");
        }
    }

    #[test]
    fn respects_iteration_cap_per_column() {
        let mut rng = Pcg32::seeded(35);
        let n = 40;
        let (a, b_base, _) = spd_system(&mut rng, n);
        let shifts = [0.0, 1.0, 2.0];
        let mut b = vec![0.0; n * 3];
        for bj in b.chunks_mut(n) {
            bj.copy_from_slice(&b_base);
        }
        let mut x = vec![0.0; n * 3];
        let stats = block_cg(&a, &shifts, &b, &mut x, &SolverConfig { max_iters: 2, tol: 1e-16 });
        for s in &stats {
            assert!(s.iterations <= 2);
        }
        // two iterations still move every column off the zero start
        assert!(x[..n].iter().any(|&v| v != 0.0));
    }
}
