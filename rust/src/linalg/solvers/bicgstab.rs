//! BiCGStab (van der Vorst 1992) — transpose-free alternative to QMR for
//! nonsymmetric systems; used as a fallback when an operator cannot provide
//! `Aᵀx` cheaply.

use super::{LinOp, SolveStats, SolverConfig, Stopping};
use crate::linalg::vecops::{axpy, dot, norm2};

/// Solve `A x = b`, starting from `x` (updated in place).
pub fn bicgstab(a: &dyn LinOp, b: &[f64], x: &mut [f64], cfg: &SolverConfig) -> SolveStats {
    let n = a.dim();
    assert_eq!(b.len(), n);
    assert_eq!(x.len(), n);

    let stop = Stopping::new(cfg, b);
    if stop.zero_rhs() {
        return Stopping::zero_solution(x);
    }

    let mut r = vec![0.0; n];
    a.apply(x, &mut r);
    for i in 0..n {
        r[i] = b[i] - r[i];
    }
    let r0 = r.clone(); // shadow residual
    let mut rho_old = 1.0f64;
    let mut alpha = 1.0f64;
    let mut omega = 1.0f64;
    let mut v = vec![0.0; n];
    let mut p = vec![0.0; n];
    let mut s = vec![0.0; n];
    let mut t = vec![0.0; n];

    let mut res_norm = norm2(&r);
    let mut iters = 0;
    while iters < cfg.max_iters && !stop.converged(res_norm) {
        iters += 1;
        let rho = dot(&r0, &r);
        if rho.abs() < f64::MIN_POSITIVE {
            break; // breakdown
        }
        let beta = (rho / rho_old) * (alpha / omega);
        // p = r + beta (p - omega v)
        for i in 0..n {
            p[i] = r[i] + beta * (p[i] - omega * v[i]);
        }
        a.apply(&p, &mut v);
        let r0v = dot(&r0, &v);
        if r0v.abs() < f64::MIN_POSITIVE {
            break;
        }
        alpha = rho / r0v;
        for i in 0..n {
            s[i] = r[i] - alpha * v[i];
        }
        if stop.converged(norm2(&s)) {
            axpy(alpha, &p, x);
            res_norm = norm2(&s);
            return SolveStats { iterations: iters, residual_norm: res_norm, converged: true };
        }
        a.apply(&s, &mut t);
        let tt = dot(&t, &t);
        if tt < f64::MIN_POSITIVE {
            break;
        }
        omega = dot(&t, &s) / tt;
        for i in 0..n {
            x[i] += alpha * p[i] + omega * s[i];
            r[i] = s[i] - omega * t[i];
        }
        res_norm = norm2(&r);
        rho_old = rho;
        if omega.abs() < f64::MIN_POSITIVE {
            break;
        }
    }
    SolveStats { iterations: iters, residual_norm: res_norm, converged: stop.converged(res_norm) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::solvers::testutil::{nonsym_system, spd_system};
    use crate::linalg::vecops::assert_allclose;
    use crate::util::rng::Pcg32;

    #[test]
    fn solves_nonsymmetric() {
        let mut rng = Pcg32::seeded(40);
        let (a, b, x_true) = nonsym_system(&mut rng, 45);
        let mut x = vec![0.0; 45];
        let stats = bicgstab(&a, &b, &mut x, &SolverConfig { max_iters: 300, tol: 1e-12 });
        assert!(stats.converged, "residual={}", stats.residual_norm);
        assert_allclose(&x, &x_true, 1e-6, 1e-6);
    }

    #[test]
    fn solves_spd() {
        let mut rng = Pcg32::seeded(41);
        let (a, b, x_true) = spd_system(&mut rng, 20);
        let mut x = vec![0.0; 20];
        let stats = bicgstab(&a, &b, &mut x, &SolverConfig { max_iters: 200, tol: 1e-12 });
        assert!(stats.converged);
        assert_allclose(&x, &x_true, 1e-6, 1e-6);
    }

    #[test]
    fn agrees_with_qmr() {
        let mut rng = Pcg32::seeded(42);
        let (a, b, _) = nonsym_system(&mut rng, 30);
        let cfg = SolverConfig { max_iters: 500, tol: 1e-12 };
        let mut x1 = vec![0.0; 30];
        let mut x2 = vec![0.0; 30];
        bicgstab(&a, &b, &mut x1, &cfg);
        crate::linalg::solvers::qmr(&a, &b, &mut x2, &cfg);
        assert_allclose(&x1, &x2, 1e-5, 1e-5);
    }
}
