//! Iterative solvers for `A x = b` where `A` is available only as a linear
//! operator (matrix–vector product).
//!
//! The paper trains ridge regression with MINRES [62] and the SVM's inner
//! Newton system with QMR [50]; CG and BiCGStab are provided as alternatives
//! and for testing. All solvers are matrix-free: they only require a
//! [`LinOp`], which the [`crate::gvt`] module implements without ever
//! materializing the Kronecker product.

pub mod cg;
pub mod block_cg;
pub mod minres;
pub mod qmr;
pub mod bicgstab;

pub use cg::{cg, cg_cb};
pub use block_cg::block_cg;
pub use minres::{minres, minres_cb};
pub use qmr::qmr;
pub use bicgstab::bicgstab;

/// Per-iteration monitor: called with (iteration, current solution); return
/// `false` to stop the solver early (early-stopping regularization, §3.3).
pub type IterMonitor<'a> = &'a mut dyn FnMut(usize, &[f64]) -> bool;

use crate::linalg::Matrix;

/// A square linear operator `R^n → R^n`.
pub trait LinOp {
    /// Operator dimension `n`.
    fn dim(&self) -> usize;

    /// `y ← A x`.
    fn apply(&self, x: &[f64], y: &mut [f64]);

    /// `y ← Aᵀ x`. Default assumes a symmetric operator; nonsymmetric
    /// operators (e.g. the SVM Newton system `H·Q + λI`) must override.
    fn apply_transpose(&self, x: &[f64], y: &mut [f64]) {
        self.apply(x, y)
    }

    /// Allocating convenience wrapper.
    fn apply_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.dim()];
        self.apply(x, &mut y);
        y
    }
}

/// A [`LinOp`] that can apply itself to many vectors at once.
///
/// `v` and `u` hold `k_rhs` column *planes* of length [`LinOp::dim`] each
/// (`v[j·n..][..n]` is RHS `j`). Implementors must keep **column `j` of the
/// batched result bitwise identical to a single [`LinOp::apply`] on plane
/// `j`** — the block solvers rely on that to retrace single-RHS trajectories
/// exactly. The default implementation just loops; real implementors (the
/// GVT kernel operator, [`Matrix`]) batch the traversal/GEMM.
pub trait MultiLinOp: LinOp {
    /// `u_j ← A v_j` for `k_rhs` stacked column planes.
    fn apply_multi(&self, v: &[f64], k_rhs: usize, u: &mut [f64]) {
        let n = self.dim();
        assert_eq!(v.len(), n * k_rhs, "v must hold k_rhs planes of length n");
        assert_eq!(u.len(), n * k_rhs, "u must hold k_rhs planes of length n");
        for (vj, uj) in v.chunks(n.max(1)).zip(u.chunks_mut(n.max(1))) {
            self.apply(vj, uj);
        }
    }
}

impl LinOp for Matrix {
    fn dim(&self) -> usize {
        assert_eq!(self.rows(), self.cols(), "LinOp requires a square matrix");
        self.rows()
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.matvec_into(x, y);
    }

    fn apply_transpose(&self, x: &[f64], y: &mut [f64]) {
        let yt = self.matvec_t(x);
        y.copy_from_slice(&yt);
    }
}

impl MultiLinOp for Matrix {
    /// One NT GEMM instead of `k_rhs` matvecs: with `V` the `k_rhs×n` plane
    /// matrix, `U = V·Aᵀ` gives `U[j,i] = dot(v_j, A.row(i))` — bitwise the
    /// per-column [`Matrix::matvec_into`] value (IEEE multiplication is
    /// commutative, and the GEMM uses the same `dot` reduction).
    fn apply_multi(&self, v: &[f64], k_rhs: usize, u: &mut [f64]) {
        let n = self.dim();
        assert_eq!(v.len(), n * k_rhs, "v must hold k_rhs planes of length n");
        assert_eq!(u.len(), n * k_rhs, "u must hold k_rhs planes of length n");
        crate::linalg::gemm::gemm_nt_into(v, self.data(), k_rhs, n, n, u, 1);
    }
}

/// Operator defined by closures (used by tests and by operator compositions).
pub struct FnOp<F, G>
where
    F: Fn(&[f64], &mut [f64]),
    G: Fn(&[f64], &mut [f64]),
{
    /// Operator dimension.
    pub n: usize,
    /// Forward product `y ← A x`.
    pub fwd: F,
    /// Transpose product `y ← Aᵀ x`.
    pub tr: G,
}

impl<F, G> LinOp for FnOp<F, G>
where
    F: Fn(&[f64], &mut [f64]),
    G: Fn(&[f64], &mut [f64]),
{
    fn dim(&self) -> usize {
        self.n
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        (self.fwd)(x, y)
    }

    fn apply_transpose(&self, x: &[f64], y: &mut [f64]) {
        (self.tr)(x, y)
    }
}

/// Outcome of an iterative solve.
#[derive(Debug, Clone, Copy)]
pub struct SolveStats {
    /// Iterations actually performed.
    pub iterations: usize,
    /// Final residual norm ‖b − A x‖ (or the solver's internal estimate).
    pub residual_norm: f64,
    /// Whether the tolerance was met before the iteration cap.
    pub converged: bool,
}

/// Common solver configuration.
#[derive(Debug, Clone, Copy)]
pub struct SolverConfig {
    /// Maximum number of iterations (the paper's "inner iterations").
    pub max_iters: usize,
    /// Relative residual tolerance ‖r‖ ≤ tol·‖b‖.
    pub tol: f64,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig { max_iters: 100, tol: 1e-10 }
    }
}

impl SolverConfig {
    /// Default tolerance with an explicit iteration cap.
    pub fn with_iters(max_iters: usize) -> Self {
        SolverConfig { max_iters, ..Default::default() }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Random SPD system with known solution.
    pub fn spd_system(rng: &mut Pcg32, n: usize) -> (Matrix, Vec<f64>, Vec<f64>) {
        let g = Matrix::from_fn(n, n, |_, _| rng.normal());
        let mut a = g.matmul_nt(&g);
        a.add_diag(n as f64); // well conditioned
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7).sin()).collect();
        let b = a.matvec(&x_true);
        (a, b, x_true)
    }

    /// Random diagonally dominant nonsymmetric system with known solution.
    pub fn nonsym_system(rng: &mut Pcg32, n: usize) -> (Matrix, Vec<f64>, Vec<f64>) {
        let mut a = Matrix::from_fn(n, n, |_, _| rng.normal() * 0.3);
        a.add_diag(n as f64 * 0.5);
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64 * 0.3).cos()).collect();
        let b = a.matvec(&x_true);
        (a, b, x_true)
    }
}
