//! Iterative solvers for `A x = b` where `A` is available only as a linear
//! operator (matrix–vector product).
//!
//! The paper trains ridge regression with MINRES [62] and the SVM's inner
//! Newton system with QMR [50]; CG and BiCGStab are provided as alternatives
//! and for testing. All solvers are matrix-free: they only require a
//! [`LinOp`], which the [`crate::gvt`] module implements without ever
//! materializing the Kronecker product.

pub mod cg;
pub mod block_cg;
pub mod minres;
pub mod qmr;
pub mod bicgstab;

pub use cg::{cg, cg_cb, pcg, pcg_cb};
pub use block_cg::{block_cg, block_pcg};
pub use minres::{minres, minres_cb};
pub use qmr::qmr;
pub use bicgstab::bicgstab;

/// Per-iteration monitor: called with (iteration, current solution); return
/// `false` to stop the solver early (early-stopping regularization, §3.3).
pub type IterMonitor<'a> = &'a mut dyn FnMut(usize, &[f64]) -> bool;

use crate::linalg::Matrix;

/// A square linear operator `R^n → R^n`.
pub trait LinOp {
    /// Operator dimension `n`.
    fn dim(&self) -> usize;

    /// `y ← A x`.
    fn apply(&self, x: &[f64], y: &mut [f64]);

    /// `y ← Aᵀ x`. Default assumes a symmetric operator; nonsymmetric
    /// operators (e.g. the SVM Newton system `H·Q + λI`) must override.
    fn apply_transpose(&self, x: &[f64], y: &mut [f64]) {
        self.apply(x, y)
    }

    /// Allocating convenience wrapper.
    fn apply_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.dim()];
        self.apply(x, &mut y);
        y
    }
}

/// A [`LinOp`] that can apply itself to many vectors at once.
///
/// `v` and `u` hold `k_rhs` column *planes* of length [`LinOp::dim`] each
/// (`v[j·n..][..n]` is RHS `j`). Implementors must keep **column `j` of the
/// batched result bitwise identical to a single [`LinOp::apply`] on plane
/// `j`** — the block solvers rely on that to retrace single-RHS trajectories
/// exactly. The default implementation just loops; real implementors (the
/// GVT kernel operator, [`Matrix`]) batch the traversal/GEMM.
pub trait MultiLinOp: LinOp {
    /// `u_j ← A v_j` for `k_rhs` stacked column planes.
    fn apply_multi(&self, v: &[f64], k_rhs: usize, u: &mut [f64]) {
        let n = self.dim();
        assert_eq!(v.len(), n * k_rhs, "v must hold k_rhs planes of length n");
        assert_eq!(u.len(), n * k_rhs, "u must hold k_rhs planes of length n");
        for (vj, uj) in v.chunks(n.max(1)).zip(u.chunks_mut(n.max(1))) {
            self.apply(vj, uj);
        }
    }
}

impl LinOp for Matrix {
    fn dim(&self) -> usize {
        assert_eq!(self.rows(), self.cols(), "LinOp requires a square matrix");
        self.rows()
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.matvec_into(x, y);
    }

    fn apply_transpose(&self, x: &[f64], y: &mut [f64]) {
        let yt = self.matvec_t(x);
        y.copy_from_slice(&yt);
    }
}

impl MultiLinOp for Matrix {
    /// One NT GEMM instead of `k_rhs` matvecs: with `V` the `k_rhs×n` plane
    /// matrix, `U = V·Aᵀ` gives `U[j,i] = dot(v_j, A.row(i))` — bitwise the
    /// per-column [`Matrix::matvec_into`] value (IEEE multiplication is
    /// commutative, and the GEMM uses the same `dot` reduction).
    fn apply_multi(&self, v: &[f64], k_rhs: usize, u: &mut [f64]) {
        let n = self.dim();
        assert_eq!(v.len(), n * k_rhs, "v must hold k_rhs planes of length n");
        assert_eq!(u.len(), n * k_rhs, "u must hold k_rhs planes of length n");
        crate::linalg::gemm::gemm_nt_into(v, self.data(), k_rhs, n, n, u, 1);
    }
}

/// Operator defined by closures (used by tests and by operator compositions).
pub struct FnOp<F, G>
where
    F: Fn(&[f64], &mut [f64]),
    G: Fn(&[f64], &mut [f64]),
{
    /// Operator dimension.
    pub n: usize,
    /// Forward product `y ← A x`.
    pub fwd: F,
    /// Transpose product `y ← Aᵀ x`.
    pub tr: G,
}

impl<F, G> LinOp for FnOp<F, G>
where
    F: Fn(&[f64], &mut [f64]),
    G: Fn(&[f64], &mut [f64]),
{
    fn dim(&self) -> usize {
        self.n
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        (self.fwd)(x, y)
    }

    fn apply_transpose(&self, x: &[f64], y: &mut [f64]) {
        (self.tr)(x, y)
    }
}

/// Outcome of an iterative solve.
#[derive(Debug, Clone, Copy)]
pub struct SolveStats {
    /// Iterations actually performed.
    pub iterations: usize,
    /// Final residual norm ‖b − A x‖ (or the solver's internal estimate).
    pub residual_norm: f64,
    /// Whether the tolerance was met before the iteration cap.
    pub converged: bool,
}

/// Common solver configuration.
#[derive(Debug, Clone, Copy)]
pub struct SolverConfig {
    /// Maximum number of iterations (the paper's "inner iterations").
    pub max_iters: usize,
    /// Relative residual tolerance ‖r‖ ≤ tol·‖b‖.
    pub tol: f64,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig { max_iters: 100, tol: 1e-10 }
    }
}

impl SolverConfig {
    /// Default tolerance with an explicit iteration cap.
    pub fn with_iters(max_iters: usize) -> Self {
        SolverConfig { max_iters, ..Default::default() }
    }
}

/// Shared residual-norm stopping criterion.
///
/// Every Krylov solver in this module stops on the **same** rule: the
/// (estimated) residual norm falls to `tol · ‖b‖`, and a zero right-hand
/// side short-circuits to the zero solution. Historically each solver
/// hand-rolled this arithmetic — `minres` even diverged by folding an
/// `f64::MIN_POSITIVE` floor into `tol_abs`, which silently burned
/// `max_iters` iterations on `b = 0` with a nonzero initial guess.
/// Centralizing the rule here keeps the preconditioned variants bitwise
/// consistent with the plain ones and gives the rule its own tests.
#[derive(Debug, Clone, Copy)]
pub struct Stopping {
    b_norm: f64,
    tol_abs: f64,
}

impl Stopping {
    /// Build the criterion for right-hand side `b` under `cfg`.
    pub fn new(cfg: &SolverConfig, b: &[f64]) -> Self {
        let b_norm = crate::linalg::vecops::norm2(b);
        Stopping { b_norm, tol_abs: cfg.tol * b_norm }
    }

    /// `‖b‖ = 0`: the unique solution of an SPD/nonsingular system is
    /// `x = 0`, no iterations needed.
    pub fn zero_rhs(&self) -> bool {
        self.b_norm == 0.0
    }

    /// Absolute tolerance `tol · ‖b‖` the residual norm is compared against.
    pub fn tol_abs(&self) -> f64 {
        self.tol_abs
    }

    /// Has the residual norm met the tolerance? (Boundary counts: equality
    /// converges, matching the historical `<=` in every solver.)
    pub fn converged(&self, residual_norm: f64) -> bool {
        residual_norm <= self.tol_abs
    }

    /// Resolve a zero-RHS solve: zero the iterate and report immediate
    /// convergence with a zero residual.
    pub fn zero_solution(x: &mut [f64]) -> SolveStats {
        x.fill(0.0);
        SolveStats { iterations: 0, residual_norm: 0.0, converged: true }
    }
}

/// A symmetric positive-definite preconditioner `z ← M r` (with `M ≈ A⁻¹`),
/// pluggable into [`pcg`]/[`block_pcg`]. Like [`LinOp`], implementors only
/// need a product — `M` itself is never materialized.
pub trait Preconditioner {
    /// Operator dimension `n`.
    fn dim(&self) -> usize;

    /// `z ← M r`.
    fn apply(&self, r: &[f64], z: &mut [f64]);
}

/// The identity preconditioner `M = I`. [`pcg`] with this preconditioner
/// retraces plain [`cg`] bitwise (same dot/norm reduction order), which the
/// tests pin as a regression guard.
#[derive(Debug, Clone, Copy, Default)]
pub struct IdentityPrecond {
    /// Operator dimension.
    pub n: usize,
}

impl Preconditioner for IdentityPrecond {
    fn dim(&self) -> usize {
        self.n
    }

    fn apply(&self, r: &[f64], z: &mut [f64]) {
        z.copy_from_slice(r);
    }
}

/// Diagonal (Jacobi) preconditioner `M = diag(a)⁻¹`.
#[derive(Debug, Clone)]
pub struct JacobiPrecond {
    inv_diag: Vec<f64>,
}

impl JacobiPrecond {
    /// Build from the operator's diagonal; every entry must be positive
    /// (true for SPD systems, and for `Q + λI` with PSD `Q` and `λ > 0`).
    pub fn new(diag: &[f64]) -> Self {
        assert!(diag.iter().all(|&d| d > 0.0), "Jacobi preconditioner needs a positive diagonal");
        JacobiPrecond { inv_diag: diag.iter().map(|d| 1.0 / d).collect() }
    }
}

impl Preconditioner for JacobiPrecond {
    fn dim(&self) -> usize {
        self.inv_diag.len()
    }

    fn apply(&self, r: &[f64], z: &mut [f64]) {
        for ((zi, ri), di) in z.iter_mut().zip(r).zip(&self.inv_diag) {
            *zi = ri * di;
        }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Random SPD system with known solution.
    pub fn spd_system(rng: &mut Pcg32, n: usize) -> (Matrix, Vec<f64>, Vec<f64>) {
        let g = Matrix::from_fn(n, n, |_, _| rng.normal());
        let mut a = g.matmul_nt(&g);
        a.add_diag(n as f64); // well conditioned
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7).sin()).collect();
        let b = a.matvec(&x_true);
        (a, b, x_true)
    }

    /// Random diagonally dominant nonsymmetric system with known solution.
    pub fn nonsym_system(rng: &mut Pcg32, n: usize) -> (Matrix, Vec<f64>, Vec<f64>) {
        let mut a = Matrix::from_fn(n, n, |_, _| rng.normal() * 0.3);
        a.add_diag(n as f64 * 0.5);
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64 * 0.3).cos()).collect();
        let b = a.matvec(&x_true);
        (a, b, x_true)
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::spd_system;
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn stopping_tol_abs_is_tol_times_b_norm() {
        let cfg = SolverConfig { max_iters: 10, tol: 1e-6 };
        let b = vec![3.0, 4.0]; // ‖b‖ = 5
        let stop = Stopping::new(&cfg, &b);
        assert_eq!(stop.tol_abs(), 1e-6 * 5.0);
        assert!(!stop.zero_rhs());
    }

    #[test]
    fn stopping_zero_rhs_detected() {
        let stop = Stopping::new(&SolverConfig::default(), &[0.0; 7]);
        assert!(stop.zero_rhs());
        assert!(stop.converged(0.0));
        let mut x = vec![1.0, -2.0, 3.0];
        let stats = Stopping::zero_solution(&mut x);
        assert_eq!(x, vec![0.0; 3]);
        assert!(stats.converged);
        assert_eq!(stats.iterations, 0);
        assert_eq!(stats.residual_norm, 0.0);
    }

    #[test]
    fn stopping_boundary_equality_converges() {
        let cfg = SolverConfig { max_iters: 10, tol: 0.5 };
        let stop = Stopping::new(&cfg, &[2.0]); // tol_abs = 1.0
        assert!(stop.converged(1.0));
        assert!(stop.converged(1.0 - f64::EPSILON));
        assert!(!stop.converged(1.0 + 1e-15));
    }

    /// All solvers must map `b = 0` to `x = 0` in zero iterations, even from
    /// a nonzero warm start (minres previously burned `max_iters` here).
    #[test]
    fn zero_rhs_zeroes_warm_start_in_every_solver() {
        let mut rng = Pcg32::seeded(0x51);
        let (a, _, _) = spd_system(&mut rng, 8);
        let b = vec![0.0; 8];
        let cfg = SolverConfig::default();
        let warm: Vec<f64> = (0..8).map(|i| 1.0 + i as f64).collect();
        type Solver = fn(&dyn LinOp, &[f64], &mut [f64], &SolverConfig) -> SolveStats;
        let solvers: [(&str, Solver); 4] =
            [("cg", cg), ("minres", minres), ("qmr", qmr), ("bicgstab", bicgstab)];
        for (name, solve) in solvers {
            let mut x = warm.clone();
            let stats = solve(&a, &b, &mut x, &cfg);
            assert!(stats.converged, "{name} did not converge on b=0");
            assert_eq!(stats.iterations, 0, "{name} iterated on b=0");
            assert_eq!(x, vec![0.0; 8], "{name} left a nonzero solution for b=0");
        }
        let mut x = warm.clone();
        let stats = pcg(&a, &b, &mut x, &IdentityPrecond { n: 8 }, &cfg);
        assert!(stats.converged && stats.iterations == 0 && x == vec![0.0; 8]);
    }

    /// Starting from the exact solution, every solver must accept immediately.
    #[test]
    fn already_converged_start_takes_zero_iterations() {
        let mut rng = Pcg32::seeded(0x52);
        let (a, _, _) = spd_system(&mut rng, 8);
        // Choose x_true, then b = A·x_true so the initial residual is exactly 0.
        let x_true: Vec<f64> = (0..8).map(|i| (i as f64 * 0.9).cos()).collect();
        let b = a.apply_vec(&x_true);
        let cfg = SolverConfig::default();
        type Solver = fn(&dyn LinOp, &[f64], &mut [f64], &SolverConfig) -> SolveStats;
        let solvers: [(&str, Solver); 4] =
            [("cg", cg), ("minres", minres), ("qmr", qmr), ("bicgstab", bicgstab)];
        for (name, solve) in solvers {
            let mut x = x_true.clone();
            let stats = solve(&a, &b, &mut x, &cfg);
            assert!(stats.converged, "{name} did not converge from exact start");
            assert_eq!(stats.iterations, 0, "{name} iterated from exact start");
            assert_eq!(x, x_true, "{name} perturbed an exact solution");
        }
        let mut x = x_true.clone();
        let stats = pcg(&a, &b, &mut x, &IdentityPrecond { n: 8 }, &cfg);
        assert!(stats.converged && stats.iterations == 0 && x == x_true);
    }

    #[test]
    fn jacobi_precond_applies_inverse_diagonal() {
        let m = JacobiPrecond::new(&[2.0, 4.0, 0.5]);
        assert_eq!(m.dim(), 3);
        let mut z = vec![0.0; 3];
        m.apply(&[2.0, 4.0, 0.5], &mut z);
        assert_eq!(z, vec![1.0, 1.0, 1.0]);
    }

    #[test]
    #[should_panic]
    fn jacobi_precond_rejects_nonpositive_diagonal() {
        let _ = JacobiPrecond::new(&[1.0, 0.0]);
    }
}
