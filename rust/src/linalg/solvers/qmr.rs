//! QMR — quasi-minimal residual method (Freund & Nachtigal 1991, [50] in the
//! paper) for general nonsymmetric systems, used as the inner solver of the
//! Kronecker SVM truncated-Newton loop (the Newton system
//! `H·R(G⊗K)Rᵀ + λI` is nonsymmetric because H is a 0/1 mask).
//!
//! Unpreconditioned two-sided Lanczos formulation following Barrett et al.,
//! *Templates for the Solution of Linear Systems*, §2.3.6. Requires both
//! `A·x` and `Aᵀ·x` products, which every operator in this crate provides.

use super::{LinOp, SolveStats, SolverConfig, Stopping};
use crate::linalg::vecops::{axpby, axpy, norm2, dot};

/// Solve `A x = b`, starting from `x` (updated in place).
pub fn qmr(a: &dyn LinOp, b: &[f64], x: &mut [f64], cfg: &SolverConfig) -> SolveStats {
    let n = a.dim();
    assert_eq!(b.len(), n);
    assert_eq!(x.len(), n);

    let stop = Stopping::new(cfg, b);
    if stop.zero_rhs() {
        return Stopping::zero_solution(x);
    }

    // r = b - A x
    let mut r = vec![0.0; n];
    a.apply(x, &mut r);
    for i in 0..n {
        r[i] = b[i] - r[i];
    }
    let mut res_norm = norm2(&r);
    if stop.converged(res_norm) {
        return SolveStats { iterations: 0, residual_norm: res_norm, converged: true };
    }

    let mut v_t = r.clone(); // ṽ
    let mut rho = norm2(&v_t);
    let mut w_t = r.clone(); // w̃
    let mut xi = norm2(&w_t);
    let mut gamma = 1.0f64;
    let mut eta = -1.0f64;
    let mut theta = 0.0f64;
    let mut eps = 1.0f64;

    let mut p = vec![0.0; n];
    let mut q = vec![0.0; n];
    let mut d = vec![0.0; n];
    let mut s = vec![0.0; n];
    let mut p_t = vec![0.0; n]; // A p

    let mut iters = 0;
    while iters < cfg.max_iters {
        iters += 1;
        if rho.abs() < f64::MIN_POSITIVE || xi.abs() < f64::MIN_POSITIVE {
            break; // Lanczos breakdown
        }
        // v = ṽ/ρ, w = w̃/ξ  (no preconditioner: y = v, z = w)
        let mut v = v_t.clone();
        for vi in &mut v {
            *vi /= rho;
        }
        let mut w = w_t.clone();
        for wi in &mut w {
            *wi /= xi;
        }
        let delta = dot(&w, &v);
        if delta.abs() < f64::MIN_POSITIVE {
            break;
        }
        if iters == 1 {
            p.copy_from_slice(&v);
            q.copy_from_slice(&w);
        } else {
            // p = v − (ξ δ / ε) p ;  q = w − (ρ δ / ε) q
            axpby(1.0, &v, -(xi * delta / eps), &mut p);
            axpby(1.0, &w, -(rho * delta / eps), &mut q);
        }
        a.apply(&p, &mut p_t);
        eps = dot(&q, &p_t);
        if eps.abs() < f64::MIN_POSITIVE {
            break;
        }
        let beta = eps / delta;
        if beta.abs() < f64::MIN_POSITIVE {
            break;
        }
        // ṽ = A p − β v
        v_t.copy_from_slice(&p_t);
        axpy(-beta, &v, &mut v_t);
        let rho_old = rho;
        rho = norm2(&v_t);
        // w̃ = Aᵀ q − β w
        a.apply_transpose(&q, &mut w_t);
        axpy(-beta, &w, &mut w_t);
        xi = norm2(&w_t);

        let theta_old = theta;
        let gamma_old = gamma;
        theta = rho / (gamma_old * beta.abs());
        gamma = 1.0 / (1.0 + theta * theta).sqrt();
        if gamma.abs() < f64::MIN_POSITIVE {
            break;
        }
        eta = -eta * rho_old * gamma * gamma / (beta * gamma_old * gamma_old);

        let tg2 = (theta_old * gamma) * (theta_old * gamma);
        if iters == 1 {
            for i in 0..n {
                d[i] = eta * p[i];
                s[i] = eta * p_t[i];
            }
        } else {
            for i in 0..n {
                d[i] = eta * p[i] + tg2 * d[i];
                s[i] = eta * p_t[i] + tg2 * s[i];
            }
        }
        axpy(1.0, &d, x);
        axpy(-1.0, &s, &mut r);
        res_norm = norm2(&r);
        if stop.converged(res_norm) {
            return SolveStats { iterations: iters, residual_norm: res_norm, converged: true };
        }
    }
    SolveStats { iterations: iters, residual_norm: res_norm, converged: stop.converged(res_norm) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::solvers::testutil::{nonsym_system, spd_system};
    use crate::linalg::vecops::assert_allclose;
    use crate::util::rng::Pcg32;

    #[test]
    fn solves_nonsymmetric() {
        let mut rng = Pcg32::seeded(30);
        let (a, b, x_true) = nonsym_system(&mut rng, 40);
        let mut x = vec![0.0; 40];
        let stats = qmr(&a, &b, &mut x, &SolverConfig { max_iters: 300, tol: 1e-12 });
        assert!(stats.converged, "residual={}", stats.residual_norm);
        assert_allclose(&x, &x_true, 1e-6, 1e-6);
    }

    #[test]
    fn solves_spd_too() {
        let mut rng = Pcg32::seeded(31);
        let (a, b, x_true) = spd_system(&mut rng, 25);
        let mut x = vec![0.0; 25];
        let stats = qmr(&a, &b, &mut x, &SolverConfig { max_iters: 300, tol: 1e-12 });
        assert!(stats.converged);
        assert_allclose(&x, &x_true, 1e-6, 1e-6);
    }

    #[test]
    fn masked_newton_like_system() {
        // System of the exact form the SVM produces: diag(h)·Q + λI with Q
        // SPD and h a 0/1 mask — nonsymmetric, must still converge.
        let mut rng = Pcg32::seeded(32);
        let n = 30;
        let (q, _, _) = spd_system(&mut rng, n);
        let mask: Vec<f64> = (0..n).map(|i| if i % 3 == 0 { 0.0 } else { 1.0 }).collect();
        let lambda = 0.5;
        let mut a = crate::linalg::Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let v = mask[i] * q.get(i, j) + if i == j { lambda } else { 0.0 };
                a.set(i, j, v);
            }
        }
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let b = a.matvec(&x_true);
        let mut x = vec![0.0; n];
        let stats = qmr(&a, &b, &mut x, &SolverConfig { max_iters: 500, tol: 1e-12 });
        assert!(stats.converged, "residual={}", stats.residual_norm);
        assert_allclose(&x, &x_true, 1e-5, 1e-5);
    }

    #[test]
    fn zero_rhs() {
        let mut rng = Pcg32::seeded(33);
        let (a, _, _) = nonsym_system(&mut rng, 10);
        let mut x = vec![3.0; 10];
        let stats = qmr(&a, &vec![0.0; 10], &mut x, &SolverConfig::default());
        assert!(stats.converged);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn iteration_cap_respected() {
        let mut rng = Pcg32::seeded(34);
        let (a, b, _) = nonsym_system(&mut rng, 50);
        let mut x = vec![0.0; 50];
        let stats = qmr(&a, &b, &mut x, &SolverConfig { max_iters: 4, tol: 1e-16 });
        assert!(stats.iterations <= 4);
    }
}
