//! Dense linear algebra substrate: a row-major [`Matrix`] type with a
//! cache-blocked GEMM, vector helpers, and the iterative solvers used by the
//! training algorithms (CG, MINRES, QMR, BiCGStab).

pub mod matrix;
pub mod vecops;
pub mod solvers;

pub use matrix::Matrix;
pub use solvers::{LinOp, SolveStats};
