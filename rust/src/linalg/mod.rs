//! Dense linear algebra substrate: a row-major [`Matrix`] type backed by a
//! packed, register-blocked, thread-parallel GEMM ([`gemm`]), vector
//! helpers, a symmetric eigensolver ([`eig`]), and the iterative solvers
//! used by the training algorithms (CG, block CG, MINRES, QMR, BiCGStab).

pub mod eig;
pub mod gemm;
pub mod matrix;
pub mod vecops;
pub mod solvers;

pub use eig::{eigh, eigh_count, EigH};
pub use matrix::Matrix;
pub use solvers::{LinOp, MultiLinOp, SolveStats};
