//! Dense linear algebra substrate: a row-major [`Matrix`] type backed by a
//! packed, register-blocked, thread-parallel GEMM ([`gemm`]), vector
//! helpers, and the iterative solvers used by the training algorithms (CG,
//! block CG, MINRES, QMR, BiCGStab).

pub mod gemm;
pub mod matrix;
pub mod vecops;
pub mod solvers;

pub use matrix::Matrix;
pub use solvers::{LinOp, MultiLinOp, SolveStats};
