//! Packed, register-blocked GEMM — the dense compute core under every
//! kernel-matrix build and explicit baseline.
//!
//! Two entry points cover the shapes the library needs:
//!
//! * [`gemm_nt_into`] — `C = A·Bᵀ` with `B` given row-major (`n×k`), i.e.
//!   rows of `A` dotted with rows of `B`. This is the kernel-matrix shape
//!   (`kernels::compute::kernel_matrix` inner products) and needs no packing:
//!   the rows of `B` *are* the packed panel layout.
//! * [`gemm_nn_into`] — `C = A·B` with `B` row-major (`k×n`). The pack step
//!   is a blocked transpose of `B` into the same row-panel layout, after
//!   which the NT core runs unchanged.
//!
//! ### Blocking scheme
//!
//! * **Register tile** [`MR`]`×`[`NR`] (4×4): the micro-kernel holds the full
//!   tile of accumulators live across the shared k-loop, reusing each loaded
//!   `A` value `NR` times and each `B` value `MR` times, with the k-loop
//!   unrolled 4-wide so every accumulator is itself 4 independent partial
//!   sums (ILP / SIMD lanes).
//! * **Cache panel** [`NC`] (64 packed rows): the `j`-loop is blocked so the
//!   active `B` panel (`NC·k` doubles) stays resident in L1/L2 while the
//!   whole `A` row range streams past it.
//! * **Row-panel threads**: workers are std scoped threads (the same style as
//!   [`crate::gvt::engine`]), each owning a contiguous range of `C` rows —
//!   disjoint writes, no locks, no atomics.
//!
//! ### Determinism
//!
//! Every element of `C` is produced by exactly the reduction of
//! [`vecops::dot`](crate::linalg::vecops::dot): four k-strided partial sums
//! combined as `(s0+s1)+(s2+s3)+tail`. Consequences, both load-bearing:
//!
//! * the result is **bitwise identical for every thread count** (the row
//!   partition never changes any element's accumulation order), and
//! * [`gemm_nt_into`] is bitwise identical to a per-element
//!   `dot(a_row, b_row)` loop — which is what `kernel_row_into` computes, so
//!   the serving cache's "cached row == matrix row" guarantee survives the
//!   GEMM rewrite.
//!
//! The dense inner loops deliberately contain **no zero-skipping branches**:
//! on dense kernel data a mispredicted `if x == 0.0` costs more than the
//! multiply it skips (sparse shortcuts belong to the GVT stage-1 loops,
//! where they implement eq. 5 of the paper).

use crate::linalg::vecops::dot;

/// Register-tile rows (`A` rows per micro-kernel call).
pub const MR: usize = 4;
/// Register-tile columns (packed `B` rows per micro-kernel call).
pub const NR: usize = 4;
/// Packed-`B` rows per cache panel; the `j`-loop is blocked at this width so
/// the active panel (`NC·k` doubles) stays cache-resident.
pub const NC: usize = 64;

/// Below this many multiply-adds (`m·n·k`) the scoped-thread fan-out is not
/// worth its spawn cost and the core runs serially.
const MIN_PARALLEL_FLOPS: usize = 1 << 18;

/// `C = A·Bᵀ` for row-major `A (m×k)`, `B (n×k)`, into row-major `C (m×n)`
/// (overwritten). `threads = 0` uses all cores, `1` runs serially; results
/// are bitwise identical for every thread count.
pub fn gemm_nt_into(
    a: &[f64],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f64],
    threads: usize,
) {
    assert_eq!(a.len(), m * k, "A buffer size mismatch");
    assert_eq!(b.len(), n * k, "B buffer size mismatch");
    assert_eq!(c.len(), m * n, "C buffer size mismatch");
    let threads = resolve_threads(threads, m, n, k);
    if threads <= 1 {
        gemm_rows(a, b, k, n, 0, m, c);
        return;
    }
    let ranges = row_chunks(m, threads);
    std::thread::scope(|scope| {
        let mut rest = c;
        for &(i0, i1) in &ranges {
            let (slab, tail) = rest.split_at_mut((i1 - i0) * n);
            rest = tail;
            scope.spawn(move || gemm_rows(a, b, k, n, i0, i1, slab));
        }
    });
}

/// `C = A·B` for row-major `A (m×k)`, `B (k×n)`, into row-major `C (m×n)`
/// (overwritten). Packs `Bᵀ` once (blocked transpose into row-panel layout),
/// then runs the NT core. Same determinism guarantees as [`gemm_nt_into`].
pub fn gemm_nn_into(
    a: &[f64],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f64],
    threads: usize,
) {
    assert_eq!(b.len(), k * n, "B buffer size mismatch");
    let bt = pack_transpose(b, k, n);
    gemm_nt_into(a, &bt, m, k, n, c, threads);
}

/// Blocked transpose of a row-major `rows×cols` buffer into a new
/// `cols×rows` buffer — the pack step that turns `B`'s columns into the
/// contiguous row panels the micro-kernel consumes.
pub fn pack_transpose(src: &[f64], rows: usize, cols: usize) -> Vec<f64> {
    assert_eq!(src.len(), rows * cols, "pack buffer size mismatch");
    let mut dst = vec![0.0; rows * cols];
    const B: usize = 32;
    for ib in (0..rows).step_by(B) {
        for jb in (0..cols).step_by(B) {
            for i in ib..(ib + B).min(rows) {
                for j in jb..(jb + B).min(cols) {
                    dst[j * rows + i] = src[i * cols + j];
                }
            }
        }
    }
    dst
}

/// `0` → available parallelism; then clamp to what the problem size and row
/// count can use.
fn resolve_threads(threads: usize, m: usize, n: usize, k: usize) -> usize {
    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    } else {
        threads
    };
    if m.saturating_mul(n).saturating_mul(k) < MIN_PARALLEL_FLOPS {
        1
    } else {
        threads.min(m)
    }
}

/// Split `0..m` into at most `parts` contiguous non-empty equal-ish ranges.
fn row_chunks(m: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.clamp(1, m.max(1));
    let base = m / parts;
    let rem = m % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let size = base + usize::from(p < rem);
        if size > 0 {
            out.push((start, start + size));
            start += size;
        }
    }
    out
}

/// Serial core for `C` rows `i0..i1`: panel-blocked `j`-loop over packed `B`
/// rows, [`MR`]`×`[`NR`] register tiles inside, per-element [`dot`] fallback
/// on the tile tails (bitwise-identical reduction either way). `c` is the
/// slab holding rows `i0..i1` only.
fn gemm_rows(a: &[f64], bt: &[f64], k: usize, n: usize, i0: usize, i1: usize, c: &mut [f64]) {
    debug_assert_eq!(c.len(), (i1 - i0) * n);
    for jb in (0..n).step_by(NC) {
        let jend = (jb + NC).min(n);
        let mut i = i0;
        while i + MR <= i1 {
            let mut j = jb;
            while j + NR <= jend {
                micro_tile(a, bt, k, n, i, j, i0, c);
                j += NR;
            }
            for jj in j..jend {
                let brow = &bt[jj * k..(jj + 1) * k];
                for ir in 0..MR {
                    c[(i + ir - i0) * n + jj] = dot(&a[(i + ir) * k..(i + ir + 1) * k], brow);
                }
            }
            i += MR;
        }
        for ii in i..i1 {
            let arow = &a[ii * k..(ii + 1) * k];
            for jj in jb..jend {
                c[(ii - i0) * n + jj] = dot(arow, &bt[jj * k..(jj + 1) * k]);
            }
        }
    }
}

/// One full [`MR`]`×`[`NR`] register tile at `C[i.., j..]`, accumulated in
/// exactly [`dot`]'s reduction order per element: 4 k-strided partial sums,
/// a sequential tail, combined as `(s0+s1)+(s2+s3)+tail`.
#[inline]
#[allow(clippy::too_many_arguments)]
fn micro_tile(
    a: &[f64],
    bt: &[f64],
    k: usize,
    n: usize,
    i: usize,
    j: usize,
    i0: usize,
    c: &mut [f64],
) {
    let ar: [&[f64]; MR] = [
        &a[i * k..(i + 1) * k],
        &a[(i + 1) * k..(i + 2) * k],
        &a[(i + 2) * k..(i + 3) * k],
        &a[(i + 3) * k..(i + 4) * k],
    ];
    let br: [&[f64]; NR] = [
        &bt[j * k..(j + 1) * k],
        &bt[(j + 1) * k..(j + 2) * k],
        &bt[(j + 2) * k..(j + 3) * k],
        &bt[(j + 3) * k..(j + 4) * k],
    ];
    let mut acc = [[[0.0f64; 4]; NR]; MR];
    let kc = k - k % 4;
    let mut kk = 0;
    while kk < kc {
        for ir in 0..MR {
            let arow = ar[ir];
            let av = [arow[kk], arow[kk + 1], arow[kk + 2], arow[kk + 3]];
            for jr in 0..NR {
                let brow = br[jr];
                let t = &mut acc[ir][jr];
                t[0] += av[0] * brow[kk];
                t[1] += av[1] * brow[kk + 1];
                t[2] += av[2] * brow[kk + 2];
                t[3] += av[3] * brow[kk + 3];
            }
        }
        kk += 4;
    }
    for ir in 0..MR {
        let arow = ar[ir];
        for jr in 0..NR {
            let brow = br[jr];
            let mut tail = 0.0;
            for kt in kc..k {
                tail += arow[kt] * brow[kt];
            }
            let t = acc[ir][jr];
            c[(i + ir - i0) * n + j + jr] = (t[0] + t[1]) + (t[2] + t[3]) + tail;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Per-element `dot` reference — the reduction the GEMM must match
    /// bitwise.
    fn dot_reference_nt(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                c[i * n + j] = dot(&a[i * k..(i + 1) * k], &b[j * k..(j + 1) * k]);
            }
        }
        c
    }

    /// Plain sequential triple loop (different association → approximate).
    fn naive_nn(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a[i * k + kk] * b[kk * n + j];
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    fn random_vec(rng: &mut Pcg32, len: usize) -> Vec<f64> {
        (0..len).map(|_| rng.normal()).collect()
    }

    /// Shapes that hit every tail path: 1×1, primes, exact-tile multiples,
    /// k % 4 ∈ {0,1,2,3}.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (2, 3, 2),
        (4, 4, 4),
        (5, 7, 3),
        (7, 11, 13),
        (8, 16, 8),
        (9, 5, 6),
        (17, 33, 9),
        (12, 4, 64),
        (70, 65, 130),
    ];

    #[test]
    fn nt_matches_dot_reference_bitwise() {
        let mut rng = Pcg32::seeded(0xA11CE);
        for &(m, k, n) in SHAPES {
            let a = random_vec(&mut rng, m * k);
            let b = random_vec(&mut rng, n * k);
            let reference = dot_reference_nt(&a, &b, m, k, n);
            for threads in [1, 2, 3, 8] {
                let mut c = vec![f64::NAN; m * n];
                gemm_nt_into(&a, &b, m, k, n, &mut c, threads);
                assert_eq!(c, reference, "m={m} k={k} n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn nn_matches_dot_reference_bitwise() {
        let mut rng = Pcg32::seeded(0xB0B);
        for &(m, k, n) in SHAPES {
            let a = random_vec(&mut rng, m * k);
            let b = random_vec(&mut rng, k * n);
            let bt = pack_transpose(&b, k, n);
            let reference = dot_reference_nt(&a, &bt, m, k, n);
            for threads in [1, 4] {
                let mut c = vec![f64::NAN; m * n];
                gemm_nn_into(&a, &b, m, k, n, &mut c, threads);
                assert_eq!(c, reference, "m={m} k={k} n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn nn_close_to_sequential_naive() {
        let mut rng = Pcg32::seeded(0xC0DE);
        for &(m, k, n) in SHAPES {
            let a = random_vec(&mut rng, m * k);
            let b = random_vec(&mut rng, k * n);
            let mut c = vec![0.0; m * n];
            gemm_nn_into(&a, &b, m, k, n, &mut c, 1);
            let naive = naive_nn(&a, &b, m, k, n);
            crate::linalg::vecops::assert_allclose(&c, &naive, 1e-9, 1e-9);
        }
    }

    #[test]
    fn pack_transpose_is_exact() {
        let mut rng = Pcg32::seeded(0xFACE);
        for &(rows, cols) in &[(1usize, 1usize), (3, 5), (33, 40), (64, 64)] {
            let src = random_vec(&mut rng, rows * cols);
            let dst = pack_transpose(&src, rows, cols);
            for i in 0..rows {
                for j in 0..cols {
                    assert_eq!(dst[j * rows + i], src[i * cols + j]);
                }
            }
        }
    }

    #[test]
    fn empty_k_yields_zeros() {
        let mut c = vec![f64::NAN; 6];
        gemm_nt_into(&[], &[], 2, 0, 3, &mut c, 1);
        assert!(c.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn zero_threads_autodetects() {
        // threads = 0 must not panic and must match serial bitwise.
        let mut rng = Pcg32::seeded(0xD1E);
        let (m, k, n) = (40, 50, 45);
        let a = random_vec(&mut rng, m * k);
        let b = random_vec(&mut rng, n * k);
        let mut serial = vec![0.0; m * n];
        let mut auto = vec![0.0; m * n];
        gemm_nt_into(&a, &b, m, k, n, &mut serial, 1);
        gemm_nt_into(&a, &b, m, k, n, &mut auto, 0);
        assert_eq!(serial, auto);
    }
}
