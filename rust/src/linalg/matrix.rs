//! Row-major dense matrix type.
//!
//! The explicit-kernel baselines (the methods the paper beats) need real
//! dense matmuls over matrices with 10⁴–10⁵ rows, so [`Matrix::matmul`],
//! [`Matrix::matmul_into`], and [`Matrix::matmul_nt`] all delegate to the
//! packed, register-blocked GEMM core in [`crate::linalg::gemm`] (which is
//! also what the native kernel-matrix computation uses); the `*_threaded`
//! variants shard the same GEMM over scoped worker threads with bitwise
//! identical results.

use crate::linalg::{gemm, vecops};

/// Dense row-major `rows × cols` matrix of f64.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Matrix { rows, cols, data }
    }

    /// Build from nested rows (testing convenience).
    pub fn from_rows(rows: &[&[f64]]) -> Matrix {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Element at `(i, j)`.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    /// Overwrite element `(i, j)`.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Accumulate `v` into element `(i, j)`.
    #[inline]
    pub fn add_at(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] += v;
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Borrow row `i` mutably.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Raw data (row-major).
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable raw data (row-major).
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the raw buffer.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// Matrix–vector product `y = A x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "matvec dim mismatch");
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// Matrix–vector product into a preallocated output.
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            y[i] = vecops::dot(self.row(i), x);
        }
    }

    /// Transposed matrix–vector product `y = Aᵀ x`.
    ///
    /// The inner loop is branch-free: on the dense matrices this type holds,
    /// testing `x[i]` for zero costs more in mispredictions than the skipped
    /// AXPY saves (sparse shortcuts live in the GVT stage-1 loops instead).
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows, "matvec_t dim mismatch");
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            vecops::axpy(x[i], self.row(i), &mut y);
        }
        y
    }

    /// Matrix product `C = A · B` through the packed, register-blocked GEMM
    /// ([`crate::linalg::gemm`]).
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows, "matmul dim mismatch");
        let mut c = Matrix::zeros(self.rows, b.cols);
        self.matmul_into(b, &mut c);
        c
    }

    /// [`Matrix::matmul`] sharded over `threads` scoped worker threads
    /// (`0` = all cores, `1` = serial); bitwise identical for every thread
    /// count.
    pub fn matmul_threaded(&self, b: &Matrix, threads: usize) -> Matrix {
        assert_eq!(self.cols, b.rows, "matmul dim mismatch");
        let mut c = Matrix::zeros(self.rows, b.cols);
        gemm::gemm_nn_into(&self.data, &b.data, self.rows, self.cols, b.cols, &mut c.data, threads);
        c
    }

    /// `C = A · B` into a preallocated output (C is overwritten). Delegates
    /// to the packed GEMM core ([`crate::linalg::gemm::gemm_nn_into`]).
    pub fn matmul_into(&self, b: &Matrix, c: &mut Matrix) {
        assert_eq!(self.cols, b.rows);
        assert_eq!(c.rows, self.rows);
        assert_eq!(c.cols, b.cols);
        gemm::gemm_nn_into(&self.data, &b.data, self.rows, self.cols, b.cols, &mut c.data, 1);
    }

    /// `C = A · Bᵀ` without forming Bᵀ (rows of A dotted with rows of B),
    /// through the packed GEMM core. Every output element is bitwise
    /// identical to `vecops::dot(a.row(i), b.row(j))`.
    pub fn matmul_nt(&self, b: &Matrix) -> Matrix {
        self.matmul_nt_threaded(b, 1)
    }

    /// [`Matrix::matmul_nt`] sharded over `threads` scoped worker threads
    /// (`0` = all cores, `1` = serial); bitwise identical for every thread
    /// count.
    pub fn matmul_nt_threaded(&self, b: &Matrix, threads: usize) -> Matrix {
        assert_eq!(self.cols, b.cols, "matmul_nt dim mismatch");
        let mut c = Matrix::zeros(self.rows, b.rows);
        gemm::gemm_nt_into(&self.data, &b.data, self.rows, self.cols, b.rows, &mut c.data, threads);
        c
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        vecops::dot(&self.data, &self.data).sqrt()
    }

    /// Symmetrize in place: `A ← (A + Aᵀ)/2` (numerical hygiene for kernel
    /// matrices).
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self.get(i, j) + self.get(j, i));
                self.set(i, j, v);
                self.set(j, i, v);
            }
        }
    }

    /// Add `alpha` to the diagonal.
    pub fn add_diag(&mut self, alpha: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self.data[i * self.cols + i] += alpha;
        }
    }

    /// Kronecker product `self ⊗ other` — materializes the full product.
    /// Only used by tests and the explicit baselines; the whole point of the
    /// library is to avoid calling this on large inputs.
    pub fn kron(&self, other: &Matrix) -> Matrix {
        let (a, b) = (self.rows, self.cols);
        let (c, d) = (other.rows, other.cols);
        let mut out = Matrix::zeros(a * c, b * d);
        for i in 0..a {
            for j in 0..b {
                let v = self.get(i, j);
                if v == 0.0 {
                    continue;
                }
                for k in 0..c {
                    for l in 0..d {
                        out.set(i * c + k, j * d + l, v * other.get(k, l));
                    }
                }
            }
        }
        out
    }

    /// Cholesky factorization (lower triangular) for SPD matrices.
    /// Returns `None` if the matrix is not (numerically) positive definite.
    pub fn cholesky(&self) -> Option<Matrix> {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let mut l = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = self.get(i, j);
                for k in 0..j {
                    sum -= l.get(i, k) * l.get(j, k);
                }
                if i == j {
                    if sum <= 0.0 {
                        return None;
                    }
                    l.set(i, j, sum.sqrt());
                } else {
                    l.set(i, j, sum / l.get(j, j));
                }
            }
        }
        Some(l)
    }

    /// Solve `A x = b` via Cholesky (A must be SPD). Returns `None` if the
    /// factorization fails.
    pub fn solve_spd(&self, b: &[f64]) -> Option<Vec<f64>> {
        let l = self.cholesky()?;
        let n = self.rows;
        // forward solve L y = b
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut sum = b[i];
            for k in 0..i {
                sum -= l.get(i, k) * y[k];
            }
            y[i] = sum / l.get(i, i);
        }
        // back solve Lᵀ x = y
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut sum = y[i];
            for k in (i + 1)..n {
                sum -= l.get(k, i) * x[k];
            }
            x[i] = sum / l.get(i, i);
        }
        Some(x)
    }

    /// Select rows by index into a new matrix.
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn random_matrix(rng: &mut Pcg32, r: usize, c: usize) -> Matrix {
        Matrix::from_fn(r, c, |_, _| rng.normal())
    }

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a.get(i, k) * b.get(k, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg32::seeded(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 33, 9), (64, 64, 64), (70, 130, 65)] {
            let a = random_matrix(&mut rng, m, k);
            let b = random_matrix(&mut rng, k, n);
            let c = a.matmul(&b);
            let c_ref = naive_matmul(&a, &b);
            assert!((0..m * n).all(|i| (c.data()[i] - c_ref.data()[i]).abs() < 1e-9));
        }
    }

    #[test]
    fn matmul_nt_matches() {
        let mut rng = Pcg32::seeded(2);
        let a = random_matrix(&mut rng, 13, 7);
        let b = random_matrix(&mut rng, 11, 7);
        let c1 = a.matmul_nt(&b);
        let c2 = a.matmul(&b.transpose());
        crate::linalg::vecops::assert_allclose(c1.data(), c2.data(), 1e-10, 1e-10);
    }

    #[test]
    fn matmul_nt_is_bitwise_dot_per_element() {
        // kernel_row_into ↔ kernel_matrix bitwise equality (the serving
        // cache's contract) rests on this: every matmul_nt element must be
        // exactly dot(a_row, b_row).
        let mut rng = Pcg32::seeded(21);
        let a = random_matrix(&mut rng, 19, 13);
        let b = random_matrix(&mut rng, 23, 13);
        let c = a.matmul_nt(&b);
        for i in 0..19 {
            for j in 0..23 {
                assert_eq!(c.get(i, j), crate::linalg::vecops::dot(a.row(i), b.row(j)));
            }
        }
    }

    #[test]
    fn threaded_matmuls_match_serial_bitwise() {
        let mut rng = Pcg32::seeded(22);
        let a = random_matrix(&mut rng, 37, 29);
        let b = random_matrix(&mut rng, 29, 41);
        let bt = random_matrix(&mut rng, 41, 29);
        let serial_nn = a.matmul(&b);
        let serial_nt = a.matmul_nt(&bt);
        for threads in [2, 4, 0] {
            assert_eq!(a.matmul_threaded(&b, threads), serial_nn, "nn threads={threads}");
            assert_eq!(a.matmul_nt_threaded(&bt, threads), serial_nt, "nt threads={threads}");
        }
    }

    #[test]
    fn matvec_consistent_with_matmul() {
        let mut rng = Pcg32::seeded(3);
        let a = random_matrix(&mut rng, 9, 14);
        let x: Vec<f64> = (0..14).map(|i| i as f64).collect();
        let y = a.matvec(&x);
        let xm = Matrix::from_vec(14, 1, x.clone());
        let ym = a.matmul(&xm);
        crate::linalg::vecops::assert_allclose(&y, ym.data(), 1e-10, 1e-10);
    }

    #[test]
    fn matvec_t_matches_transpose() {
        let mut rng = Pcg32::seeded(4);
        let a = random_matrix(&mut rng, 8, 5);
        let x: Vec<f64> = (0..8).map(|i| (i as f64).cos()).collect();
        let y1 = a.matvec_t(&x);
        let y2 = a.transpose().matvec(&x);
        crate::linalg::vecops::assert_allclose(&y1, &y2, 1e-12, 1e-12);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg32::seeded(5);
        let a = random_matrix(&mut rng, 37, 53);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn kron_small() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Matrix::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let k = a.kron(&b);
        assert_eq!(k.rows(), 4);
        assert_eq!(k.get(0, 1), 1.0); // a00*b01
        assert_eq!(k.get(0, 3), 2.0); // a01*b01
        assert_eq!(k.get(3, 0), 3.0); // a10*b10
    }

    #[test]
    fn kron_vec_trick_identity() {
        // (Nᵀ ⊗ M) vec(Q) = vec(M Q N)  — Roth's column lemma, with vec =
        // column stacking. Our buffers are row-major, so vec(A) = data of Aᵀ.
        let mut rng = Pcg32::seeded(6);
        let m = random_matrix(&mut rng, 3, 4);
        let q = random_matrix(&mut rng, 4, 2);
        let n = random_matrix(&mut rng, 2, 5);
        let vec_q = q.transpose().into_vec(); // column-major vec(Q)
        let lhs = n.transpose().kron(&m).matvec(&vec_q);
        let mqn = m.matmul(&q).matmul(&n);
        let rhs = mqn.transpose().into_vec();
        crate::linalg::vecops::assert_allclose(&lhs, &rhs, 1e-10, 1e-10);
    }

    #[test]
    fn cholesky_solve() {
        let mut rng = Pcg32::seeded(7);
        let n = 12;
        let g = random_matrix(&mut rng, n, n);
        let mut spd = g.matmul_nt(&g); // G Gᵀ is PSD
        spd.add_diag(0.5);
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64 * 0.3).sin()).collect();
        let b = spd.matvec(&x_true);
        let x = spd.solve_spd(&b).unwrap();
        crate::linalg::vecops::assert_allclose(&x, &x_true, 1e-8, 1e-8);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        assert!(a.cholesky().is_none());
    }

    #[test]
    fn select_rows_works() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let s = a.select_rows(&[2, 0]);
        assert_eq!(s.row(0), &[5.0, 6.0]);
        assert_eq!(s.row(1), &[1.0, 2.0]);
    }

    #[test]
    fn symmetrize_and_diag() {
        let mut a = Matrix::from_rows(&[&[1.0, 2.0], &[4.0, 1.0]]);
        a.symmetrize();
        assert_eq!(a.get(0, 1), 3.0);
        assert_eq!(a.get(1, 0), 3.0);
        a.add_diag(1.0);
        assert_eq!(a.get(0, 0), 2.0);
    }
}
