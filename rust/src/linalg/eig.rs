//! Symmetric eigendecomposition `A = Q Λ Qᵀ` for dense kernel matrices.
//!
//! Classical two-stage dense route: Householder tridiagonalization with
//! accumulated transformations (`tred2`) followed by implicit-shift QL
//! iteration with eigenvector accumulation (`tql2`) — the EISPACK pair, which
//! is deterministic, allocation-light, and zero-dependency like the rest of
//! the crate. `O(n³)` over the *factor* matrices (`q×q` and `m×m`), never
//! over the `n×n` pairwise kernel matrix.
//!
//! This powers the complete-graph fast paths of
//! [`crate::train::ridge`]: the closed-form ridge solve, the Kronecker
//! spectral preconditioner
//! ([`KronSpectralPrecond`](crate::gvt::operator::KronSpectralPrecond)), and
//! the leave-one-out shortcut — each consumes one [`eigh`] per kernel factor.
//!
//! Every decomposition bumps a thread-local counter ([`eigh_count`]) so tests
//! can pin *how many* decompositions a fast path performs, not just that its
//! numbers come out right.

use std::cell::Cell;

use crate::linalg::Matrix;

thread_local! {
    static EIGH_CALLS: Cell<usize> = const { Cell::new(0) };
}

/// Number of [`eigh`] decompositions performed **by the calling thread** so
/// far. Thread-local, so concurrently running tests cannot race each other's
/// counts; read it before and after an operation and compare the delta (e.g.
/// a whole-λ-grid [`fit_path`](crate::train::KronRidge::fit_path) on a
/// complete graph must cost exactly two — one per kernel factor).
pub fn eigh_count() -> usize {
    EIGH_CALLS.with(|c| c.get())
}

/// A symmetric eigendecomposition `A = Q Λ Qᵀ`.
#[derive(Debug, Clone)]
pub struct EigH {
    /// Eigenvalues in ascending order.
    pub values: Vec<f64>,
    /// Orthonormal eigenvectors, one per **column**: column `j` pairs with
    /// `values[j]`.
    pub vectors: Matrix,
}

impl EigH {
    /// Rebuild `Q Λ Qᵀ` (testing helper; `≈ A` up to roundoff).
    pub fn reconstruct(&self) -> Matrix {
        let n = self.values.len();
        let mut ql = Matrix::zeros(n, n);
        for i in 0..n {
            for (j, &lj) in self.values.iter().enumerate() {
                ql.set(i, j, self.vectors.get(i, j) * lj);
            }
        }
        ql.matmul_nt(&self.vectors)
    }
}

/// Decompose a symmetric matrix into eigenvalues (ascending) and orthonormal
/// eigenvectors. Only the values actually stored in `a` are read — the caller
/// is responsible for symmetry (kernel matrices are symmetric by
/// construction; [`Matrix::symmetrize`] is available otherwise). Deterministic:
/// identical input bits give identical output bits on every call and thread
/// count.
///
/// Panics if `a` is not square.
pub fn eigh(a: &Matrix) -> EigH {
    let n = a.rows();
    assert_eq!(n, a.cols(), "eigh requires a square matrix");
    EIGH_CALLS.with(|c| c.set(c.get() + 1));
    if n == 0 {
        return EigH { values: Vec::new(), vectors: Matrix::zeros(0, 0) };
    }
    let mut v = a.data().to_vec();
    let mut d = vec![0.0; n];
    let mut e = vec![0.0; n];
    tred2(&mut v, &mut d, &mut e, n);
    tql2(&mut v, &mut d, &mut e, n);
    EigH { values: d, vectors: Matrix::from_vec(n, n, v) }
}

/// Iteration cap per eigenvalue in the QL sweep. EISPACK's `tql2` iterates
/// unboundedly; in IEEE arithmetic the shift strategy converges cubically and
/// essentially never needs more than a handful of sweeps, so hitting the cap
/// means the off-diagonal has stalled at roundoff level — we accept the
/// current (fully converged in practice) value rather than loop forever.
const MAX_QL_ITERS: usize = 64;

// The two routines below are direct translations of the EISPACK/JAMA
// `tred2`/`tql2` pair; the index-heavy loops mirror the published algorithm
// so it can be audited line by line against the reference.
#[allow(clippy::needless_range_loop)]
fn tred2(v: &mut [f64], d: &mut [f64], e: &mut [f64], n: usize) {
    for j in 0..n {
        d[j] = v[(n - 1) * n + j];
    }

    // Householder reduction to tridiagonal form.
    for i in (1..n).rev() {
        let mut scale = 0.0;
        let mut h = 0.0;
        for k in 0..i {
            scale += d[k].abs();
        }
        if scale == 0.0 {
            e[i] = d[i - 1];
            for j in 0..i {
                d[j] = v[(i - 1) * n + j];
                v[i * n + j] = 0.0;
                v[j * n + i] = 0.0;
            }
        } else {
            // Generate the Householder vector.
            for k in 0..i {
                d[k] /= scale;
                h += d[k] * d[k];
            }
            let f = d[i - 1];
            let mut g = h.sqrt();
            if f > 0.0 {
                g = -g;
            }
            e[i] = scale * g;
            h -= f * g;
            d[i - 1] = f - g;
            for j in 0..i {
                e[j] = 0.0;
            }
            // Apply the similarity transformation to the remaining columns.
            for j in 0..i {
                let f = d[j];
                v[j * n + i] = f;
                let mut g = e[j] + v[j * n + j] * f;
                for k in j + 1..i {
                    g += v[k * n + j] * d[k];
                    e[k] += v[k * n + j] * f;
                }
                e[j] = g;
            }
            let mut f = 0.0;
            for j in 0..i {
                e[j] /= h;
                f += e[j] * d[j];
            }
            let hh = f / (h + h);
            for j in 0..i {
                e[j] -= hh * d[j];
            }
            for j in 0..i {
                let f = d[j];
                let g = e[j];
                for k in j..i {
                    v[k * n + j] -= f * e[k] + g * d[k];
                }
                d[j] = v[(i - 1) * n + j];
                v[i * n + j] = 0.0;
            }
        }
        d[i] = h;
    }

    // Accumulate the transformations.
    for i in 0..n - 1 {
        v[(n - 1) * n + i] = v[i * n + i];
        v[i * n + i] = 1.0;
        let h = d[i + 1];
        if h != 0.0 {
            for k in 0..=i {
                d[k] = v[k * n + i + 1] / h;
            }
            for j in 0..=i {
                let mut g = 0.0;
                for k in 0..=i {
                    g += v[k * n + i + 1] * v[k * n + j];
                }
                for k in 0..=i {
                    v[k * n + j] -= g * d[k];
                }
            }
        }
        for k in 0..=i {
            v[k * n + i + 1] = 0.0;
        }
    }
    for j in 0..n {
        d[j] = v[(n - 1) * n + j];
        v[(n - 1) * n + j] = 0.0;
    }
    v[(n - 1) * n + n - 1] = 1.0;
    e[0] = 0.0;
}

#[allow(clippy::needless_range_loop)]
fn tql2(v: &mut [f64], d: &mut [f64], e: &mut [f64], n: usize) {
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;

    let mut f = 0.0f64;
    let mut tst1 = 0.0f64;
    let eps = f64::EPSILON;
    for l in 0..n {
        // Find a small subdiagonal element.
        tst1 = tst1.max(d[l].abs() + e[l].abs());
        let mut m = l;
        while m < n {
            if e[m].abs() <= eps * tst1 {
                break;
            }
            m += 1;
        }
        // An eigenvalue has converged once the subdiagonal at `l` vanishes;
        // otherwise run implicit-shift QL sweeps on the `l..=m` block.
        if m > l {
            let mut iters = 0;
            loop {
                iters += 1;
                // Compute the implicit shift.
                let g = d[l];
                let mut p = (d[l + 1] - g) / (2.0 * e[l]);
                let mut r = p.hypot(1.0);
                if p < 0.0 {
                    r = -r;
                }
                d[l] = e[l] / (p + r);
                d[l + 1] = e[l] * (p + r);
                let dl1 = d[l + 1];
                let mut h = g - d[l];
                for i in l + 2..n {
                    d[i] -= h;
                }
                f += h;
                // Implicit QL sweep with accumulated Givens rotations.
                p = d[m];
                let mut c = 1.0f64;
                let mut c2 = c;
                let mut c3 = c;
                let el1 = e[l + 1];
                let mut s = 0.0f64;
                let mut s2 = 0.0f64;
                for i in (l..m).rev() {
                    c3 = c2;
                    c2 = c;
                    s2 = s;
                    let g = c * e[i];
                    h = c * p;
                    r = p.hypot(e[i]);
                    e[i + 1] = s * r;
                    s = e[i] / r;
                    c = p / r;
                    p = c * d[i] - s * g;
                    d[i + 1] = h + s * (c * g + s * d[i]);
                    for k in 0..n {
                        let h = v[k * n + i + 1];
                        v[k * n + i + 1] = s * v[k * n + i] + c * h;
                        v[k * n + i] = c * v[k * n + i] - s * h;
                    }
                }
                p = -s * s2 * c3 * el1 * e[l] / dl1;
                e[l] = s * p;
                d[l] = c * p;
                if e[l].abs() <= eps * tst1 || iters >= MAX_QL_ITERS {
                    break;
                }
            }
        }
        d[l] += f;
        e[l] = 0.0;
    }

    // Sort eigenvalues ascending, carrying eigenvector columns along.
    for i in 0..n.saturating_sub(1) {
        let mut k = i;
        let mut p = d[i];
        for j in i + 1..n {
            if d[j] < p {
                k = j;
                p = d[j];
            }
        }
        if k != i {
            d.swap(i, k);
            for j in 0..n {
                v.swap(j * n + i, j * n + k);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::assert_allclose;
    use crate::util::proptest;

    /// `QᵀQ = I` within `tol`.
    fn assert_orthonormal(q: &Matrix, tol: f64) {
        let gram = q.transpose().matmul(q);
        let n = q.rows();
        let eye = Matrix::eye(n);
        assert_allclose(gram.data(), eye.data(), tol, tol);
    }

    #[test]
    fn reconstructs_random_spd_matrices() {
        proptest::check(0xE16, |rng| {
            let n = 1 + rng.below(20);
            let a = proptest::spd_matrix(rng, n);
            let eig = eigh(&a);
            assert_allclose(eig.reconstruct().data(), a.data(), 1e-10, 1e-10);
        });
    }

    #[test]
    fn eigenvectors_are_orthonormal() {
        proptest::check(0xE17, |rng| {
            let n = 1 + rng.below(16);
            let a = proptest::spd_matrix(rng, n);
            assert_orthonormal(&eigh(&a).vectors, 1e-10);
        });
    }

    #[test]
    fn eigenvalues_are_ascending_and_positive_for_spd() {
        proptest::check(0xE18, |rng| {
            let n = 1 + rng.below(16);
            let a = proptest::spd_matrix(rng, n);
            let eig = eigh(&a);
            for w in eig.values.windows(2) {
                assert!(w[0] <= w[1], "not ascending: {:?}", eig.values);
            }
            assert!(eig.values[0] > 0.0, "SPD matrix with eigenvalue {}", eig.values[0]);
        });
    }

    #[test]
    fn matches_2x2_closed_form() {
        proptest::check(0xE19, |rng| {
            let (a, b, c) = (rng.normal(), rng.normal(), rng.normal());
            let mat = Matrix::from_vec(2, 2, vec![a, b, b, c]);
            let disc = ((a - c) * (a - c) + 4.0 * b * b).sqrt();
            let want = [(a + c - disc) / 2.0, (a + c + disc) / 2.0];
            let eig = eigh(&mat);
            assert_allclose(&eig.values, &want, 1e-12, 1e-12);
        });
    }

    #[test]
    fn matches_3x3_closed_form() {
        // Second-difference matrix: eigenvalues 2 − √2, 2, 2 + √2.
        let a = Matrix::from_vec(3, 3, vec![2.0, -1.0, 0.0, -1.0, 2.0, -1.0, 0.0, -1.0, 2.0]);
        let eig = eigh(&a);
        let s = 2.0f64.sqrt();
        assert_allclose(&eig.values, &[2.0 - s, 2.0, 2.0 + s], 1e-13, 1e-13);
        assert_orthonormal(&eig.vectors, 1e-13);
        assert_allclose(eig.reconstruct().data(), a.data(), 1e-13, 1e-13);
    }

    #[test]
    fn diagonal_matrix_eigenvalues_are_sorted_diagonal() {
        let a = Matrix::from_fn(4, 4, |i, j| if i == j { [3.0, -1.0, 7.0, 0.5][i] } else { 0.0 });
        let eig = eigh(&a);
        assert_allclose(&eig.values, &[-1.0, 0.5, 3.0, 7.0], 1e-14, 1e-14);
    }

    #[test]
    fn handles_indefinite_symmetric_matrices() {
        proptest::check(0xE1A, |rng| {
            let n = 2 + rng.below(10);
            let mut a = Matrix::from_fn(n, n, |_, _| rng.normal());
            a.symmetrize();
            let eig = eigh(&a);
            assert_allclose(eig.reconstruct().data(), a.data(), 1e-10, 1e-10);
            assert_orthonormal(&eig.vectors, 1e-10);
        });
    }

    #[test]
    fn one_by_one_and_empty_matrices() {
        let eig = eigh(&Matrix::from_vec(1, 1, vec![4.5]));
        assert_eq!(eig.values, vec![4.5]);
        assert_eq!(eig.vectors.get(0, 0), 1.0);
        let empty = eigh(&Matrix::zeros(0, 0));
        assert!(empty.values.is_empty());
    }

    #[test]
    fn decomposition_is_deterministic() {
        let mut rng = crate::util::rng::Pcg32::seeded(0xE1B);
        let a = proptest::spd_matrix(&mut rng, 9);
        let e1 = eigh(&a);
        let e2 = eigh(&a);
        assert_eq!(e1.values, e2.values);
        assert_eq!(e1.vectors.data(), e2.vectors.data());
    }

    #[test]
    fn counter_tracks_calls_on_this_thread() {
        let mut rng = crate::util::rng::Pcg32::seeded(0xE1C);
        let a = proptest::spd_matrix(&mut rng, 5);
        let before = eigh_count();
        let _ = eigh(&a);
        let _ = eigh(&a);
        assert_eq!(eigh_count() - before, 2);
    }
}
