//! Vector operations shared by the solvers and training loops.

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: better ILP and deterministic ordering.
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut tail = 0.0;
    for j in chunks * 4..n {
        tail += a[j] * b[j];
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `y = alpha * x + beta * y`.
#[inline]
pub fn axpby(alpha: f64, x: &[f64], beta: f64, y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = alpha * xi + beta * *yi;
    }
}

/// `x *= alpha`.
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for xi in x {
        *xi *= alpha;
    }
}

/// Elementwise subtraction `out = a - b`.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Max absolute difference between two vectors.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

/// Assert two vectors are close (testing helper).
pub fn assert_allclose(a: &[f64], b: &[f64], atol: f64, rtol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        assert!(
            (x - y).abs() <= tol,
            "element {i}: {x} vs {y} (|diff|={} > tol={tol})",
            (x - y).abs()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..37).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..37).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-10);
    }

    #[test]
    fn axpy_works() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
    }

    #[test]
    fn axpby_works() {
        let x = vec![1.0, 2.0];
        let mut y = vec![3.0, 4.0];
        axpby(2.0, &x, 0.5, &mut y);
        assert_eq!(y, vec![3.5, 6.0]);
    }

    #[test]
    fn norms() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
    }

    #[test]
    #[should_panic]
    fn allclose_detects_mismatch() {
        assert_allclose(&[1.0], &[2.0], 1e-8, 1e-8);
    }
}
