//! The general truncated-Newton framework of §3.2–3.3 (Algorithms 2 and 3)
//! for *any* Table-2 loss — ridge and L2-SVM have specialized trainers
//! ([`super::ridge`], [`super::svm`]); this module additionally enables
//! logistic regression and RankRLS with the Kronecker product kernel.
//!
//! Dual Newton system (eq. 9):  `(H·R(G⊗K)Rᵀ + λI) x = g + λa`.
//! Primal Newton system:        `(XᵀHX + λI) x = Xᵀg + λw`, `X = R(T⊗D)`.

use crate::api::Compute;
use crate::data::Dataset;
use crate::eval::auc::auc;
use crate::gvt::{PairwiseKernelKind, PairwiseOp};
use crate::kernels::KernelKind;
use crate::linalg::solvers::{cg, qmr, FnOp, LinOp, SolverConfig};
use crate::linalg::vecops::dot;
use crate::losses::Loss;
use crate::model::primal::{PrimalKronOp, PrimalNewtonOp};
use crate::model::{DualModel, PrimalModel};
use crate::train::ridge::{dual_kernel_op, validation_op};
use crate::train::trace::{IterRecord, TrainTrace};
use crate::util::timer::Timer;

/// Configuration for the generic truncated-Newton trainer.
#[derive(Debug, Clone, Copy)]
pub struct NewtonConfig {
    /// Regularization parameter λ.
    pub lambda: f64,
    /// Start-vertex kernel `k`.
    pub kernel_d: KernelKind,
    /// End-vertex kernel `g`.
    pub kernel_t: KernelKind,
    /// Outer (truncated Newton) iterations.
    pub outer_iters: usize,
    /// Inner (QMR / CG) iterations per Newton step.
    pub inner_iters: usize,
    /// Step size δ (constant, as in the paper's experiments).
    pub delta: f64,
    /// Record per-outer-iteration risk/AUC.
    pub trace: bool,
    /// Early-stopping patience on validation AUC (0 disables).
    pub patience: usize,
}

impl Default for NewtonConfig {
    fn default() -> Self {
        NewtonConfig {
            lambda: 1.0,
            kernel_d: KernelKind::Linear,
            kernel_t: KernelKind::Linear,
            outer_iters: 10,
            inner_iters: 10,
            delta: 1.0,
            trace: false,
            patience: 0,
        }
    }
}

/// Truncated-Newton trainer over an arbitrary [`Loss`].
///
/// Method-specific knobs live in [`NewtonConfig`]; the pairwise kernel
/// family and the execution policy are set with
/// [`NewtonTrainer::with_pairwise`] / [`NewtonTrainer::with_compute`] (or
/// through the [`Learner`](crate::api::Learner) builder).
pub struct NewtonTrainer<L: Loss> {
    /// Training configuration.
    pub cfg: NewtonConfig,
    /// The loss being optimized.
    pub loss: L,
    /// Pairwise kernel family composed over the GVT engine.
    pub pairwise: PairwiseKernelKind,
    /// Execution policy (threads, workspace retention); transparent to
    /// results.
    pub compute: Compute,
}

impl<L: Loss> NewtonTrainer<L> {
    /// Trainer for `loss` with the given configuration, the Kronecker
    /// pairwise family, and the default (serial) execution policy.
    pub fn new(loss: L, cfg: NewtonConfig) -> Self {
        NewtonTrainer {
            cfg,
            loss,
            pairwise: PairwiseKernelKind::Kronecker,
            compute: Compute::default(),
        }
    }

    /// Select the pairwise kernel family composed over the GVT engine.
    pub fn with_pairwise(mut self, pairwise: PairwiseKernelKind) -> Self {
        self.pairwise = pairwise;
        self
    }

    /// Set the execution policy (threads, workspace retention). Results are
    /// bitwise identical for every policy.
    pub fn with_compute(mut self, compute: Compute) -> Self {
        self.compute = compute;
        self
    }

    /// Algorithm 2 (dual).
    pub fn fit_dual(
        &self,
        train: &Dataset,
        val: Option<&Dataset>,
    ) -> Result<(DualModel, TrainTrace), String> {
        train.validate()?;
        let n = train.n_edges();
        if n == 0 {
            return Err("empty training set".into());
        }
        let timer = Timer::start();
        let op = dual_kernel_op(
            train,
            self.cfg.kernel_d,
            self.cfg.kernel_t,
            self.pairwise,
            &self.compute,
        )?;
        let val_op = val
            .map(|v| {
                validation_op(
                    train,
                    v,
                    self.cfg.kernel_d,
                    self.cfg.kernel_t,
                    self.pairwise,
                    &self.compute,
                )
            })
            .transpose()?;
        let y = &train.labels;

        let mut a = vec![0.0; n];
        let mut p = vec![0.0; n];
        let mut g = vec![0.0; n];
        let mut trace = TrainTrace::default();
        let inner_cfg = SolverConfig { max_iters: self.cfg.inner_iters, tol: 1e-12 };

        for outer in 1..=self.cfg.outer_iters {
            self.loss.gradient(&p, y, &mut g);
            let rhs: Vec<f64> = (0..n).map(|i| g[i] + self.cfg.lambda * a[i]).collect();
            // Newton operator x ↦ H·(Q x) + λx; transpose x ↦ Q·(H x) + λx.
            let lambda = self.cfg.lambda;
            let loss = &self.loss;
            let p_ref = &p;
            let op_ref = &op;
            let newton = FnOp {
                n,
                fwd: move |x: &[f64], out: &mut [f64]| {
                    let qx = op_ref.apply_vec(x);
                    loss.hessian_vec(p_ref, y, &qx, out);
                    for i in 0..x.len() {
                        out[i] += lambda * x[i];
                    }
                },
                tr: move |x: &[f64], out: &mut [f64]| {
                    let mut hx = vec![0.0; x.len()];
                    loss.hessian_vec(p_ref, y, x, &mut hx);
                    op_ref.apply(&hx, out);
                    for i in 0..x.len() {
                        out[i] += lambda * x[i];
                    }
                },
            };
            let mut x = vec![0.0; n];
            qmr(&newton, &rhs, &mut x, &inner_cfg);
            for i in 0..n {
                a[i] -= self.cfg.delta * x[i];
            }
            op.apply_into(&a, &mut p);

            if self.cfg.trace || (val.is_some() && self.cfg.patience > 0) {
                let risk = self.loss.value(&p, y) + 0.5 * self.cfg.lambda * dot(&a, &p);
                let val_auc =
                    val_op.as_ref().zip(val).map(|(vo, v)| auc(&v.labels, &vo.predict(&a)));
                trace.push(IterRecord {
                    iter: outer,
                    risk,
                    val_auc,
                    elapsed_secs: timer.elapsed_secs(),
                });
                if trace.should_stop(self.cfg.patience) {
                    break;
                }
            }
        }

        let model = DualModel {
            dual_coef: a,
            train_start_features: train.start_features.clone(),
            train_end_features: train.end_features.clone(),
            train_idx: train.kron_index(),
            kernel_d: self.cfg.kernel_d,
            kernel_t: self.cfg.kernel_t,
            pairwise: self.pairwise,
        };
        Ok((model, trace))
    }

    /// Algorithm 3 (primal, linear vertex kernels). Restricted to losses
    /// with diagonal Hessians (the [`PrimalNewtonOp`] shortcut); RankRLS
    /// would need a dedicated operator.
    pub fn fit_primal(
        &self,
        train: &Dataset,
        val: Option<&Dataset>,
    ) -> Result<(PrimalModel, TrainTrace), String> {
        if !self.loss.diagonal_hessian() {
            return Err(format!(
                "primal Newton supports diagonal-Hessian losses only (got {})",
                self.loss.name()
            ));
        }
        if self.pairwise != PairwiseKernelKind::Kronecker {
            return Err(format!(
                "the primal path supports the Kronecker pairwise kernel only (got '{}')",
                self.pairwise.name()
            ));
        }
        train.validate()?;
        let n = train.n_edges();
        if n == 0 {
            return Err("empty training set".into());
        }
        let timer = Timer::start();
        let op = PrimalKronOp::new(train);
        let y = &train.labels;
        let d_features = train.start_features.cols();
        let r_features = train.end_features.cols();

        let mut w = vec![0.0; op.w_dim()];
        let mut p = vec![0.0; n];
        let mut g = vec![0.0; n];
        let mut h = vec![0.0; n];
        let mut trace = TrainTrace::default();
        let inner_cfg = SolverConfig { max_iters: self.cfg.inner_iters, tol: 1e-12 };

        for outer in 1..=self.cfg.outer_iters {
            self.loss.gradient(&p, y, &mut g);
            self.loss.hessian_diag(&p, y, &mut h);
            let mut rhs = op.adjoint(&g);
            for i in 0..rhs.len() {
                rhs[i] += self.cfg.lambda * w[i];
            }
            let newton =
                PrimalNewtonOp { op: &op, hess_diag: h.clone(), lambda: self.cfg.lambda };
            let mut x = vec![0.0; op.w_dim()];
            cg(&newton, &rhs, &mut x, &inner_cfg);
            for i in 0..w.len() {
                w[i] -= self.cfg.delta * x[i];
            }
            p = op.forward(&w);

            if self.cfg.trace || (val.is_some() && self.cfg.patience > 0) {
                let risk = self.loss.value(&p, y) + 0.5 * self.cfg.lambda * dot(&w, &w);
                let val_auc = val.map(|v| {
                    let pm = PrimalModel { w: w.clone(), d_features, r_features };
                    auc(&v.labels, &pm.predict(v))
                });
                trace.push(IterRecord {
                    iter: outer,
                    risk,
                    val_auc,
                    elapsed_secs: timer.elapsed_secs(),
                });
                if trace.should_stop(self.cfg.patience) {
                    break;
                }
            }
        }

        Ok((PrimalModel { w, d_features, r_features }, trace))
    }

    /// Training-kernel operator access for diagnostics.
    pub fn kernel_op(&self, train: &Dataset) -> Result<PairwiseOp, String> {
        dual_kernel_op(
            train,
            self.cfg.kernel_d,
            self.cfg.kernel_t,
            self.pairwise,
            &self.compute,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::losses::{L2SvmLoss, LogisticLoss, RankRlsLoss, RidgeLoss};
    use crate::train::ridge::{ridge_exact_dual, RidgeConfig};
    use crate::util::rng::Pcg32;

    fn toy_train(seed: u64, m: usize, q: usize, n: usize) -> Dataset {
        let mut rng = Pcg32::seeded(seed);
        Dataset {
            start_features: crate::linalg::Matrix::from_fn(m, 3, |_, _| rng.normal()),
            end_features: crate::linalg::Matrix::from_fn(q, 2, |_, _| rng.normal()),
            start_idx: (0..n).map(|_| rng.below(m) as u32).collect(),
            end_idx: (0..n).map(|_| rng.below(q) as u32).collect(),
            labels: (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect(),
            name: "toy".into(),
        }
    }

    #[test]
    fn ridge_loss_newton_matches_exact_ridge() {
        // With the squared loss the Newton step is exact in one outer
        // iteration (given enough inner iterations).
        let train = toy_train(600, 8, 8, 26);
        let cfg = NewtonConfig {
            lambda: 0.7,
            outer_iters: 3,
            inner_iters: 400,
            ..Default::default()
        };
        let (model, _) = NewtonTrainer::new(RidgeLoss, cfg).fit_dual(&train, None).unwrap();
        let exact = ridge_exact_dual(
            &train,
            &RidgeConfig { lambda: 0.7, ..Default::default() },
            PairwiseKernelKind::Kronecker,
        );
        crate::linalg::vecops::assert_allclose(&model.dual_coef, &exact, 1e-5, 1e-5);
    }

    #[test]
    fn logistic_newton_decreases_risk() {
        let train = toy_train(601, 10, 10, 50);
        let cfg = NewtonConfig {
            lambda: 0.1,
            outer_iters: 12,
            inner_iters: 30,
            trace: true,
            ..Default::default()
        };
        let (_, trace) = NewtonTrainer::new(LogisticLoss, cfg).fit_dual(&train, None).unwrap();
        let first = trace.records.first().unwrap().risk;
        let last = trace.records.last().unwrap().risk;
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn rankrls_newton_decreases_risk() {
        let mut train = toy_train(602, 9, 9, 40);
        // regression-style labels for ranking
        let mut rng = Pcg32::seeded(603);
        for y in train.labels.iter_mut() {
            *y = rng.normal();
        }
        let cfg = NewtonConfig {
            lambda: 0.5,
            outer_iters: 8,
            inner_iters: 40,
            trace: true,
            ..Default::default()
        };
        let (_, trace) = NewtonTrainer::new(RankRlsLoss, cfg).fit_dual(&train, None).unwrap();
        // risk of the zero model
        let zero_risk = RankRlsLoss.value(&vec![0.0; train.n_edges()], &train.labels);
        let last = trace.records.last().unwrap().risk;
        assert!(last < 0.95 * zero_risk, "{zero_risk} -> {last}");
    }

    #[test]
    fn generic_l2svm_agrees_with_specialized_trainer() {
        let train = toy_train(604, 10, 9, 45);
        let ncfg = NewtonConfig {
            lambda: 0.8,
            outer_iters: 25,
            inner_iters: 50,
            ..Default::default()
        };
        let (generic, _) = NewtonTrainer::new(L2SvmLoss, ncfg).fit_dual(&train, None).unwrap();
        let scfg = crate::train::svm::SvmConfig {
            lambda: 0.8,
            outer_iters: 25,
            inner_iters: 50,
            sparsity_threshold: 0.0,
            ..Default::default()
        };
        let special = crate::train::svm::KronSvm::new(scfg).fit(&train).unwrap();
        crate::linalg::vecops::assert_allclose(
            &generic.dual_coef,
            &special.dual_coef,
            1e-4,
            1e-3,
        );
    }

    #[test]
    fn primal_rejects_non_diagonal_hessian() {
        let train = toy_train(605, 5, 5, 12);
        let cfg = NewtonConfig::default();
        assert!(NewtonTrainer::new(RankRlsLoss, cfg).fit_primal(&train, None).is_err());
    }
}
