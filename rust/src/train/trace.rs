//! Per-iteration training traces (regularized risk, validation AUC,
//! wall-clock) — the raw data behind the convergence figures (Figs. 3–5) and
//! the early-stopping rule.

/// One optimization-iteration record.
#[derive(Debug, Clone, Copy)]
pub struct IterRecord {
    /// Outer iteration number (1-based).
    pub iter: usize,
    /// Regularized risk `J(f) = L(p,y) + (λ/2)‖f‖²`.
    pub risk: f64,
    /// AUC on the validation set, if one was supplied.
    pub val_auc: Option<f64>,
    /// Seconds since training started.
    pub elapsed_secs: f64,
}

/// Training trace plus early-stopping bookkeeping.
#[derive(Debug, Clone, Default)]
pub struct TrainTrace {
    /// Per-iteration records, in iteration order.
    pub records: Vec<IterRecord>,
}

impl TrainTrace {
    /// Append one iteration record.
    pub fn push(&mut self, rec: IterRecord) {
        self.records.push(rec);
    }

    /// Best validation AUC seen (if any records carry one).
    pub fn best_val_auc(&self) -> Option<f64> {
        self.records.iter().filter_map(|r| r.val_auc).fold(None, |best, v| {
            Some(best.map_or(v, |b: f64| b.max(v)))
        })
    }

    /// Iteration index (1-based) of the best validation AUC.
    pub fn best_iter(&self) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for r in &self.records {
            if let Some(v) = r.val_auc {
                if best.map_or(true, |(_, b)| v > b) {
                    best = Some((r.iter, v));
                }
            }
        }
        best.map(|(i, _)| i)
    }

    /// Whether validation AUC has failed to improve for `patience`
    /// consecutive records (the early-stopping criterion).
    pub fn should_stop(&self, patience: usize) -> bool {
        if patience == 0 {
            return false;
        }
        let with_auc: Vec<(usize, f64)> = self
            .records
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.val_auc.map(|v| (i, v)))
            .collect();
        if with_auc.len() <= patience {
            return false;
        }
        let best_pos = with_auc
            .iter()
            .enumerate()
            .max_by(|(_, (_, a)), (_, (_, b))| a.partial_cmp(b).unwrap())
            .map(|(pos, _)| pos)
            .unwrap();
        with_auc.len() - 1 - best_pos >= patience
    }

    /// Final risk (∞ when empty).
    pub fn final_risk(&self) -> f64 {
        self.records.last().map(|r| r.risk).unwrap_or(f64::INFINITY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(iter: usize, risk: f64, auc: Option<f64>) -> IterRecord {
        IterRecord { iter, risk, val_auc: auc, elapsed_secs: 0.0 }
    }

    #[test]
    fn best_tracking() {
        let mut t = TrainTrace::default();
        t.push(rec(1, 10.0, Some(0.6)));
        t.push(rec(2, 5.0, Some(0.75)));
        t.push(rec(3, 3.0, Some(0.7)));
        assert_eq!(t.best_val_auc(), Some(0.75));
        assert_eq!(t.best_iter(), Some(2));
        assert_eq!(t.final_risk(), 3.0);
    }

    #[test]
    fn early_stop_patience() {
        let mut t = TrainTrace::default();
        t.push(rec(1, 9.0, Some(0.8)));
        assert!(!t.should_stop(2));
        t.push(rec(2, 8.0, Some(0.7)));
        assert!(!t.should_stop(2));
        t.push(rec(3, 7.0, Some(0.71)));
        assert!(t.should_stop(2));
        assert!(!t.should_stop(3));
        // patience 0 disables
        assert!(!t.should_stop(0));
    }

    #[test]
    fn no_auc_means_no_stop() {
        let mut t = TrainTrace::default();
        for i in 0..10 {
            t.push(rec(i, 1.0, None));
        }
        assert!(!t.should_stop(2));
        assert_eq!(t.best_val_auc(), None);
    }
}
