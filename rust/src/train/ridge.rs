//! Kronecker ridge regression (§4.1).
//!
//! Dual: solve `(R(G⊗K)Rᵀ + λI) a = y` with MINRES ([62], as in the paper's
//! experiments) — `O(mn + qn)` per iteration via the generalized vec trick.
//!
//! Primal (linear vertex kernels): solve
//! `((Tᵀ⊗Dᵀ)RᵀR(T⊗D) + λI) w = (Tᵀ⊗Dᵀ)Rᵀ y` with CG —
//! `O(min(mdr + nr, drq + dn))` per iteration.
//!
//! **Eigendecomposition fast paths** (two-step method, arXiv 1606.04275;
//! comparative study, arXiv 1803.01575): when the training graph is
//! *complete* — every (end-vertex, start-vertex) pair labeled exactly once —
//! `R` is a permutation and `Q + λI = R(G⊗K + λI)Rᵀ`, so per-factor
//! eigendecompositions `G = Q_g Λ_g Q_gᵀ`, `K = Q_k Λ_k Q_kᵀ` give the duals
//! in closed form:
//!
//! ```text
//! A = Q_g ( (Q_gᵀ Y Q_k) ∘ D⁻¹ ) Q_kᵀ ,   D[i][j] = λg_i·λk_j + λ ,
//! ```
//!
//! with `Y` the labels on the `q × m` grid — no iterations, no `n × n`
//! objects, one decomposition pair for *every* λ (see
//! [`KronRidge::fit_path`] and the leave-one-out shortcut
//! [`KronRidge::loo_path`]). For incomplete graphs the same decompositions
//! feed the spectral preconditioner
//! ([`KronSpectralPrecond`](crate::gvt::KronSpectralPrecond)) behind
//! [`RidgeSolver::PrecondCg`]. Solver choice is [`RidgeSolver`]; the default
//! `Auto` picks the closed form whenever it applies.

use crate::api::Compute;
use crate::data::Dataset;
use crate::eval::auc::auc;
use crate::gvt::{KronSpectralPrecond, PairwiseKernelKind, PairwiseOp};
use crate::kernels::KernelKind;
use crate::linalg::eig::{eigh, EigH};
use crate::linalg::solvers::{
    block_cg, block_pcg, cg_cb, minres_cb, pcg_cb, Preconditioner, SolverConfig,
};
use crate::linalg::vecops::dot;
use crate::linalg::Matrix;
use crate::model::primal::{PrimalKronOp, PrimalNewtonOp};
use crate::model::{DualModel, PrimalModel};
use crate::train::trace::{IterRecord, TrainTrace};
use crate::util::timer::Timer;

/// Kronecker ridge regression configuration.
#[derive(Debug, Clone, Copy)]
pub struct RidgeConfig {
    /// Regularization parameter λ.
    pub lambda: f64,
    /// Start-vertex kernel `k`.
    pub kernel_d: KernelKind,
    /// End-vertex kernel `g`.
    pub kernel_t: KernelKind,
    /// Maximum solver iterations (the paper's main tuning knob besides λ).
    pub iterations: usize,
    /// Residual tolerance (loose by default — early stopping is the
    /// regularizer of choice, §5.2).
    pub tol: f64,
    /// Record risk per iteration (costs one extra kernel matvec each).
    pub trace: bool,
    /// Early-stopping patience on validation AUC (0 disables).
    pub patience: usize,
}

impl Default for RidgeConfig {
    fn default() -> Self {
        RidgeConfig {
            lambda: 1.0,
            kernel_d: KernelKind::Linear,
            kernel_t: KernelKind::Linear,
            iterations: 100,
            tol: 1e-9,
            trace: false,
            patience: 0,
        }
    }
}

/// Dual-solver selection for [`KronRidge`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RidgeSolver {
    /// Pick automatically: the closed-form eigendecomposition solve when the
    /// training graph is complete (Kronecker family, no per-iteration
    /// monitoring requested), MINRES otherwise.
    #[default]
    Auto,
    /// Closed-form per-factor eigendecomposition solve. Errors unless the
    /// training graph is complete (and the family is Kronecker).
    Exact,
    /// MINRES (the paper's solver), unconditionally iterative.
    Minres,
    /// Plain conjugate gradient.
    Cg,
    /// Conjugate gradient with the Kronecker spectral preconditioner
    /// ([`KronSpectralPrecond`]) built from the complete-graph surrogate.
    PrecondCg,
}

impl RidgeSolver {
    /// Parse a CLI name: `auto`, `exact`, `minres`, `cg`, or `precond-cg`.
    pub fn parse(s: &str) -> Result<RidgeSolver, String> {
        match s {
            "auto" => Ok(RidgeSolver::Auto),
            "exact" => Ok(RidgeSolver::Exact),
            "minres" => Ok(RidgeSolver::Minres),
            "cg" => Ok(RidgeSolver::Cg),
            "precond-cg" => Ok(RidgeSolver::PrecondCg),
            other => Err(format!(
                "unknown solver '{other}' (expected auto, exact, minres, cg, or precond-cg)"
            )),
        }
    }

    /// CLI name of this solver.
    pub fn name(&self) -> &'static str {
        match self {
            RidgeSolver::Auto => "auto",
            RidgeSolver::Exact => "exact",
            RidgeSolver::Minres => "minres",
            RidgeSolver::Cg => "cg",
            RidgeSolver::PrecondCg => "precond-cg",
        }
    }
}

/// Kronecker ridge regression trainer.
///
/// Method-specific knobs live in [`RidgeConfig`]; the pairwise kernel family,
/// the solver, and the execution policy are set with
/// [`KronRidge::with_pairwise`] / [`KronRidge::with_solver`] /
/// [`KronRidge::with_compute`] (or through the
/// [`Learner`](crate::api::Learner) builder) — the config structs no longer
/// duplicate `threads`/`pairwise`.
#[derive(Debug, Clone)]
pub struct KronRidge {
    /// Training configuration.
    pub cfg: RidgeConfig,
    /// Pairwise kernel family composed over the GVT engine
    /// (`Kronecker` reproduces the pre-family behavior bit for bit).
    pub pairwise: PairwiseKernelKind,
    /// Dual-solver selection ([`RidgeSolver::Auto`] picks the closed-form
    /// fast path on complete training graphs).
    pub solver: RidgeSolver,
    /// Execution policy (threads, workspace retention); transparent to
    /// results.
    pub compute: Compute,
}

/// Build the dual training operator for the chosen pairwise family from a
/// dataset under a [`Compute`] policy: matvecs shard over
/// `compute.threads` worker threads, and the operator's scratch pool is
/// bounded by `compute.workspace_retention`. The kernel matrices themselves
/// are built with the same thread count through the packed GEMM (bitwise
/// identical to the serial build); the symmetric / anti-symmetric families
/// additionally build the end-vs-start cross-kernel block.
pub(crate) fn dual_kernel_op(
    train: &Dataset,
    kernel_d: KernelKind,
    kernel_t: KernelKind,
    pairwise: PairwiseKernelKind,
    compute: &Compute,
) -> Result<PairwiseOp, String> {
    // One shared checked constructor with the prediction path
    // (`validation_op` below): domain validation and per-family block
    // assembly live in `PairwiseOp::training_from_features`, so the trained
    // and scored kernels share a single seam.
    Ok(PairwiseOp::training_from_features(
        pairwise,
        kernel_d,
        kernel_t,
        &train.start_features,
        &train.end_features,
        train.kron_index(),
        compute.threads,
    )?
    .with_pool_retention(compute.workspace_retention))
}

/// Build a zero-shot prediction operator from training to validation edges
/// for the chosen pairwise family.
pub(crate) fn validation_op(
    train: &Dataset,
    val: &Dataset,
    kernel_d: KernelKind,
    kernel_t: KernelKind,
    pairwise: PairwiseKernelKind,
    compute: &Compute,
) -> Result<PairwiseOp, String> {
    PairwiseOp::prediction_from_features(
        pairwise,
        kernel_d,
        kernel_t,
        &val.start_features,
        &val.end_features,
        &train.start_features,
        &train.end_features,
        val.kron_index(),
        train.kron_index(),
        compute.threads,
    )
}

/// Package dual coefficients into a portable model.
fn make_dual_model(
    train: &Dataset,
    cfg: &RidgeConfig,
    pairwise: PairwiseKernelKind,
    dual_coef: Vec<f64>,
) -> DualModel {
    DualModel {
        dual_coef,
        train_start_features: train.start_features.clone(),
        train_end_features: train.end_features.clone(),
        train_idx: train.kron_index(),
        kernel_d: cfg.kernel_d,
        kernel_t: cfg.kernel_t,
        pairwise,
    }
}

/// Elementwise square of a matrix (`Q ∘ Q`), used by the LOO diagonal GEMMs.
fn squared_elements(a: &Matrix) -> Matrix {
    Matrix::from_fn(a.rows(), a.cols(), |i, j| {
        let v = a.get(i, j);
        v * v
    })
}

/// Per-factor eigendecomposition context for a **complete** training graph:
/// everything the closed-form ridge solve, the whole-λ-grid path, and the
/// leave-one-out shortcut share, computed once.
///
/// Holds `G = Q_g Λ_g Q_gᵀ` (q×q), `K = Q_k Λ_k Q_kᵀ` (m×m), the
/// grid-cell→edge layout of the complete edge index, and the rotated labels
/// `Ỹ = Q_gᵀ Y Q_k` (λ-independent, so a whole regularization path reuses
/// them).
struct EigContext {
    layout: Vec<u32>,
    g_eig: EigH,
    k_eig: EigH,
    ytil: Matrix,
    threads: usize,
}

impl EigContext {
    /// Attempt to build the context: `None` when the training graph is not
    /// complete (the closed form does not apply). Costs two [`eigh`] calls —
    /// `O(q³ + m³)` — and two grid GEMMs.
    fn build(
        train: &Dataset,
        kernel_d: KernelKind,
        kernel_t: KernelKind,
        compute: &Compute,
    ) -> Option<EigContext> {
        let q = train.end_features.rows();
        let m = train.start_features.rows();
        let layout = train.kron_index().complete_layout(q, m)?;
        let threads = compute.threads;
        let g = kernel_t.square_matrix_threaded(&train.end_features, threads);
        let k = kernel_d.square_matrix_threaded(&train.start_features, threads);
        let g_eig = eigh(&g);
        let k_eig = eigh(&k);
        let ygrid = Matrix::from_fn(q, m, |s, r| train.labels[layout[s * m + r] as usize]);
        let ytil = g_eig
            .vectors
            .transpose()
            .matmul_threaded(&ygrid, threads)
            .matmul_threaded(&k_eig.vectors, threads);
        Some(EigContext { layout, g_eig, k_eig, ytil, threads })
    }

    /// Closed-form duals for one λ:
    /// `A = Q_g (Ỹ ∘ D⁻¹) Q_kᵀ`, `D[i][j] = λg_i·λk_j + λ`, gathered back to
    /// edge order.
    fn solve(&self, lambda: f64) -> Vec<f64> {
        let m = self.k_eig.values.len();
        let mut w = self.ytil.clone();
        {
            let data = w.data_mut();
            for (i, &gl) in self.g_eig.values.iter().enumerate() {
                for (j, &kl) in self.k_eig.values.iter().enumerate() {
                    data[i * m + j] /= gl * kl + lambda;
                }
            }
        }
        let agrid = self
            .g_eig
            .vectors
            .matmul_threaded(&w, self.threads)
            .matmul_nt_threaded(&self.k_eig.vectors, self.threads);
        let mut a = vec![0.0; self.layout.len()];
        for (pos, &h) in self.layout.iter().enumerate() {
            a[h as usize] = agrid.data()[pos];
        }
        a
    }

    /// Diagonal of `(Q + λI)⁻¹` in edge order via two grid GEMMs:
    /// `diag = (Q_g ∘ Q_g) · D⁻¹ · (Q_k ∘ Q_k)ᵀ` — the hat-matrix diagonal
    /// the leave-one-out identity needs. `qg2`/`qk2` are the elementwise
    /// squares of the eigenvector matrices (hoisted by the caller because
    /// they are λ-independent).
    fn inverse_diagonal(&self, lambda: f64, qg2: &Matrix, qk2: &Matrix) -> Vec<f64> {
        let q = self.g_eig.values.len();
        let m = self.k_eig.values.len();
        let invd = Matrix::from_fn(q, m, |i, j| {
            1.0 / (self.g_eig.values[i] * self.k_eig.values[j] + lambda)
        });
        let grid = qg2.matmul_threaded(&invd, self.threads).matmul_nt_threaded(qk2, self.threads);
        let mut diag = vec![0.0; self.layout.len()];
        for (pos, &h) in self.layout.iter().enumerate() {
            diag[h as usize] = grid.data()[pos];
        }
        diag
    }
}

impl KronRidge {
    /// Trainer with the given configuration, the Kronecker pairwise family,
    /// and the default (serial) execution policy.
    pub fn new(cfg: RidgeConfig) -> Self {
        KronRidge {
            cfg,
            pairwise: PairwiseKernelKind::Kronecker,
            solver: RidgeSolver::Auto,
            compute: Compute::default(),
        }
    }

    /// Select the pairwise kernel family composed over the GVT engine.
    pub fn with_pairwise(mut self, pairwise: PairwiseKernelKind) -> Self {
        self.pairwise = pairwise;
        self
    }

    /// Select the dual solver (default [`RidgeSolver::Auto`]).
    pub fn with_solver(mut self, solver: RidgeSolver) -> Self {
        self.solver = solver;
        self
    }

    /// Set the execution policy (threads, workspace retention). Results are
    /// bitwise identical for every policy.
    pub fn with_compute(mut self, compute: Compute) -> Self {
        self.compute = compute;
        self
    }

    /// Train the dual model (any kernels).
    pub fn fit(&self, train: &Dataset) -> Result<DualModel, String> {
        Ok(self.fit_traced(train, None)?.0)
    }

    /// Train the dual model, tracing risk (and AUC on `val` if given) per
    /// solver iteration. Early-stops on validation AUC when
    /// `cfg.patience > 0`.
    ///
    /// Solver dispatch ([`KronRidge::with_solver`]):
    /// * [`RidgeSolver::Exact`] — closed-form eigendecomposition solve;
    ///   errors unless the graph is complete. Returns an empty trace (there
    ///   are no iterations to record).
    /// * [`RidgeSolver::Auto`] (default) — the closed form when the graph is
    ///   complete, the family is Kronecker, and no per-iteration monitoring
    ///   is requested (`cfg.trace` / early stopping force the iterative
    ///   path); MINRES otherwise. Incomplete-graph behavior is unchanged
    ///   from earlier releases.
    /// * [`RidgeSolver::Minres`] / [`RidgeSolver::Cg`] /
    ///   [`RidgeSolver::PrecondCg`] — always iterative.
    pub fn fit_traced(
        &self,
        train: &Dataset,
        val: Option<&Dataset>,
    ) -> Result<(DualModel, TrainTrace), String> {
        train.validate()?;
        if train.n_edges() == 0 {
            return Err("empty training set".into());
        }
        let want_monitor = self.cfg.trace || (val.is_some() && self.cfg.patience > 0);
        let try_closed = match self.solver {
            RidgeSolver::Exact => true,
            RidgeSolver::Auto => {
                self.pairwise == PairwiseKernelKind::Kronecker
                    && !want_monitor
                    && self.cfg.lambda > 0.0
            }
            _ => false,
        };
        if try_closed {
            if self.pairwise != PairwiseKernelKind::Kronecker {
                return Err(format!(
                    "solver 'exact' supports the Kronecker pairwise family only (got '{}')",
                    self.pairwise.name()
                ));
            }
            if self.cfg.lambda <= 0.0 {
                return Err("solver 'exact' requires lambda > 0".into());
            }
            match EigContext::build(train, self.cfg.kernel_d, self.cfg.kernel_t, &self.compute) {
                Some(ctx) => {
                    let a = ctx.solve(self.cfg.lambda);
                    let model = make_dual_model(train, &self.cfg, self.pairwise, a);
                    return Ok((model, TrainTrace::default()));
                }
                None => {
                    if self.solver == RidgeSolver::Exact {
                        return Err("solver 'exact' requires a complete training graph \
                                    (every (end, start) vertex pair labeled exactly once); \
                                    use auto, minres, cg, or precond-cg instead"
                            .into());
                    }
                    // Auto on an incomplete graph: fall through to MINRES.
                }
            }
        }

        let timer = Timer::start();
        let precond = match self.solver {
            RidgeSolver::PrecondCg => Some(self.spectral_precond(train, self.cfg.lambda)?),
            _ => None,
        };
        let op = dual_kernel_op(
            train,
            self.cfg.kernel_d,
            self.cfg.kernel_t,
            self.pairwise,
            &self.compute,
        )?;
        let val_op = val
            .map(|v| {
                validation_op(
                    train,
                    v,
                    self.cfg.kernel_d,
                    self.cfg.kernel_t,
                    self.pairwise,
                    &self.compute,
                )
            })
            .transpose()?;
        let sys = crate::gvt::operator::RidgeSystemOp { op: &op, lambda: self.cfg.lambda };
        let y = &train.labels;
        let mut a = vec![0.0; train.n_edges()];
        let mut trace = TrainTrace::default();

        let solver_cfg = SolverConfig { max_iters: self.cfg.iterations, tol: self.cfg.tol };
        if want_monitor {
            let mut p = vec![0.0; train.n_edges()];
            let patience = self.cfg.patience;
            let lambda = self.cfg.lambda;
            let mut monitor = |iter: usize, x: &[f64]| -> bool {
                op.apply_into(x, &mut p);
                let loss: f64 =
                    0.5 * p.iter().zip(y).map(|(pi, yi)| (pi - yi) * (pi - yi)).sum::<f64>();
                let risk = loss + 0.5 * lambda * dot(x, &p);
                let val_auc = val_op.as_ref().zip(val).map(|(vo, v)| auc(&v.labels, &vo.predict(x)));
                trace.push(IterRecord { iter, risk, val_auc, elapsed_secs: timer.elapsed_secs() });
                !trace.should_stop(patience)
            };
            self.run_iterative(&sys, y, &mut a, &solver_cfg, precond.as_ref(), Some(&mut monitor));
        } else {
            self.run_iterative(&sys, y, &mut a, &solver_cfg, precond.as_ref(), None);
        }

        Ok((make_dual_model(train, &self.cfg, self.pairwise, a), trace))
    }

    /// Dispatch one iterative dual solve according to `self.solver`.
    /// `precond` must be `Some` iff the solver is [`RidgeSolver::PrecondCg`]
    /// (the caller builds it so errors surface before the solve starts).
    fn run_iterative(
        &self,
        sys: &dyn crate::linalg::LinOp,
        y: &[f64],
        a: &mut [f64],
        solver_cfg: &SolverConfig,
        precond: Option<&KronSpectralPrecond>,
        monitor: Option<crate::linalg::solvers::IterMonitor<'_>>,
    ) -> crate::linalg::SolveStats {
        match self.solver {
            RidgeSolver::Cg => cg_cb(sys, y, a, solver_cfg, monitor),
            RidgeSolver::PrecondCg => {
                let pc = precond.expect("precond-cg dispatch requires a preconditioner");
                pcg_cb(sys, y, a, pc, solver_cfg, monitor)
            }
            _ => minres_cb(sys, y, a, solver_cfg, monitor),
        }
    }

    /// Per-factor kernel eigendecompositions (`G` then `K`) for the spectral
    /// preconditioner; Kronecker family only.
    fn factor_eigs(&self, train: &Dataset) -> Result<(EigH, EigH), String> {
        if self.pairwise != PairwiseKernelKind::Kronecker {
            return Err(format!(
                "solver 'precond-cg' supports the Kronecker pairwise family only (got '{}')",
                self.pairwise.name()
            ));
        }
        let threads = self.compute.threads;
        let g = self.cfg.kernel_t.square_matrix_threaded(&train.end_features, threads);
        let k = self.cfg.kernel_d.square_matrix_threaded(&train.start_features, threads);
        Ok((eigh(&g), eigh(&k)))
    }

    /// Build the Kronecker spectral preconditioner for `Q + λI`.
    fn spectral_precond(
        &self,
        train: &Dataset,
        lambda: f64,
    ) -> Result<KronSpectralPrecond, String> {
        if lambda <= 0.0 {
            return Err("solver 'precond-cg' requires lambda > 0".into());
        }
        let (g_eig, k_eig) = self.factor_eigs(train)?;
        Ok(KronSpectralPrecond::new(&g_eig, &k_eig, train.kron_index(), lambda)
            .with_threads(self.compute.threads))
    }

    /// Train one dual model per λ in `lambdas` through the **batched
    /// compute core**: the kernel operator is built once, and a single
    /// [`block_cg`] solve drives all shifted systems `(Q + λ_j I) a_j = y`
    /// with one multi-RHS GVT apply per iteration — a whole regularization
    /// path for little more than the cost of one model (`cfg.lambda` is
    /// ignored; `cfg.iterations`/`cfg.tol` and the trainer's
    /// [`Compute`] policy apply).
    ///
    /// Uses CG rather than the single-model path's MINRES, so a
    /// one-element path is numerically (not bitwise) equivalent to
    /// [`KronRidge::fit`]; each returned model matches the standalone CG
    /// solve for its λ bit for bit.
    ///
    /// Solver dispatch: with [`RidgeSolver::Auto`]/[`RidgeSolver::Exact`] on
    /// a complete training graph (Kronecker family, positive λ), the whole
    /// path is solved **closed-form from one eigendecomposition pair** —
    /// exactly two [`eigh`] calls no matter how many λ values (asserted via
    /// [`crate::linalg::eig::eigh_count`] in the test suite).
    /// [`RidgeSolver::PrecondCg`] runs [`block_pcg`] with one spectral
    /// preconditioner per λ sharing the same decomposition pair. `Cg`,
    /// `Minres` (no block MINRES exists; CG is the block iterative
    /// workhorse), and `Auto` on incomplete graphs run [`block_cg`].
    pub fn fit_path(&self, train: &Dataset, lambdas: &[f64]) -> Result<Vec<DualModel>, String> {
        train.validate()?;
        if train.n_edges() == 0 {
            return Err("empty training set".into());
        }
        if lambdas.is_empty() {
            return Ok(Vec::new());
        }
        if matches!(self.solver, RidgeSolver::Auto | RidgeSolver::Exact) {
            let eligible =
                self.pairwise == PairwiseKernelKind::Kronecker && lambdas.iter().all(|&l| l > 0.0);
            let ctx = if eligible {
                EigContext::build(train, self.cfg.kernel_d, self.cfg.kernel_t, &self.compute)
            } else {
                None
            };
            if let Some(ctx) = ctx {
                return Ok(lambdas
                    .iter()
                    .map(|&lambda| {
                        make_dual_model(train, &self.cfg, self.pairwise, ctx.solve(lambda))
                    })
                    .collect());
            }
            if self.solver == RidgeSolver::Exact {
                return Err("solver 'exact' requires the Kronecker pairwise family, a complete \
                            training graph, and positive lambdas; use auto, cg, or precond-cg \
                            instead"
                    .into());
            }
        }
        let op = dual_kernel_op(
            train,
            self.cfg.kernel_d,
            self.cfg.kernel_t,
            self.pairwise,
            &self.compute,
        )?;
        let n = train.n_edges();
        let k = lambdas.len();
        let mut b = vec![0.0; n * k];
        for bj in b.chunks_mut(n) {
            bj.copy_from_slice(&train.labels);
        }
        let mut duals = vec![0.0; n * k];
        let solver_cfg = SolverConfig { max_iters: self.cfg.iterations, tol: self.cfg.tol };
        if self.solver == RidgeSolver::PrecondCg {
            if let Some(&bad) = lambdas.iter().find(|&&l| l <= 0.0) {
                return Err(format!("solver 'precond-cg' requires lambda > 0 (got {bad})"));
            }
            let (g_eig, k_eig) = self.factor_eigs(train)?;
            let preconds: Vec<KronSpectralPrecond> = lambdas
                .iter()
                .map(|&lambda| {
                    KronSpectralPrecond::new(&g_eig, &k_eig, train.kron_index(), lambda)
                        .with_threads(self.compute.threads)
                })
                .collect();
            let precond_refs: Vec<&dyn Preconditioner> =
                preconds.iter().map(|p| p as &dyn Preconditioner).collect();
            block_pcg(&op, lambdas, &precond_refs, &b, &mut duals, &solver_cfg);
        } else {
            block_cg(&op, lambdas, &b, &mut duals, &solver_cfg);
        }
        Ok((0..k)
            .map(|j| {
                make_dual_model(
                    train,
                    &self.cfg,
                    self.pairwise,
                    duals[j * n..(j + 1) * n].to_vec(),
                )
            })
            .collect())
    }

    /// Leave-one-out cross-validation shortcut on a **complete** training
    /// graph: for each λ, the vector of held-out predictions
    /// `f₋ₕ(xₕ) = yₕ − aₕ / [(Q+λI)⁻¹]ₕₕ` for every edge `h` — the exact
    /// result of `n` literal refits, from **one** eigendecomposition pair
    /// for the whole λ grid (two [`eigh`] calls total; each λ then costs
    /// four `q×m`-grid GEMMs).
    ///
    /// Errors if the pairwise family is not Kronecker, any λ is not
    /// positive, or the training graph is incomplete.
    pub fn loo_path(&self, train: &Dataset, lambdas: &[f64]) -> Result<Vec<Vec<f64>>, String> {
        train.validate()?;
        if train.n_edges() == 0 {
            return Err("empty training set".into());
        }
        if self.pairwise != PairwiseKernelKind::Kronecker {
            return Err(format!(
                "the leave-one-out shortcut supports the Kronecker pairwise family only \
                 (got '{}')",
                self.pairwise.name()
            ));
        }
        if let Some(&bad) = lambdas.iter().find(|&&l| l <= 0.0) {
            return Err(format!("the leave-one-out shortcut requires lambda > 0 (got {bad})"));
        }
        let ctx = EigContext::build(train, self.cfg.kernel_d, self.cfg.kernel_t, &self.compute)
            .ok_or_else(|| {
                "the leave-one-out shortcut requires a complete training graph (every \
                 (end, start) vertex pair labeled exactly once)"
                    .to_string()
            })?;
        let qg2 = squared_elements(&ctx.g_eig.vectors);
        let qk2 = squared_elements(&ctx.k_eig.vectors);
        Ok(lambdas
            .iter()
            .map(|&lambda| {
                let a = ctx.solve(lambda);
                let diag = ctx.inverse_diagonal(lambda, &qg2, &qk2);
                train
                    .labels
                    .iter()
                    .zip(a.iter().zip(&diag))
                    .map(|(y, (ai, di))| y - ai / di)
                    .collect()
            })
            .collect())
    }

    /// Train the primal model (implicitly linear vertex kernels; the
    /// configured kernels are ignored).
    pub fn fit_primal(
        &self,
        train: &Dataset,
        val: Option<&Dataset>,
    ) -> Result<(PrimalModel, TrainTrace), String> {
        train.validate()?;
        if train.n_edges() == 0 {
            return Err("empty training set".into());
        }
        if self.pairwise != PairwiseKernelKind::Kronecker {
            return Err(format!(
                "the primal path supports the Kronecker pairwise kernel only (got '{}')",
                self.pairwise.name()
            ));
        }
        let timer = Timer::start();
        let op = PrimalKronOp::new(train);
        let rhs = op.adjoint(&train.labels);
        let sys = PrimalNewtonOp {
            op: &op,
            hess_diag: vec![1.0; train.n_edges()],
            lambda: self.cfg.lambda,
        };
        let mut w = vec![0.0; op.w_dim()];
        let mut trace = TrainTrace::default();
        let solver_cfg = SolverConfig { max_iters: self.cfg.iterations, tol: self.cfg.tol };

        let want_monitor = self.cfg.trace || (val.is_some() && self.cfg.patience > 0);
        if want_monitor {
            let y = &train.labels;
            let patience = self.cfg.patience;
            let lambda = self.cfg.lambda;
            let d_features = train.start_features.cols();
            let r_features = train.end_features.cols();
            let mut monitor = |iter: usize, x: &[f64]| -> bool {
                let p = op.forward(x);
                let loss: f64 =
                    0.5 * p.iter().zip(y).map(|(pi, yi)| (pi - yi) * (pi - yi)).sum::<f64>();
                let risk = loss + 0.5 * lambda * dot(x, x);
                let val_auc = val.map(|v| {
                    let pm = PrimalModel { w: x.to_vec(), d_features, r_features };
                    auc(&v.labels, &pm.predict(v))
                });
                trace.push(IterRecord { iter, risk, val_auc, elapsed_secs: timer.elapsed_secs() });
                !trace.should_stop(patience)
            };
            cg_cb(&sys, &rhs, &mut w, &solver_cfg, Some(&mut monitor));
        } else {
            cg_cb(&sys, &rhs, &mut w, &solver_cfg, None);
        }

        let model = PrimalModel {
            w,
            d_features: train.start_features.cols(),
            r_features: train.end_features.cols(),
        };
        Ok((model, trace))
    }
}

/// Exact (direct) dual ridge solve via Cholesky on the materialized pairwise
/// kernel matrix — `O(n³)`; testing oracle for small problems (any family).
pub fn ridge_exact_dual(
    train: &Dataset,
    cfg: &RidgeConfig,
    pairwise: PairwiseKernelKind,
) -> Vec<f64> {
    let op = dual_kernel_op(train, cfg.kernel_d, cfg.kernel_t, pairwise, &Compute::serial())
        .expect("valid pairwise configuration");
    let mut q = op.explicit_dense();
    q.add_diag(cfg.lambda);
    q.solve_spd(&train.labels).expect("ridge system should be SPD")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::checkerboard::CheckerboardConfig;
    use crate::linalg::vecops::assert_allclose;
    use crate::util::rng::Pcg32;

    fn toy_train(seed: u64, m: usize, q: usize, n: usize) -> Dataset {
        let mut rng = Pcg32::seeded(seed);
        Dataset {
            start_features: crate::linalg::Matrix::from_fn(m, 3, |_, _| rng.normal()),
            end_features: crate::linalg::Matrix::from_fn(q, 2, |_, _| rng.normal()),
            start_idx: (0..n).map(|_| rng.below(m) as u32).collect(),
            end_idx: (0..n).map(|_| rng.below(q) as u32).collect(),
            labels: (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect(),
            name: "toy".into(),
        }
    }

    #[test]
    fn dual_matches_exact_solution() {
        let train = toy_train(400, 8, 7, 25);
        let cfg = RidgeConfig { lambda: 0.5, iterations: 500, tol: 1e-12, ..Default::default() };
        let model = KronRidge::new(cfg).fit(&train).unwrap();
        let exact = ridge_exact_dual(&train, &cfg, PairwiseKernelKind::Kronecker);
        assert_allclose(&model.dual_coef, &exact, 1e-6, 1e-6);
    }

    /// Homogeneous toy set: both vertex roles share one feature space.
    fn toy_homogeneous(seed: u64, v: usize, n: usize) -> Dataset {
        let mut rng = Pcg32::seeded(seed);
        let features = crate::linalg::Matrix::from_fn(v, 2, |_, _| rng.normal());
        Dataset {
            start_features: features.clone(),
            end_features: features,
            start_idx: (0..n).map(|_| rng.below(v) as u32).collect(),
            end_idx: (0..n).map(|_| rng.below(v) as u32).collect(),
            labels: (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect(),
            name: "toy-homo".into(),
        }
    }

    #[test]
    fn pairwise_dual_matches_exact_solution_per_family() {
        // The iterative solve against the matrix-free pairwise operator must
        // agree with the direct Cholesky solve on the materialized matrix.
        let train = toy_homogeneous(420, 9, 24);
        for pairwise in [
            crate::gvt::PairwiseKernelKind::SymmetricKron,
            crate::gvt::PairwiseKernelKind::AntiSymmetricKron,
            crate::gvt::PairwiseKernelKind::Cartesian,
        ] {
            let cfg = RidgeConfig {
                lambda: 1.0,
                kernel_d: KernelKind::Gaussian { gamma: 0.4 },
                kernel_t: KernelKind::Gaussian { gamma: 0.4 },
                iterations: 800,
                tol: 1e-13,
                ..Default::default()
            };
            let model = KronRidge::new(cfg).with_pairwise(pairwise).fit(&train).unwrap();
            let exact = ridge_exact_dual(&train, &cfg, pairwise);
            assert_allclose(&model.dual_coef, &exact, 1e-6, 1e-6);
        }
    }

    #[test]
    fn symmetric_rejects_heterogeneous_feature_spaces() {
        // toy_train carries 3-d start and 2-d end features — no shared domain.
        let train = toy_train(421, 6, 6, 20);
        let err = KronRidge::new(RidgeConfig::default())
            .with_pairwise(crate::gvt::PairwiseKernelKind::SymmetricKron)
            .fit(&train)
            .unwrap_err();
        assert!(err.contains("feature space"), "{err}");
        // mismatched kernels over a shared space are rejected too
        let homo = toy_homogeneous(422, 6, 18);
        let cfg = RidgeConfig {
            kernel_d: KernelKind::Gaussian { gamma: 1.0 },
            kernel_t: KernelKind::Linear,
            ..Default::default()
        };
        assert!(KronRidge::new(cfg)
            .with_pairwise(crate::gvt::PairwiseKernelKind::SymmetricKron)
            .fit(&homo)
            .is_err());
    }

    #[test]
    fn dual_and_primal_agree_for_linear_kernel() {
        // With linear kernels the dual and primal models define the same
        // function; compare predictions on held-out edges.
        let data = toy_train(401, 20, 15, 120);
        let (train, test) = data.zero_shot_split(0.3, 5);
        let cfg = RidgeConfig { lambda: 1.0, iterations: 800, tol: 1e-13, ..Default::default() };
        let ridge = KronRidge::new(cfg);
        let dual = ridge.fit(&train).unwrap();
        let (primal, _) = ridge.fit_primal(&train, None).unwrap();
        let pd = dual.predict(&test);
        let pp = primal.predict(&test);
        assert_allclose(&pd, &pp, 1e-5, 1e-4);
    }

    #[test]
    fn trace_records_risk_decrease() {
        let train = toy_train(402, 10, 10, 60);
        let cfg = RidgeConfig {
            lambda: 0.1,
            iterations: 30,
            trace: true,
            tol: 1e-14,
            ..Default::default()
        };
        let (_, trace) = KronRidge::new(cfg).fit_traced(&train, None).unwrap();
        assert!(trace.records.len() >= 5);
        // risk should broadly decrease from first to last
        assert!(trace.final_risk() < trace.records[0].risk);
    }

    #[test]
    fn learns_checkerboard_with_gaussian_kernel() {
        let data =
            CheckerboardConfig { m: 60, q: 60, density: 0.4, noise: 0.1, feature_range: 8.0, seed: 3, ..Default::default() }.generate();
        let (train, test) = data.zero_shot_split(0.3, 9);
        let cfg = RidgeConfig {
            lambda: 2f64.powi(-7),
            kernel_d: KernelKind::Gaussian { gamma: 1.0 },
            kernel_t: KernelKind::Gaussian { gamma: 1.0 },
            iterations: 100,
            ..Default::default()
        };
        let model = KronRidge::new(cfg).fit(&train).unwrap();
        let test_auc = auc(&test.labels, &model.predict(&test));
        assert!(test_auc > 0.7, "AUC={test_auc}");
    }

    #[test]
    fn early_stopping_halts_iterations() {
        let data = toy_train(403, 15, 15, 100);
        let (train, val) = data.zero_shot_split(0.3, 2);
        let cfg = RidgeConfig {
            lambda: 1e-6,
            iterations: 100,
            trace: true,
            patience: 3,
            tol: 1e-16,
            ..Default::default()
        };
        let (_, trace) = KronRidge::new(cfg).fit_traced(&train, Some(&val)).unwrap();
        // with noise labels and tiny lambda, AUC should saturate and stop early
        assert!(
            trace.records.len() < 100,
            "expected early stop, got {} iters",
            trace.records.len()
        );
    }

    #[test]
    fn rejects_empty_training_set() {
        let ds = toy_train(404, 5, 5, 10).subset_by_edges(&[], "empty");
        assert!(KronRidge::new(RidgeConfig::default()).fit(&ds).is_err());
    }

    #[test]
    fn fit_path_matches_exact_solutions_per_lambda() {
        let train = toy_train(406, 8, 7, 26);
        let lambdas = [0.25, 1.0, 4.0];
        let cfg = RidgeConfig { iterations: 600, tol: 1e-13, ..Default::default() };
        let models = KronRidge::new(cfg).fit_path(&train, &lambdas).unwrap();
        assert_eq!(models.len(), lambdas.len());
        for (model, &lambda) in models.iter().zip(&lambdas) {
            let exact = ridge_exact_dual(
                &train,
                &RidgeConfig { lambda, ..cfg },
                PairwiseKernelKind::Kronecker,
            );
            assert_allclose(&model.dual_coef, &exact, 1e-6, 1e-6);
        }
    }

    #[test]
    fn fit_path_threaded_matches_serial_bitwise() {
        let train = toy_train(407, 30, 30, 2400);
        let lambdas = [0.5, 2.0];
        let base = RidgeConfig { iterations: 25, tol: 1e-12, ..Default::default() };
        let serial = KronRidge::new(base).fit_path(&train, &lambdas).unwrap();
        let par = KronRidge::new(base)
            .with_compute(crate::api::Compute::threads(4))
            .fit_path(&train, &lambdas)
            .unwrap();
        for (s, p) in serial.iter().zip(&par) {
            assert_eq!(s.dual_coef, p.dual_coef);
        }
    }

    #[test]
    fn fit_path_empty_lambdas_returns_no_models() {
        let train = toy_train(408, 5, 5, 12);
        let models =
            KronRidge::new(RidgeConfig::default()).fit_path(&train, &[]).unwrap();
        assert!(models.is_empty());
    }

    #[test]
    fn solver_names_roundtrip() {
        for solver in [
            RidgeSolver::Auto,
            RidgeSolver::Exact,
            RidgeSolver::Minres,
            RidgeSolver::Cg,
            RidgeSolver::PrecondCg,
        ] {
            assert_eq!(RidgeSolver::parse(solver.name()).unwrap(), solver);
        }
        let err = RidgeSolver::parse("cholesky").unwrap_err();
        assert!(err.contains("unknown solver 'cholesky'"), "{err}");
    }

    #[test]
    fn auto_uses_closed_form_on_complete_graph_and_matches_oracle() {
        let mut rng = Pcg32::seeded(430);
        let train = crate::util::proptest::complete_dataset(&mut rng, 6, 5);
        let cfg = RidgeConfig {
            lambda: 0.5,
            kernel_d: KernelKind::Gaussian { gamma: 0.3 },
            kernel_t: KernelKind::Gaussian { gamma: 0.3 },
            ..Default::default()
        };
        let before = crate::linalg::eig::eigh_count();
        let model = KronRidge::new(cfg).fit(&train).unwrap();
        assert_eq!(
            crate::linalg::eig::eigh_count() - before,
            2,
            "closed form must cost exactly one eigendecomposition pair"
        );
        let exact = ridge_exact_dual(&train, &cfg, PairwiseKernelKind::Kronecker);
        assert_allclose(&model.dual_coef, &exact, 1e-8, 1e-8);
        // The explicit 'exact' solver takes the identical code path.
        let em = KronRidge::new(cfg).with_solver(RidgeSolver::Exact).fit(&train).unwrap();
        assert_eq!(em.dual_coef, model.dual_coef);
    }

    #[test]
    fn exact_solver_rejects_ineligible_problems() {
        // Incomplete graph (duplicate/missing edges).
        let train = toy_train(431, 6, 5, 20);
        let err = KronRidge::new(RidgeConfig { lambda: 0.5, ..Default::default() })
            .with_solver(RidgeSolver::Exact)
            .fit(&train)
            .unwrap_err();
        assert!(err.contains("complete training graph"), "{err}");
        // Non-positive lambda.
        let mut rng = Pcg32::seeded(432);
        let complete = crate::util::proptest::complete_dataset(&mut rng, 4, 4);
        let err = KronRidge::new(RidgeConfig { lambda: 0.0, ..Default::default() })
            .with_solver(RidgeSolver::Exact)
            .fit(&complete)
            .unwrap_err();
        assert!(err.contains("lambda > 0"), "{err}");
        // Non-Kronecker pairwise family.
        let homo = toy_homogeneous(433, 5, 15);
        let cfg = RidgeConfig {
            kernel_d: KernelKind::Gaussian { gamma: 0.4 },
            kernel_t: KernelKind::Gaussian { gamma: 0.4 },
            ..Default::default()
        };
        let err = KronRidge::new(cfg)
            .with_pairwise(PairwiseKernelKind::SymmetricKron)
            .with_solver(RidgeSolver::Exact)
            .fit(&homo)
            .unwrap_err();
        assert!(err.contains("Kronecker pairwise family only"), "{err}");
    }

    #[test]
    fn cg_and_precond_cg_match_minres_on_incomplete_graph() {
        let train = toy_train(434, 8, 7, 25);
        let cfg = RidgeConfig { lambda: 0.5, iterations: 500, tol: 1e-12, ..Default::default() };
        let minres = KronRidge::new(cfg).with_solver(RidgeSolver::Minres).fit(&train).unwrap();
        let cg = KronRidge::new(cfg).with_solver(RidgeSolver::Cg).fit(&train).unwrap();
        let pcg = KronRidge::new(cfg).with_solver(RidgeSolver::PrecondCg).fit(&train).unwrap();
        assert_allclose(&cg.dual_coef, &minres.dual_coef, 1e-6, 1e-6);
        assert_allclose(&pcg.dual_coef, &minres.dual_coef, 1e-6, 1e-6);
    }

    #[test]
    fn fit_path_on_complete_graph_uses_one_decomposition_pair() {
        let mut rng = Pcg32::seeded(435);
        let train = crate::util::proptest::complete_dataset(&mut rng, 5, 4);
        let lambdas = [0.1, 1.0, 10.0, 100.0];
        let cfg = RidgeConfig {
            kernel_d: KernelKind::Gaussian { gamma: 0.25 },
            kernel_t: KernelKind::Gaussian { gamma: 0.25 },
            ..Default::default()
        };
        let before = crate::linalg::eig::eigh_count();
        let models = KronRidge::new(cfg).fit_path(&train, &lambdas).unwrap();
        assert_eq!(
            crate::linalg::eig::eigh_count() - before,
            2,
            "the whole λ grid must share one eigendecomposition pair"
        );
        assert_eq!(models.len(), lambdas.len());
        for (model, &lambda) in models.iter().zip(&lambdas) {
            let exact = ridge_exact_dual(
                &train,
                &RidgeConfig { lambda, ..cfg },
                PairwiseKernelKind::Kronecker,
            );
            assert_allclose(&model.dual_coef, &exact, 1e-8, 1e-8);
        }
    }

    #[test]
    fn loo_path_requires_complete_graph_and_positive_lambda() {
        let train = toy_train(436, 5, 4, 12);
        let err =
            KronRidge::new(RidgeConfig::default()).loo_path(&train, &[1.0]).unwrap_err();
        assert!(err.contains("complete training graph"), "{err}");
        let mut rng = Pcg32::seeded(437);
        let complete = crate::util::proptest::complete_dataset(&mut rng, 4, 3);
        let err =
            KronRidge::new(RidgeConfig::default()).loo_path(&complete, &[0.0]).unwrap_err();
        assert!(err.contains("lambda > 0"), "{err}");
    }

    #[test]
    fn auto_with_trace_still_iterates_on_complete_graph() {
        // Per-iteration monitoring (trace / early stopping) forces the
        // iterative path even when the closed form would apply.
        let mut rng = Pcg32::seeded(438);
        let train = crate::util::proptest::complete_dataset(&mut rng, 6, 5);
        let cfg = RidgeConfig {
            lambda: 0.5,
            iterations: 50,
            trace: true,
            tol: 1e-14,
            ..Default::default()
        };
        let before = crate::linalg::eig::eigh_count();
        let (_, trace) = KronRidge::new(cfg).fit_traced(&train, None).unwrap();
        assert_eq!(crate::linalg::eig::eigh_count() - before, 0);
        assert!(!trace.records.is_empty());
    }

    #[test]
    fn threaded_training_matches_serial() {
        // The threads knob must not change the trained model: parallel GVT
        // matvecs are bitwise identical to serial ones, and MINRES is fully
        // deterministic given identical matvecs.
        let train = toy_train(405, 40, 40, 2600);
        let base = RidgeConfig { lambda: 0.3, iterations: 40, tol: 1e-12, ..Default::default() };
        let serial = KronRidge::new(base).fit(&train).unwrap();
        for threads in [2, 4] {
            let par = KronRidge::new(base)
                .with_compute(crate::api::Compute::threads(threads))
                .fit(&train)
                .unwrap();
            assert_eq!(serial.dual_coef, par.dual_coef, "threads={threads}");
        }
    }
}
