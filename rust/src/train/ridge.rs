//! Kronecker ridge regression (§4.1).
//!
//! Dual: solve `(R(G⊗K)Rᵀ + λI) a = y` with MINRES ([62], as in the paper's
//! experiments) — `O(mn + qn)` per iteration via the generalized vec trick.
//!
//! Primal (linear vertex kernels): solve
//! `((Tᵀ⊗Dᵀ)RᵀR(T⊗D) + λI) w = (Tᵀ⊗Dᵀ)Rᵀ y` with CG —
//! `O(min(mdr + nr, drq + dn))` per iteration.

use std::sync::Arc;

use crate::api::Compute;
use crate::data::Dataset;
use crate::eval::auc::auc;
use crate::gvt::{delta_matrix, PairwiseKernelKind, PairwiseOp};
use crate::kernels::{kernel_matrix_threaded, KernelKind};
use crate::linalg::solvers::{block_cg, cg_cb, minres_cb, SolverConfig};
use crate::linalg::vecops::dot;
use crate::model::primal::{PrimalKronOp, PrimalNewtonOp};
use crate::model::{DualModel, PrimalModel};
use crate::train::trace::{IterRecord, TrainTrace};
use crate::util::timer::Timer;

/// Kronecker ridge regression configuration.
#[derive(Debug, Clone, Copy)]
pub struct RidgeConfig {
    /// Regularization parameter λ.
    pub lambda: f64,
    /// Start-vertex kernel `k`.
    pub kernel_d: KernelKind,
    /// End-vertex kernel `g`.
    pub kernel_t: KernelKind,
    /// Maximum solver iterations (the paper's main tuning knob besides λ).
    pub iterations: usize,
    /// Residual tolerance (loose by default — early stopping is the
    /// regularizer of choice, §5.2).
    pub tol: f64,
    /// Record risk per iteration (costs one extra kernel matvec each).
    pub trace: bool,
    /// Early-stopping patience on validation AUC (0 disables).
    pub patience: usize,
}

impl Default for RidgeConfig {
    fn default() -> Self {
        RidgeConfig {
            lambda: 1.0,
            kernel_d: KernelKind::Linear,
            kernel_t: KernelKind::Linear,
            iterations: 100,
            tol: 1e-9,
            trace: false,
            patience: 0,
        }
    }
}

/// Kronecker ridge regression trainer.
///
/// Method-specific knobs live in [`RidgeConfig`]; the pairwise kernel family
/// and the execution policy are set with [`KronRidge::with_pairwise`] /
/// [`KronRidge::with_compute`] (or through the
/// [`Learner`](crate::api::Learner) builder) — the config structs no longer
/// duplicate `threads`/`pairwise`.
#[derive(Debug, Clone)]
pub struct KronRidge {
    /// Training configuration.
    pub cfg: RidgeConfig,
    /// Pairwise kernel family composed over the GVT engine
    /// (`Kronecker` reproduces the pre-family behavior bit for bit).
    pub pairwise: PairwiseKernelKind,
    /// Execution policy (threads, workspace retention); transparent to
    /// results.
    pub compute: Compute,
}

/// Build the dual training operator for the chosen pairwise family from a
/// dataset under a [`Compute`] policy: matvecs shard over
/// `compute.threads` worker threads, and the operator's scratch pool is
/// bounded by `compute.workspace_retention`. The kernel matrices themselves
/// are built with the same thread count through the packed GEMM (bitwise
/// identical to the serial build); the symmetric / anti-symmetric families
/// additionally build the end-vs-start cross-kernel block.
pub(crate) fn dual_kernel_op(
    train: &Dataset,
    kernel_d: KernelKind,
    kernel_t: KernelKind,
    pairwise: PairwiseKernelKind,
    compute: &Compute,
) -> Result<PairwiseOp, String> {
    let threads = compute.threads;
    pairwise.validate_vertex_domains(
        kernel_d,
        kernel_t,
        train.start_features.cols(),
        train.end_features.cols(),
    )?;
    let k = Arc::new(kernel_d.square_matrix_threaded(&train.start_features, threads));
    let g = Arc::new(kernel_t.square_matrix_threaded(&train.end_features, threads));
    let (aux_g, aux_k) = match pairwise {
        PairwiseKernelKind::Kronecker => (None, None),
        PairwiseKernelKind::SymmetricKron | PairwiseKernelKind::AntiSymmetricKron => (
            Some(Arc::new(kernel_matrix_threaded(
                kernel_t,
                &train.end_features,
                &train.start_features,
                threads,
            ))),
            None,
        ),
        // Feature-equality δ blocks (not the index identity), so the trained
        // kernel agrees with what the prediction path scores when distinct
        // vertex indices carry identical feature rows.
        PairwiseKernelKind::Cartesian => (
            Some(Arc::new(delta_matrix(&train.end_features, &train.end_features))),
            Some(Arc::new(delta_matrix(&train.start_features, &train.start_features))),
        ),
    };
    Ok(PairwiseOp::training(pairwise, g, k, aux_g, aux_k, train.kron_index())?
        .with_threads(threads)
        .with_pool_retention(compute.workspace_retention))
}

/// Build a zero-shot prediction operator from training to validation edges
/// for the chosen pairwise family.
pub(crate) fn validation_op(
    train: &Dataset,
    val: &Dataset,
    kernel_d: KernelKind,
    kernel_t: KernelKind,
    pairwise: PairwiseKernelKind,
    compute: &Compute,
) -> Result<PairwiseOp, String> {
    PairwiseOp::prediction_from_features(
        pairwise,
        kernel_d,
        kernel_t,
        &val.start_features,
        &val.end_features,
        &train.start_features,
        &train.end_features,
        val.kron_index(),
        train.kron_index(),
        compute.threads,
    )
}

impl KronRidge {
    /// Trainer with the given configuration, the Kronecker pairwise family,
    /// and the default (serial) execution policy.
    pub fn new(cfg: RidgeConfig) -> Self {
        KronRidge {
            cfg,
            pairwise: PairwiseKernelKind::Kronecker,
            compute: Compute::default(),
        }
    }

    /// Select the pairwise kernel family composed over the GVT engine.
    pub fn with_pairwise(mut self, pairwise: PairwiseKernelKind) -> Self {
        self.pairwise = pairwise;
        self
    }

    /// Set the execution policy (threads, workspace retention). Results are
    /// bitwise identical for every policy.
    pub fn with_compute(mut self, compute: Compute) -> Self {
        self.compute = compute;
        self
    }

    /// Train the dual model (any kernels).
    pub fn fit(&self, train: &Dataset) -> Result<DualModel, String> {
        Ok(self.fit_traced(train, None)?.0)
    }

    /// Train the dual model, tracing risk (and AUC on `val` if given) per
    /// MINRES iteration. Early-stops on validation AUC when
    /// `cfg.patience > 0`.
    pub fn fit_traced(
        &self,
        train: &Dataset,
        val: Option<&Dataset>,
    ) -> Result<(DualModel, TrainTrace), String> {
        train.validate()?;
        if train.n_edges() == 0 {
            return Err("empty training set".into());
        }
        let timer = Timer::start();
        let op = dual_kernel_op(
            train,
            self.cfg.kernel_d,
            self.cfg.kernel_t,
            self.pairwise,
            &self.compute,
        )?;
        let val_op = val
            .map(|v| {
                validation_op(
                    train,
                    v,
                    self.cfg.kernel_d,
                    self.cfg.kernel_t,
                    self.pairwise,
                    &self.compute,
                )
            })
            .transpose()?;
        let sys = crate::gvt::operator::RidgeSystemOp { op: &op, lambda: self.cfg.lambda };
        let y = &train.labels;
        let mut a = vec![0.0; train.n_edges()];
        let mut trace = TrainTrace::default();

        let want_monitor = self.cfg.trace || (val.is_some() && self.cfg.patience > 0);
        let solver_cfg = SolverConfig { max_iters: self.cfg.iterations, tol: self.cfg.tol };
        if want_monitor {
            let mut p = vec![0.0; train.n_edges()];
            let patience = self.cfg.patience;
            let lambda = self.cfg.lambda;
            let mut monitor = |iter: usize, x: &[f64]| -> bool {
                op.apply_into(x, &mut p);
                let loss: f64 =
                    0.5 * p.iter().zip(y).map(|(pi, yi)| (pi - yi) * (pi - yi)).sum::<f64>();
                let risk = loss + 0.5 * lambda * dot(x, &p);
                let val_auc = val_op.as_ref().zip(val).map(|(vo, v)| auc(&v.labels, &vo.predict(x)));
                trace.push(IterRecord { iter, risk, val_auc, elapsed_secs: timer.elapsed_secs() });
                !trace.should_stop(patience)
            };
            minres_cb(&sys, y, &mut a, &solver_cfg, Some(&mut monitor));
        } else {
            minres_cb(&sys, y, &mut a, &solver_cfg, None);
        }

        let model = DualModel {
            dual_coef: a,
            train_start_features: train.start_features.clone(),
            train_end_features: train.end_features.clone(),
            train_idx: train.kron_index(),
            kernel_d: self.cfg.kernel_d,
            kernel_t: self.cfg.kernel_t,
            pairwise: self.pairwise,
        };
        Ok((model, trace))
    }

    /// Train one dual model per λ in `lambdas` through the **batched
    /// compute core**: the kernel operator is built once, and a single
    /// [`block_cg`] solve drives all shifted systems `(Q + λ_j I) a_j = y`
    /// with one multi-RHS GVT apply per iteration — a whole regularization
    /// path for little more than the cost of one model (`cfg.lambda` is
    /// ignored; `cfg.iterations`/`cfg.tol` and the trainer's
    /// [`Compute`] policy apply).
    ///
    /// Uses CG rather than the single-model path's MINRES, so a
    /// one-element path is numerically (not bitwise) equivalent to
    /// [`KronRidge::fit`]; each returned model matches the standalone CG
    /// solve for its λ bit for bit.
    pub fn fit_path(&self, train: &Dataset, lambdas: &[f64]) -> Result<Vec<DualModel>, String> {
        train.validate()?;
        if train.n_edges() == 0 {
            return Err("empty training set".into());
        }
        if lambdas.is_empty() {
            return Ok(Vec::new());
        }
        let op = dual_kernel_op(
            train,
            self.cfg.kernel_d,
            self.cfg.kernel_t,
            self.pairwise,
            &self.compute,
        )?;
        let n = train.n_edges();
        let k = lambdas.len();
        let mut b = vec![0.0; n * k];
        for bj in b.chunks_mut(n) {
            bj.copy_from_slice(&train.labels);
        }
        let mut duals = vec![0.0; n * k];
        let solver_cfg = SolverConfig { max_iters: self.cfg.iterations, tol: self.cfg.tol };
        block_cg(&op, lambdas, &b, &mut duals, &solver_cfg);
        Ok((0..k)
            .map(|j| DualModel {
                dual_coef: duals[j * n..(j + 1) * n].to_vec(),
                train_start_features: train.start_features.clone(),
                train_end_features: train.end_features.clone(),
                train_idx: train.kron_index(),
                kernel_d: self.cfg.kernel_d,
                kernel_t: self.cfg.kernel_t,
                pairwise: self.pairwise,
            })
            .collect())
    }

    /// Train the primal model (implicitly linear vertex kernels; the
    /// configured kernels are ignored).
    pub fn fit_primal(
        &self,
        train: &Dataset,
        val: Option<&Dataset>,
    ) -> Result<(PrimalModel, TrainTrace), String> {
        train.validate()?;
        if train.n_edges() == 0 {
            return Err("empty training set".into());
        }
        if self.pairwise != PairwiseKernelKind::Kronecker {
            return Err(format!(
                "the primal path supports the Kronecker pairwise kernel only (got '{}')",
                self.pairwise.name()
            ));
        }
        let timer = Timer::start();
        let op = PrimalKronOp::new(train);
        let rhs = op.adjoint(&train.labels);
        let sys = PrimalNewtonOp {
            op: &op,
            hess_diag: vec![1.0; train.n_edges()],
            lambda: self.cfg.lambda,
        };
        let mut w = vec![0.0; op.w_dim()];
        let mut trace = TrainTrace::default();
        let solver_cfg = SolverConfig { max_iters: self.cfg.iterations, tol: self.cfg.tol };

        let want_monitor = self.cfg.trace || (val.is_some() && self.cfg.patience > 0);
        if want_monitor {
            let y = &train.labels;
            let patience = self.cfg.patience;
            let lambda = self.cfg.lambda;
            let d_features = train.start_features.cols();
            let r_features = train.end_features.cols();
            let mut monitor = |iter: usize, x: &[f64]| -> bool {
                let p = op.forward(x);
                let loss: f64 =
                    0.5 * p.iter().zip(y).map(|(pi, yi)| (pi - yi) * (pi - yi)).sum::<f64>();
                let risk = loss + 0.5 * lambda * dot(x, x);
                let val_auc = val.map(|v| {
                    let pm = PrimalModel { w: x.to_vec(), d_features, r_features };
                    auc(&v.labels, &pm.predict(v))
                });
                trace.push(IterRecord { iter, risk, val_auc, elapsed_secs: timer.elapsed_secs() });
                !trace.should_stop(patience)
            };
            cg_cb(&sys, &rhs, &mut w, &solver_cfg, Some(&mut monitor));
        } else {
            cg_cb(&sys, &rhs, &mut w, &solver_cfg, None);
        }

        let model = PrimalModel {
            w,
            d_features: train.start_features.cols(),
            r_features: train.end_features.cols(),
        };
        Ok((model, trace))
    }
}

/// Exact (direct) dual ridge solve via Cholesky on the materialized pairwise
/// kernel matrix — `O(n³)`; testing oracle for small problems (any family).
pub fn ridge_exact_dual(
    train: &Dataset,
    cfg: &RidgeConfig,
    pairwise: PairwiseKernelKind,
) -> Vec<f64> {
    let op = dual_kernel_op(train, cfg.kernel_d, cfg.kernel_t, pairwise, &Compute::serial())
        .expect("valid pairwise configuration");
    let mut q = op.explicit_dense();
    q.add_diag(cfg.lambda);
    q.solve_spd(&train.labels).expect("ridge system should be SPD")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::checkerboard::CheckerboardConfig;
    use crate::linalg::vecops::assert_allclose;
    use crate::util::rng::Pcg32;

    fn toy_train(seed: u64, m: usize, q: usize, n: usize) -> Dataset {
        let mut rng = Pcg32::seeded(seed);
        Dataset {
            start_features: crate::linalg::Matrix::from_fn(m, 3, |_, _| rng.normal()),
            end_features: crate::linalg::Matrix::from_fn(q, 2, |_, _| rng.normal()),
            start_idx: (0..n).map(|_| rng.below(m) as u32).collect(),
            end_idx: (0..n).map(|_| rng.below(q) as u32).collect(),
            labels: (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect(),
            name: "toy".into(),
        }
    }

    #[test]
    fn dual_matches_exact_solution() {
        let train = toy_train(400, 8, 7, 25);
        let cfg = RidgeConfig { lambda: 0.5, iterations: 500, tol: 1e-12, ..Default::default() };
        let model = KronRidge::new(cfg).fit(&train).unwrap();
        let exact = ridge_exact_dual(&train, &cfg, PairwiseKernelKind::Kronecker);
        assert_allclose(&model.dual_coef, &exact, 1e-6, 1e-6);
    }

    /// Homogeneous toy set: both vertex roles share one feature space.
    fn toy_homogeneous(seed: u64, v: usize, n: usize) -> Dataset {
        let mut rng = Pcg32::seeded(seed);
        let features = crate::linalg::Matrix::from_fn(v, 2, |_, _| rng.normal());
        Dataset {
            start_features: features.clone(),
            end_features: features,
            start_idx: (0..n).map(|_| rng.below(v) as u32).collect(),
            end_idx: (0..n).map(|_| rng.below(v) as u32).collect(),
            labels: (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect(),
            name: "toy-homo".into(),
        }
    }

    #[test]
    fn pairwise_dual_matches_exact_solution_per_family() {
        // The iterative solve against the matrix-free pairwise operator must
        // agree with the direct Cholesky solve on the materialized matrix.
        let train = toy_homogeneous(420, 9, 24);
        for pairwise in [
            crate::gvt::PairwiseKernelKind::SymmetricKron,
            crate::gvt::PairwiseKernelKind::AntiSymmetricKron,
            crate::gvt::PairwiseKernelKind::Cartesian,
        ] {
            let cfg = RidgeConfig {
                lambda: 1.0,
                kernel_d: KernelKind::Gaussian { gamma: 0.4 },
                kernel_t: KernelKind::Gaussian { gamma: 0.4 },
                iterations: 800,
                tol: 1e-13,
                ..Default::default()
            };
            let model = KronRidge::new(cfg).with_pairwise(pairwise).fit(&train).unwrap();
            let exact = ridge_exact_dual(&train, &cfg, pairwise);
            assert_allclose(&model.dual_coef, &exact, 1e-6, 1e-6);
        }
    }

    #[test]
    fn symmetric_rejects_heterogeneous_feature_spaces() {
        // toy_train carries 3-d start and 2-d end features — no shared domain.
        let train = toy_train(421, 6, 6, 20);
        let err = KronRidge::new(RidgeConfig::default())
            .with_pairwise(crate::gvt::PairwiseKernelKind::SymmetricKron)
            .fit(&train)
            .unwrap_err();
        assert!(err.contains("feature space"), "{err}");
        // mismatched kernels over a shared space are rejected too
        let homo = toy_homogeneous(422, 6, 18);
        let cfg = RidgeConfig {
            kernel_d: KernelKind::Gaussian { gamma: 1.0 },
            kernel_t: KernelKind::Linear,
            ..Default::default()
        };
        assert!(KronRidge::new(cfg)
            .with_pairwise(crate::gvt::PairwiseKernelKind::SymmetricKron)
            .fit(&homo)
            .is_err());
    }

    #[test]
    fn dual_and_primal_agree_for_linear_kernel() {
        // With linear kernels the dual and primal models define the same
        // function; compare predictions on held-out edges.
        let data = toy_train(401, 20, 15, 120);
        let (train, test) = data.zero_shot_split(0.3, 5);
        let cfg = RidgeConfig { lambda: 1.0, iterations: 800, tol: 1e-13, ..Default::default() };
        let ridge = KronRidge::new(cfg);
        let dual = ridge.fit(&train).unwrap();
        let (primal, _) = ridge.fit_primal(&train, None).unwrap();
        let pd = dual.predict(&test);
        let pp = primal.predict(&test);
        assert_allclose(&pd, &pp, 1e-5, 1e-4);
    }

    #[test]
    fn trace_records_risk_decrease() {
        let train = toy_train(402, 10, 10, 60);
        let cfg = RidgeConfig {
            lambda: 0.1,
            iterations: 30,
            trace: true,
            tol: 1e-14,
            ..Default::default()
        };
        let (_, trace) = KronRidge::new(cfg).fit_traced(&train, None).unwrap();
        assert!(trace.records.len() >= 5);
        // risk should broadly decrease from first to last
        assert!(trace.final_risk() < trace.records[0].risk);
    }

    #[test]
    fn learns_checkerboard_with_gaussian_kernel() {
        let data =
            CheckerboardConfig { m: 60, q: 60, density: 0.4, noise: 0.1, feature_range: 8.0, seed: 3, ..Default::default() }.generate();
        let (train, test) = data.zero_shot_split(0.3, 9);
        let cfg = RidgeConfig {
            lambda: 2f64.powi(-7),
            kernel_d: KernelKind::Gaussian { gamma: 1.0 },
            kernel_t: KernelKind::Gaussian { gamma: 1.0 },
            iterations: 100,
            ..Default::default()
        };
        let model = KronRidge::new(cfg).fit(&train).unwrap();
        let test_auc = auc(&test.labels, &model.predict(&test));
        assert!(test_auc > 0.7, "AUC={test_auc}");
    }

    #[test]
    fn early_stopping_halts_iterations() {
        let data = toy_train(403, 15, 15, 100);
        let (train, val) = data.zero_shot_split(0.3, 2);
        let cfg = RidgeConfig {
            lambda: 1e-6,
            iterations: 100,
            trace: true,
            patience: 3,
            tol: 1e-16,
            ..Default::default()
        };
        let (_, trace) = KronRidge::new(cfg).fit_traced(&train, Some(&val)).unwrap();
        // with noise labels and tiny lambda, AUC should saturate and stop early
        assert!(
            trace.records.len() < 100,
            "expected early stop, got {} iters",
            trace.records.len()
        );
    }

    #[test]
    fn rejects_empty_training_set() {
        let ds = toy_train(404, 5, 5, 10).subset_by_edges(&[], "empty");
        assert!(KronRidge::new(RidgeConfig::default()).fit(&ds).is_err());
    }

    #[test]
    fn fit_path_matches_exact_solutions_per_lambda() {
        let train = toy_train(406, 8, 7, 26);
        let lambdas = [0.25, 1.0, 4.0];
        let cfg = RidgeConfig { iterations: 600, tol: 1e-13, ..Default::default() };
        let models = KronRidge::new(cfg).fit_path(&train, &lambdas).unwrap();
        assert_eq!(models.len(), lambdas.len());
        for (model, &lambda) in models.iter().zip(&lambdas) {
            let exact = ridge_exact_dual(
                &train,
                &RidgeConfig { lambda, ..cfg },
                PairwiseKernelKind::Kronecker,
            );
            assert_allclose(&model.dual_coef, &exact, 1e-6, 1e-6);
        }
    }

    #[test]
    fn fit_path_threaded_matches_serial_bitwise() {
        let train = toy_train(407, 30, 30, 2400);
        let lambdas = [0.5, 2.0];
        let base = RidgeConfig { iterations: 25, tol: 1e-12, ..Default::default() };
        let serial = KronRidge::new(base).fit_path(&train, &lambdas).unwrap();
        let par = KronRidge::new(base)
            .with_compute(crate::api::Compute::threads(4))
            .fit_path(&train, &lambdas)
            .unwrap();
        for (s, p) in serial.iter().zip(&par) {
            assert_eq!(s.dual_coef, p.dual_coef);
        }
    }

    #[test]
    fn fit_path_empty_lambdas_returns_no_models() {
        let train = toy_train(408, 5, 5, 12);
        let models =
            KronRidge::new(RidgeConfig::default()).fit_path(&train, &[]).unwrap();
        assert!(models.is_empty());
    }

    #[test]
    fn threaded_training_matches_serial() {
        // The threads knob must not change the trained model: parallel GVT
        // matvecs are bitwise identical to serial ones, and MINRES is fully
        // deterministic given identical matvecs.
        let train = toy_train(405, 40, 40, 2600);
        let base = RidgeConfig { lambda: 0.3, iterations: 40, tol: 1e-12, ..Default::default() };
        let serial = KronRidge::new(base).fit(&train).unwrap();
        for threads in [2, 4] {
            let par = KronRidge::new(base)
                .with_compute(crate::api::Compute::threads(threads))
                .fit(&train)
                .unwrap();
            assert_eq!(serial.dual_coef, par.dual_coef, "threads={threads}");
        }
    }
}
