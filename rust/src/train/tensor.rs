//! Ridge regression on **D-way tensor-product chains** (§4.1 generalized):
//! solve `(Q + λI)a = y` with `Q = R(K₁⊗…⊗K_D)Rᵀ` applied matrix-free
//! through [`TensorKernelOp`] — the same conjugate-gradient machinery that
//! drives the two-factor trainers, pointed at a D-way chain.
//!
//! The two-factor [`KronRidge`](super::KronRidge) remains the pairwise
//! entry point (eigendecomposition fast paths, preconditioning, tracing);
//! this trainer is the grid/tensor path behind
//! [`Learner::fit_tensor`](crate::api::Learner::fit_tensor).

use std::sync::Arc;

use crate::api::Compute;
use crate::data::TensorDataset;
use crate::gvt::operator::RidgeSystemOp;
use crate::gvt::TensorKernelOp;
use crate::kernels::KernelKind;
use crate::linalg::solvers::{block_cg, cg, SolverConfig};
use crate::linalg::Matrix;
use crate::model::TensorModel;

/// Configuration for [`TensorRidge`].
#[derive(Debug, Clone)]
pub struct TensorRidgeConfig {
    /// Regularization parameter λ.
    pub lambda: f64,
    /// One kernel per mode. An empty list broadcasts [`KernelKind::Linear`]
    /// to every mode; a one-element list broadcasts that kernel.
    pub kernels: Vec<KernelKind>,
    /// CG iteration cap.
    pub iterations: usize,
    /// Relative residual tolerance of the CG solve.
    pub tol: f64,
}

impl Default for TensorRidgeConfig {
    fn default() -> Self {
        TensorRidgeConfig { lambda: 1.0, kernels: Vec::new(), iterations: 100, tol: 1e-9 }
    }
}

/// Ridge regression trainer over a D-way tensor-product chain.
///
/// Builds one symmetric kernel matrix per mode, assembles the matrix-free
/// system operator `Q + λI`, and runs conjugate gradient — `O(n·Σ_d m_d)`
/// per iteration through the chained GVT apply instead of the `O(n²)` a
/// materialized `Q` would cost.
#[derive(Debug, Clone)]
pub struct TensorRidge {
    cfg: TensorRidgeConfig,
    compute: Compute,
}

impl TensorRidge {
    /// Create a trainer from its configuration (default [`Compute`]).
    pub fn new(cfg: TensorRidgeConfig) -> TensorRidge {
        TensorRidge { cfg, compute: Compute::default() }
    }

    /// Set the execution policy (threads etc.). Transparent to results.
    pub fn with_compute(mut self, compute: Compute) -> TensorRidge {
        self.compute = compute;
        self
    }

    /// Resolve the per-mode kernel list against the dataset order.
    fn mode_kernels(&self, order: usize) -> Result<Vec<KernelKind>, String> {
        match self.cfg.kernels.len() {
            0 => Ok(vec![KernelKind::Linear; order]),
            1 => Ok(vec![self.cfg.kernels[0]; order]),
            n if n == order => Ok(self.cfg.kernels.clone()),
            n => Err(format!("{n} mode kernels configured but the dataset has {order} modes")),
        }
    }

    /// Build the training kernel operator (one symmetric kernel matrix per
    /// mode) and the resolved kernel list.
    fn kernel_op(&self, data: &TensorDataset) -> Result<(TensorKernelOp, Vec<KernelKind>), String> {
        data.validate()?;
        let kernels = self.mode_kernels(data.order())?;
        let threads = self.compute.threads;
        let factors: Vec<Arc<Matrix>> = data
            .features
            .iter()
            .zip(&kernels)
            .map(|(f, k)| Arc::new(k.square_matrix_threaded(f, threads)))
            .collect();
        let op = TensorKernelOp::new(factors, data.index.clone()).with_threads(threads);
        Ok((op, kernels))
    }

    fn solver_cfg(&self) -> SolverConfig {
        SolverConfig { max_iters: self.cfg.iterations, tol: self.cfg.tol }
    }

    /// Train: solve `(Q + λI)a = y` by CG and package the dual model.
    pub fn fit(&self, data: &TensorDataset) -> Result<TensorModel, String> {
        let (op, kernels) = self.kernel_op(data)?;
        let sys = RidgeSystemOp { op: &op, lambda: self.cfg.lambda };
        let mut a = vec![0.0; data.n_edges()];
        cg(&sys, &data.labels, &mut a, &self.solver_cfg());
        Ok(TensorModel {
            dual_coef: a,
            train_features: data.features.clone(),
            train_idx: data.index.clone(),
            kernels,
        })
    }

    /// Train the whole regularization path in one batched block-CG solve
    /// over the shared chain operator (one model per λ; the configured
    /// `lambda` is ignored). Every λ reuses the same per-iteration chained
    /// GVT apply, so the path costs barely more than one solve.
    pub fn fit_path(
        &self,
        data: &TensorDataset,
        lambdas: &[f64],
    ) -> Result<Vec<TensorModel>, String> {
        if lambdas.is_empty() {
            return Err("fit_path needs at least one lambda".into());
        }
        if let Some(bad) = lambdas.iter().find(|l| !l.is_finite() || **l < 0.0) {
            return Err(format!("lambdas must be finite and non-negative, got {bad}"));
        }
        let (op, kernels) = self.kernel_op(data)?;
        let n = data.n_edges();
        let k = lambdas.len();
        let mut b = Vec::with_capacity(n * k);
        for _ in 0..k {
            b.extend_from_slice(&data.labels);
        }
        let mut duals = vec![0.0; n * k];
        block_cg(&op, lambdas, &b, &mut duals, &self.solver_cfg());
        Ok(duals
            .chunks(n.max(1))
            .map(|a| TensorModel {
                dual_coef: a.to_vec(),
                train_features: data.features.clone(),
                train_idx: data.index.clone(),
                kernels: kernels.clone(),
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::GridCheckerboardConfig;
    use crate::linalg::vecops::assert_allclose;

    fn small_grid(seed: u64) -> TensorDataset {
        GridCheckerboardConfig {
            dims: vec![6, 5, 4],
            density: 0.5,
            noise: 0.1,
            feature_range: 4.0,
            seed,
        }
        .generate()
    }

    fn gaussian_cfg(lambda: f64) -> TensorRidgeConfig {
        TensorRidgeConfig {
            lambda,
            kernels: vec![KernelKind::Gaussian { gamma: 0.5 }],
            iterations: 400,
            tol: 1e-12,
        }
    }

    #[test]
    fn fit_solves_the_dual_system() {
        let data = small_grid(31);
        let trainer = TensorRidge::new(gaussian_cfg(0.3));
        let model = trainer.fit(&data).unwrap();
        model.validate().unwrap();
        // residual check: (Q + λ I) a ≈ y through the matrix-free operator
        let (op, _) = trainer.kernel_op(&data).unwrap();
        let mut r = vec![0.0; data.n_edges()];
        op.apply_into(&model.dual_coef, &mut r);
        for (ri, (&ai, &yi)) in r.iter_mut().zip(model.dual_coef.iter().zip(&data.labels)) {
            *ri += 0.3 * ai - yi;
        }
        let resid = r.iter().map(|x| x * x).sum::<f64>().sqrt();
        let ynorm = data.labels.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!(resid <= 1e-8 * ynorm, "residual {resid} vs ‖y‖ {ynorm}");
    }

    #[test]
    fn fit_is_deterministic_across_thread_counts() {
        let data = small_grid(32);
        let serial = TensorRidge::new(gaussian_cfg(0.5)).fit(&data).unwrap();
        for threads in [2, 4] {
            let threaded = TensorRidge::new(gaussian_cfg(0.5))
                .with_compute(Compute::threads(threads))
                .fit(&data)
                .unwrap();
            assert_eq!(serial.dual_coef, threaded.dual_coef, "threads={threads}");
        }
    }

    #[test]
    fn fit_path_matches_individual_fits() {
        let data = small_grid(33);
        let lambdas = [0.1, 1.0, 10.0];
        let trainer = TensorRidge::new(gaussian_cfg(0.0));
        let path = trainer.fit_path(&data, &lambdas).unwrap();
        assert_eq!(path.len(), 3);
        for (model, &lambda) in path.iter().zip(&lambdas) {
            let single = TensorRidge::new(gaussian_cfg(lambda)).fit(&data).unwrap();
            assert_allclose(&model.dual_coef, &single.dual_coef, 1e-8, 1e-8);
        }
    }

    #[test]
    fn kernel_broadcast_and_mismatch() {
        let data = small_grid(34);
        // empty list broadcasts linear; explicit per-mode list accepted
        assert!(TensorRidge::new(TensorRidgeConfig::default()).fit(&data).is_ok());
        let cfg = TensorRidgeConfig {
            kernels: vec![KernelKind::Linear, KernelKind::Linear],
            ..TensorRidgeConfig::default()
        };
        let err = TensorRidge::new(cfg).fit(&data).unwrap_err();
        assert!(err.contains("2 mode kernels"), "{err}");
    }
}
