//! Training algorithms: the truncated-Newton optimization framework of §3.3
//! (Algorithms 2 and 3) and the two case studies of §4 — Kronecker ridge
//! regression and the Kronecker L2-SVM.
//!
//! All trainers share:
//! * matrix-free operators from [`crate::gvt`] (dual) /
//!   [`crate::model::primal`] (primal) — the Kronecker product is never
//!   materialized;
//! * per-iteration tracing of regularized risk and validation AUC (the data
//!   behind Figs. 3–5);
//! * early stopping on validation AUC (§3.3, §5.2).
//!
//! [`tensor`] extends the ridge case study to D-way tensor-product chains:
//! the same CG machinery over a [`TensorKernelOp`](crate::gvt::TensorKernelOp).
//!
//! [`stochastic`] scales past the exact solvers: mini-batch sampled-GVT
//! block coordinate descent over a streaming edge source
//! ([`crate::data::stream`]), never holding the label vector or edge index
//! in one allocation.

pub mod trace;
pub mod ridge;
pub mod svm;
pub mod newton;
pub mod tensor;
pub mod stochastic;

pub use ridge::{KronRidge, RidgeConfig, RidgeSolver};
pub use svm::{KronSvm, SvmConfig};
pub use newton::{NewtonConfig, NewtonTrainer};
pub use stochastic::{
    fit_stochastic, fit_stochastic_source, EdgeSampler, SamplingMode, StepPolicy,
    StochasticConfig, StochasticResult,
};
pub use tensor::{TensorRidge, TensorRidgeConfig};
pub use trace::{IterRecord, TrainTrace};
