//! Kronecker L2-SVM (§4.2) via truncated Newton optimization (Algorithm 2
//! dual / Algorithm 3 primal).
//!
//! Each outer iteration computes training predictions `p = R(G⊗K)Rᵀa` with
//! the generalized vec trick, forms the active set
//! `S = {i : yᵢ·pᵢ < 1}`, and solves the Newton system
//! `(diag(1_S)·Q + λI) x = g + λa` approximately with QMR ([50]) truncated
//! at `inner_iters` iterations (the paper's "10 inner iterations"), then
//! steps `a ← a − δx` with constant `δ = 1`.
//!
//! Matvecs skip zero coefficients, so as the model becomes sparse the
//! per-iteration cost falls toward `O(min(q‖a‖₀ + m|S|, m‖a‖₀ + q|S|))`.

use crate::api::Compute;
use crate::data::Dataset;
use crate::eval::auc::auc;
use crate::gvt::operator::SvmNewtonOp;
use crate::gvt::PairwiseKernelKind;
use crate::kernels::KernelKind;
use crate::linalg::solvers::{cg, qmr, SolverConfig};
use crate::linalg::vecops::dot;
use crate::losses::{L2SvmLoss, Loss};
use crate::model::primal::{PrimalKronOp, PrimalNewtonOp};
use crate::model::{DualModel, PrimalModel};
use crate::train::ridge::{dual_kernel_op, validation_op};
use crate::train::trace::{IterRecord, TrainTrace};
use crate::util::timer::Timer;

/// Kronecker SVM configuration.
#[derive(Debug, Clone, Copy)]
pub struct SvmConfig {
    /// Regularization parameter λ.
    pub lambda: f64,
    /// Start-vertex kernel `k`.
    pub kernel_d: KernelKind,
    /// End-vertex kernel `g`.
    pub kernel_t: KernelKind,
    /// Outer (truncated Newton) iterations — paper default 10.
    pub outer_iters: usize,
    /// Inner (QMR / CG) iterations per Newton step — paper default 10.
    pub inner_iters: usize,
    /// Step size δ (paper uses the constant 1).
    pub delta: f64,
    /// Record per-outer-iteration risk/AUC.
    pub trace: bool,
    /// Early-stopping patience on validation AUC (0 disables).
    pub patience: usize,
    /// Coefficients with |aᵢ| below this are snapped to exact zero after
    /// each Newton step (inactive coordinates converge to 0; truncated inner
    /// solves leave numerical dust that would defeat the sparse shortcut).
    pub sparsity_threshold: f64,
}

impl Default for SvmConfig {
    fn default() -> Self {
        SvmConfig {
            lambda: 1.0,
            kernel_d: KernelKind::Linear,
            kernel_t: KernelKind::Linear,
            outer_iters: 10,
            inner_iters: 10,
            delta: 1.0,
            trace: false,
            patience: 0,
            sparsity_threshold: 1e-12,
        }
    }
}

/// Kronecker L2-SVM trainer.
///
/// Method-specific knobs live in [`SvmConfig`]; the pairwise kernel family
/// and the execution policy are set with [`KronSvm::with_pairwise`] /
/// [`KronSvm::with_compute`] (or through the
/// [`Learner`](crate::api::Learner) builder).
#[derive(Debug, Clone)]
pub struct KronSvm {
    /// Training configuration.
    pub cfg: SvmConfig,
    /// Pairwise kernel family composed over the GVT engine.
    pub pairwise: PairwiseKernelKind,
    /// Execution policy (threads, workspace retention); transparent to
    /// results.
    pub compute: Compute,
}

impl KronSvm {
    /// Trainer with the given configuration, the Kronecker pairwise family,
    /// and the default (serial) execution policy.
    pub fn new(cfg: SvmConfig) -> Self {
        KronSvm {
            cfg,
            pairwise: PairwiseKernelKind::Kronecker,
            compute: Compute::default(),
        }
    }

    /// Select the pairwise kernel family composed over the GVT engine.
    pub fn with_pairwise(mut self, pairwise: PairwiseKernelKind) -> Self {
        self.pairwise = pairwise;
        self
    }

    /// Set the execution policy (threads, workspace retention). Results are
    /// bitwise identical for every policy.
    pub fn with_compute(mut self, compute: Compute) -> Self {
        self.compute = compute;
        self
    }

    /// Train the dual model.
    pub fn fit(&self, train: &Dataset) -> Result<DualModel, String> {
        Ok(self.fit_traced(train, None)?.0)
    }

    /// Train the dual model with tracing / early stopping.
    pub fn fit_traced(
        &self,
        train: &Dataset,
        val: Option<&Dataset>,
    ) -> Result<(DualModel, TrainTrace), String> {
        train.validate()?;
        let n = train.n_edges();
        if n == 0 {
            return Err("empty training set".into());
        }
        for &y in &train.labels {
            if y != 1.0 && y != -1.0 {
                return Err("SVM requires ±1 labels".into());
            }
        }
        let timer = Timer::start();
        let op = dual_kernel_op(
            train,
            self.cfg.kernel_d,
            self.cfg.kernel_t,
            self.pairwise,
            &self.compute,
        )?;
        let val_op = val
            .map(|v| {
                validation_op(
                    train,
                    v,
                    self.cfg.kernel_d,
                    self.cfg.kernel_t,
                    self.pairwise,
                    &self.compute,
                )
            })
            .transpose()?;
        let y = &train.labels;
        let loss = L2SvmLoss;

        let mut a = vec![0.0; n];
        let mut p = vec![0.0; n]; // p = Q a (a = 0 ⇒ p = 0)
        let mut trace = TrainTrace::default();
        let inner_cfg = SolverConfig { max_iters: self.cfg.inner_iters, tol: 1e-12 };

        for outer in 1..=self.cfg.outer_iters {
            // Active set and gradient pieces at the current point.
            let mask = L2SvmLoss::active_mask(&p, y);
            if mask.iter().all(|&m| m == 0.0) {
                break; // zero loss and zero gradient of the loss term
            }
            // rhs = g + λa with g = 1_S ∘ (p − y)
            let rhs: Vec<f64> = (0..n)
                .map(|i| mask[i] * (p[i] - y[i]) + self.cfg.lambda * a[i])
                .collect();
            let newton = SvmNewtonOp::new(&op, mask, self.cfg.lambda);
            let mut x = vec![0.0; n];
            qmr(&newton, &rhs, &mut x, &inner_cfg);
            for i in 0..n {
                a[i] -= self.cfg.delta * x[i];
                if a[i].abs() < self.cfg.sparsity_threshold {
                    a[i] = 0.0;
                }
            }
            op.apply_into(&a, &mut p);

            if self.cfg.trace || (val.is_some() && self.cfg.patience > 0) {
                let risk = loss.value(&p, y) + 0.5 * self.cfg.lambda * dot(&a, &p);
                let val_auc =
                    val_op.as_ref().zip(val).map(|(vo, v)| auc(&v.labels, &vo.predict(&a)));
                trace.push(IterRecord {
                    iter: outer,
                    risk,
                    val_auc,
                    elapsed_secs: timer.elapsed_secs(),
                });
                if trace.should_stop(self.cfg.patience) {
                    break;
                }
            }
        }

        let model = DualModel {
            dual_coef: a,
            train_start_features: train.start_features.clone(),
            train_end_features: train.end_features.clone(),
            train_idx: train.kron_index(),
            kernel_d: self.cfg.kernel_d,
            kernel_t: self.cfg.kernel_t,
            pairwise: self.pairwise,
        };
        Ok((model, trace))
    }

    /// Train the primal model (linear vertex kernels). The Newton system
    /// `XᵀHX + λI` is symmetric PSD, so the inner solver is CG.
    pub fn fit_primal(
        &self,
        train: &Dataset,
        val: Option<&Dataset>,
    ) -> Result<(PrimalModel, TrainTrace), String> {
        train.validate()?;
        let n = train.n_edges();
        if n == 0 {
            return Err("empty training set".into());
        }
        if self.pairwise != PairwiseKernelKind::Kronecker {
            return Err(format!(
                "the primal path supports the Kronecker pairwise kernel only (got '{}')",
                self.pairwise.name()
            ));
        }
        let timer = Timer::start();
        let op = PrimalKronOp::new(train);
        let y = &train.labels;
        let loss = L2SvmLoss;

        let mut w = vec![0.0; op.w_dim()];
        let mut p = vec![0.0; n];
        let mut trace = TrainTrace::default();
        let inner_cfg = SolverConfig { max_iters: self.cfg.inner_iters, tol: 1e-12 };
        let d_features = train.start_features.cols();
        let r_features = train.end_features.cols();

        for outer in 1..=self.cfg.outer_iters {
            let mask = L2SvmLoss::active_mask(&p, y);
            if mask.iter().all(|&m| m == 0.0) {
                break;
            }
            // rhs = Xᵀ g + λw with g = 1_S ∘ (p − y)
            let g: Vec<f64> = (0..n).map(|i| mask[i] * (p[i] - y[i])).collect();
            let mut rhs = op.adjoint(&g);
            for i in 0..rhs.len() {
                rhs[i] += self.cfg.lambda * w[i];
            }
            let newton = PrimalNewtonOp { op: &op, hess_diag: mask, lambda: self.cfg.lambda };
            let mut x = vec![0.0; op.w_dim()];
            cg(&newton, &rhs, &mut x, &inner_cfg);
            for i in 0..w.len() {
                w[i] -= self.cfg.delta * x[i];
            }
            p = op.forward(&w);

            if self.cfg.trace || (val.is_some() && self.cfg.patience > 0) {
                let risk = loss.value(&p, y) + 0.5 * self.cfg.lambda * dot(&w, &w);
                let val_auc = val.map(|v| {
                    let pm = PrimalModel { w: w.clone(), d_features, r_features };
                    auc(&v.labels, &pm.predict(v))
                });
                trace.push(IterRecord {
                    iter: outer,
                    risk,
                    val_auc,
                    elapsed_secs: timer.elapsed_secs(),
                });
                if trace.should_stop(self.cfg.patience) {
                    break;
                }
            }
        }

        Ok((PrimalModel { w, d_features, r_features }, trace))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::checkerboard::CheckerboardConfig;
    use crate::linalg::solvers::LinOp;
    use crate::linalg::vecops::assert_allclose;
    use crate::util::rng::Pcg32;

    fn toy_train(seed: u64, m: usize, q: usize, n: usize) -> Dataset {
        let mut rng = Pcg32::seeded(seed);
        Dataset {
            start_features: crate::linalg::Matrix::from_fn(m, 3, |_, _| rng.normal()),
            end_features: crate::linalg::Matrix::from_fn(q, 2, |_, _| rng.normal()),
            start_idx: (0..n).map(|_| rng.below(m) as u32).collect(),
            end_idx: (0..n).map(|_| rng.below(q) as u32).collect(),
            labels: (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect(),
            name: "toy".into(),
        }
    }

    #[test]
    fn risk_decreases_over_outer_iterations() {
        let train = toy_train(500, 12, 10, 70);
        let cfg = SvmConfig {
            lambda: 0.05,
            kernel_d: KernelKind::Gaussian { gamma: 0.5 },
            kernel_t: KernelKind::Gaussian { gamma: 0.5 },
            outer_iters: 15,
            inner_iters: 20,
            trace: true,
            ..Default::default()
        };
        let (_, trace) = KronSvm::new(cfg).fit_traced(&train, None).unwrap();
        assert!(trace.records.len() >= 3);
        // Risk of the zero model: L2-SVM loss at p = 0 is n/2.
        let zero_risk = 0.5 * train.n_edges() as f64;
        let last = trace.records.last().unwrap().risk;
        assert!(last < 0.9 * zero_risk, "risk {zero_risk} -> {last}");
        // and the trace is (weakly) monotone within float noise
        let first = trace.records.first().unwrap().risk;
        assert!(last <= first * (1.0 + 1e-9), "risk {first} -> {last}");
    }

    #[test]
    fn converges_towards_optimality_conditions() {
        // At the optimum of the L2-SVM dual formulation used here,
        // the gradient R(G⊗K)Rᵀ(g + λa) must vanish; since Q is PSD it
        // suffices that ‖g + λa‖ is small on a well-conditioned toy problem.
        let train = toy_train(501, 8, 8, 30);
        let cfg = SvmConfig {
            lambda: 1.0,
            outer_iters: 60,
            inner_iters: 60,
            ..Default::default()
        };
        let model = KronSvm::new(cfg).fit(&train).unwrap();
        let op = dual_kernel_op(
            &train,
            cfg.kernel_d,
            cfg.kernel_t,
            crate::gvt::PairwiseKernelKind::Kronecker,
            &Compute::serial(),
        )
        .unwrap();
        let p = op.apply_vec(&model.dual_coef);
        let mask = L2SvmLoss::active_mask(&p, &train.labels);
        let resid: Vec<f64> = (0..30)
            .map(|i| mask[i] * (p[i] - train.labels[i]) + cfg.lambda * model.dual_coef[i])
            .collect();
        let norm = crate::linalg::vecops::norm2(&resid);
        assert!(norm < 1e-3, "optimality residual={norm}");
    }

    #[test]
    fn learns_checkerboard() {
        let data =
            CheckerboardConfig { m: 60, q: 60, density: 0.4, noise: 0.1, feature_range: 8.0, seed: 8, ..Default::default() }.generate();
        let (train, test) = data.zero_shot_split(0.3, 4);
        let cfg = SvmConfig {
            lambda: 2f64.powi(-7),
            kernel_d: KernelKind::Gaussian { gamma: 1.0 },
            kernel_t: KernelKind::Gaussian { gamma: 1.0 },
            outer_iters: 10,
            inner_iters: 10,
            ..Default::default()
        };
        let model = KronSvm::new(cfg).fit(&train).unwrap();
        let test_auc = auc(&test.labels, &model.predict(&test));
        assert!(test_auc > 0.7, "AUC={test_auc}");
    }

    #[test]
    fn model_becomes_sparse_when_separable() {
        // Fewer active constraints → some dual coefficients exactly zero.
        let mut train = toy_train(502, 10, 10, 60);
        // Make labels easily separable: label by sign of a feature product.
        for h in 0..train.n_edges() {
            let d = train.start_features.get(train.start_idx[h] as usize, 0);
            let t = train.end_features.get(train.end_idx[h] as usize, 0);
            train.labels[h] = if d * t >= 0.0 { 1.0 } else { -1.0 };
        }
        let cfg = SvmConfig {
            lambda: 0.01,
            kernel_d: KernelKind::Gaussian { gamma: 0.5 },
            kernel_t: KernelKind::Gaussian { gamma: 0.5 },
            outer_iters: 40,
            inner_iters: 40,
            sparsity_threshold: 1e-8,
            ..Default::default()
        };
        let model = KronSvm::new(cfg).fit(&train).unwrap();
        assert!(model.nnz() < train.n_edges(), "nnz={} of {}", model.nnz(), train.n_edges());
    }

    #[test]
    fn primal_and_dual_agree_for_linear_kernel() {
        let data = toy_train(503, 18, 14, 110);
        let (train, test) = data.zero_shot_split(0.3, 6);
        let cfg = SvmConfig {
            lambda: 1.0,
            outer_iters: 40,
            inner_iters: 80,
            sparsity_threshold: 0.0,
            ..Default::default()
        };
        let svm = KronSvm::new(cfg);
        let dual = svm.fit(&train).unwrap();
        let (primal, _) = svm.fit_primal(&train, None).unwrap();
        let pd = dual.predict(&test);
        let pp = primal.predict(&test);
        assert_allclose(&pd, &pp, 2e-3, 2e-2);
    }

    #[test]
    fn threaded_training_matches_serial() {
        // Truncated Newton + QMR is deterministic given identical matvecs,
        // and parallel matvecs are bitwise identical to serial ones.
        let train = toy_train(505, 35, 35, 2200);
        let base = SvmConfig { lambda: 0.1, outer_iters: 5, inner_iters: 8, ..Default::default() };
        let serial = KronSvm::new(base).fit(&train).unwrap();
        let par = KronSvm::new(base)
            .with_compute(Compute::threads(4))
            .fit(&train)
            .unwrap();
        assert_eq!(serial.dual_coef, par.dual_coef);
    }

    #[test]
    fn rejects_non_binary_labels() {
        let mut train = toy_train(504, 5, 5, 12);
        train.labels[3] = 0.5;
        assert!(KronSvm::new(SvmConfig::default()).fit(&train).is_err());
    }
}
