//! Stochastic mini-batch dual training (sampled vec trick, arXiv
//! 2606.16979): randomized **block coordinate descent** on the dual ridge
//! objective
//!
//! ```text
//! J(a) = ½ aᵀ(Q + λI)a − yᵀa ,    Q = R(G⊗K)Rᵀ ,
//! ```
//!
//! where every per-iteration operator touch is the GVT apply restricted to a
//! sampled edge batch ([`BatchPlan`]) instead of the full `O(e(q+m))` apply:
//!
//! * a persistent stage-1 accumulator `T ∈ R^{m×q}` (the scatter of the
//!   *entire* current dual vector) makes the batch gradient **exact**:
//!   `g_B = (Qa)_B + λ a_B − y_B` costs only a strided gather
//!   ([`GvtEngine::gather_batch`], `O(|B|·m)`);
//! * after the block step `a_B ← a_B − η_B g_B`, the accumulator is patched
//!   incrementally ([`GvtEngine::scatter_batch`], `O(|B|·q)`) — no full
//!   re-scatter per batch;
//! * because the gradient is exact (not an unbiased estimate), the descent
//!   is monotone with no stochastic noise floor: the *randomness* is only in
//!   the visit order, the *iterates* are a deterministic function of the
//!   seed.
//!
//! Edges arrive through a [`StreamingEdgeSource`]
//! ([`crate::data::stream`]), chunk-major: per epoch the chunk visit order
//! is shuffled, each loaded chunk is sampled into batches
//! ([`EdgeSampler`]), and only the dual vector (length `e`) plus one chunk
//! ever need a full allocation — the label vector and edge index never do.
//!
//! Step sizes ([`StepPolicy::Auto`]) use the per-batch trace bound
//! `η_B = 1 / (λ + Σ_{h∈B} Q_hh)` with `Q_hh = G[t_h,t_h]·K[s_h,s_h]`:
//! since `λmax(Q_BB + λI) ≤ λ + trace(Q_BB)`, the exact-gradient block step
//! can never overshoot. Conservative by design; [`StepPolicy::Fixed`]
//! overrides it when the spectrum is known.
//!
//! Per epoch the trainer re-scatters the accumulator from scratch
//! ([`StochasticConfig::snapshot_every`]) — the SVRG-style snapshot that
//! bounds float drift from millions of incremental patches — and runs one
//! streaming monitor pass producing the residual `‖y − (Q+λI)a‖` for the
//! [`Stopping`]-compatible convergence test plus the same regularized-risk
//! trace the exact solvers record.

use crate::api::Compute;
use crate::data::stream::{InMemorySource, StreamingEdgeSource};
use crate::data::Dataset;
use crate::eval::auc::auc;
use crate::gvt::{BatchPlan, Branch, GvtEngine, KronIndex, PairwiseKernelKind, PairwiseOp};
use crate::kernels::KernelKind;
use crate::linalg::solvers::{SolverConfig, Stopping};
use crate::linalg::Matrix;
use crate::model::DualModel;
use crate::train::trace::{IterRecord, TrainTrace};
use crate::util::rng::Pcg32;
use crate::util::timer::Timer;

/// How [`EdgeSampler`] draws batches within each loaded chunk.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SamplingMode {
    /// Shuffle the chunk's edges once per epoch and cut consecutive
    /// batches: every edge is visited exactly once per epoch (the mode that
    /// makes the descent a true block *coordinate* pass).
    #[default]
    EpochShuffle,
    /// Draw `⌈chunk/batch⌉` batches of `batch_edges` positions uniformly
    /// with replacement from the loaded chunk (classic SGD sampling; edges
    /// may repeat within and across batches).
    WithReplacement,
}

impl SamplingMode {
    /// Parse a CLI name: `epoch-shuffle` or `with-replacement`.
    pub fn parse(s: &str) -> Result<SamplingMode, String> {
        match s {
            "epoch-shuffle" => Ok(SamplingMode::EpochShuffle),
            "with-replacement" => Ok(SamplingMode::WithReplacement),
            other => Err(format!(
                "unknown sampling mode '{other}' (expected epoch-shuffle or with-replacement)"
            )),
        }
    }

    /// CLI name of this mode.
    pub fn name(&self) -> &'static str {
        match self {
            SamplingMode::EpochShuffle => "epoch-shuffle",
            SamplingMode::WithReplacement => "with-replacement",
        }
    }
}

/// Step-size policy for the block update.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum StepPolicy {
    /// Per-batch safe step `1 / (λ + Σ_{h∈B} Q_hh)` (trace bound on
    /// `λmax(Q_BB + λI)`); never overshoots, at the price of conservatism
    /// on ill-conditioned batches.
    #[default]
    Auto,
    /// Fixed step size (must be positive and finite; the caller owns
    /// stability).
    Fixed(f64),
}

/// Configuration of the stochastic dual trainer.
#[derive(Debug, Clone, Copy)]
pub struct StochasticConfig {
    /// Regularization parameter λ (must be positive: strong convexity is
    /// what the step policy and the convergence argument lean on).
    pub lambda: f64,
    /// Start-vertex kernel `k`.
    pub kernel_d: KernelKind,
    /// End-vertex kernel `g`.
    pub kernel_t: KernelKind,
    /// Edges per mini-batch (must be ≥ 1).
    pub batch_edges: usize,
    /// Maximum training epochs (must be ≥ 1); one epoch streams every
    /// chunk once.
    pub epochs: usize,
    /// Sampler seed. Defaults to **1** — the same default the CLI `--seed`
    /// flag documents — so an unconfigured run is still reproducible.
    pub seed: u64,
    /// Batch sampling mode.
    pub sampling: SamplingMode,
    /// Step-size policy.
    pub step: StepPolicy,
    /// Relative residual tolerance: stop when `‖y − (Q+λI)a‖ ≤ tol·‖y‖`
    /// at an epoch boundary.
    pub tol: f64,
    /// Rebuild the stage-1 accumulator from scratch every this many epochs
    /// (0 = never): bounds float drift from incremental patches. Default 1.
    pub snapshot_every: usize,
    /// Early-stopping patience on validation AUC (0 disables).
    pub patience: usize,
}

impl Default for StochasticConfig {
    fn default() -> Self {
        StochasticConfig {
            lambda: 1.0,
            kernel_d: KernelKind::Linear,
            kernel_t: KernelKind::Linear,
            batch_edges: 512,
            epochs: 30,
            seed: 1,
            sampling: SamplingMode::EpochShuffle,
            step: StepPolicy::Auto,
            tol: 1e-6,
            snapshot_every: 1,
            patience: 0,
        }
    }
}

impl StochasticConfig {
    /// Validate the configuration, naming the offending field, the value it
    /// got, and a fix in every error.
    pub fn validate(&self) -> Result<(), String> {
        if self.batch_edges == 0 {
            return Err("stochastic config: batch_edges must be ≥ 1 (got 0); \
                        512 is the CLI --batch-edges default"
                .into());
        }
        if self.epochs == 0 {
            return Err("stochastic config: epochs must be ≥ 1 (got 0); \
                        each epoch streams every edge chunk once"
                .into());
        }
        if !(self.lambda > 0.0 && self.lambda.is_finite()) {
            return Err(format!(
                "stochastic config: lambda must be positive and finite (got {}); the dual \
                 objective is strongly convex — and the auto step safe — only for lambda > 0",
                self.lambda
            ));
        }
        if !(self.tol >= 0.0 && self.tol.is_finite()) {
            return Err(format!(
                "stochastic config: tol must be ≥ 0 and finite (got {}); use 0 to always run \
                 the full epoch budget",
                self.tol
            ));
        }
        if let StepPolicy::Fixed(s) = self.step {
            if !(s > 0.0 && s.is_finite()) {
                return Err(format!(
                    "stochastic config: fixed step must be positive and finite (got {s}); \
                     use StepPolicy::Auto for the safe per-batch trace bound"
                ));
            }
        }
        Ok(())
    }
}

/// Deterministic seeded batch sampler: given the same seed, mode, and
/// chunk geometry it emits the same chunk visit order and the same batch
/// position lists on every run (the fixed-seed reproducibility the tests
/// pin). One sampler instance carries the RNG stream across epochs, so
/// epochs differ from each other but the whole schedule is a pure function
/// of the seed.
#[derive(Debug, Clone)]
pub struct EdgeSampler {
    rng: Pcg32,
    mode: SamplingMode,
}

impl EdgeSampler {
    /// Sampler with the given seed and mode.
    pub fn new(seed: u64, mode: SamplingMode) -> EdgeSampler {
        EdgeSampler { rng: Pcg32::seeded(seed), mode }
    }

    /// Shuffled chunk visit order for one epoch (both modes randomize it:
    /// chunk-major streaming fixes *which* edges are co-resident, the order
    /// across chunks is free).
    pub fn chunk_order(&mut self, n_chunks: usize) -> Vec<u32> {
        let mut order: Vec<u32> = (0..n_chunks as u32).collect();
        self.rng.shuffle(&mut order);
        order
    }

    /// Batch position lists (chunk-local, 0-based) covering one loaded
    /// chunk for one epoch. Under [`SamplingMode::EpochShuffle`] the lists
    /// partition `0..chunk_len` (the last may be short); under
    /// [`SamplingMode::WithReplacement`] there are `⌈chunk_len/batch⌉`
    /// lists of exactly `batch_edges` draws each.
    pub fn chunk_batches(&mut self, chunk_len: usize, batch_edges: usize) -> Vec<Vec<u32>> {
        assert!(batch_edges > 0, "batch_edges must be ≥ 1");
        assert!(chunk_len > 0, "cannot sample an empty chunk");
        match self.mode {
            SamplingMode::EpochShuffle => {
                let mut pos: Vec<u32> = (0..chunk_len as u32).collect();
                self.rng.shuffle(&mut pos);
                pos.chunks(batch_edges).map(|b| b.to_vec()).collect()
            }
            SamplingMode::WithReplacement => {
                let n_batches = chunk_len.div_ceil(batch_edges);
                (0..n_batches)
                    .map(|_| {
                        (0..batch_edges).map(|_| self.rng.below(chunk_len) as u32).collect()
                    })
                    .collect()
            }
        }
    }
}

/// Everything a stochastic fit produces besides the model itself.
#[derive(Debug, Clone)]
pub struct StochasticResult {
    /// Final dual coefficients, in global edge order.
    pub duals: Vec<f64>,
    /// Per-epoch monitor records (risk, optional validation AUC,
    /// wall-clock) — same schema as the exact solvers' traces.
    pub trace: TrainTrace,
    /// Epochs actually run (≤ `cfg.epochs`).
    pub epochs_run: usize,
    /// Whether the residual tolerance was met before the epoch budget.
    pub converged: bool,
    /// Final residual norm `‖y − (Q+λI)a‖`.
    pub final_residual: f64,
}

/// One streamed pass rebuilding the stage-1 accumulator from the full dual
/// vector (chunks in natural order — the rebuild is sampler-independent).
fn rebuild_accumulator(
    source: &dyn StreamingEdgeSource,
    engine: &GvtEngine,
    g_t: &Matrix,
    duals: &[f64],
    q_v: usize,
    m_v: usize,
    acc: &mut [f64],
) -> Result<(), String> {
    acc.fill(0.0);
    for k in 0..source.n_chunks() {
        let (lo, hi) = source.chunk_range(k);
        let chunk = source.read_chunk(k)?;
        let idx = KronIndex::new(chunk.end_idx, chunk.start_idx);
        let positions: Vec<u32> = (0..(hi - lo) as u32).collect();
        let plan = BatchPlan::build(&idx, &positions, q_v, m_v);
        engine.scatter_batch(g_t, &idx, &plan, &duals[lo..hi], acc, Branch::T);
    }
    Ok(())
}

/// Train dual ridge coefficients against a [`StreamingEdgeSource`] — the
/// core the [`fit_stochastic`] wrapper and the CLI both call. Only the
/// duals (length `e`), the `m×q` accumulator, and one chunk are ever
/// resident; the source is re-read each epoch.
///
/// `val` optionally supplies a prediction operator plus labels for the
/// per-epoch validation AUC (and early stopping via `cfg.patience`).
///
/// Given identical sources (same values, same `chunk_edges`), the result
/// is **bitwise identical** across thread counts and across
/// in-memory/on-disk sources: every parallel primitive underneath is
/// pinned to its serial order, and the sampling schedule depends only on
/// the seed and the chunk geometry.
pub fn fit_stochastic_source(
    source: &dyn StreamingEdgeSource,
    start_features: &Matrix,
    end_features: &Matrix,
    cfg: &StochasticConfig,
    compute: &Compute,
    val: Option<(&PairwiseOp, &[f64])>,
) -> Result<StochasticResult, String> {
    cfg.validate()?;
    let n = source.n_edges();
    if n == 0 {
        return Err("empty training set".into());
    }
    let m_v = start_features.rows();
    let q_v = end_features.rows();
    let timer = Timer::start();

    // Kernel factor matrices (threaded build is bitwise identical to
    // serial); the trainer runs branch T exclusively: M = G, N = K, scatter
    // factor Gᵀ, accumulator T ∈ R^{m_v × q_v}.
    let g = cfg.kernel_t.square_matrix_threaded(end_features, compute.threads);
    let k = cfg.kernel_d.square_matrix_threaded(start_features, compute.threads);
    let g_t = g.transpose();
    let engine = GvtEngine::new(compute.threads);

    // Validation + ‖y‖ pre-pass (streamed; also catches out-of-bounds
    // vertex indices before any arithmetic).
    let mut b2 = 0.0;
    for kk in 0..source.n_chunks() {
        let chunk = source.read_chunk(kk)?;
        chunk.validate(m_v, q_v).map_err(|e| format!("edge chunk {kk}: {e}"))?;
        b2 += chunk.labels.iter().map(|y| y * y).sum::<f64>();
    }
    // `Stopping` expects the RHS vector, but a streamed trainer only has
    // the accumulated norm — a one-element slice round-trips it exactly
    // (‖[x]‖ = |x|), keeping the stopping rule shared with the Krylov
    // solvers.
    let solver_cfg = SolverConfig { max_iters: cfg.epochs, tol: cfg.tol };
    let stopping = Stopping::new(&solver_cfg, &[b2.sqrt()]);
    let mut duals = vec![0.0; n];
    if stopping.zero_rhs() {
        return Ok(StochasticResult {
            duals,
            trace: TrainTrace::default(),
            epochs_run: 0,
            converged: true,
            final_residual: 0.0,
        });
    }

    let mut acc = vec![0.0; m_v * q_v];
    let mut sampler = EdgeSampler::new(cfg.seed, cfg.sampling);
    let mut trace = TrainTrace::default();
    let mut converged = false;
    let mut final_residual = f64::INFINITY;
    let mut epochs_run = 0;

    for epoch in 0..cfg.epochs {
        epochs_run = epoch + 1;
        for &ck in &sampler.chunk_order(source.n_chunks()) {
            let (lo, hi) = source.chunk_range(ck as usize);
            let chunk = source.read_chunk(ck as usize)?;
            let labels = chunk.labels;
            let idx = KronIndex::new(chunk.end_idx, chunk.start_idx);
            for positions in sampler.chunk_batches(hi - lo, cfg.batch_edges) {
                let plan = BatchPlan::build(&idx, &positions, q_v, m_v);
                let mut qa = vec![0.0; positions.len()];
                engine.gather_batch(&g, &k, &idx, &plan, &acc, &mut qa, Branch::T);
                let eta = match cfg.step {
                    StepPolicy::Fixed(s) => s,
                    StepPolicy::Auto => {
                        let diag: f64 = positions
                            .iter()
                            .map(|&pos| {
                                let l = pos as usize;
                                let t = idx.left[l] as usize;
                                let s = idx.right[l] as usize;
                                g.get(t, t) * k.get(s, s)
                            })
                            .sum();
                        1.0 / (cfg.lambda + diag)
                    }
                };
                // Exact block gradient at the pre-step iterate (duplicate
                // positions under with-replacement sampling see the same
                // iterate and simply double the step on that coordinate).
                let delta: Vec<f64> = positions
                    .iter()
                    .zip(&qa)
                    .map(|(&pos, &qah)| {
                        let h = lo + pos as usize;
                        -eta * (qah + cfg.lambda * duals[h] - labels[pos as usize])
                    })
                    .collect();
                for (&pos, &di) in positions.iter().zip(&delta) {
                    duals[lo + pos as usize] += di;
                }
                engine.scatter_batch(&g_t, &idx, &plan, &delta, &mut acc, Branch::T);
            }
        }

        // SVRG-style snapshot: periodically re-scatter the accumulator from
        // the full dual vector so incremental-patch float drift cannot
        // compound across epochs.
        if cfg.snapshot_every > 0 && (epoch + 1) % cfg.snapshot_every == 0 {
            rebuild_accumulator(source, &engine, &g_t, &duals, q_v, m_v, &mut acc)?;
        }

        // Streamed monitor pass: exact residual and regularized risk from
        // full-chunk gathers against the (fresh or patched) accumulator.
        let mut resid2 = 0.0;
        let mut loss = 0.0;
        let mut reg = 0.0;
        for kk in 0..source.n_chunks() {
            let (lo, hi) = source.chunk_range(kk);
            let chunk = source.read_chunk(kk)?;
            let idx = KronIndex::new(chunk.end_idx, chunk.start_idx);
            let positions: Vec<u32> = (0..(hi - lo) as u32).collect();
            let plan = BatchPlan::build(&idx, &positions, q_v, m_v);
            let mut qa = vec![0.0; positions.len()];
            engine.gather_batch(&g, &k, &idx, &plan, &acc, &mut qa, Branch::T);
            for (i, (&p, &y)) in qa.iter().zip(&chunk.labels).enumerate() {
                let ah = duals[lo + i];
                let r = y - p - cfg.lambda * ah;
                resid2 += r * r;
                loss += (p - y) * (p - y);
                reg += ah * p;
            }
        }
        final_residual = resid2.sqrt();
        let risk = 0.5 * loss + 0.5 * cfg.lambda * reg;
        let val_auc = val.map(|(op, y)| auc(y, &op.predict(&duals)));
        trace.push(IterRecord {
            iter: epoch + 1,
            risk,
            val_auc,
            elapsed_secs: timer.elapsed_secs(),
        });

        if stopping.converged(final_residual) {
            converged = true;
            break;
        }
        if trace.should_stop(cfg.patience) {
            break;
        }
    }

    Ok(StochasticResult { duals, trace, epochs_run, converged, final_residual })
}

/// Train a portable [`DualModel`] stochastically from an in-memory
/// [`Dataset`] (Kronecker pairwise family), tracing per-epoch risk and —
/// when `val` is given — zero-shot validation AUC with early stopping via
/// `cfg.patience`. Thin wrapper over [`fit_stochastic_source`] with an
/// [`InMemorySource`]; training from the same edges through an on-disk
/// [`crate::data::stream::BinaryEdgeReader`] with equal `chunk_edges`
/// produces bitwise-identical duals.
pub fn fit_stochastic(
    train: &Dataset,
    val: Option<&Dataset>,
    cfg: &StochasticConfig,
    compute: &Compute,
) -> Result<(DualModel, TrainTrace), String> {
    train.validate()?;
    let val_op = val
        .map(|v| {
            super::ridge::validation_op(
                train,
                v,
                cfg.kernel_d,
                cfg.kernel_t,
                PairwiseKernelKind::Kronecker,
                compute,
            )
        })
        .transpose()?;
    let source = InMemorySource::new(train);
    let result = fit_stochastic_source(
        &source,
        &train.start_features,
        &train.end_features,
        cfg,
        compute,
        val_op.as_ref().zip(val).map(|(op, v)| (op, v.labels.as_slice())),
    )?;
    let model = DualModel {
        dual_coef: result.duals,
        train_start_features: train.start_features.clone(),
        train_end_features: train.end_features.clone(),
        train_idx: train.kron_index(),
        kernel_d: cfg.kernel_d,
        kernel_t: cfg.kernel_t,
        pairwise: PairwiseKernelKind::Kronecker,
    };
    Ok((model, result.trace))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::assert_allclose;
    use crate::train::ridge::{ridge_exact_dual, RidgeConfig};
    use crate::util::proptest::complete_dataset;

    #[test]
    fn config_validation_names_field_value_and_fix() {
        let bad = StochasticConfig { batch_edges: 0, ..Default::default() };
        let err = bad.validate().unwrap_err();
        assert!(err.contains("batch_edges") && err.contains("got 0"), "{err}");
        let bad = StochasticConfig { epochs: 0, ..Default::default() };
        let err = bad.validate().unwrap_err();
        assert!(err.contains("epochs") && err.contains("got 0"), "{err}");
        let bad = StochasticConfig { lambda: -1.0, ..Default::default() };
        let err = bad.validate().unwrap_err();
        assert!(err.contains("lambda") && err.contains("-1"), "{err}");
        let bad = StochasticConfig { step: StepPolicy::Fixed(0.0), ..Default::default() };
        let err = bad.validate().unwrap_err();
        assert!(err.contains("step") && err.contains("Auto"), "{err}");
        assert!(StochasticConfig::default().validate().is_ok());
    }

    #[test]
    fn sampling_mode_names_roundtrip() {
        for mode in [SamplingMode::EpochShuffle, SamplingMode::WithReplacement] {
            assert_eq!(SamplingMode::parse(mode.name()).unwrap(), mode);
        }
        assert!(SamplingMode::parse("importance").unwrap_err().contains("importance"));
    }

    #[test]
    fn sampler_is_deterministic_and_epoch_shuffle_partitions() {
        let mut a = EdgeSampler::new(7, SamplingMode::EpochShuffle);
        let mut b = EdgeSampler::new(7, SamplingMode::EpochShuffle);
        assert_eq!(a.chunk_order(5), b.chunk_order(5));
        let batches = a.chunk_batches(23, 6);
        assert_eq!(batches, b.chunk_batches(23, 6));
        // exactly once per epoch, last batch short
        let mut seen: Vec<u32> = batches.iter().flatten().copied().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..23).collect::<Vec<u32>>());
        assert_eq!(batches.last().unwrap().len(), 23 % 6);
        // a different seed produces a different schedule
        let mut c = EdgeSampler::new(8, SamplingMode::EpochShuffle);
        assert_ne!(c.chunk_batches(23, 6), batches);
        // with-replacement: full-size batches, in-bounds draws
        let mut d = EdgeSampler::new(7, SamplingMode::WithReplacement);
        let wr = d.chunk_batches(10, 4);
        assert_eq!(wr.len(), 3);
        assert!(wr.iter().all(|b| b.len() == 4 && b.iter().all(|&p| p < 10)));
    }

    #[test]
    fn converges_to_the_exact_dual_solution() {
        let mut rng = Pcg32::seeded(500);
        let train = complete_dataset(&mut rng, 5, 4);
        let cfg = StochasticConfig {
            lambda: 2.0,
            batch_edges: 4,
            epochs: 2000,
            tol: 1e-10,
            ..Default::default()
        };
        let (model, trace) = fit_stochastic(&train, None, &cfg, &Compute::serial()).unwrap();
        let exact = ridge_exact_dual(
            &train,
            &RidgeConfig { lambda: cfg.lambda, ..Default::default() },
            PairwiseKernelKind::Kronecker,
        );
        assert_allclose(&model.dual_coef, &exact, 1e-5, 1e-5);
        // monotone risk: the exact-gradient block step never overshoots
        let risks: Vec<f64> = trace.records.iter().map(|r| r.risk).collect();
        assert!(risks.windows(2).all(|w| w[1] <= w[0] + 1e-12), "risk not monotone");
    }

    #[test]
    fn fixed_seed_runs_are_bitwise_identical_across_threads() {
        let mut rng = Pcg32::seeded(501);
        let train = complete_dataset(&mut rng, 6, 5);
        let cfg = StochasticConfig { epochs: 12, batch_edges: 7, ..Default::default() };
        let (serial, _) = fit_stochastic(&train, None, &cfg, &Compute::serial()).unwrap();
        let (again, _) = fit_stochastic(&train, None, &cfg, &Compute::serial()).unwrap();
        assert_eq!(serial.dual_coef, again.dual_coef);
        let (par, _) = fit_stochastic(&train, None, &cfg, &Compute::threads(4)).unwrap();
        assert_eq!(serial.dual_coef, par.dual_coef);
        // a different seed walks a different trajectory
        let reseeded = StochasticConfig { seed: 99, ..cfg };
        let (other, _) = fit_stochastic(&train, None, &reseeded, &Compute::serial()).unwrap();
        assert_ne!(serial.dual_coef, other.dual_coef);
    }
}
