//! Batched, cache-aware, sharded zero-shot prediction server.
//!
//! Serving is where the paper's eq. (5) shortcut pays off operationally: a
//! request carries *novel* vertices (features never seen in training) plus
//! the edges to score. The server batches concurrently queued requests into
//! one prediction call — the generalized vec trick's cost
//! `O(min(v‖a‖₀ + m·t, u‖a‖₀ + q·t))` amortizes the `‖a‖₀` term across the
//! whole batch, exactly as dynamic batching does in model-serving systems.
//!
//! Architecture (three stages, backpressure end to end):
//!
//! 1. Submitters push [`PredictRequest`]s onto a **bounded** MPSC queue
//!    ([`ServerConfig::max_queue`]); when the pipeline is saturated, sends
//!    block — load shedding belongs to the caller via
//!    [`PredictServer::sender`]'s `try_send`.
//! 2. A **merger** thread drains whatever is queued (up to
//!    [`ServerConfig::max_batch_edges`]), validates and merges it into one
//!    batch dataset with offset vertex indices.
//! 3. A small **scoring pool** ([`ServerConfig::workers`], a
//!    [`WorkerPool`]) shards merged batches across workers. All workers
//!    share one [`PredictContext`]: the pruned model, the prebuilt train-side
//!    `EdgePlan`, pooled workspaces, and the per-vertex kernel-row LRU cache
//!    (`compute.cache_vertices` of the shared [`Compute`] policy) — vertices
//!    repeated across requests never recompute their `K̂`/`Ĝ` rows. Each
//!    batch's matvec is itself sharded over `compute.threads`.
//!
//! Scores are **bitwise identical** for a given batch whether the cache is
//! cold, warm, or disabled, and for every `threads`/`workers` setting (the
//! GVT engine is bitwise deterministic and cached rows match freshly
//! computed ones exactly). Batch *composition* depends on arrival timing, as
//! in any dynamic batcher; submit one request at a time for fully
//! reproducible runs.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::jobs::WorkerPool;
use crate::api::Compute;
use crate::data::Dataset;
use crate::linalg::Matrix;
use crate::model::{DualModel, PredictContext};

/// One prediction request: a private bipartite graph (novel vertices +
/// edges) to score against the trained model.
pub struct PredictRequest {
    /// Start-vertex feature rows (u × d, flattened row-major).
    pub start_features: Vec<Vec<f64>>,
    /// End-vertex feature rows (v × r).
    pub end_features: Vec<Vec<f64>>,
    /// Edges as (start_row, end_row) into the request's own vertex lists.
    pub edges: Vec<(u32, u32)>,
    /// Reply channel for the scores (one per edge, in order).
    pub reply: Sender<Vec<f64>>,
}

/// Server configuration. Serving-topology knobs (batching, pool size,
/// backpressure) live here; the per-batch execution policy — matvec
/// threads, kernel-row cache capacity, workspace retention — is the shared
/// [`Compute`] policy, not re-declared per subsystem.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Edge budget per merged batch.
    pub max_batch_edges: usize,
    /// Scoring workers: merged batches are scored concurrently by this many
    /// pool threads (min 1). Distinct from `compute.threads`, which shards
    /// *within* one batch; `workers` overlaps independent batches.
    pub workers: usize,
    /// Bound on queued-but-unmerged requests. Submission blocks (or
    /// `try_send` fails) once the queue is full — the backpressure knob.
    pub max_queue: usize,
    /// Execution policy for the shared [`PredictContext`]:
    /// `compute.threads` shards each merged batch's matvec (`0` = all
    /// cores), `compute.cache_vertices` bounds each side's kernel-row LRU
    /// (`0` disables), `compute.workspace_retention` bounds pooled scratch.
    /// The trained model is shared, not copied — the GVT operators are
    /// `Sync`, so sharding a batch costs no extra memory.
    pub compute: Compute,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch_edges: 65_536,
            workers: 1,
            max_queue: 1024,
            compute: Compute::default(),
        }
    }
}

/// Running counters.
#[derive(Debug, Default)]
pub struct ServerStats {
    /// Requests answered.
    pub requests: AtomicUsize,
    /// Merged batches executed.
    pub batches: AtomicUsize,
    /// Total edges scored.
    pub edges_scored: AtomicUsize,
    /// Kernel-row cache hits (start + end side). Shared with the context's
    /// caches, hence the `Arc`.
    pub cache_hits: Arc<AtomicUsize>,
    /// Kernel-row cache misses (start + end side).
    pub cache_misses: Arc<AtomicUsize>,
}

/// A validated, merged batch en route to the scoring pool.
struct MergedBatch {
    ds: Option<Dataset>,
    /// Edge count per request (0 for invalid ones).
    spans: Vec<usize>,
    /// Requests flagged invalid during merging (replied to with NaNs).
    bad: Vec<bool>,
    requests: Vec<PredictRequest>,
}

/// Handle to a running prediction server.
pub struct PredictServer {
    tx: Option<SyncSender<PredictRequest>>,
    merger: Option<JoinHandle<()>>,
    pool: Option<WorkerPool<MergedBatch>>,
    stats: Arc<ServerStats>,
}

impl PredictServer {
    /// Spawn the merger thread and scoring pool around a trained model.
    pub fn start(model: DualModel, cfg: ServerConfig) -> PredictServer {
        let stats = Arc::new(ServerStats::default());
        let ctx = Arc::new(
            model
                .predict_context(&cfg.compute)
                .with_cache_counters(stats.cache_hits.clone(), stats.cache_misses.clone()),
        );
        let (d, r) = ctx_dims(&model);
        let pool = {
            let stats = stats.clone();
            WorkerPool::spawn(cfg.workers, cfg.workers.max(1) * 2, move |batch: MergedBatch| {
                score_batch(&ctx, batch, &stats)
            })
        };
        let (tx, rx) = sync_channel::<PredictRequest>(cfg.max_queue.max(1));
        let merger = {
            let pool_tx = pool.sender();
            std::thread::spawn(move || merger_loop(d, r, cfg.max_batch_edges, rx, pool_tx))
        };
        PredictServer { tx: Some(tx), merger: Some(merger), pool: Some(pool), stats }
    }

    /// Sender handle for asynchronous submission from other threads. The
    /// queue is bounded: `send` blocks when the server is saturated,
    /// `try_send` fails instead (caller-side load shedding).
    ///
    /// NOTE: every clone must be dropped before [`PredictServer::shutdown`]
    /// can complete — the merger exits when all senders disconnect.
    pub fn sender(&self) -> SyncSender<PredictRequest> {
        self.tx.as_ref().expect("server running").clone()
    }

    /// Convenience: submit one request and block for its scores.
    pub fn predict_blocking(
        &self,
        start_features: Vec<Vec<f64>>,
        end_features: Vec<Vec<f64>>,
        edges: Vec<(u32, u32)>,
    ) -> Result<Vec<f64>, String> {
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        self.tx
            .as_ref()
            .expect("server running")
            .send(PredictRequest { start_features, end_features, edges, reply: reply_tx })
            .map_err(|_| "server stopped".to_string())?;
        reply_rx.recv().map_err(|_| "server dropped request".to_string())
    }

    /// Observability counters.
    pub fn stats(&self) -> &ServerStats {
        &self.stats
    }

    /// Graceful shutdown: waits for queued work to finish.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        drop(self.tx.take());
        if let Some(m) = self.merger.take() {
            let _ = m.join(); // merger drains the queue, then drops its pool sender
        }
        if let Some(pool) = self.pool.take() {
            pool.shutdown(); // scores everything the merger submitted
        }
    }
}

impl Drop for PredictServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Trained-side feature dimensions `(d, r)` the merger validates against.
fn ctx_dims(model: &DualModel) -> (usize, usize) {
    (model.train_start_features.cols(), model.train_end_features.cols())
}

fn merger_loop(
    d: usize,
    r: usize,
    max_batch_edges: usize,
    rx: Receiver<PredictRequest>,
    pool_tx: SyncSender<MergedBatch>,
) {
    loop {
        // Block for the first request of the batch.
        let first = match rx.recv() {
            Ok(req) => req,
            Err(_) => return, // all senders gone
        };
        let mut batch = vec![first];
        let mut edge_count = batch[0].edges.len();
        // Greedily drain whatever else is queued (dynamic batching).
        while edge_count < max_batch_edges {
            match rx.try_recv() {
                Ok(req) => {
                    edge_count += req.edges.len();
                    batch.push(req);
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        // Blocks when the scoring pool is saturated — backpressure that
        // propagates to the bounded request queue and its submitters.
        if pool_tx.send(merge_batch(d, r, batch)).is_err() {
            return; // scoring pool gone (worker panic)
        }
    }
}

/// Validate each request and merge the batch into one dataset with offset
/// vertex indices. Invalid requests are flagged and excluded from scoring —
/// the merged matrices are sized to the *valid* requests only, so no kernel
/// row is ever computed (or cached) for a phantom vertex.
fn merge_batch(d: usize, r: usize, batch: Vec<PredictRequest>) -> MergedBatch {
    let bad: Vec<bool> = batch
        .iter()
        .map(|req| {
            let valid = req.start_features.iter().all(|f| f.len() == d)
                && req.end_features.iter().all(|f| f.len() == r)
                && req.edges.iter().all(|&(s, e)| {
                    (s as usize) < req.start_features.len()
                        && (e as usize) < req.end_features.len()
                });
            !valid
        })
        .collect();
    let valid_reqs = || batch.iter().zip(&bad).filter(|(_, &b)| !b).map(|(req, _)| req);
    let total_starts: usize = valid_reqs().map(|b| b.start_features.len()).sum();
    let total_ends: usize = valid_reqs().map(|b| b.end_features.len()).sum();
    let total_edges: usize = valid_reqs().map(|b| b.edges.len()).sum();

    let mut start_features = Matrix::zeros(total_starts, d);
    let mut end_features = Matrix::zeros(total_ends, r);
    let mut start_idx = Vec::with_capacity(total_edges);
    let mut end_idx = Vec::with_capacity(total_edges);
    let mut start_off = 0u32;
    let mut end_off = 0u32;
    let mut spans = Vec::with_capacity(batch.len());

    for (req, &is_bad) in batch.iter().zip(&bad) {
        if is_bad {
            spans.push(0);
            continue;
        }
        for (i, f) in req.start_features.iter().enumerate() {
            start_features.row_mut(start_off as usize + i).copy_from_slice(f);
        }
        for (j, f) in req.end_features.iter().enumerate() {
            end_features.row_mut(end_off as usize + j).copy_from_slice(f);
        }
        for &(s, e) in &req.edges {
            start_idx.push(start_off + s);
            end_idx.push(end_off + e);
        }
        spans.push(req.edges.len());
        start_off += req.start_features.len() as u32;
        end_off += req.end_features.len() as u32;
    }

    let n_scored = start_idx.len();
    let ds = (n_scored > 0).then(|| Dataset {
        start_features,
        end_features,
        start_idx,
        end_idx,
        labels: vec![0.0; n_scored],
        name: "server-batch".into(),
    });
    MergedBatch { ds, spans, bad, requests: batch }
}

/// Score one merged batch on a pool worker and scatter the replies.
fn score_batch(ctx: &PredictContext, batch: MergedBatch, stats: &ServerStats) {
    let scores = match &batch.ds {
        Some(ds) => ctx.predict_batch(ds),
        None => Vec::new(),
    };
    let n_scored = scores.len();

    // Update stats BEFORE delivering replies so a client that observed its
    // reply also observes the counters.
    stats.requests.fetch_add(batch.requests.len(), Ordering::Relaxed);
    stats.batches.fetch_add(1, Ordering::Relaxed);
    stats.edges_scored.fetch_add(n_scored, Ordering::Relaxed);

    let mut cursor = 0usize;
    for (req, (&span, &is_bad)) in
        batch.requests.iter().zip(batch.spans.iter().zip(&batch.bad))
    {
        if is_bad {
            let _ = req.reply.send(vec![f64::NAN; req.edges.len()]);
            continue;
        }
        let _ = req.reply.send(scores[cursor..cursor + span].to_vec());
        cursor += span;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gvt::KronIndex;
    use crate::kernels::KernelKind;
    use crate::util::rng::Pcg32;
    use std::sync::mpsc::channel;

    fn toy_model(seed: u64) -> DualModel {
        let mut rng = Pcg32::seeded(seed);
        let (m, q, n) = (6, 5, 15);
        DualModel {
            dual_coef: rng.normal_vec(n),
            train_start_features: Matrix::from_fn(m, 3, |_, _| rng.normal()),
            train_end_features: Matrix::from_fn(q, 2, |_, _| rng.normal()),
            train_idx: KronIndex::new(
                (0..n).map(|_| rng.below(q) as u32).collect(),
                (0..n).map(|_| rng.below(m) as u32).collect(),
            ),
            kernel_d: KernelKind::Gaussian { gamma: 0.3 },
            kernel_t: KernelKind::Gaussian { gamma: 0.3 },
            pairwise: crate::gvt::PairwiseKernelKind::Kronecker,
        }
    }

    fn request_data(
        rng: &mut Pcg32,
        u: usize,
        v: usize,
        t: usize,
    ) -> (Vec<Vec<f64>>, Vec<Vec<f64>>, Vec<(u32, u32)>) {
        let sf: Vec<Vec<f64>> = (0..u).map(|_| rng.normal_vec(3)).collect();
        let ef: Vec<Vec<f64>> = (0..v).map(|_| rng.normal_vec(2)).collect();
        let edges: Vec<(u32, u32)> =
            (0..t).map(|_| (rng.below(u) as u32, rng.below(v) as u32)).collect();
        (sf, ef, edges)
    }

    #[test]
    fn server_matches_direct_prediction() {
        let model = toy_model(1100);
        let mut rng = Pcg32::seeded(1101);
        let (sf, ef, edges) = request_data(&mut rng, 4, 3, 10);

        // direct prediction for reference
        let ds = Dataset {
            start_features: Matrix::from_fn(4, 3, |i, j| sf[i][j]),
            end_features: Matrix::from_fn(3, 2, |i, j| ef[i][j]),
            start_idx: edges.iter().map(|&(s, _)| s).collect(),
            end_idx: edges.iter().map(|&(_, e)| e).collect(),
            labels: vec![0.0; 10],
            name: "direct".into(),
        };
        let direct = model.predict(&ds);

        let server = PredictServer::start(model, ServerConfig::default());
        let served = server.predict_blocking(sf, ef, edges).unwrap();
        // the toy model has no zero duals, so this is exact, not just close
        assert_eq!(served, direct);
        assert_eq!(server.stats().requests.load(Ordering::Relaxed), 1);
        server.shutdown();
    }

    #[test]
    fn warm_cache_replies_are_bitwise_identical_to_cold() {
        let model = toy_model(1106);
        let mut rng = Pcg32::seeded(1107);
        let (sf, ef, edges) = request_data(&mut rng, 4, 4, 12);
        let server = PredictServer::start(
            model,
            ServerConfig {
                compute: Compute::threads(2).with_cache_vertices(64),
                ..Default::default()
            },
        );
        let cold = server.predict_blocking(sf.clone(), ef.clone(), edges.clone()).unwrap();
        let warm = server.predict_blocking(sf, ef, edges).unwrap();
        assert_eq!(cold, warm);
        let st = server.stats();
        let hits = st.cache_hits.load(Ordering::Relaxed);
        let misses = st.cache_misses.load(Ordering::Relaxed);
        assert_eq!(hits + misses, 16, "two rounds × 4+4 vertex lookups");
        assert!(misses <= 8, "only the cold request may compute rows, got {misses}");
        assert!(hits >= 8, "the warm request must hit, got {hits}");
        server.shutdown();
    }

    #[test]
    fn concurrent_requests_are_all_answered() {
        let model = toy_model(1102);
        let server = PredictServer::start(
            model,
            ServerConfig {
                max_batch_edges: 1000,
                workers: 3,
                compute: Compute::threads(2),
                ..Default::default()
            },
        );
        let sender = server.sender();
        let mut replies = Vec::new();
        let mut rng = Pcg32::seeded(1103);
        for _ in 0..20 {
            let (sf, ef, edges) = request_data(&mut rng, 3, 3, 6);
            let (tx, rx) = channel();
            sender
                .send(PredictRequest { start_features: sf, end_features: ef, edges, reply: tx })
                .unwrap();
            replies.push(rx);
        }
        drop(sender); // release our clone so shutdown() can disconnect the merger
        for rx in replies {
            let scores = rx.recv().unwrap();
            assert_eq!(scores.len(), 6);
            assert!(scores.iter().all(|s| s.is_finite()));
        }
        let total = server.stats().edges_scored.load(Ordering::Relaxed);
        assert_eq!(total, 120);
        server.shutdown();
    }

    #[test]
    fn invalid_request_gets_nan_reply_without_poisoning_batch() {
        let model = toy_model(1104);
        let server = PredictServer::start(model, ServerConfig::default());
        // bad: edge references missing vertex
        let bad = server.predict_blocking(vec![vec![0.0; 3]], vec![vec![0.0; 2]], vec![(0, 5)]);
        let scores = bad.unwrap();
        assert!(scores[0].is_nan());
        // bad: wrong feature dimension
        let bad_dim = server.predict_blocking(vec![vec![0.0; 7]], vec![vec![0.0; 2]], vec![(0, 0)]);
        assert!(bad_dim.unwrap()[0].is_nan());
        // a good request still works afterwards
        let mut rng = Pcg32::seeded(1105);
        let (sf, ef, edges) = request_data(&mut rng, 2, 2, 3);
        let good = server.predict_blocking(sf, ef, edges).unwrap();
        assert!(good.iter().all(|s| s.is_finite()));
        server.shutdown();
    }

    #[test]
    fn shutdown_after_heavy_traffic_loses_nothing() {
        let model = toy_model(1108);
        let server = PredictServer::start(
            model,
            ServerConfig {
                max_batch_edges: 64,
                workers: 4,
                max_queue: 8,
                compute: Compute::serial().with_cache_vertices(16),
            },
        );
        let mut rng = Pcg32::seeded(1109);
        let mut replies = Vec::new();
        let sender = server.sender();
        for _ in 0..40 {
            let (sf, ef, edges) = request_data(&mut rng, 2, 2, 4);
            let (tx, rx) = channel();
            sender
                .send(PredictRequest { start_features: sf, end_features: ef, edges, reply: tx })
                .unwrap();
            replies.push(rx);
        }
        drop(sender);
        server.shutdown(); // graceful: drains queue + pool before returning
        for rx in replies {
            let scores = rx.recv().expect("reply delivered before shutdown completed");
            assert_eq!(scores.len(), 4);
        }
    }
}
