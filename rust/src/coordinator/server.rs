//! Batched, cache-aware, sharded, fault-tolerant zero-shot prediction
//! server.
//!
//! Serving is where the paper's eq. (5) shortcut pays off operationally: a
//! request carries *novel* vertices (features never seen in training) plus
//! the edges to score. The server batches concurrently queued requests into
//! one prediction call — the generalized vec trick's cost
//! `O(min(v‖a‖₀ + m·t, u‖a‖₀ + q·t))` amortizes the `‖a‖₀` term across the
//! whole batch, exactly as dynamic batching does in model-serving systems.
//!
//! Architecture (three stages, backpressure end to end):
//!
//! 1. Submitters push [`PredictRequest`]s onto a **bounded** MPSC queue
//!    ([`ServerConfig::max_queue`]); when the pipeline is saturated,
//!    [`PredictServer::submit`] blocks and [`PredictServer::try_submit`]
//!    answers [`PredictError::Overloaded`] — typed load shedding instead of
//!    a hang.
//! 2. A **merger** thread drains whatever is queued (up to
//!    [`ServerConfig::max_batch_edges`]), stamps the default deadline
//!    ([`ServerConfig::request_timeout_ms`]) on requests that carry none,
//!    validates, and merges the batch into one dataset with offset vertex
//!    indices. Invalid and already-expired requests are excluded here — no
//!    kernel row is ever computed for them.
//! 3. A small **supervised scoring pool** ([`ServerConfig::workers`], a
//!    [`WorkerPool`]) shards merged batches across workers: a panicking
//!    worker costs one batch (its requests observe the dropped reply
//!    channel as [`PredictError::ShuttingDown`]) and is respawned under the
//!    pool's [`RespawnPolicy`], with `panics`/`respawns` surfaced in
//!    [`ServerStats`]. All workers share one
//!    [`PredictContext`] behind a swappable slot — see
//!    [`PredictServer::swap_model`] — including the per-vertex kernel-row
//!    LRU cache (`compute.cache_vertices` of the shared [`Compute`]
//!    policy). Each batch's matvec is itself sharded over
//!    `compute.threads`.
//!
//! Every request is answered exactly once with a typed
//! [`PredictReply`]: the scores, or a [`PredictError`] naming what happened
//! (invalid request, expired deadline, overload, shutdown) — the old
//! silent-NaN convention is gone. Deadlines are enforced twice: at merge
//! time and again on the scoring worker, so work that expired waiting in a
//! queue is shed, not computed.
//!
//! Scores are **bitwise identical** for a given batch whether the cache is
//! cold, warm, or disabled, and for every `threads`/`workers` setting (the
//! GVT engine is bitwise deterministic and cached rows match freshly
//! computed ones exactly). Batch *composition* depends on arrival timing, as
//! in any dynamic batcher; submit one request at a time for fully
//! reproducible runs.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{
    sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TryRecvError, TrySendError,
};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::faults::FaultPlan;
use super::jobs::{RespawnPolicy, WorkerPool};
use crate::api::Compute;
use crate::data::Dataset;
use crate::linalg::Matrix;
use crate::model::{DualModel, PredictContext};

/// Extra time a blocking caller waits past its request's deadline for the
/// typed `DeadlineExceeded` reply to drain back (the reply is produced by
/// the scoring worker, not conjured at the deadline instant).
const REPLY_DRAIN_SLACK: Duration = Duration::from_millis(2_000);

/// Why a request was not scored. Every non-score outcome is typed — the
/// pre-robustness server answered invalid requests with silent NaN vectors
/// and had no vocabulary at all for timeouts, overload, or faults.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PredictError {
    /// The request failed validation (the reason names what): wrong feature
    /// dimensionality, or an edge referencing a vertex the request does not
    /// carry.
    InvalidRequest(String),
    /// The request's deadline passed before it was scored; its work was
    /// shed, not computed.
    DeadlineExceeded,
    /// The bounded request queue was full at admission
    /// ([`PredictServer::try_submit`]) — the load-shedding signal. Back off
    /// and retry.
    Overloaded,
    /// The server went away before a reply was produced — a shutdown, or a
    /// scoring worker crashing mid-batch (the supervisor respawns the
    /// worker; this request's batch is the one casualty). Retry against a
    /// live server.
    ShuttingDown,
}

impl std::fmt::Display for PredictError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PredictError::InvalidRequest(reason) => write!(f, "invalid request: {reason}"),
            PredictError::DeadlineExceeded => write!(f, "deadline exceeded"),
            PredictError::Overloaded => write!(f, "server overloaded"),
            PredictError::ShuttingDown => write!(f, "server shutting down"),
        }
    }
}

impl std::error::Error for PredictError {}

impl From<PredictError> for String {
    fn from(e: PredictError) -> String {
        e.to_string()
    }
}

/// One reply per request: the scores or a typed error, stamped with the
/// **generation** of the model that handled it — after a
/// [`PredictServer::swap_model`], callers can tell old-model from new-model
/// scores. A reply is never torn across generations: the scoring worker
/// pins one context for the whole batch.
#[derive(Debug, Clone, PartialEq)]
pub struct PredictReply {
    /// Scores (one per edge, in request order) or the typed refusal.
    pub result: Result<Vec<f64>, PredictError>,
    /// Generation of the model that handled the request: `0` for the model
    /// the server started with, incremented by every successful
    /// [`PredictServer::swap_model`].
    pub generation: u64,
}

/// One prediction request: a private bipartite graph (novel vertices +
/// edges) to score against the trained model, plus the typed reply channel
/// and an optional deadline.
pub struct PredictRequest {
    /// Start-vertex feature rows (u × d, flattened row-major).
    pub start_features: Vec<Vec<f64>>,
    /// End-vertex feature rows (v × r).
    pub end_features: Vec<Vec<f64>>,
    /// Edges as (start_row, end_row) into the request's own vertex lists.
    pub edges: Vec<(u32, u32)>,
    /// Reply channel: scores or a [`PredictError`], stamped with the
    /// scoring generation. Answered exactly once — unless the scoring
    /// worker dies mid-batch, in which case the sender is dropped and the
    /// receiver observes a disconnect.
    pub reply: Sender<PredictReply>,
    /// Absolute deadline. Past it the request is answered
    /// [`PredictError::DeadlineExceeded`] and its work shed (checked at
    /// merge time and again before scoring). `None` = no deadline, though
    /// [`ServerConfig::request_timeout_ms`] may stamp a default at
    /// admission.
    pub deadline: Option<Instant>,
}

impl PredictRequest {
    /// Build a request with no explicit deadline.
    pub fn new(
        start_features: Vec<Vec<f64>>,
        end_features: Vec<Vec<f64>>,
        edges: Vec<(u32, u32)>,
        reply: Sender<PredictReply>,
    ) -> PredictRequest {
        PredictRequest { start_features, end_features, edges, reply, deadline: None }
    }

    /// Set an absolute deadline `ms` milliseconds from now. `0` expires the
    /// request immediately — useful for deterministic shed tests.
    pub fn with_deadline_ms(mut self, ms: u64) -> PredictRequest {
        self.deadline = Some(Instant::now() + Duration::from_millis(ms));
        self
    }

    fn expired(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }

    /// Deliver the reply (ignoring a hung-up caller).
    fn answer(&self, result: Result<Vec<f64>, PredictError>, generation: u64) {
        let _ = self.reply.send(PredictReply { result, generation });
    }
}

/// Server configuration. Serving-topology knobs (batching, pool size,
/// backpressure, deadlines) live here; the per-batch execution policy —
/// matvec threads, kernel-row cache capacity, workspace retention — is the
/// shared [`Compute`] policy, not re-declared per subsystem.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Edge budget per merged batch.
    pub max_batch_edges: usize,
    /// Scoring workers: merged batches are scored concurrently by this many
    /// pool threads (min 1). Distinct from `compute.threads`, which shards
    /// *within* one batch; `workers` overlaps independent batches.
    pub workers: usize,
    /// Bound on queued-but-unmerged requests. Submission blocks (or
    /// [`PredictServer::try_submit`] answers `Overloaded`) once the queue
    /// is full — the backpressure knob.
    pub max_queue: usize,
    /// Default per-request deadline in milliseconds, stamped at admission
    /// on requests that don't carry their own ([`PredictRequest::deadline`]
    /// wins when set). `0` disables the default — requests then wait as
    /// long as it takes.
    pub request_timeout_ms: u64,
    /// Execution policy for the shared [`PredictContext`]:
    /// `compute.threads` shards each merged batch's matvec (`0` = all
    /// cores), `compute.cache_vertices` bounds each side's kernel-row LRU
    /// (`0` disables), `compute.workspace_retention` bounds pooled scratch.
    /// The trained model is shared, not copied — the GVT operators are
    /// `Sync`, so sharding a batch costs no extra memory.
    pub compute: Compute,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch_edges: 65_536,
            workers: 1,
            max_queue: 1024,
            request_timeout_ms: 0,
            compute: Compute::default(),
        }
    }
}

/// Running counters. The robustness counters (`deadline_expired`, `shed`,
/// `rejected_overload`, `panics`, `respawns`, `generation`) quantify every
/// fault path the server survives — see `docs/BENCHMARKS.md`.
#[derive(Debug, Default)]
pub struct ServerStats {
    /// Requests answered (scores and typed errors alike).
    pub requests: AtomicUsize,
    /// Merged batches executed.
    pub batches: AtomicUsize,
    /// Total edges scored.
    pub edges_scored: AtomicUsize,
    /// Requests answered [`PredictError::DeadlineExceeded`] (expired at
    /// merge time or on the scoring worker).
    pub deadline_expired: AtomicUsize,
    /// Requests whose merged work was dropped **un-computed** on the
    /// scoring worker — they expired between merging and scoring (a subset
    /// of `deadline_expired`).
    pub shed: AtomicUsize,
    /// Requests answered [`PredictError::Overloaded`] at admission (full
    /// queue via [`PredictServer::try_submit`], or injected).
    pub rejected_overload: AtomicUsize,
    /// Scoring-worker panics observed by the pool supervisors. Shared with
    /// the pool's [`RespawnPolicy`], hence the `Arc`.
    pub panics: Arc<AtomicUsize>,
    /// Scoring workers respawned after a panic.
    pub respawns: Arc<AtomicUsize>,
    /// Current model generation (bumped by every successful
    /// [`PredictServer::swap_model`]).
    pub generation: AtomicU64,
    /// Kernel-row cache hits (start + end side, cumulative across
    /// generations). Shared with the context's caches, hence the `Arc`.
    pub cache_hits: Arc<AtomicUsize>,
    /// Kernel-row cache misses (start + end side).
    pub cache_misses: Arc<AtomicUsize>,
}

/// Per-request outcome of merging (re-checked before scoring).
enum Verdict {
    /// Valid and in the merged dataset.
    Ok,
    /// Failed validation; answered `InvalidRequest` with this reason.
    Invalid(String),
    /// Deadline passed; answered `DeadlineExceeded`, work shed.
    Expired,
}

/// A validated, merged batch en route to the scoring pool.
struct MergedBatch {
    ds: Option<Dataset>,
    /// Edge count per request (0 for non-`Ok` ones).
    spans: Vec<usize>,
    verdicts: Vec<Verdict>,
    requests: Vec<PredictRequest>,
}

/// The swappable model slot: the live context and its generation. Workers
/// hold the lock only long enough to clone the `Arc` (an `arc-swap`
/// emulated with a mutex — the zero-dependency constraint), so neither a
/// swap nor a slow batch ever blocks the other for more than that clone.
struct ContextSlot {
    generation: u64,
    ctx: Arc<PredictContext>,
}

/// Handle to a running prediction server.
pub struct PredictServer {
    tx: Option<SyncSender<PredictRequest>>,
    merger: Option<JoinHandle<()>>,
    pool: Option<WorkerPool<MergedBatch>>,
    stats: Arc<ServerStats>,
    slot: Arc<Mutex<ContextSlot>>,
    compute: Compute,
    dims: (usize, usize),
    request_timeout_ms: u64,
    faults: Arc<FaultPlan>,
}

impl PredictServer {
    /// Spawn the merger thread and supervised scoring pool around a trained
    /// model.
    pub fn start(model: DualModel, cfg: ServerConfig) -> PredictServer {
        PredictServer::start_with_faults(model, cfg, FaultPlan::none())
    }

    /// [`PredictServer::start`] with a deterministic [`FaultPlan`] — the
    /// test harness for the fault-tolerance guarantees. An empty plan is
    /// free; production servers use [`PredictServer::start`].
    pub fn start_with_faults(
        model: DualModel,
        cfg: ServerConfig,
        faults: FaultPlan,
    ) -> PredictServer {
        let stats = Arc::new(ServerStats::default());
        let faults = Arc::new(faults);
        let ctx = Arc::new(
            model
                .predict_context(&cfg.compute)
                .with_cache_counters(stats.cache_hits.clone(), stats.cache_misses.clone()),
        );
        let dims = ctx.feature_dims();
        let slot = Arc::new(Mutex::new(ContextSlot { generation: 0, ctx }));
        let pool = {
            let stats = stats.clone();
            let slot = slot.clone();
            let faults = faults.clone();
            let policy = RespawnPolicy {
                panics: stats.panics.clone(),
                respawns: stats.respawns.clone(),
                ..Default::default()
            };
            WorkerPool::spawn_supervised(
                cfg.workers,
                cfg.workers.max(1) * 2,
                policy,
                move |batch: MergedBatch| score_batch(&slot, batch, &stats, &faults, dims),
            )
        };
        let (tx, rx) = sync_channel::<PredictRequest>(cfg.max_queue.max(1));
        let merger = {
            let pool_tx = pool.sender();
            let timeout_ms = cfg.request_timeout_ms;
            std::thread::spawn(move || {
                merger_loop(dims.0, dims.1, cfg.max_batch_edges, timeout_ms, rx, pool_tx)
            })
        };
        PredictServer {
            tx: Some(tx),
            merger: Some(merger),
            pool: Some(pool),
            stats,
            slot,
            compute: cfg.compute,
            dims,
            request_timeout_ms: cfg.request_timeout_ms,
            faults,
        }
    }

    /// Sender handle for asynchronous submission from other threads. The
    /// queue is bounded: `send` blocks when the server is saturated,
    /// `try_send` fails instead. Raw-sender traffic skips the admission
    /// hooks ([`PredictServer::submit`] / [`PredictServer::try_submit`]
    /// stamp default deadlines and count overload rejections); the merger
    /// still stamps the default deadline on requests that carry none.
    ///
    /// NOTE: every clone must be dropped before [`PredictServer::shutdown`]
    /// can complete — the merger exits when all senders disconnect.
    pub fn sender(&self) -> SyncSender<PredictRequest> {
        self.tx.as_ref().expect("server running").clone()
    }

    /// Submit one request, blocking while the bounded queue is full
    /// (backpressure). Stamps the config's default deadline when the
    /// request has none. On failure the request's reply channel is answered
    /// with the same typed error this returns, so no consumer path hangs.
    pub fn submit(&self, req: PredictRequest) -> Result<(), PredictError> {
        let req = self.admit(req)?;
        match self.tx.as_ref().expect("server running").send(req) {
            Ok(()) => Ok(()),
            Err(std::sync::mpsc::SendError(req)) => {
                Err(self.refuse(req, PredictError::ShuttingDown))
            }
        }
    }

    /// Non-blocking [`PredictServer::submit`]: a full queue answers (and
    /// returns) [`PredictError::Overloaded`] instead of blocking — the
    /// caller-side load-shedding path, guaranteed never to hang.
    pub fn try_submit(&self, req: PredictRequest) -> Result<(), PredictError> {
        let req = self.admit(req)?;
        match self.tx.as_ref().expect("server running").try_send(req) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(req)) => {
                self.stats.rejected_overload.fetch_add(1, Ordering::Relaxed);
                Err(self.refuse(req, PredictError::Overloaded))
            }
            Err(TrySendError::Disconnected(req)) => {
                Err(self.refuse(req, PredictError::ShuttingDown))
            }
        }
    }

    /// Shared admission: default-deadline stamping plus the injected queue
    /// fault (which mimics a full queue).
    fn admit(&self, mut req: PredictRequest) -> Result<PredictRequest, PredictError> {
        if req.deadline.is_none() && self.request_timeout_ms > 0 {
            req = req.with_deadline_ms(self.request_timeout_ms);
        }
        if self.faults.trip_queue_rejection() {
            self.stats.rejected_overload.fetch_add(1, Ordering::Relaxed);
            return Err(self.refuse(req, PredictError::Overloaded));
        }
        Ok(req)
    }

    /// Answer a refused request on its reply channel and hand the error
    /// back to the submitter.
    fn refuse(&self, req: PredictRequest, err: PredictError) -> PredictError {
        req.answer(Err(err.clone()), self.stats.generation.load(Ordering::Relaxed));
        err
    }

    /// Convenience: submit one request and block for its scores.
    ///
    /// The wait is bounded: a dropped reply (scoring worker crashed
    /// mid-batch, server stopped) returns [`PredictError::ShuttingDown`]
    /// instead of hanging forever, and when the request carries a deadline
    /// (explicit or the config default) the wait is additionally capped at
    /// the deadline plus a drain allowance.
    pub fn predict_blocking(
        &self,
        start_features: Vec<Vec<f64>>,
        end_features: Vec<Vec<f64>>,
        edges: Vec<(u32, u32)>,
    ) -> Result<Vec<f64>, PredictError> {
        Ok(self.predict_reply(start_features, end_features, edges)?.result?)
    }

    /// [`PredictServer::predict_blocking`], but returning the full
    /// [`PredictReply`] so the caller sees the scoring generation.
    pub fn predict_reply(
        &self,
        start_features: Vec<Vec<f64>>,
        end_features: Vec<Vec<f64>>,
        edges: Vec<(u32, u32)>,
    ) -> Result<PredictReply, PredictError> {
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        let mut req = PredictRequest::new(start_features, end_features, edges, reply_tx);
        if req.deadline.is_none() && self.request_timeout_ms > 0 {
            req = req.with_deadline_ms(self.request_timeout_ms);
        }
        let deadline = req.deadline;
        self.submit(req)?;
        wait_reply(&reply_rx, deadline)
    }

    /// Atomically install a new model with **zero downtime**. In-flight
    /// batches finish on the generation they started with; batches that
    /// begin after the swap score on the new model; every reply carries the
    /// generation that scored it (never torn across models). Returns the
    /// new generation, also visible as [`ServerStats::generation`].
    ///
    /// The incoming model must be a dual (kernel) model whose start/end
    /// feature dimensions match the serving one — the merger validates
    /// requests against those dimensions for the server's lifetime. The
    /// kernel-row caches start cold for the new generation (old-model rows
    /// must never score new-model requests); the hit/miss counters keep
    /// accumulating.
    pub fn swap_model(&self, model: crate::api::TrainedModel) -> Result<u64, String> {
        let dual = model.into_dual().map_err(|e| format!("cannot hot-swap: {e}"))?;
        let dims = (dual.train_start_features.cols(), dual.train_end_features.cols());
        if dims != self.dims {
            return Err(format!(
                "cannot hot-swap: the server validates requests against feature dims \
                 (d, r) = {:?}, but the new model expects {:?}",
                self.dims, dims
            ));
        }
        let ctx = Arc::new(
            dual.predict_context(&self.compute)
                .with_cache_counters(self.stats.cache_hits.clone(), self.stats.cache_misses.clone()),
        );
        let generation = {
            let mut guard = self.slot.lock().unwrap_or_else(|p| p.into_inner());
            guard.generation += 1;
            guard.ctx = ctx;
            guard.generation
        };
        self.stats.generation.store(generation, Ordering::Relaxed);
        Ok(generation)
    }

    /// Observability counters.
    pub fn stats(&self) -> &ServerStats {
        &self.stats
    }

    /// The configured default request timeout
    /// ([`ServerConfig::request_timeout_ms`]); `0` means none. The network
    /// front-end uses this to stamp the same default deadline the merger
    /// would, so its reply waits stay bounded.
    pub fn request_timeout_ms(&self) -> u64 {
        self.request_timeout_ms
    }

    /// Feature dimensions `(d, r)` the server validates requests against —
    /// fixed for the server's lifetime (hot swaps must match them). The
    /// wire protocol exposes these through the `info` operation so remote
    /// clients and load generators can shape traffic without a model file.
    pub fn feature_dims(&self) -> (usize, usize) {
        self.dims
    }

    /// Graceful shutdown: waits for queued work to finish.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        drop(self.tx.take());
        if let Some(m) = self.merger.take() {
            let _ = m.join(); // merger drains the queue, then drops its pool sender
        }
        if let Some(pool) = self.pool.take() {
            pool.shutdown(); // scores everything the merger submitted
        }
    }
}

impl Drop for PredictServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Bounded reply wait: map a disconnected reply channel (worker crash,
/// shutdown) to `ShuttingDown`, and cap the wait at the deadline plus
/// [`REPLY_DRAIN_SLACK`] when one is set — a blocking caller can never
/// hang on a request the pipeline dropped.
pub(crate) fn wait_reply(
    rx: &Receiver<PredictReply>,
    deadline: Option<Instant>,
) -> Result<PredictReply, PredictError> {
    match deadline {
        None => rx.recv().map_err(|_| PredictError::ShuttingDown),
        Some(d) => {
            let wait = d.saturating_duration_since(Instant::now()) + REPLY_DRAIN_SLACK;
            match rx.recv_timeout(wait) {
                Ok(reply) => Ok(reply),
                Err(RecvTimeoutError::Timeout) => Err(PredictError::DeadlineExceeded),
                Err(RecvTimeoutError::Disconnected) => Err(PredictError::ShuttingDown),
            }
        }
    }
}

fn merger_loop(
    d: usize,
    r: usize,
    max_batch_edges: usize,
    timeout_ms: u64,
    rx: Receiver<PredictRequest>,
    pool_tx: SyncSender<MergedBatch>,
) {
    // Default-deadline stamp for raw-sender traffic (requests admitted
    // through the server's submit APIs were already stamped at submission,
    // so their time in the queue counts against the deadline).
    let stamp = |mut req: PredictRequest| -> PredictRequest {
        if req.deadline.is_none() && timeout_ms > 0 {
            req = req.with_deadline_ms(timeout_ms);
        }
        req
    };
    loop {
        // Block for the first request of the batch.
        let first = match rx.recv() {
            Ok(req) => stamp(req),
            Err(_) => return, // all senders gone
        };
        let mut batch = vec![first];
        let mut edge_count = batch[0].edges.len();
        // Greedily drain whatever else is queued (dynamic batching).
        while edge_count < max_batch_edges {
            match rx.try_recv() {
                Ok(req) => {
                    let req = stamp(req);
                    edge_count += req.edges.len();
                    batch.push(req);
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        // Blocks when the scoring pool is saturated — backpressure that
        // propagates to the bounded request queue and its submitters.
        if pool_tx.send(merge_batch(d, r, batch)).is_err() {
            return; // scoring pool gone (respawn budget exhausted)
        }
    }
}

/// Validate one request against the trained-side feature dimensions.
fn validate(d: usize, r: usize, req: &PredictRequest) -> Verdict {
    if req.expired() {
        return Verdict::Expired;
    }
    if let Some(f) = req.start_features.iter().find(|f| f.len() != d) {
        return Verdict::Invalid(format!(
            "start-vertex features must have {d} columns, got {}",
            f.len()
        ));
    }
    if let Some(f) = req.end_features.iter().find(|f| f.len() != r) {
        return Verdict::Invalid(format!(
            "end-vertex features must have {r} columns, got {}",
            f.len()
        ));
    }
    let (u, v) = (req.start_features.len(), req.end_features.len());
    if let Some(&(s, e)) = req
        .edges
        .iter()
        .find(|&&(s, e)| s as usize >= u || e as usize >= v)
    {
        return Verdict::Invalid(format!(
            "edge ({s}, {e}) references a vertex outside the request's {u}×{v} vertex lists"
        ));
    }
    Verdict::Ok
}

/// Validate each request and merge the batch into one dataset with offset
/// vertex indices. Invalid and expired requests are excluded from scoring —
/// the merged matrices are sized to the surviving requests only, so no
/// kernel row is ever computed (or cached) for a phantom vertex.
fn merge_batch(d: usize, r: usize, batch: Vec<PredictRequest>) -> MergedBatch {
    let verdicts: Vec<Verdict> = batch.iter().map(|req| validate(d, r, req)).collect();
    let (ds, spans) = build_dataset(d, r, &batch, &verdicts);
    MergedBatch { ds, spans, verdicts, requests: batch }
}

/// Build the merged dataset over the `Verdict::Ok` requests.
fn build_dataset(
    d: usize,
    r: usize,
    batch: &[PredictRequest],
    verdicts: &[Verdict],
) -> (Option<Dataset>, Vec<usize>) {
    let ok = |i: usize| matches!(verdicts[i], Verdict::Ok);
    let ok_reqs = || batch.iter().enumerate().filter(|&(i, _)| ok(i)).map(|(_, req)| req);
    let total_starts: usize = ok_reqs().map(|b| b.start_features.len()).sum();
    let total_ends: usize = ok_reqs().map(|b| b.end_features.len()).sum();
    let total_edges: usize = ok_reqs().map(|b| b.edges.len()).sum();

    let mut start_features = Matrix::zeros(total_starts, d);
    let mut end_features = Matrix::zeros(total_ends, r);
    let mut start_idx = Vec::with_capacity(total_edges);
    let mut end_idx = Vec::with_capacity(total_edges);
    let mut start_off = 0u32;
    let mut end_off = 0u32;
    let mut spans = Vec::with_capacity(batch.len());

    for (i, req) in batch.iter().enumerate() {
        if !ok(i) {
            spans.push(0);
            continue;
        }
        for (j, f) in req.start_features.iter().enumerate() {
            start_features.row_mut(start_off as usize + j).copy_from_slice(f);
        }
        for (j, f) in req.end_features.iter().enumerate() {
            end_features.row_mut(end_off as usize + j).copy_from_slice(f);
        }
        for &(s, e) in &req.edges {
            start_idx.push(start_off + s);
            end_idx.push(end_off + e);
        }
        spans.push(req.edges.len());
        start_off += req.start_features.len() as u32;
        end_off += req.end_features.len() as u32;
    }

    let n_scored = start_idx.len();
    let ds = (n_scored > 0).then(|| Dataset {
        start_features,
        end_features,
        start_idx,
        end_idx,
        labels: vec![0.0; n_scored],
        name: "server-batch".into(),
    });
    (ds, spans)
}

/// Score one merged batch on a pool worker and scatter the typed replies.
fn score_batch(
    slot: &Mutex<ContextSlot>,
    mut batch: MergedBatch,
    stats: &ServerStats,
    faults: &FaultPlan,
    dims: (usize, usize),
) {
    // Injected faults first: a planned panic must cost exactly this batch
    // (the supervisor respawns the worker), a planned stall models a
    // straggler that pushes requests past their deadlines.
    faults.trip_batch_start();

    // Second deadline pass: shed whatever expired after merging (queueing
    // to the pool, or an injected stall) instead of computing it.
    let mut newly_expired = false;
    for (req, v) in batch.requests.iter().zip(batch.verdicts.iter_mut()) {
        if matches!(v, Verdict::Ok) && req.expired() {
            *v = Verdict::Expired;
            newly_expired = true;
            stats.shed.fetch_add(1, Ordering::Relaxed);
        }
    }
    if newly_expired {
        let (ds, spans) = build_dataset(dims.0, dims.1, &batch.requests, &batch.verdicts);
        batch.ds = ds;
        batch.spans = spans;
    }

    // Pin one generation for the whole batch: a concurrent swap_model takes
    // effect from the next batch on, and no reply mixes two models. The
    // slot lock is held only for the Arc clone.
    let (generation, ctx) = {
        let guard = slot.lock().unwrap_or_else(|p| p.into_inner());
        (guard.generation, Arc::clone(&guard.ctx))
    };
    let scores = match &batch.ds {
        Some(ds) => ctx.predict_batch(ds),
        None => Vec::new(),
    };
    let n_scored = scores.len();
    let expired = batch.verdicts.iter().filter(|v| matches!(v, Verdict::Expired)).count();

    // Update stats BEFORE delivering replies so a client that observed its
    // reply also observes the counters.
    stats.requests.fetch_add(batch.requests.len(), Ordering::Relaxed);
    stats.batches.fetch_add(1, Ordering::Relaxed);
    stats.edges_scored.fetch_add(n_scored, Ordering::Relaxed);
    stats.deadline_expired.fetch_add(expired, Ordering::Relaxed);

    let mut cursor = 0usize;
    for (req, (&span, verdict)) in
        batch.requests.iter().zip(batch.spans.iter().zip(&batch.verdicts))
    {
        match verdict {
            Verdict::Ok => {
                req.answer(Ok(scores[cursor..cursor + span].to_vec()), generation);
                cursor += span;
            }
            Verdict::Invalid(reason) => {
                req.answer(Err(PredictError::InvalidRequest(reason.clone())), generation);
            }
            Verdict::Expired => {
                req.answer(Err(PredictError::DeadlineExceeded), generation);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gvt::KronIndex;
    use crate::kernels::KernelKind;
    use crate::util::rng::Pcg32;
    use std::sync::mpsc::channel;

    fn toy_model(seed: u64) -> DualModel {
        let mut rng = Pcg32::seeded(seed);
        let (m, q, n) = (6, 5, 15);
        DualModel {
            dual_coef: rng.normal_vec(n),
            train_start_features: Matrix::from_fn(m, 3, |_, _| rng.normal()),
            train_end_features: Matrix::from_fn(q, 2, |_, _| rng.normal()),
            train_idx: KronIndex::new(
                (0..n).map(|_| rng.below(q) as u32).collect(),
                (0..n).map(|_| rng.below(m) as u32).collect(),
            ),
            kernel_d: KernelKind::Gaussian { gamma: 0.3 },
            kernel_t: KernelKind::Gaussian { gamma: 0.3 },
            pairwise: crate::gvt::PairwiseKernelKind::Kronecker,
        }
    }

    fn request_data(
        rng: &mut Pcg32,
        u: usize,
        v: usize,
        t: usize,
    ) -> (Vec<Vec<f64>>, Vec<Vec<f64>>, Vec<(u32, u32)>) {
        let sf: Vec<Vec<f64>> = (0..u).map(|_| rng.normal_vec(3)).collect();
        let ef: Vec<Vec<f64>> = (0..v).map(|_| rng.normal_vec(2)).collect();
        let edges: Vec<(u32, u32)> =
            (0..t).map(|_| (rng.below(u) as u32, rng.below(v) as u32)).collect();
        (sf, ef, edges)
    }

    #[test]
    fn server_matches_direct_prediction() {
        let model = toy_model(1100);
        let mut rng = Pcg32::seeded(1101);
        let (sf, ef, edges) = request_data(&mut rng, 4, 3, 10);

        // direct prediction for reference
        let ds = Dataset {
            start_features: Matrix::from_fn(4, 3, |i, j| sf[i][j]),
            end_features: Matrix::from_fn(3, 2, |i, j| ef[i][j]),
            start_idx: edges.iter().map(|&(s, _)| s).collect(),
            end_idx: edges.iter().map(|&(_, e)| e).collect(),
            labels: vec![0.0; 10],
            name: "direct".into(),
        };
        let direct = model.predict(&ds);

        let server = PredictServer::start(model, ServerConfig::default());
        let served = server.predict_blocking(sf, ef, edges).unwrap();
        // the toy model has no zero duals, so this is exact, not just close
        assert_eq!(served, direct);
        assert_eq!(server.stats().requests.load(Ordering::Relaxed), 1);
        server.shutdown();
    }

    #[test]
    fn warm_cache_replies_are_bitwise_identical_to_cold() {
        let model = toy_model(1106);
        let mut rng = Pcg32::seeded(1107);
        let (sf, ef, edges) = request_data(&mut rng, 4, 4, 12);
        let server = PredictServer::start(
            model,
            ServerConfig {
                compute: Compute::threads(2).with_cache_vertices(64),
                ..Default::default()
            },
        );
        let cold = server.predict_blocking(sf.clone(), ef.clone(), edges.clone()).unwrap();
        let warm = server.predict_blocking(sf, ef, edges).unwrap();
        assert_eq!(cold, warm);
        let st = server.stats();
        let hits = st.cache_hits.load(Ordering::Relaxed);
        let misses = st.cache_misses.load(Ordering::Relaxed);
        assert_eq!(hits + misses, 16, "two rounds × 4+4 vertex lookups");
        assert!(misses <= 8, "only the cold request may compute rows, got {misses}");
        assert!(hits >= 8, "the warm request must hit, got {hits}");
        server.shutdown();
    }

    #[test]
    fn concurrent_requests_are_all_answered() {
        let model = toy_model(1102);
        let server = PredictServer::start(
            model,
            ServerConfig {
                max_batch_edges: 1000,
                workers: 3,
                compute: Compute::threads(2),
                ..Default::default()
            },
        );
        let sender = server.sender();
        let mut replies = Vec::new();
        let mut rng = Pcg32::seeded(1103);
        for _ in 0..20 {
            let (sf, ef, edges) = request_data(&mut rng, 3, 3, 6);
            let (tx, rx) = channel();
            sender.send(PredictRequest::new(sf, ef, edges, tx)).unwrap();
            replies.push(rx);
        }
        drop(sender); // release our clone so shutdown() can disconnect the merger
        for rx in replies {
            let reply = rx.recv().unwrap();
            assert_eq!(reply.generation, 0, "no swap happened");
            let scores = reply.result.unwrap();
            assert_eq!(scores.len(), 6);
            assert!(scores.iter().all(|s| s.is_finite()));
        }
        let total = server.stats().edges_scored.load(Ordering::Relaxed);
        assert_eq!(total, 120);
        server.shutdown();
    }

    #[test]
    fn invalid_request_gets_typed_error_without_poisoning_batch() {
        let model = toy_model(1104);
        let server = PredictServer::start(model, ServerConfig::default());
        // bad: edge references missing vertex
        let bad = server.predict_blocking(vec![vec![0.0; 3]], vec![vec![0.0; 2]], vec![(0, 5)]);
        match bad {
            Err(PredictError::InvalidRequest(reason)) => {
                assert!(reason.contains("edge (0, 5)"), "{reason}")
            }
            other => panic!("expected InvalidRequest, got {other:?}"),
        }
        // bad: wrong feature dimension
        let bad_dim =
            server.predict_blocking(vec![vec![0.0; 7]], vec![vec![0.0; 2]], vec![(0, 0)]);
        match bad_dim {
            Err(PredictError::InvalidRequest(reason)) => {
                assert!(reason.contains("3 columns"), "{reason}")
            }
            other => panic!("expected InvalidRequest, got {other:?}"),
        }
        // a good request still works afterwards
        let mut rng = Pcg32::seeded(1105);
        let (sf, ef, edges) = request_data(&mut rng, 2, 2, 3);
        let good = server.predict_blocking(sf, ef, edges).unwrap();
        assert!(good.iter().all(|s| s.is_finite()));
        server.shutdown();
    }

    #[test]
    fn expired_deadline_is_typed_and_sheds_work() {
        let model = toy_model(1110);
        let mut rng = Pcg32::seeded(1111);
        let (sf, ef, edges) = request_data(&mut rng, 3, 3, 5);
        let server = PredictServer::start(model, ServerConfig::default());
        let (tx, rx) = channel();
        let req = PredictRequest::new(sf.clone(), ef.clone(), edges.clone(), tx)
            .with_deadline_ms(0); // expired on arrival — deterministic
        server.submit(req).unwrap();
        let reply = rx.recv().unwrap();
        assert_eq!(reply.result, Err(PredictError::DeadlineExceeded));
        let st = server.stats();
        assert_eq!(st.deadline_expired.load(Ordering::Relaxed), 1);
        assert_eq!(st.edges_scored.load(Ordering::Relaxed), 0, "expired work is never computed");
        // an undeadlined request on the same server still scores
        let ok = server.predict_blocking(sf, ef, edges).unwrap();
        assert_eq!(ok.len(), 5);
        server.shutdown();
    }

    #[test]
    fn shutdown_after_heavy_traffic_loses_nothing() {
        let model = toy_model(1108);
        let server = PredictServer::start(
            model,
            ServerConfig {
                max_batch_edges: 64,
                workers: 4,
                max_queue: 8,
                compute: Compute::serial().with_cache_vertices(16),
                ..Default::default()
            },
        );
        let mut rng = Pcg32::seeded(1109);
        let mut replies = Vec::new();
        let sender = server.sender();
        for _ in 0..40 {
            let (sf, ef, edges) = request_data(&mut rng, 2, 2, 4);
            let (tx, rx) = channel();
            sender.send(PredictRequest::new(sf, ef, edges, tx)).unwrap();
            replies.push(rx);
        }
        drop(sender);
        server.shutdown(); // graceful: drains queue + pool before returning
        for rx in replies {
            let reply = rx.recv().expect("reply delivered before shutdown completed");
            assert_eq!(reply.result.expect("scored").len(), 4);
        }
    }
}
